//! Structured experiment logging: CSV + JSONL writers.

use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;

use crate::util::json::Json;

/// Append-mode CSV writer with a fixed header.
pub struct CsvLog {
    file: std::fs::File,
    columns: usize,
}

/// Quote a CSV field per RFC 4180 *only when it needs it* (embedded
/// comma, double quote, or newline) — plain numeric fields pass through
/// byte-identical, so existing logs keep their exact shape.
fn csv_field(value: &str) -> String {
    if value.contains(',') || value.contains('"') || value.contains('\n') || value.contains('\r')
    {
        format!("\"{}\"", value.replace('"', "\"\""))
    } else {
        value.to_string()
    }
}

impl CsvLog {
    pub fn create(path: &Path, header: &[&str]) -> Result<CsvLog> {
        // Failing to create the directory used to be swallowed with
        // `.ok()`, deferring to a baffling "No such file" from the file
        // create below; surface the real cause. Bare filenames have an
        // empty parent, which is not a directory to create.
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).with_context(|| {
                    format!("creating log directory {}", parent.display())
                })?;
            }
        }
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let header: Vec<String> = header.iter().map(|h| csv_field(h)).collect();
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvLog { file, columns: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> Result<()> {
        anyhow::ensure!(
            values.len() == self.columns,
            "row has {} values, header has {}",
            values.len(),
            self.columns
        );
        let quoted: Vec<String> = values.iter().map(|v| csv_field(v)).collect();
        writeln!(self.file, "{}", quoted.join(","))?;
        Ok(())
    }

    pub fn row_f64(&mut self, values: &[f64]) -> Result<()> {
        self.row(&values.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }
}

/// Canonical per-epoch CSV header, including the elastic columns
/// (active-λ per epoch; churn/recovery totals live in the run-level
/// summary — [`crate::stats::churn_summary`]).
pub const EPOCH_COLUMNS: [&str; 6] =
    ["epoch", "sim_time_s", "train_loss", "test_loss", "test_error_pct", "active_lambda"];

/// Render one [`EpochStat`] as a row under [`EPOCH_COLUMNS`].
///
/// [`EpochStat`]: crate::coordinator::engine_sim::EpochStat
pub fn epoch_row(e: &crate::coordinator::engine_sim::EpochStat) -> Vec<String> {
    let opt = |v: Option<f64>| v.map(|x| format!("{x}")).unwrap_or_default();
    vec![
        e.epoch.to_string(),
        format!("{}", e.sim_time),
        format!("{}", e.train_loss),
        opt(e.test_loss),
        opt(e.test_error_pct),
        e.active_lambda.to_string(),
    ]
}

/// Canonical per-learner communication CSV header: bytes pushed onto the
/// wire (compressed sizes) and the final error-feedback residual norm
/// (0 when no codec is on or residuals are not engine-observable).
pub const COMM_COLUMNS: [&str; 3] = ["learner", "compressed_bytes", "residual_norm"];

/// Render one learner's comm stats as a row under [`COMM_COLUMNS`].
pub fn comm_row(learner: usize, compressed_bytes: f64, residual_norm: f64) -> Vec<String> {
    vec![
        learner.to_string(),
        format!("{compressed_bytes}"),
        format!("{residual_norm}"),
    ]
}

/// Append-mode JSONL writer.
pub struct JsonlLog {
    file: std::fs::File,
}

impl JsonlLog {
    pub fn create(path: &Path) -> Result<JsonlLog> {
        // Same deferred-error bug as [`CsvLog::create`]: propagate the
        // directory failure instead of `.ok()`-ing it away.
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).with_context(|| {
                    format!("creating log directory {}", parent.display())
                })?;
            }
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        Ok(JsonlLog { file })
    }

    pub fn record(&mut self, value: &Json) -> Result<()> {
        writeln!(self.file, "{}", value.to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("rudra_test_log");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let mut log = CsvLog::create(&path, &["epoch", "loss"]).unwrap();
        log.row_f64(&[1.0, 0.5]).unwrap();
        log.row_f64(&[2.0, 0.25]).unwrap();
        assert!(log.row_f64(&[1.0]).is_err(), "column count enforced");
        drop(log);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "epoch,loss\n1,0.5\n2,0.25\n");
    }

    #[test]
    fn epoch_rows_fit_the_header() {
        let e = crate::coordinator::engine_sim::EpochStat {
            epoch: 3,
            sim_time: 12.5,
            train_loss: 0.75,
            test_loss: None,
            test_error_pct: Some(18.0),
            active_lambda: 6,
        };
        let row = epoch_row(&e);
        assert_eq!(row.len(), EPOCH_COLUMNS.len());
        assert_eq!(row[0], "3");
        assert_eq!(row[3], "", "missing eval renders empty");
        assert_eq!(row[5], "6");
        // and the CsvLog accepts it under the canonical header
        let dir = std::env::temp_dir().join("rudra_test_log");
        std::fs::create_dir_all(&dir).unwrap();
        let mut log = CsvLog::create(&dir.join("epochs.csv"), &EPOCH_COLUMNS).unwrap();
        log.row(&row).unwrap();
    }

    #[test]
    fn comm_rows_fit_the_header() {
        let row = comm_row(3, 48.0e6, 0.25);
        assert_eq!(row.len(), COMM_COLUMNS.len());
        assert_eq!(row[0], "3");
        assert_eq!(row[1], "48000000");
        assert_eq!(row[2], "0.25");
        let dir = std::env::temp_dir().join("rudra_test_log");
        std::fs::create_dir_all(&dir).unwrap();
        let mut log = CsvLog::create(&dir.join("comm.csv"), &COMM_COLUMNS).unwrap();
        log.row(&row).unwrap();
    }

    // Regression: `create_dir_all` failures were `.ok()`-ed away, so a
    // parent path blocked by a regular *file* surfaced later as a
    // baffling error from `File::create`. Both writers now propagate the
    // directory error with the actual path in context.
    #[test]
    fn create_surfaces_directory_errors() {
        let dir = std::env::temp_dir().join("rudra_test_log_direrr");
        std::fs::create_dir_all(&dir).unwrap();
        let blocker = dir.join("not_a_dir");
        std::fs::write(&blocker, b"file, not directory").unwrap();
        let under = blocker.join("x.csv");
        let err = CsvLog::create(&under, &["a"]).unwrap_err();
        assert!(err.to_string().contains("log directory"), "{err:#}");
        let err = JsonlLog::create(&blocker.join("x.jsonl")).unwrap_err();
        assert!(err.to_string().contains("log directory"), "{err:#}");
        // bare filenames (empty parent) must not trip the directory path
        // (`create_dir_all("")` errors, which the old `.ok()` also hid)
        CsvLog::create(Path::new("rudra_test_bare_tmp.csv"), &["a"]).unwrap();
        std::fs::remove_file("rudra_test_bare_tmp.csv").ok();
    }

    // Regression: fields with embedded commas/quotes/newlines were
    // written raw, silently corrupting the column structure. They now get
    // RFC-4180 quoting; plain fields stay byte-identical (see
    // `csv_roundtrip`).
    #[test]
    fn csv_quotes_special_fields_only() {
        assert_eq!(csv_field("1.25"), "1.25");
        assert_eq!(csv_field("a,b"), "\"a,b\"");
        assert_eq!(csv_field("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(csv_field("two\nlines"), "\"two\nlines\"");
        let dir = std::env::temp_dir().join("rudra_test_log");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("quoted.csv");
        let mut log = CsvLog::create(&path, &["label", "loss"]).unwrap();
        log.row(&["(σ̄=1, μ=4, λ=30) 1-softsync/base".to_string(), "0.5".to_string()])
            .unwrap();
        drop(log);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "label,loss\n\"(σ̄=1, μ=4, λ=30) 1-softsync/base\",0.5\n");
    }

    #[test]
    fn jsonl_lines_parse_back() {
        let dir = std::env::temp_dir().join("rudra_test_log");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        let mut log = JsonlLog::create(&path).unwrap();
        log.record(&Json::obj(vec![("a", Json::num(1.0))])).unwrap();
        log.record(&Json::obj(vec![("a", Json::num(2.0))])).unwrap();
        drop(log);
        let text = std::fs::read_to_string(&path).unwrap();
        for line in text.lines() {
            Json::parse(line).unwrap();
        }
        assert_eq!(text.lines().count(), 2);
    }
}
