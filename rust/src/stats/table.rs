//! Plain-text table renderer for the paper-reproduction benches
//! (each bench prints `paper | reproduced` rows).

/// A simple column-aligned table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, values: Vec<String>) -> &mut Self {
        assert_eq!(values.len(), self.header.len(), "column count mismatch");
        self.rows.push(values);
        self
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (cell, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {cell:w$} |", w = w));
            }
            s
        };
        out.push_str(&line(&self.header, &widths));
        out.push('\n');
        out.push_str("|");
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with `digits` decimal places.
pub fn f(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

/// Format a percentage.
pub fn pct(v: f64) -> String {
    format!("{v:.2}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["proto", "err"]);
        t.row(vec!["hardsync".into(), pct(18.56)]);
        t.row(vec!["1-softsync".into(), pct(18.09)]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // all lines same width
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(s.contains("18.09%"));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn enforces_columns() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
