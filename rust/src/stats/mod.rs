//! The Statistics Server (§3.2) in library form: evaluation, metric
//! history, structured logging, and the table renderer the benches use.

pub mod log;
pub mod table;

use anyhow::Result;

use crate::coordinator::engine_sim::Evaluator;
use crate::data::loader::ImageSet;
use crate::data::sampler::EvalIter;
use crate::params::FlatVec;
use crate::runtime::EvalExec;

/// Statistics-server evaluator over the held-out image set: runs the AOT
/// eval graph in fixed-size chunks and scores only valid samples.
pub struct ImageEvaluator<'a> {
    pub exec: &'a EvalExec,
    pub set: &'a ImageSet,
    pub batch: usize,
}

impl<'a> ImageEvaluator<'a> {
    pub fn new(exec: &'a EvalExec, set: &'a ImageSet, batch: usize) -> Self {
        ImageEvaluator { exec, set, batch }
    }
}

impl<'a> Evaluator for ImageEvaluator<'a> {
    fn eval(&mut self, theta: &FlatVec) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut n = 0usize;
        for (batch, valid) in EvalIter::new(self.set, self.batch) {
            let (loss, corr) = self.exec.run(theta, &batch.images, &[], &batch.labels)?;
            for i in 0..valid {
                loss_sum += loss[i] as f64;
                correct += corr[i] as f64;
            }
            n += valid;
        }
        anyhow::ensure!(n > 0, "empty eval set");
        let mean_loss = loss_sum / n as f64;
        let err_pct = 100.0 * (1.0 - correct / n as f64);
        Ok((mean_loss, err_pct))
    }
}

/// Evaluator over token batches (the LM example): scores fixed windows
/// deterministically sampled from the held-out tail of the corpus.
pub struct TokenEvaluator<'a> {
    pub exec: &'a EvalExec,
    pub windows: Vec<(Vec<i32>, Vec<i32>)>,
}

impl<'a> TokenEvaluator<'a> {
    /// Carve `n_windows` non-overlapping (tokens, targets) windows of
    /// `batch × seq` from the corpus tail.
    pub fn new(
        exec: &'a EvalExec,
        corpus: &crate::data::loader::Corpus,
        batch: usize,
        seq: usize,
        n_windows: usize,
    ) -> Result<Self> {
        let need = n_windows * batch * (seq + 1);
        anyhow::ensure!(
            corpus.bytes.len() >= need,
            "corpus too small for {n_windows} eval windows"
        );
        let tail = &corpus.bytes[corpus.bytes.len() - need..];
        let mut windows = Vec::with_capacity(n_windows);
        let mut off = 0;
        for _ in 0..n_windows {
            let mut tokens = Vec::with_capacity(batch * seq);
            let mut targets = Vec::with_capacity(batch * seq);
            for _ in 0..batch {
                for s in 0..seq {
                    tokens.push(tail[off + s] as i32);
                    targets.push(tail[off + s + 1] as i32);
                }
                off += seq + 1;
            }
            windows.push((tokens, targets));
        }
        Ok(TokenEvaluator { exec, windows })
    }
}

impl<'a> Evaluator for TokenEvaluator<'a> {
    fn eval(&mut self, theta: &FlatVec) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut n = 0usize;
        for (tokens, targets) in &self.windows {
            let (loss, corr) = self.exec.run(theta, &[], tokens, targets)?;
            loss_sum += loss.iter().map(|&x| x as f64).sum::<f64>();
            correct += corr.iter().map(|&x| x as f64).sum::<f64>();
            n += loss.len();
        }
        anyhow::ensure!(n > 0, "no eval windows");
        Ok((loss_sum / n as f64, 100.0 * (1.0 - correct / n as f64)))
    }
}

/// One-line report of an elastic run's churn: event counts by kind plus
/// mean recovery time, e.g. `3 churn events (2 kills, 1 rejoins), mean
/// recovery 12.3s`. Static runs render as `no churn`.
pub fn churn_summary(
    churn: &[crate::elastic::membership::ChurnRecord],
    recovery_secs: &[f64],
) -> String {
    use crate::elastic::membership::ChurnKind;
    if churn.is_empty() {
        return "no churn".to_string();
    }
    let count = |k: ChurnKind| churn.iter().filter(|c| c.kind == k).count();
    let mut parts = Vec::new();
    for (kind, noun) in [
        (ChurnKind::Kill, "kills"),
        (ChurnKind::Rejoin, "rejoins"),
        (ChurnKind::Join, "joins"),
        (ChurnKind::Suspect, "suspects"),
        (ChurnKind::Recover, "recovers"),
    ] {
        let n = count(kind);
        if n > 0 {
            parts.push(format!("{n} {noun}"));
        }
    }
    let mut out = format!("{} churn events ({})", churn.len(), parts.join(", "));
    if !recovery_secs.is_empty() {
        out.push_str(&format!(
            ", mean recovery {}",
            crate::util::fmt_secs(crate::util::mean(recovery_secs))
        ));
    }
    out
}

/// One-line report of per-shard applyUpdate counts from a sharded-server
/// run. Lockstep shards render compactly (`4 shards × 120 updates`); any
/// divergence — which would indicate a routing bug — is spelled out in
/// full so it cannot hide in a summary.
pub fn shard_update_summary(shard_updates: &[u64]) -> String {
    match (shard_updates.iter().min(), shard_updates.iter().max()) {
        (Some(min), Some(max)) if min == max => {
            format!("{} shards × {} updates", shard_updates.len(), max)
        }
        (Some(_), Some(_)) => {
            format!("{} shards, DIVERGENT updates {:?}", shard_updates.len(), shard_updates)
        }
        _ => "0 shards".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn churn_summary_renders_counts_and_recovery() {
        use crate::elastic::membership::{ChurnKind, ChurnRecord};
        assert_eq!(churn_summary(&[], &[]), "no churn");
        let rec = |kind, learner| ChurnRecord { at: 1.0, learner, kind, active_after: 3 };
        let log = vec![
            rec(ChurnKind::Kill, 0),
            rec(ChurnKind::Kill, 1),
            rec(ChurnKind::Rejoin, 0),
        ];
        let s = churn_summary(&log, &[10.0, 14.0]);
        assert!(s.contains("3 churn events"), "{s}");
        assert!(s.contains("2 kills") && s.contains("1 rejoins"), "{s}");
        assert!(s.contains("12.00s"), "{s}");
    }

    #[test]
    fn shard_summary_lockstep_and_divergent() {
        assert_eq!(shard_update_summary(&[120, 120, 120, 120]), "4 shards × 120 updates");
        assert_eq!(shard_update_summary(&[7]), "1 shards × 7 updates");
        let s = shard_update_summary(&[3, 4]);
        assert!(s.contains("DIVERGENT") && s.contains("[3, 4]"), "{s}");
        assert_eq!(shard_update_summary(&[]), "0 shards");
    }
}
