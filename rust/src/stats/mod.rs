//! The Statistics Server (§3.2) in library form: evaluation, metric
//! history, structured logging, and the table renderer the benches use.

pub mod log;
pub mod table;

use anyhow::Result;

use crate::coordinator::engine_sim::Evaluator;
use crate::data::loader::ImageSet;
use crate::data::sampler::EvalIter;
use crate::params::FlatVec;
use crate::runtime::EvalExec;

/// Statistics-server evaluator over the held-out image set: runs the AOT
/// eval graph in fixed-size chunks and scores only valid samples.
pub struct ImageEvaluator<'a> {
    pub exec: &'a EvalExec,
    pub set: &'a ImageSet,
    pub batch: usize,
}

impl<'a> ImageEvaluator<'a> {
    pub fn new(exec: &'a EvalExec, set: &'a ImageSet, batch: usize) -> Self {
        ImageEvaluator { exec, set, batch }
    }
}

impl<'a> Evaluator for ImageEvaluator<'a> {
    fn eval(&mut self, theta: &FlatVec) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut n = 0usize;
        for (batch, valid) in EvalIter::new(self.set, self.batch) {
            let (loss, corr) = self.exec.run(theta, &batch.images, &[], &batch.labels)?;
            for i in 0..valid {
                loss_sum += loss[i] as f64;
                correct += corr[i] as f64;
            }
            n += valid;
        }
        anyhow::ensure!(n > 0, "empty eval set");
        let mean_loss = loss_sum / n as f64;
        let err_pct = 100.0 * (1.0 - correct / n as f64);
        Ok((mean_loss, err_pct))
    }
}

/// Evaluator over token batches (the LM example): scores fixed windows
/// deterministically sampled from the held-out tail of the corpus.
pub struct TokenEvaluator<'a> {
    pub exec: &'a EvalExec,
    pub windows: Vec<(Vec<i32>, Vec<i32>)>,
}

impl<'a> TokenEvaluator<'a> {
    /// Carve `n_windows` non-overlapping (tokens, targets) windows of
    /// `batch × seq` from the corpus tail.
    pub fn new(
        exec: &'a EvalExec,
        corpus: &crate::data::loader::Corpus,
        batch: usize,
        seq: usize,
        n_windows: usize,
    ) -> Result<Self> {
        let need = n_windows * batch * (seq + 1);
        anyhow::ensure!(
            corpus.bytes.len() >= need,
            "corpus too small for {n_windows} eval windows"
        );
        let tail = &corpus.bytes[corpus.bytes.len() - need..];
        let mut windows = Vec::with_capacity(n_windows);
        let mut off = 0;
        for _ in 0..n_windows {
            let mut tokens = Vec::with_capacity(batch * seq);
            let mut targets = Vec::with_capacity(batch * seq);
            for _ in 0..batch {
                for s in 0..seq {
                    tokens.push(tail[off + s] as i32);
                    targets.push(tail[off + s + 1] as i32);
                }
                off += seq + 1;
            }
            windows.push((tokens, targets));
        }
        Ok(TokenEvaluator { exec, windows })
    }
}

impl<'a> Evaluator for TokenEvaluator<'a> {
    fn eval(&mut self, theta: &FlatVec) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut n = 0usize;
        for (tokens, targets) in &self.windows {
            let (loss, corr) = self.exec.run(theta, &[], tokens, targets)?;
            loss_sum += loss.iter().map(|&x| x as f64).sum::<f64>();
            correct += corr.iter().map(|&x| x as f64).sum::<f64>();
            n += loss.len();
        }
        anyhow::ensure!(n > 0, "no eval windows");
        Ok((loss_sum / n as f64, 100.0 * (1.0 - correct / n as f64)))
    }
}

/// One-line report of an elastic run's churn: event counts by kind plus
/// mean recovery time, e.g. `3 churn events (2 kills, 1 rejoins), mean
/// recovery 12.3s`. Static runs render as `no churn`.
pub fn churn_summary(
    churn: &[crate::elastic::membership::ChurnRecord],
    recovery_secs: &[f64],
) -> String {
    use crate::elastic::membership::ChurnKind;
    if churn.is_empty() {
        return "no churn".to_string();
    }
    let count = |k: ChurnKind| churn.iter().filter(|c| c.kind == k).count();
    let mut parts = Vec::new();
    for (kind, noun) in [
        (ChurnKind::Kill, "kills"),
        (ChurnKind::Rejoin, "rejoins"),
        (ChurnKind::Join, "joins"),
        (ChurnKind::Suspect, "suspects"),
        (ChurnKind::Recover, "recovers"),
    ] {
        let n = count(kind);
        if n > 0 {
            parts.push(format!("{n} {noun}"));
        }
    }
    let mut out = format!("{} churn events ({})", churn.len(), parts.join(", "));
    if !recovery_secs.is_empty() {
        out.push_str(&format!(
            ", mean recovery {}",
            crate::util::fmt_secs(crate::util::mean(recovery_secs))
        ));
    }
    out
}

/// One-line report of a run's straggler profile: the per-learner compute
/// utilization spread plus backup-sync's dropped-gradient accounting,
/// e.g. `learner util 9–97% (mean 21%), 42 gradients dropped (worst:
/// learner 0 × 40)`. Homogeneous, drop-free runs render as `balanced
/// (util ≈ 87%)`.
pub fn straggler_summary(utilization: &[f64], dropped_by: &[u64]) -> String {
    if utilization.is_empty() {
        return "no learners".to_string();
    }
    let pct = |x: f64| (x * 100.0).round() as i64;
    let min = utilization.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = utilization.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mean = crate::util::mean(utilization);
    let total_dropped: u64 = dropped_by.iter().sum();
    // a spread under 10 points of utilization with no drops is balanced
    if max - min < 0.10 && total_dropped == 0 {
        return format!("balanced (util ≈ {}%)", pct(mean));
    }
    let mut out = format!(
        "learner util {}–{}% (mean {}%)",
        pct(min),
        pct(max),
        pct(mean)
    );
    if total_dropped > 0 {
        let (worst, count) = dropped_by
            .iter()
            .enumerate()
            .max_by_key(|&(_, c)| c)
            .map(|(l, &c)| (l, c))
            .unwrap_or((0, 0));
        out.push_str(&format!(
            ", {total_dropped} gradients dropped (worst: learner {worst} × {count})"
        ));
    }
    out
}

/// One-line report of a chaos run's fault-plane accounting: messages
/// routed, losses, the retry layer's work (with the worst per-learner
/// retransmit column), dedup suppressions, and the byte overhead the
/// retries added, e.g. `1200 routed, 23 dropped (2 exhausted),
/// 57 retransmits (worst: learner 3 × 11), 4 dups injected,
/// 9 dedup-dropped, retry overhead 1.2MB`. A run whose fault plane never
/// fired renders as `fault plane armed, no faults fired`.
pub fn fault_summary(f: &crate::netsim::reliable::FaultStats) -> String {
    if f.retransmits == 0 && f.dropped == 0 && f.dups_injected == 0 && f.dedup_dropped == 0 {
        return "fault plane armed, no faults fired".to_string();
    }
    let mut out = format!("{} routed, {} dropped", f.sent, f.dropped);
    if f.exhausted > 0 {
        out.push_str(&format!(" ({} exhausted)", f.exhausted));
    }
    out.push_str(&format!(", {} retransmits", f.retransmits));
    if let Some((worst, &count)) = f
        .retransmits_by
        .iter()
        .enumerate()
        .max_by_key(|&(_, c)| c)
        .filter(|&(_, &c)| c > 0)
    {
        out.push_str(&format!(" (worst: learner {worst} × {count})"));
    }
    out.push_str(&format!(
        ", {} dups injected, {} dedup-dropped, retry overhead {}",
        f.dups_injected,
        f.dedup_dropped,
        crate::util::fmt_bytes(f.retry_bytes)
    ));
    out
}

/// One-line report of the adaptive-n controller's trajectory, e.g.
/// `adaptive-n: 3 retunes, n 8 → 2, ⟨σ⟩ 7.6 → 2.1`. An empty log renders
/// as `adaptive-n: no decisions`.
pub fn adaptive_summary(log: &[crate::straggler::adaptive::AdaptiveRecord]) -> String {
    let (Some(first), Some(last)) = (log.first(), log.last()) else {
        return "adaptive-n: no decisions".to_string();
    };
    let retunes = log.iter().filter(|r| r.new_n != r.old_n).count();
    format!(
        "adaptive-n: {retunes} retunes, n {} → {}, ⟨σ⟩ {:.1} → {:.1}",
        first.old_n, last.new_n, first.observed_sigma, last.observed_sigma
    )
}

/// One-line report of a compressed run's communication: total bytes
/// pushed, the codec's dense-to-compressed ratio, and (when the engine
/// owns the codecs — the sim path) the worst per-learner error-feedback
/// residual, e.g. `comm: 48.0MB pushed (50.0× vs dense), max residual
/// ‖r‖ 0.412`. Pass an empty `residual_norms` when residuals are not
/// observable (the live engine keeps them learner-thread-local).
pub fn comm_summary(
    bytes_by_learner: &[f64],
    residual_norms: &[f64],
    compression_ratio: f64,
) -> String {
    let total: f64 = bytes_by_learner.iter().sum();
    let mut out = format!(
        "comm: {} pushed ({compression_ratio:.1}× vs dense)",
        crate::util::fmt_bytes(total)
    );
    if !residual_norms.is_empty() {
        let max = residual_norms.iter().cloned().fold(0.0f64, f64::max);
        out.push_str(&format!(", max residual ‖r‖ {max:.3}"));
    }
    out
}

/// One-line report of per-shard applyUpdate counts from a sharded-server
/// run. Lockstep shards render compactly (`4 shards × 120 updates`); any
/// divergence — which would indicate a routing bug — is spelled out in
/// full so it cannot hide in a summary.
pub fn shard_update_summary(shard_updates: &[u64]) -> String {
    match (shard_updates.iter().min(), shard_updates.iter().max()) {
        (Some(min), Some(max)) if min == max => {
            format!("{} shards × {} updates", shard_updates.len(), max)
        }
        (Some(_), Some(_)) => {
            format!("{} shards, DIVERGENT updates {:?}", shard_updates.len(), shard_updates)
        }
        _ => "0 shards".to_string(),
    }
}

/// Minimum and maximum over the *finite* entries of a slice (`None` if no
/// entry is finite). Axis scaling for the report plots: series legally
/// carry NaN (empty sample windows) and a NaN must never poison an axis.
pub fn finite_min_max(xs: &[f64]) -> Option<(f64, f64)> {
    let mut out: Option<(f64, f64)> = None;
    for &x in xs {
        if !x.is_finite() {
            continue;
        }
        out = Some(match out {
            None => (x, x),
            Some((lo, hi)) => (lo.min(x), hi.max(x)),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finite_min_max_skips_non_finite() {
        assert_eq!(finite_min_max(&[]), None);
        assert_eq!(finite_min_max(&[f64::NAN, f64::INFINITY]), None);
        assert_eq!(finite_min_max(&[2.0, f64::NAN, -1.0, 5.0]), Some((-1.0, 5.0)));
        assert_eq!(finite_min_max(&[3.0]), Some((3.0, 3.0)));
    }

    #[test]
    fn churn_summary_renders_counts_and_recovery() {
        use crate::elastic::membership::{ChurnKind, ChurnRecord};
        assert_eq!(churn_summary(&[], &[]), "no churn");
        let rec = |kind, learner| ChurnRecord { at: 1.0, learner, kind, active_after: 3 };
        let log = vec![
            rec(ChurnKind::Kill, 0),
            rec(ChurnKind::Kill, 1),
            rec(ChurnKind::Rejoin, 0),
        ];
        let s = churn_summary(&log, &[10.0, 14.0]);
        assert!(s.contains("3 churn events"), "{s}");
        assert!(s.contains("2 kills") && s.contains("1 rejoins"), "{s}");
        assert!(s.contains("12.00s"), "{s}");
    }

    #[test]
    fn straggler_summary_renders_spread_and_drops() {
        assert_eq!(straggler_summary(&[], &[]), "no learners");
        let s = straggler_summary(&[0.85, 0.87, 0.86], &[0, 0, 0]);
        assert!(s.starts_with("balanced"), "{s}");
        let s = straggler_summary(&[0.95, 0.10, 0.12], &[40, 0, 2]);
        assert!(s.contains("10–95%"), "{s}");
        assert!(s.contains("42 gradients dropped"), "{s}");
        assert!(s.contains("learner 0 × 40"), "{s}");
        // drops force the detailed rendering even when utilization is flat
        let s = straggler_summary(&[0.5, 0.5], &[3, 0]);
        assert!(s.contains("3 gradients dropped"), "{s}");
    }

    #[test]
    fn fault_summary_renders_counters_and_worst_learner() {
        use crate::netsim::reliable::FaultStats;
        let quiet = FaultStats::new(4);
        assert_eq!(fault_summary(&quiet), "fault plane armed, no faults fired");
        let mut f = FaultStats::new(4);
        f.sent = 1200;
        f.delivered = 1177;
        f.dropped = 23;
        f.exhausted = 2;
        f.retransmits = 57;
        f.retransmits_by = vec![10, 20, 16, 11];
        f.dups_injected = 4;
        f.dedup_dropped = 9;
        f.retry_bytes = 1.2e6;
        let s = fault_summary(&f);
        assert!(s.contains("1200 routed"), "{s}");
        assert!(s.contains("23 dropped (2 exhausted)"), "{s}");
        assert!(s.contains("57 retransmits"), "{s}");
        assert!(s.contains("learner 1 × 20"), "{s}");
        assert!(s.contains("4 dups injected"), "{s}");
        assert!(s.contains("9 dedup-dropped"), "{s}");
        assert!(s.contains("1.2MB"), "{s}");
    }

    #[test]
    fn adaptive_summary_renders_trajectory() {
        use crate::straggler::adaptive::AdaptiveRecord;
        assert_eq!(adaptive_summary(&[]), "adaptive-n: no decisions");
        let rec = |epoch, sigma, old_n, new_n| AdaptiveRecord {
            epoch,
            observed_sigma: sigma,
            epoch_secs: 1.0,
            old_n,
            new_n,
        };
        let log = vec![rec(1, 7.6, 8, 4), rec(2, 3.9, 4, 2), rec(3, 2.1, 2, 2)];
        let s = adaptive_summary(&log);
        assert!(s.contains("2 retunes"), "{s}");
        assert!(s.contains("n 8 → 2"), "{s}");
        assert!(s.contains("7.6 → 2.1"), "{s}");
    }

    #[test]
    fn comm_summary_renders_bytes_ratio_and_residuals() {
        let s = comm_summary(&[24.0e6, 24.0e6], &[0.1, 0.412], 50.0);
        assert!(s.contains("48.0MB"), "{s}");
        assert!(s.contains("50.0× vs dense"), "{s}");
        assert!(s.contains("0.412"), "{s}");
        // live engine path: no residual column
        let s = comm_summary(&[1.0e3], &[], 6.4);
        assert!(s.contains("6.4×"), "{s}");
        assert!(!s.contains("residual"), "{s}");
    }

    #[test]
    fn shard_summary_lockstep_and_divergent() {
        assert_eq!(shard_update_summary(&[120, 120, 120, 120]), "4 shards × 120 updates");
        assert_eq!(shard_update_summary(&[7]), "1 shards × 7 updates");
        let s = shard_update_summary(&[3, 4]);
        assert!(s.contains("DIVERGENT") && s.contains("[3, 4]"), "{s}");
        assert_eq!(shard_update_summary(&[]), "0 shards");
    }
}
