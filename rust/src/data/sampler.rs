//! Per-learner mini-batch samplers (the paper's getMinibatch, §2).
//!
//! Each learner "selects randomly a mini-batch of examples from the
//! training data" — learners sample independently with replacement across
//! the shared dataset (the paper's data server serves random samples, not
//! partitions). Epoch accounting follows the paper: an epoch is one pass
//! worth of samples *in aggregate* across all learners.

use crate::data::loader::ImageSet;
use crate::util::rng::Rng;

/// A sampled mini-batch in the flat layouts the grad executables expect.
#[derive(Debug, Clone)]
pub struct Batch {
    /// [μ · h · w · c] f32, row-major NHWC.
    pub images: Vec<f32>,
    /// [μ] i32.
    pub labels: Vec<i32>,
    pub mu: usize,
}

/// Random-with-replacement sampler over an [`ImageSet`], one per learner,
/// seeded from the learner id so runs replay exactly.
#[derive(Debug)]
pub struct BatchSampler<'a> {
    set: &'a ImageSet,
    rng: Rng,
    pub mu: usize,
}

impl<'a> BatchSampler<'a> {
    pub fn new(set: &'a ImageSet, mu: usize, seed: u64, learner: usize) -> Self {
        assert!(mu >= 1, "mini-batch size must be >= 1");
        BatchSampler { set, rng: Rng::new(seed).split(learner as u64), mu }
    }

    pub fn next_batch(&mut self) -> Batch {
        let len = self.set.sample_len();
        let mut images = vec![0.0f32; self.mu * len];
        let mut labels = vec![0i32; self.mu];
        for b in 0..self.mu {
            let i = self.rng.usize_below(self.set.n);
            self.set.fill_sample(i, &mut images[b * len..(b + 1) * len]);
            labels[b] = self.set.labels[i];
        }
        Batch { images, labels, mu: self.mu }
    }
}

/// Sequential full-coverage iterator for evaluation: yields fixed-size
/// batches padded by wrapping, plus the count of *valid* samples in each
/// (the stats server only scores the valid prefix).
#[derive(Debug)]
pub struct EvalIter<'a> {
    set: &'a ImageSet,
    batch: usize,
    pos: usize,
}

impl<'a> EvalIter<'a> {
    pub fn new(set: &'a ImageSet, batch: usize) -> Self {
        EvalIter { set, batch, pos: 0 }
    }
}

impl<'a> Iterator for EvalIter<'a> {
    /// (batch, valid_count)
    type Item = (Batch, usize);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.set.n {
            return None;
        }
        let len = self.set.sample_len();
        let valid = (self.set.n - self.pos).min(self.batch);
        let mut images = vec![0.0f32; self.batch * len];
        let mut labels = vec![0i32; self.batch];
        for b in 0..self.batch {
            // wrap padding re-scores early samples; they are not counted.
            let i = (self.pos + b) % self.set.n;
            self.set.fill_sample(i, &mut images[b * len..(b + 1) * len]);
            labels[b] = self.set.labels[i];
        }
        self.pos += valid;
        Some((Batch { images, labels, mu: self.batch }, valid))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_set() -> ImageSet {
        let n = 10;
        let (h, w, c) = (2, 2, 1);
        ImageSet {
            n,
            h,
            w,
            c,
            classes: 5,
            images: (0..n * h * w * c).map(|i| i as f32).collect(),
            labels: (0..n as i32).map(|i| i % 5).collect(),
        }
    }

    #[test]
    fn batches_have_right_shape_and_content() {
        let set = tiny_set();
        let mut s = BatchSampler::new(&set, 4, 42, 0);
        let b = s.next_batch();
        assert_eq!(b.images.len(), 4 * 4);
        assert_eq!(b.labels.len(), 4);
        // each row must be a real sample
        for i in 0..4 {
            let first = b.images[i * 4];
            let idx = (first as usize) / 4;
            assert!(idx < set.n);
            assert_eq!(b.labels[i], set.labels[idx]);
        }
    }

    #[test]
    fn different_learners_sample_differently() {
        let set = tiny_set();
        let mut a = BatchSampler::new(&set, 8, 42, 0);
        let mut b = BatchSampler::new(&set, 8, 42, 1);
        assert_ne!(a.next_batch().labels, b.next_batch().labels);
    }

    #[test]
    fn same_seed_replays() {
        let set = tiny_set();
        let mut a = BatchSampler::new(&set, 8, 42, 3);
        let mut b = BatchSampler::new(&set, 8, 42, 3);
        for _ in 0..5 {
            assert_eq!(a.next_batch().labels, b.next_batch().labels);
        }
    }

    #[test]
    fn eval_iter_covers_exactly_once() {
        let set = tiny_set();
        let mut total = 0;
        let mut batches = 0;
        for (b, valid) in EvalIter::new(&set, 4) {
            assert_eq!(b.labels.len(), 4);
            total += valid;
            batches += 1;
        }
        assert_eq!(total, set.n);
        assert_eq!(batches, 3); // 4 + 4 + 2
    }
}
