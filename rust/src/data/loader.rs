//! Readers for the binary dataset formats written by
//! `python/compile/datagen.py` (all little-endian; see that module's
//! docstring for the layouts).

use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

const IMG_MAGIC: &[u8; 8] = b"RUDRAIMG";
const TXT_MAGIC: &[u8; 8] = b"RUDRATXT";

/// An in-memory labeled image dataset (row-major [n, h, w, c] f32).
#[derive(Debug, Clone)]
pub struct ImageSet {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub classes: usize,
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
}

impl ImageSet {
    pub fn load(path: &Path) -> Result<ImageSet> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening image set {}", path.display()))?;
        let mut header = [0u8; 8 + 24];
        f.read_exact(&mut header)?;
        if &header[..8] != IMG_MAGIC {
            bail!("{}: bad magic", path.display());
        }
        let u = |i: usize| {
            u32::from_le_bytes(header[8 + 4 * i..12 + 4 * i].try_into().unwrap()) as usize
        };
        let (ver, n, h, w, c, classes) = (u(0), u(1), u(2), u(3), u(4), u(5));
        if ver != 1 {
            bail!("{}: unsupported version {ver}", path.display());
        }
        let px = n * h * w * c;
        let mut raw = vec![0u8; px * 4];
        f.read_exact(&mut raw).context("truncated image payload")?;
        let images = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        let mut raw_labels = vec![0u8; n * 4];
        f.read_exact(&mut raw_labels).context("truncated labels")?;
        let labels: Vec<i32> = raw_labels
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        for &l in &labels {
            if l < 0 || l as usize >= classes {
                bail!("{}: label {l} out of range [0, {classes})", path.display());
            }
        }
        Ok(ImageSet { n, h, w, c, classes, images, labels })
    }

    /// Floats per image.
    pub fn sample_len(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Copy sample `i`'s pixels into `out` (length `sample_len`).
    pub fn fill_sample(&self, i: usize, out: &mut [f32]) {
        let len = self.sample_len();
        out.copy_from_slice(&self.images[i * len..(i + 1) * len]);
    }
}

/// The text corpus for the LM example.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub bytes: Vec<u8>,
}

impl Corpus {
    pub fn load(path: &Path) -> Result<Corpus> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening corpus {}", path.display()))?;
        let mut header = [0u8; 8 + 4 + 8];
        f.read_exact(&mut header)?;
        if &header[..8] != TXT_MAGIC {
            bail!("{}: bad magic", path.display());
        }
        let ver = u32::from_le_bytes(header[8..12].try_into().unwrap());
        if ver != 1 {
            bail!("{}: unsupported version {ver}", path.display());
        }
        let len = u64::from_le_bytes(header[12..20].try_into().unwrap()) as usize;
        let mut bytes = vec![0u8; len];
        f.read_exact(&mut bytes).context("truncated corpus")?;
        Ok(Corpus { bytes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_imageset(path: &Path, n: usize, h: usize, w: usize, c: usize, classes: u32) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(IMG_MAGIC).unwrap();
        for v in [1u32, n as u32, h as u32, w as u32, c as u32, classes] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        for i in 0..(n * h * w * c) {
            f.write_all(&(i as f32).to_le_bytes()).unwrap();
        }
        for i in 0..n {
            f.write_all(&((i as i32) % classes as i32).to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn image_roundtrip() {
        let dir = std::env::temp_dir().join("rudra_test_loader");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("imgs.bin");
        write_imageset(&path, 4, 2, 2, 3, 10);
        let set = ImageSet::load(&path).unwrap();
        assert_eq!((set.n, set.h, set.w, set.c, set.classes), (4, 2, 2, 3, 10));
        assert_eq!(set.sample_len(), 12);
        let mut buf = vec![0.0f32; 12];
        set.fill_sample(1, &mut buf);
        assert_eq!(buf[0], 12.0);
        assert_eq!(set.labels, vec![0, 1, 2, 3]);
    }

    #[test]
    fn rejects_out_of_range_labels() {
        let dir = std::env::temp_dir().join("rudra_test_loader");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad_labels.bin");
        write_imageset(&path, 4, 2, 2, 3, 10);
        // Corrupt the final label to 99 (>= classes).
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 4..].copy_from_slice(&99i32.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        assert!(ImageSet::load(&path).is_err());
    }

    #[test]
    fn corpus_roundtrip() {
        let dir = std::env::temp_dir().join("rudra_test_loader");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corpus.bin");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(TXT_MAGIC).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&5u64.to_le_bytes()).unwrap();
        f.write_all(b"hello").unwrap();
        drop(f);
        let c = Corpus::load(&path).unwrap();
        assert_eq!(c.bytes, b"hello");
    }
}
