//! Data layer: the paper's "Data Server" (§3.2) in library form.
//!
//! The paper hosts training data on GPFS and gives every learner an I/O
//! thread that prefetches mini-batches "via random sampling prior to
//! training", fully overlapped with compute. Here [`loader`] reads the
//! binary datasets produced by the AOT step, [`sampler`] reproduces the
//! per-learner random sampling (with an optional prefetch thread in the
//! live engine), and [`corpus`] provides contiguous-window sampling over
//! the byte corpus for the transformer example.

pub mod corpus;
pub mod loader;
pub mod sampler;
