//! Contiguous-window sampling over the byte corpus (transformer example).

use crate::data::loader::Corpus;
use crate::util::rng::Rng;

/// A token batch: `tokens[b, s]` inputs and `targets[b, s]` next-byte
/// labels, both flattened row-major i32 as the LM grad executable expects.
#[derive(Debug, Clone)]
pub struct TokenBatch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// Random-window LM sampler, seeded per learner like [`super::sampler`].
#[derive(Debug)]
pub struct WindowSampler<'a> {
    corpus: &'a Corpus,
    rng: Rng,
    pub batch: usize,
    pub seq: usize,
}

impl<'a> WindowSampler<'a> {
    pub fn new(corpus: &'a Corpus, batch: usize, seq: usize, seed: u64, learner: usize) -> Self {
        assert!(
            corpus.bytes.len() > seq + 1,
            "corpus ({} bytes) shorter than seq+1 ({})",
            corpus.bytes.len(),
            seq + 1
        );
        WindowSampler { corpus, rng: Rng::new(seed).split(learner as u64), batch, seq }
    }

    pub fn next_batch(&mut self) -> TokenBatch {
        let mut tokens = vec![0i32; self.batch * self.seq];
        let mut targets = vec![0i32; self.batch * self.seq];
        let max_start = self.corpus.bytes.len() - self.seq - 1;
        for b in 0..self.batch {
            let start = self.rng.usize_below(max_start);
            for s in 0..self.seq {
                tokens[b * self.seq + s] = self.corpus.bytes[start + s] as i32;
                targets[b * self.seq + s] = self.corpus.bytes[start + s + 1] as i32;
            }
        }
        TokenBatch { tokens, targets, batch: self.batch, seq: self.seq }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus { bytes: (0..=255u8).cycle().take(4096).collect() }
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let c = corpus();
        let mut s = WindowSampler::new(&c, 2, 16, 7, 0);
        let b = s.next_batch();
        for row in 0..2 {
            for i in 0..15 {
                // with the cyclic corpus, target[i] == (token[i] + 1) mod 256
                assert_eq!(
                    b.targets[row * 16 + i],
                    (b.tokens[row * 16 + i] + 1) % 256
                );
                assert_eq!(b.targets[row * 16 + i], b.tokens[row * 16 + i + 1]);
            }
        }
    }

    #[test]
    fn deterministic_per_learner() {
        let c = corpus();
        let mut a = WindowSampler::new(&c, 2, 8, 7, 1);
        let mut b = WindowSampler::new(&c, 2, 8, 7, 1);
        assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
        let mut other = WindowSampler::new(&c, 2, 8, 7, 2);
        assert_ne!(a.next_batch().tokens, other.next_batch().tokens);
    }

    #[test]
    #[should_panic(expected = "shorter")]
    fn rejects_tiny_corpus() {
        let c = Corpus { bytes: vec![1, 2, 3] };
        WindowSampler::new(&c, 1, 8, 0, 0);
    }
}
