//! Adaptive-n staleness controller: hold a target ⟨σ⟩ by retuning the
//! n-softsync splitting parameter from observations.
//!
//! The paper picks n offline and shows ⟨σ⟩ ≈ n (§5.1); under
//! heterogeneous speeds and elastic membership the realized staleness
//! drifts away from the configured n, and with it the error–runtime
//! operating point (Dutta et al., *Slow and Stale Gradients Can Win the
//! Race*). The [`AdaptiveController`] closes the loop: at every epoch
//! boundary it measures the epoch's mean gradient staleness from the
//! staleness histogram totals and multiplicatively steps n toward the
//! target (⟨σ⟩ ≈ n makes `n ← n · target/⟨σ⟩` a fixed-point iteration),
//! clamped to one doubling/halving per epoch and to `1 ≤ n ≤ λ_active`.
//! A deadband around the target suppresses hunting. Every decision is
//! logged as an [`AdaptiveRecord`].
//!
//! The controller only *decides*; applying the new n — revalidating the
//! quota c = ⌊λ_active/n⌋ and swapping the protocol on the sharded
//! server's accumulators between updates — is
//! [`crate::coordinator::shard::ShardedServer::set_softsync_n`]'s job,
//! driven by the engine.

use anyhow::{bail, Result};

/// Adaptive-control spec, parsed from the `adaptive` config knob:
/// `none` (default, open-loop) or `sigma:<target>` with an optional
/// `,band:<frac>` deadband override (default 0.25 — retune only when the
/// observed ⟨σ⟩ leaves ±25% of the target).
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptiveSpec {
    /// Target mean gradient staleness (None = controller off).
    pub target_sigma: Option<f64>,
    /// Relative deadband around the target.
    pub deadband: f64,
}

impl Default for AdaptiveSpec {
    fn default() -> AdaptiveSpec {
        AdaptiveSpec::none()
    }
}

impl AdaptiveSpec {
    pub fn none() -> AdaptiveSpec {
        AdaptiveSpec { target_sigma: None, deadband: DEFAULT_DEADBAND }
    }

    pub fn enabled(&self) -> bool {
        self.target_sigma.is_some()
    }

    /// Parse the config DSL (see the type docs).
    pub fn parse(s: &str) -> Result<AdaptiveSpec> {
        let mut out = AdaptiveSpec::none();
        let s = s.trim();
        if s.is_empty() || s.eq_ignore_ascii_case("none") {
            return Ok(out);
        }
        for tok in s.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let (head, rest) = tok
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("bad adaptive token {tok:?} (want kind:…)"))?;
            match head.to_ascii_lowercase().as_str() {
                "sigma" => {
                    let t: f64 = rest
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad adaptive target {rest:?}"))?;
                    if !t.is_finite() || t <= 0.0 {
                        bail!("adaptive target sigma must be > 0");
                    }
                    out.target_sigma = Some(t);
                }
                "band" => {
                    let b: f64 = rest
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad adaptive deadband {rest:?}"))?;
                    if !(0.0..1.0).contains(&b) {
                        bail!("adaptive deadband must be in [0, 1)");
                    }
                    out.deadband = b;
                }
                other => bail!("unknown adaptive entry {other:?} (sigma|band|none)"),
            }
        }
        if out.target_sigma.is_none() {
            bail!("adaptive spec needs a sigma:<target> entry (or \"none\")");
        }
        Ok(out)
    }

    /// Canonical label (round-trips through [`AdaptiveSpec::parse`]).
    pub fn label(&self) -> String {
        match self.target_sigma {
            None => "none".to_string(),
            Some(t) if self.deadband == DEFAULT_DEADBAND => format!("sigma:{t}"),
            Some(t) => format!("sigma:{t},band:{}", self.deadband),
        }
    }
}

const DEFAULT_DEADBAND: f64 = 0.25;

/// One per-epoch controller decision (`new_n == old_n` means the
/// observation stayed inside the deadband or the clamp bound).
#[derive(Debug, Clone)]
pub struct AdaptiveRecord {
    pub epoch: usize,
    /// Mean gradient staleness over the epoch's updates.
    pub observed_sigma: f64,
    /// Virtual seconds the epoch took.
    pub epoch_secs: f64,
    pub old_n: usize,
    pub new_n: usize,
}

/// The feedback loop. Owns the decision log; the engine applies the
/// returned n to the server.
#[derive(Debug)]
pub struct AdaptiveController {
    target: f64,
    deadband: f64,
    n: usize,
    last_count: u64,
    last_sum: f64,
    last_epoch_time: f64,
    pub log: Vec<AdaptiveRecord>,
}

impl AdaptiveController {
    /// `n0` is the configured n-softsync splitting parameter the run
    /// starts with. Returns `None` for an open-loop (quiet) spec.
    pub fn new(spec: &AdaptiveSpec, n0: usize) -> Option<AdaptiveController> {
        spec.target_sigma.map(|target| AdaptiveController {
            target,
            deadband: spec.deadband,
            n: n0.max(1),
            last_count: 0,
            last_sum: 0.0,
            last_epoch_time: 0.0,
            log: Vec::new(),
        })
    }

    /// The n currently in force (as last decided).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Serialize the controller's feedback state for checkpointing: the
    /// retuned n plus the epoch-window baselines (cumulative count/sum
    /// and the last boundary's virtual time). Without this a restore
    /// would rebuild the controller at the *config* n and with zeroed
    /// baselines — silently undoing every retune and mis-differencing
    /// the first post-restore window (the PR-4 regression). The decision
    /// log rides along too: a mid-flight resume must report the same
    /// [`AdaptiveRecord`] history an uninterrupted run would, so pre-cut
    /// decisions cannot be dropped on the floor. Floats in the log are
    /// stored as IEEE 754 bit patterns (hex) so resume stays bit-exact.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("target", Json::num(self.target)),
            ("deadband", Json::num(self.deadband)),
            ("n", Json::num(self.n as f64)),
            ("last_count", Json::num(self.last_count as f64)),
            ("last_sum", Json::num(self.last_sum)),
            ("last_epoch_time", Json::num(self.last_epoch_time)),
            (
                "log",
                Json::Arr(
                    self.log
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("epoch", Json::num(r.epoch as f64)),
                                (
                                    "observed_sigma_bits",
                                    Json::str(format!("{:016x}", r.observed_sigma.to_bits())),
                                ),
                                (
                                    "epoch_secs_bits",
                                    Json::str(format!("{:016x}", r.epoch_secs.to_bits())),
                                ),
                                ("old_n", Json::num(r.old_n as f64)),
                                ("new_n", Json::num(r.new_n as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Rebuild a controller from [`AdaptiveController::to_json`] output
    /// (self-contained: the target/deadband ride along, so restore needs
    /// no config).
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<AdaptiveController> {
        let n = j.get("n")?.as_usize()?;
        anyhow::ensure!(n >= 1, "adaptive checkpoint with n = 0");
        let bits = |r: &crate::util::json::Json, key: &str| -> anyhow::Result<f64> {
            let s = r.get(key)?.as_str()?;
            let raw = u64::from_str_radix(s, 16)
                .map_err(|_| anyhow::anyhow!("bad float bits {s:?} for {key}"))?;
            Ok(f64::from_bits(raw))
        };
        let log = j
            .get("log")?
            .as_arr()?
            .iter()
            .map(|r| {
                Ok(AdaptiveRecord {
                    epoch: r.get("epoch")?.as_usize()?,
                    observed_sigma: bits(r, "observed_sigma_bits")?,
                    epoch_secs: bits(r, "epoch_secs_bits")?,
                    old_n: r.get("old_n")?.as_usize()?,
                    new_n: r.get("new_n")?.as_usize()?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(AdaptiveController {
            target: j.get("target")?.as_f64()?,
            deadband: j.get("deadband")?.as_f64()?,
            n,
            last_count: j.get("last_count")?.as_u64()?,
            last_sum: j.get("last_sum")?.as_f64()?,
            last_epoch_time: j.get("last_epoch_time")?.as_f64()?,
            log,
        })
    }

    /// Membership shrink: the active quorum fell to `active`, possibly
    /// below the controller's current n — follow it down (n ≤ λ_active is
    /// the checked quota's feasibility rule). Returns the new n when the
    /// controller had to move; the engine applies it to the server
    /// *before* re-deriving the quota for the shrunk quorum, so a kill at
    /// the n ceiling retunes instead of aborting the run.
    pub fn clamp_to_lambda(&mut self, active: usize) -> Option<usize> {
        let cap = active.max(1);
        if self.n > cap {
            self.n = cap;
            Some(cap)
        } else {
            None
        }
    }

    /// Feed one epoch boundary: `count`/`sum` are the run-cumulative
    /// gradient count and staleness sum (the controller differences them
    /// into a per-epoch window itself), `now` the boundary's virtual
    /// time, `active_lambda` the clamp ceiling. Returns `Some(new_n)`
    /// when the server's splitting parameter should change.
    pub fn epoch_tick(
        &mut self,
        epoch: usize,
        now: f64,
        count: u64,
        sum: f64,
        active_lambda: usize,
    ) -> Option<usize> {
        let window_count = count.saturating_sub(self.last_count);
        let window_sum = sum - self.last_sum;
        let epoch_secs = now - self.last_epoch_time;
        self.last_count = count;
        self.last_sum = sum;
        self.last_epoch_time = now;
        if window_count == 0 {
            return None;
        }
        let sigma = window_sum / window_count as f64;
        let old_n = self.n;
        let mut new_n = old_n;
        let hi = self.target * (1.0 + self.deadband);
        let lo = self.target * (1.0 - self.deadband);
        if sigma > hi || sigma < lo {
            // ⟨σ⟩ ≈ n ⇒ multiplicative step toward the target, at most one
            // doubling/halving per epoch so a noisy window cannot slam the
            // protocol across its whole range.
            let ratio = (self.target / sigma.max(1e-9)).clamp(0.5, 2.0);
            new_n = ((old_n as f64 * ratio).round() as usize).clamp(1, active_lambda.max(1));
        }
        self.log.push(AdaptiveRecord {
            epoch,
            observed_sigma: sigma,
            epoch_secs,
            old_n,
            new_n,
        });
        if new_n != old_n {
            self.n = new_n;
            Some(new_n)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_label_roundtrip() {
        let s = AdaptiveSpec::parse("sigma:2").unwrap();
        assert_eq!(s.target_sigma, Some(2.0));
        assert_eq!(s.deadband, DEFAULT_DEADBAND);
        assert_eq!(AdaptiveSpec::parse(&s.label()).unwrap(), s);
        let s = AdaptiveSpec::parse("sigma:1.5,band:0.1").unwrap();
        assert_eq!(s.deadband, 0.1);
        assert_eq!(AdaptiveSpec::parse(&s.label()).unwrap(), s);
        assert!(AdaptiveSpec::parse("none").unwrap().target_sigma.is_none());
        assert!(!AdaptiveSpec::parse("none").unwrap().enabled());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(AdaptiveSpec::parse("sigma:0").is_err());
        assert!(AdaptiveSpec::parse("sigma:-2").is_err());
        assert!(AdaptiveSpec::parse("band:0.5").is_err(), "band without target");
        assert!(AdaptiveSpec::parse("sigma:2,band:1.5").is_err());
        assert!(AdaptiveSpec::parse("tau:3").is_err());
    }

    #[test]
    fn quiet_spec_builds_no_controller() {
        assert!(AdaptiveController::new(&AdaptiveSpec::none(), 4).is_none());
    }

    #[test]
    fn steps_toward_target_with_clamped_rate() {
        let spec = AdaptiveSpec::parse("sigma:2").unwrap();
        let mut c = AdaptiveController::new(&spec, 8).unwrap();
        // epoch 1: observed ⟨σ⟩ = 8 (100 gradients, sum 800) ⇒ ratio
        // 2/8 = 0.25 clamps to 0.5 ⇒ n 8 → 4
        assert_eq!(c.epoch_tick(1, 10.0, 100, 800.0, 8), Some(4));
        // epoch 2: window is differenced — 100 more gradients at σ = 4
        assert_eq!(c.epoch_tick(2, 20.0, 200, 1200.0, 8), Some(2));
        assert_eq!(c.n(), 2);
        // epoch 3: on target ⇒ inside the deadband, no change
        assert_eq!(c.epoch_tick(3, 30.0, 300, 1400.0, 8), None);
        assert_eq!(c.log.len(), 3);
        assert_eq!(c.log[0].old_n, 8);
        assert_eq!(c.log[0].new_n, 4);
        assert!((c.log[1].observed_sigma - 4.0).abs() < 1e-12);
        assert!((c.log[2].epoch_secs - 10.0).abs() < 1e-12);
    }

    #[test]
    fn raises_n_when_too_fresh_and_respects_lambda_clamp() {
        let spec = AdaptiveSpec::parse("sigma:6").unwrap();
        let mut c = AdaptiveController::new(&spec, 4).unwrap();
        // observed σ = 1 ⇒ ratio clamps to 2 ⇒ 4 → 8, but λ_active = 6
        assert_eq!(c.epoch_tick(1, 5.0, 50, 50.0, 6), Some(6));
        assert_eq!(c.n(), 6);
        // n never drops below 1
        let mut floor = AdaptiveController::new(&AdaptiveSpec::parse("sigma:0.1").unwrap(), 1)
            .unwrap();
        assert_eq!(floor.epoch_tick(1, 1.0, 10, 100.0, 8), None);
        assert_eq!(floor.n(), 1);
    }

    #[test]
    fn membership_clamp_follows_quorum_down() {
        let spec = AdaptiveSpec::parse("sigma:8").unwrap();
        let mut c = AdaptiveController::new(&spec, 6).unwrap();
        assert_eq!(c.clamp_to_lambda(8), None, "quorum above n: no move");
        assert_eq!(c.clamp_to_lambda(4), Some(4), "kill below the ceiling retunes");
        assert_eq!(c.n(), 4);
        assert_eq!(c.clamp_to_lambda(4), None, "idempotent at the cap");
        // never below 1, even for a pathological quorum report
        assert_eq!(c.clamp_to_lambda(0), Some(1));
        assert_eq!(c.n(), 1);
    }

    #[test]
    fn json_roundtrip_preserves_retuned_n_and_window_baselines() {
        // Regression (PR 4): checkpoints never carried the controller's
        // state, so a restore reset the retuned n to the config value and
        // zeroed the window baselines.
        let spec = AdaptiveSpec::parse("sigma:2,band:0.1").unwrap();
        let mut c = AdaptiveController::new(&spec, 8).unwrap();
        assert_eq!(c.epoch_tick(1, 10.0, 100, 800.0, 8), Some(4), "retuned 8 → 4");
        let text = c.to_json().to_string();
        let mut back =
            AdaptiveController::from_json(&crate::util::json::Json::parse(&text).unwrap())
                .unwrap();
        assert_eq!(back.n(), 4, "restore must keep the retuned n, not the config n");
        // the decision log survives the round trip bit for bit: a resumed
        // run must report the same history an uninterrupted one would
        assert_eq!(back.log.len(), 1, "pre-checkpoint decisions must be restored");
        assert_eq!(
            back.log[0].observed_sigma.to_bits(),
            c.log[0].observed_sigma.to_bits(),
            "observed sigma restores bit-exactly"
        );
        assert_eq!(back.log[0].epoch_secs.to_bits(), c.log[0].epoch_secs.to_bits());
        assert_eq!((back.log[0].old_n, back.log[0].new_n), (8, 4));
        // both controllers difference the next epoch window identically
        let a = c.epoch_tick(2, 20.0, 200, 1200.0, 8);
        let b = back.epoch_tick(2, 20.0, 200, 1200.0, 8);
        assert_eq!(a, b);
        assert_eq!(c.n(), back.n());
        let orig_sigma = c.log.last().unwrap().observed_sigma;
        let back_sigma = back.log.last().unwrap().observed_sigma;
        assert!(
            (orig_sigma - back_sigma).abs() < 1e-12,
            "window baselines must survive the round trip"
        );
        assert!((c.log.last().unwrap().epoch_secs - 10.0).abs() < 1e-12);
        // garbage is rejected
        assert!(AdaptiveController::from_json(
            &crate::util::json::Json::parse(r#"{"n": 0}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn empty_window_is_skipped() {
        let spec = AdaptiveSpec::parse("sigma:2").unwrap();
        let mut c = AdaptiveController::new(&spec, 4).unwrap();
        assert_eq!(c.epoch_tick(1, 1.0, 0, 0.0, 8), None);
        assert!(c.log.is_empty());
    }
}
