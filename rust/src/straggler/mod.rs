//! Straggler subsystem: heterogeneous learner speeds, the backup-sync
//! protocol, and adaptive staleness control.
//!
//! The paper's accuracy/runtime study assumes homogeneous learners — the
//! P775 testbed's only speed variation is the uniform per-minibatch
//! compute jitter — yet its softsync protocol exists precisely because
//! real clusters have stragglers. This subsystem opens that scenario
//! axis:
//!
//! * [`hetero`] — a per-learner heterogeneity model built from a spec DSL
//!   (the `hetero` config knob): explicit `slow:<id>x<factor>` entries,
//!   sampled `lognormal:<sigma>` / `pareto:<alpha>` persistent speed
//!   distributions, and a `markov:<p_degrade>:<p_recover>:<mult>`
//!   two-state transient-degradation process. Factors scale the netsim
//!   compute-time draws; all randomness comes from the model's own named
//!   RNG stream, so `hetero none` (the default) leaves fixed-seed
//!   trajectories — and PR 2 checkpoints — bit-identical.
//! * `Protocol::BackupSync { b }` (`backup:<b>`,
//!   [`crate::coordinator::protocol`]) — Chen et al.'s *Revisiting
//!   Distributed Synchronous SGD*: a hardsync barrier over the first
//!   λ_active − b arrivals per round; the b slowest gradients are dropped
//!   on arrival and the dropped learners are refreshed with current
//!   weights. Integrated with the sharded server's accumulators, the
//!   elastic rescaler (the checked quota rejects λ_active ≤ b on every
//!   membership change), and the single-clock staleness analysis
//!   (aggregated gradients are always fresh, so σ ≡ 0 like hardsync).
//! * [`adaptive`] — a feedback controller (the `adaptive` config knob)
//!   that retunes the n-softsync splitting parameter per epoch from the
//!   observed staleness distribution and epoch time, holding a target
//!   ⟨σ⟩ as heterogeneity and membership shift the operating point —
//!   the Dutta et al. error–runtime tradeoff swept live.
//!
//! `benches/perf_stragglers.rs` sweeps slowdown factor × protocol
//! (hardsync vs backup:b vs n-softsync vs async) and checks that
//! backup-sync recovers most of the ideal hardsync epoch time under a
//! 10× single-straggler scenario while plain hardsync degrades toward
//! the straggler's speed.

pub mod adaptive;
pub mod hetero;

pub use adaptive::{AdaptiveController, AdaptiveRecord, AdaptiveSpec};
pub use hetero::{HeteroModel, HeteroSpec, MarkovSpec};
