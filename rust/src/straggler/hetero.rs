//! Per-learner speed heterogeneity: spec DSL + deterministic model.
//!
//! A [`HeteroSpec`] describes *why* learners differ in speed; a
//! [`HeteroModel`] realizes it for a concrete λ as one persistent
//! slowdown factor per learner plus an optional two-state Markov
//! transient. The virtual-time engine multiplies each mini-batch's base
//! compute time ([`crate::netsim::cost::LearnerCompute::minibatch_secs`])
//! by the learner's current factor before the usual jitter draw.
//!
//! All randomness — sampling the persistent factors and driving the
//! Markov transitions — comes from the model's own RNG stream, derived
//! from the run seed but separate from the engine's jitter stream. A
//! quiet spec (`none`) therefore consumes zero draws and leaves
//! fixed-seed trajectories bit-identical with heterogeneity-free builds,
//! and the stream is checkpointed by name (`"hetero"`) alongside the
//! engine stream so elastic checkpoints stay replayable.

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Two-state Markov transient degradation: every mini-batch, a nominal
/// learner degrades with probability `p_degrade` and a degraded learner
/// recovers with probability `p_recover`; while degraded, compute time is
/// multiplied by `mult` on top of the learner's persistent factor. This
/// models transient interference (co-tenant bursts, GC pauses, thermal
/// throttling) as opposed to the persistent factors' hardware skew.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MarkovSpec {
    pub p_degrade: f64,
    pub p_recover: f64,
    pub mult: f64,
}

/// Heterogeneity spec, parsed from the `hetero` config knob: a
/// comma-separated list of
///
/// * `slow:<id>x<factor>` — learner `<id>` runs `<factor>`× slower,
///   persistently (factors multiply onto any sampled distribution);
/// * `lognormal:<sigma>` — every learner's persistent factor is
///   multiplied by exp(σ·N(0,1)) (median 1, right-skewed);
/// * `pareto:<alpha>` — every learner's persistent factor is multiplied
///   by a Pareto(α, xₘ = 1) draw (≥ 1, heavy-tailed: the Downpour-style
///   commodity-cluster skew);
/// * `markov:<p_degrade>:<p_recover>:<mult>` — the [`MarkovSpec`]
///   transient process;
///
/// or `none` (the default). Repeating a distribution token overrides the
/// earlier value (last wins, like config layering).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct HeteroSpec {
    /// Explicit persistent slowdowns, `(learner id, factor)`.
    pub slow: Vec<(usize, f64)>,
    pub lognormal_sigma: Option<f64>,
    pub pareto_alpha: Option<f64>,
    pub markov: Option<MarkovSpec>,
}

impl HeteroSpec {
    pub fn none() -> HeteroSpec {
        HeteroSpec::default()
    }

    /// True when the spec injects no heterogeneity at all.
    pub fn is_quiet(&self) -> bool {
        self.slow.is_empty()
            && self.lognormal_sigma.is_none()
            && self.pareto_alpha.is_none()
            && self.markov.is_none()
    }

    /// Largest learner id referenced by a `slow:` entry, if any — config
    /// validation checks it against λ.
    pub fn max_learner_id(&self) -> Option<usize> {
        self.slow.iter().map(|&(l, _)| l).max()
    }

    /// Parse the config DSL (see the type docs).
    pub fn parse(s: &str) -> Result<HeteroSpec> {
        let mut out = HeteroSpec::none();
        let s = s.trim();
        if s.is_empty() || s.eq_ignore_ascii_case("none") {
            return Ok(out);
        }
        for tok in s.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let (head, rest) = tok
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("bad hetero token {tok:?} (want kind:…)"))?;
            match head.to_ascii_lowercase().as_str() {
                "slow" => {
                    let (id, factor) = rest.split_once('x').ok_or_else(|| {
                        anyhow::anyhow!("bad hetero entry {tok:?} (want slow:<id>x<factor>)")
                    })?;
                    let learner: usize = id
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad learner id {id:?} in {tok:?}"))?;
                    let factor: f64 = factor
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad factor {factor:?} in {tok:?}"))?;
                    if !factor.is_finite() || factor <= 0.0 {
                        bail!("hetero factor must be a finite positive number in {tok:?}");
                    }
                    out.slow.push((learner, factor));
                }
                "lognormal" => {
                    let sigma: f64 = rest
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad lognormal sigma {rest:?}"))?;
                    if !sigma.is_finite() || sigma < 0.0 {
                        bail!("lognormal sigma must be >= 0");
                    }
                    out.lognormal_sigma = Some(sigma);
                }
                "pareto" => {
                    let alpha: f64 = rest
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad pareto alpha {rest:?}"))?;
                    if !alpha.is_finite() || alpha <= 0.0 {
                        bail!("pareto alpha must be > 0");
                    }
                    out.pareto_alpha = Some(alpha);
                }
                "markov" => {
                    let parts: Vec<&str> = rest.split(':').collect();
                    if parts.len() != 3 {
                        bail!(
                            "bad hetero entry {tok:?} \
                             (want markov:<p_degrade>:<p_recover>:<mult>)"
                        );
                    }
                    let p_degrade: f64 = parts[0]
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad markov p_degrade in {tok:?}"))?;
                    let p_recover: f64 = parts[1]
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad markov p_recover in {tok:?}"))?;
                    let mult: f64 = parts[2]
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad markov mult in {tok:?}"))?;
                    if !(0.0..=1.0).contains(&p_degrade) || !(0.0..=1.0).contains(&p_recover) {
                        bail!("markov probabilities must be in [0, 1] in {tok:?}");
                    }
                    if !mult.is_finite() || mult < 1.0 {
                        bail!("markov mult must be >= 1 in {tok:?}");
                    }
                    out.markov = Some(MarkovSpec { p_degrade, p_recover, mult });
                }
                other => bail!(
                    "unknown hetero entry {other:?} (slow|lognormal|pareto|markov|none)"
                ),
            }
        }
        Ok(out)
    }

    /// Canonical label (round-trips through [`HeteroSpec::parse`]).
    pub fn label(&self) -> String {
        if self.is_quiet() {
            return "none".to_string();
        }
        let mut parts: Vec<String> =
            self.slow.iter().map(|(l, f)| format!("slow:{l}x{f}")).collect();
        if let Some(s) = self.lognormal_sigma {
            parts.push(format!("lognormal:{s}"));
        }
        if let Some(a) = self.pareto_alpha {
            parts.push(format!("pareto:{a}"));
        }
        if let Some(m) = self.markov {
            parts.push(format!("markov:{}:{}:{}", m.p_degrade, m.p_recover, m.mult));
        }
        parts.join(",")
    }
}

/// Stream-decorrelation constant for the hetero RNG (distinct from the
/// failure injector's).
const HETERO_STREAM: u64 = 0x57A6_61E5_0C0D_E5D1;

/// A realized heterogeneity model for one run: per-learner persistent
/// factors plus the Markov transient state, all driven by a dedicated
/// seeded RNG stream.
#[derive(Debug, Clone)]
pub struct HeteroModel {
    /// Persistent slowdown factor per learner slot (1.0 = nominal).
    factors: Vec<f64>,
    markov: Option<MarkovSpec>,
    degraded: Vec<bool>,
    rng: Rng,
    enabled: bool,
}

impl HeteroModel {
    /// Realize `spec` for `lambda` learner slots. Sampling order is fixed
    /// (lognormal for every slot, then pareto for every slot), so a given
    /// (spec, λ, seed) always yields the same factors. `slow:` entries
    /// referencing ids ≥ λ are ignored here — the engine rejects such a
    /// config up front, before any event runs.
    pub fn build(spec: &HeteroSpec, lambda: usize, seed: u64) -> HeteroModel {
        let mut rng = Rng::new(seed ^ HETERO_STREAM);
        let mut factors = vec![1.0f64; lambda];
        if let Some(sigma) = spec.lognormal_sigma {
            for f in factors.iter_mut() {
                *f *= (sigma * rng.normal()).exp();
            }
        }
        if let Some(alpha) = spec.pareto_alpha {
            for f in factors.iter_mut() {
                // Inverse-CDF Pareto(α, xₘ = 1): (1 − u)^(−1/α) ≥ 1.
                let u = rng.f64();
                *f *= (1.0 - u).max(f64::MIN_POSITIVE).powf(-1.0 / alpha);
            }
        }
        for &(l, factor) in &spec.slow {
            if l < lambda {
                factors[l] *= factor;
            }
        }
        HeteroModel {
            factors,
            markov: spec.markov,
            degraded: vec![false; lambda],
            rng,
            enabled: !spec.is_quiet(),
        }
    }

    /// Whether the model injects any heterogeneity. Disabled models never
    /// touch their RNG after construction.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The persistent per-learner factors (1.0 everywhere when quiet).
    pub fn persistent(&self) -> &[f64] {
        &self.factors
    }

    /// The RNG stream, for checkpointing by name.
    pub fn rng(&self) -> &Rng {
        &self.rng
    }

    /// Which learners are currently in the degraded Markov state (always
    /// all-false without a `markov:` spec) — the mutable half of the
    /// model alongside the RNG stream.
    pub fn degraded_state(&self) -> &[bool] {
        &self.degraded
    }

    /// Install mid-flight state captured from another model of the same
    /// (spec, λ, seed): the RNG stream position and the per-learner
    /// Markov degradation flags. The persistent factors are already
    /// identical because `build` samples them deterministically before
    /// any draw.
    pub fn restore_state(&mut self, rng_state: u64, degraded: &[bool]) -> Result<()> {
        if degraded.len() != self.degraded.len() {
            bail!(
                "hetero checkpoint has {} learner slots, model has {}",
                degraded.len(),
                self.degraded.len()
            );
        }
        self.rng = Rng::from_state(rng_state);
        self.degraded.copy_from_slice(degraded);
        Ok(())
    }

    /// Current slowdown factor for learner `l`'s next mini-batch,
    /// advancing the learner's Markov transient state by one step.
    pub fn draw(&mut self, l: usize) -> f64 {
        if !self.enabled {
            return 1.0;
        }
        let mut f = self.factors[l];
        if let Some(m) = self.markov {
            let p = if self.degraded[l] { m.p_recover } else { m.p_degrade };
            if self.rng.f64() < p {
                self.degraded[l] = !self.degraded[l];
            }
            if self.degraded[l] {
                f *= m.mult;
            }
        }
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_label_roundtrip() {
        let s =
            HeteroSpec::parse("slow:0x10, slow:3x1.5, lognormal:0.3, pareto:2.5, markov:0.05:0.3:4")
                .unwrap();
        assert_eq!(s.slow, vec![(0, 10.0), (3, 1.5)]);
        assert_eq!(s.lognormal_sigma, Some(0.3));
        assert_eq!(s.pareto_alpha, Some(2.5));
        assert_eq!(
            s.markov,
            Some(MarkovSpec { p_degrade: 0.05, p_recover: 0.3, mult: 4.0 })
        );
        assert_eq!(s.max_learner_id(), Some(3));
        assert!(!s.is_quiet());
        assert_eq!(HeteroSpec::parse(&s.label()).unwrap(), s);
        assert!(HeteroSpec::parse("none").unwrap().is_quiet());
        assert_eq!(HeteroSpec::parse("none").unwrap().label(), "none");
    }

    #[test]
    fn parse_rejects_malformed_entries() {
        assert!(HeteroSpec::parse("slow:2").is_err(), "missing factor");
        assert!(HeteroSpec::parse("slow:2x0").is_err(), "zero factor");
        assert!(HeteroSpec::parse("slow:2x-3").is_err(), "negative factor");
        assert!(HeteroSpec::parse("lognormal:-0.1").is_err());
        assert!(HeteroSpec::parse("pareto:0").is_err());
        assert!(HeteroSpec::parse("markov:0.1:0.2").is_err(), "missing mult");
        assert!(HeteroSpec::parse("markov:1.5:0.2:4").is_err(), "p > 1");
        assert!(HeteroSpec::parse("markov:0.1:0.2:0.5").is_err(), "mult < 1");
        assert!(HeteroSpec::parse("turbo:1x2").is_err(), "unknown kind");
    }

    #[test]
    fn quiet_model_is_inert() {
        let mut m = HeteroModel::build(&HeteroSpec::none(), 4, 42);
        assert!(!m.enabled());
        let before = m.rng().state();
        for l in 0..4 {
            assert_eq!(m.draw(l), 1.0);
        }
        assert_eq!(m.rng().state(), before, "quiet model must not consume draws");
        assert_eq!(m.persistent(), &[1.0; 4]);
    }

    #[test]
    fn explicit_slow_factors_apply() {
        let spec = HeteroSpec::parse("slow:1x10,slow:3x2.5").unwrap();
        let mut m = HeteroModel::build(&spec, 4, 7);
        assert_eq!(m.draw(0), 1.0);
        assert_eq!(m.draw(1), 10.0);
        assert_eq!(m.draw(3), 2.5);
        // persistent factors are stable across draws
        assert_eq!(m.draw(1), 10.0);
    }

    #[test]
    fn sampled_factors_are_deterministic_and_distributed() {
        let spec = HeteroSpec::parse("lognormal:0.5").unwrap();
        let a = HeteroModel::build(&spec, 64, 11);
        let b = HeteroModel::build(&spec, 64, 11);
        assert_eq!(a.persistent(), b.persistent(), "same seed ⇒ same factors");
        let c = HeteroModel::build(&spec, 64, 12);
        assert_ne!(a.persistent(), c.persistent(), "seed matters");
        // median ≈ 1: roughly half the factors on each side
        let above = a.persistent().iter().filter(|&&f| f > 1.0).count();
        assert!((16..=48).contains(&above), "lognormal factors skewed: {above}/64 above 1");
        // pareto draws are always ≥ 1
        let p = HeteroModel::build(&HeteroSpec::parse("pareto:2").unwrap(), 64, 11);
        assert!(p.persistent().iter().all(|&f| f >= 1.0));
    }

    #[test]
    fn markov_transient_toggles_and_multiplies() {
        let spec = HeteroSpec::parse("markov:0.5:0.5:8").unwrap();
        let mut m = HeteroModel::build(&spec, 1, 3);
        let draws: Vec<f64> = (0..200).map(|_| m.draw(0)).collect();
        assert!(draws.iter().any(|&f| f == 1.0), "spends time nominal");
        assert!(draws.iter().any(|&f| f == 8.0), "spends time degraded");
        assert!(draws.iter().all(|&f| f == 1.0 || f == 8.0));
        // deterministic replay
        let mut m2 = HeteroModel::build(&spec, 1, 3);
        let replay: Vec<f64> = (0..200).map(|_| m2.draw(0)).collect();
        assert_eq!(draws, replay);
    }

    #[test]
    fn restore_state_resumes_markov_stream_exactly() {
        let spec = HeteroSpec::parse("lognormal:0.3,markov:0.3:0.3:5").unwrap();
        let mut a = HeteroModel::build(&spec, 4, 9);
        for _ in 0..50 {
            for l in 0..4 {
                a.draw(l);
            }
        }
        let (state, degraded) = (a.rng().state(), a.degraded_state().to_vec());
        let mut b = HeteroModel::build(&spec, 4, 9);
        b.restore_state(state, &degraded).unwrap();
        for _ in 0..50 {
            for l in 0..4 {
                assert_eq!(a.draw(l), b.draw(l));
            }
        }
        assert!(b.restore_state(state, &[false; 2]).is_err(), "λ mismatch rejected");
    }

    #[test]
    fn out_of_range_slow_ids_are_ignored_by_build() {
        // the engine rejects the config before running; build itself must
        // not panic on a λ smaller than the spec references
        let spec = HeteroSpec::parse("slow:9x5").unwrap();
        let m = HeteroModel::build(&spec, 2, 1);
        assert_eq!(m.persistent(), &[1.0, 1.0]);
    }
}
