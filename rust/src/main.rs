//! `rudra` — CLI for the Rudra reproduction (leader entrypoint).
//!
//! Subcommands:
//! * `info`              — artifact/platform summary
//! * `train`             — live engine (threads) on the synthetic CNN
//! * `sim`               — one (σ, μ, λ) point: real SGD + simulated time
//! * `sweep`             — (μ, λ) grid under one protocol
//! * `timing`            — timing-only simulation at paper scale
//! * `runs`              — list/diff the persistent run index (runs.jsonl)
//! * `analyze`           — bottleneck attribution from a profiled run (--profile)
//! * `report`            — render the run index into a self-contained HTML dashboard
//! * `bench-diff`        — perf-trajectory gate over two BENCH_hotpath.json

use anyhow::Result;

use rudra::config::RunConfig;
use rudra::coordinator::engine_live::{run_live, LiveConfig, LiveElastic};
use rudra::coordinator::engine_sim::{SimConfig, SimEngine};
use rudra::coordinator::protocol::Protocol;
use rudra::elastic::checkpoint::SimCheckpoint;
use rudra::elastic::rescaler::RescalePolicy;
use rudra::harness::sweep::Sweep;
use rudra::harness::Workspace;
use rudra::netsim::cost::ModelCost;
use rudra::params::optimizer::Optimizer;
use rudra::stats::table::{f, pct, Table};
use rudra::util::cli::Args;
use rudra::util::fmt_secs;

const USAGE: &str = "usage: rudra <info|train|sim|sweep|timing|runs|analyze|report|bench-diff> [--flags]
  info                      show artifacts, platform, model sizes
  train                     live engine (real threads) on the synthetic CNN
                            (--synthetic: deterministic mock gradients, no
                            artifacts needed — CI smoke for trace/series)
  sim                       one (σ,μ,λ) point: real SGD + simulated P775 time
  sweep                     (μ,λ) grid under one protocol
  timing                    timing-only simulation at paper scale
  runs [list|diff I J]      query the persistent run index
                            (--index FILE [runs.jsonl], --filter SUBSTR)
  analyze METRICS.json      bottleneck attribution for a profiled run: the
                            per-category critical-path breakdown, per-learner
                            blame, and Amdahl-style what-if projections
                            (needs a run made with --profile)
  analyze --index F I [J]   same over run-index records — one record, or a
                            side-by-side diff of two
  report                    render the run index (+ embedded time series)
                            into one dependency-free HTML dashboard
                            (--index FILE [runs.jsonl], --out FILE
                            [report.html], --bench A.json,B.json for the
                            events/sec trajectory panel)
  bench-diff OLD NEW        compare two BENCH_hotpath.json baselines; exits
                            non-zero on perf regressions (--threshold F;
                            --strict also fails on kernels or λ rungs
                            removed from the new baseline)
common flags: --protocol hardsync|async|<n>-softsync|backup:<b>
              --arch base|adv|adv*
              --mu N --lambda N --epochs N --seed N --lr F --config FILE
              --shards S (root parameter shards; 1 = flat server)
sweep grid:   --mus a,b,c --lambdas a,b,c (grid axes; JSON keys mus/lambdas)
              --jobs N (worker threads for grid points; 0 = auto
                [available parallelism], 1 = serial — results are
                bit-identical at any value)
elasticity:   --churn SPEC (kill:<id>@<t>,rejoin:<id>@<t>,join:<id>@<t>,
                rate:<kills/1000s>,downtime:<mean-s> | none) [sim/sweep/timing]
              --rescale none|mulambda (hold μ·λ_active ≈ μ₀·λ₀)
              --checkpoint-every N (server checkpoint every N updates)
                [sim/sweep/timing]
              --heartbeat-ms N (live engine: evict learners silent > 2N ms)
              --epoch-csv FILE (sim: per-epoch CSV incl. active-λ column)
stragglers:   --hetero SPEC (slow:<id>x<f>,lognormal:<σ>,pareto:<α>,
                markov:<p↓>:<p↑>:<mult> | none) per-learner speed skew
                [sim/sweep/timing]
              --adaptive sigma:<target>[,band:<f>] (retune n-softsync's n
                per epoch to hold ⟨σ⟩) [sim/sweep/timing]
comm:         --compress none|topk:<frac>|qsgd:<bits> (gradient codec with
                per-learner error-feedback residuals; shrinks push wire
                time) [all engines]
              --comm-csv FILE (sim: per-learner compressed-bytes +
                residual-norm rows)
chaos:        --faults SPEC (message-level network faults with ack/retry +
                dedup: loss:<p>,dup:<p>,reorder:<p>,delayspike:<p>x<mult>,
                partition:rack<A>-rack<B>@<T>s+<D>s,retries:<n>,rto:<secs>
                | none. Deterministic per seed; exhausted retries evict
                via Suspect→Dead; partitions heal and the learner
                rejoins; JSON key faults) [sim/sweep/timing; train
                --synthetic takes loss/dup/retries/rto]
observability: --trace PATH (Chrome trace-event JSON — load in Perfetto/
                chrome://tracing. sim/timing: spans over virtual sim
                time; train: spans over wall time; sweep: PATH is a
                directory, one <label>.trace.json per grid point.
                'none' clears a config-file value; JSON key trace)
              --metrics-json PATH (metrics snapshot: staleness histogram,
                barrier waits, queue depth, per-shard updates, root
                bytes. sweep: PATH is a directory, one
                <label>.metrics.json per grid point; JSON key
                metrics_json)
              --metrics-every SECS (sample a time series — staleness,
                queue depth, active λ, bytes/s, losses — every SECS
                virtual seconds [sim/sweep/timing] or wall seconds
                [train] into the metrics snapshot; JSON key
                metrics_every; 'none' clears)
              --run-index FILE (append one record per point to a JSONL
                run index; query with `rudra runs`, render with
                `rudra report`; JSON key run_index)
              --profile (critical-path profiler: attribute every weight
                update's causal chain to compute/wire/barrier/delivery
                categories with per-learner blame and what-if
                projections, attached to the metrics snapshot under
                \"profile\" — read back with `rudra analyze`. sim/timing:
                exact virtual-time attribution; train: aggregate
                wall-clock totals; JSON key profile)
scale/resume: --max-updates N (timing: hard cap on weight updates — quick
                CI points at datacenter λ)
              --stop-after-events N (timing: halt after N processed events
                and capture a mid-flight sim checkpoint; the count is
                absolute, so a resume passes the total, not a remainder)
              --sim-checkpoint FILE (timing: where that checkpoint is
                written; JSON keys stop_after_events / sim_checkpoint)
              --resume FILE (timing: install a sim checkpoint captured
                under the *same* config and continue bit-identically)
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = argv.remove(0);
    let args = Args::parse(
        argv,
        &["verbose", "eval-each-epoch", "no-eval", "synthetic", "profile", "strict"],
    )?;

    let mut cfg = RunConfig::default();
    if let Some(path) = args.get("config") {
        cfg.apply_file(std::path::Path::new(path))?;
    }
    cfg.apply_args(&args)?;

    match cmd.as_str() {
        "info" => cmd_info(),
        "train" => cmd_train(&cfg, &args),
        "sim" => cmd_sim(&cfg, &args),
        "sweep" => cmd_sweep(&cfg),
        "timing" => cmd_timing(&cfg, &args),
        "runs" => cmd_runs(&args),
        "analyze" => cmd_analyze(&args),
        "report" => cmd_report(&args),
        "bench-diff" => cmd_bench_diff(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            anyhow::bail!("unknown command {other:?}\n{USAGE}");
        }
    }
}

/// One comm line (quiet codecs print nothing): the byte/ratio/residual
/// summary, plus the root-tier in/out breakdown when the engine tracked
/// it (the sim paths; the live engine's fabric is a real channel).
fn print_comm(
    compress: rudra::comm::codec::CodecSpec,
    model_bytes: f64,
    bytes_by_learner: &[f64],
    residual_norms: &[f64],
    root_in_out: Option<(f64, f64)>,
) {
    if compress.is_quiet() {
        return;
    }
    let ratio =
        rudra::comm::wire::WireModel::new(compress, model_bytes).compression_ratio();
    let summary = rudra::stats::comm_summary(bytes_by_learner, residual_norms, ratio);
    match root_in_out {
        Some((r_in, r_out)) => println!(
            "{summary}  (root bytes: {} in / {} out)",
            rudra::util::fmt_bytes(r_in),
            rudra::util::fmt_bytes(r_out)
        ),
        None => println!("{summary}"),
    }
}

/// Write a metrics snapshot where `--metrics-json` asked (atomically: a
/// crash mid-write cannot leave a truncated snapshot behind).
fn write_metrics_json(path: &std::path::Path, metrics: &rudra::util::json::Json) -> Result<()> {
    rudra::util::write_atomic(path, &metrics.to_string())?;
    println!("wrote metrics snapshot to {}", path.display());
    Ok(())
}

/// Run-index record for one sim/sweep point (`point_cfg` is the config
/// that shaped the point — for sweeps, the reconstructed grid-order
/// config, not the top-level one).
fn point_record(
    kind: &str,
    point_cfg: &RunConfig,
    p: &rudra::harness::sweep::PointResult,
) -> rudra::obs::runindex::RunRecord {
    rudra::obs::runindex::RunRecord {
        kind: kind.to_string(),
        label: point_cfg.label(),
        fingerprint: p.fingerprint.clone(),
        seed: point_cfg.seed,
        mu: p.mu,
        lambda: p.lambda,
        shards: point_cfg.shards,
        epochs: point_cfg.epochs,
        test_error_pct: Some(p.test_error_pct),
        train_loss: Some(p.train_loss),
        sim_seconds: p.sim_seconds,
        wall_seconds: p.wall_seconds,
        updates: p.updates,
        events: p.events,
        avg_staleness: p.avg_staleness,
        max_staleness: p.max_staleness,
        root_bytes_in: p.root_bytes_in,
        root_bytes_out: p.root_bytes_out,
        metrics: p.metrics.clone(),
    }
}

/// Append one record to the run index and say where it went.
fn index_run(index: &std::path::Path, record: &rudra::obs::runindex::RunRecord) -> Result<()> {
    rudra::obs::runindex::append(index, record)?;
    println!("indexed run in {}", index.display());
    Ok(())
}

/// Live-engine elasticity from the config + CLI: `--heartbeat-ms` arms
/// eviction of silent learners; the rescale policy rides along. (The
/// time-based `--churn` DSL drives the *sim* engine; the live engine's
/// deterministic churn schedules are test-facing —
/// [`rudra::coordinator::engine_live::LiveElastic`].)
fn live_elastic(cfg: &RunConfig, args: &Args) -> Result<Option<LiveElastic>> {
    let hb_ms = args.u64_or("heartbeat-ms", 0)?;
    if hb_ms == 0 && cfg.rescale == RescalePolicy::None {
        return Ok(None);
    }
    let mut e = LiveElastic::heartbeat(std::time::Duration::from_millis(hb_ms));
    e.rescale = cfg.rescale;
    Ok(Some(e))
}

fn cmd_info() -> Result<()> {
    let ws = Workspace::open_default()?;
    println!("platform: {}", ws.runtime.platform());
    println!(
        "cnn: {} params, grad batches {:?}",
        ws.manifest.cnn.params,
        ws.manifest.cnn.batch_sizes()
    );
    match &ws.manifest.lm {
        Some(lm) => println!(
            "lm:  {} params, batch {}, seq {}",
            lm.params, ws.manifest.lm_batch, ws.manifest.lm_seq
        ),
        None => println!("lm:  (not built — aot ran with --skip-lm)"),
    }
    println!(
        "data: train {} / test {} images ({}x{}x{}, {} classes), corpus {} bytes",
        ws.train.n,
        ws.test.n,
        ws.train.h,
        ws.train.w,
        ws.train.c,
        ws.train.classes,
        ws.corpus.bytes.len()
    );
    Ok(())
}

fn cmd_train(cfg: &RunConfig, args: &Args) -> Result<()> {
    use rudra::harness::providers::{ComputeService, ServiceProvider};
    use rudra::params::FlatVec;
    let synthetic = args.flag("synthetic");
    println!(
        "live training {}{}",
        cfg.label(),
        if synthetic { " (synthetic gradients)" } else { "" }
    );

    // `--synthetic` swaps the CNN workload for deterministic mock
    // gradient providers: no artifacts, no PJRT, no eval — a cheap way
    // for CI to drive the live engine's trace/series machinery for real.
    // PJRT is not Send: in the real mode gradient execution runs on a
    // dedicated compute service thread that must outlive the run;
    // learner threads talk to it over channels.
    let mut _service: Option<ComputeService> = None;
    let mut ws: Option<Workspace> = None;
    let (providers, theta0, samples_per_epoch) = if synthetic {
        let dim = 64usize;
        let theta0 =
            FlatVec::from_vec((0..dim).map(|i| (i as f32) * 0.01 - 0.32).collect());
        let providers: Vec<Box<dyn rudra::coordinator::learner::GradProvider + Send>> = (0
            ..cfg.lambda)
            .map(|_| {
                Box::new(rudra::coordinator::learner::MockProvider::new(vec![0.0; dim]))
                    as Box<dyn rudra::coordinator::learner::GradProvider + Send>
            })
            .collect();
        (providers, theta0, 256u64)
    } else {
        let manifest_path = std::env::var("RUDRA_MANIFEST")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|_| rudra::runtime::Manifest::default_path());
        let service = ComputeService::start_cnn(manifest_path.clone(), cfg.mu)?;
        let train = std::sync::Arc::new(rudra::data::loader::ImageSet::load(
            &rudra::runtime::Manifest::load(&manifest_path)?.data.train,
        )?);
        let providers: Vec<Box<dyn rudra::coordinator::learner::GradProvider + Send>> = (0
            ..cfg.lambda)
            .map(|id| {
                Box::new(ServiceProvider::new(&service, train.clone(), cfg.mu, cfg.seed, id))
                    as Box<dyn rudra::coordinator::learner::GradProvider + Send>
            })
            .collect();
        _service = Some(service);
        let workspace = Workspace::open_default()?;
        let theta0 = workspace.cnn_init()?;
        let n = train.n as u64;
        ws = Some(workspace);
        (providers, theta0, n)
    };

    let live_cfg = LiveConfig {
        protocol: cfg.protocol,
        mu: cfg.mu,
        lambda: cfg.lambda,
        epochs: cfg.epochs,
        samples_per_epoch,
        shards: cfg.shards,
        log_every: args.u64_or("log-every", 50)?,
        elastic: live_elastic(cfg, args)?,
        compress: cfg.compress,
        checkpoint_every: cfg.checkpoint_every,
        collect_metrics: cfg.collect_metrics(),
        trace: cfg.trace.is_some(),
        metrics_every: cfg.metrics_every,
        profile: cfg.profile,
        faults: cfg.faults.clone(),
    };
    let optimizer = Optimizer::new(cfg.optimizer, cfg.weight_decay, theta0.len());
    let result = run_live(&live_cfg, theta0, optimizer, cfg.lr_policy(), providers)?;

    for (push, loss) in &result.loss_log {
        println!("  push {push:>6}  train-loss {loss:.4}");
    }
    println!(
        "done: {} updates, {} pushes, wall {}, ⟨σ⟩={:.2}, max σ={}",
        result.updates,
        result.pushes,
        fmt_secs(result.wall_seconds),
        result.staleness.overall_avg(),
        result.staleness.max
    );
    if cfg.shards > 1 {
        println!("server: {}", rudra::stats::shard_update_summary(&result.shard_updates));
    }
    print_comm(
        cfg.compress,
        4.0 * result.theta.len() as f64,
        &result.comm_bytes_by_learner,
        &[],
        None,
    );
    if result.checkpoints_taken > 0 {
        println!("checkpoints: {} captured (live engine)", result.checkpoints_taken);
    }
    if !result.churn.is_empty() {
        println!(
            "membership: {} (λ_active at end: {})",
            rudra::stats::churn_summary(&result.churn, &result.recovery_secs),
            result.final_active_lambda
        );
    }
    if let Some(f) = &result.faults {
        println!("faults: {}", rudra::stats::fault_summary(f));
    }

    let mut final_eval: Option<(f64, f64)> = None;
    if let (false, Some(ws)) = (args.flag("no-eval"), &ws) {
        let eval = ws.cnn_eval()?;
        let mut ev =
            rudra::stats::ImageEvaluator::new(&eval, &ws.test, ws.manifest.cnn.eval_batch);
        use rudra::coordinator::engine_sim::Evaluator;
        let (loss, err) = ev.eval(&result.theta)?;
        println!("test: loss {loss:.4}, error {err:.2}%");
        final_eval = Some((loss, err));
    }

    if let (Some(path), Some(events)) = (&cfg.trace, &result.trace) {
        rudra::obs::trace::write(path, events)?;
        println!(
            "wrote live trace to {} (wall-clock spans; load in Perfetto / chrome://tracing)",
            path.display()
        );
    }
    if let (Some(path), Some(m)) = (&cfg.metrics_json, &result.metrics) {
        write_metrics_json(path, m)?;
    }
    if let Some(index) = &cfg.run_index {
        index_run(
            index,
            &rudra::obs::runindex::RunRecord {
                kind: "train".to_string(),
                label: cfg.label(),
                // Live runs have no sim-engine fingerprint; mark the
                // engine so `runs diff` refuses cross-engine comparisons.
                fingerprint: format!("live|{}", cfg.label()),
                seed: cfg.seed,
                mu: cfg.mu,
                lambda: cfg.lambda,
                shards: cfg.shards,
                epochs: cfg.epochs,
                test_error_pct: final_eval.map(|(_, err)| err),
                train_loss: result.loss_log.last().map(|&(_, l)| l as f64),
                sim_seconds: 0.0,
                wall_seconds: result.wall_seconds,
                updates: result.updates,
                events: 0,
                avg_staleness: result.staleness.overall_avg(),
                max_staleness: result.staleness.max,
                root_bytes_in: result.comm_bytes_by_learner.iter().sum(),
                root_bytes_out: 0.0,
                metrics: result.metrics.clone(),
            },
        )?;
    }
    Ok(())
}

fn cmd_sim(cfg: &RunConfig, args: &Args) -> Result<()> {
    let ws = Workspace::open_default()?;
    let mut sweep = Sweep::new(&ws, cfg.epochs);
    sweep.seed = cfg.seed;
    sweep.arch = cfg.arch;
    sweep.eval_each_epoch = args.flag("eval-each-epoch");
    println!("sim {}  (epochs={})", cfg.label(), cfg.epochs);
    let p = sweep.run_point(cfg)?;
    println!(
        "test error {:.2}%  train loss {:.4}  ⟨σ⟩={:.2}  max σ={}  updates={}",
        p.test_error_pct, p.train_loss, p.avg_staleness, p.max_staleness, p.updates
    );
    println!(
        "simulated time: synthetic workload {}  |  paper CIFAR10 geometry {}",
        fmt_secs(p.sim_seconds),
        fmt_secs(p.paper_sim_seconds)
    );
    if p.churn_events > 0 {
        let mean_rec = rudra::util::mean(&p.recovery_secs);
        println!(
            "membership: {} churn events, λ_active at end {}, mean recovery {}",
            p.churn_events,
            p.final_active_lambda,
            fmt_secs(mean_rec)
        );
    }
    if !cfg.hetero.is_quiet() || p.dropped_gradients > 0 {
        println!(
            "stragglers: {}",
            rudra::stats::straggler_summary(&p.learner_utilization, &p.dropped_by_learner)
        );
    }
    if !p.adaptive.is_empty() {
        println!("{}", rudra::stats::adaptive_summary(&p.adaptive));
    }
    print_comm(
        cfg.compress,
        ws.cnn_cost().bytes,
        &p.comm_bytes_by_learner,
        &p.residual_norms,
        Some((p.root_bytes_in, p.root_bytes_out)),
    );
    for e in &p.epochs {
        if let Some(err) = e.test_error_pct {
            println!(
                "  epoch {:>3}  sim t {:>10}  train loss {:.4}  test err {:.2}%  λ_active {}",
                e.epoch,
                fmt_secs(e.sim_time),
                e.train_loss,
                err,
                e.active_lambda
            );
        }
    }
    if let Some(path) = args.get("epoch-csv") {
        let mut log = rudra::stats::log::CsvLog::create(
            std::path::Path::new(path),
            &rudra::stats::log::EPOCH_COLUMNS,
        )?;
        for e in &p.epochs {
            log.row(&rudra::stats::log::epoch_row(e))?;
        }
        println!("wrote {} epoch rows to {path}", p.epochs.len());
    }
    if let Some(path) = args.get("comm-csv") {
        let mut log = rudra::stats::log::CsvLog::create(
            std::path::Path::new(path),
            &rudra::stats::log::COMM_COLUMNS,
        )?;
        for l in 0..p.comm_bytes_by_learner.len() {
            log.row(&rudra::stats::log::comm_row(
                l,
                p.comm_bytes_by_learner[l],
                p.residual_norms.get(l).copied().unwrap_or(0.0),
            ))?;
        }
        println!("wrote {} comm rows to {path}", p.comm_bytes_by_learner.len());
    }
    if let Some(path) = &cfg.trace {
        println!(
            "wrote trace to {} (load in Perfetto / chrome://tracing)",
            path.display()
        );
    }
    if let (Some(path), Some(m)) = (&cfg.metrics_json, &p.metrics) {
        write_metrics_json(path, m)?;
    }
    if let Some(index) = &cfg.run_index {
        index_run(index, &point_record("sim", cfg, &p))?;
    }
    Ok(())
}

fn cmd_sweep(cfg: &RunConfig) -> Result<()> {
    let ws = Workspace::open_default()?;
    // Grid axes layer like every other knob: JSON config (`mus`/`lambdas`)
    // under CLI (`--mus`/`--lambdas`), already merged into `cfg`.
    let mus = cfg.sweep_mus.clone().unwrap_or_else(|| vec![4, 32, 128]);
    let lambdas = cfg.sweep_lambdas.clone().unwrap_or_else(|| vec![1, 4, 30]);
    let mut sweep = Sweep::new(&ws, cfg.epochs);
    sweep.seed = cfg.seed;
    sweep.arch = cfg.arch;
    sweep.jobs = cfg.jobs;
    sweep.collect_metrics = cfg.collect_metrics();
    // Sweep observability is per point: `--trace DIR` / `--metrics-json
    // DIR` name *directories*, and every grid point writes its own
    // `<label>.trace.json` / `<label>.metrics.json` from its worker
    // thread — parallel points never share a file.
    sweep.trace_dir = cfg.trace.clone();
    sweep.metrics_dir = cfg.metrics_json.clone();
    sweep.metrics_every = cfg.metrics_every;
    sweep.profile = cfg.profile;
    let points = mus.len() * lambdas.len();
    println!(
        "sweep: {points} grid points on {} worker thread(s)",
        rudra::harness::sweep::resolve_jobs(cfg.jobs).min(points.max(1))
    );
    let proto = cfg.protocol;
    let results = sweep.run_grid(&mus, &lambdas, |_lambda| proto)?;
    let mut t = Table::new(&["μ", "λ", "⟨σ⟩", "test err", "sim time (paper geom)"]);
    for r in &results {
        t.row(vec![
            r.mu.to_string(),
            r.lambda.to_string(),
            f(r.avg_staleness, 2),
            pct(r.test_error_pct),
            fmt_secs(r.paper_sim_seconds),
        ]);
    }
    t.print();

    if let Some(dir) = &cfg.trace {
        println!("wrote {points} per-point traces under {} (<label>.trace.json)", dir.display());
    }
    if let Some(dir) = &cfg.metrics_json {
        println!(
            "wrote {points} per-point metrics snapshots under {} (<label>.metrics.json)",
            dir.display()
        );
    }
    if let Some(index) = &cfg.run_index {
        // Reconstruct the grid-order point configs (λ-major, μ-minor —
        // [`Sweep::run_grid`]'s construction) so each record carries the
        // label and seed of the point that produced it.
        let mut point_cfgs = Vec::with_capacity(results.len());
        for &lambda in &lambdas {
            for &mu in &mus {
                let mut c = RunConfig {
                    mu,
                    lambda,
                    protocol: proto,
                    epochs: cfg.epochs,
                    seed: cfg.seed,
                    ..RunConfig::default()
                };
                c.arch = cfg.arch;
                point_cfgs.push(c);
            }
        }
        for (r, c) in results.iter().zip(&point_cfgs) {
            rudra::obs::runindex::append(index, &point_record("sweep", c, r))?;
        }
        println!("indexed {} sweep points in {}", results.len(), index.display());
    }
    Ok(())
}

fn cmd_timing(cfg: &RunConfig, args: &Args) -> Result<()> {
    let model = match args.str_or("workload", "cifar10").as_str() {
        "cifar10" => ModelCost::cifar10(),
        "imagenet" => ModelCost::imagenet(),
        "adversarial" => ModelCost::adversarial_300mb(),
        other => anyhow::bail!("unknown workload {other:?}"),
    };
    let epochs = args.usize_or("epochs", cfg.epochs)?;
    let mut sim_cfg = SimConfig::paper(cfg.protocol, cfg.arch, cfg.mu, cfg.lambda, epochs, model);
    sim_cfg.shards = cfg.shards;
    sim_cfg.seed = cfg.seed;
    sim_cfg.churn = cfg.churn.clone();
    sim_cfg.rescale = cfg.rescale;
    sim_cfg.checkpoint_every_updates = cfg.checkpoint_every;
    sim_cfg.hetero = cfg.hetero.clone();
    sim_cfg.adaptive = cfg.adaptive.clone();
    sim_cfg.compress = cfg.compress;
    sim_cfg.stop_after_events = cfg.stop_after_events;
    sim_cfg.sim_checkpoint_path = cfg.sim_checkpoint.clone();
    sim_cfg.trace = cfg.trace.is_some();
    sim_cfg.trace_path = cfg.trace.clone();
    sim_cfg.collect_metrics = cfg.collect_metrics();
    sim_cfg.metrics_every = cfg.metrics_every;
    sim_cfg.profile = cfg.profile;
    sim_cfg.faults = cfg.faults.clone();
    if args.get("max-updates").is_some() {
        sim_cfg.max_updates = Some(args.u64_or("max-updates", 0)?);
    }
    let mut engine = SimEngine::new(
        &sim_cfg,
        rudra::params::FlatVec::zeros(0),
        Optimizer::new(rudra::params::optimizer::OptimizerKind::Sgd, 0.0, 0),
        cfg.lr_policy(),
        None,
        None,
    );
    if let Some(path) = args.get("resume") {
        let ckpt = SimCheckpoint::load(std::path::Path::new(path))?;
        println!(
            "resuming from {path} ({} events already processed)",
            ckpt.events_processed()?
        );
        engine.install_sim_checkpoint(&ckpt)?;
    }
    let started = std::time::Instant::now();
    let r = engine.run()?;
    let wall_seconds = started.elapsed().as_secs_f64();
    println!(
        "{}: {} epochs in simulated {}  ({} updates, ⟨σ⟩={:.2}, overlap {:.2}%, {} events)",
        cfg.label(),
        epochs,
        fmt_secs(r.sim_seconds),
        r.updates,
        r.staleness.overall_avg(),
        r.overlap.overlap_pct(),
        r.events_processed
    );
    if cfg.shards > 1 {
        println!("server: {}", rudra::stats::shard_update_summary(&r.shard_updates));
    }
    if !r.churn.is_empty() {
        println!(
            "membership: {} (λ_active at end: {})",
            rudra::stats::churn_summary(&r.churn, &r.recovery_secs),
            r.final_active_lambda
        );
    }
    if r.checkpoints_taken > 0 {
        println!("checkpoints: {} captured", r.checkpoints_taken);
    }
    if r.sim_checkpoint.is_some() {
        match &sim_cfg.sim_checkpoint_path {
            Some(p) => println!(
                "sim checkpoint: stopped after {} events → {}",
                r.events_processed,
                p.display()
            ),
            None => println!(
                "sim checkpoint: stopped after {} events (in-memory only; \
                 pass --sim-checkpoint FILE to persist)",
                r.events_processed
            ),
        }
    }
    if !cfg.hetero.is_quiet() || r.dropped_gradients > 0 {
        println!(
            "stragglers: {}",
            rudra::stats::straggler_summary(&r.learner_utilization, &r.dropped_by_learner)
        );
    }
    if !r.adaptive.is_empty() {
        println!("{}", rudra::stats::adaptive_summary(&r.adaptive));
    }
    if let Some(f) = &r.faults {
        println!("faults: {}", rudra::stats::fault_summary(f));
    }
    print_comm(
        cfg.compress,
        sim_cfg.model.bytes,
        &r.comm_bytes_by_learner,
        &r.residual_norms,
        Some((r.root_bytes_in, r.root_bytes_out)),
    );
    if let Some(path) = &cfg.trace {
        println!(
            "wrote trace to {} (load in Perfetto / chrome://tracing)",
            path.display()
        );
    }
    if let (Some(path), Some(m)) = (&cfg.metrics_json, &r.metrics) {
        write_metrics_json(path, m)?;
    }
    if let Some(index) = &cfg.run_index {
        index_run(
            index,
            &rudra::obs::runindex::RunRecord {
                kind: "timing".to_string(),
                label: cfg.label(),
                fingerprint: SimEngine::config_fingerprint(&sim_cfg),
                seed: cfg.seed,
                mu: cfg.mu,
                lambda: cfg.lambda,
                shards: cfg.shards,
                epochs,
                test_error_pct: r.final_eval.map(|(_, err)| err),
                train_loss: Some(r.final_train_loss),
                sim_seconds: r.sim_seconds,
                wall_seconds,
                updates: r.updates,
                events: r.events_processed,
                avg_staleness: r.staleness.overall_avg(),
                max_staleness: r.staleness.max,
                root_bytes_in: r.root_bytes_in,
                root_bytes_out: r.root_bytes_out,
                metrics: r.metrics.clone(),
            },
        )?;
    }
    let _ = Protocol::Hardsync; // referenced for doc completeness
    Ok(())
}

/// `rudra runs [list|diff I J]` — query the persistent run index.
fn cmd_runs(args: &Args) -> Result<()> {
    use rudra::obs::runindex;
    let index = std::path::PathBuf::from(args.str_or("index", runindex::DEFAULT_INDEX));
    let records = runindex::load(&index)?;
    let action = args.positional.first().map(|s| s.as_str()).unwrap_or("list");
    match action {
        "list" => {
            let filter = args.get("filter").map(|s| s.to_lowercase());
            let rows: Vec<(usize, &runindex::RunRecord)> = records
                .iter()
                .enumerate()
                .filter(|(_, r)| match &filter {
                    Some(f) => {
                        r.label.to_lowercase().contains(f.as_str())
                            || r.kind.to_lowercase().contains(f.as_str())
                    }
                    None => true,
                })
                .collect();
            if records.is_empty() {
                println!(
                    "no runs indexed in {} (pass --run-index {} to sim/sweep/timing)",
                    index.display(),
                    runindex::DEFAULT_INDEX
                );
                return Ok(());
            }
            runindex::render_list(&rows).print();
            println!("{} of {} records in {}", rows.len(), records.len(), index.display());
        }
        "diff" => {
            let parse_idx = |pos: usize, name: &str| -> Result<usize> {
                let raw = args
                    .positional
                    .get(pos)
                    .ok_or_else(|| anyhow::anyhow!("usage: rudra runs diff I J"))?;
                let i: usize = raw
                    .parse()
                    .map_err(|_| anyhow::anyhow!("{name}: bad record number {raw:?}"))?;
                anyhow::ensure!(
                    i < records.len(),
                    "{name}: record #{i} out of range (index has {} records)",
                    records.len()
                );
                Ok(i)
            };
            let (i, j) = (parse_idx(1, "I")?, parse_idx(2, "J")?);
            println!("runs diff #{i} -> #{j} ({}):", index.display());
            for line in runindex::render_diff(&records[i], &records[j]) {
                println!("{line}");
            }
        }
        other => anyhow::bail!("unknown runs action {other:?} (list | diff I J)"),
    }
    Ok(())
}

/// `rudra analyze` — bottleneck attribution for a profiled run: render
/// the per-category critical-path breakdown, per-learner blame, and
/// what-if projections from a `"profile"` section (produced with
/// `--profile`), read either from a metrics snapshot file or from
/// run-index records (`--index runs.jsonl I [J]` — two records render a
/// side-by-side diff).
fn cmd_analyze(args: &Args) -> Result<()> {
    use rudra::obs::profile;
    use rudra::util::json::Json;
    if let Some(index) = args.get("index") {
        use rudra::obs::runindex;
        let index = std::path::PathBuf::from(index);
        let records = runindex::load(&index)?;
        let parse_idx = |pos: usize, name: &str| -> Result<usize> {
            let raw = args.positional.get(pos).ok_or_else(|| {
                anyhow::anyhow!("usage: rudra analyze --index {} I [J]", index.display())
            })?;
            let i: usize = raw
                .parse()
                .map_err(|_| anyhow::anyhow!("{name}: bad record number {raw:?}"))?;
            anyhow::ensure!(
                i < records.len(),
                "{name}: record #{i} out of range (index has {} records)",
                records.len()
            );
            Ok(i)
        };
        let profile_of = |i: usize| -> Result<&Json> {
            records[i].metrics.as_ref().and_then(|m| m.opt("profile")).ok_or_else(|| {
                anyhow::anyhow!(
                    "record #{i} ({}) carries no profile — rerun it with --profile",
                    records[i].label
                )
            })
        };
        let i = parse_idx(0, "I")?;
        if args.positional.len() > 1 {
            let j = parse_idx(1, "J")?;
            println!("analyze #{i} vs #{j} ({}):", index.display());
            let (a_title, b_title) =
                (format!("#{i} {}", records[i].label), format!("#{j} {}", records[j].label));
            for line in profile::render_diff(profile_of(i)?, &a_title, profile_of(j)?, &b_title)
            {
                println!("{line}");
            }
        } else {
            for line in
                profile::render_analysis(profile_of(i)?, &format!("#{i} {}", records[i].label))
            {
                println!("{line}");
            }
        }
    } else {
        let Some(path) = args.positional.first() else {
            anyhow::bail!(
                "usage: rudra analyze METRICS.json | rudra analyze --index runs.jsonl I [J]"
            );
        };
        let metrics = Json::parse_file(std::path::Path::new(path))?;
        let profile_j = metrics.opt("profile").ok_or_else(|| {
            anyhow::anyhow!("{path}: no \"profile\" section — rerun the point with --profile")
        })?;
        for line in profile::render_analysis(profile_j, path) {
            println!("{line}");
        }
    }
    Ok(())
}

/// `rudra report` — render the run index (plus any time series embedded
/// in its metrics snapshots) into one self-contained, dependency-free
/// HTML dashboard.
fn cmd_report(args: &Args) -> Result<()> {
    use rudra::obs::{report, runindex};
    use rudra::util::json::Json;
    let index = std::path::PathBuf::from(args.str_or("index", runindex::DEFAULT_INDEX));
    let out = std::path::PathBuf::from(args.str_or("out", "report.html"));
    let records = runindex::load(&index)?;
    let mut benches: Vec<(String, Json)> = Vec::new();
    if let Some(list) = args.get("bench") {
        for path in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            benches.push((path.to_string(), Json::parse_file(std::path::Path::new(path))?));
        }
    }
    let html = report::render(&records, &benches, &index.display().to_string());
    rudra::util::write_atomic(&out, &html)?;
    println!(
        "wrote report over {} run(s) / {} bench baseline(s) to {}",
        records.len(),
        benches.len(),
        out.display()
    );
    Ok(())
}

/// `rudra bench-diff OLD.json NEW.json` — the perf-trajectory gate over
/// two `BENCH_hotpath.json` baselines; exits non-zero on regression.
fn cmd_bench_diff(args: &Args) -> Result<()> {
    use rudra::obs::benchdiff;
    use rudra::util::json::Json;
    let (Some(old_path), Some(new_path)) =
        (args.positional.first(), args.positional.get(1))
    else {
        anyhow::bail!("usage: rudra bench-diff OLD.json NEW.json [--threshold F] [--strict]");
    };
    let threshold = args.f64_or("threshold", benchdiff::DEFAULT_THRESHOLD)?;
    let old = Json::parse_file(std::path::Path::new(old_path))?;
    let new = Json::parse_file(std::path::Path::new(new_path))?;
    let report = benchdiff::compare(&old, &new, threshold, args.flag("strict"))?;
    for line in &report.lines {
        println!("{line}");
    }
    if !report.passed() {
        anyhow::bail!(
            "{} perf regression(s) past the {threshold}x noise threshold:\n  {}",
            report.regressions.len(),
            report.regressions.join("\n  ")
        );
    }
    println!("bench-diff: OK ({old_path} -> {new_path}, threshold {threshold}x)");
    Ok(())
}
