//! `rudra` — CLI for the Rudra reproduction (leader entrypoint).
//!
//! Subcommands:
//! * `info`              — artifact/platform summary
//! * `train`             — live engine (threads) on the synthetic CNN
//! * `sim`               — one (σ, μ, λ) point: real SGD + simulated time
//! * `sweep`             — (μ, λ) grid under one protocol
//! * `timing`            — timing-only simulation at paper scale

use anyhow::Result;

use rudra::config::RunConfig;
use rudra::coordinator::engine_live::{run_live, LiveConfig, LiveElastic};
use rudra::coordinator::engine_sim::{SimConfig, SimEngine};
use rudra::coordinator::protocol::Protocol;
use rudra::elastic::checkpoint::SimCheckpoint;
use rudra::elastic::rescaler::RescalePolicy;
use rudra::harness::sweep::Sweep;
use rudra::harness::Workspace;
use rudra::netsim::cost::ModelCost;
use rudra::params::optimizer::Optimizer;
use rudra::stats::table::{f, pct, Table};
use rudra::util::cli::Args;
use rudra::util::fmt_secs;

const USAGE: &str = "usage: rudra <info|train|sim|sweep|timing> [--flags]
  info                      show artifacts, platform, model sizes
  train                     live engine (real threads) on the synthetic CNN
  sim                       one (σ,μ,λ) point: real SGD + simulated P775 time
  sweep                     (μ,λ) grid under one protocol
  timing                    timing-only simulation at paper scale
common flags: --protocol hardsync|async|<n>-softsync|backup:<b>
              --arch base|adv|adv*
              --mu N --lambda N --epochs N --seed N --lr F --config FILE
              --shards S (root parameter shards; 1 = flat server)
sweep grid:   --mus a,b,c --lambdas a,b,c (grid axes; JSON keys mus/lambdas)
              --jobs N (worker threads for grid points; 0 = auto
                [available parallelism], 1 = serial — results are
                bit-identical at any value)
elasticity:   --churn SPEC (kill:<id>@<t>,rejoin:<id>@<t>,join:<id>@<t>,
                rate:<kills/1000s>,downtime:<mean-s> | none) [sim/sweep/timing]
              --rescale none|mulambda (hold μ·λ_active ≈ μ₀·λ₀)
              --checkpoint-every N (server checkpoint every N updates)
                [sim/sweep/timing]
              --heartbeat-ms N (live engine: evict learners silent > 2N ms)
              --epoch-csv FILE (sim: per-epoch CSV incl. active-λ column)
stragglers:   --hetero SPEC (slow:<id>x<f>,lognormal:<σ>,pareto:<α>,
                markov:<p↓>:<p↑>:<mult> | none) per-learner speed skew
                [sim/sweep/timing]
              --adaptive sigma:<target>[,band:<f>] (retune n-softsync's n
                per epoch to hold ⟨σ⟩) [sim/sweep/timing]
comm:         --compress none|topk:<frac>|qsgd:<bits> (gradient codec with
                per-learner error-feedback residuals; shrinks push wire
                time) [all engines]
              --comm-csv FILE (sim: per-learner compressed-bytes +
                residual-norm rows)
scale/resume: --max-updates N (timing: hard cap on weight updates — quick
                CI points at datacenter λ)
              --stop-after-events N (timing: halt after N processed events
                and capture a mid-flight sim checkpoint; the count is
                absolute, so a resume passes the total, not a remainder)
              --sim-checkpoint FILE (timing: where that checkpoint is
                written; JSON keys stop_after_events / sim_checkpoint)
              --resume FILE (timing: install a sim checkpoint captured
                under the *same* config and continue bit-identically)
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = argv.remove(0);
    let args = Args::parse(argv, &["verbose", "eval-each-epoch", "no-eval"])?;

    let mut cfg = RunConfig::default();
    if let Some(path) = args.get("config") {
        cfg.apply_file(std::path::Path::new(path))?;
    }
    cfg.apply_args(&args)?;

    match cmd.as_str() {
        "info" => cmd_info(),
        "train" => cmd_train(&cfg, &args),
        "sim" => cmd_sim(&cfg, &args),
        "sweep" => cmd_sweep(&cfg),
        "timing" => cmd_timing(&cfg, &args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            anyhow::bail!("unknown command {other:?}\n{USAGE}");
        }
    }
}

/// One comm line (quiet codecs print nothing): the byte/ratio/residual
/// summary, plus the root-tier in/out breakdown when the engine tracked
/// it (the sim paths; the live engine's fabric is a real channel).
fn print_comm(
    compress: rudra::comm::codec::CodecSpec,
    model_bytes: f64,
    bytes_by_learner: &[f64],
    residual_norms: &[f64],
    root_in_out: Option<(f64, f64)>,
) {
    if compress.is_quiet() {
        return;
    }
    let ratio =
        rudra::comm::wire::WireModel::new(compress, model_bytes).compression_ratio();
    let summary = rudra::stats::comm_summary(bytes_by_learner, residual_norms, ratio);
    match root_in_out {
        Some((r_in, r_out)) => println!(
            "{summary}  (root bytes: {} in / {} out)",
            rudra::util::fmt_bytes(r_in),
            rudra::util::fmt_bytes(r_out)
        ),
        None => println!("{summary}"),
    }
}

/// Live-engine elasticity from the config + CLI: `--heartbeat-ms` arms
/// eviction of silent learners; the rescale policy rides along. (The
/// time-based `--churn` DSL drives the *sim* engine; the live engine's
/// deterministic churn schedules are test-facing —
/// [`rudra::coordinator::engine_live::LiveElastic`].)
fn live_elastic(cfg: &RunConfig, args: &Args) -> Result<Option<LiveElastic>> {
    let hb_ms = args.u64_or("heartbeat-ms", 0)?;
    if hb_ms == 0 && cfg.rescale == RescalePolicy::None {
        return Ok(None);
    }
    let mut e = LiveElastic::heartbeat(std::time::Duration::from_millis(hb_ms));
    e.rescale = cfg.rescale;
    Ok(Some(e))
}

fn cmd_info() -> Result<()> {
    let ws = Workspace::open_default()?;
    println!("platform: {}", ws.runtime.platform());
    println!(
        "cnn: {} params, grad batches {:?}",
        ws.manifest.cnn.params,
        ws.manifest.cnn.batch_sizes()
    );
    match &ws.manifest.lm {
        Some(lm) => println!(
            "lm:  {} params, batch {}, seq {}",
            lm.params, ws.manifest.lm_batch, ws.manifest.lm_seq
        ),
        None => println!("lm:  (not built — aot ran with --skip-lm)"),
    }
    println!(
        "data: train {} / test {} images ({}x{}x{}, {} classes), corpus {} bytes",
        ws.train.n,
        ws.test.n,
        ws.train.h,
        ws.train.w,
        ws.train.c,
        ws.train.classes,
        ws.corpus.bytes.len()
    );
    Ok(())
}

fn cmd_train(cfg: &RunConfig, args: &Args) -> Result<()> {
    use rudra::harness::providers::{ComputeService, ServiceProvider};
    let manifest_path = std::env::var("RUDRA_MANIFEST")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| rudra::runtime::Manifest::default_path());
    println!("live training {}", cfg.label());

    // PJRT is not Send: gradient execution runs on a dedicated compute
    // service thread; learner threads talk to it over channels.
    let service = ComputeService::start_cnn(manifest_path.clone(), cfg.mu)?;
    let train = std::sync::Arc::new(rudra::data::loader::ImageSet::load(
        &rudra::runtime::Manifest::load(&manifest_path)?.data.train,
    )?);
    let providers: Vec<Box<dyn rudra::coordinator::learner::GradProvider + Send>> = (0
        ..cfg.lambda)
        .map(|id| {
            Box::new(ServiceProvider::new(&service, train.clone(), cfg.mu, cfg.seed, id))
                as Box<dyn rudra::coordinator::learner::GradProvider + Send>
        })
        .collect();

    let live_cfg = LiveConfig {
        protocol: cfg.protocol,
        mu: cfg.mu,
        lambda: cfg.lambda,
        epochs: cfg.epochs,
        samples_per_epoch: train.n as u64,
        shards: cfg.shards,
        log_every: args.u64_or("log-every", 50)?,
        elastic: live_elastic(cfg, args)?,
        compress: cfg.compress,
        checkpoint_every: cfg.checkpoint_every,
    };
    let ws = Workspace::open_default()?;
    let theta0 = ws.cnn_init()?;
    let optimizer = Optimizer::new(cfg.optimizer, cfg.weight_decay, theta0.len());
    let result = run_live(&live_cfg, theta0, optimizer, cfg.lr_policy(), providers)?;

    for (push, loss) in &result.loss_log {
        println!("  push {push:>6}  train-loss {loss:.4}");
    }
    println!(
        "done: {} updates, {} pushes, wall {}, ⟨σ⟩={:.2}, max σ={}",
        result.updates,
        result.pushes,
        fmt_secs(result.wall_seconds),
        result.staleness.overall_avg(),
        result.staleness.max
    );
    if cfg.shards > 1 {
        println!("server: {}", rudra::stats::shard_update_summary(&result.shard_updates));
    }
    print_comm(
        cfg.compress,
        4.0 * result.theta.len() as f64,
        &result.comm_bytes_by_learner,
        &[],
        None,
    );
    if result.checkpoints_taken > 0 {
        println!("checkpoints: {} captured (live engine)", result.checkpoints_taken);
    }
    if !result.churn.is_empty() {
        println!(
            "membership: {} (λ_active at end: {})",
            rudra::stats::churn_summary(&result.churn, &result.recovery_secs),
            result.final_active_lambda
        );
    }

    if !args.flag("no-eval") {
        let eval = ws.cnn_eval()?;
        let mut ev =
            rudra::stats::ImageEvaluator::new(&eval, &ws.test, ws.manifest.cnn.eval_batch);
        use rudra::coordinator::engine_sim::Evaluator;
        let (loss, err) = ev.eval(&result.theta)?;
        println!("test: loss {loss:.4}, error {err:.2}%");
    }
    Ok(())
}

fn cmd_sim(cfg: &RunConfig, args: &Args) -> Result<()> {
    let ws = Workspace::open_default()?;
    let mut sweep = Sweep::new(&ws, cfg.epochs);
    sweep.seed = cfg.seed;
    sweep.arch = cfg.arch;
    sweep.eval_each_epoch = args.flag("eval-each-epoch");
    println!("sim {}  (epochs={})", cfg.label(), cfg.epochs);
    let p = sweep.run_point(cfg)?;
    println!(
        "test error {:.2}%  train loss {:.4}  ⟨σ⟩={:.2}  max σ={}  updates={}",
        p.test_error_pct, p.train_loss, p.avg_staleness, p.max_staleness, p.updates
    );
    println!(
        "simulated time: synthetic workload {}  |  paper CIFAR10 geometry {}",
        fmt_secs(p.sim_seconds),
        fmt_secs(p.paper_sim_seconds)
    );
    if p.churn_events > 0 {
        let mean_rec = rudra::util::mean(&p.recovery_secs);
        println!(
            "membership: {} churn events, λ_active at end {}, mean recovery {}",
            p.churn_events,
            p.final_active_lambda,
            fmt_secs(mean_rec)
        );
    }
    if !cfg.hetero.is_quiet() || p.dropped_gradients > 0 {
        println!(
            "stragglers: {}",
            rudra::stats::straggler_summary(&p.learner_utilization, &p.dropped_by_learner)
        );
    }
    if !p.adaptive.is_empty() {
        println!("{}", rudra::stats::adaptive_summary(&p.adaptive));
    }
    print_comm(
        cfg.compress,
        ws.cnn_cost().bytes,
        &p.comm_bytes_by_learner,
        &p.residual_norms,
        Some((p.root_bytes_in, p.root_bytes_out)),
    );
    for e in &p.epochs {
        if let Some(err) = e.test_error_pct {
            println!(
                "  epoch {:>3}  sim t {:>10}  train loss {:.4}  test err {:.2}%  λ_active {}",
                e.epoch,
                fmt_secs(e.sim_time),
                e.train_loss,
                err,
                e.active_lambda
            );
        }
    }
    if let Some(path) = args.get("epoch-csv") {
        let mut log = rudra::stats::log::CsvLog::create(
            std::path::Path::new(path),
            &rudra::stats::log::EPOCH_COLUMNS,
        )?;
        for e in &p.epochs {
            log.row(&rudra::stats::log::epoch_row(e))?;
        }
        println!("wrote {} epoch rows to {path}", p.epochs.len());
    }
    if let Some(path) = args.get("comm-csv") {
        let mut log = rudra::stats::log::CsvLog::create(
            std::path::Path::new(path),
            &rudra::stats::log::COMM_COLUMNS,
        )?;
        for l in 0..p.comm_bytes_by_learner.len() {
            log.row(&rudra::stats::log::comm_row(
                l,
                p.comm_bytes_by_learner[l],
                p.residual_norms.get(l).copied().unwrap_or(0.0),
            ))?;
        }
        println!("wrote {} comm rows to {path}", p.comm_bytes_by_learner.len());
    }
    Ok(())
}

fn cmd_sweep(cfg: &RunConfig) -> Result<()> {
    let ws = Workspace::open_default()?;
    // Grid axes layer like every other knob: JSON config (`mus`/`lambdas`)
    // under CLI (`--mus`/`--lambdas`), already merged into `cfg`.
    let mus = cfg.sweep_mus.clone().unwrap_or_else(|| vec![4, 32, 128]);
    let lambdas = cfg.sweep_lambdas.clone().unwrap_or_else(|| vec![1, 4, 30]);
    let mut sweep = Sweep::new(&ws, cfg.epochs);
    sweep.seed = cfg.seed;
    sweep.arch = cfg.arch;
    sweep.jobs = cfg.jobs;
    let points = mus.len() * lambdas.len();
    println!(
        "sweep: {points} grid points on {} worker thread(s)",
        rudra::harness::sweep::resolve_jobs(cfg.jobs).min(points.max(1))
    );
    let proto = cfg.protocol;
    let results = sweep.run_grid(&mus, &lambdas, |_lambda| proto)?;
    let mut t = Table::new(&["μ", "λ", "⟨σ⟩", "test err", "sim time (paper geom)"]);
    for r in &results {
        t.row(vec![
            r.mu.to_string(),
            r.lambda.to_string(),
            f(r.avg_staleness, 2),
            pct(r.test_error_pct),
            fmt_secs(r.paper_sim_seconds),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_timing(cfg: &RunConfig, args: &Args) -> Result<()> {
    let model = match args.str_or("workload", "cifar10").as_str() {
        "cifar10" => ModelCost::cifar10(),
        "imagenet" => ModelCost::imagenet(),
        "adversarial" => ModelCost::adversarial_300mb(),
        other => anyhow::bail!("unknown workload {other:?}"),
    };
    let epochs = args.usize_or("epochs", cfg.epochs)?;
    let mut sim_cfg = SimConfig::paper(cfg.protocol, cfg.arch, cfg.mu, cfg.lambda, epochs, model);
    sim_cfg.shards = cfg.shards;
    sim_cfg.seed = cfg.seed;
    sim_cfg.churn = cfg.churn.clone();
    sim_cfg.rescale = cfg.rescale;
    sim_cfg.checkpoint_every_updates = cfg.checkpoint_every;
    sim_cfg.hetero = cfg.hetero.clone();
    sim_cfg.adaptive = cfg.adaptive.clone();
    sim_cfg.compress = cfg.compress;
    sim_cfg.stop_after_events = cfg.stop_after_events;
    sim_cfg.sim_checkpoint_path = cfg.sim_checkpoint.clone();
    if args.get("max-updates").is_some() {
        sim_cfg.max_updates = Some(args.u64_or("max-updates", 0)?);
    }
    let mut engine = SimEngine::new(
        &sim_cfg,
        rudra::params::FlatVec::zeros(0),
        Optimizer::new(rudra::params::optimizer::OptimizerKind::Sgd, 0.0, 0),
        cfg.lr_policy(),
        None,
        None,
    );
    if let Some(path) = args.get("resume") {
        let ckpt = SimCheckpoint::load(std::path::Path::new(path))?;
        println!(
            "resuming from {path} ({} events already processed)",
            ckpt.events_processed()?
        );
        engine.install_sim_checkpoint(&ckpt)?;
    }
    let r = engine.run()?;
    println!(
        "{}: {} epochs in simulated {}  ({} updates, ⟨σ⟩={:.2}, overlap {:.2}%, {} events)",
        cfg.label(),
        epochs,
        fmt_secs(r.sim_seconds),
        r.updates,
        r.staleness.overall_avg(),
        r.overlap.overlap_pct(),
        r.events_processed
    );
    if cfg.shards > 1 {
        println!("server: {}", rudra::stats::shard_update_summary(&r.shard_updates));
    }
    if !r.churn.is_empty() {
        println!(
            "membership: {} (λ_active at end: {})",
            rudra::stats::churn_summary(&r.churn, &r.recovery_secs),
            r.final_active_lambda
        );
    }
    if r.checkpoints_taken > 0 {
        println!("checkpoints: {} captured", r.checkpoints_taken);
    }
    if r.sim_checkpoint.is_some() {
        match &sim_cfg.sim_checkpoint_path {
            Some(p) => println!(
                "sim checkpoint: stopped after {} events → {}",
                r.events_processed,
                p.display()
            ),
            None => println!(
                "sim checkpoint: stopped after {} events (in-memory only; \
                 pass --sim-checkpoint FILE to persist)",
                r.events_processed
            ),
        }
    }
    if !cfg.hetero.is_quiet() || r.dropped_gradients > 0 {
        println!(
            "stragglers: {}",
            rudra::stats::straggler_summary(&r.learner_utilization, &r.dropped_by_learner)
        );
    }
    if !r.adaptive.is_empty() {
        println!("{}", rudra::stats::adaptive_summary(&r.adaptive));
    }
    print_comm(
        cfg.compress,
        sim_cfg.model.bytes,
        &r.comm_bytes_by_learner,
        &r.residual_norms,
        Some((r.root_bytes_in, r.root_bytes_out)),
    );
    let _ = Protocol::Hardsync; // referenced for doc completeness
    Ok(())
}
