//! # Rudra — reproduction of "Model Accuracy and Runtime Tradeoff in
//! # Distributed Deep Learning: A Systematic Study" (IJCAI 2017)
//!
//! A parameter-server distributed deep-learning framework in the paper's
//! image: learners compute gradients (real numerics, via AOT-compiled
//! JAX/Pallas HLO executed through PJRT), a parameter server applies them
//! under one of three synchronization protocols (hardsync, n-softsync,
//! async), and a vector clock quantifies gradient staleness.
//!
//! Two execution engines are provided:
//! * [`coordinator::engine_sim`] — a deterministic virtual-time engine in
//!   which compute and communication durations come from a discrete-event
//!   cluster model ([`netsim`]) calibrated to the paper's P775 testbed,
//!   while gradients are computed for real. One run yields both an
//!   accuracy trajectory and a simulated wall-clock.
//! * [`coordinator::engine_live`] — a tokio-based live engine (threads +
//!   channels), the "production" path.
//!
//! See DESIGN.md for the experiment index mapping every table and figure
//! of the paper to a bench target.

pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod elastic;
pub mod harness;
pub mod netsim;
pub mod obs;
pub mod params;
pub mod runtime;
pub mod stats;
pub mod straggler;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
