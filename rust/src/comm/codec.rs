//! Gradient compression codecs with per-learner error feedback.
//!
//! A [`CodecSpec`] names the scheme (the `compress` config knob); a
//! [`LearnerCodec`] realizes it for one learner: it owns the learner's
//! error-feedback residual `r` and (for the stochastic quantizer) a
//! dedicated RNG stream. Every encode works on the *accumulated* vector
//! `a = g + r`: the transmitted part becomes the [`EncodedGrad`], the
//! untransmitted part becomes the new residual, so
//! `decoded + r' == g + r` holds **exactly** in f32 for `topk` (the
//! partition moves entries, it never rounds them) — the lossless-in-
//! aggregate property the tests pin — and within one quantization level
//! per coordinate for `qsgd`.
//!
//! Determinism: the quantizer draws from its own named stream (seeded
//! from the run seed and the learner id), never the engine's, so
//! `compress none` keeps fixed-seed trajectories bit-identical and a
//! quantized run replays exactly. [`CommState`] bundles one codec per
//! learner slot and serializes residuals + RNG states for
//! [`crate::elastic::checkpoint::Checkpoint`].

use anyhow::{bail, Result};

use crate::params::FlatVec;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Stream-decorrelation constant for codec RNGs (distinct from the
/// hetero model's and the failure injector's).
const COMM_STREAM: u64 = 0x9E3C_0DEC_57A3_11B7;

/// Compression scheme, parsed from the `compress` config knob:
/// `none` (default), `topk:<frac>` (keep the ⌈frac·n⌉ largest-magnitude
/// coordinates of g + r; 8 wire bytes per survivor), or `qsgd:<bits>`
/// (stochastic quantization to 2^bits − 1 magnitude levels plus sign;
/// bits + 1 wire bits per coordinate plus one f32 norm).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum CodecSpec {
    #[default]
    None,
    TopK { frac: f64 },
    Qsgd { bits: u32 },
}

impl CodecSpec {
    pub fn none() -> CodecSpec {
        CodecSpec::None
    }

    /// True when no codec is configured (the bit-identical baseline path).
    pub fn is_quiet(&self) -> bool {
        matches!(self, CodecSpec::None)
    }

    /// Parse the config DSL (see the type docs).
    pub fn parse(s: &str) -> Result<CodecSpec> {
        let s = s.trim().to_ascii_lowercase();
        if s.is_empty() || s == "none" {
            return Ok(CodecSpec::None);
        }
        if let Some(f) = s.strip_prefix("topk:") {
            let frac: f64 = f
                .parse()
                .map_err(|_| anyhow::anyhow!("bad topk fraction {f:?} (want topk:<frac>)"))?;
            if !frac.is_finite() || frac <= 0.0 || frac > 1.0 {
                bail!("topk fraction must be in (0, 1], got {frac}");
            }
            return Ok(CodecSpec::TopK { frac });
        }
        if let Some(b) = s.strip_prefix("qsgd:") {
            let bits: u32 = b
                .parse()
                .map_err(|_| anyhow::anyhow!("bad qsgd bit width {b:?} (want qsgd:<bits>)"))?;
            if !(1..=8).contains(&bits) {
                bail!("qsgd bit width must be in 1..=8, got {bits}");
            }
            return Ok(CodecSpec::Qsgd { bits });
        }
        bail!("unknown compress spec {s:?} (none | topk:<frac> | qsgd:<bits>)");
    }

    /// Canonical label (round-trips through [`CodecSpec::parse`]).
    pub fn label(&self) -> String {
        match *self {
            CodecSpec::None => "none".to_string(),
            CodecSpec::TopK { frac } => format!("topk:{frac}"),
            CodecSpec::Qsgd { bits } => format!("qsgd:{bits}"),
        }
    }
}

/// One encoded gradient — what travels learner → (leaf) → root. The
/// server decodes it back to a dense vector and then accumulates
/// ([`crate::coordinator::shard::ShardedServer::push_encoded`]).
#[derive(Debug, Clone)]
pub enum EncodedGrad {
    /// Uncompressed (the `none` codec, and the timing-only placeholder).
    Dense(FlatVec),
    /// top-k sparsification: the surviving (index, value) pairs.
    Sparse { dim: usize, idx: Vec<u32>, val: Vec<f32> },
    /// QSGD-style quantization: signed levels in [−s, s], s = 2^bits − 1,
    /// against one max-norm scale.
    Quant { dim: usize, norm: f32, bits: u32, levels: Vec<i32> },
}

/// Shared by encode (residual = a − decoded) and decode so the two
/// always produce bit-identical values.
fn qsgd_value(norm: f32, level: i32, s: f32) -> f32 {
    norm * level as f32 / s
}

impl EncodedGrad {
    /// Decoded gradient length.
    pub fn dim(&self) -> usize {
        match self {
            EncodedGrad::Dense(v) => v.len(),
            EncodedGrad::Sparse { dim, .. } | EncodedGrad::Quant { dim, .. } => *dim,
        }
    }

    /// Decode to the dense vector the server folds. `Dense` payloads pass
    /// through without a copy, which is what keeps the `none` path
    /// allocation- and bit-identical to the pre-codec engine.
    pub fn into_dense(self) -> FlatVec {
        match self {
            EncodedGrad::Dense(v) => v,
            other => {
                let mut out = FlatVec::zeros(0);
                other.decode_into(&mut out);
                out
            }
        }
    }

    /// Decode into a reusable scratch buffer (the servers' per-push
    /// decode pool): `out` is resized to the payload's dim and every
    /// element overwritten, so a dirty buffer decodes bit-identically to
    /// a fresh one. `Dense` payloads *copy* here — callers that can take
    /// ownership should route them through [`EncodedGrad::into_dense`]
    /// (or fold the vector directly) to keep the `none` path copy-free.
    pub fn decode_into(&self, out: &mut FlatVec) {
        match self {
            EncodedGrad::Dense(v) => {
                out.data.clear();
                out.data.extend_from_slice(&v.data);
            }
            EncodedGrad::Sparse { dim, idx, val } => {
                out.data.clear();
                out.data.resize(*dim, 0.0);
                for (&i, &v) in idx.iter().zip(val.iter()) {
                    out.data[i as usize] = v;
                }
            }
            EncodedGrad::Quant { dim, norm, bits, levels } => {
                let s = ((1u32 << bits) - 1) as f32;
                out.data.clear();
                out.data.resize(*dim, 0.0);
                for (o, &l) in out.data.iter_mut().zip(levels.iter()) {
                    *o = qsgd_value(*norm, l, s);
                }
            }
        }
    }

    /// Actual encoded payload size in bytes (4 per dense f32, 4 + 4 per
    /// sparse survivor, (bits + 1)/8 per quantized coordinate plus the
    /// f32 norm). Engines price wire time off the *deterministic*
    /// [`crate::comm::wire::WireModel`] instead, so numeric and
    /// timing-only runs account bytes identically; this is the
    /// ground-truth the wire model is validated against.
    pub fn wire_bytes(&self) -> f64 {
        match self {
            EncodedGrad::Dense(v) => 4.0 * v.len() as f64,
            EncodedGrad::Sparse { idx, .. } => 8.0 * idx.len() as f64,
            EncodedGrad::Quant { dim, bits, .. } => {
                4.0 + *dim as f64 * (*bits + 1) as f64 / 8.0
            }
        }
    }
}

/// One learner's codec: the error-feedback residual plus the quantizer's
/// RNG stream.
#[derive(Debug, Clone)]
pub struct LearnerCodec {
    spec: CodecSpec,
    residual: FlatVec,
    /// Tracks whether the residual holds any non-zero entry. The quiet
    /// case skips the `g + r` add entirely, so `topk:1.0` (which always
    /// drains its residual) transmits `g` bit-for-bit — the identity the
    /// `topk:1.0 ≡ baseline` property test pins.
    has_residual: bool,
    rng: Rng,
}

impl LearnerCodec {
    /// Codec for learner `learner` over an `n_params` model. `seed` is
    /// the run seed; each learner derives an independent stream from it.
    pub fn new(spec: CodecSpec, n_params: usize, seed: u64, learner: usize) -> LearnerCodec {
        LearnerCodec {
            spec,
            residual: FlatVec::zeros(n_params),
            has_residual: false,
            rng: Rng::new(seed ^ COMM_STREAM).split(learner as u64),
        }
    }

    pub fn spec(&self) -> CodecSpec {
        self.spec
    }

    /// L2 norm of the current error-feedback residual (0 for `none` and
    /// for codecs that have drained it).
    pub fn residual_norm(&self) -> f64 {
        if self.has_residual {
            self.residual.norm()
        } else {
            0.0
        }
    }

    /// Reset the residual (a killed learner's untransmitted error dies
    /// with its process; its rejoined incarnation starts clean).
    pub fn reset_residual(&mut self) {
        if self.has_residual {
            self.residual.fill(0.0);
            self.has_residual = false;
        }
    }

    /// The accumulated vector a = g + r (skipping the add while the
    /// residual is identically zero, so the quiet path is bitwise `g`).
    fn accumulate(&self, grad: &FlatVec) -> FlatVec {
        if self.has_residual {
            let mut a = grad.clone();
            a.add_assign(&self.residual);
            a
        } else {
            grad.clone()
        }
    }

    /// Encode one gradient, updating the residual: the returned payload
    /// plus the new residual partition (or quantize-and-difference) the
    /// accumulated `g + r` exactly.
    pub fn encode(&mut self, grad: &FlatVec) -> EncodedGrad {
        debug_assert_eq!(grad.len(), self.residual.len());
        match self.spec {
            CodecSpec::None => EncodedGrad::Dense(grad.clone()),
            CodecSpec::TopK { frac } => {
                let a = self.accumulate(grad);
                let n = a.len();
                let k = ((frac * n as f64).ceil() as usize).clamp(1, n.max(1));
                let mut order: Vec<u32> = (0..n as u32).collect();
                // Partition the k largest |a| to the front in O(n) —
                // this runs on every push of every learner, so the full
                // sort's O(n log n) would be pure waste. The comparator
                // is a total order (magnitude desc, index asc), so the
                // selected set is deterministic even with repeated
                // magnitudes.
                if k < n {
                    order.select_nth_unstable_by(k - 1, |&x, &y| {
                        let (ax, ay) = (a.data[x as usize].abs(), a.data[y as usize].abs());
                        ay.total_cmp(&ax).then(x.cmp(&y))
                    });
                }
                let mut idx: Vec<u32> = order[..k.min(n)].to_vec();
                idx.sort_unstable();
                let val: Vec<f32> = idx.iter().map(|&i| a.data[i as usize]).collect();
                // residual := a with the transmitted entries zeroed
                self.residual = a;
                for &i in &idx {
                    self.residual.data[i as usize] = 0.0;
                }
                self.has_residual = self.residual.data.iter().any(|&x| x != 0.0);
                EncodedGrad::Sparse { dim: n, idx, val }
            }
            CodecSpec::Qsgd { bits } => {
                let a = self.accumulate(grad);
                let n = a.len();
                let s_int = (1u32 << bits) - 1;
                let s = s_int as f32;
                let norm = a.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                let mut levels = vec![0i32; n];
                if norm > 0.0 {
                    for (l, &x) in levels.iter_mut().zip(a.data.iter()) {
                        let scaled = x.abs() / norm * s;
                        let mut lv = scaled.floor();
                        // stochastic rounding keeps the quantizer unbiased
                        if self.rng.f64() < (scaled - lv) as f64 {
                            lv += 1.0;
                        }
                        let lv = (lv as i32).min(s_int as i32);
                        *l = if x < 0.0 { -lv } else { lv };
                    }
                }
                // residual := a − decoded, with decode's exact arithmetic
                self.residual = a;
                for (r, &l) in self.residual.data.iter_mut().zip(levels.iter()) {
                    *r -= qsgd_value(norm, l, s);
                }
                self.has_residual = self.residual.data.iter().any(|&x| x != 0.0);
                EncodedGrad::Quant { dim: n, norm, bits, levels }
            }
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("residual", Json::arr_f32(&self.residual.data)),
            ("rng", Json::str(format!("{:016x}", self.rng.state()))),
        ])
    }

    fn from_json(spec: CodecSpec, j: &Json) -> Result<LearnerCodec> {
        let residual = FlatVec::from_vec(j.get("residual")?.as_f32_vec()?);
        let state = u64::from_str_radix(j.get("rng")?.as_str()?, 16)
            .map_err(|_| anyhow::anyhow!("bad codec RNG state"))?;
        let has_residual = residual.data.iter().any(|&x| x != 0.0);
        Ok(LearnerCodec { spec, residual, has_residual, rng: Rng::from_state(state) })
    }
}

/// All learner codecs of one run, engine-owned (the sim engine encodes at
/// the push boundary; the live engine moves each codec into its learner
/// thread instead and does not use this bundle). Serialized into
/// checkpoints so a restore continues the exact error-feedback state.
#[derive(Debug, Clone)]
pub struct CommState {
    spec: CodecSpec,
    codecs: Vec<LearnerCodec>,
}

impl CommState {
    /// One codec per learner slot; `None` for a quiet spec so the
    /// baseline path stays untouched.
    pub fn build(spec: CodecSpec, lambda: usize, n_params: usize, seed: u64) -> Option<CommState> {
        if spec.is_quiet() {
            return None;
        }
        let codecs =
            (0..lambda).map(|l| LearnerCodec::new(spec, n_params, seed, l)).collect();
        Some(CommState { spec, codecs })
    }

    pub fn spec(&self) -> CodecSpec {
        self.spec
    }

    pub fn encode(&mut self, learner: usize, grad: &FlatVec) -> EncodedGrad {
        self.codecs[learner].encode(grad)
    }

    pub fn reset_residual(&mut self, learner: usize) {
        self.codecs[learner].reset_residual();
    }

    /// Final per-learner residual L2 norms (the stats column).
    pub fn residual_norms(&self) -> Vec<f64> {
        self.codecs.iter().map(|c| c.residual_norm()).collect()
    }

    /// Serialize spec + every learner's residual and RNG state (the
    /// checkpoint payload; self-contained, so restore needs no config).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("spec", Json::str(self.spec.label())),
            ("codecs", Json::Arr(self.codecs.iter().map(|c| c.to_json()).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<CommState> {
        let spec = CodecSpec::parse(j.get("spec")?.as_str()?)?;
        anyhow::ensure!(!spec.is_quiet(), "comm checkpoint with a quiet codec spec");
        let codecs = j
            .get("codecs")?
            .as_arr()?
            .iter()
            .map(|c| LearnerCodec::from_json(spec, c))
            .collect::<Result<Vec<_>>>()?;
        Ok(CommState { spec, codecs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_label_roundtrip() {
        for s in ["none", "topk:0.01", "topk:1", "qsgd:4", "qsgd:8"] {
            let spec = CodecSpec::parse(s).unwrap();
            assert_eq!(CodecSpec::parse(&spec.label()).unwrap(), spec, "{s}");
        }
        assert!(CodecSpec::parse("none").unwrap().is_quiet());
        assert!(!CodecSpec::parse("topk:0.5").unwrap().is_quiet());
        assert_eq!(CodecSpec::parse("").unwrap(), CodecSpec::None);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(CodecSpec::parse("topk:0").is_err());
        assert!(CodecSpec::parse("topk:1.5").is_err());
        assert!(CodecSpec::parse("topk:x").is_err());
        assert!(CodecSpec::parse("qsgd:0").is_err());
        assert!(CodecSpec::parse("qsgd:9").is_err());
        assert!(CodecSpec::parse("gzip").is_err());
    }

    #[test]
    fn decode_into_matches_into_dense_on_a_dirty_buffer() {
        // The servers' decode pool reuses one scratch buffer across
        // pushes; a leftover from a previous (longer, garbage-filled)
        // decode must not leak into the next one.
        let g = FlatVec::from_vec(vec![1.0, -4.0, 0.5, 3.0, -0.25]);
        let encs = [
            EncodedGrad::Dense(g.clone()),
            LearnerCodec::new(CodecSpec::TopK { frac: 0.4 }, 5, 7, 0).encode(&g),
            LearnerCodec::new(CodecSpec::Qsgd { bits: 4 }, 5, 7, 1).encode(&g),
        ];
        for enc in encs {
            let want = enc.clone().into_dense();
            let mut dirty = FlatVec::from_vec(vec![9.0; 11]);
            enc.decode_into(&mut dirty);
            assert_eq!(dirty.data, want.data, "{enc:?}");
            // and again from a too-short buffer
            let mut short = FlatVec::zeros(1);
            enc.decode_into(&mut short);
            assert_eq!(short.data, want.data);
        }
    }

    #[test]
    fn none_codec_is_bitwise_identity() {
        let mut c = LearnerCodec::new(CodecSpec::None, 4, 7, 0);
        let g = FlatVec::from_vec(vec![0.1, -2.5, 0.0, 3.0e-9]);
        let enc = c.encode(&g);
        assert_eq!(enc.wire_bytes(), 16.0);
        assert_eq!(enc.into_dense().data, g.data);
        assert_eq!(c.residual_norm(), 0.0);
    }

    #[test]
    fn topk_full_fraction_is_bitwise_identity() {
        let mut c = LearnerCodec::new(CodecSpec::TopK { frac: 1.0 }, 5, 7, 2);
        let g = FlatVec::from_vec(vec![0.25, -1.5, 3.0, 0.0, -0.125]);
        for _ in 0..3 {
            let enc = c.encode(&g);
            let dec = enc.into_dense();
            assert_eq!(dec.data, g.data, "frac = 1 transmits everything");
            assert_eq!(c.residual_norm(), 0.0, "residual fully drained");
        }
    }

    #[test]
    fn topk_partitions_exactly_and_picks_largest() {
        let mut c = LearnerCodec::new(CodecSpec::TopK { frac: 0.4 }, 5, 7, 0);
        let g = FlatVec::from_vec(vec![1.0, -4.0, 0.5, 3.0, -0.25]);
        let enc = c.encode(&g);
        // k = ⌈0.4·5⌉ = 2 ⇒ entries 1 (−4) and 3 (3) survive
        match &enc {
            EncodedGrad::Sparse { idx, val, dim } => {
                assert_eq!(*dim, 5);
                assert_eq!(idx, &[1, 3]);
                assert_eq!(val, &[-4.0, 3.0]);
            }
            other => panic!("expected sparse, got {other:?}"),
        }
        let dec = enc.into_dense();
        assert_eq!(dec.data, vec![0.0, -4.0, 0.0, 3.0, 0.0]);
        // exact partition: decoded + residual == g (residual untouched: 0)
        for i in 0..5 {
            assert_eq!(dec.data[i] + c.residual.data[i], g.data[i]);
        }
        // the skipped mass re-enters on the next encode (k = 2: the two
        // largest residual entries, 1.0 and 0.5, come back)
        let z = FlatVec::zeros(5);
        let dec2 = c.encode(&z).into_dense();
        assert_eq!(dec2.data, vec![1.0, 0.0, 0.5, 0.0, 0.0], "residual mass returns");
    }

    #[test]
    fn topk_error_feedback_is_lossless_in_aggregate() {
        // Over a full accumulation cycle, transmitted + final residual
        // equals the running f32 sum of the inputs, exactly: every encode
        // partitions a = g ⊕ r without rounding any entry.
        let n = 32;
        let mut c = LearnerCodec::new(CodecSpec::TopK { frac: 0.25 }, n, 3, 1);
        let mut rng = Rng::new(41);
        let mut transmitted_sum = FlatVec::zeros(n);
        for _ in 0..20 {
            let g = FlatVec::from_vec(
                (0..n).map(|_| (rng.range(-1.0, 1.0)) as f32).collect(),
            );
            // mirror the codec's exact add order: acc = g ⊕ residual_before
            let before = c.residual.clone();
            let mut acc = g.clone();
            acc.add_assign(&before);
            let dec = c.encode(&g).into_dense();
            for i in 0..n {
                assert_eq!(
                    dec.data[i] + c.residual.data[i],
                    acc.data[i],
                    "partition must be exact at coord {i}"
                );
            }
            transmitted_sum.add_assign(&dec);
        }
        assert!(c.residual_norm() > 0.0, "a 0.25 fraction leaves mass in the residual");
        assert!(transmitted_sum.is_finite());
    }

    #[test]
    fn qsgd_error_bounded_by_one_level_and_deterministic() {
        let n = 64;
        let bits = 4u32;
        let s = ((1u32 << bits) - 1) as f32;
        let mut a = LearnerCodec::new(CodecSpec::Qsgd { bits }, n, 9, 0);
        let mut b = LearnerCodec::new(CodecSpec::Qsgd { bits }, n, 9, 0);
        let mut rng = Rng::new(5);
        for _ in 0..5 {
            let g = FlatVec::from_vec((0..n).map(|_| rng.range(-2.0, 2.0) as f32).collect());
            let acc_norm = {
                let mut acc = g.clone();
                if a.has_residual {
                    acc.add_assign(&a.residual);
                }
                acc.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
            };
            let ea = a.encode(&g);
            let eb = b.encode(&g);
            let (da, db) = (ea.into_dense(), eb.into_dense());
            assert_eq!(da.data, db.data, "same seed ⇒ same quantization");
            assert!(da.is_finite());
            // per-coordinate error ≤ one level = norm/s
            for &r in a.residual.data.iter() {
                assert!(
                    r.abs() <= acc_norm / s + 1e-6,
                    "residual {r} exceeds one quantization level {}",
                    acc_norm / s
                );
            }
        }
    }

    #[test]
    fn qsgd_zero_gradient_encodes_to_zero() {
        let mut c = LearnerCodec::new(CodecSpec::Qsgd { bits: 2 }, 3, 1, 0);
        let dec = c.encode(&FlatVec::zeros(3)).into_dense();
        assert_eq!(dec.data, vec![0.0; 3]);
        assert_eq!(c.residual_norm(), 0.0);
    }

    #[test]
    fn reset_residual_clears_error_feedback() {
        let mut c = LearnerCodec::new(CodecSpec::TopK { frac: 0.5 }, 4, 1, 0);
        c.encode(&FlatVec::from_vec(vec![1.0, 2.0, 3.0, 4.0]));
        assert!(c.residual_norm() > 0.0);
        c.reset_residual();
        assert_eq!(c.residual_norm(), 0.0);
        // and the next encode sees a clean slate
        let dec = c.encode(&FlatVec::from_vec(vec![0.0, 0.0, 5.0, 6.0])).into_dense();
        assert_eq!(dec.data, vec![0.0, 0.0, 5.0, 6.0]);
    }

    #[test]
    fn comm_state_roundtrips_through_json() {
        let mut cs = CommState::build(CodecSpec::Qsgd { bits: 3 }, 3, 6, 77).unwrap();
        let mut rng = Rng::new(2);
        for l in 0..3 {
            for _ in 0..4 {
                let g = FlatVec::from_vec((0..6).map(|_| rng.range(-1.0, 1.0) as f32).collect());
                cs.encode(l, &g);
            }
        }
        let text = cs.to_json().to_string();
        let mut back = CommState::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.spec(), cs.spec());
        assert_eq!(back.residual_norms(), cs.residual_norms());
        // both continue bit-identically (residual AND rng restored)
        let g = FlatVec::from_vec(vec![0.3, -0.7, 0.1, 0.9, -0.2, 0.5]);
        for l in 0..3 {
            let a = cs.encode(l, &g).into_dense();
            let b = back.encode(l, &g).into_dense();
            assert_eq!(a.data, b.data, "learner {l} diverged after restore");
        }
    }

    #[test]
    fn comm_state_quiet_spec_builds_nothing() {
        assert!(CommState::build(CodecSpec::None, 4, 8, 1).is_none());
        assert!(CommState::from_json(
            &Json::parse(r#"{"spec": "none", "codecs": []}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn encoded_wire_bytes_match_the_format() {
        let mut topk = LearnerCodec::new(CodecSpec::TopK { frac: 0.25 }, 16, 1, 0);
        let g = FlatVec::from_vec((0..16).map(|i| i as f32 - 8.0).collect());
        let enc = topk.encode(&g);
        assert_eq!(enc.wire_bytes(), 8.0 * 4.0, "4 survivors × 8 bytes");
        let mut q = LearnerCodec::new(CodecSpec::Qsgd { bits: 3 }, 16, 1, 0);
        let enc = q.encode(&g);
        assert_eq!(enc.wire_bytes(), 4.0 + 16.0 * 4.0 / 8.0, "norm + (3+1) bits/coord");
    }
}
