//! Deterministic wire-cost model for compressed gradient traffic.
//!
//! The [`crate::netsim`] fabric never carries payloads — every transfer
//! is priced off a byte count derived from
//! [`crate::netsim::cost::ModelCost::bytes`]. The [`WireModel`] is the
//! single place that byte count is adjusted for a codec, so numeric and
//! timing-only runs account communication identically (and the `none`
//! spec reproduces today's sizes bit for bit):
//!
//! * **push** (gradient, learner → root or learner → leaf): the encoded
//!   payload — `2·frac·M` for `topk:<frac>` (4 value + 4 index bytes per
//!   survivor vs 4 bytes per dense f32), `M·(bits+1)/32 + 4` for
//!   `qsgd:<bits>` (sign + level bits per coordinate plus the f32 norm,
//!   matching [`crate::comm::codec::EncodedGrad::wire_bytes`] exactly),
//!   both capped at the dense size `M` (a codec that inflates the
//!   payload falls back to dense framing);
//! * **relay** (leaf → root): a leaf cannot sum encoded gradients
//!   without decompressing, so it forwards the batch's encodings back to
//!   back — `batch · push` bytes, again capped at `M` (beyond which the
//!   leaf's dense partial sum is the cheaper message, which is exactly
//!   the uncompressed behavior);
//! * **pull / broadcast** (weights, root → learner): always the full
//!   model `M` — the codecs compress gradients, not weights; pull-side
//!   relief comes from the shard-striped broadcast
//!   ([`crate::comm::stripe`]) instead.

use crate::comm::codec::CodecSpec;

/// Compressed-payload sizes for one run's (codec, model) pair.
#[derive(Debug, Clone, Copy)]
pub struct WireModel {
    spec: CodecSpec,
    model_bytes: f64,
}

impl WireModel {
    pub fn new(spec: CodecSpec, model_bytes: f64) -> WireModel {
        WireModel { spec, model_bytes }
    }

    pub fn spec(&self) -> CodecSpec {
        self.spec
    }

    /// Bytes of one encoded gradient push.
    pub fn push_bytes(&self) -> f64 {
        match self.spec {
            CodecSpec::None => self.model_bytes,
            CodecSpec::TopK { frac } => (2.0 * frac * self.model_bytes).min(self.model_bytes),
            CodecSpec::Qsgd { bits } => {
                (self.model_bytes * (bits + 1) as f64 / 32.0 + 4.0).min(self.model_bytes)
            }
        }
    }

    /// Bytes of one leaf → root relay carrying `batch` encoded gradients.
    pub fn relay_bytes(&self, batch: usize) -> f64 {
        (batch.max(1) as f64 * self.push_bytes()).min(self.model_bytes)
    }

    /// Bytes of one weight pull/broadcast hop (never compressed).
    pub fn pull_bytes(&self) -> f64 {
        self.model_bytes
    }

    /// Dense-to-compressed push ratio (1.0 for `none`).
    pub fn compression_ratio(&self) -> f64 {
        let p = self.push_bytes();
        if p > 0.0 {
            self.model_bytes / p
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const M: f64 = 300.0e6;

    #[test]
    fn none_is_dense_everywhere() {
        let w = WireModel::new(CodecSpec::None, M);
        assert_eq!(w.push_bytes(), M);
        assert_eq!(w.pull_bytes(), M);
        assert_eq!(w.relay_bytes(1), M);
        assert_eq!(w.relay_bytes(8), M, "dense relays carry the partial sum: one model");
        assert_eq!(w.compression_ratio(), 1.0);
    }

    #[test]
    fn topk_scales_with_the_fraction_and_caps_at_dense() {
        let w = WireModel::new(CodecSpec::TopK { frac: 0.01 }, M);
        assert!((w.push_bytes() - 0.02 * M).abs() < 1e-6);
        assert!((w.compression_ratio() - 50.0).abs() < 1e-9);
        // 8 forwarded encodings of 0.02·M = 0.16·M
        assert!((w.relay_bytes(8) - 0.16 * M).abs() < 1e-3);
        // frac ≥ 0.5 would inflate past dense: capped
        let w = WireModel::new(CodecSpec::TopK { frac: 1.0 }, M);
        assert_eq!(w.push_bytes(), M);
        assert_eq!(w.relay_bytes(4), M, "capped relay equals the dense partial sum");
    }

    #[test]
    fn qsgd_scales_with_the_bit_width() {
        // 4-bit levels + sign = 5 bits per 32-bit coordinate ≈ 6.4×
        let w = WireModel::new(CodecSpec::Qsgd { bits: 4 }, M);
        assert!((w.push_bytes() - (M * 5.0 / 32.0 + 4.0)).abs() < 1e-6);
        assert!(w.compression_ratio() > 6.0 && w.compression_ratio() < 6.5);
        // pulls stay dense under every codec
        assert_eq!(w.pull_bytes(), M);
    }

    #[test]
    fn wire_model_matches_actual_encodings() {
        // the deterministic model must agree with a real encoded payload
        // (for topk, up to the ⌈frac·n⌉ rounding of the survivor count)
        use crate::comm::codec::LearnerCodec;
        use crate::params::FlatVec;
        let n = 1000usize;
        let mb = 4.0 * n as f64;
        let g = FlatVec::from_vec((0..n).map(|i| (i as f32 - 500.0) * 1e-3).collect());
        let mut c = LearnerCodec::new(CodecSpec::TopK { frac: 0.05 }, n, 1, 0);
        let actual = c.encode(&g).wire_bytes();
        let modeled = WireModel::new(CodecSpec::TopK { frac: 0.05 }, mb).push_bytes();
        assert!((actual - modeled).abs() <= 8.0, "{actual} vs {modeled}");
        let mut c = LearnerCodec::new(CodecSpec::Qsgd { bits: 4 }, n, 1, 0);
        let actual = c.encode(&g).wire_bytes();
        let modeled = WireModel::new(CodecSpec::Qsgd { bits: 4 }, mb).push_bytes();
        assert!((actual - modeled).abs() < 1e-9, "qsgd model must be exact: {actual} vs {modeled}");
    }
}
