//! Shard-striped Adv\* broadcast: one weight-broadcast subtree per root
//! shard.
//!
//! Closes the ROADMAP "shard-aware Adv\* broadcast tree" item. PR 1
//! sharded the *push* path — S root endpoints each receiving a 1/S slice
//! of every gradient — but the Adv\* learner-side broadcast still modeled
//! the weight payload as one model-sized message per tree tier, so its
//! propagation period did not improve with S. The fix mirrors the push
//! striping: each root shard roots its **own** broadcast subtree over the
//! learner tree and streams only its contiguous θ slice
//! ([`crate::coordinator::shard::ShardSpec::range`]) down it. The S
//! subtrees run concurrently over disjoint slices, so one tier hop moves
//! `bytes/S` per link and the end-to-end period becomes
//! `depth · wire_time(bytes/S)` — the same 1/S relief the push path got,
//! now on the pull side. A learner holds the full weights once all S
//! slice streams of an update have reached it (the completion rule the
//! engines already use for striped pulls,
//! [`crate::netsim::cluster::Fabric::send_from_shards`]).
//!
//! With S = 1 the plan degenerates to the flat broadcast, bit for bit —
//! the depth and period arithmetic reproduce the pre-stripe engine
//! formula exactly, which is what keeps `compress none`, S = 1
//! fixed-seed trajectories identical to pre-comm builds.

use crate::netsim::cluster::ClusterSpec;

/// The striped broadcast topology for one run: λ learners in a tree of
/// the given fan-out, fed by `shards` concurrent slice streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StripePlan {
    pub lambda: usize,
    pub fanout: usize,
    pub shards: usize,
}

impl StripePlan {
    /// `shards` is clamped to ≥ 1 (0 would be a divide-by-zero typo, and
    /// 1 is the flat broadcast).
    pub fn new(lambda: usize, fanout: usize, shards: usize) -> StripePlan {
        StripePlan { lambda, fanout, shards: shards.max(1) }
    }

    /// Tree depth in hops. Matches the engine's historical formula
    /// exactly (same f64 operation sequence) so S = 1 periods are
    /// bit-identical to pre-stripe builds.
    pub fn depth(&self) -> f64 {
        (self.lambda.max(2) as f64)
            .log(self.fanout.max(2) as f64)
            .ceil()
            .max(1.0)
    }

    /// Bytes one tier hop carries per subtree: the shard's θ slice.
    pub fn slice_bytes(&self, model_bytes: f64) -> f64 {
        model_bytes / self.shards as f64
    }

    /// End-to-end broadcast period: the time for an update's weights to
    /// reach the whole tree, all S slice streams propagating in parallel.
    pub fn period(&self, cluster: &ClusterSpec, model_bytes: f64) -> f64 {
        self.depth() * cluster.wire_time(self.slice_bytes(model_bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_plan_reproduces_the_legacy_period_formula() {
        let cluster = ClusterSpec::p775();
        let (lambda, lpn, bytes) = (54usize, 8usize, 289.0e6);
        let plan = StripePlan::new(lambda, lpn, 1);
        // the pre-stripe engine formula, verbatim
        let fan = lpn.max(2) as f64;
        let depth = (lambda.max(2) as f64).log(fan).ceil().max(1.0);
        let legacy = depth * cluster.wire_time(bytes);
        assert_eq!(plan.period(&cluster, bytes), legacy, "S = 1 must be bit-identical");
    }

    #[test]
    fn striping_divides_the_period_payload_by_s() {
        let cluster = ClusterSpec::p775();
        let bytes = 300.0e6;
        let flat = StripePlan::new(32, 8, 1).period(&cluster, bytes);
        let striped = StripePlan::new(32, 8, 4).period(&cluster, bytes);
        // latency is per-hop either way; the bandwidth term shrinks 4×
        assert!(striped < flat / 3.0, "{striped} vs {flat}");
        assert!(striped > flat / 5.0, "latency floor keeps it above exactly 1/4");
    }

    #[test]
    fn zero_shards_clamps_to_flat() {
        let plan = StripePlan::new(8, 4, 0);
        assert_eq!(plan.shards, 1);
        assert_eq!(plan.slice_bytes(100.0), 100.0);
    }

    #[test]
    fn depth_grows_with_lambda_and_shrinks_with_fanout() {
        assert_eq!(StripePlan::new(8, 8, 1).depth(), 1.0);
        assert_eq!(StripePlan::new(64, 8, 1).depth(), 2.0);
        assert_eq!(StripePlan::new(64, 2, 1).depth(), 6.0);
        // degenerate λ ≤ 2 / fanout ≤ 2 clamp instead of NaN-ing
        assert_eq!(StripePlan::new(1, 1, 1).depth(), 1.0);
    }
}
