//! Communication subsystem: gradient compression codecs + wire-cost
//! model + shard-striped Adv\* broadcast.
//!
//! The paper's own analysis (§3.2–3.3, Table 1) pins the runtime ceiling
//! on bytes through the root parameter server — every push carries the
//! full model ([`crate::netsim::cost::ModelCost::bytes`]), and at
//! λ = 16 × 300 MB the root NIC serializes the wave into a >1 s stall.
//! This module adds the missing axis of the accuracy–runtime tradeoff:
//! trade gradient *fidelity* for *wire time* (Dutta et al., *Slow and
//! Stale Gradients Can Win the Race*; Chen et al., *Revisiting
//! Distributed Synchronous SGD* motivate cheapening per-round cost to
//! keep sync protocols viable).
//!
//! Three layers:
//! * [`codec`] — the value path: `none`, `topk:<frac>` sparsification,
//!   and `qsgd:<bits>` stochastic quantization, each with per-learner
//!   error-feedback residuals (Karimireddy et al.'s EF-SGD scheme: the
//!   untransmitted part of every gradient is carried forward into the
//!   next encode, so compression error is fed back rather than lost).
//!   Residuals and the quantizer's RNG stream are serialized through
//!   [`crate::elastic::checkpoint`].
//! * [`wire`] — the time path: deterministic compressed-payload sizes
//!   reported to the [`crate::netsim`] fabric, so push/relay times shrink
//!   with the codec while weight pulls stay model-sized. Byte accounting
//!   is identical in numeric and timing-only runs.
//! * [`stripe`] — the topology path (closes the ROADMAP "shard-aware
//!   Adv\* broadcast tree" item): each root shard roots its own broadcast
//!   subtree carrying only its θ slice, so the Adv\* weight-propagation
//!   period scales with `bytes / S` and pull-side scaling matches the
//!   sharded push path of PR 1.
//!
//! **Placement of encode/decode.** Learners encode (updating their
//! residual); the root decodes and then accumulates
//! ([`crate::coordinator::shard::ShardedServer::push_encoded`]), so
//! staleness semantics and the single-clock analysis are untouched — a
//! compressed gradient is still one gradient with one timestamp. The
//! simulated fabric carries byte counts, not payloads, so the encoded
//! form exists between the two calls and the wire model prices it.

pub mod codec;
pub mod stripe;
pub mod wire;
