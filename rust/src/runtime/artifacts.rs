//! The AOT artifact manifest (`artifacts/manifest.json`).
//!
//! Written by `python/compile/aot.py`; indexes every HLO file, initial
//! weight binary, and dataset the Rust side consumes. Loading validates
//! that every referenced file exists so misconfigured runs fail fast.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// A model family's artifacts (grad per batch size, eval, init weights).
#[derive(Debug, Clone)]
pub struct ModelArtifacts {
    pub params: usize,
    /// batch size → grad HLO path.
    pub grad: BTreeMap<usize, PathBuf>,
    pub eval_batch: usize,
    pub eval: PathBuf,
    pub init: PathBuf,
    /// Analytic forward FLOPs per sample (CNN) or per token (LM),
    /// feeding the simulator's cost model.
    pub flops: f64,
}

impl ModelArtifacts {
    /// Batch sizes with a compiled grad graph, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.grad.keys().copied().collect()
    }

    pub fn grad_path(&self, mu: usize) -> Result<&PathBuf> {
        self.grad
            .get(&mu)
            .ok_or_else(|| anyhow::anyhow!(
                "no grad executable for μ={mu}; available: {:?}",
                self.batch_sizes()
            ))
    }
}

/// Dataset pointers + geometry.
#[derive(Debug, Clone)]
pub struct DataArtifacts {
    pub train: PathBuf,
    pub test: PathBuf,
    pub corpus: PathBuf,
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub classes: usize,
    pub train_n: usize,
    pub test_n: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub cnn: ModelArtifacts,
    /// Present unless AOT ran with --skip-lm.
    pub lm: Option<ModelArtifacts>,
    pub lm_batch: usize,
    pub lm_seq: usize,
    pub data: DataArtifacts,
}

impl Manifest {
    /// Default location relative to the repo root.
    pub fn default_path() -> PathBuf {
        PathBuf::from("artifacts/manifest.json")
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let json = Json::parse_file(path)?;
        let dir = path.parent().unwrap_or(Path::new(".")).to_path_buf();

        let cnn = Self::parse_model(&dir, json.get("cnn")?, "flops_per_sample")
            .context("manifest: cnn section")?;
        let (lm, lm_batch, lm_seq) = match json.opt("lm") {
            None => (None, 0, 0),
            Some(lm_json) => {
                let params = lm_json.get("params")?.as_usize()?;
                let batch = lm_json.get("batch")?.as_usize()?;
                let seq = lm_json.get("cfg")?.get("seq")?.as_usize()?;
                let mut grad = BTreeMap::new();
                grad.insert(batch, dir.join(lm_json.get("grad")?.as_str()?));
                let m = ModelArtifacts {
                    params,
                    grad,
                    eval_batch: batch,
                    eval: dir.join(lm_json.get("eval")?.as_str()?),
                    init: dir.join(lm_json.get("init")?.as_str()?),
                    flops: lm_json.get("flops_per_token")?.as_f64()?,
                };
                (Some(m), batch, seq)
            }
        };

        let d = json.get("data")?;
        let data = DataArtifacts {
            train: dir.join(d.get("train")?.as_str()?),
            test: dir.join(d.get("test")?.as_str()?),
            corpus: dir.join(d.get("corpus")?.as_str()?),
            height: d.get("height")?.as_usize()?,
            width: d.get("width")?.as_usize()?,
            channels: d.get("channels")?.as_usize()?,
            classes: d.get("classes")?.as_usize()?,
            train_n: d.get("train_n")?.as_usize()?,
            test_n: d.get("test_n")?.as_usize()?,
        };

        let m = Manifest { dir, cnn, lm, lm_batch, lm_seq, data };
        m.validate()?;
        Ok(m)
    }

    fn parse_model(dir: &Path, j: &Json, flops_key: &str) -> Result<ModelArtifacts> {
        let params = j.get("params")?.as_usize()?;
        let mut grad = BTreeMap::new();
        for (k, v) in j.get("grad")?.as_obj()? {
            let mu: usize = k.parse().with_context(|| format!("grad batch key {k:?}"))?;
            grad.insert(mu, dir.join(v.as_str()?));
        }
        if grad.is_empty() {
            bail!("no grad executables listed");
        }
        let e = j.get("eval")?;
        Ok(ModelArtifacts {
            params,
            grad,
            eval_batch: e.get("batch")?.as_usize()?,
            eval: dir.join(e.get("path")?.as_str()?),
            init: dir.join(j.get("init")?.as_str()?),
            flops: j.get(flops_key)?.as_f64()?,
        })
    }

    fn validate(&self) -> Result<()> {
        let mut paths: Vec<&PathBuf> = vec![
            &self.cnn.eval,
            &self.cnn.init,
            &self.data.train,
            &self.data.test,
            &self.data.corpus,
        ];
        paths.extend(self.cnn.grad.values());
        if let Some(lm) = &self.lm {
            paths.push(&lm.eval);
            paths.push(&lm.init);
            paths.extend(lm.grad.values());
        }
        for p in paths {
            if !p.exists() {
                bail!(
                    "manifest references missing artifact {} — run `make artifacts`",
                    p.display()
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a minimal on-disk manifest + touched artifact files.
    fn fake_manifest(dir: &Path) -> PathBuf {
        std::fs::create_dir_all(dir.join("data")).unwrap();
        for f in [
            "cnn_grad_b4.hlo.txt",
            "cnn_eval_b128.hlo.txt",
            "cnn_init.bin",
            "data/synth_train.bin",
            "data/synth_test.bin",
            "data/corpus.bin",
        ] {
            std::fs::write(dir.join(f), "x").unwrap();
        }
        let text = r#"{
            "cnn": {
                "params": 100,
                "grad": {"4": "cnn_grad_b4.hlo.txt"},
                "eval": {"batch": 128, "path": "cnn_eval_b128.hlo.txt"},
                "init": "cnn_init.bin",
                "flops_per_sample": 123456
            },
            "data": {
                "train": "data/synth_train.bin",
                "test": "data/synth_test.bin",
                "corpus": "data/corpus.bin",
                "height": 12, "width": 12, "channels": 3, "classes": 10,
                "train_n": 2048, "test_n": 512
            },
            "version": 1
        }"#;
        let path = dir.join("manifest.json");
        std::fs::write(&path, text).unwrap();
        path
    }

    #[test]
    fn loads_and_indexes() {
        let dir = std::env::temp_dir().join("rudra_test_manifest");
        let _ = std::fs::remove_dir_all(&dir);
        let path = fake_manifest(&dir);
        let m = Manifest::load(&path).unwrap();
        assert_eq!(m.cnn.params, 100);
        assert_eq!(m.cnn.batch_sizes(), vec![4]);
        assert!(m.lm.is_none());
        assert!(m.cnn.grad_path(4).is_ok());
        assert!(m.cnn.grad_path(8).is_err());
        assert_eq!(m.data.classes, 10);
    }

    #[test]
    fn missing_artifact_fails_fast() {
        let dir = std::env::temp_dir().join("rudra_test_manifest2");
        let _ = std::fs::remove_dir_all(&dir);
        let path = fake_manifest(&dir);
        std::fs::remove_file(dir.join("cnn_init.bin")).unwrap();
        let err = Manifest::load(&path).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
