//! Typed wrappers around PJRT-compiled HLO executables.
//!
//! Load path: `HloModuleProto::from_text_file` → `XlaComputation::from_proto`
//! → `client.compile` (the text parser reassigns instruction ids, which is
//! why text — not serialized protos — is the interchange format; see
//! /opt/xla-example/README.md).
//!
//! Execution: all graphs were lowered with `return_tuple=True`, so each
//! execute yields a single tuple literal that we unpack.

use anyhow::{anyhow, Context, Result};
use std::path::Path;

use crate::params::FlatVec;

/// Shared PJRT CPU client. One per process; executables borrow it.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
    }

    /// Load a gradient graph `(theta[P], x, y) -> (grads[P], loss)`.
    ///
    /// `x_dims`/`y_dims` give the batch tensor shapes (NHWC images + i32
    /// labels for the CNN; i32 token/target matrices for the LM).
    pub fn load_grad(
        &self,
        path: &Path,
        n_params: usize,
        x_dims: Vec<usize>,
        y_dims: Vec<usize>,
    ) -> Result<GradExec> {
        Ok(GradExec {
            exe: self.compile(path)?,
            n_params,
            x_dims,
            y_dims,
            x_is_f32: true,
        })
    }

    /// Same as [`Runtime::load_grad`] but with an integer `x` input (LM
    /// token ids).
    pub fn load_grad_tokens(
        &self,
        path: &Path,
        n_params: usize,
        x_dims: Vec<usize>,
        y_dims: Vec<usize>,
    ) -> Result<GradExec> {
        Ok(GradExec {
            exe: self.compile(path)?,
            n_params,
            x_dims,
            y_dims,
            x_is_f32: false,
        })
    }

    /// Load an eval graph `(theta, x, y) -> (loss[b], correct[b])`.
    pub fn load_eval(
        &self,
        path: &Path,
        n_params: usize,
        x_dims: Vec<usize>,
        y_dims: Vec<usize>,
        x_is_f32: bool,
    ) -> Result<EvalExec> {
        Ok(EvalExec {
            exe: self.compile(path)?,
            n_params,
            x_dims,
            y_dims,
            x_is_f32,
        })
    }
}

fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(dims).map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
}

fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(dims).map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
}

/// The learner's calcGradient executable.
pub struct GradExec {
    exe: xla::PjRtLoadedExecutable,
    pub n_params: usize,
    pub x_dims: Vec<usize>,
    pub y_dims: Vec<usize>,
    x_is_f32: bool,
}

/// Output of one gradient step.
#[derive(Debug, Clone)]
pub struct GradOut {
    pub grads: FlatVec,
    pub loss: f32,
}

impl GradExec {
    /// Run one mini-batch: `theta` (flat weights), `x` (flat batch
    /// tensor), `y` (flat labels/targets).
    pub fn run(&self, theta: &FlatVec, x_f32: &[f32], x_i32: &[i32], y: &[i32]) -> Result<GradOut> {
        let expect_x: usize = self.x_dims.iter().product();
        let expect_y: usize = self.y_dims.iter().product();
        let xd: Vec<i64> = self.x_dims.iter().map(|&d| d as i64).collect();
        let yd: Vec<i64> = self.y_dims.iter().map(|&d| d as i64).collect();
        anyhow::ensure!(theta.len() == self.n_params, "theta length mismatch");

        let theta_lit = literal_f32(&theta.data, &[self.n_params as i64])?;
        let x_lit = if self.x_is_f32 {
            anyhow::ensure!(x_f32.len() == expect_x, "x length mismatch");
            literal_f32(x_f32, &xd)?
        } else {
            anyhow::ensure!(x_i32.len() == expect_x, "x length mismatch");
            literal_i32(x_i32, &xd)?
        };
        let y_lit = literal_i32(y, &yd)?;
        anyhow::ensure!(y.len() == expect_y, "y length mismatch");

        let result = self
            .exe
            .execute::<xla::Literal>(&[theta_lit, x_lit, y_lit])
            .map_err(|e| anyhow!("grad execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("grad to_literal: {e:?}"))?;
        let (grads_lit, loss_lit) =
            tuple.to_tuple2().map_err(|e| anyhow!("grad tuple: {e:?}"))?;
        let grads = grads_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("grads to_vec: {e:?}"))?;
        let loss = loss_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss to_vec: {e:?}"))?
            .first()
            .copied()
            .context("empty loss literal")?;
        Ok(GradOut { grads: FlatVec::from_vec(grads), loss })
    }

    /// Convenience for image models (f32 inputs).
    pub fn run_images(&self, theta: &FlatVec, images: &[f32], labels: &[i32]) -> Result<GradOut> {
        self.run(theta, images, &[], labels)
    }

    /// Convenience for token models (i32 inputs).
    pub fn run_tokens(&self, theta: &FlatVec, tokens: &[i32], targets: &[i32]) -> Result<GradOut> {
        self.run(theta, &[], tokens, targets)
    }
}

/// The statistics server's eval executable.
pub struct EvalExec {
    exe: xla::PjRtLoadedExecutable,
    pub n_params: usize,
    pub x_dims: Vec<usize>,
    pub y_dims: Vec<usize>,
    x_is_f32: bool,
}

impl EvalExec {
    /// Returns (per-example loss, per-example correct∈{0,1}).
    pub fn run(
        &self,
        theta: &FlatVec,
        x_f32: &[f32],
        x_i32: &[i32],
        y: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let xd: Vec<i64> = self.x_dims.iter().map(|&d| d as i64).collect();
        let yd: Vec<i64> = self.y_dims.iter().map(|&d| d as i64).collect();
        let theta_lit = literal_f32(&theta.data, &[self.n_params as i64])?;
        let x_lit = if self.x_is_f32 {
            literal_f32(x_f32, &xd)?
        } else {
            literal_i32(x_i32, &xd)?
        };
        let y_lit = literal_i32(y, &yd)?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[theta_lit, x_lit, y_lit])
            .map_err(|e| anyhow!("eval execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("eval to_literal: {e:?}"))?;
        let (loss_lit, correct_lit) =
            tuple.to_tuple2().map_err(|e| anyhow!("eval tuple: {e:?}"))?;
        let loss = loss_lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let correct = correct_lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok((loss, correct))
    }
}
