//! PJRT runtime: load AOT-compiled HLO text, execute from the hot path.
//!
//! The AOT bridge (DESIGN.md §2): `python/compile/aot.py` lowers every L2
//! graph to **HLO text** once; this module compiles those artifacts on the
//! embedded PJRT CPU client and exposes typed executables to the
//! coordinator. Python never runs at training time.

pub mod artifacts;
pub mod executable;

pub use artifacts::Manifest;
pub use executable::{EvalExec, GradExec, Runtime};
