//! Compute-cost models, calibrated to the paper's published numbers.
//!
//! §4.1: one P775 node = 4× eight-core POWER7 @3.84 GHz, 982 GFLOP/s
//! peak, 512 GB/s memory bandwidth, 192 GB/s bidirectional interconnect.
//! Learners are "4-way multi-threaded" tasks (§3.3's Table 1 scenario),
//! i.e. 8 learners per 32-core node → peak ≈ 982/8 ≈ 123 GFLOP/s per
//! learner, of which dense GEMM achieves a fraction that *falls off at
//! small mini-batch sizes* — §5.2: "a reduction in the mini-batch size
//! results in a proportionate decrease in the GEMM throughput".
//!
//! The falloff is modeled as efficiency(μ) = μ/(μ + μ_half), the standard
//! half-saturation curve for GEMM with a skinny dimension: at μ = 128 the
//! learner runs near its dense-GEMM ceiling, at μ = 4 it is ~8× slower
//! per sample, matching the paper's Figure 6 observation that the
//! (0,4,1) configuration trains slower than (0,128,1) per epoch.

/// A trainable model as the simulator sees it: pure cost numbers.
#[derive(Debug, Clone)]
pub struct ModelCost {
    pub name: &'static str,
    /// Forward-pass FLOPs per sample (backward ≈ 2× forward).
    pub flops_per_sample: f64,
    /// Model size in bytes (the push/pull message size, §3.2).
    pub bytes: f64,
    /// Number of training samples per epoch.
    pub samples_per_epoch: u64,
}

impl ModelCost {
    /// The paper's CIFAR10 study model: ~90K params, ~350 kB, 50 000
    /// training images (§4.2). FLOPs from the caffe cifar10_full shape
    /// (3 conv layers at 32×32→16×16→8×8 + pooling + FC): ≈25 MFLOP/sample
    /// forward.
    pub fn cifar10() -> ModelCost {
        ModelCost {
            name: "cifar10-cnn",
            flops_per_sample: 25.0e6,
            bytes: 350.0e3,
            samples_per_epoch: 50_000,
        }
    }

    /// The paper's ImageNet model (AlexNet-style, §4.2): 72M params,
    /// 289 MB, 1.2M images, ≈1.4 GFLOP/sample forward.
    pub fn imagenet() -> ModelCost {
        ModelCost {
            name: "imagenet-alexnet",
            flops_per_sample: 1.4e9,
            bytes: 289.0e6,
            samples_per_epoch: 1_200_000,
        }
    }

    /// The Table 1 adversarial scenario: "model size is 300MB".
    pub fn adversarial_300mb() -> ModelCost {
        ModelCost {
            name: "adversarial-300mb",
            flops_per_sample: 1.4e9,
            bytes: 300.0e6,
            samples_per_epoch: 1_200_000,
        }
    }

    /// Build a cost model from the AOT manifest (the synthetic CNN),
    /// letting sim-engine timing reflect the *actual* model being trained.
    pub fn from_manifest(
        name: &'static str,
        flops_per_sample: f64,
        n_params: usize,
        samples_per_epoch: u64,
    ) -> ModelCost {
        ModelCost {
            name,
            flops_per_sample,
            bytes: (n_params * 4) as f64,
            samples_per_epoch,
        }
    }
}

/// Per-learner compute-rate model with the small-μ GEMM falloff.
#[derive(Debug, Clone)]
pub struct LearnerCompute {
    /// Peak dense-GEMM rate of one learner (FLOP/s).
    pub peak_flops: f64,
    /// Fraction of peak attainable on this workload at large μ.
    pub gemm_efficiency: f64,
    /// Half-saturation mini-batch size for the GEMM falloff.
    pub mu_half: f64,
    /// Backward-to-forward FLOP ratio (2.0 for dense nets).
    pub backward_ratio: f64,
}

impl LearnerCompute {
    /// P775 defaults: 8 learners/node ⇒ 982/8 ≈ 123 GFLOP/s per-learner
    /// peak. `gemm_efficiency` = 0.2 calibrates against two anchors from
    /// the paper: the CIFAR10 baseline (μ=128, λ=1) takes 22 392 s for
    /// 140 epochs (§5.4) ⇒ ≈410 ms/minibatch, and the ImageNet baseline
    /// (μ=256, λ=1) takes 54 h/epoch (§5.5) ⇒ ≈44 s/minibatch; both land
    /// within 10% at 0.2 of peak. Half-saturation μ ≈ 6 reproduces the
    /// Fig 6/8 small-μ slowdowns.
    pub fn p775() -> LearnerCompute {
        LearnerCompute {
            peak_flops: 982.0e9 / 8.0,
            gemm_efficiency: 0.2,
            mu_half: 6.0,
            backward_ratio: 2.0,
        }
    }

    /// GEMM efficiency at mini-batch size μ (half-saturation curve,
    /// normalized to 1.0 at μ = 128, the paper's reference batch).
    pub fn efficiency(&self, mu: usize) -> f64 {
        let sat = |m: f64| m / (m + self.mu_half);
        sat(mu as f64) / sat(128.0)
    }

    /// Seconds to compute one mini-batch of size μ (forward + backward).
    /// Heterogeneous clusters ([`crate::straggler::hetero`]) scale this
    /// homogeneous cost by a per-learner slowdown factor at draw time.
    pub fn minibatch_secs(&self, model: &ModelCost, mu: usize) -> f64 {
        let flops = model.flops_per_sample * (1.0 + self.backward_ratio) * mu as f64;
        let rate = self.peak_flops * self.gemm_efficiency * self.efficiency(mu);
        flops / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_monotone_in_mu() {
        let c = LearnerCompute::p775();
        let e4 = c.efficiency(4);
        let e32 = c.efficiency(32);
        let e128 = c.efficiency(128);
        assert!(e4 < e32 && e32 < e128);
        assert!((e128 - 1.0).abs() < 1e-12, "normalized at 128");
    }

    #[test]
    fn small_mu_costs_more_per_sample() {
        let c = LearnerCompute::p775();
        let m = ModelCost::cifar10();
        let per_sample_4 = c.minibatch_secs(&m, 4) / 4.0;
        let per_sample_128 = c.minibatch_secs(&m, 128) / 128.0;
        assert!(
            per_sample_4 > 2.0 * per_sample_128,
            "μ=4 should be markedly slower per sample: {per_sample_4} vs {per_sample_128}"
        );
    }

    #[test]
    fn imagenet_epoch_scale_matches_paper() {
        // §5.5: baseline (μ=256, λ=1) takes 54 hours/epoch. Our P775
        // learner model should land within ~2× of that.
        let c = LearnerCompute::p775();
        let m = ModelCost::imagenet();
        let steps = m.samples_per_epoch as f64 / 256.0;
        let hours = steps * c.minibatch_secs(&m, 256) / 3600.0;
        assert!(
            (20.0..110.0).contains(&hours),
            "simulated baseline epoch {hours} h should be within ~2x of the paper's 54 h"
        );
    }

    #[test]
    fn cifar_baseline_training_time_scale() {
        // §5.4: baseline (μ=128, λ=1) takes 22 392 s for 140 epochs.
        let c = LearnerCompute::p775();
        let m = ModelCost::cifar10();
        let steps = m.samples_per_epoch as f64 / 128.0;
        let total = 140.0 * steps * c.minibatch_secs(&m, 128);
        assert!(
            (8_000.0..90_000.0).contains(&total),
            "simulated 140-epoch baseline {total} s should be same order as 22 392 s"
        );
    }
}
