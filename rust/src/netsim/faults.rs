//! Message-level network fault injection over the simulated fabric.
//!
//! The paper's protocols are analyzed over a network that always
//! delivers; real parameter-server deployments drop, duplicate, reorder,
//! and partition traffic constantly. [`FaultSpec`] is the experiment
//! knob — a compact DSL parsed from `--faults` / config JSON — and
//! [`FaultPlane`] is the runtime: it perturbs per-message delivery on the
//! learner↔infrastructure links and prices a capped, jittered
//! exponential-backoff retry chain for every message, all from its own
//! named RNG stream so a fault schedule replays bit-identically per seed
//! and `faults none` leaves the legacy code path untouched.
//!
//! Routing is *planned at send time*: the caller hands the plane a
//! pricing closure over the fabric, and the plane walks the whole attempt
//! chain (attempt → drop? → back off → retry …) immediately, booking
//! fabric contention for every attempt. The outcome — a delivery time, an
//! optional duplicate delivery, or a give-up time — is scheduled as
//! ordinary events, so in-flight retries live in the event queue and
//! stop/resume needs no extra machinery beyond the plane's RNG state.
//!
//! Two routing disciplines:
//! * **unreliable** ([`FaultPlane::route`]) for learner↔infra messages:
//!   the retry budget is capped; exhaustion means the learner is
//!   unreachable and the engine hands it to the membership path
//!   (Suspect → Dead) instead of deadlocking a barrier;
//! * **reliable** ([`FaultPlane::route_reliable`]) for infra↔infra relay
//!   links: retries continue until delivery (bounded by a large safety
//!   cap), so an aggregating leaf can never wedge behind a lost batch.
//!
//! Partitions model rack cuts: learner ids map onto `R` contiguous rack
//! blocks, the root/shards/leaves live on rack 0, and a
//! `partition:rackA-rackB@T s+D s` window blocks every attempt between
//! the two racks for its duration. Like the failure injector, the plane
//! is policy-light: *what* to do about an unreachable learner is the
//! engine's call.

use anyhow::{bail, Context, Result};

use crate::netsim::reliable::FaultStats;
use crate::util::rng::Rng;

/// Domain-separation constant for the fault RNG stream (distinct from the
/// failure injector's `0xE1A5_71C0_FA17_0B3D`, so churn and chaos draws
/// never correlate under a shared seed).
const FAULT_STREAM_SALT: u64 = 0xFA17_5EED_C4A0_55E7;

/// Floor for the retransmission timeout when neither the DSL nor the
/// first attempt's round-trip estimate provides one.
const RTO_FLOOR_SECS: f64 = 1e-3;

/// Backoff jitter span: each retry waits `rto · 2^k · (1 + j·u)` with
/// `u ~ U[0,1)`, desynchronizing retry storms.
const BACKOFF_JITTER: f64 = 0.25;

/// Safety cap on reliable-route attempts. At any loss rate the DSL
/// accepts, 64 consecutive drops is astronomically unlikely; the cap only
/// guarantees termination, after which the message delivers regardless.
const RELIABLE_MAX_ATTEMPTS: u32 = 64;

/// Default unreliable retry budget when the DSL omits `retries:<n>`.
pub const DEFAULT_RETRIES: u32 = 6;

/// One rack-cut window: traffic between `rack_a` and `rack_b` is blocked
/// for `[start, start + dur)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartitionWindow {
    pub rack_a: usize,
    pub rack_b: usize,
    pub start: f64,
    pub dur: f64,
}

impl PartitionWindow {
    pub fn end(&self) -> f64 {
        self.start + self.dur
    }

    fn active(&self, at: f64) -> bool {
        at >= self.start && at < self.end()
    }

    fn cuts(&self, r1: usize, r2: usize) -> bool {
        (self.rack_a == r1 && self.rack_b == r2) || (self.rack_a == r2 && self.rack_b == r1)
    }
}

/// Parsed `faults` knob. `FaultSpec::none()` (the default) is the quiet
/// spec: engines skip fault-plane construction entirely, so quiet runs
/// take the exact legacy code path.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Per-attempt drop probability.
    pub loss: f64,
    /// Probability a delivered message is also delivered a second time.
    pub dup: f64,
    /// Probability a delivered message is held back (delivered late,
    /// after messages sent later).
    pub reorder: f64,
    /// Probability a delivered message's network time is multiplied by
    /// `delayspike_mult` (tail-latency spikes).
    pub delayspike_p: f64,
    pub delayspike_mult: f64,
    /// Rack-cut windows, kept sorted by start time.
    pub partitions: Vec<PartitionWindow>,
    /// Unreliable-route retry budget (retransmissions after the
    /// original attempt).
    pub retries: u32,
    /// Retransmission-timeout floor in seconds; 0 = derive from the first
    /// attempt's round-trip estimate.
    pub rto: f64,
}

impl Default for FaultSpec {
    fn default() -> FaultSpec {
        FaultSpec::none()
    }
}

impl FaultSpec {
    pub fn none() -> FaultSpec {
        FaultSpec {
            loss: 0.0,
            dup: 0.0,
            reorder: 0.0,
            delayspike_p: 0.0,
            delayspike_mult: 1.0,
            partitions: Vec::new(),
            retries: DEFAULT_RETRIES,
            rto: 0.0,
        }
    }

    /// Quiet ⇔ no perturbation is ever drawn: engines skip the fault
    /// plane entirely. Retry knobs alone do not arm faults (there is
    /// nothing to retry).
    pub fn is_quiet(&self) -> bool {
        self.loss == 0.0
            && self.dup == 0.0
            && self.reorder == 0.0
            && self.delayspike_p == 0.0
            && self.partitions.is_empty()
    }

    /// Parse the DSL: comma-separated `key:value` tokens, e.g.
    /// `loss:0.05,dup:0.01,reorder:0.02,delayspike:0.1x20,partition:rack0-rack1@30s+15s,retries:6,rto:0.5`.
    /// `none` (or empty) is the quiet spec.
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let s = s.trim();
        let mut spec = FaultSpec::none();
        if s.is_empty() || s == "none" {
            return Ok(spec);
        }
        for token in s.split(',') {
            let token = token.trim();
            let Some((key, val)) = token.split_once(':') else {
                bail!("fault token '{token}' is not key:value (see `faults` docs)");
            };
            match key {
                "loss" => spec.loss = parse_prob(val, "loss")?,
                "dup" => spec.dup = parse_prob(val, "dup")?,
                "reorder" => spec.reorder = parse_prob(val, "reorder")?,
                "delayspike" => {
                    let Some((p, mult)) = val.split_once('x') else {
                        bail!("delayspike wants <p>x<mult>, got '{val}'");
                    };
                    spec.delayspike_p = parse_prob(p, "delayspike")?;
                    spec.delayspike_mult = mult
                        .parse::<f64>()
                        .with_context(|| format!("delayspike multiplier '{mult}'"))?;
                    if !spec.delayspike_mult.is_finite() || spec.delayspike_mult < 1.0 {
                        bail!("delayspike multiplier must be ≥ 1, got {mult}");
                    }
                }
                "partition" => spec.partitions.push(parse_partition(val)?),
                "retries" => {
                    spec.retries =
                        val.parse::<u32>().with_context(|| format!("retries '{val}'"))?;
                }
                "rto" => {
                    spec.rto = val.parse::<f64>().with_context(|| format!("rto '{val}'"))?;
                    if !spec.rto.is_finite() || spec.rto < 0.0 {
                        bail!("rto must be a non-negative number of seconds, got {val}");
                    }
                }
                other => bail!(
                    "unknown fault knob '{other}' (want loss/dup/reorder/delayspike/partition/retries/rto)"
                ),
            }
        }
        spec.partitions.sort_by(|a, b| {
            a.start.total_cmp(&b.start).then(a.rack_a.cmp(&b.rack_a)).then(a.rack_b.cmp(&b.rack_b))
        });
        Ok(spec)
    }

    /// Canonical label: round-trips through [`FaultSpec::parse`], and is
    /// the experiment-identity string (config labels, fingerprints).
    pub fn label(&self) -> String {
        if self.is_quiet() {
            return "none".to_string();
        }
        let mut parts = Vec::new();
        if self.loss > 0.0 {
            parts.push(format!("loss:{}", self.loss));
        }
        if self.dup > 0.0 {
            parts.push(format!("dup:{}", self.dup));
        }
        if self.reorder > 0.0 {
            parts.push(format!("reorder:{}", self.reorder));
        }
        if self.delayspike_p > 0.0 {
            parts.push(format!("delayspike:{}x{}", self.delayspike_p, self.delayspike_mult));
        }
        for p in &self.partitions {
            parts.push(format!(
                "partition:rack{}-rack{}@{}s+{}s",
                p.rack_a, p.rack_b, p.start, p.dur
            ));
        }
        if self.retries != DEFAULT_RETRIES {
            parts.push(format!("retries:{}", self.retries));
        }
        if self.rto != 0.0 {
            parts.push(format!("rto:{}", self.rto));
        }
        parts.join(",")
    }

    /// Number of racks the learner-id space is carved into: the highest
    /// rack a partition names, plus one (minimum two once any partition
    /// exists — a cut needs two sides). One rack when no partitions.
    pub fn racks(&self) -> usize {
        let max = self.partitions.iter().map(|p| p.rack_a.max(p.rack_b)).max();
        match max {
            Some(m) => (m + 1).max(2),
            None => 1,
        }
    }
}

fn parse_prob(val: &str, knob: &str) -> Result<f64> {
    let p = val.parse::<f64>().with_context(|| format!("{knob} probability '{val}'"))?;
    if !p.is_finite() || !(0.0..1.0).contains(&p) {
        bail!("{knob} probability must be in [0, 1), got {val}");
    }
    Ok(p)
}

fn parse_partition(val: &str) -> Result<PartitionWindow> {
    let err = || format!("partition wants rack<A>-rack<B>@<T>s+<D>s, got '{val}'");
    let (racks, timing) = val.split_once('@').with_context(err)?;
    let (a, b) = racks.split_once('-').with_context(err)?;
    let rack_a =
        a.strip_prefix("rack").with_context(err)?.parse::<usize>().with_context(err)?;
    let rack_b =
        b.strip_prefix("rack").with_context(err)?.parse::<usize>().with_context(err)?;
    if rack_a == rack_b {
        bail!("partition must name two different racks, got '{val}'");
    }
    let (start, dur) = timing.split_once('+').with_context(err)?;
    let start =
        start.strip_suffix('s').with_context(err)?.parse::<f64>().with_context(err)?;
    let dur = dur.strip_suffix('s').with_context(err)?.parse::<f64>().with_context(err)?;
    if !start.is_finite() || start < 0.0 || !dur.is_finite() || dur <= 0.0 {
        bail!("partition window needs start ≥ 0 and duration > 0, got '{val}'");
    }
    Ok(PartitionWindow { rack_a, rack_b, start, dur })
}

/// Outcome of routing one message through the fault plane. All times are
/// absolute simulation times; `retries` is the number of retransmission
/// attempts (0 = the original went through).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RouteOutcome {
    Deliver {
        at: f64,
        /// A second delivery of the same payload/sequence, when the
        /// plane injected a duplicate.
        dup_at: Option<f64>,
        retries: u32,
    },
    Lost {
        /// When the sender gives up (the final retry timeout expiring) —
        /// the moment the engine learns the peer is unreachable.
        give_up_at: f64,
        retries: u32,
        /// Whether an active partition (rather than random loss) blocked
        /// the final attempt; partition-evicted learners revive on heal.
        by_partition: bool,
    },
}

/// Runtime fault injector: owns the spec, the named RNG stream, and the
/// accounting ledger. Engines construct one only when the spec is
/// non-quiet.
#[derive(Debug)]
pub struct FaultPlane {
    spec: FaultSpec,
    rng: Rng,
    /// Learner-id space bound, for the rack mapping.
    lambda: usize,
    racks: usize,
    pub stats: FaultStats,
}

impl FaultPlane {
    pub fn new(spec: FaultSpec, seed: u64, lambda: usize) -> FaultPlane {
        let racks = spec.racks();
        FaultPlane {
            rng: Rng::new(seed ^ FAULT_STREAM_SALT),
            stats: FaultStats::new(lambda),
            lambda,
            racks,
            spec,
        }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Rack of learner `l`: contiguous id blocks over `racks()` racks.
    /// The root, shards, and aggregation leaves all live on rack 0.
    pub fn rack_of(&self, l: usize) -> usize {
        if self.racks <= 1 || self.lambda == 0 {
            return 0;
        }
        (l * self.racks / self.lambda).min(self.racks - 1)
    }

    /// Is learner `l` cut off from the rack-0 infrastructure at `at`?
    pub fn partitioned(&self, l: usize, at: f64) -> bool {
        let rack = self.rack_of(l);
        if rack == 0 {
            return false;
        }
        self.spec.partitions.iter().any(|p| p.active(at) && p.cuts(rack, 0))
    }

    /// Raw RNG state for checkpointing (hex-encoded by the engine, like
    /// the failure injector's stream).
    pub fn rng_state(&self) -> u64 {
        self.rng.state()
    }

    pub fn restore_rng_state(&mut self, state: u64) {
        self.rng = Rng::from_state(state);
    }

    /// Route one learner↔infra message: walk the capped retry chain at
    /// send time, booking fabric contention for every attempt through
    /// `price` (absolute send time in, absolute arrival time out).
    /// `learner` attributes retransmissions for the per-learner stats
    /// columns.
    pub fn route(
        &mut self,
        now: f64,
        learner: usize,
        price: impl FnMut(f64) -> f64,
    ) -> RouteOutcome {
        self.route_inner(now, Some(learner), self.spec.retries, price)
    }

    /// Route one infra↔infra message (leaf→root relay): partitions do not
    /// apply (both endpoints sit on rack 0) and retries continue to the
    /// safety cap, after which the message delivers regardless — an
    /// aggregation leaf must never wedge behind a lost batch.
    pub fn route_reliable(&mut self, now: f64, price: impl FnMut(f64) -> f64) -> RouteOutcome {
        self.route_inner(now, None, RELIABLE_MAX_ATTEMPTS, price)
    }

    fn route_inner(
        &mut self,
        now: f64,
        learner: Option<usize>,
        max_retries: u32,
        mut price: impl FnMut(f64) -> f64,
    ) -> RouteOutcome {
        self.stats.sent += 1;
        let reliable = learner.is_none();
        let mut send_time = now;
        let mut rto = self.spec.rto.max(RTO_FLOOR_SECS);
        let mut attempt: u32 = 0;
        loop {
            let arrival = price(send_time);
            if attempt == 0 {
                // Derive the timeout from the first attempt's one-way
                // estimate unless the DSL pinned one.
                rto = self.spec.rto.max(2.0 * (arrival - now)).max(RTO_FLOOR_SECS);
            }
            let blocked =
                !reliable && learner.is_some_and(|l| self.partitioned(l, send_time));
            let final_forced = reliable && attempt >= max_retries;
            let dropped = !final_forced
                && (blocked || (self.spec.loss > 0.0 && self.rng.f64() < self.spec.loss));
            if !dropped {
                let mut at = arrival;
                if self.spec.delayspike_p > 0.0 && self.rng.f64() < self.spec.delayspike_p {
                    at = send_time + (at - send_time) * self.spec.delayspike_mult;
                }
                if self.spec.reorder > 0.0 && self.rng.f64() < self.spec.reorder {
                    at += self.rng.f64() * rto;
                }
                let mut dup_at = None;
                if self.spec.dup > 0.0 && self.rng.f64() < self.spec.dup {
                    // Duplicates are a network artifact (a re-delivered
                    // frame), so they trail the real delivery without
                    // booking fresh fabric contention.
                    dup_at = Some(at + self.rng.f64() * rto);
                    self.stats.dups_injected += 1;
                    self.stats.delivered += 1;
                }
                self.stats.delivered += 1;
                return RouteOutcome::Deliver { at, dup_at, retries: attempt };
            }
            self.stats.dropped += 1;
            let backoff = rto
                * f64::from(1u32 << attempt.min(16))
                * (1.0 + BACKOFF_JITTER * self.rng.f64());
            if attempt >= max_retries {
                self.stats.exhausted += 1;
                return RouteOutcome::Lost {
                    give_up_at: send_time + backoff,
                    retries: attempt,
                    by_partition: blocked,
                };
            }
            attempt += 1;
            self.stats.retransmits += 1;
            if let Some(l) = learner {
                if let Some(r) = self.stats.retransmits_by.get_mut(l) {
                    *r += 1;
                }
            }
            send_time += backoff;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_none_and_empty_are_quiet() {
        assert!(FaultSpec::parse("none").unwrap().is_quiet());
        assert!(FaultSpec::parse("").unwrap().is_quiet());
        assert_eq!(FaultSpec::parse("none").unwrap().label(), "none");
        assert_eq!(FaultSpec::parse("none").unwrap(), FaultSpec::none());
    }

    #[test]
    fn parse_and_label_roundtrip() {
        let s = "loss:0.05,dup:0.01,reorder:0.02,delayspike:0.1x20,\
                 partition:rack0-rack1@30s+15s,retries:4,rto:0.5";
        let spec = FaultSpec::parse(s).unwrap();
        assert_eq!(spec.loss, 0.05);
        assert_eq!(spec.dup, 0.01);
        assert_eq!(spec.reorder, 0.02);
        assert_eq!(spec.delayspike_p, 0.1);
        assert_eq!(spec.delayspike_mult, 20.0);
        assert_eq!(
            spec.partitions,
            vec![PartitionWindow { rack_a: 0, rack_b: 1, start: 30.0, dur: 15.0 }]
        );
        assert_eq!(spec.retries, 4);
        assert_eq!(spec.rto, 0.5);
        let relabel = FaultSpec::parse(&spec.label()).unwrap();
        assert_eq!(relabel, spec, "label must round-trip through parse");
    }

    #[test]
    fn parse_rejects_bad_tokens() {
        for bad in [
            "loss",
            "loss:1.5",
            "loss:-0.1",
            "loss:1.0",
            "frobnicate:0.5",
            "delayspike:0.1",
            "delayspike:0.1x0.5",
            "partition:rack0-rack0@1s+1s",
            "partition:rack0-rack1@1s+0s",
            "partition:rack0-rack1@1+1",
            "rto:-1",
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "'{bad}' should be rejected");
        }
    }

    #[test]
    fn retry_knobs_alone_stay_quiet() {
        let spec = FaultSpec::parse("retries:3,rto:0.1").unwrap();
        assert!(spec.is_quiet(), "nothing to retry without a perturbation");
    }

    #[test]
    fn racks_and_rack_mapping() {
        assert_eq!(FaultSpec::none().racks(), 1);
        let spec = FaultSpec::parse("partition:rack0-rack1@1s+1s").unwrap();
        assert_eq!(spec.racks(), 2);
        let plane = FaultPlane::new(spec, 7, 8);
        let racks: Vec<usize> = (0..8).map(|l| plane.rack_of(l)).collect();
        assert_eq!(racks, vec![0, 0, 0, 0, 1, 1, 1, 1]);
        let spec3 = FaultSpec::parse("partition:rack1-rack2@1s+1s").unwrap();
        assert_eq!(spec3.racks(), 3);
    }

    #[test]
    fn partition_blocks_only_named_racks_during_window() {
        let spec = FaultSpec::parse("partition:rack0-rack2@10s+5s").unwrap();
        let plane = FaultPlane::new(spec, 7, 9);
        // racks: 0 → ids 0-2, 1 → ids 3-5, 2 → ids 6-8
        assert!(!plane.partitioned(7, 9.9), "before the window");
        assert!(plane.partitioned(7, 10.0), "rack 2 cut from rack 0");
        assert!(plane.partitioned(7, 14.9));
        assert!(!plane.partitioned(7, 15.0), "healed");
        assert!(!plane.partitioned(4, 12.0), "rack 1 unaffected");
        assert!(!plane.partitioned(0, 12.0), "rack 0 is the infra side");
    }

    #[test]
    fn quiet_route_is_passthrough() {
        // loss:0 with a partition elsewhere: a clear-path message must
        // deliver on attempt 0 at exactly the priced time.
        let spec = FaultSpec::parse("partition:rack0-rack1@100s+1s").unwrap();
        let mut plane = FaultPlane::new(spec, 7, 4);
        let out = plane.route(1.0, 0, |at| at + 0.25);
        assert_eq!(out, RouteOutcome::Deliver { at: 1.25, dup_at: None, retries: 0 });
    }

    #[test]
    fn route_is_deterministic_per_seed() {
        let run = |seed: u64| {
            let spec =
                FaultSpec::parse("loss:0.3,dup:0.1,reorder:0.1,delayspike:0.1x10").unwrap();
            let mut plane = FaultPlane::new(spec, seed, 4);
            (0..200).map(|i| plane.route(i as f64, i % 4, |at| at + 0.1)).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7), "same seed replays bit-identically");
        assert_ne!(run(7), run(8), "different seed diverges");
    }

    #[test]
    fn conservation_law_holds_under_chaos() {
        let spec = FaultSpec::parse("loss:0.4,dup:0.2,reorder:0.1,retries:2").unwrap();
        let mut plane = FaultPlane::new(spec, 11, 4);
        let mut lost = 0;
        for i in 0..500 {
            if let RouteOutcome::Lost { .. } = plane.route(i as f64, i % 4, |at| at + 0.1) {
                lost += 1;
            }
        }
        assert!(plane.stats.balances(), "{:?}", plane.stats);
        assert_eq!(plane.stats.sent, 500);
        assert_eq!(plane.stats.exhausted, lost);
        assert!(lost > 0, "loss:0.4 with retries:2 must exhaust sometimes");
        assert!(plane.stats.retransmits > 0);
        assert!(plane.stats.dups_injected > 0);
        let by: u64 = plane.stats.retransmits_by.iter().sum();
        assert_eq!(by, plane.stats.retransmits, "per-learner attribution is total");
    }

    #[test]
    fn partition_exhausts_with_by_partition_flag() {
        // Learner 1 (rack 1) inside a long partition: every attempt is
        // blocked, so the route must exhaust and blame the partition.
        let spec = FaultSpec::parse("partition:rack0-rack1@0s+1000000s,retries:2").unwrap();
        let mut plane = FaultPlane::new(spec, 7, 2);
        match plane.route(1.0, 1, |at| at + 0.1) {
            RouteOutcome::Lost { give_up_at, retries, by_partition } => {
                assert!(by_partition);
                assert_eq!(retries, 2);
                assert!(give_up_at > 1.0);
            }
            other => panic!("expected Lost, got {other:?}"),
        }
        assert!(plane.stats.balances());
    }

    #[test]
    fn reliable_route_never_loses() {
        let spec = FaultSpec::parse("loss:0.6,retries:1").unwrap();
        let mut plane = FaultPlane::new(spec, 13, 4);
        for i in 0..300 {
            match plane.route_reliable(i as f64, |at| at + 0.1) {
                RouteOutcome::Deliver { .. } => {}
                RouteOutcome::Lost { .. } => panic!("reliable route must always deliver"),
            }
        }
        assert!(plane.stats.balances());
        assert!(plane.stats.retransmits > 0, "loss:0.6 must force retries");
        assert_eq!(plane.stats.exhausted, 0);
    }

    #[test]
    fn retry_chain_books_every_attempt_and_backs_off() {
        // Deterministic hunt for a route with ≥ 1 retry; the pricing
        // closure records each attempt's send time.
        let spec = FaultSpec::parse("loss:0.5,retries:4,rto:0.2").unwrap();
        let mut plane = FaultPlane::new(spec, 3, 2);
        let mut found = false;
        for i in 0..100 {
            let mut sends = Vec::new();
            let out = plane.route(i as f64 * 10.0, 0, |at| {
                sends.push(at);
                at + 0.05
            });
            if let RouteOutcome::Deliver { at, retries, .. } = out {
                assert_eq!(sends.len() as u32, retries + 1, "every attempt priced");
                if retries >= 2 {
                    // backoff doubles (jitter aside): gap k+1 > gap k
                    let g1 = sends[1] - sends[0];
                    let g2 = sends[2] - sends[1];
                    assert!(g2 > g1, "exponential backoff: {g2} vs {g1}");
                    assert!(at >= sends[retries as usize], "delivery after final send");
                    found = true;
                    break;
                }
            }
        }
        assert!(found, "loss:0.5 should produce a ≥2-retry delivery in 100 tries");
    }

    #[test]
    fn rng_state_checkpoint_resumes_exact_outcomes() {
        let spec = FaultSpec::parse("loss:0.3,dup:0.1").unwrap();
        let mut plane = FaultPlane::new(spec.clone(), 9, 4);
        for i in 0..50 {
            plane.route(i as f64, i % 4, |at| at + 0.1);
        }
        let state = plane.rng_state();
        let tail: Vec<RouteOutcome> =
            (0..50).map(|i| plane.route(i as f64, i % 4, |at| at + 0.1)).collect();
        let mut resumed = FaultPlane::new(spec, 9, 4);
        resumed.restore_rng_state(state);
        let replay: Vec<RouteOutcome> =
            (0..50).map(|i| resumed.route(i as f64, i % 4, |at| at + 0.1)).collect();
        assert_eq!(tail, replay);
    }
}
