//! Deterministic failure injection for the virtual-time cluster.
//!
//! Models the random component of a churn schedule — spot-instance
//! preemptions arriving as a Poisson process, each followed by an
//! exponentially distributed downtime before the learner warm-restarts.
//! Everything draws from a dedicated seeded [`Rng`] stream, so a churned
//! run replays bit-identically for a given seed (the same property the
//! rest of the event queue guarantees).
//!
//! The injector is policy-light by design: it only *draws* kill times,
//! victims, and downtimes. Applying them — updating the membership
//! ledger, rescaling μ·λ, flushing protocol quotas — is the engine's job
//! ([`crate::coordinator::engine_sim`]).

use crate::util::rng::Rng;

/// Draws a Poisson kill process with exponential downtimes.
#[derive(Debug, Clone)]
pub struct FailureInjector {
    rng: Rng,
    /// Mean seconds between random kills (∞ encoded as 0 rate upstream).
    mean_interarrival: f64,
    /// Mean downtime before a killed learner rejoins (0 = never rejoin).
    mean_downtime: f64,
}

impl FailureInjector {
    /// `kill_rate_per_ksec` is the schedule's mean kills per 1000 virtual
    /// seconds; 0 disables the random process entirely.
    pub fn new(kill_rate_per_ksec: f64, mean_downtime_secs: f64, seed: u64) -> FailureInjector {
        FailureInjector {
            // decorrelate from the engine's jitter stream
            rng: Rng::new(seed ^ 0xE1A5_71C0_FA17_0B3D),
            mean_interarrival: if kill_rate_per_ksec > 0.0 {
                1000.0 / kill_rate_per_ksec
            } else {
                0.0
            },
            mean_downtime: mean_downtime_secs.max(0.0),
        }
    }

    /// Whether the random kill process is active.
    pub fn enabled(&self) -> bool {
        self.mean_interarrival > 0.0
    }

    /// Raw RNG state, for mid-flight sim checkpoints.
    pub fn rng_state(&self) -> u64 {
        self.rng.state()
    }

    /// Install a checkpointed [`FailureInjector::rng_state`], resuming
    /// the exact kill/downtime/victim stream.
    pub fn restore_rng_state(&mut self, state: u64) {
        self.rng = Rng::from_state(state);
    }

    /// Seconds until the next random kill (exponential interarrival).
    /// Only meaningful when [`FailureInjector::enabled`].
    pub fn next_kill_delay(&mut self) -> f64 {
        debug_assert!(self.enabled());
        self.rng.exponential(self.mean_interarrival)
    }

    /// Downtime for a freshly killed learner: `Some(secs)` if the
    /// schedule lets learners rejoin, `None` for permanent eviction.
    pub fn downtime(&mut self) -> Option<f64> {
        if self.mean_downtime > 0.0 {
            Some(self.rng.exponential(self.mean_downtime))
        } else {
            None
        }
    }

    /// Pick a victim uniformly among `candidates` (the engine passes the
    /// currently live set, minus any survivors it wants to protect).
    pub fn pick(&mut self, candidates: &[usize]) -> Option<usize> {
        if candidates.is_empty() {
            None
        } else {
            Some(candidates[self.rng.usize_below(candidates.len())])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_at_zero_rate() {
        let inj = FailureInjector::new(0.0, 10.0, 1);
        assert!(!inj.enabled());
    }

    #[test]
    fn deterministic_for_a_seed() {
        let mut a = FailureInjector::new(5.0, 20.0, 42);
        let mut b = FailureInjector::new(5.0, 20.0, 42);
        for _ in 0..50 {
            assert_eq!(a.next_kill_delay(), b.next_kill_delay());
            assert_eq!(a.downtime(), b.downtime());
            assert_eq!(a.pick(&[3, 5, 9]), b.pick(&[3, 5, 9]));
        }
    }

    #[test]
    fn kill_delays_match_requested_rate() {
        // 5 kills per 1000 s ⇒ mean interarrival 200 s.
        let mut inj = FailureInjector::new(5.0, 0.0, 7);
        let n = 4000;
        let mean: f64 = (0..n).map(|_| inj.next_kill_delay()).sum::<f64>() / n as f64;
        assert!((150.0..250.0).contains(&mean), "mean interarrival {mean}");
        assert_eq!(inj.downtime(), None, "downtime 0 = permanent eviction");
    }

    #[test]
    fn pick_covers_all_candidates() {
        let mut inj = FailureInjector::new(1.0, 1.0, 3);
        let cands = [2usize, 4, 7];
        let mut seen = [false; 3];
        for _ in 0..200 {
            let v = inj.pick(&cands).unwrap();
            seen[cands.iter().position(|&c| c == v).unwrap()] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(inj.pick(&[]), None);
    }
}
