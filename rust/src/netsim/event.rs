//! Virtual-time event queue.
//!
//! A deterministic discrete-event core: events carry an `f64` virtual time
//! (seconds) and a sequence number that breaks ties FIFO, so simulations
//! replay bit-identically for a given seed regardless of host timing.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at virtual time `at`, carrying payload `T`.
#[derive(Debug, Clone)]
struct Scheduled<T> {
    at: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Scheduled<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<T> Eq for Scheduled<T> {}

impl<T> Ord for Scheduled<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first. Ties break
        // by insertion order (lower seq first) for FIFO determinism.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for Scheduled<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic virtual-time event queue.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Scheduled<T>>,
    now: f64,
    seq: u64,
    processed: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0 }
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-sized queue: the sim engine knows its steady-state event count
    /// (a few per live learner), and reserving it up front spares the
    /// heap its doubling migrations on the hot path.
    pub fn with_capacity(cap: usize) -> Self {
        EventQueue { heap: BinaryHeap::with_capacity(cap), now: 0.0, seq: 0, processed: 0 }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `payload` at absolute virtual time `at` (clamped to now).
    ///
    /// `at` must not be NaN: the heap's ordering falls back to `Equal`
    /// for incomparable times, so a single NaN entry would silently
    /// corrupt pop order for every event around it. Debug builds panic;
    /// release builds clamp a NaN to `now` (the documented containment
    /// behavior — the event fires immediately and deterministically, and
    /// the heap order stays total). Infinities order correctly and pass
    /// through: a `+∞` event simply sorts after everything finite.
    pub fn schedule_at(&mut self, at: f64, payload: T) {
        debug_assert!(!at.is_nan(), "schedule_at: NaN virtual time");
        let at = if at.is_nan() || at < self.now { self.now } else { at };
        self.heap.push(Scheduled { at, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` `delay` seconds from now.
    pub fn schedule_in(&mut self, delay: f64, payload: T) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay, payload);
    }

    /// Pop the earliest event, advancing virtual time to it.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.at >= self.now, "time went backwards");
        self.now = ev.at;
        self.processed += 1;
        Some((ev.at, ev.payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 3.0);
    }

    #[test]
    fn ties_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule_at(1.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn schedule_in_accumulates() {
        let mut q = EventQueue::new();
        q.schedule_in(1.0, 1);
        q.pop();
        q.schedule_in(0.5, 2);
        let (t, _) = q.pop().unwrap();
        assert!((t - 1.5).abs() < 1e-12);
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, 1);
        q.pop();
        q.schedule_at(1.0, 2); // in the past → clamped
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 5.0);
    }

    // Regression (NaN heap corruption): `schedule_at` used to accept a
    // NaN timestamp verbatim; `partial_cmp(..).unwrap_or(Equal)` then
    // made the NaN entry compare Equal to *everything*, silently
    // breaking the heap's pop order around it. Debug builds now panic at
    // the call site; release builds clamp the NaN to `now` so the order
    // stays total.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "NaN virtual time")]
    fn nan_schedule_panics_in_debug() {
        let mut q = EventQueue::new();
        q.schedule_at(f64::NAN, 1);
    }

    #[cfg(not(debug_assertions))]
    #[test]
    fn nan_schedule_clamps_to_now_in_release() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, 1);
        q.pop(); // now = 5.0
        q.schedule_at(9.0, 2);
        q.schedule_at(f64::NAN, 3); // clamped to now = 5.0
        q.schedule_at(7.0, 4);
        // pop order stays strictly by (time, seq): the clamped NaN fires
        // first at now, the rest in time order — no corruption.
        let order: Vec<(f64, i32)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(5.0, 3), (7.0, 4), (9.0, 2)]);
    }

    #[test]
    fn infinite_times_order_after_everything_finite() {
        let mut q = EventQueue::new();
        q.schedule_at(f64::INFINITY, 1);
        q.schedule_at(2.0, 2);
        let (t, p) = q.pop().unwrap();
        assert_eq!((t, p), (2.0, 2));
        let (t, p) = q.pop().unwrap();
        assert!(t.is_infinite() && p == 1);
    }

    #[test]
    fn with_capacity_behaves_like_new() {
        let mut q = EventQueue::with_capacity(64);
        assert!(q.is_empty());
        q.schedule_in(1.0, 7);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some((1.0, 7)));
        assert_eq!(q.processed(), 1);
    }
}
