//! Virtual-time event queue.
//!
//! A deterministic discrete-event core: events carry an `f64` virtual time
//! (seconds) and a sequence number that breaks ties FIFO, so simulations
//! replay bit-identically for a given seed regardless of host timing.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at virtual time `at`, carrying payload `T`.
#[derive(Debug, Clone)]
struct Scheduled<T> {
    at: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Scheduled<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<T> Eq for Scheduled<T> {}

impl<T> Ord for Scheduled<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first. Ties break
        // by insertion order (lower seq first) for FIFO determinism.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for Scheduled<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic virtual-time event queue.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Scheduled<T>>,
    now: f64,
    seq: u64,
    processed: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0 }
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `payload` at absolute virtual time `at` (clamped to now).
    pub fn schedule_at(&mut self, at: f64, payload: T) {
        let at = if at < self.now { self.now } else { at };
        self.heap.push(Scheduled { at, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` `delay` seconds from now.
    pub fn schedule_in(&mut self, delay: f64, payload: T) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay, payload);
    }

    /// Pop the earliest event, advancing virtual time to it.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.at >= self.now, "time went backwards");
        self.now = ev.at;
        self.processed += 1;
        Some((ev.at, ev.payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 3.0);
    }

    #[test]
    fn ties_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule_at(1.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn schedule_in_accumulates() {
        let mut q = EventQueue::new();
        q.schedule_in(1.0, 1);
        q.pop();
        q.schedule_in(0.5, 2);
        let (t, _) = q.pop().unwrap();
        assert!((t - 1.5).abs() < 1e-12);
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, 1);
        q.pop();
        q.schedule_at(1.0, 2); // in the past → clamped
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 5.0);
    }
}
