//! Virtual-time event queue.
//!
//! A deterministic discrete-event core: events carry an `f64` virtual time
//! (seconds) and a sequence number that breaks ties FIFO, so simulations
//! replay bit-identically for a given seed regardless of host timing.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at virtual time `at`, carrying payload `T`.
#[derive(Debug, Clone)]
struct Scheduled<T> {
    at: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Scheduled<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<T> Eq for Scheduled<T> {}

impl<T> Ord for Scheduled<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first. Ties break
        // by insertion order (lower seq first) for FIFO determinism.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for Scheduled<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic virtual-time event queue.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Scheduled<T>>,
    now: f64,
    seq: u64,
    processed: u64,
    /// Deepest the queue has ever been (observability gauge — one `max`
    /// per schedule, never consulted by scheduling itself).
    high_water: usize,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0, high_water: 0 }
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-sized queue: the sim engine knows its steady-state event count
    /// (a few per live learner), and reserving it up front spares the
    /// heap its doubling migrations on the hot path.
    pub fn with_capacity(cap: usize) -> Self {
        EventQueue {
            heap: BinaryHeap::with_capacity(cap),
            now: 0.0,
            seq: 0,
            processed: 0,
            high_water: 0,
        }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Deepest the queue has ever been (pending events, not lifetime
    /// total). Restored queues restart the mark from their restored
    /// depth: a resumed segment reports *its own* high water.
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Schedule `payload` at absolute virtual time `at` (clamped to now).
    ///
    /// `at` must not be NaN: the heap's ordering falls back to `Equal`
    /// for incomparable times, so a single NaN entry would silently
    /// corrupt pop order for every event around it. Debug builds panic;
    /// release builds clamp a NaN to `now` (the documented containment
    /// behavior — the event fires immediately and deterministically, and
    /// the heap order stays total). Infinities order correctly and pass
    /// through: a `+∞` event simply sorts after everything finite.
    pub fn schedule_at(&mut self, at: f64, payload: T) {
        debug_assert!(!at.is_nan(), "schedule_at: NaN virtual time");
        let at = if at.is_nan() || at < self.now { self.now } else { at };
        self.heap.push(Scheduled { at, seq: self.seq, payload });
        self.seq += 1;
        if self.heap.len() > self.high_water {
            self.high_water = self.heap.len();
        }
    }

    /// Schedule `payload` `delay` seconds from now.
    ///
    /// `delay` must be non-negative. Debug builds panic on a negative
    /// delay; release builds delegate to [`EventQueue::schedule_at`],
    /// whose past-time clamp fires the event at `now` — immediately and
    /// deterministically, mirroring the NaN containment above. (A NaN
    /// delay follows the same NaN contract: debug panic, release clamp
    /// to `now`.)
    pub fn schedule_in(&mut self, delay: f64, payload: T) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay, payload);
    }

    /// Pop the earliest event, advancing virtual time to it.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.at >= self.now, "time went backwards");
        self.now = ev.at;
        self.processed += 1;
        Some((ev.at, ev.payload))
    }

    /// Next sequence number to be assigned (part of the queue's
    /// checkpointable state — ties between a restored event and a newly
    /// scheduled one must break exactly as they would have uninterrupted).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Non-destructive snapshot of every pending event as
    /// `(at, seq, payload)` triples sorted in pop order. Feeding the
    /// triples to [`EventQueue::restore`] rebuilds a queue that pops the
    /// identical sequence.
    pub fn snapshot(&self) -> Vec<(f64, u64, T)>
    where
        T: Clone,
    {
        self.entries().into_iter().map(|(at, seq, p)| (at, seq, p.clone())).collect()
    }

    /// Borrowing variant of [`EventQueue::snapshot`] for payloads that are
    /// expensive (or impossible) to clone — the caller serializes through
    /// the references.
    pub fn entries(&self) -> Vec<(f64, u64, &T)> {
        let mut entries: Vec<&Scheduled<T>> = self.heap.iter().collect();
        entries.sort_by(|a, b| {
            a.at.partial_cmp(&b.at)
                .unwrap_or(Ordering::Equal)
                .then_with(|| a.seq.cmp(&b.seq))
        });
        entries.into_iter().map(|s| (s.at, s.seq, &s.payload)).collect()
    }

    /// Rebuild a queue from checkpointed state: `now`/`seq`/`processed`
    /// counters plus the pending `(at, seq, payload)` entries from
    /// [`EventQueue::snapshot`]. Sequence numbers are installed verbatim
    /// so FIFO tie-breaks replay bit-identically.
    pub fn restore(now: f64, seq: u64, processed: u64, entries: Vec<(f64, u64, T)>) -> Self {
        let mut heap = BinaryHeap::with_capacity(entries.len().max(16));
        for (at, s, payload) in entries {
            heap.push(Scheduled { at, seq: s, payload });
        }
        let high_water = heap.len();
        EventQueue { heap, now, seq, processed, high_water }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 3.0);
    }

    #[test]
    fn ties_are_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule_at(1.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn schedule_in_accumulates() {
        let mut q = EventQueue::new();
        q.schedule_in(1.0, 1);
        q.pop();
        q.schedule_in(0.5, 2);
        let (t, _) = q.pop().unwrap();
        assert!((t - 1.5).abs() < 1e-12);
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, 1);
        q.pop();
        q.schedule_at(1.0, 2); // in the past → clamped
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 5.0);
    }

    // Regression (NaN heap corruption): `schedule_at` used to accept a
    // NaN timestamp verbatim; `partial_cmp(..).unwrap_or(Equal)` then
    // made the NaN entry compare Equal to *everything*, silently
    // breaking the heap's pop order around it. Debug builds now panic at
    // the call site; release builds clamp the NaN to `now` so the order
    // stays total.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "NaN virtual time")]
    fn nan_schedule_panics_in_debug() {
        let mut q = EventQueue::new();
        q.schedule_at(f64::NAN, 1);
    }

    #[cfg(not(debug_assertions))]
    #[test]
    fn nan_schedule_clamps_to_now_in_release() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, 1);
        q.pop(); // now = 5.0
        q.schedule_at(9.0, 2);
        q.schedule_at(f64::NAN, 3); // clamped to now = 5.0
        q.schedule_at(7.0, 4);
        // pop order stays strictly by (time, seq): the clamped NaN fires
        // first at now, the rest in time order — no corruption.
        let order: Vec<(f64, i32)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(5.0, 3), (7.0, 4), (9.0, 2)]);
    }

    // Regression (release clamp contract): `schedule_in` with a negative
    // delay only `debug_assert`s; release builds clamp via `schedule_at`
    // so the event fires at `now`. Pin the clamp the same way the NaN
    // tests above pin theirs — the containment behavior is part of the
    // method's documented contract, not an accident.
    #[cfg(not(debug_assertions))]
    #[test]
    fn negative_delay_clamps_to_now_in_release() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, 1);
        q.pop(); // now = 5.0
        q.schedule_at(9.0, 2);
        q.schedule_in(-3.0, 3); // clamped to now = 5.0
        q.schedule_in(2.0, 4);
        let order: Vec<(f64, i32)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(5.0, 3), (7.0, 4), (9.0, 2)]);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "negative delay")]
    fn negative_delay_panics_in_debug() {
        let mut q = EventQueue::new();
        q.schedule_in(-1.0, 1);
    }

    #[test]
    fn snapshot_restore_replays_identically() {
        let mut q = EventQueue::new();
        q.schedule_at(2.0, "b");
        q.schedule_at(1.0, "a");
        q.pop(); // now = 1.0, processed = 1
        q.schedule_in(0.5, "tie1");
        q.schedule_at(1.5, "tie2"); // same time, later seq
        q.schedule_at(3.0, "d");

        let snap = q.snapshot();
        let mut restored = EventQueue::restore(q.now(), q.seq(), q.processed(), snap);
        assert_eq!(restored.now(), q.now());
        assert_eq!(restored.seq(), q.seq());
        assert_eq!(restored.processed(), q.processed());

        // Both queues must pop the same sequence, including the FIFO
        // tie-break at t=1.5, and assign the same seq to new events.
        restored.schedule_at(1.5, "tie3");
        q.schedule_at(1.5, "tie3");
        loop {
            let (a, b) = (q.pop(), restored.pop());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn infinite_times_order_after_everything_finite() {
        let mut q = EventQueue::new();
        q.schedule_at(f64::INFINITY, 1);
        q.schedule_at(2.0, 2);
        let (t, p) = q.pop().unwrap();
        assert_eq!((t, p), (2.0, 2));
        let (t, p) = q.pop().unwrap();
        assert!(t.is_infinite() && p == 1);
    }

    #[test]
    fn high_water_tracks_peak_depth() {
        let mut q = EventQueue::new();
        assert_eq!(q.high_water(), 0);
        q.schedule_at(1.0, 1);
        q.schedule_at(2.0, 2);
        q.schedule_at(3.0, 3);
        assert_eq!(q.high_water(), 3);
        q.pop();
        q.pop();
        q.schedule_at(4.0, 4); // depth back to 2 — the mark stays at 3
        assert_eq!(q.high_water(), 3);
        let restored = EventQueue::restore(q.now(), q.seq(), q.processed(), q.snapshot());
        assert_eq!(restored.high_water(), 2, "restored queues restart from restored depth");
    }

    #[test]
    fn with_capacity_behaves_like_new() {
        let mut q = EventQueue::with_capacity(64);
        assert!(q.is_empty());
        q.schedule_in(1.0, 7);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some((1.0, 7)));
        assert_eq!(q.processed(), 1);
    }
}
