//! Computation/communication overlap accounting (Table 1).
//!
//! The paper measures "the ratio between computation time and the sum of
//! computation and communication time" (§3.3) — i.e. the fraction of a
//! learner's wall-clock spent computing rather than *stalled* on
//! communication. Rudra-base scores 11.52%, Rudra-adv 56.75%, and
//! Rudra-adv\* 99.56% in the adversarial scenario (μ=4, 300 MB model,
//! ~60 learners).

/// Per-learner time accounting.
#[derive(Debug, Default, Clone)]
pub struct OverlapTracker {
    pub compute: f64,
    /// Communication time *not* hidden behind compute (stall time).
    pub comm_exposed: f64,
    /// Communication time overlapped with compute (adv* background
    /// threads; accounted for reporting but not counted as stall).
    pub comm_hidden: f64,
}

impl OverlapTracker {
    pub fn add_compute(&mut self, secs: f64) {
        self.compute += secs;
    }

    pub fn add_exposed_comm(&mut self, secs: f64) {
        self.comm_exposed += secs.max(0.0);
    }

    pub fn add_hidden_comm(&mut self, secs: f64) {
        self.comm_hidden += secs.max(0.0);
    }

    /// The paper's Table-1 metric: compute / (compute + exposed comm).
    pub fn overlap_pct(&self) -> f64 {
        let denom = self.compute + self.comm_exposed;
        if denom == 0.0 {
            return 100.0;
        }
        100.0 * self.compute / denom
    }

    pub fn merge(&mut self, other: &OverlapTracker) {
        self.compute += other.compute;
        self.comm_exposed += other.comm_exposed;
        self.comm_hidden += other.comm_hidden;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fully_hidden_comm_is_100pct() {
        let mut t = OverlapTracker::default();
        t.add_compute(10.0);
        t.add_hidden_comm(5.0);
        assert!((t.overlap_pct() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn exposed_comm_reduces_overlap() {
        let mut t = OverlapTracker::default();
        t.add_compute(1.0);
        t.add_exposed_comm(9.0);
        assert!((t.overlap_pct() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = OverlapTracker::default();
        a.add_compute(1.0);
        let mut b = OverlapTracker::default();
        b.add_exposed_comm(1.0);
        a.merge(&b);
        assert!((a.overlap_pct() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn negative_stall_clamped() {
        let mut t = OverlapTracker::default();
        t.add_compute(1.0);
        t.add_exposed_comm(-5.0);
        assert!((t.overlap_pct() - 100.0).abs() < 1e-9);
    }
}
