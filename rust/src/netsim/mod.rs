//! Discrete-event cluster model — the substitute for the paper's P775
//! testbed (DESIGN.md §3).
//!
//! The paper's runtime results are driven by three quantities the model
//! reproduces from the published hardware description (§4.1):
//! * learner compute time per mini-batch — model FLOPs / effective GEMM
//!   rate, with the small-μ GEMM-efficiency falloff the paper calls out
//!   in §5.2 ([`cost`]);
//! * message time — bytes / link bandwidth + latency ([`cluster`]);
//! * contention — serialized service at a shared receiver: "if 16 tasks
//!   are sending 300 MB to the same receiver and there is link
//!   contention, it would take over a second" (§3.3) ([`cluster`]).
//!
//! [`event`] provides the virtual-time event queue shared with the
//! coordinator's simulation engine; [`overlap`] accounts the
//! computation/communication overlap ratio that Table 1 reports;
//! [`failure`] injects deterministic churn (random kills + downtimes) for
//! the elastic-membership scenarios ([`crate::elastic`]); [`faults`] and
//! [`reliable`] add message-level chaos (loss, duplication, reordering,
//! delay spikes, rack partitions) with an ack/retry reliability layer and
//! receiver-side dedup so every protocol survives a lossy network.

pub mod cluster;
pub mod cost;
pub mod event;
pub mod failure;
pub mod faults;
pub mod overlap;
pub mod reliable;
