//! Node/link model with contention, calibrated to the P775 (§4.1).
//!
//! Links are modeled as serialized servers: a message occupies its
//! source's egress and the destination's ingress for `bytes/bandwidth`
//! seconds after a fixed latency, and transfers to a busy endpoint queue
//! behind it. This reproduces the §3.3 observation that motivated
//! Rudra-adv: a flat parameter server receiving λ simultaneous 300 MB
//! pushes serializes them into a >1 s stall.

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Cluster-wide communication parameters.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Point-to-point bandwidth per endpoint (bytes/s).
    pub link_bandwidth: f64,
    /// Intra-node (co-located process) copy bandwidth (bytes/s) — pulls
    /// from a co-located PS leaf are memory copies, not NIC transfers.
    pub local_bandwidth: f64,
    /// Per-message fixed latency (seconds).
    pub latency: f64,
    /// Learners per node (co-located endpoints share the node's NIC).
    pub learners_per_node: usize,
    /// Multiplicative jitter on compute times (0 = fully deterministic):
    /// each mini-batch duration is scaled by `1 + jitter·N(0,1)` clamped
    /// to ≥ 0.2. Homogeneous-cluster runs in the paper still show ±~10%
    /// spread (Fig 4's staleness tails come from exactly this).
    pub compute_jitter: f64,
    /// Straggler (chaos) injection for relaxed/heterogeneous-cluster
    /// studies (the paper's §7 future work #1: "extension to more
    /// relaxed/chaotic systems"): with probability `straggler_prob` a
    /// mini-batch takes `straggler_mult ×` its jittered duration —
    /// producing the Downpour-style "staleness as large as hundreds"
    /// tails (§3.1) the homogeneous P775 never exhibits.
    pub straggler_prob: f64,
    pub straggler_mult: f64,
}

impl ClusterSpec {
    /// P775 calibration. The node interconnect is 192 GB/s bidirectional,
    /// but a *single MPI stream* achieves a small fraction of that; the
    /// paper's own anchors pin the effective per-stream rate: "a single
    /// learner pushing a model of 300 MB would take more than 10 ms" and
    /// "if 16 tasks are sending 300 MB to the same receiver and there is
    /// link contention, it would take over a second" (§3.3). 3 GB/s per
    /// stream gives 100 ms and 1.6 s respectively — both consistent.
    /// MPI small-message latency ~2 µs.
    pub fn p775() -> ClusterSpec {
        ClusterSpec {
            link_bandwidth: 3.0e9,
            local_bandwidth: 12.0e9, // shared-memory copy, ~4× a NIC stream
            latency: 2.0e-6,
            learners_per_node: 8,
            compute_jitter: 0.08,
            straggler_prob: 0.0,
            straggler_mult: 1.0,
        }
    }

    /// A chaotic commodity-cluster variant: 5% of mini-batches take 10×
    /// (Downpour-SGD territory).
    pub fn chaotic() -> ClusterSpec {
        ClusterSpec { straggler_prob: 0.05, straggler_mult: 10.0, ..Self::p775() }
    }

    /// Seconds to move `bytes` over one uncontended link.
    pub fn wire_time(&self, bytes: f64) -> f64 {
        self.latency + bytes / self.link_bandwidth
    }

    /// Sanity-check the spec before a run. The load-bearing rule is the
    /// jitter bound: [`jittered`] draws `1 + jitter·N(0,1)` clamped to
    /// ≥ 0.2, so a jitter ≥ 1 puts a large probability mass on the clamp
    /// and *silently inflates* the mean compute time instead of widening
    /// it symmetrically — a config typo (e.g. writing a percentage) would
    /// skew every runtime result without failing. Negative jitter,
    /// out-of-range straggler probability, sub-1 straggler multipliers,
    /// and degenerate bandwidth/topology values are rejected for the same
    /// reason.
    pub fn validate(&self) -> Result<()> {
        if !self.compute_jitter.is_finite() || !(0.0..1.0).contains(&self.compute_jitter) {
            bail!(
                "compute_jitter must be in [0, 1), got {} (the 1 + jitter·N(0,1) \
                 clamp would silently distort the mean at jitter >= 1)",
                self.compute_jitter
            );
        }
        if !self.straggler_prob.is_finite() || !(0.0..=1.0).contains(&self.straggler_prob) {
            bail!("straggler_prob must be a probability, got {}", self.straggler_prob);
        }
        if self.straggler_prob > 0.0 && (!self.straggler_mult.is_finite() || self.straggler_mult < 1.0)
        {
            bail!("straggler_mult must be >= 1, got {}", self.straggler_mult);
        }
        if !self.link_bandwidth.is_finite()
            || self.link_bandwidth <= 0.0
            || !self.local_bandwidth.is_finite()
            || self.local_bandwidth <= 0.0
        {
            bail!("link/local bandwidth must be > 0");
        }
        if !self.latency.is_finite() || self.latency < 0.0 {
            bail!("latency must be >= 0, got {}", self.latency);
        }
        if self.learners_per_node == 0 {
            bail!("learners_per_node must be >= 1");
        }
        Ok(())
    }
}

/// An endpoint (a learner's or server's NIC attachment) whose busy-until
/// horizon serializes transfers — the contention model.
#[derive(Debug, Clone, Default)]
pub struct Endpoint {
    busy_until: f64,
    /// Total seconds this endpoint spent transferring (for utilization).
    pub busy_total: f64,
}

impl Endpoint {
    /// Reserve the endpoint for a transfer of duration `dur` starting no
    /// earlier than `earliest`; returns the transfer's completion time.
    pub fn reserve(&mut self, earliest: f64, dur: f64) -> f64 {
        let start = self.busy_until.max(earliest);
        self.busy_until = start + dur;
        self.busy_total += dur;
        self.busy_until
    }

    pub fn busy_until(&self) -> f64 {
        self.busy_until
    }
}

/// The communication fabric: one egress endpoint per sender plus one
/// ingress endpoint per receiver. A message must reserve both. Endpoints
/// can be marked *single-duplex*: the paper's parameter server "handles
/// each incoming message one by one" (§3.2), so its sends and receives
/// serialize through a single service queue.
#[derive(Debug)]
pub struct Fabric {
    pub spec: ClusterSpec,
    egress: Vec<Endpoint>,
    ingress: Vec<Endpoint>,
    single_duplex: Vec<bool>,
}

impl Fabric {
    pub fn new(spec: ClusterSpec, endpoints: usize) -> Fabric {
        Fabric {
            spec,
            egress: vec![Endpoint::default(); endpoints],
            ingress: vec![Endpoint::default(); endpoints],
            single_duplex: vec![false; endpoints],
        }
    }

    /// Mark `e` as single-duplex: its sends and receives share one
    /// service queue (the §3.2 one-by-one PS message handling).
    pub fn set_single_duplex(&mut self, e: usize) {
        self.single_duplex[e] = true;
    }

    pub fn endpoints(&self) -> usize {
        self.egress.len()
    }

    /// Send `bytes` from endpoint `src` to endpoint `dst`, starting no
    /// earlier than `at`; returns delivery completion time. Loopback
    /// (src == dst, e.g. a learner pulling from its co-located PS leaf)
    /// is an intra-node memory copy: `bytes/local_bandwidth`, uncontended.
    pub fn send(&mut self, at: f64, src: usize, dst: usize, bytes: f64) -> f64 {
        if src == dst {
            return at + self.spec.latency + bytes / self.spec.local_bandwidth;
        }
        let dur = bytes / self.spec.link_bandwidth;
        // Reserve egress first, then ingress after the egress start; a
        // store-and-forward approximation of cut-through wormhole routing
        // that keeps contention effects first-order correct. Single-duplex
        // endpoints use their ingress queue for both directions.
        let egress_done = if self.single_duplex[src] {
            self.ingress[src].reserve(at, dur)
        } else {
            self.egress[src].reserve(at, dur)
        };
        let start_rx = egress_done - dur; // transmission start
        let ingress_done = self.ingress[dst].reserve(start_rx, dur);
        ingress_done + self.spec.latency
    }

    /// Striped push to a sharded server (§3.3 root-bottleneck fix): the
    /// message is split evenly across the shard endpoints, the sender's
    /// egress carries the slices back to back, and each shard's ingress
    /// serves only its slice. Returns the time the *last* slice lands —
    /// the moment the full gradient is folded. With one shard endpoint
    /// this is exactly [`Fabric::send`].
    pub fn send_to_shards(&mut self, at: f64, src: usize, shard_eps: &[usize], bytes: f64) -> f64 {
        assert!(!shard_eps.is_empty(), "need at least one shard endpoint");
        if shard_eps.len() == 1 {
            return self.send(at, src, shard_eps[0], bytes);
        }
        let per = bytes / shard_eps.len() as f64;
        let mut done = f64::NEG_INFINITY;
        for &e in shard_eps {
            done = done.max(self.send(at, src, e, per));
        }
        done
    }

    /// Striped pull/broadcast from a sharded server: each shard endpoint
    /// sends its slice of the weights; the payload is complete when the
    /// last slice arrives at `dst`. With one shard endpoint this is
    /// exactly [`Fabric::send`].
    pub fn send_from_shards(&mut self, at: f64, shard_eps: &[usize], dst: usize, bytes: f64) -> f64 {
        assert!(!shard_eps.is_empty(), "need at least one shard endpoint");
        if shard_eps.len() == 1 {
            return self.send(at, shard_eps[0], dst, bytes);
        }
        let per = bytes / shard_eps.len() as f64;
        let mut done = f64::NEG_INFINITY;
        for &e in shard_eps {
            done = done.max(self.send(at, e, dst, per));
        }
        done
    }

    /// Ingress utilization of endpoint `e` over `[0, horizon]`.
    pub fn ingress_utilization(&self, e: usize, horizon: f64) -> f64 {
        if horizon <= 0.0 {
            0.0
        } else {
            self.ingress[e].busy_total / horizon
        }
    }

    /// Dump every endpoint's mutable state as
    /// `(egress_busy_until, egress_busy_total, ingress_busy_until,
    /// ingress_busy_total)` rows, for a mid-flight sim checkpoint. The
    /// topology (endpoint count, single-duplex marks) is derived from
    /// config and not included.
    pub fn endpoint_state(&self) -> Vec<(f64, f64, f64, f64)> {
        self.egress
            .iter()
            .zip(&self.ingress)
            .map(|(e, i)| (e.busy_until, e.busy_total, i.busy_until, i.busy_total))
            .collect()
    }

    /// Install endpoint state captured by [`Fabric::endpoint_state`] into
    /// a freshly built fabric of the same topology.
    pub fn restore_endpoint_state(&mut self, rows: &[(f64, f64, f64, f64)]) -> Result<()> {
        if rows.len() != self.egress.len() {
            bail!(
                "fabric checkpoint has {} endpoints, topology has {}",
                rows.len(),
                self.egress.len()
            );
        }
        for (n, &(eb, et, ib, it)) in rows.iter().enumerate() {
            self.egress[n].busy_until = eb;
            self.egress[n].busy_total = et;
            self.ingress[n].busy_until = ib;
            self.ingress[n].busy_total = it;
        }
        Ok(())
    }
}

/// Draw a jittered compute duration (with optional straggler injection).
pub fn jittered(base: f64, spec: &ClusterSpec, rng: &mut Rng) -> f64 {
    let mut t = if spec.compute_jitter == 0.0 {
        base
    } else {
        base * (1.0 + spec.compute_jitter * rng.normal()).max(0.2)
    };
    if spec.straggler_prob > 0.0 && rng.f64() < spec.straggler_prob {
        t *= spec.straggler_mult.max(1.0);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_time_scale_matches_paper() {
        // §3.3: "a single learner pushing a model of 300 MB would take
        // more than 10 ms".
        let spec = ClusterSpec::p775();
        let t = spec.wire_time(300.0e6);
        assert!(t > 0.010 && t < 0.3, "300MB push = {t}s");
    }

    #[test]
    fn contention_serializes() {
        // §3.3: "If 16 tasks are sending 300 MB to the same receiver and
        // there is link contention, it would take over a second."
        let spec = ClusterSpec::p775();
        let mut fabric = Fabric::new(spec, 17);
        let mut last = 0.0f64;
        for src in 1..=16 {
            last = last.max(fabric.send(0.0, src, 0, 300.0e6));
        }
        assert!(last > 1.0, "16×300MB into one receiver took {last}s");
        // and strictly worse than a single send
        let mut f2 = Fabric::new(ClusterSpec::p775(), 2);
        let single = f2.send(0.0, 1, 0, 300.0e6);
        assert!(last > 10.0 * single);
    }

    #[test]
    fn loopback_is_local_copy() {
        let mut fabric = Fabric::new(ClusterSpec::p775(), 2);
        let t = fabric.send(1.0, 1, 1, 1.2e9);
        let want = 1.0 + fabric.spec.latency + 1.2e9 / fabric.spec.local_bandwidth;
        assert!((t - want).abs() < 1e-9);
        // and much cheaper than a NIC transfer of the same size
        let wire = fabric.spec.wire_time(1.2e9);
        assert!(t - 1.0 < wire);
    }

    #[test]
    fn single_duplex_serializes_both_directions() {
        let spec = ClusterSpec::p775();
        let mut fabric = Fabric::new(spec, 3);
        fabric.set_single_duplex(0);
        // A receive then a send on endpoint 0 must serialize.
        let t1 = fabric.send(0.0, 1, 0, 300.0e6); // into 0
        let t2 = fabric.send(0.0, 0, 2, 300.0e6); // out of 0
        let dur = 300.0e6 / fabric.spec.link_bandwidth;
        assert!(t2 >= t1 + dur - 1e-9, "send must queue behind receive: {t2} vs {t1}");
        // Whereas a normal endpoint overlaps the two directions.
        let mut f2 = Fabric::new(ClusterSpec::p775(), 3);
        let a1 = f2.send(0.0, 1, 0, 300.0e6);
        let a2 = f2.send(0.0, 0, 2, 300.0e6);
        assert!(a2 < a1 + dur - 1e-9);
    }

    #[test]
    fn striped_send_with_one_shard_is_plain_send() {
        let mut a = Fabric::new(ClusterSpec::p775(), 3);
        let mut b = Fabric::new(ClusterSpec::p775(), 3);
        // interleave some traffic so endpoint state is non-trivial
        a.send(0.0, 1, 2, 1.0e6);
        b.send(0.0, 1, 2, 1.0e6);
        let ta = a.send_to_shards(0.5, 1, &[0], 300.0e6);
        let tb = b.send(0.5, 1, 0, 300.0e6);
        assert_eq!(ta, tb);
        let ta = a.send_from_shards(1.0, &[0], 2, 300.0e6);
        let tb = b.send(1.0, 0, 2, 300.0e6);
        assert_eq!(ta, tb);
    }

    #[test]
    fn sharding_relieves_the_root_bottleneck() {
        // The §3.3 adversarial wave: 16 learners push 300 MB at once. One
        // root endpoint serializes the full 4.8 GB; four shard endpoints
        // each serialize only a quarter of it.
        let flat_last = {
            let mut f = Fabric::new(ClusterSpec::p775(), 17);
            let mut last = 0.0f64;
            for src in 1..=16 {
                last = last.max(f.send_to_shards(0.0, src, &[0], 300.0e6));
            }
            last
        };
        let sharded_last = {
            let mut f = Fabric::new(ClusterSpec::p775(), 20);
            let shard_eps = [16, 17, 18, 19];
            let mut last = 0.0f64;
            for src in 0..16 {
                last = last.max(f.send_to_shards(0.0, src, &shard_eps, 300.0e6));
            }
            last
        };
        assert!(
            sharded_last < flat_last * 0.5,
            "4 shards should cut the root stall well below half: {sharded_last} vs {flat_last}"
        );
    }

    #[test]
    fn striped_pull_completes_when_last_slice_lands() {
        let mut f = Fabric::new(ClusterSpec::p775(), 4);
        // preload shard endpoint 2's egress so its slice arrives late
        f.send(0.0, 2, 3, 300.0e6);
        let t = f.send_from_shards(0.0, &[1, 2], 0, 100.0e6);
        let mut g = Fabric::new(ClusterSpec::p775(), 4);
        let unloaded = g.send_from_shards(0.0, &[1, 2], 0, 100.0e6);
        assert!(t > unloaded, "busy shard must delay completion: {t} vs {unloaded}");
    }

    #[test]
    fn endpoint_reserve_is_fifo() {
        let mut e = Endpoint::default();
        let d1 = e.reserve(0.0, 1.0);
        let d2 = e.reserve(0.0, 1.0);
        assert_eq!(d1, 1.0);
        assert_eq!(d2, 2.0);
        let d3 = e.reserve(5.0, 1.0); // idle gap then new reservation
        assert_eq!(d3, 6.0);
    }

    #[test]
    fn endpoint_state_roundtrip_replays_contention() {
        let mut a = Fabric::new(ClusterSpec::p775(), 4);
        a.set_single_duplex(0);
        a.send(0.0, 1, 0, 300.0e6);
        a.send(0.1, 2, 0, 300.0e6);
        let rows = a.endpoint_state();
        let mut b = Fabric::new(ClusterSpec::p775(), 4);
        b.set_single_duplex(0);
        b.restore_endpoint_state(&rows).unwrap();
        // identical queueing from here on, to the bit
        let ta = a.send(0.2, 3, 0, 300.0e6);
        let tb = b.send(0.2, 3, 0, 300.0e6);
        assert_eq!(ta.to_bits(), tb.to_bits());
        assert_eq!(a.ingress_utilization(0, 10.0), b.ingress_utilization(0, 10.0));
        assert!(b.restore_endpoint_state(&rows[..2]).is_err(), "topology mismatch rejected");
    }

    #[test]
    fn stragglers_produce_heavy_tail() {
        let spec = ClusterSpec::chaotic();
        let mut rng = Rng::new(4);
        let xs: Vec<f64> = (0..5000).map(|_| jittered(1.0, &spec, &mut rng)).collect();
        let slow = xs.iter().filter(|&&x| x > 5.0).count() as f64 / xs.len() as f64;
        assert!(
            (0.02..0.10).contains(&slow),
            "~5% of mini-batches should straggle, got {slow}"
        );
        // no straggler config: never beyond the jitter envelope
        let spec = ClusterSpec::p775();
        let mut rng = Rng::new(4);
        assert!((0..5000).all(|_| jittered(1.0, &spec, &mut rng) < 2.0));
    }

    #[test]
    fn validate_rejects_distorting_jitter() {
        // Regression: a jitter >= 1 (or < 0) used to be accepted silently
        // even though the 1 + jitter·N(0,1) clamp at 0.2 turns it into a
        // mean shift rather than symmetric noise.
        assert!(ClusterSpec::p775().validate().is_ok());
        assert!(ClusterSpec::chaotic().validate().is_ok());
        let spec = |j: f64| ClusterSpec { compute_jitter: j, ..ClusterSpec::p775() };
        for bad in [-0.1, 1.0, 1.5, f64::NAN, f64::INFINITY] {
            let err = spec(bad).validate().unwrap_err();
            assert!(err.to_string().contains("compute_jitter"), "{bad}: {err}");
        }
        assert!(spec(0.0).validate().is_ok());
        assert!(spec(0.99).validate().is_ok());
        // the clamp's mean-shift, demonstrated: at jitter 2 the mean draw
        // is well above the nominal 1.0 the spec pretends to preserve
        let distorted = ClusterSpec { compute_jitter: 2.0, ..ClusterSpec::p775() };
        let mut rng = Rng::new(1);
        let mean: f64 =
            (0..20_000).map(|_| jittered(1.0, &distorted, &mut rng)).sum::<f64>() / 20_000.0;
        assert!(mean > 1.15, "clamp inflates the mean to {mean} — why jitter >= 1 is invalid");
        // the other knobs are covered too
        let bad_prob = ClusterSpec { straggler_prob: 1.5, ..ClusterSpec::p775() };
        assert!(bad_prob.validate().is_err());
        let bad_mult =
            ClusterSpec { straggler_prob: 0.1, straggler_mult: 0.5, ..ClusterSpec::p775() };
        assert!(bad_mult.validate().is_err());
        let bad_lpn = ClusterSpec { learners_per_node: 0, ..ClusterSpec::p775() };
        assert!(bad_lpn.validate().is_err());
    }

    #[test]
    fn jitter_bounded_and_deterministic() {
        let spec = ClusterSpec::p775();
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        for _ in 0..100 {
            let a = jittered(1.0, &spec, &mut r1);
            let b = jittered(1.0, &spec, &mut r2);
            assert_eq!(a, b);
            assert!(a >= 0.2);
        }
    }
}
