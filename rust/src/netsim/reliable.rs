//! Reliability primitives for the fault-injected fabric: idempotent
//! dedup windows and the message-accounting ledger.
//!
//! The fault plane ([`crate::netsim::faults`]) can drop, duplicate,
//! reorder, and retransmit messages. Exactly-once *effect* semantics are
//! restored at the receivers: every sender stamps a per-link sequence
//! number, and every receiver passes it through a [`DedupWindow`] before
//! acting, so a duplicated or retried gradient is never double-accumulated
//! and a duplicated broadcast never starts a second compute loop.
//!
//! [`FaultStats`] is the shared ledger. Its invariant — checked in tests
//! and by the CI chaos smoke — is message conservation:
//!
//! ```text
//! sent + retransmits + dups_injected == delivered + dropped
//! ```
//!
//! every transmission attempt (original, retry, or injected duplicate)
//! either arrives or is dropped; nothing is created or lost off-ledger.
//! `dedup_dropped` counts receiver-side rejections of messages that *did*
//! arrive, so it sits outside the conservation law on purpose.

use crate::util::json::Json;
use anyhow::Result;

/// Sliding dedup window over per-sender sequence numbers: a 64-deep
/// bitmask anchored at the highest sequence seen. Accepts any unseen
/// sequence within the window (so reordered deliveries still land),
/// rejects duplicates and anything older than the window (a retry that
/// stale has long been superseded).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DedupWindow {
    /// Highest sequence accepted so far (valid only once `seen_any`).
    max_seen: u64,
    /// Bit `i` set ⇔ sequence `max_seen - i` was accepted.
    mask: u64,
    seen_any: bool,
}

impl DedupWindow {
    pub fn new() -> DedupWindow {
        DedupWindow::default()
    }

    /// Returns `true` iff `seq` has not been accepted before and is not
    /// older than the 64-message window; records it when accepted.
    pub fn accept(&mut self, seq: u64) -> bool {
        if !self.seen_any {
            self.seen_any = true;
            self.max_seen = seq;
            self.mask = 1;
            return true;
        }
        if seq > self.max_seen {
            let shift = seq - self.max_seen;
            self.mask = if shift >= 64 { 0 } else { self.mask << shift };
            self.mask |= 1;
            self.max_seen = seq;
            return true;
        }
        let back = self.max_seen - seq;
        if back >= 64 {
            return false; // beyond the window: treat as a stale duplicate
        }
        if self.mask & (1u64 << back) != 0 {
            return false;
        }
        self.mask |= 1u64 << back;
        true
    }

    /// Checkpoint form: `(max_seen, mask, seen_any)`.
    pub fn state(&self) -> (u64, u64, bool) {
        (self.max_seen, self.mask, self.seen_any)
    }

    pub fn from_state(max_seen: u64, mask: u64, seen_any: bool) -> DedupWindow {
        DedupWindow { max_seen, mask, seen_any }
    }
}

/// Serialize a slice of windows as one compact string per window. The
/// mask is a full 64-bit value, so it travels as hex (JSON numbers are
/// f64-backed and silently round above 2⁵³ — the same reason RNG states
/// checkpoint as hex strings).
pub fn windows_to_json(wins: &[DedupWindow]) -> Json {
    Json::Arr(
        wins.iter()
            .map(|w| {
                let (max_seen, mask, seen_any) = w.state();
                Json::str(format!("{max_seen}:{mask:016x}:{}", u8::from(seen_any)))
            })
            .collect(),
    )
}

/// Inverse of [`windows_to_json`]; `expect` guards the learner-count
/// match against the resuming config.
pub fn windows_from_json(j: &Json, expect: usize) -> Result<Vec<DedupWindow>> {
    let arr = j.as_arr()?;
    anyhow::ensure!(
        arr.len() == expect,
        "dedup window checkpoint has {} entries for {} windows",
        arr.len(),
        expect
    );
    arr.iter()
        .map(|v| {
            let s = v.as_str()?;
            let mut it = s.split(':');
            let (Some(max_seen), Some(mask), Some(seen), None) =
                (it.next(), it.next(), it.next(), it.next())
            else {
                anyhow::bail!("malformed dedup window entry '{s}'");
            };
            Ok(DedupWindow::from_state(
                max_seen.parse::<u64>()?,
                u64::from_str_radix(mask, 16)?,
                seen != "0",
            ))
        })
        .collect()
}

/// Fault/retry/dedup accounting shared by the fault plane and the
/// engines. All counters are message-level (one per transmission attempt
/// or receiver decision), except `retry_bytes`, which books the byte
/// overhead retransmissions add on the root links.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultStats {
    /// Original messages handed to the fault plane (one per logical send).
    pub sent: u64,
    /// Retransmission attempts after a drop (never counts the original).
    pub retransmits: u64,
    /// Duplicate deliveries injected by the fault plane.
    pub dups_injected: u64,
    /// Transmission attempts dropped in the network (loss or partition).
    pub dropped: u64,
    /// Deliveries that reached a receiver (originals, retries, and dups).
    pub delivered: u64,
    /// Messages abandoned after the retry budget was exhausted.
    pub exhausted: u64,
    /// Deliveries rejected by a receiver dedup window (arrived, not acted).
    pub dedup_dropped: u64,
    /// Byte overhead of retransmissions (booked into root bytes in/out).
    pub retry_bytes: f64,
    /// Retransmission attempts attributed per learner slot (the stats
    /// server's per-learner chaos columns).
    pub retransmits_by: Vec<u64>,
}

impl FaultStats {
    pub fn new(lambda: usize) -> FaultStats {
        FaultStats { retransmits_by: vec![0; lambda], ..FaultStats::default() }
    }

    /// The conservation law: every attempt (original, retry, injected
    /// dup) either arrives or drops.
    pub fn balances(&self) -> bool {
        self.sent + self.retransmits + self.dups_injected == self.delivered + self.dropped
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("sent", Json::num(self.sent as f64)),
            ("retransmits", Json::num(self.retransmits as f64)),
            ("dups_injected", Json::num(self.dups_injected as f64)),
            ("dropped", Json::num(self.dropped as f64)),
            ("delivered", Json::num(self.delivered as f64)),
            ("exhausted", Json::num(self.exhausted as f64)),
            ("dedup_dropped", Json::num(self.dedup_dropped as f64)),
            ("retry_bytes", Json::num(self.retry_bytes)),
            ("retransmits_by", Json::arr_u64(&self.retransmits_by)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<FaultStats> {
        Ok(FaultStats {
            sent: j.get("sent")?.as_u64()?,
            retransmits: j.get("retransmits")?.as_u64()?,
            dups_injected: j.get("dups_injected")?.as_u64()?,
            dropped: j.get("dropped")?.as_u64()?,
            delivered: j.get("delivered")?.as_u64()?,
            exhausted: j.get("exhausted")?.as_u64()?,
            dedup_dropped: j.get("dedup_dropped")?.as_u64()?,
            retry_bytes: j.get("retry_bytes")?.as_f64()?,
            retransmits_by: j.get("retransmits_by")?.as_u64_vec()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_accepts_fresh_rejects_duplicate() {
        let mut w = DedupWindow::new();
        assert!(w.accept(0));
        assert!(!w.accept(0), "exact duplicate rejected");
        assert!(w.accept(1));
        assert!(w.accept(2));
        assert!(!w.accept(1), "replayed retry rejected");
    }

    #[test]
    fn window_accepts_reordered_within_window() {
        let mut w = DedupWindow::new();
        assert!(w.accept(5));
        assert!(w.accept(3), "late-but-unseen sequence still lands");
        assert!(!w.accept(3));
        assert!(w.accept(4));
        assert!(w.accept(6));
    }

    #[test]
    fn window_rejects_older_than_depth() {
        let mut w = DedupWindow::new();
        assert!(w.accept(100));
        assert!(!w.accept(36), "100 - 36 = 64 ≥ window depth");
        assert!(w.accept(37), "100 - 37 = 63 still inside");
    }

    #[test]
    fn window_zero_is_a_real_sequence() {
        let mut w = DedupWindow::new();
        assert!(w.accept(0));
        assert!(!w.accept(0));
    }

    #[test]
    fn window_large_jump_clears_history() {
        let mut w = DedupWindow::new();
        assert!(w.accept(1));
        assert!(w.accept(1000));
        assert!(!w.accept(1), "fell out of the window");
        assert!(w.accept(999));
    }

    #[test]
    fn window_state_roundtrip() {
        let mut w = DedupWindow::new();
        for s in [4u64, 2, 7, 5] {
            w.accept(s);
        }
        let (m, b, any) = w.state();
        let mut back = DedupWindow::from_state(m, b, any);
        assert_eq!(back, w);
        assert!(!back.accept(7));
        assert!(back.accept(6));
    }

    #[test]
    fn windows_flat_json_roundtrip() {
        let mut a = DedupWindow::new();
        a.accept(9);
        a.accept(11);
        let wins = vec![a, DedupWindow::new()];
        let j = windows_to_json(&wins);
        let back = windows_from_json(&j, 2).unwrap();
        assert_eq!(back, wins);
        assert!(windows_from_json(&j, 3).is_err(), "count mismatch rejected");
    }

    #[test]
    fn windows_json_preserves_full_64bit_mask() {
        // A mask with the top bit set must survive the round-trip exactly
        // (it would round if it ever passed through an f64-backed number).
        let w = DedupWindow::from_state(200, u64::MAX, true);
        let back = windows_from_json(&windows_to_json(std::slice::from_ref(&w)), 1).unwrap();
        assert_eq!(back[0], w);
    }

    #[test]
    fn stats_json_roundtrip_and_balance() {
        let mut s = FaultStats::new(3);
        s.sent = 10;
        s.retransmits = 4;
        s.dups_injected = 1;
        s.delivered = 11;
        s.dropped = 4;
        s.exhausted = 1;
        s.dedup_dropped = 1;
        s.retry_bytes = 1234.5;
        s.retransmits_by = vec![2, 0, 2];
        assert!(s.balances());
        let back = FaultStats::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        s.dropped += 1;
        assert!(!s.balances());
    }
}
