//! Run configuration: defaults, JSON config files, CLI overrides.
//!
//! A [`RunConfig`] fully determines a training run (model, protocol,
//! (σ, μ, λ) point, architecture, LR policy, seeds) and can be built from
//! a JSON file (`--config run.json`) with CLI flags layered on top —
//! the "real config system" a framework needs, sized to the offline
//! dependency set (our own JSON, no serde).

use anyhow::{anyhow, bail, Result};
use std::path::Path;

use crate::comm::codec::CodecSpec;
use crate::coordinator::protocol::Protocol;
use crate::coordinator::tree::Arch;
use crate::elastic::membership::ChurnSchedule;
use crate::elastic::rescaler::RescalePolicy;
use crate::netsim::faults::FaultSpec;
use crate::params::lr::Modulation;
use crate::params::optimizer::OptimizerKind;
use crate::straggler::adaptive::AdaptiveSpec;
use crate::straggler::hetero::HeteroSpec;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Which model family a run trains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    Cnn,
    Lm,
}

impl ModelKind {
    pub fn parse(s: &str) -> Result<ModelKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "cnn" => Ok(ModelKind::Cnn),
            "lm" | "transformer" => Ok(ModelKind::Lm),
            other => bail!("unknown model {other:?} (cnn | lm)"),
        }
    }
}

/// Complete run description.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: ModelKind,
    pub protocol: Protocol,
    pub arch: Arch,
    pub mu: usize,
    pub lambda: usize,
    pub epochs: usize,
    pub seed: u64,
    pub base_lr: f64,
    pub modulation: Modulation,
    pub optimizer: OptimizerKind,
    pub weight_decay: f32,
    /// Reference batch size B for the hardsync √-rule (paper: 128).
    pub reference_batch: usize,
    /// Use the paper-shaped step LR schedule (drops at 85%/93%).
    pub paper_schedule: bool,
    /// Warm-start: epochs of hardsync before switching protocol (§5.5).
    pub warmstart_epochs: usize,
    pub eval_each_epoch: bool,
    /// Parameter shards at the server's root tier (JSON key / CLI flag
    /// `shards`). 1 (the default) is the paper's flat server; S > 1
    /// splits θ into S contiguous shards with independent endpoints and
    /// parallel applyUpdate — the §3.3 root-bottleneck fix
    /// ([`crate::coordinator::shard`]). Protocol semantics, staleness,
    /// and fixed-seed S = 1 trajectories are unchanged.
    pub shards: usize,
    /// Elastic membership churn (JSON key / flag `churn`): a DSL string
    /// of deterministic events and/or a random failure process, e.g.
    /// `"kill:3@10,rejoin:3@25,rate:2,downtime:30"` — see
    /// [`ChurnSchedule::parse`]. `"none"` (the default) is churn-free.
    pub churn: ChurnSchedule,
    /// Checkpoint interval in weight updates (JSON key / flag
    /// `checkpoint_every`): capture the full server + RNG state every N
    /// updates ([`crate::elastic::checkpoint`]). 0 = off.
    pub checkpoint_every: u64,
    /// μ·λ rescale policy on membership changes (JSON key / flag
    /// `rescale`): `"none"` keeps μ fixed, `"mulambda"` holds
    /// μ·λ_active ≈ μ₀·λ₀ live ([`crate::elastic::rescaler`]).
    pub rescale: RescalePolicy,
    /// Per-learner speed heterogeneity (JSON key / flag `hetero`): a DSL
    /// string of explicit `slow:<id>x<factor>` entries, sampled
    /// `lognormal:<sigma>` / `pareto:<alpha>` distributions, and a
    /// `markov:<p_degrade>:<p_recover>:<mult>` transient process — see
    /// [`HeteroSpec::parse`]. `"none"` (default) is homogeneous and
    /// preserves bit-identical fixed-seed trajectories.
    pub hetero: HeteroSpec,
    /// Adaptive-n staleness control (JSON key / flag `adaptive`):
    /// `"sigma:<target>"` retunes the n-softsync splitting parameter per
    /// epoch to hold the target ⟨σ⟩ ([`AdaptiveSpec::parse`]). `"none"`
    /// (default) is open-loop.
    pub adaptive: AdaptiveSpec,
    /// Gradient compression codec (JSON key / flag `compress`):
    /// `"none"` (default, bit-identical baseline), `"topk:<frac>"`
    /// sparsification, or `"qsgd:<bits>"` stochastic quantization, each
    /// with per-learner error-feedback residuals
    /// ([`crate::comm::codec`]). Compressed pushes shrink wire time in
    /// both engines; weight pulls stay dense.
    pub compress: CodecSpec,
    /// Parallel grid execution (JSON key / flag `jobs`): worker threads
    /// for sweep grids ([`crate::harness::sweep::run_indexed`]). `0` (the
    /// default) = available parallelism, `1` = the serial path. A
    /// host-side scheduling knob only — grid points own their seeds and
    /// RNG streams, so results are bit-identical at any value (which is
    /// also why `jobs` never appears in [`RunConfig::label`]).
    pub jobs: usize,
    /// Sweep grid μ axis (JSON key `mus` / flag `--mus a,b,c`): the
    /// per-learner mini-batch sizes the `sweep` subcommand runs. `None`
    /// keeps the subcommand's built-in default axis; single-point
    /// commands (`sim`/`train`/`timing`) ignore it.
    pub sweep_mus: Option<Vec<usize>>,
    /// Sweep grid λ axis (JSON key `lambdas` / flag `--lambdas`),
    /// mirroring [`RunConfig::sweep_mus`].
    pub sweep_lambdas: Option<Vec<usize>>,
    /// Timing-only early stop (JSON key `stop_after_events` / flag
    /// `--stop-after-events`): halt the `timing` engine once this many
    /// events have been processed and capture a mid-flight sim
    /// checkpoint. The count is absolute, so a resumed run passes the
    /// *total* target, not a remainder. `None` runs to completion.
    pub stop_after_events: Option<u64>,
    /// Where the `timing` engine writes the mid-flight sim checkpoint
    /// when `stop_after_events` fires (JSON key `sim_checkpoint` / flag
    /// `--sim-checkpoint`). `None` keeps the snapshot in memory only.
    pub sim_checkpoint: Option<std::path::PathBuf>,
    /// Chrome trace-event output path (JSON key `trace` / flag `--trace`;
    /// `"none"` clears a config-file value). Single-point commands
    /// (`sim`/`timing`) record spans over virtual sim time, `train`
    /// records over wall time ([`crate::obs::trace::TimeBase`]), and the
    /// file lands at run end ([`crate::obs::trace`]). For `sweep` the
    /// path is a *directory*: each grid point writes its own
    /// `<label>.trace.json` from its worker thread. Purely observational,
    /// so trajectories stay bit-identical; like the resume knobs above,
    /// it never enters [`RunConfig::label`].
    pub trace: Option<std::path::PathBuf>,
    /// Metrics snapshot output path (JSON key `metrics_json` / flag
    /// `--metrics-json`; `"none"` clears). Enables the
    /// [`crate::obs::metrics`] registry and dumps its end-of-run snapshot
    /// as JSON. For `sweep` the path is a directory holding per-point
    /// `<label>.metrics.json` files, mirroring `trace`.
    pub metrics_json: Option<std::path::PathBuf>,
    /// Persistent run index (JSON key `run_index` / flag `--run-index`;
    /// `"none"` clears). Every sim/sweep/timing point appends one record
    /// to this JSONL file ([`crate::obs::runindex`]; query with
    /// `rudra runs` or render with `rudra report`).
    pub run_index: Option<std::path::PathBuf>,
    /// Time-series sampling interval in engine seconds (JSON key
    /// `metrics_every` / flag `--metrics-every SECS`; `"none"` clears).
    /// Arms the [`crate::obs::series`] recorder: windowed staleness /
    /// queue-depth / active-λ / byte-rate samples over virtual time
    /// (sim/timing) or wall time (train), attached to the metrics
    /// snapshot under `"series"`. Off by default; purely observational.
    pub metrics_every: Option<f64>,
    /// Critical-path profiler (JSON key `profile` / flag `--profile`).
    /// Attributes every weight update's causal chain to categories
    /// (compute, wire, barrier wait, …) with per-learner blame and
    /// Amdahl-style what-if projections, attached to the metrics snapshot
    /// under `"profile"` and read back by `rudra analyze`. Off by
    /// default; purely observational (bit-identical trajectories), so —
    /// like the other obs knobs — it never enters [`RunConfig::label`].
    pub profile: bool,
    /// Network chaos (JSON key `faults` / flag `--faults SPEC`): a
    /// message-fault DSL such as
    /// `loss:0.05,dup:0.01,reorder:0.02,delayspike:0.1x20,partition:rack0-rack1@30s+15s`,
    /// driving the sim engine's fault plane ([`crate::netsim::faults`])
    /// and the live engine's synthetic loss layer. `none` (the default)
    /// is bit-identical to the pre-chaos engine; unlike the obs knobs it
    /// changes trajectories, so it *does* enter [`RunConfig::label`].
    pub faults: FaultSpec,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: ModelKind::Cnn,
            protocol: Protocol::NSoftsync { n: 1 },
            arch: Arch::Base,
            mu: 16,
            lambda: 4,
            epochs: 10,
            seed: 42,
            base_lr: 0.02,
            modulation: Modulation::Auto,
            optimizer: OptimizerKind::Momentum { momentum: 0.9 },
            weight_decay: 0.0,
            reference_batch: 128,
            paper_schedule: true,
            warmstart_epochs: 0,
            eval_each_epoch: true,
            shards: 1,
            churn: ChurnSchedule::none(),
            checkpoint_every: 0,
            rescale: RescalePolicy::None,
            hetero: HeteroSpec::none(),
            adaptive: AdaptiveSpec::none(),
            compress: CodecSpec::None,
            jobs: 0,
            sweep_mus: None,
            sweep_lambdas: None,
            stop_after_events: None,
            sim_checkpoint: None,
            trace: None,
            metrics_json: None,
            run_index: None,
            metrics_every: None,
            profile: false,
            faults: FaultSpec::none(),
        }
    }
}

/// Path-valued observability knobs accept `"none"` to clear a value set
/// earlier in the layering (so a CLI flag can switch off a config-file
/// default).
fn path_or_none(s: &str) -> Option<std::path::PathBuf> {
    if s.trim().eq_ignore_ascii_case("none") {
        None
    } else {
        Some(std::path::PathBuf::from(s))
    }
}

/// Seconds-valued knob that, like the path knobs, accepts `"none"` to
/// clear a value set earlier in the layering.
fn secs_or_none(s: &str) -> Result<Option<f64>> {
    if s.trim().eq_ignore_ascii_case("none") {
        return Ok(None);
    }
    let v: f64 = s.trim().parse().map_err(|_| anyhow!("bad seconds value {s:?}"))?;
    Ok(Some(v))
}

/// JSON array of integers (the sweep grid axes).
fn parse_axis(v: &Json) -> Result<Vec<usize>> {
    checked_axis(
        "sweep axis",
        v.as_arr()?.iter().map(|x| x.as_usize()).collect::<Result<Vec<usize>>>()?,
    )
}

/// A sweep axis must name at least one point, each with μ/λ ≥ 1.
fn checked_axis(name: &str, axis: Vec<usize>) -> Result<Vec<usize>> {
    if axis.is_empty() || axis.contains(&0) {
        bail!("{name}: sweep axes must be non-empty lists of integers >= 1, got {axis:?}");
    }
    Ok(axis)
}

impl RunConfig {
    /// Layer a JSON object over this config.
    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        let obj = j.as_obj()?;
        for (k, v) in obj {
            match k.as_str() {
                "model" => self.model = ModelKind::parse(v.as_str()?)?,
                "protocol" => self.protocol = Protocol::parse(v.as_str()?)?,
                "arch" => self.arch = Arch::parse(v.as_str()?)?,
                "mu" => self.mu = v.as_usize()?,
                "lambda" => self.lambda = v.as_usize()?,
                "epochs" => self.epochs = v.as_usize()?,
                "seed" => self.seed = v.as_usize()? as u64,
                "base_lr" => self.base_lr = v.as_f64()?,
                "modulation" => self.modulation = parse_modulation(v.as_str()?)?,
                "optimizer" => self.optimizer = parse_optimizer(v.as_str()?)?,
                "weight_decay" => self.weight_decay = v.as_f64()? as f32,
                "reference_batch" => self.reference_batch = v.as_usize()?,
                "paper_schedule" => self.paper_schedule = v.as_bool()?,
                "warmstart_epochs" => self.warmstart_epochs = v.as_usize()?,
                "eval_each_epoch" => self.eval_each_epoch = v.as_bool()?,
                "shards" => self.shards = v.as_usize()?,
                "churn" => self.churn = ChurnSchedule::parse(v.as_str()?)?,
                "checkpoint_every" => self.checkpoint_every = v.as_usize()? as u64,
                "rescale" => self.rescale = RescalePolicy::parse(v.as_str()?)?,
                "hetero" => self.hetero = HeteroSpec::parse(v.as_str()?)?,
                "adaptive" => self.adaptive = AdaptiveSpec::parse(v.as_str()?)?,
                "compress" => self.compress = CodecSpec::parse(v.as_str()?)?,
                "jobs" => self.jobs = v.as_usize()?,
                "mus" => self.sweep_mus = Some(parse_axis(v)?),
                "lambdas" => self.sweep_lambdas = Some(parse_axis(v)?),
                "stop_after_events" => self.stop_after_events = Some(v.as_u64()?),
                "sim_checkpoint" => {
                    self.sim_checkpoint = Some(std::path::PathBuf::from(v.as_str()?))
                }
                "trace" => self.trace = path_or_none(v.as_str()?),
                "metrics_json" => self.metrics_json = path_or_none(v.as_str()?),
                "run_index" => self.run_index = path_or_none(v.as_str()?),
                "metrics_every" => {
                    self.metrics_every = match v {
                        Json::Str(s) => secs_or_none(s)?,
                        _ => Some(v.as_f64()?),
                    }
                }
                "profile" => self.profile = v.as_bool()?,
                "faults" => self.faults = FaultSpec::parse(v.as_str()?)?,
                other => bail!("unknown config key {other:?}"),
            }
        }
        Ok(())
    }

    pub fn apply_file(&mut self, path: &Path) -> Result<()> {
        self.apply_json(&Json::parse_file(path)?)
    }

    /// Layer CLI flags over this config.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(v) = args.get("model") {
            self.model = ModelKind::parse(v)?;
        }
        if let Some(v) = args.get("protocol") {
            self.protocol = Protocol::parse(v)?;
        }
        if let Some(v) = args.get("arch") {
            self.arch = Arch::parse(v)?;
        }
        self.mu = args.usize_or("mu", self.mu)?;
        self.lambda = args.usize_or("lambda", self.lambda)?;
        self.epochs = args.usize_or("epochs", self.epochs)?;
        self.seed = args.u64_or("seed", self.seed)?;
        self.base_lr = args.f64_or("lr", self.base_lr)?;
        if let Some(v) = args.get("modulation") {
            self.modulation = parse_modulation(v)?;
        }
        if let Some(v) = args.get("optimizer") {
            self.optimizer = parse_optimizer(v)?;
        }
        self.warmstart_epochs = args.usize_or("warmstart", self.warmstart_epochs)?;
        self.shards = args.usize_or("shards", self.shards)?;
        if let Some(v) = args.get("churn") {
            self.churn = ChurnSchedule::parse(v)?;
        }
        self.checkpoint_every = args.u64_or("checkpoint-every", self.checkpoint_every)?;
        if let Some(v) = args.get("rescale") {
            self.rescale = RescalePolicy::parse(v)?;
        }
        if let Some(v) = args.get("hetero") {
            self.hetero = HeteroSpec::parse(v)?;
        }
        if let Some(v) = args.get("adaptive") {
            self.adaptive = AdaptiveSpec::parse(v)?;
        }
        if let Some(v) = args.get("compress") {
            self.compress = CodecSpec::parse(v)?;
        }
        self.jobs = args.usize_or("jobs", self.jobs)?;
        if args.get("mus").is_some() {
            self.sweep_mus = Some(checked_axis("mus", args.usize_list_or("mus", &[])?)?);
        }
        if args.get("lambdas").is_some() {
            self.sweep_lambdas =
                Some(checked_axis("lambdas", args.usize_list_or("lambdas", &[])?)?);
        }
        if args.get("stop-after-events").is_some() {
            self.stop_after_events = Some(args.u64_or("stop-after-events", 0)?);
        }
        if let Some(v) = args.get("sim-checkpoint") {
            self.sim_checkpoint = Some(std::path::PathBuf::from(v));
        }
        if let Some(v) = args.get("trace") {
            self.trace = path_or_none(v);
        }
        if let Some(v) = args.get("metrics-json") {
            self.metrics_json = path_or_none(v);
        }
        if let Some(v) = args.get("run-index") {
            self.run_index = path_or_none(v);
        }
        if let Some(v) = args.get("metrics-every") {
            self.metrics_every = secs_or_none(v)?;
        }
        if args.flag("profile") {
            self.profile = true;
        }
        if let Some(v) = args.get("faults") {
            self.faults = FaultSpec::parse(v)?;
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<()> {
        if self.mu == 0 || self.lambda == 0 || self.epochs == 0 {
            bail!("mu, lambda, and epochs must all be >= 1");
        }
        if self.shards == 0 {
            bail!("shards must be >= 1 (1 = the flat, unsharded server)");
        }
        if let Some(max_id) = self.churn.max_learner_id() {
            if max_id >= self.lambda {
                bail!(
                    "churn schedule references learner {max_id}, but lambda = {} \
                     (ids are 0-based)",
                    self.lambda
                );
            }
        }
        if let Protocol::NSoftsync { n } = self.protocol {
            if n > self.lambda {
                // allowed (degenerates to async-like c=1) but suspicious
                // for λ-softsync runs; the paper only uses n ≤ λ.
            }
            if n == 0 {
                bail!("n-softsync requires n >= 1");
            }
        }
        if let Protocol::BackupSync { .. } = self.protocol {
            // the checked quota is the single source of the b < λ rule
            self.protocol.try_gradients_per_update(self.lambda)?;
        }
        if let Some(max_id) = self.hetero.max_learner_id() {
            if max_id >= self.lambda {
                bail!(
                    "hetero spec references learner {max_id}, but lambda = {} \
                     (ids are 0-based)",
                    self.lambda
                );
            }
        }
        if self.adaptive.enabled() && !matches!(self.protocol, Protocol::NSoftsync { .. }) {
            bail!(
                "adaptive staleness control retunes the n-softsync splitting \
                 parameter; protocol {} has none",
                self.protocol.label()
            );
        }
        if let Some(every) = self.metrics_every {
            if !every.is_finite() || every <= 0.0 {
                bail!("metrics_every must be a finite number of seconds > 0, got {every}");
            }
        }
        if !self.faults.partitions.is_empty() && self.faults.racks() > self.lambda {
            bail!(
                "fault spec names rack {} but lambda = {} supports at most {} racks \
                 (one learner per rack minimum)",
                self.faults.racks() - 1,
                self.lambda,
                self.lambda
            );
        }
        Ok(())
    }

    /// Whether any enabled observability sink needs the metrics registry
    /// (the snapshot feeds both the `--metrics-json` dump and the run
    /// index records).
    pub fn collect_metrics(&self) -> bool {
        self.metrics_json.is_some() || self.run_index.is_some()
    }

    /// The LR policy implied by this config.
    pub fn lr_policy(&self) -> crate::params::lr::LrPolicy {
        let schedule = if self.paper_schedule {
            crate::params::lr::Schedule::paper_shape(self.base_lr, self.epochs)
        } else {
            crate::params::lr::Schedule::constant(self.base_lr)
        };
        crate::params::lr::LrPolicy::new(schedule, self.modulation, self.reference_batch)
    }

    /// Short human label, e.g. `(σ=1, μ=4, λ=30) 1-softsync/base`; a
    /// sharded root tier appends ` S=<shards>`, elastic runs append the
    /// churn/rescale markers.
    pub fn label(&self) -> String {
        let shard_suffix =
            if self.shards > 1 { format!(" S={}", self.shards) } else { String::new() };
        let churn_suffix = if self.churn.is_quiet() {
            String::new()
        } else {
            format!(" churn[{}]", self.churn.label())
        };
        let rescale_suffix = if self.rescale == RescalePolicy::MuLambdaConst {
            " μλ=const"
        } else {
            ""
        };
        let hetero_suffix = if self.hetero.is_quiet() {
            String::new()
        } else {
            format!(" hetero[{}]", self.hetero.label())
        };
        let adaptive_suffix = match self.adaptive.target_sigma {
            Some(t) => format!(" adaptive[σ→{t}]"),
            None => String::new(),
        };
        let compress_suffix = if self.compress.is_quiet() {
            String::new()
        } else {
            format!(" comm[{}]", self.compress.label())
        };
        let faults_suffix = if self.faults.is_quiet() {
            String::new()
        } else {
            format!(" faults[{}]", self.faults.label())
        };
        format!(
            "(σ̄={}, μ={}, λ={}) {}/{}{}{}{}{}{}{}{}",
            self.protocol.effective_n(self.lambda),
            self.mu,
            self.lambda,
            self.protocol.label(),
            self.arch.label(),
            shard_suffix,
            churn_suffix,
            rescale_suffix,
            hetero_suffix,
            adaptive_suffix,
            compress_suffix,
            faults_suffix,
        )
    }
}

fn parse_modulation(s: &str) -> Result<Modulation> {
    Modulation::parse(s)
}

fn parse_optimizer(s: &str) -> Result<OptimizerKind> {
    match s.trim().to_ascii_lowercase().as_str() {
        "sgd" => Ok(OptimizerKind::Sgd),
        "momentum" => Ok(OptimizerKind::Momentum { momentum: 0.9 }),
        "adagrad" => Ok(OptimizerKind::Adagrad { eps: 1e-8 }),
        other => bail!("unknown optimizer {other:?} (sgd|momentum|adagrad)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_then_cli_layering() {
        let mut cfg = RunConfig::default();
        cfg.apply_json(
            &Json::parse(r#"{"protocol": "30-softsync", "mu": 8, "lambda": 30}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.protocol, Protocol::NSoftsync { n: 30 });
        assert_eq!(cfg.mu, 8);
        let args = Args::parse(
            ["--mu", "4", "--optimizer", "adagrad"].iter().map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.mu, 4); // CLI wins
        assert_eq!(cfg.lambda, 30); // JSON preserved
        assert_eq!(cfg.optimizer, OptimizerKind::Adagrad { eps: 1e-8 });
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = RunConfig::default();
        let err = cfg.apply_json(&Json::parse(r#"{"mew": 4}"#).unwrap()).unwrap_err();
        assert!(err.to_string().contains("mew"));
    }

    #[test]
    fn validation_catches_zeros() {
        let mut cfg = RunConfig::default();
        cfg.mu = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn shards_knob_layers_and_validates() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.shards, 1, "flat server by default");
        cfg.apply_json(&Json::parse(r#"{"shards": 4}"#).unwrap()).unwrap();
        assert_eq!(cfg.shards, 4);
        let args =
            Args::parse(["--shards", "8"].iter().map(|s| s.to_string()), &[]).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.shards, 8, "CLI wins over JSON");
        cfg.shards = 0;
        assert!(cfg.validate().is_err(), "0 shards rejected");
        cfg.shards = 4;
        assert!(cfg.label().contains("S=4"), "{}", cfg.label());
        cfg.shards = 1;
        assert!(!cfg.label().contains("S="), "{}", cfg.label());
    }

    #[test]
    fn elastic_knobs_layer_and_validate() {
        let mut cfg = RunConfig::default();
        assert!(cfg.churn.is_quiet(), "churn-free by default");
        assert_eq!(cfg.checkpoint_every, 0);
        assert_eq!(cfg.rescale, RescalePolicy::None);
        cfg.apply_json(
            &Json::parse(
                r#"{"lambda": 8, "churn": "kill:3@10,rejoin:3@25", "checkpoint_every": 50,
                    "rescale": "mulambda"}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.churn.events.len(), 2);
        assert_eq!(cfg.checkpoint_every, 50);
        assert_eq!(cfg.rescale, RescalePolicy::MuLambdaConst);
        // CLI wins over JSON
        let args = Args::parse(
            ["--churn", "rate:2,downtime:30", "--rescale", "none", "--checkpoint-every", "10"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert!(cfg.churn.events.is_empty());
        assert_eq!(cfg.churn.kill_rate_per_ksec, 2.0);
        assert_eq!(cfg.rescale, RescalePolicy::None);
        assert_eq!(cfg.checkpoint_every, 10);
        // schedule ids are validated against λ
        cfg.churn = ChurnSchedule::parse("kill:9@1").unwrap();
        assert!(cfg.validate().is_err(), "learner 9 with λ = 8 rejected");
        cfg.lambda = 10;
        assert!(cfg.validate().is_ok());
        // labels surface elasticity
        cfg.rescale = RescalePolicy::MuLambdaConst;
        let l = cfg.label();
        assert!(l.contains("churn[") && l.contains("μλ=const"), "{l}");
    }

    #[test]
    fn straggler_knobs_layer_and_validate() {
        let mut cfg = RunConfig::default();
        assert!(cfg.hetero.is_quiet() && !cfg.adaptive.enabled(), "quiet by default");
        cfg.apply_json(
            &Json::parse(
                r#"{"lambda": 8, "protocol": "4-softsync",
                    "hetero": "slow:2x10,lognormal:0.3", "adaptive": "sigma:4"}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.hetero.slow, vec![(2, 10.0)]);
        assert_eq!(cfg.adaptive.target_sigma, Some(4.0));
        // CLI wins over JSON
        let args = Args::parse(
            ["--hetero", "pareto:2", "--adaptive", "none"].iter().map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.hetero.pareto_alpha, Some(2.0));
        assert!(cfg.hetero.slow.is_empty());
        assert!(!cfg.adaptive.enabled());
        // hetero ids validate against λ
        cfg.hetero = HeteroSpec::parse("slow:9x2").unwrap();
        assert!(cfg.validate().is_err(), "learner 9 with λ = 8 rejected");
        cfg.hetero = HeteroSpec::none();
        // adaptive needs a softsync protocol
        cfg.adaptive = AdaptiveSpec::parse("sigma:2").unwrap();
        cfg.protocol = Protocol::Hardsync;
        assert!(cfg.validate().is_err(), "adaptive + hardsync rejected");
        cfg.protocol = Protocol::NSoftsync { n: 2 };
        assert!(cfg.validate().is_ok());
        // backup:b validates b < λ
        cfg.adaptive = AdaptiveSpec::none();
        cfg.protocol = Protocol::parse("backup:8").unwrap();
        assert!(cfg.validate().is_err(), "b = λ rejected");
        cfg.protocol = Protocol::parse("backup:2").unwrap();
        assert!(cfg.validate().is_ok());
        // labels surface the new knobs
        cfg.hetero = HeteroSpec::parse("slow:1x4").unwrap();
        let l = cfg.label();
        assert!(l.contains("backup:2") && l.contains("hetero[slow:1x4]"), "{l}");
        cfg.protocol = Protocol::NSoftsync { n: 2 };
        cfg.adaptive = AdaptiveSpec::parse("sigma:3").unwrap();
        assert!(cfg.label().contains("adaptive[σ→3]"), "{}", cfg.label());
    }

    #[test]
    fn compress_knob_layers_and_labels() {
        let mut cfg = RunConfig::default();
        assert!(cfg.compress.is_quiet(), "uncompressed by default");
        cfg.apply_json(&Json::parse(r#"{"compress": "topk:0.01"}"#).unwrap()).unwrap();
        assert_eq!(cfg.compress, CodecSpec::TopK { frac: 0.01 });
        // CLI wins over JSON
        let args =
            Args::parse(["--compress", "qsgd:4"].iter().map(|s| s.to_string()), &[]).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.compress, CodecSpec::Qsgd { bits: 4 });
        assert!(cfg.label().contains("comm[qsgd:4]"), "{}", cfg.label());
        cfg.compress = CodecSpec::None;
        assert!(!cfg.label().contains("comm["), "{}", cfg.label());
        // malformed specs are rejected at the parse boundary
        let mut bad = RunConfig::default();
        assert!(bad.apply_json(&Json::parse(r#"{"compress": "topk:2"}"#).unwrap()).is_err());
    }

    #[test]
    fn jobs_and_grid_axes_layer_and_validate() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.jobs, 0, "auto parallelism by default");
        assert!(cfg.sweep_mus.is_none() && cfg.sweep_lambdas.is_none());
        cfg.apply_json(
            &Json::parse(r#"{"jobs": 4, "mus": [4, 16], "lambdas": [2, 8]}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.jobs, 4);
        assert_eq!(cfg.sweep_mus, Some(vec![4, 16]));
        assert_eq!(cfg.sweep_lambdas, Some(vec![2, 8]));
        // CLI wins over JSON
        let args = Args::parse(
            ["--jobs", "1", "--mus", "8,32", "--lambdas", "4"].iter().map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.jobs, 1);
        assert_eq!(cfg.sweep_mus, Some(vec![8, 32]));
        assert_eq!(cfg.sweep_lambdas, Some(vec![4]));
        // jobs is host-side scheduling, not experiment identity
        assert!(!cfg.label().contains("jobs"), "{}", cfg.label());
        // degenerate axes are rejected at the parse boundary
        let mut bad = RunConfig::default();
        assert!(bad.apply_json(&Json::parse(r#"{"mus": []}"#).unwrap()).is_err());
        assert!(bad.apply_json(&Json::parse(r#"{"lambdas": [0, 4]}"#).unwrap()).is_err());
        let args =
            Args::parse(["--mus", "0,4"].iter().map(|s| s.to_string()), &[]).unwrap();
        assert!(RunConfig::default().apply_args(&args).is_err());
    }

    /// Regression: the CLI grid axes must flow through `checked_axis`
    /// exactly like the JSON ones — `--mus 0` (a zero point) and
    /// `--lambdas ""` (an empty value) are rejected at the parse
    /// boundary instead of surfacing later as a degenerate grid point.
    #[test]
    fn cli_axis_validation_rejects_zero_and_empty() {
        let mus0 = Args::parse(["--mus", "0"].iter().map(|s| s.to_string()), &[]).unwrap();
        let err = RunConfig::default().apply_args(&mus0).unwrap_err();
        assert!(err.to_string().contains("mus"), "{err}");
        let empty =
            Args::parse(["--lambdas", ""].iter().map(|s| s.to_string()), &[]).unwrap();
        let err = RunConfig::default().apply_args(&empty).unwrap_err();
        assert!(err.to_string().contains("lambdas"), "{err}");
    }

    #[test]
    fn timing_resume_knobs_layer() {
        let mut cfg = RunConfig::default();
        assert!(cfg.stop_after_events.is_none() && cfg.sim_checkpoint.is_none());
        cfg.apply_json(
            &Json::parse(r#"{"stop_after_events": 5000, "sim_checkpoint": "out/sim.json"}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.stop_after_events, Some(5000));
        assert_eq!(
            cfg.sim_checkpoint.as_deref(),
            Some(std::path::Path::new("out/sim.json"))
        );
        // CLI wins over JSON
        let args = Args::parse(
            ["--stop-after-events", "250", "--sim-checkpoint", "other.json"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.stop_after_events, Some(250));
        assert_eq!(cfg.sim_checkpoint.as_deref(), Some(std::path::Path::new("other.json")));
        // host-side run-control knobs never enter the experiment label
        assert!(!cfg.label().contains("checkpoint"), "{}", cfg.label());
        let bad = Args::parse(
            ["--stop-after-events", "x"].iter().map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        assert!(RunConfig::default().apply_args(&bad).is_err());
    }

    /// The observability knobs layer like the resume knobs: JSON under
    /// CLI, `"none"` clears, and none of them are experiment identity
    /// (they never reach the label).
    #[test]
    fn obs_knobs_layer_and_none_clears() {
        let mut cfg = RunConfig::default();
        assert!(cfg.trace.is_none() && cfg.metrics_json.is_none() && cfg.run_index.is_none());
        assert!(!cfg.collect_metrics());
        cfg.apply_json(
            &Json::parse(
                r#"{"trace": "out/trace.json", "metrics_json": "out/metrics.json",
                    "run_index": "runs.jsonl"}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.trace.as_deref(), Some(std::path::Path::new("out/trace.json")));
        assert!(cfg.collect_metrics());
        // CLI wins over JSON; "none" clears a config-file value
        let args = Args::parse(
            ["--trace", "none", "--metrics-json", "m2.json", "--run-index", "none"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert!(cfg.trace.is_none());
        assert_eq!(cfg.metrics_json.as_deref(), Some(std::path::Path::new("m2.json")));
        assert!(cfg.run_index.is_none());
        assert!(cfg.collect_metrics(), "metrics sink still armed");
        // host-side observation, not experiment identity
        assert!(!cfg.label().contains("trace"), "{}", cfg.label());
        assert!(!cfg.label().contains("m2"), "{}", cfg.label());
    }

    /// `metrics_every` layers like the other obs knobs (JSON under CLI,
    /// `"none"` clears), validates positivity, and stays host-side (no
    /// label participation).
    #[test]
    fn metrics_every_layers_validates_and_clears() {
        let mut cfg = RunConfig::default();
        assert!(cfg.metrics_every.is_none());
        cfg.apply_json(&Json::parse(r#"{"metrics_every": 2.5}"#).unwrap()).unwrap();
        assert_eq!(cfg.metrics_every, Some(2.5));
        // CLI wins over JSON
        let args = Args::parse(["--metrics-every", "0.5"].iter().map(|s| s.to_string()), &[])
            .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.metrics_every, Some(0.5));
        // "none" clears
        let none = Args::parse(["--metrics-every", "none"].iter().map(|s| s.to_string()), &[])
            .unwrap();
        cfg.apply_args(&none).unwrap();
        assert!(cfg.metrics_every.is_none());
        // JSON string form accepts "none" too
        cfg.apply_json(&Json::parse(r#"{"metrics_every": "none"}"#).unwrap()).unwrap();
        assert!(cfg.metrics_every.is_none());
        // not experiment identity
        cfg.metrics_every = Some(1.0);
        assert!(!cfg.label().contains("metrics"), "{}", cfg.label());
        // zero/negative/garbage rejected
        for bad in ["0", "-1", "inf", "x"] {
            let args =
                Args::parse(["--metrics-every", bad].iter().map(|s| s.to_string()), &[]).unwrap();
            assert!(
                RunConfig::default().apply_args(&args).is_err(),
                "--metrics-every {bad} must be rejected"
            );
        }
    }

    /// `profile` layers like the other boolean obs knobs: JSON sets it,
    /// the CLI flag turns it on, and it stays host-side (no label).
    #[test]
    fn profile_knob_layers_and_stays_out_of_the_label() {
        let mut cfg = RunConfig::default();
        assert!(!cfg.profile, "off by default");
        cfg.apply_json(&Json::parse(r#"{"profile": true}"#).unwrap()).unwrap();
        assert!(cfg.profile);
        cfg.apply_json(&Json::parse(r#"{"profile": false}"#).unwrap()).unwrap();
        assert!(!cfg.profile);
        let args =
            Args::parse(["--profile"].iter().map(|s| s.to_string()), &["profile"]).unwrap();
        cfg.apply_args(&args).unwrap();
        assert!(cfg.profile, "CLI flag arms it");
        // host-side observation, not experiment identity
        assert!(!cfg.label().contains("profile"), "{}", cfg.label());
        // non-boolean values are rejected
        let mut bad = RunConfig::default();
        assert!(bad.apply_json(&Json::parse(r#"{"profile": 1}"#).unwrap()).is_err());
    }

    #[test]
    fn label_shows_sigma_mu_lambda() {
        let mut cfg = RunConfig::default();
        cfg.protocol = Protocol::NSoftsync { n: 30 };
        cfg.lambda = 30;
        cfg.mu = 4;
        let l = cfg.label();
        assert!(l.contains("μ=4") && l.contains("λ=30") && l.contains("30-softsync"), "{l}");
    }
}
