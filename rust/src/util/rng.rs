//! Deterministic PRNG (splitmix64 / PCG-XSH-RR style, no external deps).
//!
//! Every stochastic choice in the framework — mini-batch sampling, compute
//! jitter in the cluster simulator, property-test case generation — draws
//! from a [`Rng`] seeded explicitly, so whole experiment sweeps replay
//! bit-identically.

/// A 64-bit splitmix-based PRNG. Small, fast, and good enough for
/// sampling / jitter; not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zeros fixed point and decorrelate small seeds.
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Derive an independent stream (e.g. one per learner) from this seed.
    pub fn split(&self, stream: u64) -> Rng {
        let mut r = Rng::new(self.state ^ stream.wrapping_mul(0xA24B_AED4_963E_E407));
        r.next_u64();
        r
    }

    /// Raw generator state, for checkpointing a stream mid-run. Restoring
    /// via [`Rng::from_state`] resumes the exact sequence.
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild a generator from a checkpointed [`Rng::state`] value
    /// (no seed scrambling — the state is installed verbatim).
    pub fn from_state(state: u64) -> Rng {
        Rng { state }
    }

    pub fn next_u64(&mut self) -> u64 {
        // splitmix64
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`; unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with the given mean (used for message-jitter models).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        -mean * u.ln()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_checkpoint_resumes_exact_stream() {
        let mut r = Rng::new(99);
        for _ in 0..10 {
            r.next_u64();
        }
        let saved = r.state();
        let tail: Vec<u64> = (0..20).map(|_| r.next_u64()).collect();
        let mut restored = Rng::from_state(saved);
        let replay: Vec<u64> = (0..20).map(|_| restored.next_u64()).collect();
        assert_eq!(tail, replay);
    }

    #[test]
    fn split_streams_differ() {
        let base = Rng::new(7);
        let mut s1 = base.split(1);
        let mut s2 = base.split(2);
        let v1: Vec<u64> = (0..8).map(|_| s1.next_u64()).collect();
        let v2: Vec<u64> = (0..8).map(|_| s2.next_u64()).collect();
        assert_ne!(v1, v2);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let m = crate::util::mean(&xs);
        let s = crate::util::stddev(&xs);
        assert!(m.abs() < 0.05, "mean {m}");
        assert!((s - 1.0).abs() < 0.05, "std {s}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
