//! Minimal JSON reader/writer (no serde in the offline vendor set).
//!
//! Covers the full JSON grammar we produce/consume: the artifact manifest
//! written by `python/compile/aot.py`, experiment logs, and config files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization
/// is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    /// Non-negative integer as u64. Exact only below 2⁵³ (the f64 integer
    /// range) — fine for timestamps/update counts; 64-bit RNG states go
    /// through hex strings instead.
    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as u64)
    }

    /// Array of numbers as f32s (exact: every f32 round-trips through f64).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    pub fn as_u64_vec(&self) -> Result<Vec<u64>> {
        self.as_arr()?.iter().map(|v| v.as_u64()).collect()
    }

    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    // ---- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// f32 slice as a JSON array. `f32 → f64` is exact, and the writer
    /// emits shortest-round-trip decimals, so checkpoints restore the
    /// original bits.
    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_u64(xs: &[u64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- serialization -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // Surrogate pairs: only BMP needed for our data;
                            // map lone surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at b.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| {
            format!("bad number {text:?} at byte {start}")
        })?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected , or ] at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected , or }} at byte {}", self.pos),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("b").unwrap().get("c").unwrap().as_str().unwrap(),
            "hi\nthere"
        );
    }

    #[test]
    fn integers_stay_integers() {
        let v = Json::Num(24234.0);
        assert_eq!(v.to_string(), "24234");
    }

    #[test]
    fn f32_values_roundtrip_bit_exactly() {
        // Checkpoints depend on this: any f32 (weights, momentum state,
        // pending gradient sums) must survive write → parse unchanged.
        let vals: Vec<f32> = vec![
            0.1,
            -1.0 / 3.0,
            f32::MIN_POSITIVE,
            1.000_000_1,
            3.4e38,
            -0.0,
            5.877e-39, // subnormal
        ];
        let j = Json::arr_f32(&vals);
        let back = Json::parse(&j.to_string()).unwrap().as_f32_vec().unwrap();
        for (a, b) in vals.iter().zip(back.iter()) {
            assert_eq!(a.to_bits() & !0x8000_0000, b.to_bits() & !0x8000_0000, "{a} vs {b}");
            assert_eq!(a, b);
        }
    }

    #[test]
    fn u64_vec_roundtrip() {
        let vals = vec![0u64, 1, 42, 1 << 52];
        let j = Json::arr_u64(&vals);
        assert_eq!(Json::parse(&j.to_string()).unwrap().as_u64_vec().unwrap(), vals);
        assert!(Json::Num(-1.0).as_u64().is_err());
        assert!(Json::Num(1.5).as_u64().is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é\t""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é\t");
    }

    #[test]
    fn typed_accessors_error_cleanly() {
        let v = Json::parse(r#"{"n": 1.5}"#).unwrap();
        assert!(v.get("n").unwrap().as_usize().is_err());
        assert!(v.get("missing").is_err());
        assert!(v.as_str().is_err());
    }

    #[test]
    fn parses_python_manifest_style() {
        let src = "{\n \"cnn\": {\n  \"params\": 24234,\n  \"grad\": {\"4\": \"cnn_grad_b4.hlo.txt\"}\n }\n}";
        let v = Json::parse(src).unwrap();
        assert_eq!(
            v.get("cnn").unwrap().get("params").unwrap().as_usize().unwrap(),
            24234
        );
    }
}
