//! Seeded property-testing harness (proptest is not in the offline vendor
//! set). A `check` runs a property over many generated cases; on failure it
//! reports the seed and case index so the exact case replays.

use crate::util::rng::Rng;

/// Run `prop` over `cases` generated cases. `gen` maps a per-case RNG to a
/// case value; `prop` returns `Err(reason)` to fail. Panics with the seed
/// and case index on the first failure (no shrinking — cases are small and
/// fully determined by `(seed, index)`).
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base = Rng::new(seed);
    for i in 0..cases {
        let mut case_rng = base.split(i as u64);
        let case = gen(&mut case_rng);
        if let Err(reason) = prop(&case) {
            panic!(
                "property {name:?} failed at case {i} (seed {seed}):\n  case: {case:?}\n  reason: {reason}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(
            "below_in_range",
            1,
            200,
            |r| r.below(17),
            |&v| {
                if v < 17 {
                    Ok(())
                } else {
                    Err(format!("{v} >= 17"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property")]
    fn reports_failures() {
        check(
            "always_fails",
            2,
            5,
            |r| r.below(10),
            |_| Err("nope".to_string()),
        );
    }
}
