//! Tiny CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Typed getters parse on access and produce friendly errors.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, Vec<String>>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw arguments. `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminates option parsing.
                    args.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.options.entry(k.to_string()).or_default().push(v.to_string());
                } else if flag_names.contains(&body) {
                    args.flags.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{body} expects a value"))?;
                    args.options.entry(body.to_string()).or_default().push(v);
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.options
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad integer {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad integer {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad float {v:?}")),
        }
    }

    /// Comma-separated list of integers, e.g. `--mus 4,8,16`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| anyhow!("--{name}: bad integer {p:?}"))
                })
                .collect(),
        }
    }

    /// Error if any unknown option was passed (catches typos).
    pub fn ensure_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k}; known: {}", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], flags: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn mixed_styles() {
        let a = parse(
            &["train", "--mu", "4", "--lambda=30", "--verbose", "pos2"],
            &["verbose"],
        );
        assert_eq!(a.positional, vec!["train", "pos2"]);
        assert_eq!(a.usize_or("mu", 0).unwrap(), 4);
        assert_eq!(a.usize_or("lambda", 0).unwrap(), 30);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults_and_lists() {
        let a = parse(&["--mus", "4, 8,16"], &[]);
        assert_eq!(a.usize_list_or("mus", &[]).unwrap(), vec![4, 8, 16]);
        assert_eq!(a.usize_list_or("lambdas", &[1, 2]).unwrap(), vec![1, 2]);
        assert_eq!(a.f64_or("lr", 0.001).unwrap(), 0.001);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(vec!["--mu".to_string()], &[]).is_err());
    }

    #[test]
    fn unknown_option_detection() {
        let a = parse(&["--typo", "1"], &[]);
        assert!(a.ensure_known(&["mu"]).is_err());
        assert!(a.ensure_known(&["typo"]).is_ok());
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse(&["--mu", "4", "--", "--not-an-option"], &[]);
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }
}
