//! Small self-contained utilities.
//!
//! The offline build environment vendors only the `xla` crate's dependency
//! tree (see `.cargo/config.toml`), so the pieces one would normally pull
//! from crates.io live here: a counter-based PRNG ([`rng`]), a JSON
//! reader/writer ([`json`]) for the artifact manifest and experiment logs,
//! a tiny CLI argument parser ([`cli`]), and a seeded property-testing
//! harness ([`prop`]) used by the invariant test suites.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

/// Write `contents` to `path` atomically: write a sibling `.tmp` file,
/// then rename over the target (the same crash-safety pattern
/// [`crate::elastic::checkpoint`] uses). A crash mid-flush leaves the
/// previous file intact instead of a truncated, unloadable one.
pub fn write_atomic(path: &std::path::Path, contents: &str) -> anyhow::Result<()> {
    use anyhow::Context;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating directory {}", dir.display()))?;
        }
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, contents).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", path.display()))?;
    Ok(())
}

/// Format a `f64` duration in seconds as a human-readable string.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2}s", s)
    } else if s < 7200.0 {
        format!("{:.1}min", s / 60.0)
    } else {
        format!("{:.2}h", s / 3600.0)
    }
}

/// Format a byte count as a human-readable string (SI multiples).
pub fn fmt_bytes(b: f64) -> String {
    if b < 1e3 {
        format!("{b:.0}B")
    } else if b < 1e6 {
        format!("{:.1}kB", b / 1e3)
    } else if b < 1e9 {
        format!("{:.1}MB", b / 1e6)
    } else {
        format!("{:.2}GB", b / 1e9)
    }
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation over a sorted copy; `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(0.0000005), "0.5us");
        assert_eq!(fmt_secs(0.5), "500.00ms");
        assert_eq!(fmt_secs(2.0), "2.00s");
        assert_eq!(fmt_secs(180.0), "3.0min");
        assert_eq!(fmt_secs(7200.0), "2.00h");
    }

    #[test]
    fn fmt_bytes_ranges() {
        assert_eq!(fmt_bytes(512.0), "512B");
        assert_eq!(fmt_bytes(2.5e3), "2.5kB");
        assert_eq!(fmt_bytes(300.0e6), "300.0MB");
        assert_eq!(fmt_bytes(4.8e9), "4.80GB");
    }

    #[test]
    fn write_atomic_creates_parents_and_replaces() {
        let dir = std::env::temp_dir()
            .join(format!("rudra_util_atomic_{}", std::process::id()))
            .join("nested");
        let path = dir.join("out.json");
        write_atomic(&path, "first").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "first");
        write_atomic(&path, "second").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "second");
        assert!(!path.with_extension("tmp").exists(), "tmp file must not linger");
        std::fs::remove_dir_all(dir.parent().unwrap()).ok();
    }

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!(stddev(&xs) > 0.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
