//! Elastic membership, checkpoint/restore, and live μ·λ rescaling.
//!
//! The paper fixes the learner count λ for a whole run, which makes its
//! headline prescription — shrink the per-learner mini-batch μ as λ grows
//! so μ·λ stays constant — untestable under the realistic regime where
//! learners join, straggle, crash, and restart mid-training. Membership
//! churn is exactly where synchronization-protocol tradeoffs bite:
//! Chen et al., *Revisiting Distributed Synchronous SGD*, drop the
//! slowest learners via backup workers; Dutta et al., *Slow and Stale
//! Gradients Can Win the Race*, chart the error–runtime frontier under
//! stragglers. This subsystem makes the codebase elastic:
//!
//! * [`membership`] — a learner lifecycle ledger
//!   (Joining → Active → Suspect → Dead → Rejoined) with a validated
//!   transition graph, churn log, and recovery-time accounting, driven by
//!   a [`membership::ChurnSchedule`] (deterministic timed events and/or a
//!   random failure process realized by
//!   [`crate::netsim::failure::FailureInjector`]).
//! * [`checkpoint`] — serialize/restore the sharded server (θ, optimizer
//!   state, pending accumulators, shard timestamps, staleness history)
//!   and named RNG streams through the offline JSON util; restore
//!   re-validates the single-clock staleness invariant.
//! * [`rescaler`] — the μ·λ = const rule applied live: every membership
//!   change recomputes per-learner μ, the n-softsync collection threshold
//!   c = ⌊λ_active/n⌋ (via the checked quota that rejects λ_active < n),
//!   and the staleness-aware LR modulation factor through
//!   [`crate::params::lr`].
//!
//! Both engines drive it: the virtual-time engine takes deterministic
//! churn events from the netsim failure injector; the live engine detects
//! failures by heartbeat timeout on its mpsc channels. Hardsync survives
//! learner death through a membership-aware quorum (the quota flush in
//! [`crate::coordinator::shard::ShardedServer::set_active_lambda`]), and
//! the whole family of scenarios this unlocks — spot-instance preemption,
//! straggler eviction, warm restart — is swept by `benches/perf_elastic`.

pub mod checkpoint;
pub mod membership;
pub mod rescaler;

pub use checkpoint::Checkpoint;
pub use membership::{ChurnRecord, ChurnSchedule, Membership};
pub use rescaler::{RescalePolicy, RescaleRecord, Rescaler};
