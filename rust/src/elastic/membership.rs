//! Learner lifecycle ledger and churn schedules.
//!
//! The ledger tracks each learner slot through the elastic lifecycle
//!
//! ```text
//! Joining ──activate──▶ Active ──suspect──▶ Suspect ──kill──▶ Dead
//!                         │  ▲                 │               │
//!                         │  └───recover───────┘               │
//!                         └────────kill────────────────────────┤
//!                                                              ▼
//!                                          Rejoined ◀──rejoin──┘
//! ```
//!
//! `Rejoined` behaves exactly like `Active` (it exists so logs can tell a
//! warm-restarted learner from one that never failed) and may die again.
//! Learner *ids are stable across death*: a dead learner keeps its slot so
//! a rejoin reuses the same id against the server's fixed id space.
//!
//! Every transition is validated and appended to a churn log together with
//! the active-λ after the event; `recovery_secs` records death→rejoin
//! gaps (the recovery-time column in [`crate::stats`]).

use anyhow::{bail, Result};

/// Lifecycle phase of one learner slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Scheduled to join later (spot instance not yet up). Not counted in
    /// the active quorum.
    Joining,
    Active,
    /// Missed heartbeats but not yet evicted — still counted in the
    /// quorum (the live engine's grace period).
    Suspect,
    Dead,
    /// Back after a death (warm restart). Counted in the quorum.
    Rejoined,
}

impl Phase {
    /// Live phases count toward the active quorum λ_active.
    pub fn is_live(&self) -> bool {
        matches!(self, Phase::Active | Phase::Suspect | Phase::Rejoined)
    }

    pub fn label(&self) -> &'static str {
        match self {
            Phase::Joining => "joining",
            Phase::Active => "active",
            Phase::Suspect => "suspect",
            Phase::Dead => "dead",
            Phase::Rejoined => "rejoined",
        }
    }
}

/// What happened in one churn event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnKind {
    Join,
    Suspect,
    Recover,
    Kill,
    Rejoin,
}

impl ChurnKind {
    pub fn label(&self) -> &'static str {
        match self {
            ChurnKind::Join => "join",
            ChurnKind::Suspect => "suspect",
            ChurnKind::Recover => "recover",
            ChurnKind::Kill => "kill",
            ChurnKind::Rejoin => "rejoin",
        }
    }
}

/// One entry of the churn log.
#[derive(Debug, Clone)]
pub struct ChurnRecord {
    /// Event time — virtual seconds in the sim engine, wall seconds since
    /// run start in the live engine.
    pub at: f64,
    pub learner: usize,
    pub kind: ChurnKind,
    /// λ_active immediately after the event.
    pub active_after: usize,
}

/// The membership ledger: one phase per learner slot plus the churn log.
#[derive(Debug, Clone)]
pub struct Membership {
    phases: Vec<Phase>,
    /// Death time per slot (meaningful while Dead).
    died_at: Vec<f64>,
    /// Cached λ_active, maintained by every transition — the sim engine
    /// reads the quorum size on each gradient push, which must not cost
    /// an O(λ) scan at λ ≈ 4096.
    live: usize,
    pub log: Vec<ChurnRecord>,
    /// death → rejoin gaps, in event-time seconds.
    pub recovery_secs: Vec<f64>,
}

impl Membership {
    /// All `total` slots start Active (the classic fixed-λ run).
    pub fn new(total: usize) -> Membership {
        Membership {
            phases: vec![Phase::Active; total],
            died_at: vec![0.0; total],
            live: total,
            log: Vec::new(),
            recovery_secs: Vec::new(),
        }
    }

    /// `joining` slots start in `Joining` (deferred spot-instance joins);
    /// the rest start Active. Out-of-range ids are rejected.
    pub fn with_joining(total: usize, joining: &[usize]) -> Result<Membership> {
        let mut m = Membership::new(total);
        for &l in joining {
            if l >= total {
                bail!("joining learner id {l} out of range (λ slots = {total})");
            }
            if m.phases[l].is_live() {
                m.live -= 1;
            }
            m.phases[l] = Phase::Joining;
        }
        Ok(m)
    }

    pub fn total(&self) -> usize {
        self.phases.len()
    }

    pub fn phase(&self, l: usize) -> Phase {
        self.phases[l]
    }

    pub fn is_live(&self, l: usize) -> bool {
        self.phases[l].is_live()
    }

    /// λ_active: learners counted in the protocol quorum. O(1) — the
    /// count is maintained incrementally by the transition methods.
    pub fn active_count(&self) -> usize {
        debug_assert_eq!(
            self.live,
            self.phases.iter().filter(|p| p.is_live()).count(),
            "cached live count out of sync"
        );
        self.live
    }

    /// Ids currently counted in the quorum, ascending.
    pub fn live_ids(&self) -> Vec<usize> {
        (0..self.phases.len()).filter(|&l| self.phases[l].is_live()).collect()
    }

    fn record(&mut self, at: f64, learner: usize, kind: ChurnKind) {
        let active_after = self.active_count();
        self.log.push(ChurnRecord { at, learner, kind, active_after });
    }

    /// Joining → Active (the deferred learner came up).
    pub fn activate(&mut self, l: usize, at: f64) -> Result<()> {
        match self.phases[l] {
            Phase::Joining => {
                self.phases[l] = Phase::Active;
                self.live += 1;
                self.record(at, l, ChurnKind::Join);
                Ok(())
            }
            p => bail!("learner {l} cannot join from {:?}", p.label()),
        }
    }

    /// Active/Rejoined → Suspect (missed heartbeats; still in the quorum).
    pub fn suspect(&mut self, l: usize, at: f64) -> Result<()> {
        match self.phases[l] {
            Phase::Active | Phase::Rejoined => {
                self.phases[l] = Phase::Suspect;
                self.record(at, l, ChurnKind::Suspect);
                Ok(())
            }
            p => bail!("learner {l} cannot become suspect from {:?}", p.label()),
        }
    }

    /// Suspect → Active (a heartbeat arrived before eviction).
    pub fn recover(&mut self, l: usize, at: f64) -> Result<()> {
        match self.phases[l] {
            Phase::Suspect => {
                self.phases[l] = Phase::Active;
                self.record(at, l, ChurnKind::Recover);
                Ok(())
            }
            p => bail!("learner {l} cannot recover from {:?}", p.label()),
        }
    }

    /// Any live phase (or Joining) → Dead. Records the death time for the
    /// recovery-time accounting.
    pub fn kill(&mut self, l: usize, at: f64) -> Result<()> {
        match self.phases[l] {
            Phase::Active | Phase::Suspect | Phase::Rejoined | Phase::Joining => {
                if self.phases[l].is_live() {
                    self.live -= 1;
                }
                self.phases[l] = Phase::Dead;
                self.died_at[l] = at;
                self.record(at, l, ChurnKind::Kill);
                Ok(())
            }
            Phase::Dead => bail!("learner {l} is already dead"),
        }
    }

    /// Dead → Rejoined (warm restart). Returns the downtime and logs it as
    /// this learner's recovery time.
    pub fn rejoin(&mut self, l: usize, at: f64) -> Result<f64> {
        match self.phases[l] {
            Phase::Dead => {
                self.phases[l] = Phase::Rejoined;
                self.live += 1;
                let downtime = (at - self.died_at[l]).max(0.0);
                self.recovery_secs.push(downtime);
                self.record(at, l, ChurnKind::Rejoin);
                Ok(downtime)
            }
            p => bail!("learner {l} cannot rejoin from {:?}", p.label()),
        }
    }

    /// Serialize the full ledger (phases, death times, churn log,
    /// recovery gaps) for a mid-flight sim checkpoint. The cached live
    /// count is recomputed on restore rather than stored.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let log: Vec<Json> = self
            .log
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("at", Json::num(r.at)),
                    ("learner", Json::num(r.learner as f64)),
                    ("kind", Json::str(r.kind.label())),
                    ("active_after", Json::num(r.active_after as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            (
                "phases",
                Json::Arr(self.phases.iter().map(|p| Json::str(p.label())).collect()),
            ),
            ("died_at", Json::arr_f64(&self.died_at)),
            ("log", Json::Arr(log)),
            ("recovery_secs", Json::arr_f64(&self.recovery_secs)),
        ])
    }

    /// Rebuild a ledger from [`Membership::to_json`].
    pub fn from_json(v: &crate::util::json::Json) -> Result<Membership> {
        let phases = v
            .get("phases")?
            .as_arr()?
            .iter()
            .map(|p| phase_from_label(p.as_str()?))
            .collect::<Result<Vec<Phase>>>()?;
        let died_at = v.get("died_at")?.as_f64_vec()?;
        if died_at.len() != phases.len() {
            bail!("membership checkpoint: phases/died_at length mismatch");
        }
        let log = v
            .get("log")?
            .as_arr()?
            .iter()
            .map(|r| {
                Ok(ChurnRecord {
                    at: r.get("at")?.as_f64()?,
                    learner: r.get("learner")?.as_usize()?,
                    kind: churn_kind_from_label(r.get("kind")?.as_str()?)?,
                    active_after: r.get("active_after")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<ChurnRecord>>>()?;
        let recovery_secs = v.get("recovery_secs")?.as_f64_vec()?;
        let live = phases.iter().filter(|p| p.is_live()).count();
        Ok(Membership { phases, died_at, live, log, recovery_secs })
    }
}

fn phase_from_label(s: &str) -> Result<Phase> {
    Ok(match s {
        "joining" => Phase::Joining,
        "active" => Phase::Active,
        "suspect" => Phase::Suspect,
        "dead" => Phase::Dead,
        "rejoined" => Phase::Rejoined,
        other => bail!("unknown membership phase {other:?}"),
    })
}

fn churn_kind_from_label(s: &str) -> Result<ChurnKind> {
    Ok(match s {
        "join" => ChurnKind::Join,
        "suspect" => ChurnKind::Suspect,
        "recover" => ChurnKind::Recover,
        "kill" => ChurnKind::Kill,
        "rejoin" => ChurnKind::Rejoin,
        other => bail!("unknown churn kind {other:?}"),
    })
}

/// A scheduled churn action.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnAction {
    /// The learner comes up for the first time (it starts in `Joining`).
    Join,
    Kill,
    Rejoin,
}

/// One scheduled churn event (deterministic churn).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnEvent {
    /// Virtual-time seconds (sim engine).
    pub at: f64,
    pub learner: usize,
    pub action: ChurnAction,
}

/// A churn schedule: explicit timed events plus an optional random
/// kill/rejoin process (realized deterministically by
/// [`crate::netsim::failure::FailureInjector`]).
///
/// Parsed from the config DSL, a comma-separated list of
/// `kill:<id>@<secs>`, `rejoin:<id>@<secs>`, `join:<id>@<secs>`,
/// `rate:<kills-per-1000s>`, `downtime:<mean-secs>` — or `none`.
/// Example: `"kill:3@10,rejoin:3@25,rate:2,downtime:30"`.
#[derive(Debug, Clone, PartialEq)]
pub struct ChurnSchedule {
    /// Deterministic events, sorted by time.
    pub events: Vec<ChurnEvent>,
    /// Mean random kills per 1000 virtual seconds (0 = off).
    pub kill_rate_per_ksec: f64,
    /// Mean seconds a randomly killed learner stays dead before
    /// rejoining (0 = killed learners never rejoin).
    pub mean_downtime_secs: f64,
}

impl ChurnSchedule {
    pub fn none() -> ChurnSchedule {
        ChurnSchedule { events: Vec::new(), kill_rate_per_ksec: 0.0, mean_downtime_secs: 0.0 }
    }

    /// True when the schedule injects no churn at all.
    pub fn is_quiet(&self) -> bool {
        self.events.is_empty() && self.kill_rate_per_ksec == 0.0
    }

    /// Learner ids whose *first* scheduled action is `Join` — they start
    /// in the `Joining` phase instead of Active. A learner whose first
    /// event is a kill starts Active (it must be up to die); a later
    /// `join:` for it is then handled as a warm rejoin by the engine.
    /// Relies on `events` being time-sorted (parse sorts; hand-built
    /// schedules should too).
    pub fn joining_ids(&self) -> Vec<usize> {
        let mut first_seen = std::collections::BTreeSet::new();
        let mut out = Vec::new();
        for e in &self.events {
            if first_seen.insert(e.learner) && e.action == ChurnAction::Join {
                out.push(e.learner);
            }
        }
        out
    }

    /// Parse the config DSL (see the type docs).
    pub fn parse(s: &str) -> Result<ChurnSchedule> {
        let mut out = ChurnSchedule::none();
        let s = s.trim();
        if s.is_empty() || s.eq_ignore_ascii_case("none") {
            return Ok(out);
        }
        for tok in s.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let (head, rest) = tok
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("bad churn token {tok:?} (want kind:…)"))?;
            match head.to_ascii_lowercase().as_str() {
                "rate" => {
                    out.kill_rate_per_ksec = rest
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad churn rate {rest:?}"))?;
                    if out.kill_rate_per_ksec < 0.0 {
                        bail!("churn rate must be >= 0");
                    }
                }
                "downtime" => {
                    out.mean_downtime_secs = rest
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad churn downtime {rest:?}"))?;
                    if out.mean_downtime_secs < 0.0 {
                        bail!("churn downtime must be >= 0");
                    }
                }
                kind => {
                    let action = match kind {
                        "kill" => ChurnAction::Kill,
                        "rejoin" => ChurnAction::Rejoin,
                        "join" => ChurnAction::Join,
                        other => bail!(
                            "unknown churn action {other:?} (kill|rejoin|join|rate|downtime)"
                        ),
                    };
                    let (id, at) = rest.split_once('@').ok_or_else(|| {
                        anyhow::anyhow!("bad churn event {tok:?} (want {kind}:<id>@<secs>)")
                    })?;
                    let learner: usize = id
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad learner id {id:?} in {tok:?}"))?;
                    let at: f64 = at
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad event time {at:?} in {tok:?}"))?;
                    if at < 0.0 {
                        bail!("churn event time must be >= 0 in {tok:?}");
                    }
                    out.events.push(ChurnEvent { at, learner, action });
                }
            }
        }
        out.events.sort_by(|a, b| {
            a.at.partial_cmp(&b.at)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.learner.cmp(&b.learner))
        });
        Ok(out)
    }

    /// Canonical label (round-trips through [`ChurnSchedule::parse`]).
    pub fn label(&self) -> String {
        if self.is_quiet() {
            return "none".to_string();
        }
        let mut parts: Vec<String> = self
            .events
            .iter()
            .map(|e| {
                let kind = match e.action {
                    ChurnAction::Kill => "kill",
                    ChurnAction::Rejoin => "rejoin",
                    ChurnAction::Join => "join",
                };
                format!("{kind}:{}@{}", e.learner, e.at)
            })
            .collect();
        if self.kill_rate_per_ksec > 0.0 {
            parts.push(format!("rate:{}", self.kill_rate_per_ksec));
        }
        if self.mean_downtime_secs > 0.0 {
            parts.push(format!("downtime:{}", self.mean_downtime_secs));
        }
        parts.join(",")
    }

    /// Largest learner id referenced by a deterministic event, if any —
    /// config validation checks it against λ.
    pub fn max_learner_id(&self) -> Option<usize> {
        self.events.iter().map(|e| e.learner).max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_happy_path() {
        let mut m = Membership::with_joining(4, &[3]).unwrap();
        assert_eq!(m.active_count(), 3);
        assert!(!m.is_live(3));
        m.activate(3, 1.0).unwrap();
        assert_eq!(m.active_count(), 4);
        m.suspect(1, 2.0).unwrap();
        assert_eq!(m.active_count(), 4, "suspects stay in the quorum");
        m.recover(1, 2.5).unwrap();
        assert_eq!(m.phase(1), Phase::Active);
        m.kill(2, 3.0).unwrap();
        assert_eq!(m.active_count(), 3);
        assert_eq!(m.live_ids(), vec![0, 1, 3]);
        let downtime = m.rejoin(2, 7.5).unwrap();
        assert!((downtime - 4.5).abs() < 1e-12);
        assert_eq!(m.phase(2), Phase::Rejoined);
        assert_eq!(m.active_count(), 4);
        assert_eq!(m.recovery_secs, vec![4.5]);
        // a rejoined learner can die again
        m.kill(2, 9.0).unwrap();
        assert_eq!(m.active_count(), 3);
        let kinds: Vec<ChurnKind> = m.log.iter().map(|r| r.kind).collect();
        assert_eq!(
            kinds,
            vec![
                ChurnKind::Join,
                ChurnKind::Suspect,
                ChurnKind::Recover,
                ChurnKind::Kill,
                ChurnKind::Rejoin,
                ChurnKind::Kill,
            ]
        );
        assert_eq!(m.log[3].active_after, 3);
    }

    #[test]
    fn ledger_json_roundtrip_preserves_state() {
        let mut m = Membership::with_joining(4, &[3]).unwrap();
        m.activate(3, 1.0).unwrap();
        m.kill(2, 3.0).unwrap();
        m.rejoin(2, 7.5).unwrap();
        m.suspect(1, 8.0).unwrap();
        m.kill(0, 9.0).unwrap();
        let back = Membership::from_json(&m.to_json()).unwrap();
        assert_eq!(back.active_count(), m.active_count());
        for l in 0..4 {
            assert_eq!(back.phase(l), m.phase(l), "learner {l}");
        }
        assert_eq!(back.recovery_secs, m.recovery_secs);
        let kinds: Vec<ChurnKind> = back.log.iter().map(|r| r.kind).collect();
        let want: Vec<ChurnKind> = m.log.iter().map(|r| r.kind).collect();
        assert_eq!(kinds, want);
        // died_at survives: a post-restore rejoin computes the same gap
        // an uninterrupted run would have.
        let mut a = m.clone();
        let mut b = back;
        assert_eq!(a.rejoin(0, 12.25).unwrap(), b.rejoin(0, 12.25).unwrap());
        assert_eq!(a.recovery_secs, b.recovery_secs);
    }

    #[test]
    fn invalid_transitions_rejected() {
        let mut m = Membership::new(2);
        assert!(m.activate(0, 0.0).is_err(), "Active cannot re-join");
        assert!(m.rejoin(0, 0.0).is_err(), "only the dead rejoin");
        assert!(m.recover(0, 0.0).is_err(), "only suspects recover");
        m.kill(0, 1.0).unwrap();
        assert!(m.kill(0, 2.0).is_err(), "double kill");
        assert!(m.suspect(0, 2.0).is_err(), "dead learners have no heartbeat");
        assert!(Membership::with_joining(2, &[5]).is_err(), "id out of range");
    }

    #[test]
    fn schedule_parse_and_label_roundtrip() {
        let s = ChurnSchedule::parse("kill:3@10, rejoin:3@25.5, rate:2, downtime:30").unwrap();
        assert_eq!(s.events.len(), 2);
        assert_eq!(s.events[0].action, ChurnAction::Kill);
        assert_eq!(s.events[0].learner, 3);
        assert_eq!(s.events[1].at, 25.5);
        assert_eq!(s.kill_rate_per_ksec, 2.0);
        assert_eq!(s.mean_downtime_secs, 30.0);
        assert!(!s.is_quiet());
        assert_eq!(s.max_learner_id(), Some(3));
        assert_eq!(ChurnSchedule::parse(&s.label()).unwrap(), s);
        assert_eq!(ChurnSchedule::parse("none").unwrap(), ChurnSchedule::none());
        assert!(ChurnSchedule::parse("none").unwrap().is_quiet());
    }

    #[test]
    fn schedule_events_sorted_and_validated() {
        let s = ChurnSchedule::parse("kill:1@9,kill:0@3,join:2@1").unwrap();
        let times: Vec<f64> = s.events.iter().map(|e| e.at).collect();
        assert_eq!(times, vec![1.0, 3.0, 9.0]);
        assert_eq!(s.joining_ids(), vec![2]);
        // only learners whose FIRST action is Join start deferred: a
        // kill-then-join learner must start Active so the kill can land
        let s = ChurnSchedule::parse("kill:2@5,join:2@10,join:3@1").unwrap();
        assert_eq!(s.joining_ids(), vec![3]);
        assert!(ChurnSchedule::parse("explode:1@2").is_err());
        assert!(ChurnSchedule::parse("kill:x@2").is_err());
        assert!(ChurnSchedule::parse("kill:1@-2").is_err());
        assert!(ChurnSchedule::parse("rate:-1").is_err());
    }
}
