//! Checkpoint/restore for the parameter server and its RNG streams.
//!
//! A checkpoint captures everything a warm restart needs to continue the
//! *exact* fixed-seed trajectory: the sharded server's full state (θ
//! slices, optimizer state, pending accumulators, shard timestamps,
//! staleness history, LR policy — see
//! [`ShardedServer::to_json`]) plus any named RNG streams (engine jitter,
//! data samplers). Serialization uses the offline JSON util — no serde —
//! with f32/f64 values written as shortest-round-trip decimals (exact) and
//! 64-bit RNG states as hex strings (f64 JSON numbers only cover 2⁵³).
//!
//! Restore re-validates the single-clock staleness invariant (every shard
//! timestamp equal to the scalar clock) before handing back a server, so
//! a corrupt or hand-edited checkpoint cannot silently break the Eq. 2
//! analysis.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::shard::ShardedServer;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Checkpoint file format version.
pub const VERSION: u64 = 1;

/// Envelope key carrying the content checksum. Stored alongside the
/// document's own keys; stripped before the body is hashed, so the
/// checksum covers exactly the rest of the file.
const CHECKSUM_KEY: &str = "checksum";

/// FNV-1a over the serialized body — cheap, dependency-free, and enough
/// to catch a truncated or bit-rotted file (it is not an integrity MAC).
fn fnv1a(text: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Serialize an envelope with a content checksum over its body. The
/// in-memory payload stays checksum-free; the key exists only in the
/// file form, so nesting one document inside another never double-seals.
fn seal(payload: &Json) -> String {
    let body = payload.to_string();
    match payload {
        Json::Obj(m) => {
            let mut sealed = m.clone();
            sealed.insert(
                CHECKSUM_KEY.to_string(),
                Json::str(format!("{:016x}", fnv1a(&body))),
            );
            Json::Obj(sealed).to_string()
        }
        _ => body,
    }
}

/// Parse an envelope and verify its content checksum. A file without the
/// checksum key is the pre-seal format and is accepted as-is; a present
/// but mismatching checksum — or unparseable JSON, the signature of a
/// torn write — fails with a "truncated or corrupt" error naming `what`.
fn open_envelope(text: &str, what: &str) -> Result<Json> {
    let mut payload = Json::parse(text)
        .map_err(|e| anyhow::anyhow!("truncated or corrupt {what}: {e}"))?;
    if let Json::Obj(m) = &mut payload {
        if let Some(stored) = m.remove(CHECKSUM_KEY) {
            let stored = stored.as_str().context("checkpoint checksum must be a string")?;
            let computed = format!("{:016x}", fnv1a(&payload.to_string()));
            anyhow::ensure!(
                stored == computed,
                "truncated or corrupt {what}: checksum mismatch \
                 (stored {stored}, computed {computed})"
            );
        }
    }
    Ok(payload)
}

/// A captured checkpoint (an owned JSON document).
#[derive(Debug, Clone)]
pub struct Checkpoint {
    payload: Json,
}

/// What [`Checkpoint::restore`] hands back.
pub struct Restored {
    pub server: ShardedServer,
    /// Named RNG streams, resumed mid-sequence.
    pub rngs: BTreeMap<String, Rng>,
    /// Per-learner codec state (error-feedback residuals + quantizer RNG
    /// streams), when the captured run compressed gradients. `None` for
    /// `compress none` runs and for pre-comm checkpoints, both of which
    /// restore exactly as before.
    pub comm: Option<crate::comm::codec::CommState>,
    /// The adaptive-n controller mid-run (retuned n + epoch-window
    /// baselines), when the captured run had the controller on. `None`
    /// for open-loop runs and pre-PR-4 checkpoints.
    pub adaptive: Option<crate::straggler::adaptive::AdaptiveController>,
}

impl Checkpoint {
    /// Capture the server plus named RNG streams at the current instant.
    /// `label` is free-form provenance (run label, epoch, …).
    pub fn capture(label: &str, server: &ShardedServer, rngs: &[(&str, &Rng)]) -> Checkpoint {
        Self::capture_full(label, server, rngs, None, None)
    }

    /// [`Checkpoint::capture`] plus the optional run-state the elastic
    /// subsystems own: the communication codec bundle (error-feedback
    /// residuals, [`crate::comm::codec::CommState`]) and the adaptive-n
    /// controller. Both fields are omitted from the document when absent,
    /// so quiet runs produce byte-identical checkpoints to
    /// [`Checkpoint::capture`] and old checkpoints stay loadable.
    pub fn capture_full(
        label: &str,
        server: &ShardedServer,
        rngs: &[(&str, &Rng)],
        comm: Option<&crate::comm::codec::CommState>,
        adaptive: Option<&crate::straggler::adaptive::AdaptiveController>,
    ) -> Checkpoint {
        let rng_obj = Json::Obj(
            rngs.iter()
                .map(|(name, rng)| {
                    (name.to_string(), Json::str(format!("{:016x}", rng.state())))
                })
                .collect(),
        );
        let mut pairs = vec![
            ("version", Json::num(VERSION as f64)),
            ("label", Json::str(label)),
            ("server", server.to_json()),
            ("rngs", rng_obj),
        ];
        if let Some(c) = comm {
            pairs.push(("comm", c.to_json()));
        }
        if let Some(a) = adaptive {
            pairs.push(("adaptive", a.to_json()));
        }
        Checkpoint { payload: Json::obj(pairs) }
    }

    /// Rebuild the server and RNG streams. Fails on version mismatch, a
    /// malformed document, or a single-clock invariant violation.
    pub fn restore(&self) -> Result<Restored> {
        let version = self.payload.get("version")?.as_u64()?;
        anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
        let server = ShardedServer::from_json(self.payload.get("server")?)
            .context("restoring parameter server from checkpoint")?;
        let mut rngs = BTreeMap::new();
        for (name, v) in self.payload.get("rngs")?.as_obj()? {
            let state = u64::from_str_radix(v.as_str()?, 16)
                .with_context(|| format!("bad RNG state for stream {name:?}"))?;
            rngs.insert(name.clone(), Rng::from_state(state));
        }
        let comm = match self.payload.opt("comm") {
            Some(j) => Some(
                crate::comm::codec::CommState::from_json(j)
                    .context("restoring codec state from checkpoint")?,
            ),
            None => None,
        };
        let adaptive = match self.payload.opt("adaptive") {
            Some(j) => Some(
                crate::straggler::adaptive::AdaptiveController::from_json(j)
                    .context("restoring adaptive-n controller from checkpoint")?,
            ),
            None => None,
        };
        Ok(Restored { server, rngs, comm, adaptive })
    }

    /// Provenance label recorded at capture time.
    pub fn label(&self) -> Result<&str> {
        self.payload.get("label")?.as_str()
    }

    /// The update count the captured server had applied (handy for
    /// checkpoint-interval bookkeeping without a full restore).
    pub fn updates(&self) -> Result<u64> {
        self.payload.get("server")?.get("updates")?.as_u64()
    }

    /// File form: the payload sealed with a content checksum.
    pub fn to_json_string(&self) -> String {
        seal(&self.payload)
    }

    pub fn from_json_str(text: &str) -> Result<Checkpoint> {
        let payload = open_envelope(text, "checkpoint")?;
        // validate eagerly so a bad file fails at load, not first use
        let c = Checkpoint { payload };
        let version = c.payload.get("version")?.as_u64()?;
        anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
        Ok(c)
    }

    /// Write to disk (atomically: temp file + rename, so a crash mid-write
    /// never leaves a truncated checkpoint behind).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json_string())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Checkpoint::from_json_str(&text)
            .with_context(|| format!("loading {}", path.display()))
    }
}

/// Sim-checkpoint file format version (independent of the server format —
/// the server document is nested, with its own version field).
pub const SIM_VERSION: u64 = 1;

/// A mid-flight snapshot of the *whole* discrete-event simulation: the
/// server checkpoint plus the engine's live state (pending event queue,
/// in-flight messages, leaf caches, adv* broadcast history, fabric
/// contention horizons, membership ledger, RNG streams). The sim engine
/// builds and consumes the engine document; this type owns the envelope —
/// versioning, config fingerprinting, and atomic save/load — so a resume
/// against the wrong config or a truncated file fails up front instead of
/// silently diverging.
#[derive(Debug, Clone)]
pub struct SimCheckpoint {
    payload: Json,
}

impl SimCheckpoint {
    /// Assemble the envelope. `fingerprint` is the canonical label of the
    /// config the snapshot belongs to; restore requires an exact match.
    pub fn new(fingerprint: &str, server: Checkpoint, engine: Json) -> SimCheckpoint {
        SimCheckpoint {
            payload: Json::obj(vec![
                ("version", Json::num(SIM_VERSION as f64)),
                ("fingerprint", Json::str(fingerprint)),
                ("server_checkpoint", server.payload),
                ("engine", engine),
            ]),
        }
    }

    /// The config fingerprint recorded at capture time.
    pub fn fingerprint(&self) -> Result<&str> {
        self.payload.get("fingerprint")?.as_str()
    }

    /// Error unless the snapshot was captured under `expected` — resuming
    /// under a different (protocol, μ, λ, …) would replay nonsense.
    pub fn ensure_fingerprint(&self, expected: &str) -> Result<()> {
        let got = self.fingerprint()?;
        anyhow::ensure!(
            got == expected,
            "sim checkpoint belongs to config {got:?}, resuming under {expected:?}"
        );
        Ok(())
    }

    /// The nested server checkpoint (weights, optimizer, staleness, …).
    pub fn server_checkpoint(&self) -> Result<Checkpoint> {
        Ok(Checkpoint { payload: self.payload.get("server_checkpoint")?.clone() })
    }

    /// The engine-state document (the sim engine interprets it).
    pub fn engine_state(&self) -> Result<&Json> {
        self.payload.get("engine")
    }

    /// Events the captured run had processed (provenance, no restore).
    pub fn events_processed(&self) -> Result<u64> {
        self.payload.get("engine")?.get("events_processed")?.as_u64()
    }

    /// File form: the payload sealed with a content checksum.
    pub fn to_json_string(&self) -> String {
        seal(&self.payload)
    }

    pub fn from_json_str(text: &str) -> Result<SimCheckpoint> {
        let payload = open_envelope(text, "sim checkpoint")?;
        let c = SimCheckpoint { payload };
        let version = c.payload.get("version")?.as_u64()?;
        anyhow::ensure!(version == SIM_VERSION, "unsupported sim checkpoint version {version}");
        c.fingerprint()?;
        Ok(c)
    }

    /// Atomic write (temp file + rename), same contract as
    /// [`Checkpoint::save`].
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json_string())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<SimCheckpoint> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        SimCheckpoint::from_json_str(&text)
            .with_context(|| format!("loading {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::Protocol;
    use crate::coordinator::server::ServerConfig;
    use crate::params::lr::{LrPolicy, Modulation, Schedule};
    use crate::params::optimizer::{Optimizer, OptimizerKind};
    use crate::params::FlatVec;

    fn server(shards: usize) -> ShardedServer {
        let cfg = ServerConfig {
            protocol: Protocol::NSoftsync { n: 1 },
            mu: 4,
            lambda: 3,
            samples_per_epoch: 48,
            target_epochs: 4,
            shards,
        };
        let dim = 9;
        ShardedServer::new(
            cfg,
            FlatVec::from_vec((0..dim).map(|i| i as f32 * 0.31 - 1.2).collect()),
            Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, dim),
            LrPolicy::new(Schedule::constant(0.1), Modulation::Auto, 128),
        )
    }

    #[test]
    fn capture_restore_resumes_bit_identical_with_rngs() {
        let mut orig = server(3);
        let g = FlatVec::from_vec((0..9).map(|i| ((i % 4) as f32 - 1.5) * 0.2).collect());
        for i in 0..5 {
            let ts = orig.timestamp();
            orig.push_gradient(i % 3, &g, ts).unwrap();
        }
        let mut rng = Rng::new(17);
        for _ in 0..7 {
            rng.next_u64();
        }
        let ckpt = Checkpoint::capture("unit-test", &orig, &[("jitter", &rng)]);
        assert_eq!(ckpt.label().unwrap(), "unit-test");
        assert_eq!(ckpt.updates().unwrap(), orig.updates);

        // full text round trip, as the engine's save/load path would do
        let restored =
            Checkpoint::from_json_str(&ckpt.to_json_string()).unwrap().restore().unwrap();
        let mut rest_server = restored.server;
        let mut rest_rng = restored.rngs.get("jitter").cloned().unwrap();
        assert_eq!(rest_server.assemble_weights().data, orig.assemble_weights().data);
        // both servers and both rngs continue identically
        for i in 0..6 {
            let ts = orig.timestamp();
            orig.push_gradient(i % 3, &g, ts).unwrap();
            rest_server.push_gradient(i % 3, &g, ts).unwrap();
            assert_eq!(rng.next_u64(), rest_rng.next_u64());
        }
        assert_eq!(rest_server.assemble_weights().data, orig.assemble_weights().data);
        assert_eq!(rest_server.timestamp(), orig.timestamp());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("rudra_test_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.json");
        let orig = server(2);
        Checkpoint::capture("disk", &orig, &[]).save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.label().unwrap(), "disk");
        let r = back.restore().unwrap();
        assert_eq!(r.server.assemble_weights().data, orig.assemble_weights().data);
        assert!(r.rngs.is_empty());
    }

    #[test]
    fn capture_full_roundtrips_comm_and_adaptive_state() {
        use crate::comm::codec::{CodecSpec, CommState};
        use crate::straggler::adaptive::{AdaptiveController, AdaptiveSpec};
        let orig = server(2);
        // codec mid-run: residuals + quantizer streams in a known state
        let mut comm = CommState::build(CodecSpec::TopK { frac: 0.5 }, 3, 9, 13).unwrap();
        let g = FlatVec::from_vec((0..9).map(|i| (i as f32 - 4.0) * 0.3).collect());
        for l in 0..3 {
            comm.encode(l, &g);
        }
        // controller mid-run: retuned away from its config n
        let spec = AdaptiveSpec::parse("sigma:2").unwrap();
        let mut ctl = AdaptiveController::new(&spec, 8).unwrap();
        assert_eq!(ctl.epoch_tick(1, 10.0, 100, 800.0, 8), Some(4));
        let ckpt = Checkpoint::capture_full("full", &orig, &[], Some(&comm), Some(&ctl));
        let restored = Checkpoint::from_json_str(&ckpt.to_json_string())
            .unwrap()
            .restore()
            .unwrap();
        let mut back_comm = restored.comm.expect("comm state restored");
        assert_eq!(back_comm.residual_norms(), comm.residual_norms());
        let a = comm.encode(1, &g).into_dense();
        let b = back_comm.encode(1, &g).into_dense();
        assert_eq!(a.data, b.data, "codec continues bit-identically");
        let back_ctl = restored.adaptive.expect("controller restored");
        assert_eq!(back_ctl.n(), 4, "restored at the retuned n");
        // a plain capture carries neither, and old documents restore clean
        let plain = Checkpoint::capture("plain", &orig, &[]).restore().unwrap();
        assert!(plain.comm.is_none());
        assert!(plain.adaptive.is_none());
    }

    #[test]
    fn sim_checkpoint_envelope_roundtrips_and_guards() {
        let orig = server(2);
        let inner = Checkpoint::capture("sim-resume", &orig, &[]);
        let engine = Json::obj(vec![
            ("events_processed", Json::num(1234.0)),
            ("queue", Json::obj(vec![("now", Json::num(7.5))])),
        ]);
        let fp = "timing:imagenet/1-softsync/mu16/lambda30";
        let sim = SimCheckpoint::new(fp, inner, engine);
        let back = SimCheckpoint::from_json_str(&sim.to_json_string()).unwrap();
        assert_eq!(back.fingerprint().unwrap(), fp);
        assert_eq!(back.events_processed().unwrap(), 1234);
        back.ensure_fingerprint(fp).unwrap();
        assert!(
            back.ensure_fingerprint("timing:cifar10/hardsync/mu4/lambda8").is_err(),
            "resume under a different config must be rejected"
        );
        let r = back.server_checkpoint().unwrap().restore().unwrap();
        assert_eq!(r.server.assemble_weights().data, orig.assemble_weights().data);
        let now = back.engine_state().unwrap().get("queue").unwrap().get("now").unwrap();
        assert_eq!(now.as_f64().unwrap(), 7.5);

        let path = std::env::temp_dir().join("rudra_test_sim_ckpt").join("s.json");
        back.save(&path).unwrap();
        let loaded = SimCheckpoint::load(&path).unwrap();
        assert_eq!(loaded.to_json_string(), back.to_json_string());
        assert!(SimCheckpoint::from_json_str(r#"{"version": 99, "fingerprint": "x"}"#).is_err());
        assert!(SimCheckpoint::from_json_str("{").is_err());
    }

    #[test]
    fn checksum_detects_bit_flip_and_truncation() {
        let orig = server(2);
        let text = Checkpoint::capture("sealed", &orig, &[]).to_json_string();
        assert!(text.contains("\"checksum\""), "file form carries the seal");
        Checkpoint::from_json_str(&text).unwrap().restore().unwrap();
        // a single flipped character in the body fails with the clear error
        let flipped = text.replace("sealed", "zealed");
        assert_ne!(flipped, text);
        let err = Checkpoint::from_json_str(&flipped).unwrap_err().to_string();
        assert!(err.contains("corrupt checkpoint"), "{err}");
        assert!(err.contains("checksum mismatch"), "{err}");
        // a torn write (truncated file) is named as such, not a raw parse error
        let err = Checkpoint::from_json_str(&text[..text.len() - 10])
            .unwrap_err()
            .to_string();
        assert!(err.contains("truncated or corrupt checkpoint"), "{err}");
        // pre-seal files (no checksum key) still load
        let plain = Checkpoint::capture("old", &orig, &[]);
        let unsealed = {
            // what a pre-checksum build would have written: the raw payload
            let sealed = Json::parse(&plain.to_json_string()).unwrap();
            let Json::Obj(mut m) = sealed else { unreachable!() };
            m.remove("checksum");
            Json::Obj(m).to_string()
        };
        Checkpoint::from_json_str(&unsealed).unwrap().restore().unwrap();
    }

    #[test]
    fn sim_checksum_detects_bit_flip_and_truncation() {
        let orig = server(2);
        let inner = Checkpoint::capture("sim", &orig, &[]);
        let engine = Json::obj(vec![("events_processed", Json::num(7.0))]);
        let text = SimCheckpoint::new("fp:unit", inner, engine).to_json_string();
        SimCheckpoint::from_json_str(&text).unwrap();
        let err = SimCheckpoint::from_json_str(&text.replace("fp:unit", "fq:unit"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("corrupt sim checkpoint"), "{err}");
        assert!(err.contains("checksum mismatch"), "{err}");
        let err = SimCheckpoint::from_json_str(&text[..text.len() - 4])
            .unwrap_err()
            .to_string();
        assert!(err.contains("truncated or corrupt sim checkpoint"), "{err}");
    }

    #[test]
    fn version_and_garbage_rejected() {
        assert!(Checkpoint::from_json_str("{").is_err());
        assert!(Checkpoint::from_json_str(r#"{"version": 99}"#).is_err());
        let missing = Checkpoint::from_json_str(r#"{"version": 1}"#).unwrap();
        assert!(missing.restore().is_err(), "version ok but no server payload");
    }
}
