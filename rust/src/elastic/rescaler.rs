//! Live μ·λ = const rescaling — the paper's headline prescription kept
//! true under churn.
//!
//! The paper's central accuracy result is that the *aggregate* mini-batch
//! μ·λ, not the per-learner μ, is what governs convergence: adding
//! learners without shrinking μ trades accuracy for runtime (Table 2).
//! A static run fixes μ once; under elastic membership the product drifts
//! every time a learner dies or joins. The [`Rescaler`] pins it: on every
//! membership change it recomputes
//!
//! * the per-learner mini-batch μ = the integer closest to P/λ_active
//!   (P = the configured product μ₀·λ₀), so μ·λ_active stays within one
//!   mini-batch of P;
//! * the n-softsync collection threshold c = ⌊λ_active/n⌋ via the
//!   *checked* form that rejects λ_active < n
//!   ([`Protocol::try_gradients_per_update`]);
//! * the staleness-aware LR modulation factor through
//!   [`crate::params::lr`] (the Eq. 6 α₀/⟨σ⟩ rule re-evaluated at the new
//!   (μ, λ)).

use anyhow::Result;

use crate::coordinator::protocol::Protocol;
use crate::params::lr::LrPolicy;

/// Rescaling policy applied on membership changes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RescalePolicy {
    /// Keep the configured per-learner μ fixed (the paper's static runs):
    /// μ·λ drifts with churn.
    None,
    /// Hold μ·λ_active ≈ μ₀·λ₀ by recomputing μ on every change.
    MuLambdaConst,
}

impl RescalePolicy {
    pub fn parse(s: &str) -> Result<RescalePolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "none" | "off" | "fixed-mu" => Ok(RescalePolicy::None),
            "mulambda" | "mu-lambda" | "mulambda-const" | "const" => {
                Ok(RescalePolicy::MuLambdaConst)
            }
            other => anyhow::bail!("unknown rescale policy {other:?} (none|mulambda)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RescalePolicy::None => "none",
            RescalePolicy::MuLambdaConst => "mulambda",
        }
    }
}

/// One rescale decision (logged per membership change).
#[derive(Debug, Clone)]
pub struct RescaleRecord {
    /// Event time (virtual or wall seconds, engine-dependent).
    pub at: f64,
    pub active_lambda: usize,
    /// Per-learner μ in force after the event.
    pub mu: usize,
    /// Collection threshold c in force after the event.
    pub quota: usize,
    /// Staleness-aware LR modulation factor at the new (μ, λ).
    pub lr_factor: f64,
}

/// Applies a [`RescalePolicy`] against the run's configured μ₀·λ₀.
#[derive(Debug, Clone, Copy)]
pub struct Rescaler {
    policy: RescalePolicy,
    mu0: usize,
    /// Target product P = μ₀·λ₀.
    product: usize,
}

impl Rescaler {
    pub fn new(policy: RescalePolicy, mu0: usize, lambda0: usize) -> Rescaler {
        Rescaler { policy, mu0: mu0.max(1), product: mu0.max(1) * lambda0.max(1) }
    }

    pub fn policy(&self) -> RescalePolicy {
        self.policy
    }

    /// The pinned product P = μ₀·λ₀.
    pub fn target_product(&self) -> usize {
        self.product
    }

    /// Per-learner μ for `active` learners. Under `MuLambdaConst` this is
    /// whichever of ⌊P/λ⌋ and ⌈P/λ⌉ lands μ·λ closer to P (ties go to the
    /// smaller μ — erring toward fresher gradients), clamped to ≥ 1.
    pub fn mu_for(&self, active: usize) -> usize {
        match self.policy {
            RescalePolicy::None => self.mu0,
            RescalePolicy::MuLambdaConst => {
                let active = active.max(1);
                let lo = (self.product / active).max(1);
                let hi = lo + 1;
                let err = |mu: usize| (mu * active).abs_diff(self.product);
                if err(hi) < err(lo) {
                    hi
                } else {
                    lo
                }
            }
        }
    }

    /// The collection threshold for `active` learners, via the checked
    /// quota (rejects λ_active the protocol cannot serve).
    pub fn quota_for(&self, protocol: Protocol, active: usize) -> Result<usize> {
        protocol.try_gradients_per_update(active)
    }

    /// The staleness-aware LR modulation factor at the post-churn (μ, λ)
    /// — Eq. 6 re-evaluated live through [`crate::params::lr`].
    pub fn lr_factor(&self, lr: &LrPolicy, protocol: Protocol, active: usize) -> f64 {
        lr.factor(protocol, self.mu_for(active), active.max(1))
    }

    /// Build the log record for a membership change.
    pub fn record(
        &self,
        at: f64,
        lr: &LrPolicy,
        protocol: Protocol,
        active: usize,
    ) -> Result<RescaleRecord> {
        Ok(RescaleRecord {
            at,
            active_lambda: active,
            mu: self.mu_for(active),
            quota: self.quota_for(protocol, active)?,
            lr_factor: self.lr_factor(lr, protocol, active),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::lr::{Modulation, Schedule};

    #[test]
    fn policy_labels_roundtrip() {
        for p in [RescalePolicy::None, RescalePolicy::MuLambdaConst] {
            assert_eq!(RescalePolicy::parse(p.label()).unwrap(), p);
        }
        assert!(RescalePolicy::parse("sideways").is_err());
    }

    #[test]
    fn none_policy_keeps_mu_fixed() {
        let r = Rescaler::new(RescalePolicy::None, 8, 4);
        for active in [1usize, 3, 4, 9] {
            assert_eq!(r.mu_for(active), 8);
        }
    }

    #[test]
    fn mulambda_holds_product_within_one_minibatch() {
        // P = 64 with λ ranging over realistic churn: the invariant the
        // integration suite checks per churn event.
        let r = Rescaler::new(RescalePolicy::MuLambdaConst, 8, 8);
        assert_eq!(r.target_product(), 64);
        for active in 1usize..=10 {
            let mu = r.mu_for(active);
            let err = (mu * active).abs_diff(64);
            assert!(
                err <= mu,
                "λ={active}: μ={mu} gives |μλ−P| = {err} > one mini-batch"
            );
        }
        // exact divisions land exactly
        assert_eq!(r.mu_for(8), 8);
        assert_eq!(r.mu_for(4), 16);
        assert_eq!(r.mu_for(16), 4);
        // rounding picks the closer side: P=64, λ=5 → 13·5=65 beats 12·5=60
        assert_eq!(r.mu_for(5), 13);
        // μ never hits 0 even when λ exceeds P
        let tiny = Rescaler::new(RescalePolicy::MuLambdaConst, 1, 2);
        assert_eq!(tiny.mu_for(8), 1);
    }

    #[test]
    fn quota_uses_checked_form() {
        let r = Rescaler::new(RescalePolicy::MuLambdaConst, 4, 8);
        assert_eq!(r.quota_for(Protocol::NSoftsync { n: 2 }, 8).unwrap(), 4);
        assert!(r.quota_for(Protocol::NSoftsync { n: 2 }, 1).is_err());
        assert_eq!(r.quota_for(Protocol::Hardsync, 5).unwrap(), 5);
    }

    #[test]
    fn lr_factor_tracks_membership() {
        // Hardsync √-rule: α scales with √(λμ/B); under μλ=const the
        // factor is pinned too — that is the point of the rule.
        let lr = LrPolicy::new(Schedule::constant(0.1), Modulation::Auto, 64);
        let r = Rescaler::new(RescalePolicy::MuLambdaConst, 8, 8);
        let f8 = r.lr_factor(&lr, Protocol::Hardsync, 8);
        let f4 = r.lr_factor(&lr, Protocol::Hardsync, 4);
        assert!((f8 - 1.0).abs() < 1e-12, "64/64 → 1, got {f8}");
        assert!((f4 - 1.0).abs() < 1e-12, "μ rescaled to 16 keeps λμ = 64, got {f4}");
        // under a fixed-μ policy the factor drifts instead
        let fixed = Rescaler::new(RescalePolicy::None, 8, 8);
        let f4_fixed = fixed.lr_factor(&lr, Protocol::Hardsync, 4);
        assert!((f4_fixed - (32.0f64 / 64.0).sqrt()).abs() < 1e-12);
        // record() assembles the full log row
        let rec = r.record(1.5, &lr, Protocol::NSoftsync { n: 1 }, 4).unwrap();
        assert_eq!(rec.active_lambda, 4);
        assert_eq!(rec.mu, 16);
        assert_eq!(rec.quota, 4);
        assert!((rec.lr_factor - 1.0).abs() < 1e-12, "1-softsync: α₀/1");
    }
}
