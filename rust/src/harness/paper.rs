//! The paper's published numbers, transcribed for paper-vs-reproduced
//! reporting in the benches (Gupta, Zhang & Milthorpe, IJCAI 2017).

/// Table 1: communication overlap (%) in the adversarial scenario
/// (μ=4, 300 MB model, ~60 learners).
pub const TABLE1_OVERLAP: [(&str, f64); 3] =
    [("Rudra-base", 11.52), ("Rudra-adv", 56.75), ("Rudra-adv*", 99.56)];

/// §5.4 baseline: (σ,μ,λ) = (0,128,1) → 17.9% test error, 22 392 s for
/// 140 epochs.
pub const CIFAR_BASELINE_ERR: f64 = 17.9;
pub const CIFAR_BASELINE_SECS: f64 = 22_392.0;
pub const CIFAR_EPOCHS: usize = 140;

/// Table 2 rows: (σ, μ, λ, test error %, training time s), grouped by
/// μλ product.
pub const TABLE2: [(usize, usize, usize, f64, f64); 22] = [
    // μλ ≈ 128
    (1, 4, 30, 18.09, 1573.0),
    (30, 4, 30, 18.41, 2073.0),
    (18, 8, 18, 18.92, 2488.0),
    (10, 16, 10, 18.79, 3396.0),
    (4, 32, 4, 18.82, 7776.0),
    (2, 64, 2, 17.96, 13449.0),
    // μλ ≈ 256
    (1, 8, 30, 20.04, 1478.0),
    (30, 8, 30, 19.65, 1509.0),
    (18, 16, 18, 20.33, 2938.0),
    (10, 32, 10, 20.82, 3518.0),
    (4, 64, 4, 20.70, 6631.0),
    (2, 128, 2, 19.52, 11797.0),
    (1, 128, 2, 19.59, 11924.0),
    // μλ ≈ 512
    (1, 16, 30, 23.25, 1469.0),
    (30, 16, 30, 22.14, 1502.0),
    (18, 32, 18, 23.63, 2255.0),
    (10, 64, 10, 24.08, 2683.0),
    (4, 128, 4, 23.01, 7089.0),
    // μλ ≈ 1024
    (1, 32, 30, 27.16, 1299.0),
    (30, 32, 30, 27.27, 1420.0),
    (18, 64, 18, 28.31, 1713.0),
    (1, 128, 10, 29.83, 2551.0),
];

/// Table 3: the paper's top-5 (σ, μ, λ) configurations
/// (σ, μ, λ, protocol, test error %, training time s).
pub const TABLE3: [(usize, usize, usize, &str, f64, f64); 5] = [
    (1, 4, 30, "1-softsync", 18.09, 1573.0),
    (0, 8, 30, "Hardsync", 18.56, 1995.0),
    (30, 4, 30, "30-softsync", 18.41, 2073.0),
    (0, 4, 30, "Hardsync", 18.15, 2235.0),
    (18, 8, 18, "18-softsync", 18.92, 2488.0),
];

/// Table 4: ImageNet ladder — (config, arch, μ, λ, protocol,
/// top-1 err %, top-5 err %, minutes/epoch).
pub const TABLE4: [(&str, &str, usize, usize, &str, f64, f64, f64); 4] = [
    ("base-hardsync", "base", 16, 18, "hardsync", 44.35, 20.85, 330.0),
    ("base-softsync", "base", 16, 18, "1-softsync", 45.63, 22.08, 270.0),
    ("adv-softsync", "adv", 4, 54, "1-softsync", 46.09, 22.44, 212.0),
    ("adv*-softsync", "adv*", 4, 54, "1-softsync", 46.53, 23.38, 125.0),
];

/// §5.5: ImageNet baseline (μ=256, λ=1) trains at 54 h/epoch; μ=8, λ=54
/// gives >50% top-1 at ~96 min/epoch (the accuracy cliff).
pub const IMAGENET_BASELINE_HOURS_PER_EPOCH: f64 = 54.0;

/// Figure 6/7 grids.
pub const FIG67_LAMBDAS: [usize; 6] = [1, 2, 4, 10, 18, 30];
pub const FIG67_MUS: [usize; 6] = [4, 8, 16, 32, 64, 128];

/// Whether the full paper-scale grid was requested (env RUDRA_FULL=1);
/// otherwise benches run a reduced grid that preserves the comparisons.
pub fn full_grid() -> bool {
    std::env::var("RUDRA_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Reduced grid axes used when `full_grid()` is false.
pub fn grid_axes() -> (Vec<usize>, Vec<usize>, usize) {
    if full_grid() {
        (FIG67_MUS.to_vec(), FIG67_LAMBDAS.to_vec(), 30)
    } else {
        (vec![4, 32, 128], vec![1, 4, 30], 6)
    }
}

/// Standard bench banner explaining the measurement provenance.
pub fn banner(what: &str) {
    println!("=== {what} ===");
    println!(
        "[reproduction] accuracy: real SGD on the synthetic benchmark (see DESIGN.md §3);"
    );
    println!(
        "[reproduction] time: discrete-event P775 model, simulated seconds;"
    );
    println!(
        "[reproduction] grid: {} (RUDRA_FULL=1 for the paper's full grid)\n",
        if full_grid() { "FULL paper grid" } else { "reduced default" }
    );
}

#[cfg(test)]
mod tests {
    #[test]
    fn table2_groups_are_mulambda_constant() {
        // every row's μλ product sits within 30% of one of the paper's
        // four group anchors {128, 256, 512, 1024}
        for &(_, mu, lambda, _, _) in super::TABLE2.iter() {
            let p = (mu * lambda) as f64;
            let near = [128.0, 256.0, 512.0, 1024.0]
                .iter()
                .any(|g| (p / g).max(g / p) <= 1.3);
            assert!(near, "μλ = {p} not near a group anchor");
        }
    }

    #[test]
    fn table3_is_sorted_by_time() {
        let times: Vec<f64> = super::TABLE3.iter().map(|r| r.5).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]));
    }
}
