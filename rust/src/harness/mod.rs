//! Experiment harness: wires artifacts + data + engines into the
//! experiment grid of the paper's evaluation section.
//!
//! [`providers`] implements [`GradProvider`] over the AOT executables;
//! [`sweep`] runs (σ, μ, λ) grids through the virtual-time engine and
//! collects the quantities each table/figure reports.

pub mod paper;
pub mod providers;
pub mod sweep;

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::data::loader::{Corpus, ImageSet};
use crate::runtime::{EvalExec, GradExec, Manifest, Runtime};

/// Everything loaded once and shared across runs: the PJRT client,
/// compiled executables (one grad graph per μ), and the datasets.
pub struct Workspace {
    pub manifest: Manifest,
    pub runtime: Runtime,
    pub train: ImageSet,
    pub test: ImageSet,
    pub corpus: Corpus,
}

impl Workspace {
    /// Load from `artifacts/manifest.json` (or `$RUDRA_MANIFEST`).
    pub fn open_default() -> Result<Workspace> {
        let path = std::env::var("RUDRA_MANIFEST")
            .map(PathBuf::from)
            .unwrap_or_else(|_| Manifest::default_path());
        Self::open(&path)
    }

    pub fn open(manifest_path: &std::path::Path) -> Result<Workspace> {
        let manifest = Manifest::load(manifest_path)?;
        let runtime = Runtime::cpu()?;
        let train = ImageSet::load(&manifest.data.train).context("train set")?;
        let test = ImageSet::load(&manifest.data.test).context("test set")?;
        let corpus = Corpus::load(&manifest.data.corpus).context("corpus")?;
        Ok(Workspace { manifest, runtime, train, test, corpus })
    }

    /// Compile the CNN grad executable for mini-batch size μ.
    pub fn cnn_grad(&self, mu: usize) -> Result<GradExec> {
        let d = &self.manifest.data;
        self.runtime.load_grad(
            self.manifest.cnn.grad_path(mu)?,
            self.manifest.cnn.params,
            vec![mu, d.height, d.width, d.channels],
            vec![mu],
        )
    }

    /// Compile the CNN eval executable.
    pub fn cnn_eval(&self) -> Result<EvalExec> {
        let d = &self.manifest.data;
        let b = self.manifest.cnn.eval_batch;
        self.runtime.load_eval(
            &self.manifest.cnn.eval,
            self.manifest.cnn.params,
            vec![b, d.height, d.width, d.channels],
            vec![b],
            true,
        )
    }

    /// Initial CNN weights (deterministic, from the AOT step).
    pub fn cnn_init(&self) -> Result<crate::params::FlatVec> {
        let w = crate::params::FlatVec::load(&self.manifest.cnn.init)?;
        anyhow::ensure!(w.len() == self.manifest.cnn.params, "init length mismatch");
        Ok(w)
    }

    /// LM grad executable (the e2e example), if LM artifacts were built.
    pub fn lm_grad(&self) -> Result<GradExec> {
        let lm = self.lm()?;
        let b = self.manifest.lm_batch;
        let s = self.manifest.lm_seq;
        self.runtime
            .load_grad_tokens(lm.grad_path(b)?, lm.params, vec![b, s], vec![b, s])
    }

    pub fn lm_eval(&self) -> Result<EvalExec> {
        let lm = self.lm()?;
        let b = self.manifest.lm_batch;
        let s = self.manifest.lm_seq;
        self.runtime.load_eval(&lm.eval, lm.params, vec![b, s], vec![b, s], false)
    }

    pub fn lm_init(&self) -> Result<crate::params::FlatVec> {
        let lm = self.lm()?;
        let w = crate::params::FlatVec::load(&lm.init)?;
        anyhow::ensure!(w.len() == lm.params, "lm init length mismatch");
        Ok(w)
    }

    fn lm(&self) -> Result<&crate::runtime::artifacts::ModelArtifacts> {
        self.manifest
            .lm
            .as_ref()
            .context("LM artifacts not built (aot ran with --skip-lm)")
    }

    /// Cost model of the *actual* synthetic CNN workload, for sim timing.
    pub fn cnn_cost(&self) -> crate::netsim::cost::ModelCost {
        crate::netsim::cost::ModelCost::from_manifest(
            "synthetic-cnn",
            self.manifest.cnn.flops,
            self.manifest.cnn.params,
            self.manifest.data.train_n as u64,
        )
    }
}
