//! (σ, μ, λ) sweep runner: executes one grid point end to end and
//! collects everything the paper's tables/figures report.
//!
//! Grid points are *independent by construction* — each owns its seed,
//! its provider, and its RNG streams — so [`Sweep::run_grid`] executes
//! them on scoped worker threads bounded by the `jobs` knob
//! ([`run_indexed`]), returning results in grid order and bit-identical
//! to serial execution at any thread count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::engine_sim::{run_sim, SimConfig, SimResult};
use crate::coordinator::protocol::Protocol;
use crate::coordinator::tree::Arch;
use crate::harness::providers::CnnProvider;
use crate::harness::Workspace;
use crate::netsim::cluster::ClusterSpec;
use crate::netsim::cost::{LearnerCompute, ModelCost};
use crate::params::optimizer::Optimizer;
use crate::stats::ImageEvaluator;

/// One grid point's outcome.
#[derive(Debug, Clone)]
pub struct PointResult {
    pub protocol: Protocol,
    pub mu: usize,
    pub lambda: usize,
    /// Simulated training time (seconds) at P775 scale for the *paper's*
    /// workload geometry.
    pub paper_sim_seconds: f64,
    /// Simulated training time for the actual synthetic workload.
    pub sim_seconds: f64,
    pub test_error_pct: f64,
    pub test_loss: f64,
    pub train_loss: f64,
    pub avg_staleness: f64,
    pub max_staleness: u64,
    pub updates: u64,
    /// Events the numeric run's sim engine processed.
    pub events: u64,
    pub epochs: Vec<crate::coordinator::engine_sim::EpochStat>,
    /// Churn events observed (kills/rejoins/joins; 0 for static runs).
    pub churn_events: usize,
    /// Death → rejoin downtimes (virtual seconds).
    pub recovery_secs: Vec<f64>,
    /// λ_active at the end of the run.
    pub final_active_lambda: usize,
    /// Backup-sync: gradients dropped as too-slow (0 elsewhere).
    pub dropped_gradients: u64,
    /// Backup-sync: dropped-gradient count per learner slot.
    pub dropped_by_learner: Vec<u64>,
    /// Fraction of the run each learner spent computing.
    pub learner_utilization: Vec<f64>,
    /// Adaptive-n decisions, one per epoch (empty when the knob is off).
    pub adaptive: Vec<crate::straggler::adaptive::AdaptiveRecord>,
    /// Per-learner bytes pushed onto the wire (compressed sizes).
    pub comm_bytes_by_learner: Vec<f64>,
    /// Final per-learner error-feedback residual norms (empty when the
    /// `compress` knob is quiet).
    pub residual_norms: Vec<f64>,
    /// Bytes into / out of the root tier over the numeric run.
    pub root_bytes_in: f64,
    pub root_bytes_out: f64,
    /// Metrics snapshot of the numeric run ([`crate::obs::metrics`]
    /// schema); `None` unless a metrics sink was armed.
    pub metrics: Option<crate::util::json::Json>,
    /// Config fingerprint of the numeric run
    /// ([`crate::coordinator::engine_sim::SimEngine::config_fingerprint`])
    /// — the run-index comparability key.
    pub fingerprint: String,
    /// Host wall-clock the numeric run took (the run index records both
    /// time axes).
    pub wall_seconds: f64,
}

/// Host threads available for grid execution (the `jobs: 0` = auto
/// resolution target).
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a `jobs` knob value: `0` means auto (available parallelism),
/// anything else is taken literally (`1` = the serial path).
pub fn resolve_jobs(jobs: usize) -> usize {
    if jobs == 0 {
        default_jobs()
    } else {
        jobs
    }
}

/// Bench-side override of the auto default: `RUDRA_JOBS=<n>` pins the
/// worker count (0/unset/empty = auto). Lets CI and perf investigations
/// run grids serially without editing the bench.
///
/// Malformed values abort with a clear message instead of silently
/// falling back to auto — a typo'd CI variable must not quietly change
/// the benchmark shape.
pub fn env_jobs() -> usize {
    match parse_jobs(std::env::var("RUDRA_JOBS").ok().as_deref()) {
        Ok(jobs) => jobs,
        Err(e) => panic!("RUDRA_JOBS: {e}"),
    }
}

/// Strict parse for a worker-count env override: unset or empty means
/// auto (`0`); otherwise the value must be a non-negative integer.
pub fn parse_jobs(value: Option<&str>) -> Result<usize, String> {
    let Some(v) = value else { return Ok(0) };
    let t = v.trim();
    if t.is_empty() {
        return Ok(0);
    }
    t.parse::<usize>()
        .map_err(|_| format!("expected a non-negative integer, got {v:?}"))
}

/// Boolean env knob (`RUDRA_QUICK` and friends): accepts the standard
/// truthy/falsy spellings and aborts on anything else, so `=true` can
/// never silently mean *off*.
pub fn env_truthy(name: &str) -> bool {
    match parse_truthy(std::env::var(name).ok().as_deref()) {
        Ok(b) => b,
        Err(e) => panic!("{name}: {e}"),
    }
}

/// Strict parse for a boolean env value: unset/empty/`0`/`false`/`no`/
/// `off` are false, `1`/`true`/`yes`/`on` are true (case-insensitive);
/// anything else is an error naming the offending value.
pub fn parse_truthy(value: Option<&str>) -> Result<bool, String> {
    let Some(v) = value else { return Ok(false) };
    match v.trim().to_ascii_lowercase().as_str() {
        "" | "0" | "false" | "no" | "off" => Ok(false),
        "1" | "true" | "yes" | "on" => Ok(true),
        _ => Err(format!("expected a boolean (1/0/true/false/yes/no/on/off), got {v:?}")),
    }
}

/// Parallel point executor: run `f(0..n)` on up to `jobs` scoped worker
/// threads (`0` = auto, `1` = a plain serial loop) and return the results
/// **in index order**.
///
/// Safe for deterministic grids by construction: workers only decide
/// *which thread* computes each index (via an atomic work-stealing
/// counter), never the inputs, so `f(i)` — which must derive all of its
/// state from `i` — produces bit-identical output at any `jobs` value
/// (property-tested in `tests/integration_sweep.rs`). Error semantics:
/// the serial path stops at the first failing index; the parallel path
/// may compute later points before noticing, but still reports the error
/// of the *smallest* failing index.
///
/// A panicking `f` aborts the whole grid when the scope joins (same as
/// the serial loop), and the *original* panic is what propagates: the
/// results Mutex is poisoned by the first panic, so sibling workers and
/// the final collection recover the inner value instead of stacking
/// unrelated "poisoned lock" panics on top of the real one.
pub fn run_indexed<T, F>(jobs: usize, n: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    let jobs = resolve_jobs(jobs).min(n.max(1));
    if jobs <= 1 {
        return (0..n).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let done: Mutex<Vec<(usize, Result<T>)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| {
                let mut local: Vec<(usize, Result<T>)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i)));
                }
                // A sibling's panic poisons the lock; the data is still
                // intact, and dying here would bury the original panic
                // under ours. Recover and keep going — the scope join
                // re-raises the first panic.
                done.lock().unwrap_or_else(|e| e.into_inner()).extend(local);
            });
        }
    });
    let mut collected = done.into_inner().unwrap_or_else(|e| e.into_inner());
    collected.sort_by_key(|(i, _)| *i);
    debug_assert_eq!(collected.len(), n, "every grid index runs exactly once");
    let mut out = Vec::with_capacity(n);
    for (_, r) in collected {
        out.push(r?);
    }
    Ok(out)
}

/// Runs grid points with shared compiled executables.
pub struct Sweep<'a> {
    pub ws: &'a Workspace,
    pub epochs: usize,
    pub seed: u64,
    pub arch: Arch,
    /// Evaluate at every epoch boundary (needed for Fig 5/9 curves).
    pub eval_each_epoch: bool,
    /// Worker threads for grid execution (the `jobs` knob): `0` = auto
    /// (available parallelism), `1` = the serial path. Points own their
    /// seeds/providers/RNG streams, so any value is bit-identical.
    ///
    /// Caveat for the real-PJRT future: [`run_indexed`] shares `ws`
    /// across worker threads, which the offline `xla` stub permits
    /// (stateless). Real PJRT bindings are not `Sync` — swapping them in
    /// means per-thread clients or the live engine's compute-service
    /// pattern (see the ROADMAP `xla` item).
    pub jobs: usize,
    /// Collect a metrics snapshot per point even when the point's own
    /// config has no metrics sink (the `sweep` subcommand arms this when
    /// a run index is being written). Purely observational — grid results
    /// stay bit-identical either way.
    pub collect_metrics: bool,
    /// Per-point trace directory (`sweep --trace DIR`): each grid point's
    /// engine writes `<idx>-<label>.trace.json` here ([`point_file_name`])
    /// from its own worker thread — parallel points never share a file,
    /// so traces compose with any `jobs` value. `None` = no sweep tracing.
    pub trace_dir: Option<std::path::PathBuf>,
    /// Per-point metrics directory (`sweep --metrics-json DIR`): each
    /// point writes `<idx>-<label>.metrics.json` (atomic tmp + rename)
    /// from its worker thread. Setting it arms metrics collection for
    /// every point.
    pub metrics_dir: Option<std::path::PathBuf>,
    /// Time-series sampling cadence handed to every point
    /// (`--metrics-every`, virtual seconds); layered over each point
    /// config's own knob.
    pub metrics_every: Option<f64>,
    /// Critical-path profiling (`--profile`) for every point: each
    /// point's attribution rides inside its metrics snapshot. Layered
    /// over each point config's own knob; purely observational.
    pub profile: bool,
}

/// Filesystem-safe slug for one grid point's output files: the point's
/// label with anything outside `[A-Za-z0-9._-]` replaced by `_` (labels
/// contain `·`, `*`, `:` — fine on a terminal, hostile in a path).
///
/// The mapping is lossy — labels differing only in punctuation collide —
/// so grid output files are named through [`point_file_name`], which
/// prefixes the grid index to keep every point's files distinct.
pub fn point_slug(cfg: &RunConfig) -> String {
    cfg.label()
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') { c } else { '_' })
        .collect()
}

/// Output file name for one grid point: `"<idx>-<slug>.<kind>.json"`
/// inside a sweep (the zero-padded grid index keeps colliding slugs —
/// labels differing only in punctuation, or outright duplicate grid
/// entries — from overwriting each other), or `"<slug>.<kind>.json"` for
/// a standalone `run_point` with no grid position.
pub fn point_file_name(index: Option<usize>, cfg: &RunConfig, kind: &str) -> String {
    match index {
        Some(i) => format!("{i:04}-{}.{kind}.json", point_slug(cfg)),
        None => format!("{}.{kind}.json", point_slug(cfg)),
    }
}

impl<'a> Sweep<'a> {
    pub fn new(ws: &'a Workspace, epochs: usize) -> Sweep<'a> {
        Sweep {
            ws,
            epochs,
            seed: 42,
            arch: Arch::Base,
            eval_each_epoch: false,
            jobs: 0,
            collect_metrics: false,
            trace_dir: None,
            metrics_dir: None,
            metrics_every: None,
            profile: false,
        }
    }

    /// Train the synthetic benchmark at one (protocol, μ, λ) point with
    /// real gradients under simulated cluster timing, then overlay the
    /// paper-scale timing run (CIFAR10 geometry) for the time axis.
    pub fn run_point(&self, cfg: &RunConfig) -> Result<PointResult> {
        self.run_point_at(None, cfg)
    }

    /// [`Sweep::run_point`] with a grid position: per-point output files
    /// are index-prefixed ([`point_file_name`]) so colliding slugs never
    /// overwrite each other.
    fn run_point_at(&self, index: Option<usize>, cfg: &RunConfig) -> Result<PointResult> {
        let grad = self.ws.cnn_grad(cfg.mu)?;
        let eval = self.ws.cnn_eval()?;
        let mut provider =
            CnnProvider::new(&grad, &self.ws.train, cfg.mu, cfg.lambda, cfg.seed);
        let mut evaluator =
            ImageEvaluator::new(&eval, &self.ws.test, self.ws.manifest.cnn.eval_batch);

        let sim_cfg = SimConfig {
            protocol: cfg.protocol,
            arch: self.arch,
            mu: cfg.mu,
            lambda: cfg.lambda,
            epochs: self.epochs,
            seed: cfg.seed,
            cluster: ClusterSpec::p775(),
            compute: LearnerCompute::p775(),
            model: self.ws.cnn_cost(),
            shards: cfg.shards,
            eval_each_epoch: self.eval_each_epoch,
            max_updates: None,
            churn: cfg.churn.clone(),
            rescale: cfg.rescale,
            checkpoint_every_updates: cfg.checkpoint_every,
            hetero: cfg.hetero.clone(),
            adaptive: cfg.adaptive.clone(),
            compress: cfg.compress,
            stop_after_events: None,
            sim_checkpoint_path: None,
            trace: cfg.trace.is_some() || self.trace_dir.is_some(),
            trace_path: match &self.trace_dir {
                Some(dir) => Some(dir.join(point_file_name(index, cfg, "trace"))),
                None => cfg.trace.clone(),
            },
            collect_metrics: self.collect_metrics
                || self.metrics_dir.is_some()
                || cfg.collect_metrics(),
            metrics_every: self.metrics_every.or(cfg.metrics_every),
            profile: self.profile || cfg.profile,
            faults: cfg.faults.clone(),
        };
        let fingerprint =
            crate::coordinator::engine_sim::SimEngine::config_fingerprint(&sim_cfg);
        let started = std::time::Instant::now();
        let theta0 = warmstarted(self, cfg)?;
        let optimizer = Optimizer::new(cfg.optimizer, cfg.weight_decay, theta0.len());
        let result: SimResult = run_sim(
            &sim_cfg,
            theta0,
            optimizer,
            cfg.lr_policy(),
            Some(&mut provider),
            Some(&mut evaluator),
        )?;
        let wall_seconds = started.elapsed().as_secs_f64();
        let (test_loss, test_error_pct) = result.final_eval.unwrap_or((f64::NAN, f64::NAN));

        // Per-point sweep observability: the snapshot lands next to its
        // siblings, written from this worker thread (atomic tmp + rename)
        // so parallel points never contend on one file.
        if let (Some(dir), Some(m)) = (&self.metrics_dir, &result.metrics) {
            let path = dir.join(point_file_name(index, cfg, "metrics"));
            crate::util::write_atomic(&path, &m.to_string())?;
        }

        // Paper-scale timing overlay: same (protocol, μ, λ, arch) on the
        // CIFAR10 cost geometry, timing-only. Deliberately churn-free: the
        // overlay is the *paper's* static-λ reference time, and a churn
        // schedule calibrated (in seconds) to the short numeric run would
        // replay nonsensically — or kill λ_active below a softsync n —
        // over the 140-epoch horizon. Observation belongs to the numeric
        // run: the overlay must not overwrite its trace or snapshot.
        let paper_cfg = SimConfig {
            trace: false,
            trace_path: None,
            collect_metrics: false,
            metrics_every: None,
            profile: false,
            model: ModelCost::cifar10(),
            epochs: 140,
            eval_each_epoch: false,
            churn: crate::elastic::membership::ChurnSchedule::none(),
            rescale: crate::elastic::rescaler::RescalePolicy::None,
            checkpoint_every_updates: 0,
            hetero: crate::straggler::hetero::HeteroSpec::none(),
            adaptive: crate::straggler::adaptive::AdaptiveSpec::none(),
            faults: crate::netsim::faults::FaultSpec::none(),
            ..sim_cfg.clone()
        };
        let paper_time = run_sim(
            &paper_cfg,
            crate::params::FlatVec::zeros(0),
            Optimizer::new(crate::params::optimizer::OptimizerKind::Sgd, 0.0, 0),
            cfg.lr_policy(),
            None,
            None,
        )?;

        Ok(PointResult {
            protocol: cfg.protocol,
            mu: cfg.mu,
            lambda: cfg.lambda,
            paper_sim_seconds: paper_time.sim_seconds,
            sim_seconds: result.sim_seconds,
            test_error_pct,
            test_loss,
            train_loss: result.final_train_loss,
            avg_staleness: result.staleness.overall_avg(),
            max_staleness: result.staleness.max,
            updates: result.updates,
            events: result.events_processed,
            epochs: result.epochs,
            churn_events: result.churn.len(),
            recovery_secs: result.recovery_secs,
            final_active_lambda: result.final_active_lambda,
            dropped_gradients: result.dropped_gradients,
            dropped_by_learner: result.dropped_by_learner,
            learner_utilization: result.learner_utilization,
            adaptive: result.adaptive,
            comm_bytes_by_learner: result.comm_bytes_by_learner,
            residual_norms: result.residual_norms,
            root_bytes_in: result.root_bytes_in,
            root_bytes_out: result.root_bytes_out,
            metrics: result.metrics,
            fingerprint,
            wall_seconds,
        })
    }

    /// Run an explicit list of grid points, in order, on up to
    /// [`Sweep::jobs`] worker threads ([`run_indexed`]). Results are
    /// bit-identical to calling [`Sweep::run_point`] serially per config.
    pub fn run_points(&self, cfgs: &[RunConfig]) -> Result<Vec<PointResult>> {
        run_indexed(self.jobs, cfgs.len(), |i| self.run_point_at(Some(i), &cfgs[i]))
    }

    /// Run a (μ, λ) grid under one protocol family. For softsync, `n_of`
    /// maps λ to the splitting parameter (e.g. `|_| 1` for 1-softsync or
    /// `|l| l` for λ-softsync). Points execute on up to [`Sweep::jobs`]
    /// worker threads; the returned vector is always in grid order
    /// (λ-major, μ-minor — unchanged from the serial implementation).
    pub fn run_grid(
        &self,
        mus: &[usize],
        lambdas: &[usize],
        protocol_of: impl Fn(usize) -> Protocol,
    ) -> Result<Vec<PointResult>> {
        let mut cfgs = Vec::with_capacity(mus.len() * lambdas.len());
        for &lambda in lambdas {
            for &mu in mus {
                let mut cfg = RunConfig {
                    mu,
                    lambda,
                    protocol: protocol_of(lambda),
                    epochs: self.epochs,
                    seed: self.seed,
                    ..RunConfig::default()
                };
                cfg.arch = self.arch;
                cfgs.push(cfg);
            }
        }
        self.run_points(&cfgs)
    }
}

/// §5.5 warm-start: initialize from a model trained with hardsync for
/// `warmstart_epochs` before the protocol under test takes over.
fn warmstarted(sweep: &Sweep, cfg: &RunConfig) -> Result<crate::params::FlatVec> {
    let theta0 = sweep.ws.cnn_init()?;
    if cfg.warmstart_epochs == 0 {
        return Ok(theta0);
    }
    let grad = sweep.ws.cnn_grad(cfg.mu)?;
    let mut provider =
        CnnProvider::new(&grad, &sweep.ws.train, cfg.mu, cfg.lambda, cfg.seed ^ 0xDEAD);
    let warm_cfg = SimConfig {
        protocol: Protocol::Hardsync,
        arch: Arch::Base,
        mu: cfg.mu,
        lambda: cfg.lambda,
        epochs: cfg.warmstart_epochs,
        seed: cfg.seed,
        cluster: ClusterSpec::p775(),
        compute: LearnerCompute::p775(),
        model: sweep.ws.cnn_cost(),
        shards: cfg.shards,
        eval_each_epoch: false,
        max_updates: None,
        // The warm-start phase is a controlled prologue: no churn, no
        // rescaling, no checkpoints, homogeneous open-loop learners —
        // elasticity and straggler scenarios apply to the run under test
        // only.
        churn: crate::elastic::membership::ChurnSchedule::none(),
        rescale: crate::elastic::rescaler::RescalePolicy::None,
        checkpoint_every_updates: 0,
        hetero: crate::straggler::hetero::HeteroSpec::none(),
        adaptive: crate::straggler::adaptive::AdaptiveSpec::none(),
        compress: crate::comm::codec::CodecSpec::None,
        stop_after_events: None,
        sim_checkpoint_path: None,
        trace: false,
        trace_path: None,
        collect_metrics: false,
        metrics_every: None,
        profile: false,
        faults: crate::netsim::faults::FaultSpec::none(),
    };
    let optimizer = Optimizer::new(cfg.optimizer, cfg.weight_decay, theta0.len());
    let mut lr_cfg = cfg.clone();
    lr_cfg.modulation = crate::params::lr::Modulation::Auto;
    let r = run_sim(
        &warm_cfg,
        theta0,
        optimizer,
        lr_cfg.lr_policy(),
        Some(&mut provider),
        None,
    )?;
    Ok(r.theta.expect("numeric warmstart returns weights"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_indexed_returns_grid_order_at_any_job_count() {
        let want: Vec<usize> = (0..17).map(|i| i * i).collect();
        for jobs in [0usize, 1, 2, 4, 9, 64] {
            let out = run_indexed(jobs, 17, |i| Ok(i * i)).unwrap();
            assert_eq!(out, want, "jobs={jobs}");
        }
        assert!(run_indexed(4, 0, |_| Ok(0usize)).unwrap().is_empty());
    }

    #[test]
    fn run_indexed_reports_smallest_failing_index() {
        for jobs in [1usize, 2, 4] {
            let err = run_indexed(jobs, 12, |i| {
                if i == 3 || i == 9 {
                    anyhow::bail!("boom at {i}");
                }
                Ok(i)
            })
            .unwrap_err();
            assert!(err.to_string().contains("boom at 3"), "jobs={jobs}: {err}");
        }
    }

    // Regression (panic masking): a panicking grid point used to poison
    // the results Mutex, so sibling workers died on an `expect` and the
    // scope join surfaced *their* "poisoned lock" panic instead of the
    // original one. The executor now recovers the poisoned lock, and the
    // first panic is what propagates.
    #[test]
    fn run_indexed_propagates_the_original_panic() {
        for jobs in [2usize, 4] {
            let caught = std::panic::catch_unwind(|| {
                let _ = run_indexed(jobs, 8, |i| {
                    if i == 2 {
                        panic!("deliberate grid-point panic at {i}");
                    }
                    Ok(i)
                });
            })
            .expect_err("the grid-point panic must reach the caller");
            let msg = caught
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| caught.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_default();
            assert!(
                msg.contains("deliberate grid-point panic"),
                "jobs={jobs}: original panic buried, got {msg:?}"
            );
        }
    }

    #[test]
    fn point_slug_is_filesystem_safe_and_distinct_per_point() {
        let mut cfg = RunConfig::default();
        cfg.mu = 8;
        cfg.lambda = 30;
        let slug = point_slug(&cfg);
        assert!(!slug.is_empty());
        assert!(
            slug.chars()
                .all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')),
            "label chars must be path-safe: {slug:?}"
        );
        let mut other = cfg.clone();
        other.lambda = 4;
        assert_ne!(slug, point_slug(&other), "grid points get distinct files");
    }

    // Regression (silent overwrite): the slug sanitizer maps every char
    // outside [A-Za-z0-9._-] to '_', so labels differing only in
    // punctuation — or grids listing the same point twice — collided on
    // one `<slug>.trace.json` and the points overwrote each other's
    // files. Grid output names now carry the grid index.
    #[test]
    fn point_file_names_are_distinct_even_when_slugs_collide() {
        let mut cfg = RunConfig::default();
        cfg.mu = 4;
        cfg.lambda = 30;
        // the same config at two grid positions: identical slugs...
        assert_eq!(point_slug(&cfg), point_slug(&cfg.clone()));
        // ...but distinct files once the index participates
        let a = point_file_name(Some(3), &cfg, "trace");
        let b = point_file_name(Some(7), &cfg, "trace");
        assert_ne!(a, b, "colliding slugs must not share an output file");
        assert!(a.starts_with("0003-") && a.ends_with(".trace.json"), "{a:?}");
        assert!(b.starts_with("0007-") && b.ends_with(".trace.json"), "{b:?}");
        // standalone points (no grid position) keep the bare slug name
        let solo = point_file_name(None, &cfg, "metrics");
        assert_eq!(solo, format!("{}.metrics.json", point_slug(&cfg)));
    }

    #[test]
    fn jobs_resolution() {
        assert!(default_jobs() >= 1);
        assert_eq!(resolve_jobs(1), 1);
        assert_eq!(resolve_jobs(7), 7);
        assert_eq!(resolve_jobs(0), default_jobs());
    }

    // Regression (silent env misparse): `RUDRA_JOBS=4x` used to fall
    // back to auto without a word; malformed values are now hard errors.
    #[test]
    fn jobs_env_parse_is_strict() {
        assert_eq!(parse_jobs(None), Ok(0));
        assert_eq!(parse_jobs(Some("")), Ok(0));
        assert_eq!(parse_jobs(Some(" 4 ")), Ok(4));
        assert_eq!(parse_jobs(Some("0")), Ok(0));
        assert!(parse_jobs(Some("4x")).is_err());
        assert!(parse_jobs(Some("-1")).is_err());
        assert!(parse_jobs(Some("auto")).is_err());
    }

    // Regression (silent env misparse): `RUDRA_QUICK=true`/`yes` used to
    // mean *off* (only "1" counted). Standard truthy spellings now parse;
    // anything unrecognized is a hard error.
    #[test]
    fn truthy_env_parse_accepts_standard_forms() {
        for v in ["1", "true", "TRUE", "yes", "on", " Yes "] {
            assert_eq!(parse_truthy(Some(v)), Ok(true), "{v:?}");
        }
        for v in ["0", "false", "no", "off", ""] {
            assert_eq!(parse_truthy(Some(v)), Ok(false), "{v:?}");
        }
        assert_eq!(parse_truthy(None), Ok(false));
        assert!(parse_truthy(Some("quick")).is_err());
        assert!(parse_truthy(Some("2")).is_err());
    }
}
