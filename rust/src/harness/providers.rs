//! PJRT-backed [`GradProvider`] implementations: the learner's
//! getMinibatch + calcGradient over the AOT-compiled graphs.

use anyhow::Result;

use crate::coordinator::learner::GradProvider;
use crate::data::corpus::WindowSampler;
use crate::data::loader::{Corpus, ImageSet};
use crate::data::sampler::BatchSampler;
use crate::params::FlatVec;
use crate::runtime::GradExec;

/// CNN provider: per-learner random mini-batch sampling over the image
/// set + one grad-graph execution per compute.
pub struct CnnProvider<'a> {
    exec: &'a GradExec,
    samplers: Vec<BatchSampler<'a>>,
    /// Total gradient executions (diagnostics / perf accounting).
    pub steps: u64,
}

impl<'a> CnnProvider<'a> {
    pub fn new(exec: &'a GradExec, set: &'a ImageSet, mu: usize, lambda: usize, seed: u64) -> Self {
        let samplers =
            (0..lambda).map(|l| BatchSampler::new(set, mu, seed, l)).collect();
        CnnProvider { exec, samplers, steps: 0 }
    }
}

impl<'a> GradProvider for CnnProvider<'a> {
    fn compute(&mut self, learner: usize, theta: &FlatVec) -> Result<(FlatVec, f32)> {
        let batch = self.samplers[learner].next_batch();
        let out = self.exec.run_images(theta, &batch.images, &batch.labels)?;
        self.steps += 1;
        Ok((out.grads, out.loss))
    }

    fn n_params(&self) -> usize {
        self.exec.n_params
    }

    fn set_mu(&mut self, _mu: usize) -> bool {
        // The grad graph is AOT-compiled for one batch size (cnn_grad(μ));
        // resampling at a different μ would feed it a mis-shaped batch.
        // Decline: the rescaler's server-side accounting still applies.
        false
    }
}

/// LM provider: contiguous-window sampling over the byte corpus.
pub struct LmProvider<'a> {
    exec: &'a GradExec,
    samplers: Vec<WindowSampler<'a>>,
    pub steps: u64,
}

impl<'a> LmProvider<'a> {
    pub fn new(
        exec: &'a GradExec,
        corpus: &'a Corpus,
        batch: usize,
        seq: usize,
        lambda: usize,
        seed: u64,
    ) -> Self {
        let samplers = (0..lambda)
            .map(|l| WindowSampler::new(corpus, batch, seq, seed, l))
            .collect();
        LmProvider { exec, samplers, steps: 0 }
    }
}

impl<'a> GradProvider for LmProvider<'a> {
    fn compute(&mut self, learner: usize, theta: &FlatVec) -> Result<(FlatVec, f32)> {
        let batch = self.samplers[learner].next_batch();
        let out = self.exec.run_tokens(theta, &batch.tokens, &batch.targets)?;
        self.steps += 1;
        Ok((out.grads, out.loss))
    }

    fn n_params(&self) -> usize {
        self.exec.n_params
    }
}

// ---------------------------------------------------------------------------
// Compute service for the live engine
// ---------------------------------------------------------------------------

use std::sync::mpsc;
use std::sync::Arc;

/// A gradient request to the compute service.
pub struct ComputeReq {
    pub theta: Vec<f32>,
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub reply: mpsc::Sender<Result<(Vec<f32>, f32)>>,
}

/// PJRT executables are not `Send` (the client wraps a raw PJRT handle),
/// so the live engine routes gradient work through one dedicated service
/// thread that *owns* the client — mirroring the paper's design where the
/// learner process has dedicated compute/communication threads.
pub struct ComputeService {
    req_tx: Option<mpsc::Sender<ComputeReq>>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
    pub n_params: usize,
}

impl ComputeService {
    /// Start the service for the CNN grad graph at mini-batch size μ.
    pub fn start_cnn(manifest_path: std::path::PathBuf, mu: usize) -> Result<ComputeService> {
        // Validate eagerly on the caller's thread for a clean error.
        let m = crate::runtime::Manifest::load(&manifest_path)?;
        let n_params = m.cnn.params;
        let (tx, rx) = mpsc::channel::<ComputeReq>();
        let handle = std::thread::spawn(move || -> Result<()> {
            let ws = crate::harness::Workspace::open(&manifest_path)?;
            let exec = ws.cnn_grad(mu)?;
            for req in rx {
                let theta = FlatVec::from_vec(req.theta);
                let res = exec
                    .run_images(&theta, &req.images, &req.labels)
                    .map(|o| (o.grads.data, o.loss));
                let _ = req.reply.send(res);
            }
            Ok(())
        });
        Ok(ComputeService { req_tx: Some(tx), handle: Some(handle), n_params })
    }

    pub fn client(&self) -> mpsc::Sender<ComputeReq> {
        self.req_tx.as_ref().expect("service running").clone()
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        drop(self.req_tx.take()); // close the channel so the thread exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// `Send` provider for the live engine: samples its own mini-batches and
/// delegates gradient execution to the [`ComputeService`].
pub struct ServiceProvider {
    tx: mpsc::Sender<ComputeReq>,
    set: Arc<ImageSet>,
    rng: crate::util::rng::Rng,
    mu: usize,
    n_params: usize,
}

impl ServiceProvider {
    pub fn new(
        service: &ComputeService,
        set: Arc<ImageSet>,
        mu: usize,
        seed: u64,
        learner: usize,
    ) -> ServiceProvider {
        ServiceProvider {
            tx: service.client(),
            rng: crate::util::rng::Rng::new(seed).split(learner as u64),
            set,
            mu,
            n_params: service.n_params,
        }
    }

    fn sample(&mut self) -> (Vec<f32>, Vec<i32>) {
        let len = self.set.sample_len();
        let mut images = vec![0.0f32; self.mu * len];
        let mut labels = vec![0i32; self.mu];
        for b in 0..self.mu {
            let i = self.rng.usize_below(self.set.n);
            self.set.fill_sample(i, &mut images[b * len..(b + 1) * len]);
            labels[b] = self.set.labels[i];
        }
        (images, labels)
    }
}

impl GradProvider for ServiceProvider {
    fn compute(&mut self, _learner: usize, theta: &FlatVec) -> Result<(FlatVec, f32)> {
        let (images, labels) = self.sample();
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(ComputeReq { theta: theta.data.clone(), images, labels, reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("compute service terminated"))?;
        let (grads, loss) = reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("compute service dropped reply"))??;
        Ok((FlatVec::from_vec(grads), loss))
    }

    fn n_params(&self) -> usize {
        self.n_params
    }

    fn set_mu(&mut self, _mu: usize) -> bool {
        // Like CnnProvider: the compute service's grad graph is compiled
        // for the spawn-time μ, so a live retune must be declined.
        false
    }
}
