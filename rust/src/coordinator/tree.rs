//! Rudra-adv / Rudra-adv* topologies (§3.3).
//!
//! * **Rudra-adv**: a parameter-server *group* forming a tree. Leaf PS
//!   nodes are co-located with the learners they serve; each non-root
//!   node averages its children's gradients and relays the average to its
//!   parent; the root applies the weight update and weights flow back
//!   down the tree. Unlike an independently-clocked sharded PS
//!   (DistBelief/Adam), all weights share one timestamp — which is what
//!   keeps the staleness analysis tractable (the paper's key
//!   architectural distinction).
//! * **Rudra-adv\***: additionally broadcasts weights down a tree formed
//!   *within the learners* and decouples push/pull into background
//!   communication threads (see [`crate::coordinator::buffer`]).
//!
//! The **root tier** may itself be sharded
//! ([`crate::coordinator::shard`]): `root_shards` contiguous parameter
//! shards, each with its own network endpoint and applyUpdate loop. The
//! shards advance in lockstep with one scalar timestamp, so — unlike
//! DistBelief — sharding here relieves the §3.3 bottleneck *without*
//! giving up the single-clock staleness analysis.

/// System architecture selector (Tables 1 and 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    Base,
    Adv,
    AdvStar,
}

impl Arch {
    pub fn parse(s: &str) -> anyhow::Result<Arch> {
        match s.trim().to_ascii_lowercase().as_str() {
            "base" | "rudra-base" => Ok(Arch::Base),
            "adv" | "rudra-adv" => Ok(Arch::Adv),
            "adv*" | "advstar" | "rudra-adv*" => Ok(Arch::AdvStar),
            other => anyhow::bail!("unknown architecture {other:?} (base | adv | adv*)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Arch::Base => "Rudra-base",
            Arch::Adv => "Rudra-adv",
            Arch::AdvStar => "Rudra-adv*",
        }
    }
}

/// The aggregation tree: learners are grouped under leaf PS nodes of
/// fan-in `fanout` (one leaf per compute node in the paper: leaves are
/// co-located with their learners), topped by a root tier of
/// `root_shards` parameter shards.
#[derive(Debug, Clone)]
pub struct PsTree {
    pub lambda: usize,
    pub fanout: usize,
    /// leaf index for each learner.
    pub leaf_of: Vec<usize>,
    pub n_leaves: usize,
    /// Parameter shards at the root tier (1 = the paper's flat root).
    pub root_shards: usize,
}

impl PsTree {
    pub fn new(lambda: usize, fanout: usize) -> PsTree {
        Self::with_shards(lambda, fanout, 1)
    }

    /// Tree with a sharded root tier: pushes/pulls stripe across
    /// `root_shards` independent root endpoints.
    pub fn with_shards(lambda: usize, fanout: usize, root_shards: usize) -> PsTree {
        assert!(fanout >= 1);
        let n_leaves = lambda.div_ceil(fanout);
        let leaf_of = (0..lambda).map(|l| l / fanout).collect();
        PsTree { lambda, fanout, leaf_of, n_leaves, root_shards: root_shards.max(1) }
    }

    /// Fabric endpoint indices of the root shards, given the index of the
    /// first root endpoint (engines place them after the compute nodes).
    pub fn shard_endpoints(&self, first: usize) -> Vec<usize> {
        (first..first + self.root_shards).collect()
    }

    /// Learners under leaf `leaf`.
    pub fn members(&self, leaf: usize) -> impl Iterator<Item = usize> + '_ {
        (0..self.lambda).filter(move |&l| self.leaf_of[l] == leaf)
    }

    /// Number of messages hitting the root per full gradient wave —
    /// the contention-reduction factor vs. Rudra-base (λ → n_leaves).
    pub fn root_fan_in(&self) -> usize {
        self.n_leaves
    }

    /// The Adv\* weight-broadcast topology implied by this tree: one
    /// subtree per root shard, each streaming its θ slice
    /// ([`crate::comm::stripe`]). With a flat root (`root_shards` = 1)
    /// the plan reproduces the classic single-tree broadcast exactly.
    pub fn broadcast_plan(&self) -> crate::comm::stripe::StripePlan {
        crate::comm::stripe::StripePlan::new(self.lambda, self.fanout, self.root_shards)
    }
}

/// Leaf-level partial aggregation: averages `k` gradients then relays.
/// Numerically: root averaging of equal-weight leaf averages equals the
/// flat average when all leaves carry the same member count; the general
/// case is handled by weighting each relay by its member count.
#[derive(Debug)]
pub struct LeafAggregator {
    sum: crate::params::FlatVec,
    count: usize,
    clock: Vec<u64>,
}

impl LeafAggregator {
    pub fn new(n_params: usize) -> LeafAggregator {
        LeafAggregator { sum: crate::params::FlatVec::zeros(n_params), count: 0, clock: Vec::new() }
    }

    pub fn push(&mut self, grad: &crate::params::FlatVec, grad_ts: u64) {
        self.sum.add_assign(grad);
        self.count += 1;
        self.clock.push(grad_ts);
    }

    pub fn pending(&self) -> usize {
        self.count
    }

    /// Drain into (sum, count, clock) — the relay message carries the
    /// *sum* and member count so the root can average exactly.
    pub fn take(&mut self) -> (crate::params::FlatVec, usize, Vec<u64>) {
        let n = self.sum.len();
        let sum = std::mem::replace(&mut self.sum, crate::params::FlatVec::zeros(n));
        let count = std::mem::take(&mut self.count);
        let clock = std::mem::take(&mut self.clock);
        (sum, count, clock)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::FlatVec;

    #[test]
    fn sharded_root_tier() {
        let t = PsTree::new(8, 4);
        assert_eq!(t.root_shards, 1);
        assert_eq!(t.shard_endpoints(2), vec![2]);
        let t = PsTree::with_shards(8, 4, 4);
        assert_eq!(t.root_shards, 4);
        assert_eq!(t.shard_endpoints(2), vec![2, 3, 4, 5]);
        // leaf routing is independent of the root tier
        assert_eq!(t.n_leaves, 2);
        // zero clamps to the flat root
        assert_eq!(PsTree::with_shards(4, 2, 0).root_shards, 1);
    }

    #[test]
    fn tree_shapes() {
        let t = PsTree::new(54, 8);
        assert_eq!(t.n_leaves, 7);
        assert_eq!(t.root_fan_in(), 7);
        assert_eq!(t.leaf_of[0], 0);
        assert_eq!(t.leaf_of[53], 6);
        assert_eq!(t.members(0).count(), 8);
        assert_eq!(t.members(6).count(), 6); // remainder leaf
        let total: usize = (0..t.n_leaves).map(|l| t.members(l).count()).sum();
        assert_eq!(total, 54);
    }

    #[test]
    fn exact_average_via_weighted_relay() {
        // 3 learners, fanout 2 → leaves {0,1}, {2}. Root average of the
        // relayed (sum, count) pairs must equal the flat average.
        let t = PsTree::new(3, 2);
        let grads = [
            FlatVec::from_vec(vec![3.0]),
            FlatVec::from_vec(vec![6.0]),
            FlatVec::from_vec(vec![9.0]),
        ];
        let mut leaves: Vec<LeafAggregator> =
            (0..t.n_leaves).map(|_| LeafAggregator::new(1)).collect();
        for (l, g) in grads.iter().enumerate() {
            leaves[t.leaf_of[l]].push(g, 0);
        }
        let mut total = FlatVec::zeros(1);
        let mut count = 0;
        for leaf in leaves.iter_mut() {
            let (sum, c, _) = leaf.take();
            total.add_assign(&sum);
            count += c;
        }
        total.scale(1.0 / count as f32);
        assert_eq!(total.data, vec![6.0]); // (3+6+9)/3
    }

    #[test]
    fn broadcast_plan_mirrors_the_root_tier() {
        let flat = PsTree::new(32, 8).broadcast_plan();
        assert_eq!(flat.shards, 1);
        assert_eq!(flat.slice_bytes(300.0e6), 300.0e6);
        let striped = PsTree::with_shards(32, 8, 4).broadcast_plan();
        assert_eq!(striped.shards, 4);
        assert_eq!(striped.slice_bytes(300.0e6), 75.0e6);
        assert_eq!(striped.lambda, 32);
        assert_eq!(striped.fanout, 8);
    }

    #[test]
    fn arch_parse() {
        assert_eq!(Arch::parse("base").unwrap(), Arch::Base);
        assert_eq!(Arch::parse("Rudra-adv").unwrap(), Arch::Adv);
        assert_eq!(Arch::parse("adv*").unwrap(), Arch::AdvStar);
        assert!(Arch::parse("mesh").is_err());
    }
}
