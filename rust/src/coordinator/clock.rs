//! Timestamps, vector clocks, and staleness accounting (§3.1).
//!
//! The paper's quantification technique (contribution #1): weights carry a
//! scalar timestamp `ts_i` incremented by every update; a gradient
//! inherits the timestamp of the weights it was computed from; the
//! staleness of a gradient pushed while the server is at `ts_j` is
//! σ = j − i. The set of gradient timestamps that triggers update i forms
//! a vector clock ⟨ts_i1 … ts_in⟩, and the *average* staleness of that
//! update is ⟨σ⟩ = (i − 1) − mean(i1 … in)   (Eq. 2).

/// Scalar weight timestamp.
pub type Timestamp = u64;

/// One weight update's provenance: which gradient timestamps were folded in.
#[derive(Debug, Clone)]
pub struct UpdateRecord {
    /// The timestamp the server advanced *to* (i).
    pub new_ts: Timestamp,
    /// Vector clock: timestamps of the contributing gradients.
    pub clock: Vec<Timestamp>,
    /// ⟨σ⟩ for this update, per Eq. (2).
    pub avg_staleness: f64,
}

/// Running staleness statistics across a training run.
#[derive(Debug, Default, Clone)]
pub struct StalenessStats {
    /// Per-update ⟨σ⟩ series (Figure 4's y-axis).
    pub per_update_avg: Vec<f64>,
    /// Histogram over individual gradient staleness values (Fig 4b inset).
    pub histogram: Vec<u64>,
    /// Max σ observed.
    pub max: u64,
    /// Total gradients folded in.
    pub count: u64,
    sum: f64,
}

impl StalenessStats {
    /// Record one weight update from timestamps of contributing gradients.
    /// `new_ts` is the timestamp the server advanced to (i); gradients were
    /// computed at `grad_ts` (each < i).
    pub fn record(&mut self, new_ts: Timestamp, grad_ts: &[Timestamp]) -> UpdateRecord {
        debug_assert!(!grad_ts.is_empty());
        let i_minus_1 = (new_ts - 1) as f64;
        let mean_ts =
            grad_ts.iter().map(|&t| t as f64).sum::<f64>() / grad_ts.len() as f64;
        let avg = i_minus_1 - mean_ts;
        self.per_update_avg.push(avg);
        for &t in grad_ts {
            let sigma = new_ts - 1 - t; // σ = (i−1) − ts(gradient)
            if self.histogram.len() <= sigma as usize {
                self.histogram.resize(sigma as usize + 1, 0);
            }
            self.histogram[sigma as usize] += 1;
            self.max = self.max.max(sigma);
            self.sum += sigma as f64;
            self.count += 1;
        }
        UpdateRecord { new_ts, clock: grad_ts.to_vec(), avg_staleness: avg }
    }

    /// Run-cumulative `(gradient count, staleness sum)` — windowed
    /// consumers (the adaptive-n controller's per-epoch ⟨σ⟩) difference
    /// successive snapshots.
    pub fn totals(&self) -> (u64, f64) {
        (self.count, self.sum)
    }

    /// Overall ⟨σ⟩ across all gradients.
    pub fn overall_avg(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Serialize for checkpointing: restoring mid-run must resume the
    /// exact staleness series, not restart it.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("per_update_avg", Json::arr_f64(&self.per_update_avg)),
            ("histogram", Json::arr_u64(&self.histogram)),
            ("max", Json::num(self.max as f64)),
            ("count", Json::num(self.count as f64)),
            ("sum", Json::num(self.sum)),
        ])
    }

    /// Restore from [`StalenessStats::to_json`] output.
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<StalenessStats> {
        Ok(StalenessStats {
            per_update_avg: j.get("per_update_avg")?.as_f64_vec()?,
            histogram: j.get("histogram")?.as_u64_vec()?,
            max: j.get("max")?.as_u64()?,
            count: j.get("count")?.as_u64()?,
            sum: j.get("sum")?.as_f64()?,
        })
    }

    /// Fraction of gradients with σ > `bound` (the paper reports
    /// P[σ > 2n] < 1e-4 for n-softsync).
    pub fn frac_exceeding(&self, bound: u64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let over: u64 = self
            .histogram
            .iter()
            .enumerate()
            .filter(|(s, _)| *s as u64 > bound)
            .map(|(_, c)| *c)
            .sum();
        over as f64 / self.count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hardsync_staleness_is_zero() {
        // Hardsync: all λ gradients carry the previous timestamp i−1.
        let mut s = StalenessStats::default();
        let rec = s.record(5, &[4, 4, 4]);
        assert_eq!(rec.avg_staleness, 0.0);
        assert_eq!(s.max, 0);
        assert_eq!(s.overall_avg(), 0.0);
    }

    #[test]
    fn eq2_average() {
        // Update to ts=10 built from gradients at ts {9, 8, 7}:
        // ⟨σ⟩ = 9 − mean(9,8,7) = 9 − 8 = 1.
        let mut s = StalenessStats::default();
        let rec = s.record(10, &[9, 8, 7]);
        assert!((rec.avg_staleness - 1.0).abs() < 1e-12);
        // individual σ values: 0, 1, 2 → max 2
        assert_eq!(s.max, 2);
        assert_eq!(s.histogram, vec![1, 1, 1]);
    }

    #[test]
    fn histogram_and_tail() {
        let mut s = StalenessStats::default();
        s.record(2, &[1]); // σ = 0
        s.record(3, &[1]); // σ = 1
        s.record(10, &[1]); // σ = 8
        assert_eq!(s.count, 3);
        assert!((s.frac_exceeding(1) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.frac_exceeding(8), 0.0);
    }

    #[test]
    fn overall_avg_accumulates() {
        let mut s = StalenessStats::default();
        s.record(2, &[1, 1]); // σ 0,0
        s.record(4, &[1, 3]); // σ 2,0
        assert!((s.overall_avg() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip_resumes_series() {
        let mut s = StalenessStats::default();
        s.record(2, &[1]);
        s.record(5, &[2, 4]);
        let text = s.to_json().to_string();
        let mut back =
            StalenessStats::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.per_update_avg, s.per_update_avg);
        assert_eq!(back.histogram, s.histogram);
        assert_eq!(back.max, s.max);
        assert_eq!(back.count, s.count);
        assert_eq!(back.overall_avg(), s.overall_avg());
        // the restored stats keep accumulating correctly
        back.record(6, &[5]);
        s.record(6, &[5]);
        assert_eq!(back.overall_avg(), s.overall_avg());
    }
}
