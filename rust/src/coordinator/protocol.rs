//! Synchronization protocols (§3.1): hardsync, backup-sync, n-softsync,
//! async.
//!
//! The server-side update rules:
//! * **Hardsync** (Eq. 3): wait for exactly one gradient from *every*
//!   learner, average the λ of them, update, broadcast. σ ≡ 0.
//! * **Backup-sync** (Chen et al., *Revisiting Distributed Synchronous
//!   SGD*): a hardsync barrier over the first λ − b arrivals per round;
//!   the b slowest gradients are *dropped* when they land (they were
//!   computed from pre-update weights) and their learners refreshed with
//!   current weights. σ ≡ 0 for everything aggregated; straggler work is
//!   wasted instead of staled. b = 0 is exactly hardsync.
//! * **n-softsync** (Eq. 5): update after collecting at least
//!   c = ⌊λ/n⌋ gradients, averaging the c of them. Empirically ⟨σ⟩ ≈ n
//!   and σ ≤ 2n (§5.1).
//! * **Async** (Eq. 4): apply every gradient immediately — exactly the
//!   n = λ degenerate case of n-softsync (c = 1), unbounded in theory
//!   (Downpour-style); bounded here by the engine's in-flight limit.

use anyhow::{bail, Result};

/// Protocol selection. `NSoftsync { n: 1 }` is 1-softsync; `Async` is the
/// n = λ degenerate case kept separate for reporting clarity;
/// `BackupSync { b: 0 }` degenerates to hardsync.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Protocol {
    Hardsync,
    /// Hardsync with `b` backup workers: each round closes on the first
    /// λ_active − b gradients; the b slowest are dropped on arrival.
    BackupSync { b: usize },
    NSoftsync { n: usize },
    Async,
}

impl Protocol {
    /// Parse `"hardsync" | "async" | "<n>-softsync" | "softsync:<n>" |
    /// "backup:<b>"`.
    pub fn parse(s: &str) -> Result<Protocol> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "hardsync" | "hard" => return Ok(Protocol::Hardsync),
            "async" => return Ok(Protocol::Async),
            _ => {}
        }
        if let Some(b) = s.strip_prefix("backup:").or_else(|| s.strip_prefix("backup-sync:")) {
            let b: usize = b.parse().map_err(|_| {
                anyhow::anyhow!("bad backup-worker count in {s:?} (want backup:<b>)")
            })?;
            return Ok(Protocol::BackupSync { b });
        }
        if let Some(n) = s.strip_suffix("-softsync").or_else(|| s.strip_prefix("softsync:")) {
            let n: usize = n.parse().map_err(|_| {
                anyhow::anyhow!("bad softsync splitting parameter in {s:?}")
            })?;
            if n == 0 {
                bail!("n-softsync requires n >= 1");
            }
            return Ok(Protocol::NSoftsync { n });
        }
        bail!("unknown protocol {s:?} (hardsync | async | <n>-softsync | backup:<b>)");
    }

    /// Number of gradients the server collects before updating
    /// (c = ⌊λ/n⌋ for n-softsync, clamped to ≥ 1; λ for hardsync;
    /// λ − b for backup-sync, clamped to ≥ 1; 1 async).
    pub fn gradients_per_update(&self, lambda: usize) -> usize {
        match *self {
            Protocol::Hardsync => lambda,
            Protocol::BackupSync { b } => lambda.saturating_sub(b).max(1),
            Protocol::NSoftsync { n } => (lambda / n).max(1),
            Protocol::Async => 1,
        }
    }

    /// Checked form of [`Protocol::gradients_per_update`] for *recomputing*
    /// c when λ changes mid-run (elastic membership). Unlike the clamped
    /// static form, this rejects λ_active < n: there ⌊λ/n⌋ = 0, and the
    /// silent `.max(1)` clamp would quietly turn an n-softsync run into
    /// async (and a 0 quota would make the server spin waiting for a
    /// round that can never fill). Also rejects λ_active = 0 — a server
    /// with no live learners has no well-defined collection threshold.
    pub fn try_gradients_per_update(&self, lambda: usize) -> Result<usize> {
        if lambda == 0 {
            bail!("no active learners (λ_active = 0): cannot compute a collection threshold");
        }
        if let Protocol::NSoftsync { n } = *self {
            if lambda < n {
                bail!(
                    "{n}-softsync requires λ_active >= n, but λ_active = {lambda} \
                     (c = ⌊λ/n⌋ would be 0; evict fewer learners or lower n)"
                );
            }
        }
        if let Protocol::BackupSync { b } = *self {
            if lambda <= b {
                bail!(
                    "backup:{b} requires λ_active > b, but λ_active = {lambda} \
                     (a round of λ − b = 0 gradients can never close; evict \
                     fewer learners or lower b)"
                );
            }
        }
        Ok(self.gradients_per_update(lambda))
    }

    /// Whether learners block on a broadcast of fresh weights after each
    /// round (the barrier family: hardsync hears from *every* learner,
    /// backup-sync from the first λ − b).
    pub fn is_barrier(&self) -> bool {
        matches!(self, Protocol::Hardsync | Protocol::BackupSync { .. })
    }

    /// The effective splitting parameter n (λ for async, n for softsync).
    /// ⟨σ⟩ ≈ n is the paper's §5.1 measurement; the barrier protocols are
    /// stale-free (backup-sync *drops* rather than stales late gradients).
    pub fn effective_n(&self, lambda: usize) -> usize {
        match *self {
            Protocol::Hardsync | Protocol::BackupSync { .. } => 0,
            Protocol::NSoftsync { n } => n.min(lambda.max(1)),
            Protocol::Async => lambda.max(1),
        }
    }

    pub fn label(&self) -> String {
        match *self {
            Protocol::Hardsync => "hardsync".to_string(),
            Protocol::BackupSync { b } => format!("backup:{b}"),
            Protocol::NSoftsync { n } => format!("{n}-softsync"),
            Protocol::Async => "async".to_string(),
        }
    }
}

/// Gradient accumulator implementing the protocol update rules over flat
/// vectors: collects pushes, reports readiness, and produces the averaged
/// Δθ of Eq. (3)/(5) along with the contributing vector clock.
#[derive(Debug)]
pub struct Accumulator {
    protocol: Protocol,
    /// Active learner count λ_active — the quota basis (c = ⌊λ/n⌋).
    /// Starts equal to `id_bound`; elastic membership shrinks/grows it via
    /// [`Accumulator::set_active_lambda`].
    lambda: usize,
    /// Learner-id space bound (total learner slots ever allocated). Ids
    /// are stable across death/rejoin, so the bound never changes even as
    /// `lambda` does.
    id_bound: usize,
    /// Sum of pending gradients.
    sum: crate::params::FlatVec,
    /// Timestamps of the pending gradients (the vector clock in waiting).
    pending_ts: Vec<u64>,
    /// Learner ids contributing to the pending update (hardsync dedup).
    pending_from: Vec<usize>,
}

impl Accumulator {
    pub fn new(protocol: Protocol, lambda: usize, n_params: usize) -> Accumulator {
        Accumulator {
            protocol,
            lambda,
            id_bound: lambda,
            sum: crate::params::FlatVec::zeros(n_params),
            pending_ts: Vec::with_capacity(lambda),
            pending_from: Vec::with_capacity(lambda),
        }
    }

    pub fn pending(&self) -> usize {
        self.pending_ts.len()
    }

    /// Current quota basis λ_active.
    pub fn active_lambda(&self) -> usize {
        self.lambda
    }

    /// Recompute the collection quota for a changed active learner count
    /// (elastic membership). The learner-id space is unchanged — dead
    /// learners keep their ids for rejoin. Rejects λ_active values whose
    /// quota would be ill-defined (0, or < n under n-softsync); see
    /// [`Protocol::try_gradients_per_update`]. The caller decides whether
    /// an already-satisfied quota triggers an immediate applyUpdate.
    pub fn set_active_lambda(&mut self, lambda: usize) -> Result<()> {
        self.protocol.try_gradients_per_update(lambda)?;
        self.lambda = lambda;
        Ok(())
    }

    /// Adaptive-n control: swap the protocol in place (between updates),
    /// revalidating the collection quota against the current λ_active.
    /// The pending set is untouched — if the new quota is already met,
    /// the next push closes the round.
    pub fn set_protocol(&mut self, protocol: Protocol) -> Result<()> {
        protocol.try_gradients_per_update(self.lambda)?;
        self.protocol = protocol;
        Ok(())
    }

    /// Serialize for checkpointing (protocol lives in the server config).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("lambda", Json::num(self.lambda as f64)),
            ("id_bound", Json::num(self.id_bound as f64)),
            ("sum", Json::arr_f32(&self.sum.data)),
            ("pending_ts", Json::arr_u64(&self.pending_ts)),
            (
                "pending_from",
                Json::Arr(
                    self.pending_from.iter().map(|&l| Json::num(l as f64)).collect(),
                ),
            ),
        ])
    }

    /// Restore from [`Accumulator::to_json`] output.
    pub fn from_json(protocol: Protocol, j: &crate::util::json::Json) -> Result<Accumulator> {
        let lambda = j.get("lambda")?.as_usize()?;
        let id_bound = j.get("id_bound")?.as_usize()?;
        let sum = crate::params::FlatVec::from_vec(j.get("sum")?.as_f32_vec()?);
        let pending_ts = j.get("pending_ts")?.as_u64_vec()?;
        let pending_from = j
            .get("pending_from")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<Vec<usize>>>()?;
        anyhow::ensure!(
            pending_ts.len() == pending_from.len(),
            "accumulator checkpoint: pending_ts/pending_from length mismatch"
        );
        Ok(Accumulator { protocol, lambda, id_bound, sum, pending_ts, pending_from })
    }

    /// Push one gradient. Returns an error on a hardsync double-push from
    /// the same learner within a single barrier round (a protocol
    /// violation — the paper's hardsync collects *exactly one* gradient
    /// per learner per step).
    pub fn push(
        &mut self,
        learner: usize,
        grad: &crate::params::FlatVec,
        grad_ts: u64,
    ) -> Result<()> {
        self.push_scaled(learner, grad, grad_ts, 1.0)
    }

    /// Push one gradient pre-scaled by `scale` — the footnote-3
    /// per-gradient staleness modulation folds staler gradients in with
    /// smaller weight.
    pub fn push_scaled(
        &mut self,
        learner: usize,
        grad: &crate::params::FlatVec,
        grad_ts: u64,
        scale: f32,
    ) -> Result<()> {
        self.push_scaled_slice(learner, &grad.data, grad_ts, scale)
    }

    /// Slice form of [`Accumulator::push_scaled`]: the sharded server folds
    /// each shard's contiguous range of the gradient without copying it
    /// into a standalone vector first.
    pub fn push_scaled_slice(
        &mut self,
        learner: usize,
        grad: &[f32],
        grad_ts: u64,
        scale: f32,
    ) -> Result<()> {
        if learner >= self.id_bound {
            bail!("learner id {learner} out of range (λ = {})", self.id_bound);
        }
        if self.protocol.is_barrier() && self.pending_from.contains(&learner) {
            bail!("hardsync: learner {learner} pushed twice in one barrier round");
        }
        self.sum.axpy_slice(scale, grad);
        self.pending_ts.push(grad_ts);
        self.pending_from.push(learner);
        Ok(())
    }

    /// True when enough gradients have arrived to trigger applyUpdate.
    pub fn ready(&self) -> bool {
        self.pending() >= self.protocol.gradients_per_update(self.lambda)
    }

    /// Whether `learner` contributed to the pending (un-applied) set —
    /// the membership-aware hardsync flush refuses to close a round the
    /// dead learner was part of while survivors' gradients are in flight.
    pub fn pending_contains(&self, learner: usize) -> bool {
        self.pending_from.contains(&learner)
    }

    /// Drain the pending set: returns (averaged Δθ, vector clock).
    /// Averages over the *actual* number collected, matching Eq. (5)'s
    /// 1/c prefactor (and Eq. 3's 1/λ under hardsync).
    pub fn take_update(&mut self) -> (crate::params::FlatVec, Vec<u64>) {
        let mut avg = crate::params::FlatVec::zeros(0);
        let mut clock = Vec::new();
        self.drain_update(&mut avg, &mut clock);
        (avg, clock)
    }

    /// Allocation-free form of [`Accumulator::take_update`] for the
    /// per-update hot path: the averaged Δθ and the vector clock land in
    /// the caller's scratch buffers (overwritten, any prior length), and
    /// the buffers they displace become the accumulator's next-round sum
    /// and pending clock — so a warmed caller/accumulator pair recycles
    /// the same two allocations for the whole run. Values are
    /// bit-identical to `take_update` (same per-coordinate ops in the
    /// same order; a recycled sum buffer is re-zeroed with `fill`, and
    /// 0.0-filled equals freshly allocated zeros bitwise).
    pub fn drain_update(
        &mut self,
        avg: &mut crate::params::FlatVec,
        clock: &mut Vec<u64>,
    ) {
        std::mem::swap(&mut self.pending_ts, clock);
        self.pending_ts.clear();
        let c = clock.len().max(1);
        std::mem::swap(&mut self.sum, avg);
        if self.sum.len() == avg.len() {
            self.sum.fill(0.0);
        } else {
            self.sum = crate::params::FlatVec::zeros(avg.len());
        }
        avg.scale(1.0 / c as f32);
        self.pending_from.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::FlatVec;

    #[test]
    fn parse_all_forms() {
        assert_eq!(Protocol::parse("hardsync").unwrap(), Protocol::Hardsync);
        assert_eq!(Protocol::parse("async").unwrap(), Protocol::Async);
        assert_eq!(
            Protocol::parse("1-softsync").unwrap(),
            Protocol::NSoftsync { n: 1 }
        );
        assert_eq!(
            Protocol::parse("softsync:30").unwrap(),
            Protocol::NSoftsync { n: 30 }
        );
        assert!(Protocol::parse("0-softsync").is_err());
        assert!(Protocol::parse("what").is_err());
        assert_eq!(Protocol::parse("backup:2").unwrap(), Protocol::BackupSync { b: 2 });
        assert_eq!(Protocol::parse("backup:0").unwrap(), Protocol::BackupSync { b: 0 });
        assert!(Protocol::parse("backup:x").is_err());
        // labels round-trip for every variant (checkpoints rely on this)
        for p in [
            Protocol::Hardsync,
            Protocol::BackupSync { b: 3 },
            Protocol::NSoftsync { n: 4 },
            Protocol::Async,
        ] {
            assert_eq!(Protocol::parse(&p.label()).unwrap(), p);
        }
    }

    #[test]
    fn backup_sync_quota_and_barrier_family() {
        let p = Protocol::BackupSync { b: 2 };
        assert_eq!(p.gradients_per_update(8), 6);
        assert!(p.is_barrier());
        assert_eq!(p.effective_n(8), 0, "backup-sync is stale-free");
        // checked form rejects λ_active ≤ b (elastic membership shrink)
        assert_eq!(p.try_gradients_per_update(3).unwrap(), 1);
        let err = p.try_gradients_per_update(2).unwrap_err();
        assert!(err.to_string().contains("backup:2"), "{err}");
        assert!(p.try_gradients_per_update(1).is_err());
        // b = 0 is exactly hardsync's quota at every λ
        let h = Protocol::BackupSync { b: 0 };
        for lambda in 1..=8 {
            assert_eq!(
                h.gradients_per_update(lambda),
                Protocol::Hardsync.gradients_per_update(lambda)
            );
        }
    }

    #[test]
    fn backup_sync_accumulator_rounds_close_at_lambda_minus_b() {
        let mut acc = Accumulator::new(Protocol::BackupSync { b: 1 }, 3, 1);
        let g = FlatVec::from_vec(vec![3.0]);
        acc.push(0, &g, 0).unwrap();
        assert!(!acc.ready());
        acc.push(1, &g, 0).unwrap();
        assert!(acc.ready(), "round closes on λ − b = 2 arrivals");
        let (avg, clock) = acc.take_update();
        assert_eq!(avg.data, vec![3.0]);
        assert_eq!(clock, vec![0, 0]);
        // backup-sync shares the barrier family's double-push protection
        let mut acc = Accumulator::new(Protocol::BackupSync { b: 1 }, 3, 1);
        acc.push(0, &g, 0).unwrap();
        assert!(acc.push(0, &g, 0).is_err());
    }

    #[test]
    fn accumulator_set_protocol_revalidates_quota() {
        let mut acc = Accumulator::new(Protocol::NSoftsync { n: 2 }, 8, 1);
        let g = FlatVec::from_vec(vec![1.0]);
        for l in 0..3 {
            acc.push(l, &g, 0).unwrap();
        }
        assert!(!acc.ready(), "quota ⌊8/2⌋ = 4 not met by 3");
        acc.set_protocol(Protocol::NSoftsync { n: 4 }).unwrap();
        assert!(acc.ready(), "quota ⌊8/4⌋ = 2 already met");
        // n > λ_active is rejected and leaves the protocol unchanged
        let err = acc.set_protocol(Protocol::NSoftsync { n: 9 }).unwrap_err();
        assert!(err.to_string().contains("softsync"), "{err}");
        assert!(acc.ready());
    }

    #[test]
    fn gradients_per_update_matches_eq5() {
        assert_eq!(Protocol::Hardsync.gradients_per_update(30), 30);
        assert_eq!(Protocol::NSoftsync { n: 1 }.gradients_per_update(30), 30);
        assert_eq!(Protocol::NSoftsync { n: 2 }.gradients_per_update(30), 15);
        assert_eq!(Protocol::NSoftsync { n: 30 }.gradients_per_update(30), 1);
        // ⌊λ/n⌋ with n > λ clamps to 1
        assert_eq!(Protocol::NSoftsync { n: 64 }.gradients_per_update(30), 1);
        assert_eq!(Protocol::Async.gradients_per_update(30), 1);
    }

    #[test]
    fn accumulator_averages() {
        let mut acc = Accumulator::new(Protocol::NSoftsync { n: 1 }, 2, 2);
        assert!(!acc.ready());
        acc.push(0, &FlatVec::from_vec(vec![2.0, 0.0]), 0).unwrap();
        assert!(!acc.ready());
        acc.push(1, &FlatVec::from_vec(vec![0.0, 4.0]), 0).unwrap();
        assert!(acc.ready());
        let (avg, clock) = acc.take_update();
        assert_eq!(avg.data, vec![1.0, 2.0]);
        assert_eq!(clock, vec![0, 0]);
        assert_eq!(acc.pending(), 0);
    }

    #[test]
    fn drain_update_matches_take_update_with_recycled_scratch() {
        // The hot-path drain must be bitwise identical to the allocating
        // reference form, *including* when its scratch buffers are dirty
        // leftovers from earlier rounds.
        let mut a = Accumulator::new(Protocol::NSoftsync { n: 1 }, 2, 3);
        let mut b = Accumulator::new(Protocol::NSoftsync { n: 1 }, 2, 3);
        let mut avg = FlatVec::zeros(0);
        let mut clock = Vec::new();
        for round in 0..4u64 {
            for l in 0..2 {
                let g =
                    FlatVec::from_vec(vec![l as f32 + 0.5, -1.0, round as f32 * 0.25]);
                a.push(l, &g, round).unwrap();
                b.push(l, &g, round).unwrap();
            }
            assert!(a.ready() && b.ready());
            let (want_avg, want_clock) = a.take_update();
            b.drain_update(&mut avg, &mut clock);
            assert_eq!(avg.data, want_avg.data, "round {round}: bitwise average");
            assert_eq!(clock, want_clock, "round {round}: vector clock");
            assert_eq!(b.pending(), 0);
        }
    }

    #[test]
    fn async_updates_every_push() {
        let mut acc = Accumulator::new(Protocol::Async, 30, 1);
        acc.push(7, &FlatVec::from_vec(vec![3.0]), 5).unwrap();
        assert!(acc.ready());
        let (avg, clock) = acc.take_update();
        assert_eq!(avg.data, vec![3.0]);
        assert_eq!(clock, vec![5]);
    }

    #[test]
    fn hardsync_rejects_double_push() {
        let mut acc = Accumulator::new(Protocol::Hardsync, 2, 1);
        acc.push(0, &FlatVec::from_vec(vec![1.0]), 0).unwrap();
        assert!(acc.push(0, &FlatVec::from_vec(vec![1.0]), 0).is_err());
    }

    #[test]
    fn rejects_out_of_range_learner() {
        // Regression: push_scaled used to accept any learner id, silently
        // corrupting hardsync dedup and per-learner accounting.
        for protocol in [Protocol::Hardsync, Protocol::NSoftsync { n: 1 }, Protocol::Async] {
            let mut acc = Accumulator::new(protocol, 2, 1);
            let g = FlatVec::from_vec(vec![1.0]);
            let err = acc.push(2, &g, 0).unwrap_err();
            assert!(err.to_string().contains("out of range"), "{err}");
            assert!(acc.push(7, &g, 0).is_err());
            assert_eq!(acc.pending(), 0, "rejected pushes must not accumulate");
            // valid ids still work
            acc.push(1, &g, 0).unwrap();
            assert_eq!(acc.pending(), 1);
        }
    }

    #[test]
    fn effective_n() {
        assert_eq!(Protocol::Hardsync.effective_n(30), 0);
        assert_eq!(Protocol::NSoftsync { n: 4 }.effective_n(30), 4);
        assert_eq!(Protocol::Async.effective_n(30), 30);
    }

    #[test]
    fn checked_quota_rejects_lambda_below_n() {
        // Regression: recomputing c = ⌊λ/n⌋ after membership churn used
        // the clamped static form, silently turning n-softsync into async
        // when λ_active dropped below n (⌊λ/n⌋ = 0 clamped to 1).
        let p = Protocol::NSoftsync { n: 4 };
        assert_eq!(p.try_gradients_per_update(8).unwrap(), 2);
        assert_eq!(p.try_gradients_per_update(4).unwrap(), 1);
        let err = p.try_gradients_per_update(3).unwrap_err();
        assert!(err.to_string().contains("λ_active"), "{err}");
        // λ_active = 0 is rejected for every protocol.
        for proto in [Protocol::Hardsync, Protocol::NSoftsync { n: 1 }, Protocol::Async] {
            assert!(proto.try_gradients_per_update(0).is_err(), "{proto:?}");
        }
        // hardsync and async have no n constraint
        assert_eq!(Protocol::Hardsync.try_gradients_per_update(3).unwrap(), 3);
        assert_eq!(Protocol::Async.try_gradients_per_update(1).unwrap(), 1);
    }

    #[test]
    fn accumulator_rescales_quota_but_keeps_id_space() {
        let mut acc = Accumulator::new(Protocol::NSoftsync { n: 1 }, 4, 1);
        let g = FlatVec::from_vec(vec![1.0]);
        acc.push(0, &g, 0).unwrap();
        acc.push(1, &g, 0).unwrap();
        assert!(!acc.ready(), "quota 4 not met by 2 pushes");
        // two learners die: quota drops to 2, already satisfied
        acc.set_active_lambda(2).unwrap();
        assert_eq!(acc.active_lambda(), 2);
        assert!(acc.ready());
        // dead learners' ids stay addressable (they may rejoin)
        acc.push(3, &g, 0).unwrap();
        assert_eq!(acc.pending(), 3);
        // but rescaling below the protocol's floor is rejected
        let mut soft = Accumulator::new(Protocol::NSoftsync { n: 3 }, 6, 1);
        assert!(soft.set_active_lambda(2).is_err());
        assert_eq!(soft.active_lambda(), 6, "failed rescale must not change λ");
    }

    #[test]
    fn accumulator_json_roundtrip_preserves_pending_state() {
        let mut acc = Accumulator::new(Protocol::NSoftsync { n: 2 }, 4, 3);
        let g = FlatVec::from_vec(vec![0.25, -1.5, 3.0]);
        acc.push(1, &g, 7).unwrap();
        acc.push_scaled(2, &g, 5, 0.5).unwrap();
        let j = acc.to_json();
        let text = j.to_string();
        let back = Accumulator::from_json(
            Protocol::NSoftsync { n: 2 },
            &crate::util::json::Json::parse(&text).unwrap(),
        )
        .unwrap();
        assert_eq!(back.active_lambda(), 4);
        assert_eq!(back.pending(), 2);
        assert_eq!(back.sum.data, acc.sum.data, "pending sum must survive bit-exactly");
        assert_eq!(back.pending_ts, vec![7, 5]);
        assert_eq!(back.pending_from, vec![1, 2]);
    }
}
