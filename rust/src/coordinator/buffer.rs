//! Rudra-adv*'s double-buffered pullWeights (§3.3).
//!
//! "We maintain a computation buffer and a communication buffer for the
//! pullWeights thread, and the communication always happens in the
//! background. To use the newly received weights only requires a pointer
//! swap." This module implements exactly that: the communication side
//! writes into the back buffer; the compute side swaps front/back at
//! mini-batch boundaries if a fresher replica has landed.

use anyhow::Result;

use crate::coordinator::clock::Timestamp;
use crate::params::FlatVec;

/// Compute/communication weight buffer pair with pointer-swap semantics.
#[derive(Debug)]
pub struct DoubleBuffer {
    front: FlatVec,
    front_ts: Timestamp,
    back: FlatVec,
    back_ts: Timestamp,
    back_fresh: bool,
    /// Number of swaps performed (diagnostics).
    pub swaps: u64,
}

impl DoubleBuffer {
    pub fn new(theta0: &FlatVec) -> DoubleBuffer {
        DoubleBuffer {
            front: theta0.clone(),
            front_ts: 0,
            back: theta0.clone(),
            back_ts: 0,
            back_fresh: false,
            swaps: 0,
        }
    }

    /// The compute-side view (what calcGradient reads).
    pub fn compute_view(&self) -> (&FlatVec, Timestamp) {
        (&self.front, self.front_ts)
    }

    /// Communication thread delivers a freshly received replica into the
    /// back buffer. Keeps the freshest replica if several land between
    /// swaps (later deliveries overwrite).
    ///
    /// Length-checked: a replica whose size disagrees with the buffers is
    /// rejected as an error instead of panicking in `copy_from_slice`. A
    /// rejected delivery leaves the buffers and freshness untouched.
    ///
    /// This module is the §3.3 reference implementation and is not yet
    /// wired into an engine, so today nothing can hit the mismatch at
    /// runtime — but the engines' μ·λ rescale paths do legitimately
    /// resize θ views, so any future caller wiring a live adv* learner
    /// loop through this buffer must get a `Result` to act on (rebuild
    /// the pair or drop the replica), not a panic in its comm thread.
    pub fn deliver(&mut self, theta: &FlatVec, ts: Timestamp) -> Result<()> {
        anyhow::ensure!(
            theta.len() == self.back.len(),
            "deliver: replica has {} params, buffer holds {}",
            theta.len(),
            self.back.len()
        );
        if ts <= self.back_ts && self.back_fresh {
            return Ok(()); // stale delivery, ignore
        }
        self.back.data.copy_from_slice(&theta.data);
        self.back_ts = ts;
        self.back_fresh = ts > self.front_ts;
        Ok(())
    }

    /// Mini-batch boundary: swap to the fresher replica if one arrived.
    /// Returns true if a swap happened. O(1) — a pointer swap.
    pub fn try_swap(&mut self) -> bool {
        if !self.back_fresh {
            return false;
        }
        std::mem::swap(&mut self.front, &mut self.back);
        std::mem::swap(&mut self.front_ts, &mut self.back_ts);
        self.back_fresh = false;
        self.swaps += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn swap_only_when_fresh() {
        let mut db = DoubleBuffer::new(&FlatVec::zeros(2));
        assert!(!db.try_swap());
        db.deliver(&FlatVec::from_vec(vec![1.0, 1.0]), 3).unwrap();
        assert!(db.try_swap());
        assert_eq!(db.compute_view().1, 3);
        assert_eq!(db.compute_view().0.data, vec![1.0, 1.0]);
        assert!(!db.try_swap(), "no double swap on the same delivery");
    }

    #[test]
    fn later_delivery_wins() {
        let mut db = DoubleBuffer::new(&FlatVec::zeros(1));
        db.deliver(&FlatVec::from_vec(vec![1.0]), 1).unwrap();
        db.deliver(&FlatVec::from_vec(vec![2.0]), 5).unwrap();
        db.try_swap();
        assert_eq!(db.compute_view(), (&FlatVec::from_vec(vec![2.0]), 5));
    }

    #[test]
    fn stale_delivery_ignored() {
        let mut db = DoubleBuffer::new(&FlatVec::zeros(1));
        db.deliver(&FlatVec::from_vec(vec![2.0]), 5).unwrap();
        db.deliver(&FlatVec::from_vec(vec![1.0]), 1).unwrap(); // stale
        db.try_swap();
        assert_eq!(db.compute_view().1, 5);
    }

    #[test]
    fn compute_view_stable_until_swap() {
        let mut db = DoubleBuffer::new(&FlatVec::from_vec(vec![7.0]));
        db.deliver(&FlatVec::from_vec(vec![9.0]), 2).unwrap();
        // no swap yet — compute still sees the old replica
        assert_eq!(db.compute_view().0.data, vec![7.0]);
        db.try_swap();
        assert_eq!(db.compute_view().0.data, vec![9.0]);
    }

    #[test]
    fn length_mismatched_replica_is_a_checked_error() {
        // Regression: `deliver` used to panic in `copy_from_slice` when a
        // μ·λ rescale path resized θ views mid-run. It must now return an
        // error and leave the buffer pair (and its freshness) untouched.
        let mut db = DoubleBuffer::new(&FlatVec::from_vec(vec![7.0, 7.0]));
        let err = db.deliver(&FlatVec::from_vec(vec![1.0, 2.0, 3.0]), 4).unwrap_err();
        assert!(err.to_string().contains("3 params"), "{err}");
        assert!(db.deliver(&FlatVec::from_vec(vec![1.0]), 4).is_err(), "short replica too");
        assert!(!db.try_swap(), "rejected delivery must not mark the back buffer fresh");
        assert_eq!(db.compute_view(), (&FlatVec::from_vec(vec![7.0, 7.0]), 0));
        assert_eq!(db.swaps, 0);
        // a well-formed delivery still works afterwards
        db.deliver(&FlatVec::from_vec(vec![1.0, 2.0]), 4).unwrap();
        assert!(db.try_swap());
        assert_eq!(db.compute_view().0.data, vec![1.0, 2.0]);
    }
}
