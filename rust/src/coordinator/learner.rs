//! Learner-side state and the gradient-computation abstraction.
//!
//! A learner's loop (§2): getMinibatch → pullWeights → calcGradient →
//! pushGradient. The paper's timestamp-inquiry optimization (§3.2) is
//! implemented here: before pulling, the learner compares its local
//! weights timestamp with the server's and skips the (model-sized) pull
//! when they match, paying only the scalar-inquiry latency.
//!
//! [`GradProvider`] hides *what* is trained: the PJRT-backed providers in
//! [`crate::harness`] sample real mini-batches and execute the AOT grad
//! graph; tests can use [`MockProvider`], a quadratic bowl with a
//! closed-form gradient.

use anyhow::Result;

use crate::coordinator::clock::Timestamp;
use crate::params::FlatVec;

/// Computes a gradient for learner `id` from weights `theta`.
/// Implementations own their mini-batch sampling state.
pub trait GradProvider {
    /// Returns (gradient, training loss on the sampled mini-batch).
    fn compute(&mut self, learner: usize, theta: &FlatVec) -> Result<(FlatVec, f32)>;

    /// Number of parameters (gradient length).
    fn n_params(&self) -> usize;

    /// Dynamic-μ control: the elastic rescaler retunes the per-learner
    /// mini-batch size on membership changes, and the engines forward the
    /// new μ here (the live engine over each learner's reply channel, the
    /// sim engine directly). Returns whether the provider applied it —
    /// providers whose gradient graph is AOT-compiled for one batch size
    /// must decline (the default), in which case the server-side μ
    /// accounting still rescales but the provider keeps sampling at its
    /// spawn-time μ, the pre-control-channel behavior.
    fn set_mu(&mut self, _mu: usize) -> bool {
        false
    }
}

/// Per-learner replica state shared by both engines.
#[derive(Debug)]
pub struct LearnerState {
    pub id: usize,
    /// Local weight replica (what calcGradient reads).
    pub theta: FlatVec,
    /// Timestamp of the local replica.
    pub ts: Timestamp,
    /// Mini-batches computed so far.
    pub steps: u64,
}

impl LearnerState {
    pub fn new(id: usize, theta0: &FlatVec) -> LearnerState {
        LearnerState { id, theta: theta0.clone(), ts: 0, steps: 0 }
    }

    /// The §3.2 pull-skip test: does the learner need a full pull given
    /// the server's current timestamp?
    pub fn needs_pull(&self, server_ts: Timestamp) -> bool {
        server_ts > self.ts
    }

    /// Install freshly pulled weights.
    pub fn install(&mut self, theta: &FlatVec, ts: Timestamp) {
        self.theta.data.copy_from_slice(&theta.data);
        self.ts = ts;
    }
}

/// Quadratic-bowl mock: loss = ½‖θ − θ*‖², gradient = θ − θ*.
/// Deterministic, dimension-checked, converges under any sane protocol —
/// ideal for engine/protocol integration tests without artifacts.
#[derive(Debug, Clone)]
pub struct MockProvider {
    pub target: FlatVec,
    /// Last μ received over the dynamic-μ control channel (None until the
    /// first retune) — lets tests observe that the channel delivered.
    pub mu: Option<usize>,
}

impl MockProvider {
    pub fn new(target: Vec<f32>) -> MockProvider {
        MockProvider { target: FlatVec::from_vec(target), mu: None }
    }
}

impl GradProvider for MockProvider {
    fn compute(&mut self, _learner: usize, theta: &FlatVec) -> Result<(FlatVec, f32)> {
        anyhow::ensure!(theta.len() == self.target.len(), "dim mismatch");
        let mut grad = theta.clone();
        grad.axpy(-1.0, &self.target);
        let loss = 0.5 * grad.norm().powi(2);
        Ok((grad, loss as f32))
    }

    fn n_params(&self) -> usize {
        self.target.len()
    }

    fn set_mu(&mut self, mu: usize) -> bool {
        // the closed-form gradient has no batch dimension; record and
        // accept so control-channel tests can assert delivery
        self.mu = Some(mu);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pull_skip_logic() {
        let l = LearnerState::new(0, &FlatVec::zeros(3));
        assert!(!l.needs_pull(0));
        assert!(l.needs_pull(1));
    }

    #[test]
    fn install_copies() {
        let mut l = LearnerState::new(0, &FlatVec::zeros(2));
        let w = FlatVec::from_vec(vec![1.0, 2.0]);
        l.install(&w, 5);
        assert_eq!(l.theta.data, vec![1.0, 2.0]);
        assert_eq!(l.ts, 5);
        assert!(!l.needs_pull(5));
    }

    #[test]
    fn mock_gradient_points_at_target() {
        let mut p = MockProvider::new(vec![1.0, -1.0]);
        let theta = FlatVec::zeros(2);
        let (g, loss) = p.compute(0, &theta).unwrap();
        assert_eq!(g.data, vec![-1.0, 1.0]);
        assert!((loss - 1.0).abs() < 1e-6);
        // gradient descent moves toward the target
        let mut t = theta;
        t.axpy(-0.5, &g);
        assert_eq!(t.data, vec![0.5, -0.5]);
    }

    #[test]
    fn mock_rejects_dim_mismatch() {
        let mut p = MockProvider::new(vec![0.0; 3]);
        assert!(p.compute(0, &FlatVec::zeros(2)).is_err());
    }
}
