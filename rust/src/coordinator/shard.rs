//! Sharded parameter server: S contiguous weight shards, parallel
//! applyUpdate (§3.3's root-bottleneck fix).
//!
//! The paper identifies the root parameter server as the scalability wall
//! at λ = 30: every learner's push serializes through one NIC endpoint and
//! one applyUpdate loop ("if 16 tasks are sending 300 MB to the same
//! receiver and there is link contention, it would take over a second").
//! The canonical fix — the Downpour/DistBelief-style sharded server — is
//! to split the flat parameter vector θ into `S` contiguous shards, each
//! owning its slice of the accumulator, optimizer state, and weights, so
//! sumGradients and applyUpdate run per shard in parallel and push/pull
//! traffic spreads over `S` independent endpoints (see
//! [`crate::netsim::cluster::Fabric::send_to_shards`]).
//!
//! **Semantics are unchanged by construction.** Every push delivers one
//! slice to every shard, so all shard quotas fill on the same push and all
//! shards apply the same update step with the same scalar α. Per-shard
//! timestamps therefore advance in lockstep with the shared scalar clock,
//! which is exactly the property that keeps the paper's staleness analysis
//! (one scalar timestamp per model, Eq. 2) intact — the distinction the
//! paper draws against DistBelief's independently-clocked shards. At any
//! `S` the folded arithmetic is the same per-coordinate operations in the
//! same order as the unsharded [`ParameterServer`], so fixed-seed
//! trajectories are bit-identical at `S = 1` and equal within float
//! round-off at any `S` (see `prop_sharded_server_matches_unsharded`).
//!
//! Parallelism uses `std::thread::scope` over the shard set, gated on the
//! shard slices being large enough (`PAR_MIN_SHARD_PARAMS`) for fork/join to pay for
//! itself; below the threshold shards apply serially, with identical
//! results either way.

use std::ops::Range;

use anyhow::{bail, Result};

use crate::coordinator::clock::{StalenessStats, Timestamp};
use crate::coordinator::protocol::Accumulator;
use crate::coordinator::server::{PushOutcome, ServerConfig};
use crate::params::lr::LrPolicy;
use crate::params::optimizer::Optimizer;
use crate::params::FlatVec;

/// Below this many parameters *per shard slice*, fork/join costs more
/// than the axpy it parallelizes; shards run serially (same results
/// either way).
const PAR_MIN_SHARD_PARAMS: usize = 8_192;

/// Contiguous partition of a flat parameter vector into `S` shards whose
/// lengths differ by at most one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub n_params: usize,
    pub shards: usize,
}

impl ShardSpec {
    /// `shards` is clamped to ≥ 1 so a zero in a hand-built config cannot
    /// produce an empty server.
    pub fn new(n_params: usize, shards: usize) -> ShardSpec {
        ShardSpec { n_params, shards: shards.max(1) }
    }

    /// Half-open parameter range owned by shard `s`.
    pub fn range(&self, s: usize) -> Range<usize> {
        debug_assert!(s < self.shards);
        let base = self.n_params / self.shards;
        let rem = self.n_params % self.shards;
        let start = s * base + s.min(rem);
        let len = base + usize::from(s < rem);
        start..start + len
    }

    pub fn ranges(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.shards).map(|s| self.range(s))
    }
}

/// One shard: a contiguous slice of θ with its own accumulator, optimizer
/// state, and timestamp.
#[derive(Debug)]
pub struct Shard {
    pub range: Range<usize>,
    acc: Accumulator,
    optimizer: Optimizer,
    theta: FlatVec,
    /// Lockstep with the server's scalar clock (asserted after updates).
    pub ts: Timestamp,
    /// applyUpdate count for this shard (stats reporting).
    pub updates: u64,
    /// applyUpdate scratch: the drained average lands here and the
    /// displaced buffer becomes the accumulator's next-round sum, so the
    /// per-update path stops allocating once warm (not serialized — a
    /// restored shard re-warms on its first update).
    avg_scratch: FlatVec,
    /// Vector-clock scratch for the same drain (the shard-level clock is
    /// unused — the server's scalar clock is authoritative).
    clock_scratch: Vec<Timestamp>,
}

impl Shard {
    /// Fold this shard's slice of one pushed gradient. The caller
    /// ([`ShardedServer::push_gradient`]) has already validated the
    /// learner id and hardsync dedup, so the accumulator cannot reject.
    fn fold(&mut self, grad: &FlatVec, learner: usize, grad_ts: Timestamp, scale: f32) {
        self.acc
            .push_scaled_slice(learner, &grad.data[self.range.clone()], grad_ts, scale)
            .expect("shard push pre-validated by ShardedServer");
    }

    /// applyUpdate for this shard: drain the accumulator and step θ.
    fn apply(&mut self, alpha: f64) {
        self.acc.drain_update(&mut self.avg_scratch, &mut self.clock_scratch);
        self.optimizer.apply(&mut self.theta, &self.avg_scratch, alpha as f32);
        self.ts += 1;
        self.updates += 1;
    }
}

/// Parameter server over `S` shards. Drop-in for [`ParameterServer`] in
/// both engines: same protocol semantics, staleness accounting, epoch
/// bookkeeping, and LR modulation, with the numeric work split across
/// shards and applied in parallel.
///
/// [`ParameterServer`]: crate::coordinator::server::ParameterServer
pub struct ShardedServer {
    pub cfg: ServerConfig,
    spec: ShardSpec,
    shards: Vec<Shard>,
    /// Learner-id space bound (total learner slots). `cfg.lambda` tracks
    /// the *active* count under elastic membership; ids of dead learners
    /// stay reserved for rejoin, so the bound is fixed at construction.
    id_bound: usize,
    lr: LrPolicy,
    pub staleness: StalenessStats,
    /// Shared scalar timestamp (all shards advance in lockstep with it).
    ts: Timestamp,
    /// Shared vector clock in waiting (timestamps of pending gradients).
    pending_ts: Vec<Timestamp>,
    /// Learner ids contributing to the pending update (hardsync dedup).
    pending_from: Vec<usize>,
    samples_applied: u64,
    epochs_completed: usize,
    /// Number of weight updates applied (aggregate; equals every shard's
    /// own count).
    pub updates: u64,
    /// α actually used for the most recent update (for logging).
    pub last_alpha: f64,
    /// Pending vector clock for the timing-only path.
    timing_pending: Vec<Timestamp>,
    /// Backup-sync: total gradients dropped as too-slow (wasted work).
    pub dropped: u64,
    /// Backup-sync: dropped-gradient count per learner slot (straggler
    /// attribution for the stats server).
    dropped_by: Vec<u64>,
    /// Gradients actually folded per learner slot (drops excluded) — the
    /// per-learner contribution distribution the metrics registry
    /// snapshots ([`crate::obs::metrics`]).
    pushes_by: Vec<u64>,
    /// Decode scratch for [`ShardedServer::push_encoded`]: sparse and
    /// quantized payloads decode into this pooled buffer instead of a
    /// fresh allocation per push (`Dense` still passes through copy-free).
    decode_buf: FlatVec,
    /// Vector-clock spare recycled through the update drains (pending and
    /// timing paths are mutually exclusive per run, so one spare serves
    /// both).
    clock_spare: Vec<Timestamp>,
    /// Server-side dedup backstop ([`crate::netsim::reliable`]): one
    /// window per learner slot over push sequence numbers, armed only
    /// when a fault plane can deliver duplicates (the live engine's
    /// receipt path checks here, where the accumulator lives). `None` =
    /// reliable transport, zero cost.
    dedup: Option<Vec<crate::netsim::reliable::DedupWindow>>,
    /// Pushes the dedup backstop rejected (arrived but not folded).
    pub dedup_dropped: u64,
}

impl ShardedServer {
    /// `optimizer` supplies the kind and weight decay; each shard
    /// allocates its own state slice of matching length.
    pub fn new(
        cfg: ServerConfig,
        theta0: FlatVec,
        optimizer: Optimizer,
        lr: LrPolicy,
    ) -> ShardedServer {
        let spec = ShardSpec::new(theta0.len(), cfg.shards);
        let shards = spec
            .ranges()
            .map(|range| Shard {
                acc: Accumulator::new(cfg.protocol, cfg.lambda, range.len()),
                optimizer: Optimizer::new(optimizer.kind, optimizer.weight_decay, range.len()),
                theta: FlatVec::from_vec(theta0.data[range.clone()].to_vec()),
                range,
                ts: 0,
                updates: 0,
                avg_scratch: FlatVec::zeros(0),
                clock_scratch: Vec::new(),
            })
            .collect();
        ShardedServer {
            id_bound: cfg.lambda,
            dropped_by: vec![0; cfg.lambda],
            pushes_by: vec![0; cfg.lambda],
            cfg,
            spec,
            shards,
            lr,
            staleness: StalenessStats::default(),
            ts: 0,
            pending_ts: Vec::new(),
            pending_from: Vec::new(),
            samples_applied: 0,
            epochs_completed: 0,
            updates: 0,
            last_alpha: 0.0,
            timing_pending: Vec::new(),
            dropped: 0,
            decode_buf: FlatVec::zeros(0),
            clock_spare: Vec::new(),
            dedup: None,
            dedup_dropped: 0,
        }
    }

    /// Arm the per-learner dedup backstop (idempotent). The live engine
    /// calls this when its fault plane can duplicate or retry pushes.
    pub fn arm_dedup(&mut self) {
        if self.dedup.is_none() {
            self.dedup =
                Some(vec![crate::netsim::reliable::DedupWindow::new(); self.id_bound]);
        }
    }

    /// Returns `true` iff the push stamped `seq` from learner `l` should
    /// be folded. Unarmed servers accept everything (exactly-once
    /// transport needs no window); armed ones reject replays and count
    /// them in [`ShardedServer::dedup_dropped`].
    pub fn dedup_accept(&mut self, l: usize, seq: u64) -> bool {
        match self.dedup.as_mut() {
            None => true,
            Some(wins) => {
                if wins[l].accept(seq) {
                    true
                } else {
                    self.dedup_dropped += 1;
                    false
                }
            }
        }
    }

    /// The protocol currently in force (adaptive-n control can change the
    /// softsync splitting parameter mid-run; see
    /// [`ShardedServer::set_softsync_n`]).
    pub fn protocol(&self) -> crate::coordinator::protocol::Protocol {
        self.cfg.protocol
    }

    /// Per-learner dropped-gradient counts (backup-sync straggler
    /// attribution; all zeros for the other protocols).
    pub fn dropped_by(&self) -> &[u64] {
        &self.dropped_by
    }

    /// Per-learner folded-gradient counts (dropped gradients excluded; a
    /// straggler under backup-sync shows up low here and high in
    /// [`ShardedServer::dropped_by`]).
    pub fn pushes_by(&self) -> &[u64] {
        &self.pushes_by
    }

    /// Backup-sync's drop rule (see
    /// [`crate::coordinator::server::ParameterServer`]'s mirror): a
    /// gradient behind the server clock missed its round and is
    /// discarded, booked against its learner.
    fn backup_drop(&mut self, learner: usize, grad_ts: Timestamp) -> bool {
        if matches!(self.cfg.protocol, crate::coordinator::protocol::Protocol::BackupSync { .. })
            && grad_ts < self.ts
        {
            // both counters or neither (in-range ids only), so the
            // `dropped == Σ dropped_by` attribution invariant always holds
            if let Some(d) = self.dropped_by.get_mut(learner) {
                *d += 1;
                self.dropped += 1;
            }
            true
        } else {
            false
        }
    }

    /// Adaptive-n control: retune the n-softsync splitting parameter in
    /// place, *between* updates. Rejects non-softsync protocols and any n
    /// the current λ_active cannot serve (the checked quota). The pending
    /// set is untouched: if the new, smaller quota is already met, the
    /// next push closes the round — no flush is needed, so the single
    /// clock and the shard lockstep are never at risk.
    pub fn set_softsync_n(&mut self, n: usize) -> Result<()> {
        use crate::coordinator::protocol::Protocol;
        if !matches!(self.cfg.protocol, Protocol::NSoftsync { .. }) {
            bail!(
                "adaptive-n control requires an n-softsync protocol, run uses {}",
                self.cfg.protocol.label()
            );
        }
        if n == 0 {
            bail!("n-softsync requires n >= 1");
        }
        let new = Protocol::NSoftsync { n };
        new.try_gradients_per_update(self.cfg.lambda)?;
        for shard in self.shards.iter_mut() {
            shard.acc.set_protocol(new)?;
        }
        self.cfg.protocol = new;
        Ok(())
    }

    pub fn timestamp(&self) -> Timestamp {
        self.ts
    }

    pub fn epoch(&self) -> usize {
        self.epochs_completed
    }

    pub fn samples_applied(&self) -> u64 {
        self.samples_applied
    }

    pub fn n_shards(&self) -> usize {
        self.spec.shards
    }

    pub fn spec(&self) -> ShardSpec {
        self.spec
    }

    /// Per-shard applyUpdate counts (stats reporting). Lockstep shards
    /// mean every entry equals [`ShardedServer::updates`]; a divergence
    /// indicates a routing bug and is asserted against in debug builds.
    pub fn shard_updates(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.updates).collect()
    }

    /// Training completes after `target_epochs` epochs of aggregate
    /// samples have been applied (§3.2).
    pub fn done(&self) -> bool {
        self.epochs_completed >= self.cfg.target_epochs
    }

    /// Gather the sharded weights into one contiguous vector (the
    /// pullWeights payload). Engines cache the result per timestamp, so
    /// this copies at the same rate the unsharded server cloned θ.
    pub fn assemble_weights(&self) -> FlatVec {
        let mut out = FlatVec::zeros(0);
        self.assemble_weights_into(&mut out);
        out
    }

    /// Pooled form of [`ShardedServer::assemble_weights`]: resize `out`
    /// to the model and overwrite every element (the shard ranges
    /// partition θ), so the engines' snapshot pool can recycle one buffer
    /// per clock tick instead of allocating a model-sized vector each —
    /// bit-identical output either way.
    pub fn assemble_weights_into(&self, out: &mut FlatVec) {
        out.data.resize(self.spec.n_params, 0.0);
        for shard in &self.shards {
            out.data[shard.range.clone()].copy_from_slice(&shard.theta.data);
        }
    }

    /// sumGradients: fold one learner's gradient into every shard;
    /// applyUpdate fires on all shards (in parallel for large models) when
    /// the protocol quota is reached.
    pub fn push_gradient(
        &mut self,
        learner: usize,
        grad: &FlatVec,
        grad_ts: Timestamp,
    ) -> Result<PushOutcome> {
        if learner >= self.id_bound {
            bail!("learner id {learner} out of range (λ = {})", self.id_bound);
        }
        anyhow::ensure!(
            grad.len() == self.spec.n_params,
            "gradient length {} != model size {}",
            grad.len(),
            self.spec.n_params
        );
        if self.backup_drop(learner, grad_ts) {
            return Ok(PushOutcome { dropped: true, ..PushOutcome::default() });
        }
        if self.cfg.protocol.is_barrier() && self.pending_from.contains(&learner) {
            bail!("hardsync: learner {learner} pushed twice in one barrier round");
        }
        let scale = if self.lr.is_per_gradient() {
            let sigma = self.ts.saturating_sub(grad_ts);
            1.0 / (sigma as f32 + 1.0)
        } else {
            1.0
        };
        let quota = self.cfg.protocol.gradients_per_update(self.cfg.lambda);
        let will_update = self.pending_ts.len() + 1 >= quota;
        if will_update {
            // applyUpdate fires: fold the final gradient and step every
            // shard, in parallel for large models.
            let alpha = self
                .lr
                .alpha(self.epochs_completed, self.cfg.protocol, self.cfg.mu, self.cfg.lambda);
            self.last_alpha = alpha;
            self.for_each_shard(|shard| {
                shard.fold(grad, learner, grad_ts, scale);
                shard.apply(alpha);
            });
        } else {
            // Fold-only push: the per-shard work is one slice of a single
            // axpy (memory-bound), so forking threads here would cost more
            // than it hides — run the slices serially, same math.
            for shard in self.shards.iter_mut() {
                shard.fold(grad, learner, grad_ts, scale);
            }
        }
        self.pending_ts.push(grad_ts);
        self.pending_from.push(learner);
        self.pushes_by[learner] += 1;

        let mut out = PushOutcome::default();
        if will_update {
            let clock = self.take_pending_clock();
            self.pending_from.clear();
            self.advance_clock(&clock, &mut out);
            self.return_clock(clock);
            debug_assert!(
                self.shards.iter().all(|s| s.ts == self.ts),
                "shard clocks must stay in lockstep with the scalar timestamp"
            );
        }
        Ok(out)
    }

    /// Swap the pending vector clock out against the recycled spare (the
    /// drain side of the no-allocation update path); pair with
    /// [`ShardedServer::return_clock`] once [`ShardedServer::advance_clock`]
    /// has consumed it.
    fn take_pending_clock(&mut self) -> Vec<Timestamp> {
        std::mem::replace(&mut self.pending_ts, std::mem::take(&mut self.clock_spare))
    }

    /// Timing-path twin of [`ShardedServer::take_pending_clock`].
    fn take_timing_clock(&mut self) -> Vec<Timestamp> {
        std::mem::replace(&mut self.timing_pending, std::mem::take(&mut self.clock_spare))
    }

    fn return_clock(&mut self, mut clock: Vec<Timestamp>) {
        clock.clear();
        self.clock_spare = clock;
    }

    /// Decode-then-accumulate ([`crate::comm`]): decode one compressed
    /// gradient and fold it through the normal push path. The decoded
    /// vector is what enters the accumulators, so protocol quotas,
    /// staleness accounting, and the single-clock analysis are oblivious
    /// to the codec — a compressed gradient is one gradient with one
    /// timestamp. Error-feedback residual bookkeeping stays learner-side
    /// ([`crate::comm::codec::LearnerCodec`]); `Dense` payloads (the
    /// `none` codec) pass through without a copy.
    pub fn push_encoded(
        &mut self,
        learner: usize,
        enc: crate::comm::codec::EncodedGrad,
        grad_ts: Timestamp,
    ) -> Result<PushOutcome> {
        match enc {
            // `Dense` (the `none` codec) folds without a copy
            crate::comm::codec::EncodedGrad::Dense(dense) => {
                self.push_gradient(learner, &dense, grad_ts)
            }
            enc => {
                // sparse/quantized payloads decode into the pooled
                // scratch (temporarily moved out to satisfy the borrow
                // of `push_gradient(&mut self, &buf)`)
                let mut buf = std::mem::replace(&mut self.decode_buf, FlatVec::zeros(0));
                enc.decode_into(&mut buf);
                let out = self.push_gradient(learner, &buf, grad_ts);
                self.decode_buf = buf;
                out
            }
        }
    }

    /// Timing-only variant: advances protocol/clock/epoch state (including
    /// every shard's clock, so per-shard stats stay truthful) without
    /// numeric work.
    pub fn push_gradient_timing_only(&mut self, learner: usize, grad_ts: Timestamp) -> PushOutcome {
        if self.backup_drop(learner, grad_ts) {
            return PushOutcome { dropped: true, ..PushOutcome::default() };
        }
        self.timing_pending.push(grad_ts);
        if let Some(p) = self.pushes_by.get_mut(learner) {
            *p += 1;
        }
        let mut out = PushOutcome::default();
        if self.timing_pending.len() >= self.cfg.protocol.gradients_per_update(self.cfg.lambda) {
            let vclock = self.take_timing_clock();
            for shard in self.shards.iter_mut() {
                shard.ts += 1;
                shard.updates += 1;
            }
            self.advance_clock(&vclock, &mut out);
            self.return_clock(vclock);
        }
        out
    }

    /// Current active learner count λ_active (the quota/LR basis).
    pub fn active_lambda(&self) -> usize {
        self.cfg.lambda
    }

    /// Current per-learner mini-batch size μ.
    pub fn mu(&self) -> usize {
        self.cfg.mu
    }

    /// The LR policy this server applies (the rescaler reads it to report
    /// the staleness-aware modulation factor after a membership change).
    pub fn lr_policy(&self) -> &LrPolicy {
        &self.lr
    }

    /// Elastic rescale: change the per-learner mini-batch size μ (the
    /// μ·λ = const rule recomputes it on every membership change). Takes
    /// effect from the next applyUpdate; gradients already in flight keep
    /// their old sample count until folded (first-order approximation).
    pub fn set_mu(&mut self, mu: usize) {
        self.cfg.mu = mu.max(1);
    }

    /// Elastic membership: recompute the collection quota c = ⌊λ/n⌋ for a
    /// changed active learner count, *safely between updates*. Rejects
    /// unsatisfiable quotas (λ_active = 0, or < n under n-softsync). If a
    /// shrink leaves the pending set already at the new quota, the update
    /// fires immediately on every shard (returned as `Some`) — the
    /// membership-aware quorum that keeps hardsync from deadlocking when
    /// a learner dies mid-round. Shard clocks stay in lockstep with the
    /// scalar timestamp throughout.
    pub fn set_active_lambda(&mut self, lambda: usize) -> Result<Option<PushOutcome>> {
        let quota = self.cfg.protocol.try_gradients_per_update(lambda)?;
        self.cfg.lambda = lambda;
        for shard in self.shards.iter_mut() {
            shard.acc.set_active_lambda(lambda)?;
        }
        let mut out = PushOutcome::default();
        if self.pending_ts.len() >= quota && !self.pending_ts.is_empty() {
            let alpha = self
                .lr
                .alpha(self.epochs_completed, self.cfg.protocol, self.cfg.mu, self.cfg.lambda);
            self.last_alpha = alpha;
            self.for_each_shard(|shard| shard.apply(alpha));
            let clock = self.take_pending_clock();
            self.pending_from.clear();
            self.advance_clock(&clock, &mut out);
            self.return_clock(clock);
            debug_assert!(
                self.shards.iter().all(|s| s.ts == self.ts),
                "shard clocks must stay in lockstep across a quota flush"
            );
            return Ok(Some(out));
        }
        if self.timing_pending.len() >= quota && !self.timing_pending.is_empty() {
            let vclock = self.take_timing_clock();
            for shard in self.shards.iter_mut() {
                shard.ts += 1;
                shard.updates += 1;
            }
            self.advance_clock(&vclock, &mut out);
            self.return_clock(vclock);
            return Ok(Some(out));
        }
        Ok(None)
    }

    /// Membership-aware shrink for a learner *death*. Like
    /// [`ShardedServer::set_active_lambda`], but protocol-safe for
    /// hardsync: if the dead learner's own gradient sits in the pending
    /// round, the satisfied-quota flush is suppressed — survivors of that
    /// round still have gradients in flight, and closing the round early
    /// would collide with their next-round pushes (a hardsync double-push
    /// error). The round then completes through the normal push path,
    /// whose per-push quota check already uses the shrunk λ.
    pub fn remove_learner(
        &mut self,
        dead: usize,
        lambda: usize,
    ) -> Result<Option<PushOutcome>> {
        if self.cfg.protocol.is_barrier() && self.pending_from.contains(&dead) {
            let quota = self.cfg.protocol.try_gradients_per_update(lambda)?;
            debug_assert!(quota >= 1);
            self.cfg.lambda = lambda;
            for shard in self.shards.iter_mut() {
                shard.acc.set_active_lambda(lambda)?;
            }
            return Ok(None);
        }
        self.set_active_lambda(lambda)
    }

    /// Serialize the complete server state — per-shard θ slices, optimizer
    /// state, accumulators, shard timestamps, protocol/epoch bookkeeping,
    /// staleness history, and the LR policy — via the offline JSON util
    /// (no serde). [`ShardedServer::from_json`] restores a server that
    /// continues the exact trajectory.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let shard_state: Vec<Json> = self
            .shards
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("start", Json::num(s.range.start as f64)),
                    ("end", Json::num(s.range.end as f64)),
                    ("ts", Json::num(s.ts as f64)),
                    ("updates", Json::num(s.updates as f64)),
                    ("theta", Json::arr_f32(&s.theta.data)),
                    ("optimizer", s.optimizer.to_json()),
                    ("acc", s.acc.to_json()),
                ])
            })
            .collect();
        let mut pairs = vec![
            ("version", Json::num(1.0)),
            ("protocol", Json::str(self.cfg.protocol.label())),
            ("mu", Json::num(self.cfg.mu as f64)),
            ("lambda", Json::num(self.cfg.lambda as f64)),
            ("id_bound", Json::num(self.id_bound as f64)),
            ("samples_per_epoch", Json::num(self.cfg.samples_per_epoch as f64)),
            ("target_epochs", Json::num(self.cfg.target_epochs as f64)),
            ("shards", Json::num(self.spec.shards as f64)),
            ("n_params", Json::num(self.spec.n_params as f64)),
            ("ts", Json::num(self.ts as f64)),
            ("updates", Json::num(self.updates as f64)),
            ("last_alpha", Json::num(self.last_alpha)),
            ("samples_applied", Json::num(self.samples_applied as f64)),
            ("epochs_completed", Json::num(self.epochs_completed as f64)),
            ("pending_ts", Json::arr_u64(&self.pending_ts)),
            (
                "pending_from",
                Json::Arr(self.pending_from.iter().map(|&l| Json::num(l as f64)).collect()),
            ),
            ("timing_pending", Json::arr_u64(&self.timing_pending)),
            ("dropped", Json::num(self.dropped as f64)),
            ("dropped_by", Json::arr_u64(&self.dropped_by)),
            ("pushes_by", Json::arr_u64(&self.pushes_by)),
            ("staleness", self.staleness.to_json()),
            ("lr", self.lr.to_json()),
            ("shard_state", Json::Arr(shard_state)),
        ];
        // Dedup state rides only when armed, so fault-free checkpoints
        // keep the exact pre-chaos byte layout.
        if let Some(wins) = &self.dedup {
            pairs.push(("dedup", crate::netsim::reliable::windows_to_json(wins)));
            pairs.push(("dedup_dropped", Json::num(self.dedup_dropped as f64)));
        }
        Json::obj(pairs)
    }

    /// Restore a server from [`ShardedServer::to_json`] output. Enforces
    /// the single-clock staleness invariant on the way in: every shard
    /// timestamp must equal the scalar clock, or the checkpoint is
    /// rejected (a divergence would silently break the Eq. 2 analysis).
    pub fn from_json(j: &crate::util::json::Json) -> Result<ShardedServer> {
        let version = j.get("version")?.as_u64()?;
        anyhow::ensure!(version == 1, "unsupported server checkpoint version {version}");
        let protocol = crate::coordinator::protocol::Protocol::parse(
            j.get("protocol")?.as_str()?,
        )?;
        let cfg = ServerConfig {
            protocol,
            mu: j.get("mu")?.as_usize()?,
            lambda: j.get("lambda")?.as_usize()?,
            samples_per_epoch: j.get("samples_per_epoch")?.as_u64()?,
            target_epochs: j.get("target_epochs")?.as_usize()?,
            shards: j.get("shards")?.as_usize()?,
        };
        let spec = ShardSpec::new(j.get("n_params")?.as_usize()?, cfg.shards);
        let ts = j.get("ts")?.as_u64()?;
        let raw_shards = j.get("shard_state")?.as_arr()?;
        anyhow::ensure!(
            raw_shards.len() == spec.shards,
            "checkpoint has {} shard records for S = {}",
            raw_shards.len(),
            spec.shards
        );
        let mut shards = Vec::with_capacity(raw_shards.len());
        for (s, sj) in raw_shards.iter().enumerate() {
            let range = sj.get("start")?.as_usize()?..sj.get("end")?.as_usize()?;
            anyhow::ensure!(
                range == spec.range(s),
                "checkpoint shard {s} covers {range:?}, spec expects {:?}",
                spec.range(s)
            );
            let shard_ts = sj.get("ts")?.as_u64()?;
            anyhow::ensure!(
                shard_ts == ts,
                "checkpoint violates the single-clock invariant: shard {s} at ts \
                 {shard_ts}, scalar clock at {ts}"
            );
            let theta = FlatVec::from_vec(sj.get("theta")?.as_f32_vec()?);
            anyhow::ensure!(
                theta.len() == range.len(),
                "checkpoint shard {s}: θ slice has {} params, range holds {}",
                theta.len(),
                range.len()
            );
            shards.push(Shard {
                acc: Accumulator::from_json(protocol, sj.get("acc")?)?,
                optimizer: Optimizer::from_json(sj.get("optimizer")?)?,
                theta,
                range,
                ts: shard_ts,
                updates: sj.get("updates")?.as_u64()?,
                avg_scratch: FlatVec::zeros(0),
                clock_scratch: Vec::new(),
            });
        }
        let id_bound = j.get("id_bound")?.as_usize()?;
        // Drop counters entered the format after v1 shipped; absent fields
        // read as zero so pre-straggler checkpoints stay loadable.
        let dropped = j.get("dropped").and_then(|v| v.as_u64()).unwrap_or(0);
        let dropped_by = match j.get("dropped_by") {
            Ok(v) => v.as_u64_vec()?,
            Err(_) => vec![0; id_bound],
        };
        // Push-contribution counters arrived with the obs layer; same
        // absent-reads-as-zero rule as the drop counters above.
        let pushes_by = match j.get("pushes_by") {
            Ok(v) => v.as_u64_vec()?,
            Err(_) => vec![0; id_bound],
        };
        // Dedup backstop state is present only in fault-armed checkpoints
        // (absent = unarmed, the historical format).
        let dedup = match j.get("dedup") {
            Ok(v) => Some(crate::netsim::reliable::windows_from_json(v, id_bound)?),
            Err(_) => None,
        };
        let dedup_dropped = j.get("dedup_dropped").and_then(|v| v.as_u64()).unwrap_or(0);
        Ok(ShardedServer {
            id_bound,
            dropped,
            dropped_by,
            pushes_by,
            cfg,
            spec,
            shards,
            lr: LrPolicy::from_json(j.get("lr")?)?,
            staleness: crate::coordinator::clock::StalenessStats::from_json(
                j.get("staleness")?,
            )?,
            ts,
            pending_ts: j.get("pending_ts")?.as_u64_vec()?,
            pending_from: j
                .get("pending_from")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<Vec<usize>>>()?,
            samples_applied: j.get("samples_applied")?.as_u64()?,
            epochs_completed: j.get("epochs_completed")?.as_usize()?,
            updates: j.get("updates")?.as_u64()?,
            last_alpha: j.get("last_alpha")?.as_f64()?,
            timing_pending: j.get("timing_pending")?.as_u64_vec()?,
            decode_buf: FlatVec::zeros(0),
            clock_spare: Vec::new(),
            dedup,
            dedup_dropped,
        })
    }

    /// Run `f` over every shard — via a scoped thread pool when the model
    /// is large enough for the fork/join to pay off, serially otherwise.
    /// Shards are independent (disjoint θ ranges), so scheduling order
    /// cannot affect results.
    fn for_each_shard<F: Fn(&mut Shard) + Sync>(&mut self, f: F) {
        let slice_len = self.spec.n_params / self.shards.len();
        if self.shards.len() > 1 && slice_len >= PAR_MIN_SHARD_PARAMS {
            std::thread::scope(|scope| {
                // Each spawned closure must own its captures for `'scope`:
                // copy a shared reference to `f` (F: Sync) and move the
                // per-shard `&mut` in — a non-`move` closure would only
                // reborrow the loop-local binding, which dies each
                // iteration.
                let f = &f;
                for shard in self.shards.iter_mut() {
                    scope.spawn(move || f(shard));
                }
            });
        } else {
            for shard in self.shards.iter_mut() {
                f(shard);
            }
        }
    }

    // Deliberately mirrors `ParameterServer::advance_clock` line for line:
    // the flat server stays the reference implementation, and the
    // `prop_sharded_server_matches_unsharded` property test fails if the
    // two copies of the epoch/staleness bookkeeping ever diverge.
    fn advance_clock(&mut self, vclock: &[Timestamp], out: &mut PushOutcome) {
        self.ts += 1;
        self.updates += 1;
        let rec = self.staleness.record(self.ts, vclock);
        out.updated = true;
        out.avg_staleness = Some(rec.avg_staleness);
        let before = self.samples_applied / self.cfg.samples_per_epoch;
        self.samples_applied += (vclock.len() * self.cfg.mu) as u64;
        let after = self.samples_applied / self.cfg.samples_per_epoch;
        if after > before {
            self.epochs_completed = after as usize;
            out.epoch_completed = Some(self.epochs_completed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::Protocol;
    use crate::coordinator::server::ParameterServer;
    use crate::params::lr::{Modulation, Schedule};
    use crate::params::optimizer::OptimizerKind;

    fn cfg(protocol: Protocol, lambda: usize, shards: usize) -> ServerConfig {
        ServerConfig {
            protocol,
            mu: 4,
            lambda,
            samples_per_epoch: 16,
            target_epochs: 2,
            shards,
        }
    }

    fn lr() -> LrPolicy {
        LrPolicy::new(Schedule::constant(1.0), Modulation::None, 128)
    }

    #[test]
    fn spec_ranges_partition_the_vector() {
        for (n, s) in [(10, 4), (7, 3), (5, 8), (0, 3), (12, 1)] {
            let spec = ShardSpec::new(n, s);
            let mut covered = 0;
            let mut next = 0;
            for r in spec.ranges() {
                assert_eq!(r.start, next, "ranges must be contiguous");
                covered += r.len();
                next = r.end;
            }
            assert_eq!(covered, n);
            // balanced: lengths differ by at most one
            let lens: Vec<usize> = spec.ranges().map(|r| r.len()).collect();
            let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(max - min <= 1, "{lens:?}");
        }
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let spec = ShardSpec::new(4, 0);
        assert_eq!(spec.shards, 1);
        assert_eq!(spec.range(0), 0..4);
    }

    #[test]
    fn single_shard_matches_unsharded_bitwise() {
        let theta0 = FlatVec::from_vec(vec![1.0, -2.0, 0.5, 3.0, 0.25]);
        let mut reference = ParameterServer::new(
            cfg(Protocol::NSoftsync { n: 1 }, 2, 1),
            theta0.clone(),
            Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, 5),
            lr(),
        );
        let mut sharded = ShardedServer::new(
            cfg(Protocol::NSoftsync { n: 1 }, 2, 1),
            theta0,
            Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, 5),
            lr(),
        );
        let g = FlatVec::from_vec(vec![0.3, -0.1, 0.2, 0.05, -0.4]);
        for i in 0..8 {
            let ts = reference.timestamp();
            let a = reference.push_gradient(i % 2, &g, ts).unwrap();
            let b = sharded.push_gradient(i % 2, &g, ts).unwrap();
            assert_eq!(a.updated, b.updated);
            assert_eq!(a.avg_staleness, b.avg_staleness);
            assert_eq!(a.epoch_completed, b.epoch_completed);
        }
        assert_eq!(reference.weights().0.data, sharded.assemble_weights().data);
        assert_eq!(reference.timestamp(), sharded.timestamp());
        assert_eq!(reference.samples_applied(), sharded.samples_applied());
    }

    #[test]
    fn many_shards_match_unsharded() {
        for shards in [2usize, 3, 4, 7] {
            let dim = 11;
            let theta0 = FlatVec::from_vec((0..dim).map(|i| i as f32 * 0.5 - 2.0).collect());
            let mut reference = ParameterServer::new(
                cfg(Protocol::Async, 3, 1),
                theta0.clone(),
                Optimizer::new(OptimizerKind::Adagrad { eps: 1e-8 }, 1e-3, dim),
                lr(),
            );
            let mut sharded = ShardedServer::new(
                cfg(Protocol::Async, 3, shards),
                theta0,
                Optimizer::new(OptimizerKind::Adagrad { eps: 1e-8 }, 1e-3, dim),
                lr(),
            );
            for i in 0..9 {
                let g =
                    FlatVec::from_vec((0..dim).map(|d| ((i + d) % 5) as f32 * 0.1).collect());
                let ts = reference.timestamp();
                reference.push_gradient(i % 3, &g, ts).unwrap();
                sharded.push_gradient(i % 3, &g, ts).unwrap();
            }
            let want = reference.weights().0;
            let got = sharded.assemble_weights();
            for d in 0..dim {
                assert!(
                    (want.data[d] - got.data[d]).abs() <= 1e-6,
                    "S={shards} dim {d}: {} vs {}",
                    got.data[d],
                    want.data[d]
                );
            }
            assert_eq!(sharded.shard_updates(), vec![sharded.updates; shards]);
        }
    }

    #[test]
    fn parallel_apply_path_matches_unsharded() {
        // Large enough that every shard slice crosses PAR_MIN_SHARD_PARAMS
        // so applyUpdate actually runs on scoped threads; results must
        // still match the flat server exactly.
        let dim = 4 * PAR_MIN_SHARD_PARAMS + 17;
        let theta0 = FlatVec::from_vec((0..dim).map(|i| (i % 13) as f32 * 0.1 - 0.6).collect());
        let mut reference = ParameterServer::new(
            cfg(Protocol::Async, 2, 1),
            theta0.clone(),
            Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, dim),
            lr(),
        );
        let mut sharded = ShardedServer::new(
            cfg(Protocol::Async, 2, 4),
            theta0,
            Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, dim),
            lr(),
        );
        let g = FlatVec::from_vec((0..dim).map(|i| ((i % 11) as f32 - 5.0) * 0.01).collect());
        for i in 0..5 {
            let ts = reference.timestamp();
            let a = reference.push_gradient(i % 2, &g, ts).unwrap();
            let b = sharded.push_gradient(i % 2, &g, ts).unwrap();
            assert_eq!(a.updated, b.updated);
        }
        assert_eq!(reference.weights().0.data, sharded.assemble_weights().data);
        assert_eq!(sharded.shard_updates(), vec![5; 4]);
    }

    #[test]
    fn hardsync_rejects_double_push_at_any_shard_count() {
        let mut s = ShardedServer::new(
            cfg(Protocol::Hardsync, 2, 3),
            FlatVec::zeros(6),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 6),
            lr(),
        );
        let g = FlatVec::from_vec(vec![1.0; 6]);
        s.push_gradient(0, &g, 0).unwrap();
        let err = s.push_gradient(0, &g, 0).unwrap_err();
        assert!(err.to_string().contains("twice"), "{err}");
        // the round still completes once the other learner arrives
        let out = s.push_gradient(1, &g, 0).unwrap();
        assert!(out.updated);
        assert_eq!(s.timestamp(), 1);
    }

    #[test]
    fn rejects_out_of_range_learner() {
        let mut s = ShardedServer::new(
            cfg(Protocol::Async, 2, 2),
            FlatVec::zeros(4),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 4),
            lr(),
        );
        let g = FlatVec::zeros(4);
        let err = s.push_gradient(2, &g, 0).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        assert_eq!(s.updates, 0);
    }

    #[test]
    fn per_gradient_modulation_matches_unsharded() {
        let mk = |shards| {
            let c = ServerConfig {
                protocol: Protocol::NSoftsync { n: 2 },
                mu: 4,
                lambda: 2,
                samples_per_epoch: 1_000_000,
                target_epochs: 100,
                shards,
            };
            ShardedServer::new(
                c,
                FlatVec::zeros(3),
                Optimizer::new(OptimizerKind::Sgd, 0.0, 3),
                LrPolicy::new(Schedule::constant(1.0), Modulation::PerGradient, 128),
            )
        };
        let mut a = mk(1);
        let mut b = mk(3);
        let g = FlatVec::from_vec(vec![1.0, 0.5, -0.5]);
        for _ in 0..4 {
            let ts = a.timestamp();
            a.push_gradient(0, &g, ts).unwrap();
            b.push_gradient(0, &g, ts).unwrap();
        }
        // a σ = 3 push is damped identically on both
        let stale_ts = a.timestamp() - 3;
        a.push_gradient(1, &g, stale_ts).unwrap();
        b.push_gradient(1, &g, stale_ts).unwrap();
        assert_eq!(a.assemble_weights().data, b.assemble_weights().data);
    }

    #[test]
    fn backup_sync_sharded_drops_late_gradients_in_lockstep() {
        // λ = 3, b = 1 over 2 shards: rounds close on 2 arrivals; the
        // straggler's late gradient is dropped on every shard alike.
        let mut s = ShardedServer::new(
            cfg(Protocol::BackupSync { b: 1 }, 3, 2),
            FlatVec::zeros(4),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 4),
            lr(),
        );
        let g = FlatVec::from_vec(vec![1.0; 4]);
        assert!(!s.push_gradient(0, &g, 0).unwrap().updated);
        let out = s.push_gradient(1, &g, 0).unwrap();
        assert!(out.updated);
        assert_eq!(s.timestamp(), 1);
        assert_eq!(s.shard_updates(), vec![1, 1]);
        assert_eq!(s.assemble_weights().data, vec![-1.0; 4]);
        let late = s.push_gradient(2, &g, 0).unwrap();
        assert!(late.dropped && !late.updated);
        assert_eq!(s.assemble_weights().data, vec![-1.0; 4], "dropped push folds nothing");
        assert_eq!(s.dropped, 1);
        assert_eq!(s.dropped_by(), &[0, 0, 1]);
        assert_eq!(s.staleness.max, 0);
        // the elastic shrink uses the checked quota: λ_active ≤ b rejected
        assert!(s.set_active_lambda(1).is_err());
        assert_eq!(s.active_lambda(), 3, "failed rescale must not change λ");
        // shrinking to λ = 2 keeps quota 1: next fresh push updates alone
        assert!(s.set_active_lambda(2).unwrap().is_none());
        let out = s.push_gradient(0, &g, 1).unwrap();
        assert!(out.updated);
        assert_eq!(s.shard_updates(), vec![2, 2]);
    }

    #[test]
    fn set_softsync_n_retunes_quota_between_updates() {
        let mut s = ShardedServer::new(
            cfg(Protocol::NSoftsync { n: 1 }, 4, 2),
            FlatVec::zeros(4),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 4),
            lr(),
        );
        let g = FlatVec::from_vec(vec![1.0; 4]);
        // quota ⌊4/1⌋ = 4: two pushes leave the round open
        s.push_gradient(0, &g, 0).unwrap();
        s.push_gradient(1, &g, 0).unwrap();
        s.set_softsync_n(2).unwrap();
        assert_eq!(s.protocol(), Protocol::NSoftsync { n: 2 });
        // new quota ⌊4/2⌋ = 2 already met: the NEXT push closes the round
        // (no flush — the clock only ever advances through a push)
        assert_eq!(s.timestamp(), 0);
        let out = s.push_gradient(2, &g, 0).unwrap();
        assert!(out.updated);
        assert_eq!(s.timestamp(), 1);
        assert_eq!(s.shard_updates(), vec![1, 1], "lockstep preserved across retune");
        // invalid retunes are rejected and leave the protocol unchanged
        assert!(s.set_softsync_n(0).is_err());
        assert!(s.set_softsync_n(5).is_err(), "n > λ_active");
        assert_eq!(s.protocol(), Protocol::NSoftsync { n: 2 });
        let mut hard = ShardedServer::new(
            cfg(Protocol::Hardsync, 2, 1),
            FlatVec::zeros(2),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 2),
            lr(),
        );
        assert!(hard.set_softsync_n(2).is_err(), "adaptive-n is softsync-only");
    }

    #[test]
    fn drop_counters_survive_checkpoint_roundtrip() {
        let mut s = ShardedServer::new(
            cfg(Protocol::BackupSync { b: 1 }, 3, 2),
            FlatVec::zeros(4),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 4),
            lr(),
        );
        let g = FlatVec::from_vec(vec![1.0; 4]);
        s.push_gradient(0, &g, 0).unwrap();
        s.push_gradient(1, &g, 0).unwrap();
        s.push_gradient(2, &g, 0).unwrap(); // dropped
        let text = s.to_json().to_string();
        let back =
            ShardedServer::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.protocol(), Protocol::BackupSync { b: 1 });
        assert_eq!(back.dropped, 1);
        assert_eq!(back.dropped_by(), s.dropped_by());
    }

    #[test]
    fn lambda_shrink_flushes_on_every_shard_in_lockstep() {
        // hardsync λ=3 over 3 shards: two push, the third dies. The quota
        // flush must apply on every shard and keep the clocks in lockstep.
        let mut s = ShardedServer::new(
            cfg(Protocol::Hardsync, 3, 3),
            FlatVec::zeros(6),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 6),
            lr(),
        );
        let g = FlatVec::from_vec(vec![1.0; 6]);
        assert!(!s.push_gradient(0, &g, 0).unwrap().updated);
        assert!(!s.push_gradient(1, &g, 0).unwrap().updated);
        let out = s.set_active_lambda(2).unwrap().expect("quota met → flush");
        assert!(out.updated);
        assert_eq!(s.timestamp(), 1);
        assert_eq!(s.shard_updates(), vec![1, 1, 1]);
        assert_eq!(s.assemble_weights().data, vec![-1.0; 6]);
        // the shrunk quota governs the next round: 2 pushes now update
        s.push_gradient(0, &g, 1).unwrap();
        let out = s.push_gradient(1, &g, 1).unwrap();
        assert!(out.updated);
        // dead learner 2's id stays addressable for rejoin
        assert!(s.set_active_lambda(3).unwrap().is_none());
        s.push_gradient(2, &g, 2).unwrap();
        assert_eq!(s.active_lambda(), 3);
    }

    #[test]
    fn remove_learner_defers_flush_while_dead_gradient_pends() {
        // hardsync λ=3: learners 0 and 2 pushed; learner 2 dies. Its
        // gradient is in the pending round, so the shrink must NOT close
        // the round — learner 1's gradient is still in flight, and an
        // early close would make 1's next-round push collide (the
        // double-push regression this API exists to prevent).
        let mut s = ShardedServer::new(
            cfg(Protocol::Hardsync, 3, 2),
            FlatVec::zeros(4),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 4),
            lr(),
        );
        let g = FlatVec::from_vec(vec![1.0; 4]);
        s.push_gradient(0, &g, 0).unwrap();
        s.push_gradient(2, &g, 0).unwrap();
        let flush = s.remove_learner(2, 2).unwrap();
        assert!(flush.is_none(), "round containing the dead gradient must stay open");
        assert_eq!(s.timestamp(), 0);
        // learner 1's in-flight gradient lands: the round closes with all
        // three contributions under the shrunk quota…
        let out = s.push_gradient(1, &g, 0).unwrap();
        assert!(out.updated);
        assert_eq!(s.timestamp(), 1);
        // …and the survivors' next round proceeds without a double-push.
        s.push_gradient(0, &g, 1).unwrap();
        let out = s.push_gradient(1, &g, 1).unwrap();
        assert!(out.updated);
        // By contrast, a dead learner that never pushed flushes at once
        // (the deadlock case): rebuild the 0/1-pushed state.
        let mut s2 = ShardedServer::new(
            cfg(Protocol::Hardsync, 3, 2),
            FlatVec::zeros(4),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 4),
            lr(),
        );
        s2.push_gradient(0, &g, 0).unwrap();
        s2.push_gradient(1, &g, 0).unwrap();
        let out = s2.remove_learner(2, 2).unwrap().expect("quorum complete → flush");
        assert!(out.updated);
    }

    #[test]
    fn lambda_rescale_matches_flat_server() {
        // The flat server is the reference: a shrink-triggered flush must
        // produce identical weights on both.
        let theta0 = FlatVec::from_vec(vec![0.5, -1.0, 2.0, 0.0, 1.5]);
        let mut flat = ParameterServer::new(
            cfg(Protocol::NSoftsync { n: 1 }, 4, 1),
            theta0.clone(),
            Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, 5),
            lr(),
        );
        let mut sharded = ShardedServer::new(
            cfg(Protocol::NSoftsync { n: 1 }, 4, 3),
            theta0,
            Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, 5),
            lr(),
        );
        let g = FlatVec::from_vec(vec![0.1, -0.2, 0.3, 0.4, -0.5]);
        for l in 0..3 {
            flat.push_gradient(l, &g, 0).unwrap();
            sharded.push_gradient(l, &g, 0).unwrap();
        }
        let a = flat.set_active_lambda(3).unwrap().expect("flush");
        let b = sharded.set_active_lambda(3).unwrap().expect("flush");
        assert_eq!(a.updated, b.updated);
        assert_eq!(a.avg_staleness, b.avg_staleness);
        assert_eq!(flat.weights().0.data, sharded.assemble_weights().data);
        assert_eq!(flat.timestamp(), sharded.timestamp());
    }

    #[test]
    fn json_roundtrip_is_bit_identical_and_resumes() {
        let mut orig = ShardedServer::new(
            cfg(Protocol::NSoftsync { n: 2 }, 4, 4),
            FlatVec::from_vec((0..11).map(|i| i as f32 * 0.37 - 1.9).collect()),
            Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 1e-4, 11),
            lr(),
        );
        let g = FlatVec::from_vec((0..11).map(|i| ((i % 7) as f32 - 3.0) * 0.13).collect());
        // leave the accumulator mid-round (5 pushes at quota 2 → 1 pending)
        for i in 0..5 {
            let ts = orig.timestamp();
            orig.push_gradient(i % 4, &g, ts).unwrap();
        }
        let text = orig.to_json().to_string();
        let mut restored =
            ShardedServer::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(restored.timestamp(), orig.timestamp());
        assert_eq!(restored.assemble_weights().data, orig.assemble_weights().data);
        assert_eq!(restored.shard_updates(), orig.shard_updates());
        assert_eq!(restored.staleness.count, orig.staleness.count);
        // resuming pushes produces bit-identical trajectories
        for i in 0..6 {
            let ts = orig.timestamp();
            let a = orig.push_gradient(i % 4, &g, ts).unwrap();
            let b = restored.push_gradient(i % 4, &g, ts).unwrap();
            assert_eq!(a.updated, b.updated);
            assert_eq!(a.avg_staleness, b.avg_staleness);
        }
        assert_eq!(restored.assemble_weights().data, orig.assemble_weights().data);
        assert_eq!(restored.samples_applied(), orig.samples_applied());
    }

    #[test]
    fn from_json_rejects_broken_clock_invariant() {
        let s = ShardedServer::new(
            cfg(Protocol::Async, 2, 2),
            FlatVec::zeros(4),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 4),
            lr(),
        );
        let mut j = s.to_json();
        // corrupt one shard's timestamp
        if let crate::util::json::Json::Obj(m) = &mut j {
            if let Some(crate::util::json::Json::Arr(shards)) = m.get_mut("shard_state") {
                if let crate::util::json::Json::Obj(sm) = &mut shards[1] {
                    sm.insert("ts".to_string(), crate::util::json::Json::num(7.0));
                }
            }
        }
        let err = ShardedServer::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("single-clock"), "{err}");
    }

    #[test]
    fn timing_only_matches_numeric_clocking() {
        let mut numeric = ShardedServer::new(
            cfg(Protocol::NSoftsync { n: 2 }, 2, 4),
            FlatVec::zeros(8),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 8),
            lr(),
        );
        let mut timing = ShardedServer::new(
            cfg(Protocol::NSoftsync { n: 2 }, 2, 4),
            FlatVec::zeros(0),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
            lr(),
        );
        let g = FlatVec::zeros(8);
        for i in 0..6 {
            let a = numeric.push_gradient(i % 2, &g, numeric.timestamp()).unwrap();
            let b = timing.push_gradient_timing_only(i % 2, timing.timestamp());
            assert_eq!(a.updated, b.updated);
            assert_eq!(a.avg_staleness, b.avg_staleness);
        }
        assert_eq!(numeric.timestamp(), timing.timestamp());
        assert_eq!(numeric.shard_updates(), timing.shard_updates());
    }
}
