//! Sharded parameter server: S contiguous weight shards, parallel
//! applyUpdate (§3.3's root-bottleneck fix).
//!
//! The paper identifies the root parameter server as the scalability wall
//! at λ = 30: every learner's push serializes through one NIC endpoint and
//! one applyUpdate loop ("if 16 tasks are sending 300 MB to the same
//! receiver and there is link contention, it would take over a second").
//! The canonical fix — the Downpour/DistBelief-style sharded server — is
//! to split the flat parameter vector θ into `S` contiguous shards, each
//! owning its slice of the accumulator, optimizer state, and weights, so
//! sumGradients and applyUpdate run per shard in parallel and push/pull
//! traffic spreads over `S` independent endpoints (see
//! [`crate::netsim::cluster::Fabric::send_to_shards`]).
//!
//! **Semantics are unchanged by construction.** Every push delivers one
//! slice to every shard, so all shard quotas fill on the same push and all
//! shards apply the same update step with the same scalar α. Per-shard
//! timestamps therefore advance in lockstep with the shared scalar clock,
//! which is exactly the property that keeps the paper's staleness analysis
//! (one scalar timestamp per model, Eq. 2) intact — the distinction the
//! paper draws against DistBelief's independently-clocked shards. At any
//! `S` the folded arithmetic is the same per-coordinate operations in the
//! same order as the unsharded [`ParameterServer`], so fixed-seed
//! trajectories are bit-identical at `S = 1` and equal within float
//! round-off at any `S` (see `prop_sharded_server_matches_unsharded`).
//!
//! Parallelism uses `std::thread::scope` over the shard set, gated on the
//! shard slices being large enough (`PAR_MIN_SHARD_PARAMS`) for fork/join to pay for
//! itself; below the threshold shards apply serially, with identical
//! results either way.

use std::ops::Range;

use anyhow::{bail, Result};

use crate::coordinator::clock::{StalenessStats, Timestamp};
use crate::coordinator::protocol::Accumulator;
use crate::coordinator::server::{PushOutcome, ServerConfig};
use crate::params::lr::LrPolicy;
use crate::params::optimizer::Optimizer;
use crate::params::FlatVec;

/// Below this many parameters *per shard slice*, fork/join costs more
/// than the axpy it parallelizes; shards run serially (same results
/// either way).
const PAR_MIN_SHARD_PARAMS: usize = 8_192;

/// Contiguous partition of a flat parameter vector into `S` shards whose
/// lengths differ by at most one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub n_params: usize,
    pub shards: usize,
}

impl ShardSpec {
    /// `shards` is clamped to ≥ 1 so a zero in a hand-built config cannot
    /// produce an empty server.
    pub fn new(n_params: usize, shards: usize) -> ShardSpec {
        ShardSpec { n_params, shards: shards.max(1) }
    }

    /// Half-open parameter range owned by shard `s`.
    pub fn range(&self, s: usize) -> Range<usize> {
        debug_assert!(s < self.shards);
        let base = self.n_params / self.shards;
        let rem = self.n_params % self.shards;
        let start = s * base + s.min(rem);
        let len = base + usize::from(s < rem);
        start..start + len
    }

    pub fn ranges(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.shards).map(|s| self.range(s))
    }
}

/// One shard: a contiguous slice of θ with its own accumulator, optimizer
/// state, and timestamp.
#[derive(Debug)]
pub struct Shard {
    pub range: Range<usize>,
    acc: Accumulator,
    optimizer: Optimizer,
    theta: FlatVec,
    /// Lockstep with the server's scalar clock (asserted after updates).
    pub ts: Timestamp,
    /// applyUpdate count for this shard (stats reporting).
    pub updates: u64,
}

impl Shard {
    /// Fold this shard's slice of one pushed gradient. The caller
    /// ([`ShardedServer::push_gradient`]) has already validated the
    /// learner id and hardsync dedup, so the accumulator cannot reject.
    fn fold(&mut self, grad: &FlatVec, learner: usize, grad_ts: Timestamp, scale: f32) {
        self.acc
            .push_scaled_slice(learner, &grad.data[self.range.clone()], grad_ts, scale)
            .expect("shard push pre-validated by ShardedServer");
    }

    /// applyUpdate for this shard: drain the accumulator and step θ.
    fn apply(&mut self, alpha: f64) {
        let (avg, _clock) = self.acc.take_update();
        self.optimizer.apply(&mut self.theta, &avg, alpha as f32);
        self.ts += 1;
        self.updates += 1;
    }
}

/// Parameter server over `S` shards. Drop-in for [`ParameterServer`] in
/// both engines: same protocol semantics, staleness accounting, epoch
/// bookkeeping, and LR modulation, with the numeric work split across
/// shards and applied in parallel.
///
/// [`ParameterServer`]: crate::coordinator::server::ParameterServer
pub struct ShardedServer {
    pub cfg: ServerConfig,
    spec: ShardSpec,
    shards: Vec<Shard>,
    lr: LrPolicy,
    pub staleness: StalenessStats,
    /// Shared scalar timestamp (all shards advance in lockstep with it).
    ts: Timestamp,
    /// Shared vector clock in waiting (timestamps of pending gradients).
    pending_ts: Vec<Timestamp>,
    /// Learner ids contributing to the pending update (hardsync dedup).
    pending_from: Vec<usize>,
    samples_applied: u64,
    epochs_completed: usize,
    /// Number of weight updates applied (aggregate; equals every shard's
    /// own count).
    pub updates: u64,
    /// α actually used for the most recent update (for logging).
    pub last_alpha: f64,
    /// Pending vector clock for the timing-only path.
    timing_pending: Vec<Timestamp>,
}

impl ShardedServer {
    /// `optimizer` supplies the kind and weight decay; each shard
    /// allocates its own state slice of matching length.
    pub fn new(
        cfg: ServerConfig,
        theta0: FlatVec,
        optimizer: Optimizer,
        lr: LrPolicy,
    ) -> ShardedServer {
        let spec = ShardSpec::new(theta0.len(), cfg.shards);
        let shards = spec
            .ranges()
            .map(|range| Shard {
                acc: Accumulator::new(cfg.protocol, cfg.lambda, range.len()),
                optimizer: Optimizer::new(optimizer.kind, optimizer.weight_decay, range.len()),
                theta: FlatVec::from_vec(theta0.data[range.clone()].to_vec()),
                range,
                ts: 0,
                updates: 0,
            })
            .collect();
        ShardedServer {
            cfg,
            spec,
            shards,
            lr,
            staleness: StalenessStats::default(),
            ts: 0,
            pending_ts: Vec::new(),
            pending_from: Vec::new(),
            samples_applied: 0,
            epochs_completed: 0,
            updates: 0,
            last_alpha: 0.0,
            timing_pending: Vec::new(),
        }
    }

    pub fn timestamp(&self) -> Timestamp {
        self.ts
    }

    pub fn epoch(&self) -> usize {
        self.epochs_completed
    }

    pub fn samples_applied(&self) -> u64 {
        self.samples_applied
    }

    pub fn n_shards(&self) -> usize {
        self.spec.shards
    }

    pub fn spec(&self) -> ShardSpec {
        self.spec
    }

    /// Per-shard applyUpdate counts (stats reporting). Lockstep shards
    /// mean every entry equals [`ShardedServer::updates`]; a divergence
    /// indicates a routing bug and is asserted against in debug builds.
    pub fn shard_updates(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.updates).collect()
    }

    /// Training completes after `target_epochs` epochs of aggregate
    /// samples have been applied (§3.2).
    pub fn done(&self) -> bool {
        self.epochs_completed >= self.cfg.target_epochs
    }

    /// Gather the sharded weights into one contiguous vector (the
    /// pullWeights payload). Engines cache the result per timestamp, so
    /// this copies at the same rate the unsharded server cloned θ.
    pub fn assemble_weights(&self) -> FlatVec {
        let mut out = FlatVec::zeros(self.spec.n_params);
        for shard in &self.shards {
            out.data[shard.range.clone()].copy_from_slice(&shard.theta.data);
        }
        out
    }

    /// sumGradients: fold one learner's gradient into every shard;
    /// applyUpdate fires on all shards (in parallel for large models) when
    /// the protocol quota is reached.
    pub fn push_gradient(
        &mut self,
        learner: usize,
        grad: &FlatVec,
        grad_ts: Timestamp,
    ) -> Result<PushOutcome> {
        if learner >= self.cfg.lambda {
            bail!("learner id {learner} out of range (λ = {})", self.cfg.lambda);
        }
        anyhow::ensure!(
            grad.len() == self.spec.n_params,
            "gradient length {} != model size {}",
            grad.len(),
            self.spec.n_params
        );
        if self.cfg.protocol.is_barrier() && self.pending_from.contains(&learner) {
            bail!("hardsync: learner {learner} pushed twice in one barrier round");
        }
        let scale = if self.lr.is_per_gradient() {
            let sigma = self.ts.saturating_sub(grad_ts);
            1.0 / (sigma as f32 + 1.0)
        } else {
            1.0
        };
        let quota = self.cfg.protocol.gradients_per_update(self.cfg.lambda);
        let will_update = self.pending_ts.len() + 1 >= quota;
        if will_update {
            // applyUpdate fires: fold the final gradient and step every
            // shard, in parallel for large models.
            let alpha = self
                .lr
                .alpha(self.epochs_completed, self.cfg.protocol, self.cfg.mu, self.cfg.lambda);
            self.last_alpha = alpha;
            self.for_each_shard(|shard| {
                shard.fold(grad, learner, grad_ts, scale);
                shard.apply(alpha);
            });
        } else {
            // Fold-only push: the per-shard work is one slice of a single
            // axpy (memory-bound), so forking threads here would cost more
            // than it hides — run the slices serially, same math.
            for shard in self.shards.iter_mut() {
                shard.fold(grad, learner, grad_ts, scale);
            }
        }
        self.pending_ts.push(grad_ts);
        self.pending_from.push(learner);

        let mut out = PushOutcome::default();
        if will_update {
            let clock = std::mem::take(&mut self.pending_ts);
            self.pending_from.clear();
            self.advance_clock(&clock, &mut out);
            debug_assert!(
                self.shards.iter().all(|s| s.ts == self.ts),
                "shard clocks must stay in lockstep with the scalar timestamp"
            );
        }
        Ok(out)
    }

    /// Timing-only variant: advances protocol/clock/epoch state (including
    /// every shard's clock, so per-shard stats stay truthful) without
    /// numeric work.
    pub fn push_gradient_timing_only(&mut self, _learner: usize, grad_ts: Timestamp) -> PushOutcome {
        self.timing_pending.push(grad_ts);
        let mut out = PushOutcome::default();
        if self.timing_pending.len() >= self.cfg.protocol.gradients_per_update(self.cfg.lambda) {
            let vclock = std::mem::take(&mut self.timing_pending);
            for shard in self.shards.iter_mut() {
                shard.ts += 1;
                shard.updates += 1;
            }
            self.advance_clock(&vclock, &mut out);
        }
        out
    }

    /// Run `f` over every shard — via a scoped thread pool when the model
    /// is large enough for the fork/join to pay off, serially otherwise.
    /// Shards are independent (disjoint θ ranges), so scheduling order
    /// cannot affect results.
    fn for_each_shard<F: Fn(&mut Shard) + Sync>(&mut self, f: F) {
        let slice_len = self.spec.n_params / self.shards.len();
        if self.shards.len() > 1 && slice_len >= PAR_MIN_SHARD_PARAMS {
            std::thread::scope(|scope| {
                // Each spawned closure must own its captures for `'scope`:
                // copy a shared reference to `f` (F: Sync) and move the
                // per-shard `&mut` in — a non-`move` closure would only
                // reborrow the loop-local binding, which dies each
                // iteration.
                let f = &f;
                for shard in self.shards.iter_mut() {
                    scope.spawn(move || f(shard));
                }
            });
        } else {
            for shard in self.shards.iter_mut() {
                f(shard);
            }
        }
    }

    // Deliberately mirrors `ParameterServer::advance_clock` line for line:
    // the flat server stays the reference implementation, and the
    // `prop_sharded_server_matches_unsharded` property test fails if the
    // two copies of the epoch/staleness bookkeeping ever diverge.
    fn advance_clock(&mut self, vclock: &[Timestamp], out: &mut PushOutcome) {
        self.ts += 1;
        self.updates += 1;
        let rec = self.staleness.record(self.ts, vclock);
        out.updated = true;
        out.avg_staleness = Some(rec.avg_staleness);
        let before = self.samples_applied / self.cfg.samples_per_epoch;
        self.samples_applied += (vclock.len() * self.cfg.mu) as u64;
        let after = self.samples_applied / self.cfg.samples_per_epoch;
        if after > before {
            self.epochs_completed = after as usize;
            out.epoch_completed = Some(self.epochs_completed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::Protocol;
    use crate::coordinator::server::ParameterServer;
    use crate::params::lr::{Modulation, Schedule};
    use crate::params::optimizer::OptimizerKind;

    fn cfg(protocol: Protocol, lambda: usize, shards: usize) -> ServerConfig {
        ServerConfig {
            protocol,
            mu: 4,
            lambda,
            samples_per_epoch: 16,
            target_epochs: 2,
            shards,
        }
    }

    fn lr() -> LrPolicy {
        LrPolicy::new(Schedule::constant(1.0), Modulation::None, 128)
    }

    #[test]
    fn spec_ranges_partition_the_vector() {
        for (n, s) in [(10, 4), (7, 3), (5, 8), (0, 3), (12, 1)] {
            let spec = ShardSpec::new(n, s);
            let mut covered = 0;
            let mut next = 0;
            for r in spec.ranges() {
                assert_eq!(r.start, next, "ranges must be contiguous");
                covered += r.len();
                next = r.end;
            }
            assert_eq!(covered, n);
            // balanced: lengths differ by at most one
            let lens: Vec<usize> = spec.ranges().map(|r| r.len()).collect();
            let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(max - min <= 1, "{lens:?}");
        }
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let spec = ShardSpec::new(4, 0);
        assert_eq!(spec.shards, 1);
        assert_eq!(spec.range(0), 0..4);
    }

    #[test]
    fn single_shard_matches_unsharded_bitwise() {
        let theta0 = FlatVec::from_vec(vec![1.0, -2.0, 0.5, 3.0, 0.25]);
        let mut reference = ParameterServer::new(
            cfg(Protocol::NSoftsync { n: 1 }, 2, 1),
            theta0.clone(),
            Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, 5),
            lr(),
        );
        let mut sharded = ShardedServer::new(
            cfg(Protocol::NSoftsync { n: 1 }, 2, 1),
            theta0,
            Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, 5),
            lr(),
        );
        let g = FlatVec::from_vec(vec![0.3, -0.1, 0.2, 0.05, -0.4]);
        for i in 0..8 {
            let ts = reference.timestamp();
            let a = reference.push_gradient(i % 2, &g, ts).unwrap();
            let b = sharded.push_gradient(i % 2, &g, ts).unwrap();
            assert_eq!(a.updated, b.updated);
            assert_eq!(a.avg_staleness, b.avg_staleness);
            assert_eq!(a.epoch_completed, b.epoch_completed);
        }
        assert_eq!(reference.weights().0.data, sharded.assemble_weights().data);
        assert_eq!(reference.timestamp(), sharded.timestamp());
        assert_eq!(reference.samples_applied(), sharded.samples_applied());
    }

    #[test]
    fn many_shards_match_unsharded() {
        for shards in [2usize, 3, 4, 7] {
            let dim = 11;
            let theta0 = FlatVec::from_vec((0..dim).map(|i| i as f32 * 0.5 - 2.0).collect());
            let mut reference = ParameterServer::new(
                cfg(Protocol::Async, 3, 1),
                theta0.clone(),
                Optimizer::new(OptimizerKind::Adagrad { eps: 1e-8 }, 1e-3, dim),
                lr(),
            );
            let mut sharded = ShardedServer::new(
                cfg(Protocol::Async, 3, shards),
                theta0,
                Optimizer::new(OptimizerKind::Adagrad { eps: 1e-8 }, 1e-3, dim),
                lr(),
            );
            for i in 0..9 {
                let g =
                    FlatVec::from_vec((0..dim).map(|d| ((i + d) % 5) as f32 * 0.1).collect());
                let ts = reference.timestamp();
                reference.push_gradient(i % 3, &g, ts).unwrap();
                sharded.push_gradient(i % 3, &g, ts).unwrap();
            }
            let want = reference.weights().0;
            let got = sharded.assemble_weights();
            for d in 0..dim {
                assert!(
                    (want.data[d] - got.data[d]).abs() <= 1e-6,
                    "S={shards} dim {d}: {} vs {}",
                    got.data[d],
                    want.data[d]
                );
            }
            assert_eq!(sharded.shard_updates(), vec![sharded.updates; shards]);
        }
    }

    #[test]
    fn parallel_apply_path_matches_unsharded() {
        // Large enough that every shard slice crosses PAR_MIN_SHARD_PARAMS
        // so applyUpdate actually runs on scoped threads; results must
        // still match the flat server exactly.
        let dim = 4 * PAR_MIN_SHARD_PARAMS + 17;
        let theta0 = FlatVec::from_vec((0..dim).map(|i| (i % 13) as f32 * 0.1 - 0.6).collect());
        let mut reference = ParameterServer::new(
            cfg(Protocol::Async, 2, 1),
            theta0.clone(),
            Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, dim),
            lr(),
        );
        let mut sharded = ShardedServer::new(
            cfg(Protocol::Async, 2, 4),
            theta0,
            Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, dim),
            lr(),
        );
        let g = FlatVec::from_vec((0..dim).map(|i| ((i % 11) as f32 - 5.0) * 0.01).collect());
        for i in 0..5 {
            let ts = reference.timestamp();
            let a = reference.push_gradient(i % 2, &g, ts).unwrap();
            let b = sharded.push_gradient(i % 2, &g, ts).unwrap();
            assert_eq!(a.updated, b.updated);
        }
        assert_eq!(reference.weights().0.data, sharded.assemble_weights().data);
        assert_eq!(sharded.shard_updates(), vec![5; 4]);
    }

    #[test]
    fn hardsync_rejects_double_push_at_any_shard_count() {
        let mut s = ShardedServer::new(
            cfg(Protocol::Hardsync, 2, 3),
            FlatVec::zeros(6),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 6),
            lr(),
        );
        let g = FlatVec::from_vec(vec![1.0; 6]);
        s.push_gradient(0, &g, 0).unwrap();
        let err = s.push_gradient(0, &g, 0).unwrap_err();
        assert!(err.to_string().contains("twice"), "{err}");
        // the round still completes once the other learner arrives
        let out = s.push_gradient(1, &g, 0).unwrap();
        assert!(out.updated);
        assert_eq!(s.timestamp(), 1);
    }

    #[test]
    fn rejects_out_of_range_learner() {
        let mut s = ShardedServer::new(
            cfg(Protocol::Async, 2, 2),
            FlatVec::zeros(4),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 4),
            lr(),
        );
        let g = FlatVec::zeros(4);
        let err = s.push_gradient(2, &g, 0).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        assert_eq!(s.updates, 0);
    }

    #[test]
    fn per_gradient_modulation_matches_unsharded() {
        let mk = |shards| {
            let c = ServerConfig {
                protocol: Protocol::NSoftsync { n: 2 },
                mu: 4,
                lambda: 2,
                samples_per_epoch: 1_000_000,
                target_epochs: 100,
                shards,
            };
            ShardedServer::new(
                c,
                FlatVec::zeros(3),
                Optimizer::new(OptimizerKind::Sgd, 0.0, 3),
                LrPolicy::new(Schedule::constant(1.0), Modulation::PerGradient, 128),
            )
        };
        let mut a = mk(1);
        let mut b = mk(3);
        let g = FlatVec::from_vec(vec![1.0, 0.5, -0.5]);
        for _ in 0..4 {
            let ts = a.timestamp();
            a.push_gradient(0, &g, ts).unwrap();
            b.push_gradient(0, &g, ts).unwrap();
        }
        // a σ = 3 push is damped identically on both
        let stale_ts = a.timestamp() - 3;
        a.push_gradient(1, &g, stale_ts).unwrap();
        b.push_gradient(1, &g, stale_ts).unwrap();
        assert_eq!(a.assemble_weights().data, b.assemble_weights().data);
    }

    #[test]
    fn timing_only_matches_numeric_clocking() {
        let mut numeric = ShardedServer::new(
            cfg(Protocol::NSoftsync { n: 2 }, 2, 4),
            FlatVec::zeros(8),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 8),
            lr(),
        );
        let mut timing = ShardedServer::new(
            cfg(Protocol::NSoftsync { n: 2 }, 2, 4),
            FlatVec::zeros(0),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
            lr(),
        );
        let g = FlatVec::zeros(8);
        for i in 0..6 {
            let a = numeric.push_gradient(i % 2, &g, numeric.timestamp()).unwrap();
            let b = timing.push_gradient_timing_only(i % 2, timing.timestamp());
            assert_eq!(a.updated, b.updated);
            assert_eq!(a.avg_staleness, b.avg_staleness);
        }
        assert_eq!(numeric.timestamp(), timing.timestamp());
        assert_eq!(numeric.shard_updates(), timing.shard_updates());
    }
}
