//! Layer-3 coordinator — the paper's system contribution.
//!
//! A parameter-server training framework in the paper's image (§2–3):
//! learners run getMinibatch → pullWeights → calcGradient → pushGradient;
//! the server runs sumGradients → applyUpdate under one of three
//! synchronization protocols ([`protocol`]); scalar timestamps and a
//! per-update vector clock ([`clock`]) quantify gradient staleness; the
//! Rudra-adv/adv\* topologies ([`tree`], [`buffer`]) trade staleness
//! control for communication overlap.
//!
//! The server comes in two equivalent shapes: the flat [`server`] (single
//! accumulator/optimizer over the whole θ, the reference implementation)
//! and the sharded [`shard`] server (S contiguous shards applied in
//! parallel, the §3.3 root-bottleneck fix) that both engines drive.
//!
//! Two engines drive the same server/learner logic:
//! * [`engine_sim`] — deterministic virtual-time execution with real
//!   gradients; cluster timing comes from [`crate::netsim`].
//! * [`engine_live`] — std::thread + mpsc "production" execution.

pub mod buffer;
pub mod clock;
pub mod engine_live;
pub mod engine_sim;
pub mod learner;
pub mod protocol;
pub mod server;
pub mod shard;
pub mod tree;
