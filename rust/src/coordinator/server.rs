//! The parameter server (§2, §3.2): sumGradients + applyUpdate.
//!
//! Engine-agnostic state machine — both the virtual-time and the live
//! engine drive this same struct, so protocol semantics, staleness
//! accounting, and LR modulation are identical across engines. The server
//! holds the single authoritative copy of the weights together with their
//! scalar timestamp, and records the vector clock of every update.

use anyhow::Result;

use crate::coordinator::clock::{StalenessStats, Timestamp};
use crate::coordinator::protocol::{Accumulator, Protocol};
use crate::params::lr::LrPolicy;
use crate::params::optimizer::Optimizer;
use crate::params::FlatVec;

/// Static run parameters the server needs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub protocol: Protocol,
    pub mu: usize,
    pub lambda: usize,
    /// Samples per epoch (the paper's epoch = one aggregate pass).
    pub samples_per_epoch: u64,
    pub target_epochs: usize,
    /// Parameter shards at the root tier (1 = the flat server of the
    /// paper; >1 = the Downpour-style sharded server of
    /// [`crate::coordinator::shard`]). This flat [`ParameterServer`]
    /// ignores the knob and always behaves as one shard.
    pub shards: usize,
}

/// Result of folding one pushed gradient into the server.
#[derive(Debug, Clone, Default)]
pub struct PushOutcome {
    /// Set when this push triggered applyUpdate.
    pub updated: bool,
    /// ⟨σ⟩ of the triggered update (Eq. 2), if any.
    pub avg_staleness: Option<f64>,
    /// Epoch boundary crossed by this update, if any.
    pub epoch_completed: Option<usize>,
    /// Backup-sync only: the gradient arrived after its round closed (one
    /// of the b slowest) and was dropped — nothing was folded. The engine
    /// refreshes the learner with current weights instead of barriering it.
    pub dropped: bool,
}

/// The parameter server.
pub struct ParameterServer {
    pub cfg: ServerConfig,
    theta: FlatVec,
    ts: Timestamp,
    acc: Accumulator,
    optimizer: Optimizer,
    lr: LrPolicy,
    pub staleness: StalenessStats,
    /// Aggregate samples folded into updates so far.
    samples_applied: u64,
    epochs_completed: usize,
    /// Number of weight updates applied.
    pub updates: u64,
    /// α actually used for the most recent update (for logging).
    pub last_alpha: f64,
    /// Pending vector clock for the timing-only path (no FlatVec math).
    timing_pending: Vec<Timestamp>,
    /// Backup-sync: total gradients dropped as too-slow (wasted work).
    pub dropped: u64,
    /// Backup-sync: dropped-gradient count per learner slot (straggler
    /// attribution for the stats server).
    dropped_by: Vec<u64>,
    /// Decode scratch for [`ParameterServer::push_encoded`], mirroring
    /// the sharded server's pool: compressed payloads decode into one
    /// reused buffer; `Dense` still passes through copy-free.
    decode_buf: FlatVec,
    /// applyUpdate scratch pair for [`Accumulator::drain_update`]: the
    /// drained average and vector clock land here and the displaced
    /// buffers become the accumulator's next round, so the per-update
    /// path (the live engine's hot loop) stops allocating once warm.
    avg_scratch: FlatVec,
    clock_scratch: Vec<Timestamp>,
}

impl ParameterServer {
    pub fn new(
        cfg: ServerConfig,
        theta0: FlatVec,
        optimizer: Optimizer,
        lr: LrPolicy,
    ) -> ParameterServer {
        let acc = Accumulator::new(cfg.protocol, cfg.lambda, theta0.len());
        let dropped_by = vec![0; cfg.lambda];
        ParameterServer {
            cfg,
            theta: theta0,
            ts: 0,
            acc,
            optimizer,
            lr,
            staleness: StalenessStats::default(),
            samples_applied: 0,
            epochs_completed: 0,
            updates: 0,
            last_alpha: 0.0,
            timing_pending: Vec::new(),
            dropped: 0,
            dropped_by,
            decode_buf: FlatVec::zeros(0),
            avg_scratch: FlatVec::zeros(0),
            clock_scratch: Vec::new(),
        }
    }

    /// Per-learner dropped-gradient counts (backup-sync straggler
    /// attribution; all zeros for the other protocols).
    pub fn dropped_by(&self) -> &[u64] {
        &self.dropped_by
    }

    /// Backup-sync's drop rule: a gradient computed from pre-update
    /// weights (grad_ts behind the server clock) missed its round — it is
    /// one of the b slowest and its work is discarded. Returns `true`
    /// when the push should be discarded; the drop is booked only for
    /// in-range learner ids (both counters or neither, so the
    /// `dropped == Σ dropped_by` attribution invariant always holds).
    fn backup_drop(&mut self, learner: usize, grad_ts: Timestamp) -> bool {
        if matches!(self.cfg.protocol, crate::coordinator::protocol::Protocol::BackupSync { .. })
            && grad_ts < self.ts
        {
            if let Some(d) = self.dropped_by.get_mut(learner) {
                *d += 1;
                self.dropped += 1;
            }
            true
        } else {
            false
        }
    }

    /// Current weights and their timestamp (the pullWeights payload).
    pub fn weights(&self) -> (&FlatVec, Timestamp) {
        (&self.theta, self.ts)
    }

    pub fn timestamp(&self) -> Timestamp {
        self.ts
    }

    pub fn epoch(&self) -> usize {
        self.epochs_completed
    }

    pub fn samples_applied(&self) -> u64 {
        self.samples_applied
    }

    /// Training completes after `target_epochs` epochs of aggregate
    /// samples have been applied ("when a specified number of epochs are
    /// trained, parameter server shuts down each learner", §3.2).
    pub fn done(&self) -> bool {
        self.epochs_completed >= self.cfg.target_epochs
    }

    /// sumGradients: fold in one learner's gradient (computed from
    /// weights at `grad_ts`); applyUpdate fires when the protocol's
    /// quota c is reached. Under [`crate::params::lr::Modulation::PerGradient`]
    /// each gradient is individually rescaled by 1/(σᵢ+1) at fold time
    /// (the paper's footnote-3 strategy).
    pub fn push_gradient(
        &mut self,
        learner: usize,
        grad: &FlatVec,
        grad_ts: Timestamp,
    ) -> Result<PushOutcome> {
        // Validate the id before the backup-sync drop rule (mirroring
        // [`crate::coordinator::shard::ShardedServer`]): an out-of-range
        // push must be an error, never a silently booked "drop".
        if learner >= self.dropped_by.len() {
            anyhow::bail!(
                "learner id {learner} out of range (λ = {})",
                self.dropped_by.len()
            );
        }
        if self.backup_drop(learner, grad_ts) {
            return Ok(PushOutcome { dropped: true, ..PushOutcome::default() });
        }
        let scale = if self.lr.is_per_gradient() {
            let sigma = self.ts.saturating_sub(grad_ts);
            1.0 / (sigma as f32 + 1.0)
        } else {
            1.0
        };
        self.acc.push_scaled(learner, grad, grad_ts, scale)?;
        let mut out = PushOutcome::default();
        if self.acc.ready() {
            self.drain_and_apply(&mut out);
        }
        Ok(out)
    }

    /// Drain the satisfied round through the recycled scratch pair and
    /// apply it — the allocation-free twin of `take_update` +
    /// `apply_update` (bit-identical values, see
    /// [`Accumulator::drain_update`]).
    fn drain_and_apply(&mut self, out: &mut PushOutcome) {
        let mut avg = std::mem::replace(&mut self.avg_scratch, FlatVec::zeros(0));
        let mut clock = std::mem::take(&mut self.clock_scratch);
        self.acc.drain_update(&mut avg, &mut clock);
        self.apply_update(&avg, &clock, out);
        self.avg_scratch = avg;
        self.clock_scratch = clock;
    }

    /// Decode-then-accumulate mirror of
    /// [`crate::coordinator::shard::ShardedServer::push_encoded`] (the
    /// flat server stays the reference implementation): decode one
    /// compressed gradient and fold it through the normal push path.
    pub fn push_encoded(
        &mut self,
        learner: usize,
        enc: crate::comm::codec::EncodedGrad,
        grad_ts: Timestamp,
    ) -> Result<PushOutcome> {
        match enc {
            crate::comm::codec::EncodedGrad::Dense(dense) => {
                self.push_gradient(learner, &dense, grad_ts)
            }
            enc => {
                let mut buf = std::mem::replace(&mut self.decode_buf, FlatVec::zeros(0));
                enc.decode_into(&mut buf);
                let out = self.push_gradient(learner, &buf, grad_ts);
                self.decode_buf = buf;
                out
            }
        }
    }

    /// Timing-only variant: advances protocol/clock/epoch state without
    /// numeric work (used when simulating paper-scale models whose
    /// gradients we never materialize — e.g. the 289 MB AlexNet).
    pub fn push_gradient_timing_only(
        &mut self,
        learner: usize,
        grad_ts: Timestamp,
    ) -> PushOutcome {
        if self.backup_drop(learner, grad_ts) {
            return PushOutcome { dropped: true, ..PushOutcome::default() };
        }
        // Bypass the accumulator's FlatVec (which is zero-length here);
        // count pending via the vector clock alone.
        self.timing_pending.push(grad_ts);
        let mut out = PushOutcome::default();
        if self.timing_pending.len() >= self.cfg.protocol.gradients_per_update(self.cfg.lambda)
        {
            let vclock = std::mem::take(&mut self.timing_pending);
            self.advance_clock(&vclock, &mut out);
        }
        out
    }

    fn apply_update(&mut self, avg: &FlatVec, vclock: &[Timestamp], out: &mut PushOutcome) {
        let alpha =
            self.lr
                .alpha(self.epochs_completed, self.cfg.protocol, self.cfg.mu, self.cfg.lambda);
        self.last_alpha = alpha;
        self.optimizer.apply(&mut self.theta, avg, alpha as f32);
        self.advance_clock(vclock, out);
    }

    fn advance_clock(&mut self, vclock: &[Timestamp], out: &mut PushOutcome) {
        self.ts += 1;
        self.updates += 1;
        let rec = self.staleness.record(self.ts, vclock);
        out.updated = true;
        out.avg_staleness = Some(rec.avg_staleness);
        let before = self.samples_applied / self.cfg.samples_per_epoch;
        self.samples_applied += (vclock.len() * self.cfg.mu) as u64;
        let after = self.samples_applied / self.cfg.samples_per_epoch;
        if after > before {
            self.epochs_completed = after as usize;
            out.epoch_completed = Some(self.epochs_completed);
        }
    }

    /// Elastic rescale: change the per-learner mini-batch size μ (the
    /// μ·λ = const rule recomputes it on every membership change). Applies
    /// to updates from the next applyUpdate on; in-flight gradients keep
    /// their old sample count only until folded, a deliberate first-order
    /// approximation.
    pub fn set_mu(&mut self, mu: usize) {
        self.cfg.mu = mu.max(1);
    }

    /// Elastic membership: recompute the collection quota c for a changed
    /// active learner count. Rejects quotas the protocol cannot satisfy
    /// (λ_active = 0, or < n under n-softsync —
    /// [`crate::coordinator::protocol::Protocol::try_gradients_per_update`]).
    ///
    /// Shrinking λ can leave the pending set already at or above the new
    /// quota — the update is applied *immediately* (returned as
    /// `Some(outcome)`), which is what keeps hardsync from deadlocking on
    /// a dead learner: the barrier round completes with the gradients of
    /// the surviving quorum.
    pub fn set_active_lambda(&mut self, lambda: usize) -> Result<Option<PushOutcome>> {
        let quota = self.cfg.protocol.try_gradients_per_update(lambda)?;
        self.cfg.lambda = lambda;
        self.acc.set_active_lambda(lambda)?;
        let mut out = PushOutcome::default();
        if self.acc.pending() >= quota && self.acc.pending() > 0 {
            self.drain_and_apply(&mut out);
            return Ok(Some(out));
        }
        if self.timing_pending.len() >= quota && !self.timing_pending.is_empty() {
            let vclock = std::mem::take(&mut self.timing_pending);
            self.advance_clock(&vclock, &mut out);
            return Ok(Some(out));
        }
        Ok(None)
    }

    /// Membership-aware shrink for a learner *death*. Like
    /// [`ParameterServer::set_active_lambda`], but protocol-safe for
    /// hardsync: the satisfied-quota flush is suppressed while the dead
    /// learner's own gradient sits in the pending round — survivors of
    /// that round still have gradients in flight, and closing the round
    /// early would collide with their next-round pushes. The round then
    /// completes through the normal push path (the per-push quota check
    /// uses the shrunk λ).
    pub fn remove_learner(
        &mut self,
        dead: usize,
        lambda: usize,
    ) -> Result<Option<PushOutcome>> {
        if self.cfg.protocol.is_barrier() && self.acc.pending_contains(dead) {
            self.acc.set_active_lambda(lambda)?;
            self.cfg.lambda = lambda;
            return Ok(None);
        }
        self.set_active_lambda(lambda)
    }

    /// Direct access for warm-start initialization (§5.5) and checkpoints.
    pub fn theta_mut(&mut self) -> &mut FlatVec {
        &mut self.theta
    }

    pub fn reset_optimizer(&mut self) {
        self.optimizer.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::lr::{Modulation, Schedule};
    use crate::params::optimizer::OptimizerKind;

    fn server(protocol: Protocol, lambda: usize) -> ParameterServer {
        let cfg = ServerConfig {
            protocol,
            mu: 4,
            lambda,
            samples_per_epoch: 16,
            target_epochs: 2,
            shards: 1,
        };
        ParameterServer::new(
            cfg,
            FlatVec::zeros(2),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 2),
            LrPolicy::new(Schedule::constant(1.0), Modulation::None, 128),
        )
    }

    #[test]
    fn hardsync_updates_once_per_round() {
        let mut s = server(Protocol::Hardsync, 2);
        let g = FlatVec::from_vec(vec![1.0, 0.0]);
        let o1 = s.push_gradient(0, &g, 0).unwrap();
        assert!(!o1.updated);
        let o2 = s.push_gradient(1, &g, 0).unwrap();
        assert!(o2.updated);
        assert_eq!(o2.avg_staleness, Some(0.0));
        assert_eq!(s.timestamp(), 1);
        // θ = 0 − 1.0·mean(g) = −1
        assert_eq!(s.weights().0.data, vec![-1.0, 0.0]);
    }

    #[test]
    fn softsync_epoch_accounting() {
        // λ=2, μ=4, epoch=16 samples ⇒ 4 gradients (1-softsync: 2 per
        // update ⇒ 8 samples per update ⇒ epoch boundary every 2 updates).
        let mut s = server(Protocol::NSoftsync { n: 1 }, 2);
        let g = FlatVec::zeros(2);
        let mut epochs = vec![];
        for i in 0..8 {
            let out = s.push_gradient(i % 2, &g, s.timestamp()).unwrap();
            if let Some(e) = out.epoch_completed {
                epochs.push(e);
            }
        }
        assert_eq!(epochs, vec![1, 2]);
        assert!(s.done());
    }

    #[test]
    fn async_applies_every_push_with_staleness() {
        let mut s = server(Protocol::Async, 2);
        let g = FlatVec::from_vec(vec![1.0, 1.0]);
        let o = s.push_gradient(0, &g, 0).unwrap();
        assert!(o.updated);
        // learner 1 pushes a gradient computed at ts 0 while server is at 1
        let o2 = s.push_gradient(1, &g, 0).unwrap();
        assert_eq!(o2.avg_staleness, Some(1.0));
        assert_eq!(s.staleness.max, 1);
    }

    #[test]
    fn per_gradient_modulation_downweights_stale_pushes() {
        // footnote-3 strategy: a gradient with σ=3 contributes 1/4 as
        // much as a fresh one.
        let cfg = ServerConfig {
            protocol: Protocol::NSoftsync { n: 2 },
            mu: 4,
            lambda: 2,
            samples_per_epoch: 1_000_000,
            target_epochs: 100,
            shards: 1,
        };
        let mut s = ParameterServer::new(
            cfg,
            FlatVec::zeros(1),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 1),
            LrPolicy::new(Schedule::constant(1.0), Modulation::PerGradient, 128),
        );
        let g = FlatVec::from_vec(vec![1.0]);
        // Four fresh updates advance the clock to ts=4.
        for _ in 0..4 {
            let ts = s.timestamp();
            s.push_gradient(0, &g, ts).unwrap();
        }
        let theta_before = s.weights().0.data[0];
        // A σ=3 gradient: contribution scaled by 1/(3+1).
        s.push_gradient(1, &g, s.timestamp() - 3).unwrap();
        let delta = theta_before - s.weights().0.data[0];
        assert!((delta - 0.25).abs() < 1e-6, "stale push moved θ by {delta}");
        // A fresh gradient moves it by the full 1.0.
        let theta_before = s.weights().0.data[0];
        s.push_gradient(0, &g, s.timestamp()).unwrap();
        let delta = theta_before - s.weights().0.data[0];
        assert!((delta - 1.0).abs() < 1e-6, "fresh push moved θ by {delta}");
    }

    #[test]
    fn push_encoded_decodes_then_accumulates() {
        // The flat server is the reference implementation for the sharded
        // decode-then-accumulate path: an encoded push must fold exactly
        // the decoded vector, and a Dense payload must be a plain push.
        use crate::comm::codec::{CodecSpec, EncodedGrad, LearnerCodec};
        let mut a = server(Protocol::NSoftsync { n: 1 }, 2);
        let mut b = server(Protocol::NSoftsync { n: 1 }, 2);
        let g = FlatVec::from_vec(vec![0.5, -1.5]);
        let mut codec = LearnerCodec::new(CodecSpec::TopK { frac: 0.5 }, 2, 1, 0);
        let enc = codec.encode(&g);
        let dense = enc.clone().into_dense();
        let oa = a.push_encoded(0, enc, 0).unwrap();
        let ob = b.push_gradient(0, &dense, 0).unwrap();
        assert_eq!(oa.updated, ob.updated);
        let oa = a.push_encoded(1, EncodedGrad::Dense(g.clone()), 0).unwrap();
        let ob = b.push_gradient(1, &g, 0).unwrap();
        assert!(oa.updated && ob.updated);
        assert_eq!(a.weights().0.data, b.weights().0.data, "bitwise-identical fold");
        assert_eq!(a.timestamp(), b.timestamp());
    }

    #[test]
    fn backup_sync_drops_slow_gradients_and_stays_stale_free() {
        // λ = 3, b = 1: rounds close on 2 arrivals; the third (slow)
        // gradient arrives behind the clock and is dropped un-folded.
        let mut s = server(Protocol::BackupSync { b: 1 }, 3);
        let g = FlatVec::from_vec(vec![1.0, 0.0]);
        assert!(!s.push_gradient(0, &g, 0).unwrap().updated);
        let out = s.push_gradient(1, &g, 0).unwrap();
        assert!(out.updated && !out.dropped);
        assert_eq!(s.timestamp(), 1);
        assert_eq!(s.weights().0.data, vec![-1.0, 0.0], "averaged the 2 survivors");
        // the straggler's round-0 gradient lands late: dropped, θ untouched
        let late = s.push_gradient(2, &g, 0).unwrap();
        assert!(late.dropped && !late.updated);
        assert_eq!(s.weights().0.data, vec![-1.0, 0.0]);
        assert_eq!(s.dropped, 1);
        assert_eq!(s.dropped_by(), &[0, 0, 1]);
        assert_eq!(s.staleness.max, 0, "backup-sync never folds stale gradients");
        // an out-of-range id stays a hard error even when stale — it must
        // never be silently booked as a "drop" (mirrors the sharded server)
        let err = s.push_gradient(9, &g, 0).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        assert_eq!(s.dropped, 1, "rejected push must not book a drop");
        // a fresh push from the refreshed straggler folds normally
        assert!(!s.push_gradient(2, &g, 1).unwrap().dropped);
        // timing-only path books drops identically
        let mut t = server(Protocol::BackupSync { b: 1 }, 3);
        t.push_gradient_timing_only(0, 0);
        assert!(t.push_gradient_timing_only(1, 0).updated);
        assert!(t.push_gradient_timing_only(2, 0).dropped);
        assert_eq!(t.dropped, 1);
    }

    #[test]
    fn lambda_shrink_flushes_satisfied_quota() {
        // hardsync λ=3: two learners push, the third dies. The quota
        // shrink must fire the barrier update immediately (no deadlock).
        let mut s = server(Protocol::Hardsync, 3);
        let g = FlatVec::from_vec(vec![1.0, 0.0]);
        assert!(!s.push_gradient(0, &g, 0).unwrap().updated);
        assert!(!s.push_gradient(1, &g, 0).unwrap().updated);
        let out = s.set_active_lambda(2).unwrap().expect("quota met → flush");
        assert!(out.updated);
        assert_eq!(s.timestamp(), 1);
        // the update averaged the 2 surviving gradients
        assert_eq!(s.weights().0.data, vec![-1.0, 0.0]);
        // growing back (rejoin) never flushes
        assert!(s.set_active_lambda(3).unwrap().is_none());
        assert_eq!(s.cfg.lambda, 3);
    }

    #[test]
    fn lambda_rescale_rejects_unsatisfiable_quota() {
        let mut s = server(Protocol::NSoftsync { n: 2 }, 4);
        let err = s.set_active_lambda(1).unwrap_err();
        assert!(err.to_string().contains("softsync"), "{err}");
        assert_eq!(s.cfg.lambda, 4, "failed rescale must leave λ unchanged");
        assert!(s.set_active_lambda(0).is_err());
    }

    #[test]
    fn set_mu_rescales_epoch_accounting() {
        // λ=2, 1-softsync ⇒ 2 gradients per update. With μ=4 an update
        // applies 8 samples; after set_mu(8) it applies 16 = one epoch.
        let mut s = server(Protocol::NSoftsync { n: 1 }, 2);
        let g = FlatVec::zeros(2);
        s.push_gradient(0, &g, 0).unwrap();
        s.push_gradient(1, &g, 0).unwrap();
        assert_eq!(s.samples_applied(), 8);
        s.set_mu(8);
        s.push_gradient(0, &g, 1).unwrap();
        let out = s.push_gradient(1, &g, 1).unwrap();
        assert_eq!(s.samples_applied(), 24);
        assert_eq!(out.epoch_completed, Some(1));
    }

    #[test]
    fn timing_only_matches_numeric_clocking() {
        let mut a = server(Protocol::NSoftsync { n: 2 }, 2);
        let mut b = server(Protocol::NSoftsync { n: 2 }, 2);
        let g = FlatVec::zeros(2);
        for i in 0..6 {
            let oa = a.push_gradient(i % 2, &g, a.timestamp()).unwrap();
            let ob = b.push_gradient_timing_only(i % 2, b.timestamp());
            assert_eq!(oa.updated, ob.updated);
            assert_eq!(oa.avg_staleness, ob.avg_staleness);
        }
        assert_eq!(a.timestamp(), b.timestamp());
        assert_eq!(a.samples_applied(), b.samples_applied());
    }
}
