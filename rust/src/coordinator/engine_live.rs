//! Live execution engine: real threads, real time.
//!
//! The "production" path: one OS thread per learner plus the parameter
//! server on the calling thread, joined by mpsc channels (the offline
//! vendor set has no tokio; the paper itself used blocking MPI sends plus
//! dedicated communication threads, which std::thread + mpsc model
//! directly). Protocol semantics, staleness accounting and LR modulation
//! all come from the same [`ParameterServer`] the virtual-time engine
//! drives, so the two engines are behaviorally interchangeable; this one
//! measures *real* wall-clock and real thread-interleaving staleness.
//!
//! Message flow per learner iteration (§2): calcGradient on the local
//! replica → pushGradient (blocking send) → pullWeights (blocking recv of
//! the server's reply, which carries fresh weights only when the
//! timestamp advanced — the §3.2 pull-skip).

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::clock::Timestamp;
use crate::coordinator::learner::GradProvider;
use crate::coordinator::protocol::Protocol;
use crate::coordinator::server::ServerConfig;
use crate::coordinator::shard::ShardedServer;
use crate::params::lr::LrPolicy;
use crate::params::optimizer::Optimizer;
use crate::params::FlatVec;

/// Live-run configuration.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    pub protocol: Protocol,
    pub mu: usize,
    pub lambda: usize,
    pub epochs: usize,
    pub samples_per_epoch: u64,
    /// Parameter shards at the server (default 1 = the paper's flat
    /// server); applyUpdate runs per shard in parallel for large models
    /// ([`crate::coordinator::shard`]).
    pub shards: usize,
    /// Log a loss point every this many pushes (0 = never).
    pub log_every: u64,
}

/// Live-run output.
#[derive(Debug)]
pub struct LiveResult {
    pub wall_seconds: f64,
    pub updates: u64,
    pub staleness: crate::coordinator::clock::StalenessStats,
    pub theta: FlatVec,
    /// (pushes seen, mean recent training loss) log.
    pub loss_log: Vec<(u64, f32)>,
    pub pushes: u64,
    /// applyUpdate count per shard (length = `LiveConfig::shards`).
    pub shard_updates: Vec<u64>,
}

enum ToServer {
    Push { learner: usize, grad: FlatVec, ts: Timestamp, loss: f32 },
}

enum ToLearner {
    /// Fresh weights (timestamp advanced since the learner's replica).
    Weights { theta: Arc<FlatVec>, ts: Timestamp },
    /// Pull-skip: your replica is current.
    Unchanged,
    Shutdown,
}

/// Run a live training session. `providers` supplies one gradient source
/// per learner (each moved into its thread).
pub fn run_live(
    cfg: &LiveConfig,
    theta0: FlatVec,
    optimizer: Optimizer,
    lr: LrPolicy,
    providers: Vec<Box<dyn GradProvider + Send>>,
) -> Result<LiveResult> {
    anyhow::ensure!(providers.len() == cfg.lambda, "need one provider per learner");
    let server_cfg = ServerConfig {
        protocol: cfg.protocol,
        mu: cfg.mu,
        lambda: cfg.lambda,
        samples_per_epoch: cfg.samples_per_epoch,
        target_epochs: cfg.epochs,
        shards: cfg.shards,
    };
    let mut server = ShardedServer::new(server_cfg, theta0.clone(), optimizer, lr);

    let (push_tx, push_rx) = mpsc::channel::<ToServer>();
    let mut reply_txs = Vec::with_capacity(cfg.lambda);
    let mut handles = Vec::with_capacity(cfg.lambda);
    let start = Instant::now();

    for (id, mut provider) in providers.into_iter().enumerate() {
        let (reply_tx, reply_rx) = mpsc::channel::<ToLearner>();
        reply_txs.push(reply_tx);
        let push_tx = push_tx.clone();
        let mut theta = theta0.clone();
        let mut ts: Timestamp = 0;
        handles.push(std::thread::spawn(move || -> Result<()> {
            loop {
                let (grad, loss) = provider.compute(id, &theta)?;
                if push_tx.send(ToServer::Push { learner: id, grad, ts, loss }).is_err() {
                    return Ok(()); // server gone
                }
                match reply_rx.recv() {
                    Ok(ToLearner::Weights { theta: fresh, ts: new_ts }) => {
                        theta.data.copy_from_slice(&fresh.data);
                        ts = new_ts;
                    }
                    Ok(ToLearner::Unchanged) => {}
                    Ok(ToLearner::Shutdown) | Err(_) => return Ok(()),
                }
            }
        }));
    }
    drop(push_tx);

    // Parameter-server loop: handle messages one by one ("parameter
    // server handles each incoming message one by one", §3.2).
    let mut pushes: u64 = 0;
    let mut recent_losses: Vec<f64> = Vec::new();
    let mut loss_log: Vec<(u64, f32)> = Vec::new();
    // Hardsync holds replies until the barrier update fires.
    let mut barrier_waiting: Vec<usize> = Vec::new();

    while !server.done() {
        let msg = match push_rx.recv() {
            Ok(m) => m,
            Err(_) => break, // all learners exited
        };
        let ToServer::Push { learner, grad, ts, loss } = msg;
        pushes += 1;
        recent_losses.push(loss as f64);
        if cfg.log_every > 0 && pushes % cfg.log_every == 0 {
            loss_log.push((pushes, crate::util::mean(&recent_losses) as f32));
            recent_losses.clear();
        }
        let outcome = server.push_gradient(learner, &grad, ts)?;

        if cfg.protocol.is_barrier() {
            barrier_waiting.push(learner);
            if outcome.updated {
                let new_ts = server.timestamp();
                let snap = Arc::new(server.assemble_weights());
                for l in barrier_waiting.drain(..) {
                    let _ = reply_txs[l]
                        .send(ToLearner::Weights { theta: snap.clone(), ts: new_ts });
                }
            }
        } else {
            // softsync/async: reply to this learner's implicit pull.
            let cur_ts = server.timestamp();
            if cur_ts > ts {
                let snap = Arc::new(server.assemble_weights());
                let _ = reply_txs[learner]
                    .send(ToLearner::Weights { theta: snap, ts: cur_ts });
            } else {
                let _ = reply_txs[learner].send(ToLearner::Unchanged);
            }
        }
    }

    // Shut everyone down ("parameter server shuts down each learner").
    for tx in &reply_txs {
        let _ = tx.send(ToLearner::Shutdown);
    }
    // Drain stragglers so their final sends don't block (bounded work:
    // each learner sends at most one more push before seeing Shutdown).
    while let Ok(_msg) = push_rx.try_recv() {}
    for h in handles {
        match h.join() {
            Ok(r) => r?,
            Err(_) => anyhow::bail!("learner thread panicked"),
        }
    }

    Ok(LiveResult {
        wall_seconds: start.elapsed().as_secs_f64(),
        updates: server.updates,
        staleness: server.staleness.clone(),
        theta: server.assemble_weights(),
        loss_log,
        pushes,
        shard_updates: server.shard_updates(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::learner::MockProvider;
    use crate::params::lr::{LrPolicy, Modulation, Schedule};
    use crate::params::optimizer::{Optimizer, OptimizerKind};

    fn providers(lambda: usize, dim: usize) -> Vec<Box<dyn GradProvider + Send>> {
        (0..lambda)
            .map(|_| Box::new(MockProvider::new(vec![0.0; dim])) as Box<dyn GradProvider + Send>)
            .collect()
    }

    fn run(protocol: Protocol, lambda: usize) -> LiveResult {
        run_sharded(protocol, lambda, 1)
    }

    fn run_sharded(protocol: Protocol, lambda: usize, shards: usize) -> LiveResult {
        let dim = 8;
        let cfg = LiveConfig {
            protocol,
            mu: 4,
            lambda,
            epochs: 3,
            samples_per_epoch: 64,
            shards,
            log_every: 4,
        };
        let theta0 = FlatVec::from_vec((0..dim).map(|i| i as f32 - 3.5).collect());
        let opt = Optimizer::new(OptimizerKind::Sgd, 0.0, dim);
        let lr = LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128);
        run_live(&cfg, theta0, opt, lr, providers(lambda, dim)).unwrap()
    }

    #[test]
    fn hardsync_live_converges_toward_target() {
        let r = run(Protocol::Hardsync, 4);
        assert!(r.updates > 0);
        assert_eq!(r.staleness.max, 0);
        assert!(r.theta.norm() < 7.0, "moved toward 0: {}", r.theta.norm());
        assert!(!r.loss_log.is_empty());
    }

    #[test]
    fn softsync_live_completes_with_bounded_staleness() {
        let r = run(Protocol::NSoftsync { n: 1 }, 4);
        assert!(r.updates > 0);
        // 1-softsync: σ ≤ 2n with overwhelming probability; allow slack
        // for thread scheduling on a loaded box.
        assert!(r.staleness.overall_avg() < 4.0, "⟨σ⟩ = {}", r.staleness.overall_avg());
    }

    #[test]
    fn async_live_completes() {
        let r = run(Protocol::Async, 4);
        assert!(r.updates > 0);
        assert!(r.pushes >= r.updates);
    }

    #[test]
    fn single_learner_degenerates_to_sgd() {
        let r = run(Protocol::NSoftsync { n: 1 }, 1);
        assert_eq!(r.staleness.max, 0, "λ=1 has no staleness source");
        assert!(r.theta.norm() < 1.0, "plain SGD should converge well");
    }

    #[test]
    fn sharded_live_server_completes_in_lockstep() {
        let r = run_sharded(Protocol::NSoftsync { n: 1 }, 4, 4);
        assert!(r.updates > 0);
        assert!(r.theta.is_finite());
        assert_eq!(r.shard_updates, vec![r.updates; 4], "shards must stay in lockstep");
        // flat result exposes the degenerate single-shard counter
        let flat = run(Protocol::NSoftsync { n: 1 }, 4);
        assert_eq!(flat.shard_updates, vec![flat.updates]);
    }
}
