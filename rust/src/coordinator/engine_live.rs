//! Live execution engine: real threads, real time.
//!
//! The "production" path: one OS thread per learner plus the parameter
//! server on the calling thread, joined by mpsc channels (the offline
//! vendor set has no tokio; the paper itself used blocking MPI sends plus
//! dedicated communication threads, which std::thread + mpsc model
//! directly). Protocol semantics, staleness accounting and LR modulation
//! all come from the same [`ParameterServer`] the virtual-time engine
//! drives, so the two engines are behaviorally interchangeable; this one
//! measures *real* wall-clock and real thread-interleaving staleness.
//!
//! Message flow per learner iteration (§2): calcGradient on the local
//! replica → pushGradient (blocking send) → pullWeights (blocking recv of
//! the server's reply, which carries fresh weights only when the
//! timestamp advanced — the §3.2 pull-skip).
//!
//! **Elastic membership** ([`crate::elastic`]): with [`LiveConfig::elastic`]
//! set, the server loop polls its push channel with a timeout and runs
//! heartbeat detection — a learner silent past the timeout turns Suspect,
//! past twice the timeout it is evicted (Dead): its thread gets a
//! Shutdown, its handle is detached (it may be wedged inside a gradient
//! computation forever), and the surviving quorum is rescaled via
//! μ·λ = const. Deterministic churn for tests arrives as
//! kill/rejoin-after-N-pushes schedules; rejoin spawns a fresh thread from
//! a provider factory and warm-starts it from the current weights.
//! Hardsync cannot deadlock on a death: the quota shrink flushes an
//! already-satisfied barrier round immediately
//! ([`ShardedServer::set_active_lambda`]).
//!
//! [`ParameterServer`]: crate::coordinator::server::ParameterServer
//! [`ShardedServer::set_active_lambda`]: crate::coordinator::shard::ShardedServer::set_active_lambda

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::comm::codec::{CodecSpec, EncodedGrad, LearnerCodec};
use crate::comm::wire::WireModel;
use crate::coordinator::clock::Timestamp;
use crate::coordinator::learner::GradProvider;
use crate::coordinator::protocol::Protocol;
use crate::coordinator::server::ServerConfig;
use crate::coordinator::shard::ShardedServer;
use crate::elastic::checkpoint::Checkpoint;
use crate::elastic::membership::{ChurnRecord, Membership, Phase};
use crate::netsim::faults::FaultSpec;
use crate::netsim::reliable::FaultStats;
use crate::elastic::rescaler::{RescalePolicy, Rescaler};
use crate::obs::series::{SeriesInputs, SeriesRecorder};
use crate::obs::trace::{TraceEvent, TraceRecorder, PID_LEARNERS, PID_SHARDS};
use crate::params::lr::LrPolicy;
use crate::params::optimizer::Optimizer;
use crate::params::FlatVec;

/// Elastic-membership knobs for the live engine.
#[derive(Debug, Clone)]
pub struct LiveElastic {
    /// Heartbeat timeout: silent past this → Suspect, past 2× → evicted.
    /// `Duration::ZERO` disables heartbeat detection (scheduled churn
    /// still runs).
    pub heartbeat_timeout: Duration,
    /// Deterministic churn: kill learner `.1` once the server has seen
    /// `.0` total pushes.
    pub kill_after_pushes: Vec<(u64, usize)>,
    /// Deterministic churn: rejoin learner `.1` at `.0` total pushes.
    /// Requires the provider factory of [`run_live_elastic`].
    pub rejoin_after_pushes: Vec<(u64, usize)>,
    /// μ·λ rescaling policy applied on every membership change.
    pub rescale: RescalePolicy,
}

impl LiveElastic {
    /// Heartbeat-only config (no scheduled churn).
    pub fn heartbeat(timeout: Duration) -> LiveElastic {
        LiveElastic {
            heartbeat_timeout: timeout,
            kill_after_pushes: Vec::new(),
            rejoin_after_pushes: Vec::new(),
            rescale: RescalePolicy::MuLambdaConst,
        }
    }
}

/// Live-run configuration.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    pub protocol: Protocol,
    pub mu: usize,
    pub lambda: usize,
    pub epochs: usize,
    pub samples_per_epoch: u64,
    /// Parameter shards at the server (default 1 = the paper's flat
    /// server); applyUpdate runs per shard in parallel for large models
    /// ([`crate::coordinator::shard`]).
    pub shards: usize,
    /// Log a loss point every this many pushes (0 = never).
    pub log_every: u64,
    /// Elastic membership (heartbeat detection + churn schedules);
    /// `None` = the classic fixed-λ run.
    pub elastic: Option<LiveElastic>,
    /// Gradient compression ([`crate::comm`]): learners encode in their
    /// own threads (error-feedback residuals thread-local), the server
    /// decodes then accumulates. `none` ships dense payloads as before.
    pub compress: CodecSpec,
    /// Capture a server checkpoint every this many weight updates
    /// (0 = off). Captures happen on the server loop between messages —
    /// a quiesced update boundary, so the checkpointed accumulators and
    /// clock are exactly the post-update state (the ROADMAP "wire
    /// checkpoint_every into train" item).
    pub checkpoint_every: u64,
    /// Snapshot a [`crate::obs::metrics`] registry into
    /// [`LiveResult::metrics`] at shutdown (`--metrics-json` /
    /// `--run-index`). Purely observational: the live loop is untouched,
    /// the snapshot is assembled from server-side tallies after joins.
    pub collect_metrics: bool,
    /// Record Chrome trace-event spans over *wall* time (seconds since
    /// the run epoch): learner threads stamp their own compute/send
    /// offsets against the shared epoch, the single-threaded server loop
    /// records them on receipt — no cross-thread sink. Off = the exact
    /// pre-trace path (timing fields ride as zeros, never read).
    pub trace: bool,
    /// Sample a [`crate::obs::series`] time-series window every this many
    /// *wall* seconds into the metrics snapshot (`--metrics-every`).
    /// `Some` implies a metrics snapshot even if `collect_metrics` is off.
    pub metrics_every: Option<f64>,
    /// Accumulate a [`crate::obs::profile::WallProfiler`] attribution
    /// (`--profile`): aggregate wall-clock category totals from the same
    /// receipt-side stamps the trace uses — no critical-path claim (threads
    /// overlap), so the profile rides as `mode: "aggregate"`. Implies a
    /// metrics snapshot to ride in.
    pub profile: bool,
    /// Message-level chaos ([`crate::netsim::faults`]). The mpsc channel
    /// cannot drop, so `loss`/`dup` are emulated at receipt — where the
    /// wire would have applied them — with the same per-sender sequence
    /// numbers and server-side dedup window the sim engine uses. A push
    /// whose retry budget is exhausted is abandoned and the blocked
    /// learner refreshed with current weights. Partitions are a
    /// sim-engine feature; the quiet spec takes the exact legacy path.
    pub faults: FaultSpec,
}

/// Live-run output.
#[derive(Debug)]
pub struct LiveResult {
    pub wall_seconds: f64,
    pub updates: u64,
    pub staleness: crate::coordinator::clock::StalenessStats,
    pub theta: FlatVec,
    /// (pushes seen, mean recent training loss) log.
    pub loss_log: Vec<(u64, f32)>,
    pub pushes: u64,
    /// applyUpdate count per shard (length = `LiveConfig::shards`).
    pub shard_updates: Vec<u64>,
    /// Churn log (wall seconds since run start); empty without churn.
    pub churn: Vec<ChurnRecord>,
    /// Death → rejoin downtimes, wall seconds.
    pub recovery_secs: Vec<f64>,
    /// λ_active when the run ended.
    pub final_active_lambda: usize,
    /// Backup-sync: total gradients dropped as too-slow (0 elsewhere).
    pub dropped_gradients: u64,
    /// Backup-sync: dropped-gradient count per learner slot.
    pub dropped_by_learner: Vec<u64>,
    /// Per-learner bytes pushed (compressed payload sizes; dense-sized
    /// when `compress` is `none`).
    pub comm_bytes_by_learner: Vec<f64>,
    /// Checkpoints captured (per `LiveConfig::checkpoint_every`).
    pub checkpoints_taken: u64,
    /// The most recent captured checkpoint, if any.
    pub last_checkpoint: Option<Checkpoint>,
    /// Metrics snapshot ([`crate::obs::metrics`] schema); `None` unless
    /// [`LiveConfig::collect_metrics`] or [`LiveConfig::metrics_every`]
    /// was set.
    pub metrics: Option<crate::util::json::Json>,
    /// Wall-clock trace spans (seconds since the run epoch, recorded as
    /// microseconds per the trace-event format); `None` unless
    /// [`LiveConfig::trace`] was set.
    pub trace: Option<Vec<TraceEvent>>,
    /// Fault-plane accounting; `None` unless [`LiveConfig::faults`] was
    /// armed.
    pub faults: Option<FaultStats>,
}

enum ToServer {
    /// `inc` is the learner's incarnation at spawn time: a straggler push
    /// from a killed thread must not be credited to (or replied at) the
    /// learner that later rejoined under the same id. The gradient
    /// travels encoded (learner-side codec); the server decodes then
    /// accumulates. `compress none` ships it as `Dense`, which decodes
    /// without a copy. `t_compute` / `t_sent` are wall offsets from the
    /// run epoch stamped in the learner thread (compute start/end and
    /// send time) — zeros when both tracing and profiling are off, and
    /// never read then. `seq` is the per-incarnation send sequence number
    /// the fault plane's dedup window keys on (stamped always; only read
    /// when faults are armed).
    Push {
        learner: usize,
        inc: u64,
        seq: u64,
        grad: EncodedGrad,
        ts: Timestamp,
        loss: f32,
        t_compute: (f64, f64),
        t_sent: f64,
    },
}

enum ToLearner {
    /// Fresh weights (timestamp advanced since the learner's replica).
    Weights { theta: Arc<FlatVec>, ts: Timestamp },
    /// Pull-skip: your replica is current.
    Unchanged,
    /// Dynamic-μ control: the rescaler retuned the per-learner mini-batch
    /// size; apply it to the provider in place (the ROADMAP "live-engine
    /// dynamic μ" channel). Not a pull reply — the learner keeps waiting
    /// for its actual reply after applying it.
    SetMu(usize),
    Shutdown,
}

type ProviderFactory<'f> = Box<dyn FnMut(usize) -> Box<dyn GradProvider + Send> + 'f>;

/// What one heartbeat sweep should do with one live learner, given how
/// long it has been silent. Factored out of the scan so the lifecycle
/// rule is unit-testable: silence past the suspicion threshold raises
/// suspicion exactly once, and a Suspect learner whose heartbeats
/// resumed inside the threshold returns to Active (it used to linger
/// Suspect until its next push or its eviction).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum HeartbeatAction {
    None,
    Suspect,
    Recover,
}

fn heartbeat_action(silent: Duration, suspect_after: Duration, phase: Phase) -> HeartbeatAction {
    if silent > suspect_after {
        if phase == Phase::Suspect {
            HeartbeatAction::None
        } else {
            HeartbeatAction::Suspect
        }
    } else if phase == Phase::Suspect {
        HeartbeatAction::Recover
    } else {
        HeartbeatAction::None
    }
}

/// Run a live training session. `providers` supplies one gradient source
/// per learner (each moved into its thread).
pub fn run_live(
    cfg: &LiveConfig,
    theta0: FlatVec,
    optimizer: Optimizer,
    lr: LrPolicy,
    providers: Vec<Box<dyn GradProvider + Send>>,
) -> Result<LiveResult> {
    run_live_inner(cfg, theta0, optimizer, lr, providers, None)
}

/// Elastic variant: learners are built from `factory`, which is also used
/// to warm-restart rejoining learners (the rejoin schedule requires it).
pub fn run_live_elastic(
    cfg: &LiveConfig,
    theta0: FlatVec,
    optimizer: Optimizer,
    lr: LrPolicy,
    mut factory: ProviderFactory<'_>,
) -> Result<LiveResult> {
    let providers: Vec<Box<dyn GradProvider + Send>> =
        (0..cfg.lambda).map(|id| factory(id)).collect();
    run_live_inner(cfg, theta0, optimizer, lr, providers, Some(factory))
}

#[allow(clippy::too_many_arguments)]
fn spawn_learner(
    id: usize,
    inc: u64,
    seq0: u64,
    mut provider: Box<dyn GradProvider + Send>,
    mut codec: Option<LearnerCodec>,
    mut theta: FlatVec,
    mut ts: Timestamp,
    push_tx: mpsc::Sender<ToServer>,
    epoch: Option<Instant>,
) -> (std::thread::JoinHandle<Result<()>>, mpsc::Sender<ToLearner>) {
    let (reply_tx, reply_rx) = mpsc::channel::<ToLearner>();
    let handle = std::thread::spawn(move || -> Result<()> {
        // wall offset from the shared run epoch (0.0 untraced: the server
        // never reads the stamps then)
        let stamp = |e: &Option<Instant>| e.map(|e| e.elapsed().as_secs_f64()).unwrap_or(0.0);
        // rejoined incarnations start past the old incarnation's highest
        // sequence number so the server's dedup window never mistakes a
        // fresh push for a replay
        let mut seq = seq0;
        loop {
            let t0 = stamp(&epoch);
            let (grad, loss) = provider.compute(id, &theta)?;
            let t1 = stamp(&epoch);
            // encode in the learner thread: the error-feedback residual
            // is thread-local state, exactly like the paper's learner-side
            // pushGradient staging buffer
            let grad = match codec.as_mut() {
                Some(c) => c.encode(&grad),
                None => EncodedGrad::Dense(grad),
            };
            let t_sent = stamp(&epoch);
            let msg = ToServer::Push {
                learner: id,
                inc,
                seq,
                grad,
                ts,
                loss,
                t_compute: (t0, t1),
                t_sent,
            };
            seq += 1;
            if push_tx.send(msg).is_err() {
                return Ok(()); // server gone
            }
            // Drain control messages (SetMu) until the actual pull reply;
            // a retune can land at any point between two pushes.
            loop {
                match reply_rx.recv() {
                    Ok(ToLearner::SetMu(mu)) => {
                        provider.set_mu(mu);
                        continue;
                    }
                    Ok(ToLearner::Weights { theta: fresh, ts: new_ts }) => {
                        theta.data.copy_from_slice(&fresh.data);
                        ts = new_ts;
                        break;
                    }
                    Ok(ToLearner::Unchanged) => break,
                    Ok(ToLearner::Shutdown) | Err(_) => return Ok(()),
                }
            }
        }
    });
    (handle, reply_tx)
}

fn run_live_inner(
    cfg: &LiveConfig,
    theta0: FlatVec,
    optimizer: Optimizer,
    lr: LrPolicy,
    providers: Vec<Box<dyn GradProvider + Send>>,
    mut factory: Option<ProviderFactory<'_>>,
) -> Result<LiveResult> {
    anyhow::ensure!(providers.len() == cfg.lambda, "need one provider per learner");
    if let Protocol::BackupSync { .. } = cfg.protocol {
        // the checked quota is the single source of the b < λ rule
        cfg.protocol.try_gradients_per_update(cfg.lambda)?;
    }
    let elastic = cfg.elastic.clone();
    if let Some(e) = &elastic {
        anyhow::ensure!(
            e.rejoin_after_pushes.is_empty() || factory.is_some(),
            "a rejoin schedule needs the provider factory of run_live_elastic"
        );
        for &(_, l) in e.kill_after_pushes.iter().chain(e.rejoin_after_pushes.iter()) {
            anyhow::ensure!(l < cfg.lambda, "churn schedule references learner {l}, λ = {}", cfg.lambda);
        }
    }
    let server_cfg = ServerConfig {
        protocol: cfg.protocol,
        mu: cfg.mu,
        lambda: cfg.lambda,
        samples_per_epoch: cfg.samples_per_epoch,
        target_epochs: cfg.epochs,
        shards: cfg.shards,
    };
    let mut server = ShardedServer::new(server_cfg, theta0.clone(), optimizer, lr);
    let rescale_policy =
        elastic.as_ref().map(|e| e.rescale).unwrap_or(RescalePolicy::None);
    let rescaler = Rescaler::new(rescale_policy, cfg.mu, cfg.lambda);
    let mut membership = Membership::new(cfg.lambda);
    // Wire accounting prices pushes off the deterministic model (the
    // mpsc channel has no wire, but the stats column should match what
    // the payload would cost on one); live runs are wall-clock
    // nondeterministic, so codec RNG streams take a fixed seed.
    let n_params = theta0.len();
    let wire = WireModel::new(cfg.compress, 4.0 * n_params as f64);
    const LIVE_COMM_SEED: u64 = 0x11FE_C0DE;
    let mk_codec = |id: usize| {
        if cfg.compress.is_quiet() {
            None
        } else {
            Some(LearnerCodec::new(cfg.compress, n_params, LIVE_COMM_SEED, id))
        }
    };
    let mut comm_bytes_by_learner: Vec<f64> = vec![0.0; cfg.lambda];
    let mut checkpoints_taken: u64 = 0;
    let mut last_checkpoint: Option<Checkpoint> = None;
    let mut last_ckpt_at: u64 = 0;

    // Receipt-side chaos (tentpole): the mpsc channel cannot drop, so
    // loss/dup are emulated where a real wire would have applied them —
    // at receipt, before the fold. Like the codec streams above, live
    // runs are wall-clock nondeterministic, so the fault RNG takes a
    // fixed seed.
    const LIVE_FAULT_SEED: u64 = 0xFA17_11FE;
    let mut faults = if cfg.faults.is_quiet() {
        None
    } else {
        anyhow::ensure!(
            cfg.faults.partitions.is_empty(),
            "live-engine faults support loss/dup/retries only \
             (partitions need the sim engine's rack topology)"
        );
        server.arm_dedup();
        Some((
            FaultStats::new(cfg.lambda),
            crate::util::rng::Rng::new(LIVE_FAULT_SEED),
        ))
    };
    // Highest sequence number seen per learner slot, across incarnations:
    // a rejoined thread starts past it so the dedup window never mistakes
    // a fresh push for a replay.
    let mut seq_hwm: Vec<u64> = vec![0; cfg.lambda];

    // Merge the deterministic churn into one pushes-ordered agenda.
    #[derive(Clone, Copy)]
    enum Planned {
        Kill(usize),
        Rejoin(usize),
    }
    let mut agenda: Vec<(u64, Planned)> = Vec::new();
    if let Some(e) = &elastic {
        for &(at, l) in &e.kill_after_pushes {
            agenda.push((at, Planned::Kill(l)));
        }
        for &(at, l) in &e.rejoin_after_pushes {
            agenda.push((at, Planned::Rejoin(l)));
        }
    }
    agenda.sort_by_key(|(at, _)| *at);
    let mut agenda_next = 0usize;

    let (push_tx, push_rx) = mpsc::channel::<ToServer>();
    let mut reply_txs = Vec::with_capacity(cfg.lambda);
    let mut handles: Vec<Option<std::thread::JoinHandle<Result<()>>>> =
        Vec::with_capacity(cfg.lambda);
    let start = Instant::now();
    // Wall-clock observability (tentpole: the live engine used to have no
    // trace story at all — "no virtual clock" — so spans are measured
    // against the run epoch instead). Both are pure observers: learner
    // threads stamp their own offsets against the shared epoch, the
    // single-threaded server loop records them on receipt.
    let mut rec = if cfg.trace { TraceRecorder::on_wall(start) } else { TraceRecorder::off() };
    // The profiler consumes the same learner-side stamps the trace does, so
    // either knob arms them (off = both zeros, never read).
    let trace_epoch = (cfg.trace || cfg.profile).then_some(start);
    let mut wprof = cfg.profile.then(|| crate::obs::profile::WallProfiler::new(cfg.lambda));
    let mut series: Option<SeriesRecorder> = cfg.metrics_every.map(SeriesRecorder::new);
    let mut bytes_in_total: f64 = 0.0;

    // Per-learner incarnation counters (bumped at kill); pushes from a
    // dead incarnation are dropped even after the id rejoins.
    let mut incs: Vec<u64> = vec![0; cfg.lambda];
    for (id, provider) in providers.into_iter().enumerate() {
        let (handle, reply_tx) = spawn_learner(
            id,
            0,
            0,
            provider,
            mk_codec(id),
            theta0.clone(),
            0,
            push_tx.clone(),
            trace_epoch,
        );
        handles.push(Some(handle));
        reply_txs.push(reply_tx);
    }
    // A rejoin schedule must be able to wire new learners into the push
    // channel later; otherwise the sender is dropped so the loop can
    // observe disconnection when every learner exits.
    let spare_tx = if agenda.iter().any(|(_, p)| matches!(*p, Planned::Rejoin(_))) {
        Some(push_tx.clone())
    } else {
        None
    };
    drop(push_tx);

    let heartbeat = elastic
        .as_ref()
        .map(|e| e.heartbeat_timeout)
        .filter(|t| !t.is_zero());
    // Elastic runs always poll (heartbeats and liveness need a clock even
    // when only scheduled churn is configured).
    let poll = match (heartbeat, &elastic) {
        (Some(t), _) => Some((t / 4).max(Duration::from_millis(5))),
        (None, Some(_)) => Some(Duration::from_millis(25)),
        (None, None) => None,
    };
    // Hard stall guard: an elastic run whose learners all wedge or exit
    // without the ledger noticing must error out, not hang forever. It
    // scales with the heartbeat so a long timeout can still evict (the
    // eviction fires at 2× the heartbeat, well inside 8×); heartbeat-less
    // runs get a generous fixed window for slow mini-batches.
    let stall_cap: Duration = match heartbeat {
        Some(t) => (t * 8).max(Duration::from_secs(60)),
        None => Duration::from_secs(300),
    };
    let mut last_progress = Instant::now();
    let mut last_heard: Vec<Instant> = vec![start; cfg.lambda];
    // Learners that have pushed at least once. Never-heard learners get a
    // longer warm-up grace before suspicion/eviction — the first
    // mini-batch (plus thread spawn) can legitimately dwarf the
    // steady-state heartbeat.
    let mut heard: Vec<bool> = vec![false; cfg.lambda];
    // Heartbeats are checked on channel-idle timeouts AND periodically on
    // busy channels (a wedged learner must not hide behind its peers'
    // steady push traffic).
    let scan_every = poll.unwrap_or(Duration::from_millis(25));
    let mut last_scan = Instant::now();

    // Parameter-server loop: handle messages one by one ("parameter
    // server handles each incoming message one by one", §3.2).
    let mut pushes: u64 = 0;
    let mut recent_losses: Vec<f64> = Vec::new();
    let mut loss_log: Vec<(u64, f32)> = Vec::new();
    // Hardsync holds replies until the barrier update fires; each entry
    // remembers its wall offset so the series can window barrier waits.
    let mut barrier_waiting: Vec<(usize, f64)> = Vec::new();

    // Per-learner μ currently in force (retuned by the rescaler; pushed
    // to live providers over the SetMu control channel).
    let mut cur_mu = cfg.mu;

    // Weight snapshots are cached per timestamp: θ is immutable between
    // two updates, so pull replies, barrier releases, and backup-sync
    // drop-refreshes landing at the same clock share one assembly instead
    // of copying the full model per message.
    let mut snap_cache: Option<(Timestamp, Arc<FlatVec>)> = None;
    macro_rules! snapshot {
        () => {{
            let ts = server.timestamp();
            match &snap_cache {
                Some((t, s)) if *t == ts => s.clone(),
                _ => {
                    let s = Arc::new(server.assemble_weights());
                    snap_cache = Some((ts, s.clone()));
                    s
                }
            }
        }};
    }

    // Membership change: rescale μ — notifying every live learner's
    // provider over its reply channel when it moved — recompute the quota
    // (flushing a satisfied barrier round via the membership-aware quorum
    // when a death — `$dead` — triggered the change), release barrier
    // replies.
    macro_rules! rescale_members {
        ($dead:expr) => {{
            let active = membership.active_count();
            anyhow::ensure!(active > 0, "every learner is dead; training cannot continue");
            let new_mu = rescaler.mu_for(active);
            if new_mu != cur_mu {
                cur_mu = new_mu;
                for l in 0..cfg.lambda {
                    if membership.is_live(l) {
                        let _ = reply_txs[l].send(ToLearner::SetMu(new_mu));
                    }
                }
            }
            server.set_mu(new_mu);
            let dead: Option<usize> = $dead;
            let flush = match dead {
                Some(d) => server.remove_learner(d, active)?,
                None => server.set_active_lambda(active)?,
            };
            rec.instant("rescale", PID_SHARDS, 0, rec.now_s());
            if let Some(out) = flush {
                if out.updated && cfg.protocol.is_barrier() {
                    let new_ts = server.timestamp();
                    let snap = snapshot!();
                    let now_off = start.elapsed().as_secs_f64();
                    for (l, entered) in barrier_waiting.drain(..) {
                        if let Some(s) = &mut series {
                            s.note_barrier_wait(now_off - entered);
                        }
                        if let Some(p) = &mut wprof {
                            p.barrier_wait(now_off - entered);
                        }
                        let _ = reply_txs[l]
                            .send(ToLearner::Weights { theta: snap.clone(), ts: new_ts });
                    }
                }
            }
        }};
    }

    macro_rules! kill_learner {
        ($l:expr) => {{
            let l: usize = $l;
            if membership.is_live(l) {
                membership.kill(l, start.elapsed().as_secs_f64())?;
                incs[l] += 1;
                let _ = reply_txs[l].send(ToLearner::Shutdown);
                // Detach the thread: it may be wedged inside compute()
                // forever — exactly the failure heartbeats exist to catch.
                if let Some(h) = handles[l].take() {
                    drop(h);
                }
                rec.instant("evict", PID_LEARNERS, l as u64, rec.now_s());
                barrier_waiting.retain(|&(x, _)| x != l);
                rescale_members!(Some(l));
            }
        }};
    }

    // One heartbeat sweep: suspect the quiet, evict at most the single
    // stalest over-limit learner, then give every survivor a fresh grace
    // period (a barrier stalled by one wedged learner makes *everyone*
    // look silent).
    macro_rules! heartbeat_scan {
        () => {{
            if let Some(timeout) = heartbeat {
                let now = Instant::now();
                let mut stalest: Option<(usize, Duration)> = None;
                for l in 0..cfg.lambda {
                    if !membership.is_live(l) {
                        continue;
                    }
                    let silent = now.duration_since(last_heard[l]);
                    let (suspect_after, evict_after) = if heard[l] {
                        (timeout, timeout * 2)
                    } else {
                        (timeout * 5, timeout * 10)
                    };
                    match heartbeat_action(silent, suspect_after, membership.phase(l)) {
                        HeartbeatAction::Suspect => {
                            membership.suspect(l, start.elapsed().as_secs_f64())?;
                            rec.instant("suspect", PID_LEARNERS, l as u64, rec.now_s());
                        }
                        HeartbeatAction::Recover => {
                            membership.recover(l, start.elapsed().as_secs_f64())?;
                            rec.instant("recover", PID_LEARNERS, l as u64, rec.now_s());
                        }
                        HeartbeatAction::None => {}
                    }
                    if silent > evict_after
                        && stalest.map(|(_, s)| silent > s).unwrap_or(true)
                    {
                        stalest = Some((l, silent));
                    }
                }
                if let Some((l, _)) = stalest {
                    kill_learner!(l);
                    let fresh = Instant::now();
                    for t in last_heard.iter_mut() {
                        *t = fresh;
                    }
                }
            }
        }};
    }

    macro_rules! series_tick {
        () => {{
            if let Some(s) = &mut series {
                let (stale_count, stale_sum) = server.staleness.totals();
                let inputs = SeriesInputs {
                    queue_depth: 0, // mpsc exposes no queue length
                    active_lambda: membership.active_count() as u64,
                    stale_count,
                    stale_sum,
                    stale_max: server.staleness.max,
                    bytes_in: bytes_in_total,
                };
                s.maybe_sample(start.elapsed().as_secs_f64(), &inputs);
            }
        }};
    }

    while !server.done() {
        series_tick!();
        let msg = if let Some(poll) = poll {
            match push_rx.recv_timeout(poll) {
                Ok(m) => Some(m),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        } else {
            match push_rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break, // all learners exited
            }
        };

        let Some(msg) = msg else {
            anyhow::ensure!(
                last_progress.elapsed() < stall_cap,
                "live engine stalled: no pushes for {} seconds",
                stall_cap.as_secs()
            );
            last_scan = Instant::now();
            heartbeat_scan!();
            continue;
        };

        let ToServer::Push { learner, inc, seq, grad, ts, loss, t_compute, t_sent } = msg;
        if inc != incs[learner] || !membership.is_live(learner) {
            continue; // a dead incarnation's final push: message lost
        }
        seq_hwm[learner] = seq_hwm[learner].max(seq + 1);
        if rec.enabled() {
            // spans land at receipt: the learner stamped its own compute
            // window, the push span is send → server pickup (wire +
            // queue time on the mpsc channel)
            rec.span("compute", PID_LEARNERS, learner as u64, t_compute.0, t_compute.1);
            rec.span("push", PID_LEARNERS, learner as u64, t_sent, rec.now_s());
        }
        if let Some(p) = &mut wprof {
            let wire = start.elapsed().as_secs_f64() - t_sent;
            p.push(learner, t_compute.1 - t_compute.0, wire);
        }
        last_heard[learner] = Instant::now();
        heard[learner] = true;
        last_progress = Instant::now();
        if membership.phase(learner) == Phase::Suspect {
            membership.recover(learner, start.elapsed().as_secs_f64())?;
        }
        if let Some((st, rng)) = faults.as_mut() {
            st.sent += 1;
            // Each attempt drops with p(loss); the reliability layer
            // retransmits up to the budget. Retry bytes are booked into
            // the same per-learner column the original occupies.
            let mut drops: u32 = 0;
            while rng.f64() < cfg.faults.loss {
                drops += 1;
                if drops > cfg.faults.retries {
                    break;
                }
            }
            let retried = drops.min(cfg.faults.retries);
            st.retransmits += u64::from(retried);
            st.retransmits_by[learner] += u64::from(retried);
            st.dropped += u64::from(drops);
            let overhead = f64::from(retried) * wire.push_bytes();
            st.retry_bytes += overhead;
            comm_bytes_by_learner[learner] += overhead;
            bytes_in_total += overhead;
            if retried > 0 {
                rec.instant("retransmit", PID_LEARNERS, learner as u64, rec.now_s());
            }
            if drops > cfg.faults.retries {
                // Retry budget exhausted: the push is abandoned. The
                // learner is blocked on its reply, so refresh it with
                // current weights (mirrors the backup-sync drop path) and
                // keep training instead of wedging it forever.
                st.exhausted += 1;
                comm_bytes_by_learner[learner] += wire.push_bytes();
                bytes_in_total += wire.push_bytes();
                rec.instant("drop", PID_LEARNERS, learner as u64, rec.now_s());
                let snap = snapshot!();
                let _ = reply_txs[learner]
                    .send(ToLearner::Weights { theta: snap, ts: server.timestamp() });
                continue;
            }
            st.delivered += 1;
            if !server.dedup_accept(learner, seq) {
                // replay of an already-folded sequence number: the
                // idempotency backstop rejects it before accumulation
                rec.instant("dedup", PID_LEARNERS, learner as u64, rec.now_s());
                let _ = reply_txs[learner].send(ToLearner::Unchanged);
                continue;
            }
            if rng.f64() < cfg.faults.dup {
                // inject a duplicate delivery; the dedup window must
                // reject it, proving a dup can never double-fold
                st.dups_injected += 1;
                st.delivered += 1;
                anyhow::ensure!(
                    !server.dedup_accept(learner, seq),
                    "dup of a folded push must be rejected by the dedup window"
                );
                rec.instant("dedup", PID_LEARNERS, learner as u64, rec.now_s());
            }
        }
        pushes += 1;
        comm_bytes_by_learner[learner] += wire.push_bytes();
        bytes_in_total += wire.push_bytes();
        recent_losses.push(loss as f64);
        if let Some(s) = &mut series {
            s.note_loss(loss as f64);
        }
        if cfg.log_every > 0 && pushes % cfg.log_every == 0 {
            loss_log.push((pushes, crate::util::mean(&recent_losses) as f32));
            recent_losses.clear();
        }
        // decode-then-accumulate: the codec's payload becomes one dense
        // gradient with one timestamp, protocol semantics unchanged
        let outcome = server.push_encoded(learner, grad, ts)?;
        if outcome.updated {
            rec.instant("apply_update", PID_SHARDS, 0, rec.now_s());
            if let Some(p) = &mut wprof {
                p.commit(learner);
            }
        }

        if cfg.protocol.is_barrier() {
            if outcome.dropped {
                // backup-sync: one of the b slowest — nothing was folded;
                // refresh the straggler with current weights immediately
                // (the clock is necessarily ahead of its replica, and θ
                // is unchanged since the round's update, so the cached
                // snapshot is reused rather than re-assembled).
                let snap = snapshot!();
                let _ = reply_txs[learner]
                    .send(ToLearner::Weights { theta: snap, ts: server.timestamp() });
            } else {
                barrier_waiting.push((learner, start.elapsed().as_secs_f64()));
                if outcome.updated {
                    let new_ts = server.timestamp();
                    let snap = snapshot!();
                    let now_off = start.elapsed().as_secs_f64();
                    for (l, entered) in barrier_waiting.drain(..) {
                        if let Some(s) = &mut series {
                            s.note_barrier_wait(now_off - entered);
                        }
                        if let Some(p) = &mut wprof {
                            p.barrier_wait(now_off - entered);
                        }
                        let _ = reply_txs[l]
                            .send(ToLearner::Weights { theta: snap.clone(), ts: new_ts });
                    }
                }
            }
        } else {
            // softsync/async: reply to this learner's implicit pull.
            let cur_ts = server.timestamp();
            if cur_ts > ts {
                let snap = snapshot!();
                let _ = reply_txs[learner]
                    .send(ToLearner::Weights { theta: snap, ts: cur_ts });
            } else {
                let _ = reply_txs[learner].send(ToLearner::Unchanged);
            }
        }

        // Deterministic churn agenda (kills/rejoins keyed on push count).
        while agenda_next < agenda.len() && agenda[agenda_next].0 <= pushes {
            match agenda[agenda_next].1 {
                Planned::Kill(l) => kill_learner!(l),
                Planned::Rejoin(l) => {
                    if membership.phase(l) == Phase::Dead {
                        // Warm restart: a fresh provider, current weights,
                        // current timestamp — the learner re-enters the
                        // quorum as `Rejoined` under its old id.
                        let provider = factory.as_mut().expect("validated above")(l);
                        let tx = spare_tx
                            .as_ref()
                            .expect("rejoin schedule keeps a sender")
                            .clone();
                        // the rejoined incarnation's codec starts with a
                        // clean residual: untransmitted error feedback
                        // died with the old thread
                        let (handle, reply_tx) = spawn_learner(
                            l,
                            incs[l],
                            seq_hwm[l],
                            provider,
                            mk_codec(l),
                            server.assemble_weights(),
                            server.timestamp(),
                            tx,
                            trace_epoch,
                        );
                        handles[l] = Some(handle);
                        reply_txs[l] = reply_tx;
                        membership.rejoin(l, start.elapsed().as_secs_f64())?;
                        rec.instant("rejoin", PID_LEARNERS, l as u64, rec.now_s());
                        last_heard[l] = Instant::now();
                        heard[l] = false; // fresh warm-up grace for the new thread
                        // the factory builds providers at the spawn-time μ;
                        // bring the rejoiner onto the μ currently in force
                        if cur_mu != cfg.mu {
                            let _ = reply_txs[l].send(ToLearner::SetMu(cur_mu));
                        }
                        rescale_members!(None);
                    }
                }
            }
            agenda_next += 1;
        }

        // Quiesced update boundary: the push — and any membership flush
        // it triggered — is fully handled, so the serialized accumulators
        // and clock are exactly the post-update state. (Comm residuals
        // are learner-thread-local and not captured here; the sim
        // engine's checkpoints carry them.)
        if cfg.checkpoint_every > 0 && server.updates >= last_ckpt_at + cfg.checkpoint_every {
            last_checkpoint = Some(Checkpoint::capture(
                &format!("live-update-{}", server.updates),
                &server,
                &[],
            ));
            last_ckpt_at = server.updates;
            checkpoints_taken += 1;
            rec.instant("checkpoint", PID_SHARDS, 0, rec.now_s());
        }

        // Busy channels must not starve failure detection.
        if heartbeat.is_some() && last_scan.elapsed() >= scan_every {
            last_scan = Instant::now();
            heartbeat_scan!();
        }
    }

    // Shut everyone down ("parameter server shuts down each learner").
    for tx in &reply_txs {
        let _ = tx.send(ToLearner::Shutdown);
    }
    // Drain stragglers so their final sends don't block (bounded work:
    // each learner sends at most one more push before seeing Shutdown).
    while let Ok(_msg) = push_rx.try_recv() {}
    for h in handles.into_iter().flatten() {
        match h.join() {
            Ok(r) => r?,
            Err(_) => anyhow::bail!("learner thread panicked"),
        }
    }

    // The receiver-side dedup tally lives at the server; fold it into the
    // run's fault accounting before the stats are published.
    let fault_stats = faults.map(|(mut st, _)| {
        st.dedup_dropped = server.dedup_dropped;
        st
    });

    // The live loop keeps no registry of its own (no virtual clock, no
    // event queue); the snapshot is assembled once from the server-side
    // tallies, which exist regardless. A `metrics_every` series or a
    // profile implies a snapshot to ride in, even with collect_metrics off.
    let metrics = if cfg.collect_metrics || series.is_some() || wprof.is_some() {
        let bytes_in: f64 = comm_bytes_by_learner.iter().sum();
        let mut snap = crate::obs::metrics::MetricsRegistry::default().snapshot(
            &server.staleness,
            &server.shard_updates(),
            server.pushes_by(),
            bytes_in,
            0.0,
        );
        if let Some(s) = &mut series {
            let (stale_count, stale_sum) = server.staleness.totals();
            let inputs = SeriesInputs {
                queue_depth: 0,
                active_lambda: membership.active_count() as u64,
                stale_count,
                stale_sum,
                stale_max: server.staleness.max,
                bytes_in: bytes_in_total,
            };
            s.final_flush(start.elapsed().as_secs_f64(), &inputs);
            crate::obs::metrics::attach_series(&mut snap, s.to_json());
        }
        if let Some(p) = &wprof {
            let profile = p.to_json(start.elapsed().as_secs_f64());
            crate::obs::metrics::attach_profile(&mut snap, profile);
        }
        if let Some(st) = &fault_stats {
            crate::obs::metrics::attach_faults(&mut snap, st.to_json());
        }
        Some(snap)
    } else {
        None
    };

    Ok(LiveResult {
        wall_seconds: start.elapsed().as_secs_f64(),
        updates: server.updates,
        staleness: server.staleness.clone(),
        theta: server.assemble_weights(),
        loss_log,
        pushes,
        shard_updates: server.shard_updates(),
        churn: membership.log,
        recovery_secs: membership.recovery_secs,
        final_active_lambda: server.active_lambda(),
        dropped_gradients: server.dropped,
        dropped_by_learner: server.dropped_by().to_vec(),
        comm_bytes_by_learner,
        checkpoints_taken,
        last_checkpoint,
        metrics,
        trace: rec.take(),
        faults: fault_stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::learner::MockProvider;
    use crate::elastic::membership::ChurnKind;
    use crate::params::lr::{LrPolicy, Modulation, Schedule};
    use crate::params::optimizer::{Optimizer, OptimizerKind};

    fn providers(lambda: usize, dim: usize) -> Vec<Box<dyn GradProvider + Send>> {
        (0..lambda)
            .map(|_| Box::new(MockProvider::new(vec![0.0; dim])) as Box<dyn GradProvider + Send>)
            .collect()
    }

    fn base_cfg(protocol: Protocol, lambda: usize, shards: usize) -> LiveConfig {
        LiveConfig {
            protocol,
            mu: 4,
            lambda,
            epochs: 3,
            samples_per_epoch: 64,
            shards,
            log_every: 4,
            elastic: None,
            compress: CodecSpec::None,
            checkpoint_every: 0,
            collect_metrics: false,
            trace: false,
            metrics_every: None,
            profile: false,
            faults: FaultSpec::none(),
        }
    }

    fn run(protocol: Protocol, lambda: usize) -> LiveResult {
        run_sharded(protocol, lambda, 1)
    }

    fn run_sharded(protocol: Protocol, lambda: usize, shards: usize) -> LiveResult {
        let dim = 8;
        let cfg = base_cfg(protocol, lambda, shards);
        let theta0 = FlatVec::from_vec((0..dim).map(|i| i as f32 - 3.5).collect());
        let opt = Optimizer::new(OptimizerKind::Sgd, 0.0, dim);
        let lr = LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128);
        run_live(&cfg, theta0, opt, lr, providers(lambda, dim)).unwrap()
    }

    #[test]
    fn live_metrics_snapshot_rides_along() {
        let dim = 8;
        let mut cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 2, 1);
        cfg.collect_metrics = true;
        let theta0 = FlatVec::from_vec((0..dim).map(|i| i as f32 - 3.5).collect());
        let opt = Optimizer::new(OptimizerKind::Sgd, 0.0, dim);
        let lr = LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128);
        let r = run_live(&cfg, theta0, opt, lr, providers(2, dim)).unwrap();
        let m = r.metrics.as_ref().expect("collect_metrics was on");
        let pushes_by = m.get("pushes_by_learner").unwrap().as_u64_vec().unwrap();
        assert_eq!(pushes_by.len(), 2);
        assert!(pushes_by.iter().sum::<u64>() > 0, "{pushes_by:?}");
        assert_eq!(
            m.get("staleness").unwrap().get("count").unwrap().as_u64().unwrap(),
            r.staleness.count
        );
        // and the default stays quiet
        let r2 = run(Protocol::NSoftsync { n: 1 }, 2);
        assert!(r2.metrics.is_none());
    }

    #[test]
    fn live_trace_and_series_ride_along() {
        let dim = 8;
        let mut cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 2, 1);
        cfg.trace = true;
        cfg.metrics_every = Some(1e-4);
        let theta0 = FlatVec::from_vec((0..dim).map(|i| i as f32 - 3.5).collect());
        let opt = Optimizer::new(OptimizerKind::Sgd, 0.0, dim);
        let lr = LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128);
        let r = run_live(&cfg, theta0, opt, lr, providers(2, dim)).unwrap();
        let tr = r.trace.as_ref().expect("trace was on");
        assert!(tr.iter().any(|e| e.name == "compute" && e.ph == 'X'));
        assert!(tr.iter().any(|e| e.name == "push" && e.ph == 'X'));
        assert!(tr.iter().any(|e| e.name == "apply_update" && e.ph == 'i'));
        assert!(
            tr.iter().all(|e| e.ts_us >= 0.0 && e.dur_us >= 0.0),
            "wall offsets are non-negative"
        );
        // metrics_every implies a snapshot even with collect_metrics off,
        // and the series rides inside it
        let m = r.metrics.as_ref().expect("series implies a snapshot");
        let series = m.get("series").unwrap();
        let t = series.get("t").unwrap().as_f64_vec().unwrap();
        assert!(!t.is_empty(), "final_flush guarantees a sample");
        assert!(t.windows(2).all(|w| w[0] < w[1]), "wall sample times advance");
        // the default stays exactly as quiet as before
        let r2 = run(Protocol::NSoftsync { n: 1 }, 2);
        assert!(r2.trace.is_none());
        assert!(r2.metrics.is_none());
    }

    #[test]
    fn hardsync_live_converges_toward_target() {
        let r = run(Protocol::Hardsync, 4);
        assert!(r.updates > 0);
        assert_eq!(r.staleness.max, 0);
        assert!(r.theta.norm() < 7.0, "moved toward 0: {}", r.theta.norm());
        assert!(!r.loss_log.is_empty());
        assert!(r.churn.is_empty(), "no churn configured");
        assert_eq!(r.final_active_lambda, 4);
    }

    #[test]
    fn softsync_live_completes_with_bounded_staleness() {
        let r = run(Protocol::NSoftsync { n: 1 }, 4);
        assert!(r.updates > 0);
        // 1-softsync: σ ≤ 2n with overwhelming probability; allow slack
        // for thread scheduling on a loaded box.
        assert!(r.staleness.overall_avg() < 4.0, "⟨σ⟩ = {}", r.staleness.overall_avg());
    }

    #[test]
    fn async_live_completes() {
        let r = run(Protocol::Async, 4);
        assert!(r.updates > 0);
        assert!(r.pushes >= r.updates);
    }

    #[test]
    fn single_learner_degenerates_to_sgd() {
        let r = run(Protocol::NSoftsync { n: 1 }, 1);
        assert_eq!(r.staleness.max, 0, "λ=1 has no staleness source");
        assert!(r.theta.norm() < 1.0, "plain SGD should converge well");
    }

    #[test]
    fn sharded_live_server_completes_in_lockstep() {
        let r = run_sharded(Protocol::NSoftsync { n: 1 }, 4, 4);
        assert!(r.updates > 0);
        assert!(r.theta.is_finite());
        assert_eq!(r.shard_updates, vec![r.updates; 4], "shards must stay in lockstep");
        // flat result exposes the degenerate single-shard counter
        let flat = run(Protocol::NSoftsync { n: 1 }, 4);
        assert_eq!(flat.shard_updates, vec![flat.updates]);
    }

    #[test]
    fn scheduled_kill_and_rejoin_with_rescale() {
        let dim = 6;
        let mut cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 4, 2);
        cfg.epochs = 4;
        cfg.samples_per_epoch = 96;
        cfg.elastic = Some(LiveElastic {
            heartbeat_timeout: Duration::ZERO,
            kill_after_pushes: vec![(8, 2)],
            rejoin_after_pushes: vec![(20, 2)],
            rescale: RescalePolicy::MuLambdaConst,
        });
        let theta0 = FlatVec::from_vec(vec![1.0; dim]);
        let opt = Optimizer::new(OptimizerKind::Sgd, 0.0, dim);
        let lr = LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128);
        let r = run_live_elastic(
            &cfg,
            theta0,
            opt,
            lr,
            Box::new(move |_id| {
                Box::new(MockProvider::new(vec![0.0; dim])) as Box<dyn GradProvider + Send>
            }),
        )
        .unwrap();
        assert!(r.updates > 0);
        assert!(r.theta.is_finite());
        let kinds: Vec<ChurnKind> =
            r.churn.iter().filter(|c| c.learner == 2).map(|c| c.kind).collect();
        assert_eq!(kinds, vec![ChurnKind::Kill, ChurnKind::Rejoin]);
        assert_eq!(r.recovery_secs.len(), 1);
        assert_eq!(r.final_active_lambda, 4, "learner 2 rejoined the quorum");
    }

    #[test]
    fn hardsync_survives_scheduled_death() {
        let dim = 4;
        let mut cfg = base_cfg(Protocol::Hardsync, 3, 1);
        cfg.elastic = Some(LiveElastic {
            heartbeat_timeout: Duration::ZERO,
            kill_after_pushes: vec![(7, 1)],
            rejoin_after_pushes: vec![],
            rescale: RescalePolicy::MuLambdaConst,
        });
        let theta0 = FlatVec::from_vec(vec![2.0; dim]);
        let opt = Optimizer::new(OptimizerKind::Sgd, 0.0, dim);
        let lr = LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128);
        let r = run_live(&cfg, theta0, opt, lr, providers(3, dim)).unwrap();
        // the run reaches its target epochs — no barrier deadlock on the
        // dead learner — and the quorum shrank by exactly one
        assert!(r.updates > 0);
        assert_eq!(r.final_active_lambda, 2);
        assert!(r.churn.iter().any(|c| c.kind == ChurnKind::Kill && c.learner == 1));
    }

    #[test]
    fn backup_sync_live_completes_stale_free() {
        let r = run(Protocol::BackupSync { b: 1 }, 4);
        assert!(r.updates > 0);
        assert_eq!(r.staleness.max, 0, "backup-sync folds only fresh gradients");
        assert_eq!(
            r.dropped_by_learner.iter().sum::<u64>(),
            r.dropped_gradients,
            "per-learner drop attribution must add up"
        );
        assert!(r.theta.is_finite());
        // b = 0 behaves as hardsync: no drops, zero staleness
        let r0 = run(Protocol::BackupSync { b: 0 }, 3);
        assert_eq!(r0.dropped_gradients, 0);
        assert_eq!(r0.staleness.max, 0);
        assert!(r0.updates > 0);
    }

    #[test]
    fn rescale_pushes_new_mu_down_the_control_channel() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // Providers record the last μ received over the SetMu channel so
        // the test can observe delivery from outside the learner threads.
        struct MuRecorder {
            inner: MockProvider,
            seen: Arc<AtomicUsize>,
        }
        impl GradProvider for MuRecorder {
            fn compute(&mut self, l: usize, theta: &FlatVec) -> Result<(FlatVec, f32)> {
                self.inner.compute(l, theta)
            }
            fn n_params(&self) -> usize {
                self.inner.n_params()
            }
            fn set_mu(&mut self, mu: usize) -> bool {
                self.seen.store(mu, Ordering::SeqCst);
                true
            }
        }
        let dim = 4;
        let mut cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 4, 1);
        cfg.mu = 8;
        cfg.epochs = 4;
        cfg.samples_per_epoch = 256;
        cfg.elastic = Some(LiveElastic {
            heartbeat_timeout: Duration::ZERO,
            kill_after_pushes: vec![(6, 2)],
            rejoin_after_pushes: vec![],
            rescale: RescalePolicy::MuLambdaConst,
        });
        let seen: Vec<Arc<AtomicUsize>> =
            (0..4).map(|_| Arc::new(AtomicUsize::new(0))).collect();
        let provs: Vec<Box<dyn GradProvider + Send>> = seen
            .iter()
            .map(|s| {
                Box::new(MuRecorder {
                    inner: MockProvider::new(vec![0.0; dim]),
                    seen: s.clone(),
                }) as Box<dyn GradProvider + Send>
            })
            .collect();
        let theta0 = FlatVec::from_vec(vec![1.0; dim]);
        let opt = Optimizer::new(OptimizerKind::Sgd, 0.0, dim);
        let lr = LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128);
        let r = run_live(&cfg, theta0, opt, lr, provs).unwrap();
        assert!(r.updates > 0);
        assert_eq!(r.final_active_lambda, 3, "learner 2 was killed");
        // μ·λ = const with P = 32: λ 4 → 3 rescales μ 8 → 11; every
        // surviving provider must have seen it over the control channel.
        for (l, s) in seen.iter().enumerate() {
            if l == 2 {
                continue; // dead before (or at) the retune — may have missed it
            }
            assert_eq!(s.load(Ordering::SeqCst), 11, "learner {l} missed the SetMu");
        }
    }

    #[test]
    fn checkpoint_every_captures_at_quiesced_boundaries() {
        // Satellite (PR 4): checkpoint_every was sim-only; the live
        // engine now captures at update boundaries too.
        let dim = 8;
        let mut cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 4, 2);
        cfg.checkpoint_every = 3;
        let theta0 = FlatVec::from_vec((0..dim).map(|i| i as f32 - 3.5).collect());
        let opt = Optimizer::new(OptimizerKind::Sgd, 0.0, dim);
        let lr = LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128);
        let r = run_live(&cfg, theta0, opt, lr, providers(4, dim)).unwrap();
        assert!(r.updates >= 3, "enough updates to cross a boundary");
        assert!(r.checkpoints_taken > 0, "at least one checkpoint captured");
        let ckpt = r.last_checkpoint.expect("last checkpoint retained");
        let captured_updates = ckpt.updates().unwrap();
        assert!(captured_updates >= 3 && captured_updates <= r.updates);
        // the capture restores to a valid server mid-run (single-clock
        // invariant re-validated on the way in)
        let restored = ckpt.restore().unwrap();
        assert_eq!(restored.server.updates, captured_updates);
        assert!(restored.server.assemble_weights().is_finite());
        assert_eq!(restored.server.shard_updates(), vec![captured_updates; 2]);
        // off by default: no captures
        let cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 2, 1);
        let r = run_live(
            &cfg,
            FlatVec::zeros(4),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 4),
            LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128),
            providers(2, 4),
        )
        .unwrap();
        assert_eq!(r.checkpoints_taken, 0);
        assert!(r.last_checkpoint.is_none());
    }

    #[test]
    fn compressed_live_run_converges_and_books_bytes() {
        let dim = 8;
        let mut cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 4, 1);
        cfg.compress = CodecSpec::TopK { frac: 0.5 };
        let theta0 = FlatVec::from_vec((0..dim).map(|i| i as f32 - 3.5).collect());
        let opt = Optimizer::new(OptimizerKind::Sgd, 0.0, dim);
        let lr = LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128);
        let r = run_live(&cfg, theta0, opt, lr, providers(4, dim)).unwrap();
        assert!(r.updates > 0);
        assert!(r.theta.is_finite());
        // error feedback keeps top-k descent on the bowl convergent
        assert!(r.theta.norm() < 7.0, "moved toward 0: {}", r.theta.norm());
        // every learner's pushes were booked at the compressed size
        let per_push = 2.0 * 0.5 * (4 * dim) as f64;
        for (l, &b) in r.comm_bytes_by_learner.iter().enumerate() {
            assert!(b > 0.0, "learner {l} booked no bytes");
            assert!(
                (b / per_push).fract().abs() < 1e-9,
                "learner {l}: {b} not a multiple of the push size {per_push}"
            );
        }
    }

    #[test]
    fn heartbeat_action_recovers_fresh_suspects() {
        // The regression the scan fix targets: a Suspect learner whose
        // heartbeats resumed inside the suspicion threshold (e.g. after
        // the post-eviction grace refresh) returns to Active instead of
        // lingering Suspect until its next push.
        let th = Duration::from_millis(150);
        let fresh = Duration::from_millis(10);
        let stale = Duration::from_millis(200);
        assert_eq!(heartbeat_action(fresh, th, Phase::Suspect), HeartbeatAction::Recover);
        assert_eq!(heartbeat_action(fresh, th, Phase::Active), HeartbeatAction::None);
        assert_eq!(heartbeat_action(fresh, th, Phase::Rejoined), HeartbeatAction::None);
        assert_eq!(heartbeat_action(stale, th, Phase::Active), HeartbeatAction::Suspect);
        assert_eq!(heartbeat_action(stale, th, Phase::Rejoined), HeartbeatAction::Suspect);
        // already Suspect: suspicion is raised exactly once
        assert_eq!(heartbeat_action(stale, th, Phase::Suspect), HeartbeatAction::None);
        // the threshold itself is not yet suspicious
        assert_eq!(heartbeat_action(th, th, Phase::Active), HeartbeatAction::None);
        assert_eq!(heartbeat_action(th, th, Phase::Suspect), HeartbeatAction::Recover);
    }

    #[test]
    fn synthetic_faults_never_double_fold_and_balance() {
        // Synthetic-mode chaos: heavy loss + dup on the mpsc push path.
        // Every injected dup must bounce off the server's dedup window,
        // the conservation law must balance, and training must still
        // finish with finite weights.
        let dim = 8;
        let mut cfg = base_cfg(Protocol::NSoftsync { n: 1 }, 4, 1);
        cfg.epochs = 4;
        cfg.samples_per_epoch = 128;
        cfg.faults = FaultSpec::parse("loss:0.2,dup:0.3,retries:1").unwrap();
        let theta0 = FlatVec::from_vec((0..dim).map(|i| i as f32 - 3.5).collect());
        let opt = Optimizer::new(OptimizerKind::Sgd, 0.0, dim);
        let lr = LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128);
        let r = run_live(&cfg, theta0, opt, lr, providers(4, dim)).unwrap();
        assert!(r.updates > 0);
        assert!(r.theta.is_finite());
        let st = r.faults.as_ref().expect("fault plane was armed");
        assert!(st.sent > 0);
        assert!(st.balances(), "conservation law: {st:?}");
        assert!(st.dups_injected > 0, "dup:0.3 over {} sends must fire", st.sent);
        assert_eq!(
            st.dedup_dropped, st.dups_injected,
            "every injected dup is rejected by the window, nothing else is"
        );
        assert!(st.retransmits > 0, "loss:0.2 over {} sends must retry", st.sent);
        assert_eq!(
            st.retransmits,
            st.retransmits_by.iter().sum::<u64>(),
            "per-learner retransmit attribution must add up"
        );
        assert!(st.retry_bytes > 0.0);
        // the quiet default books no fault stats at all
        let quiet = run(Protocol::NSoftsync { n: 1 }, 4);
        assert!(quiet.faults.is_none());
    }

    #[test]
    fn heartbeat_evicts_wedged_learner() {
        // Learner 2 wedges forever inside compute() after 2 mini-batches;
        // under hardsync that stalls every barrier round until the
        // heartbeat detector evicts it and the quorum flush releases the
        // survivors.
        struct Wedging {
            inner: MockProvider,
            computes: u64,
        }
        impl GradProvider for Wedging {
            fn compute(&mut self, l: usize, theta: &FlatVec) -> Result<(FlatVec, f32)> {
                self.computes += 1;
                if self.computes > 2 {
                    // long enough to be "forever" relative to the 200 ms
                    // heartbeat; the thread is detached at eviction and
                    // dies with the test process
                    std::thread::sleep(Duration::from_secs(20));
                }
                self.inner.compute(l, theta)
            }
            fn n_params(&self) -> usize {
                self.inner.n_params()
            }
        }
        let dim = 4;
        let mut cfg = base_cfg(Protocol::Hardsync, 3, 1);
        cfg.epochs = 2;
        cfg.samples_per_epoch = 48;
        cfg.elastic = Some(LiveElastic::heartbeat(Duration::from_millis(200)));
        let mut provs = providers(2, dim);
        provs.push(Box::new(Wedging { inner: MockProvider::new(vec![0.0; dim]), computes: 0 }));
        let theta0 = FlatVec::from_vec(vec![1.0; dim]);
        let opt = Optimizer::new(OptimizerKind::Sgd, 0.0, dim);
        let lr = LrPolicy::new(Schedule::constant(0.05), Modulation::Auto, 128);
        let r = run_live(&cfg, theta0, opt, lr, provs).unwrap();
        assert!(r.updates > 0, "training resumed after the eviction");
        assert_eq!(r.final_active_lambda, 2, "wedged learner evicted");
        assert!(
            r.churn
                .iter()
                .any(|c| c.kind == ChurnKind::Kill && c.learner == 2),
            "churn log records the eviction: {:?}",
            r.churn
        );
    }
}
