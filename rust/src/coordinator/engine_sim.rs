//! Virtual-time execution engine: real gradients, simulated cluster.
//!
//! The paper's figures need *both* axes of every experiment — model
//! accuracy (real SGD dynamics) and wall-clock time (cluster behaviour at
//! P775 scale). This engine produces both from one run: learners,
//! parameter server, and messages advance on a deterministic
//! discrete-event clock whose durations come from [`crate::netsim`]
//! (compute-cost model + link contention), while every gradient is
//! computed *for real* through a [`GradProvider`] (PJRT executing the AOT
//! HLO) at exactly the weight versions the virtual schedule dictates.
//! Staleness distributions, protocol semantics, and accuracy are
//! therefore faithful; *seconds are simulated* (and labeled as such
//! everywhere).
//!
//! In *timing-only* mode (no provider) the same event flow runs without
//! numeric work — how paper-scale workloads (289 MB AlexNet, 1.2M-image
//! epochs) are simulated for runtime-only columns.
//!
//! Architecture modeling (§3.3, DESIGN.md §3):
//! * **Base** — every push/pull is a learner↔root message; the root's
//!   NIC endpoint serializes them (the §3.3 bottleneck). Learners block
//!   on push-then-pull (Rudra-base is "non-blocking everywhere except
//!   for pushing up gradients and pushing down weights").
//!
//! Orthogonally to the architecture, the root tier may be sharded
//! (`SimConfig::shards` > 1, [`crate::coordinator::shard`]): pushes,
//! relays, pulls, and broadcasts stripe evenly across S independent
//! single-duplex root endpoints and complete when the last slice lands,
//! while applyUpdate runs per shard in parallel. With S = 1 every code
//! path degenerates to the flat-server behavior above, bit for bit.
//! * **Adv** — learners push to a co-located leaf aggregator (loopback);
//!   leaves opportunistically batch and relay gradient sums up to the
//!   root; pulls hop root→leaf→learner with a per-leaf fetch cache so one
//!   root egress serves all co-located learners. Learners unblock once
//!   their push reaches the *leaf*.
//! * **Adv\*** — pushes additionally go through a depth-1 pipeline (the
//!   paper's pushGradient thread: a gradient may not start sending before
//!   the previous one is delivered; the learner stalls only on that), and
//!   weights arrive continuously via the learner broadcast tree: at every
//!   mini-batch boundary the learner swaps in the snapshot a broadcast
//!   initiated `bcast_period` ago would have delivered (tracked
//!   exactly via a pruned history of recent updates — no event flood).

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::Result;

use crate::comm::codec::{CodecSpec, CommState, EncodedGrad};
use crate::comm::wire::WireModel;
use crate::coordinator::clock::Timestamp;
use crate::coordinator::learner::{GradProvider, LearnerState};
use crate::coordinator::protocol::Protocol;
use crate::coordinator::server::{PushOutcome, ServerConfig};
use crate::coordinator::shard::ShardedServer;
use crate::coordinator::tree::{Arch, PsTree};
use crate::elastic::checkpoint::{Checkpoint, SimCheckpoint};
use crate::elastic::membership::{ChurnAction, ChurnEvent, ChurnRecord, ChurnSchedule, Membership};
use crate::elastic::rescaler::{RescalePolicy, RescaleRecord, Rescaler};
use crate::netsim::cluster::{jittered, ClusterSpec, Fabric};
use crate::netsim::cost::{LearnerCompute, ModelCost};
use crate::netsim::event::EventQueue;
use crate::netsim::failure::FailureInjector;
use crate::netsim::faults::{FaultPlane, FaultSpec, RouteOutcome};
use crate::netsim::overlap::OverlapTracker;
use crate::netsim::reliable::{windows_from_json, windows_to_json, DedupWindow, FaultStats};
use crate::params::lr::LrPolicy;
use crate::params::optimizer::Optimizer;
use crate::params::FlatVec;
use crate::straggler::adaptive::{AdaptiveController, AdaptiveRecord, AdaptiveSpec};
use crate::straggler::hetero::{HeteroModel, HeteroSpec};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Periodic model evaluation (the paper's Statistics Server, §3.2).
pub trait Evaluator {
    /// Returns (mean loss, error %) on the held-out set.
    fn eval(&mut self, theta: &FlatVec) -> Result<(f64, f64)>;
}

/// Full simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub protocol: Protocol,
    pub arch: Arch,
    pub mu: usize,
    pub lambda: usize,
    pub epochs: usize,
    pub seed: u64,
    pub cluster: ClusterSpec,
    pub compute: LearnerCompute,
    pub model: ModelCost,
    /// Parameter shards at the root tier (default 1 = the paper's flat
    /// server). With S > 1, pushes/pulls stripe across S independent
    /// single-duplex root endpoints and applyUpdate runs per shard
    /// ([`crate::coordinator::shard`]).
    pub shards: usize,
    /// Evaluate at every epoch boundary (requires an evaluator).
    pub eval_each_epoch: bool,
    /// Hard cap on weight updates (safety valve for huge timing runs).
    pub max_updates: Option<u64>,
    /// Elastic membership churn: deterministic kill/rejoin/join events
    /// plus an optional random failure process
    /// ([`crate::netsim::failure::FailureInjector`]). Quiet by default.
    pub churn: ChurnSchedule,
    /// What to do with μ when λ_active changes: keep it fixed, or hold
    /// μ·λ_active ≈ μ₀·λ₀ ([`crate::elastic::rescaler`]).
    pub rescale: RescalePolicy,
    /// Capture a server checkpoint every this many weight updates
    /// (0 = off); the latest lands in [`SimResult::last_checkpoint`].
    pub checkpoint_every_updates: u64,
    /// Per-learner speed heterogeneity ([`crate::straggler::hetero`]):
    /// persistent slowdown factors (explicit and/or sampled) plus an
    /// optional Markov transient, drawn from a dedicated RNG stream.
    /// Quiet (`none`, the default) preserves bit-identical trajectories.
    pub hetero: HeteroSpec,
    /// Adaptive-n staleness control ([`crate::straggler::adaptive`]):
    /// retune the n-softsync splitting parameter at epoch boundaries to
    /// hold a target ⟨σ⟩. Off by default.
    pub adaptive: AdaptiveSpec,
    /// Gradient compression ([`crate::comm`]): learners encode pushes
    /// (error-feedback residuals learner-side), the root decodes then
    /// accumulates, and the wire model shrinks push/relay times to the
    /// compressed payload. `none` (the default) takes the exact
    /// pre-codec path, bit for bit.
    pub compress: CodecSpec,
    /// Stop the event loop after this many processed events and capture a
    /// mid-flight [`SimCheckpoint`] into [`SimResult::sim_checkpoint`]
    /// (timing-only runs; `None` = run to completion). Resume by
    /// rebuilding the engine under the same config and calling
    /// [`SimEngine::install_sim_checkpoint`] — the continued run is
    /// bit-identical to an uninterrupted one.
    pub stop_after_events: Option<u64>,
    /// Where to write the mid-flight sim checkpoint when
    /// `stop_after_events` fires (`None` = keep it in-memory only).
    pub sim_checkpoint_path: Option<std::path::PathBuf>,
    /// Record a Chrome trace-event timeline over virtual sim time
    /// ([`crate::obs::trace`]). Off by default: the no-op recorder keeps
    /// quiet runs bit-identical (a host-side observation knob, excluded
    /// from the config fingerprint like `stop_after_events`).
    pub trace: bool,
    /// Where to write the recorded trace (`None` = keep it in
    /// [`SimResult::trace`] only).
    pub trace_path: Option<std::path::PathBuf>,
    /// Collect the metrics registry ([`crate::obs::metrics`]) into
    /// [`SimResult::metrics`]. Off by default; purely observational.
    pub collect_metrics: bool,
    /// Sample windowed time series ([`crate::obs::series`]) every this
    /// many virtual seconds, attached to the metrics snapshot under
    /// `"series"` (arms the registry by itself). Off by default; purely
    /// observational like the other obs knobs.
    pub metrics_every: Option<f64>,
    /// Critical-path profiler ([`crate::obs::profile`]): exact
    /// per-category runtime attribution, per-learner blame, and what-if
    /// projections, attached to the metrics snapshot under `"profile"`
    /// (arms the registry by itself). Off by default; purely
    /// observational like the other obs knobs.
    pub profile: bool,
    /// Message-level network chaos ([`crate::netsim::faults`]): loss,
    /// duplication, reordering, delay spikes, and rack partitions on the
    /// learner↔root links, with ack/retry retransmission and
    /// receiver-side dedup ([`crate::netsim::reliable`]). Draws from its
    /// own named RNG stream; quiet (`none`, the default) takes the exact
    /// pre-chaos path, bit for bit.
    pub faults: FaultSpec,
}

impl SimConfig {
    /// A convenient default wiring: P775 cluster + compute models.
    pub fn paper(
        protocol: Protocol,
        arch: Arch,
        mu: usize,
        lambda: usize,
        epochs: usize,
        model: ModelCost,
    ) -> SimConfig {
        SimConfig {
            protocol,
            arch,
            mu,
            lambda,
            epochs,
            seed: 42,
            cluster: ClusterSpec::p775(),
            compute: LearnerCompute::p775(),
            model,
            shards: 1,
            eval_each_epoch: false,
            max_updates: None,
            churn: ChurnSchedule::none(),
            rescale: RescalePolicy::None,
            checkpoint_every_updates: 0,
            hetero: HeteroSpec::none(),
            adaptive: AdaptiveSpec::none(),
            compress: CodecSpec::None,
            stop_after_events: None,
            sim_checkpoint_path: None,
            trace: false,
            trace_path: None,
            collect_metrics: false,
            metrics_every: None,
            profile: false,
            faults: FaultSpec::none(),
        }
    }

    pub fn server_config(&self) -> ServerConfig {
        ServerConfig {
            protocol: self.protocol,
            mu: self.mu,
            lambda: self.lambda,
            samples_per_epoch: self.model.samples_per_epoch,
            target_epochs: self.epochs,
            shards: self.shards,
        }
    }
}

/// One epoch-boundary record.
#[derive(Debug, Clone)]
pub struct EpochStat {
    pub epoch: usize,
    pub sim_time: f64,
    pub train_loss: f64,
    pub test_loss: Option<f64>,
    pub test_error_pct: Option<f64>,
    /// λ_active when the epoch boundary was crossed (equals λ for
    /// churn-free runs).
    pub active_lambda: usize,
}

/// Simulation output.
#[derive(Debug)]
pub struct SimResult {
    /// Simulated wall-clock (seconds) to reach the target epochs.
    pub sim_seconds: f64,
    pub updates: u64,
    pub staleness: crate::coordinator::clock::StalenessStats,
    pub overlap: OverlapTracker,
    pub epochs: Vec<EpochStat>,
    /// Final held-out (loss, error %), if an evaluator was provided.
    pub final_eval: Option<(f64, f64)>,
    /// Final weights (numeric mode only).
    pub theta: Option<FlatVec>,
    /// Mean training loss over the last epoch (numeric mode).
    pub final_train_loss: f64,
    pub events_processed: u64,
    /// applyUpdate count per root shard (length = `SimConfig::shards`;
    /// lockstep shards make every entry equal `updates`).
    pub shard_updates: Vec<u64>,
    /// Churn log: every membership transition with its virtual time and
    /// the active-λ after it (empty for churn-free runs).
    pub churn: Vec<ChurnRecord>,
    /// Death → rejoin downtimes, in virtual seconds.
    pub recovery_secs: Vec<f64>,
    /// One record per membership change: the (μ, c, LR-factor) the
    /// rescaler put in force.
    pub rescales: Vec<RescaleRecord>,
    /// λ_active when the run ended.
    pub final_active_lambda: usize,
    /// Checkpoints captured (per `SimConfig::checkpoint_every_updates`).
    pub checkpoints_taken: u64,
    /// The most recent captured checkpoint, if any.
    pub last_checkpoint: Option<Checkpoint>,
    /// Backup-sync: total gradients dropped as too-slow (0 elsewhere).
    pub dropped_gradients: u64,
    /// Backup-sync: dropped-gradient count per learner slot (straggler
    /// attribution).
    pub dropped_by_learner: Vec<u64>,
    /// Fraction of the run each learner spent computing (per-learner
    /// utilization: under a barrier protocol, fast learners idle while a
    /// straggler finishes; under backup-sync the straggler stays busy but
    /// its work lands in `dropped_by_learner` instead).
    pub learner_utilization: Vec<f64>,
    /// Persistent per-learner speed factors in force (all 1.0 when the
    /// `hetero` knob is quiet).
    pub hetero_factors: Vec<f64>,
    /// Adaptive-n controller decisions, one per epoch (empty when off).
    pub adaptive: Vec<AdaptiveRecord>,
    /// Bytes delivered *into* the root tier (gradient pushes/relays,
    /// compressed when a codec is on) — the §3.3 bottleneck quantity.
    pub root_bytes_in: f64,
    /// Bytes sent *out of* the root tier (weight pulls/broadcasts;
    /// always dense — codecs compress gradients, not weights).
    pub root_bytes_out: f64,
    /// Per-learner bytes pushed onto the wire (compressed payload sizes;
    /// the stats-server compressed-bytes column).
    pub comm_bytes_by_learner: Vec<f64>,
    /// Final per-learner error-feedback residual L2 norms (empty when
    /// `compress` is `none` or the run is timing-only).
    pub residual_norms: Vec<f64>,
    /// Mid-flight sim checkpoint, when [`SimConfig::stop_after_events`]
    /// cut the run short (the other fields then describe the truncated
    /// run, not a finished one).
    pub sim_checkpoint: Option<SimCheckpoint>,
    /// Recorded trace events (when [`SimConfig::trace`] is on; also
    /// written to [`SimConfig::trace_path`] as Chrome trace JSON).
    pub trace: Option<Vec<crate::obs::trace::TraceEvent>>,
    /// Metrics snapshot (when [`SimConfig::collect_metrics`] is on).
    pub metrics: Option<Json>,
    /// Fault/retry/dedup accounting when [`SimConfig::faults`] is
    /// non-quiet (`None` for clean-network runs).
    pub faults: Option<FaultStats>,
}

/// A gradient payload in flight. Boxed so timing-only runs (payload
/// `None`, the common case for the paper-scale sweeps) pay one pointer
/// per event instead of carrying the full [`EncodedGrad`] inline through
/// every heap sift; numeric runs pay one small allocation per push next
/// to the model-sized gradient they already allocate.
type GradInFlight = Option<Box<EncodedGrad>>;

/// (learner, incarnation, encoded gradient, timestamp) — relayed leaf
/// batches carry the incarnation so a crash invalidates in-flight
/// gradients. Leaves forward encodings as-is (decoding happens at the
/// root, [`ShardedServer::push_encoded`]); the `none` codec rides as
/// `Dense`, which decodes without a copy.
type RelayBatch = Vec<(usize, u64, GradInFlight, Timestamp)>;

/// Learner-loop events carry the learner's *incarnation* at schedule
/// time: a kill bumps the slot's incarnation, so every event the dead
/// incarnation left in flight (its compute completion, its gradient on
/// the wire, its pending pull) is dropped on arrival instead of acting on
/// the rejoined learner — message-loss semantics with no queue surgery.
/// Delivery events additionally carry a per-link sequence number (`seq` /
/// `rseq`) stamped at send time when the fault plane is armed, so
/// receiver dedup windows can reject duplicated and retried messages;
/// quiet runs stamp 0 everywhere and never consult the windows.
enum Ev {
    /// Learner finished a mini-batch gradient.
    ComputeDone { learner: usize, inc: u64 },
    /// Gradient delivered to the root (Base). The payload travels *in*
    /// the event — it is taken from the learner at send time, so an
    /// adv*-style mini-batch finishing while the previous push is still
    /// in flight can never clobber an untransmitted gradient.
    PushAtRoot { learner: usize, inc: u64, grad: GradInFlight, ts: Timestamp, seq: u64 },
    /// Gradient delivered to the learner's leaf aggregator (Adv/Adv*);
    /// payload in the event, as with [`Ev::PushAtRoot`].
    PushAtLeaf { learner: usize, inc: u64, grad: GradInFlight, ts: Timestamp, seq: u64 },
    /// A leaf's aggregated batch arrived at the root.
    RelayAtRoot { leaf: usize, batch: RelayBatch, rseq: u64 },
    /// A pull completed at the learner.
    PullDone {
        learner: usize,
        inc: u64,
        snapshot: Option<Arc<FlatVec>>,
        ts: Timestamp,
        seq: u64,
    },
    /// Hardsync broadcast delivery.
    Broadcast {
        learner: usize,
        inc: u64,
        snapshot: Option<Arc<FlatVec>>,
        ts: Timestamp,
        seq: u64,
    },
    /// A scheduled membership change (kill/rejoin/join).
    Churn { event: ChurnEvent },
    /// The random failure process fires (self re-arming).
    RandomKill,
    /// A learner's retry chain exhausted its budget: the sender gives the
    /// peer up for unreachable and hands it to the membership path
    /// (Suspect → Dead) instead of letting a barrier deadlock on it.
    FaultDead { learner: usize, inc: u64, by_partition: bool },
    /// A partition window closed: revive the learners it evicted.
    PartitionHeal,
}

impl Ev {
    /// Timing-only serialization for mid-flight sim checkpoints. Numeric
    /// payloads (gradients, weight snapshots) never occur in timing runs;
    /// [`SimEngine::capture_sim_checkpoint`] refuses numeric mode before
    /// getting here, and the ensures below are the backstop.
    fn to_json(&self) -> Result<Json> {
        fn ev(kind: &str, rest: Vec<(&str, Json)>) -> Json {
            let mut pairs = vec![("k", Json::str(kind))];
            pairs.extend(rest);
            Json::obj(pairs)
        }
        fn learner_ev(kind: &str, l: usize, inc: u64, ts: Timestamp, seq: u64) -> Json {
            ev(
                kind,
                vec![
                    ("l", Json::num(l as f64)),
                    ("inc", Json::num(inc as f64)),
                    ("ts", Json::num(ts as f64)),
                    ("seq", Json::num(seq as f64)),
                ],
            )
        }
        Ok(match self {
            Ev::ComputeDone { learner, inc } => ev(
                "compute",
                vec![("l", Json::num(*learner as f64)), ("inc", Json::num(*inc as f64))],
            ),
            Ev::PushAtRoot { learner, inc, grad, ts, seq } => {
                anyhow::ensure!(grad.is_none(), "numeric gradient in a timing-only checkpoint");
                learner_ev("push_root", *learner, *inc, *ts, *seq)
            }
            Ev::PushAtLeaf { learner, inc, grad, ts, seq } => {
                anyhow::ensure!(grad.is_none(), "numeric gradient in a timing-only checkpoint");
                learner_ev("push_leaf", *learner, *inc, *ts, *seq)
            }
            Ev::RelayAtRoot { leaf, batch, rseq } => {
                let mut flat = Vec::with_capacity(batch.len() * 3);
                for (l, inc, grad, ts) in batch {
                    anyhow::ensure!(
                        grad.is_none(),
                        "numeric gradient in a timing-only checkpoint"
                    );
                    flat.extend([*l as u64, *inc, *ts]);
                }
                ev(
                    "relay",
                    vec![
                        ("leaf", Json::num(*leaf as f64)),
                        ("batch", Json::arr_u64(&flat)),
                        ("rseq", Json::num(*rseq as f64)),
                    ],
                )
            }
            Ev::PullDone { learner, inc, snapshot, ts, seq } => {
                anyhow::ensure!(
                    snapshot.is_none(),
                    "weight snapshot in a timing-only checkpoint"
                );
                learner_ev("pull", *learner, *inc, *ts, *seq)
            }
            Ev::Broadcast { learner, inc, snapshot, ts, seq } => {
                anyhow::ensure!(
                    snapshot.is_none(),
                    "weight snapshot in a timing-only checkpoint"
                );
                learner_ev("bcast", *learner, *inc, *ts, *seq)
            }
            Ev::Churn { event } => ev(
                "churn",
                vec![
                    ("at", Json::num(event.at)),
                    ("l", Json::num(event.learner as f64)),
                    (
                        "action",
                        Json::str(match event.action {
                            ChurnAction::Kill => "kill",
                            ChurnAction::Rejoin => "rejoin",
                            ChurnAction::Join => "join",
                        }),
                    ),
                ],
            ),
            Ev::RandomKill => ev("random_kill", vec![]),
            Ev::FaultDead { learner, inc, by_partition } => ev(
                "fault_dead",
                vec![
                    ("l", Json::num(*learner as f64)),
                    ("inc", Json::num(*inc as f64)),
                    ("bp", Json::Bool(*by_partition)),
                ],
            ),
            Ev::PartitionHeal => ev("heal", vec![]),
        })
    }

    fn from_json(v: &Json) -> Result<Ev> {
        // `seq`/`rseq` default to 0 when absent, so checkpoints written
        // before the fault layer existed still load.
        fn seq_of(v: &Json, key: &str) -> Result<u64> {
            Ok(match v.opt(key) {
                Some(x) => x.as_u64()?,
                None => 0,
            })
        }
        Ok(match v.get("k")?.as_str()? {
            "compute" => Ev::ComputeDone {
                learner: v.get("l")?.as_usize()?,
                inc: v.get("inc")?.as_u64()?,
            },
            "push_root" => Ev::PushAtRoot {
                learner: v.get("l")?.as_usize()?,
                inc: v.get("inc")?.as_u64()?,
                grad: None,
                ts: v.get("ts")?.as_u64()?,
                seq: seq_of(v, "seq")?,
            },
            "push_leaf" => Ev::PushAtLeaf {
                learner: v.get("l")?.as_usize()?,
                inc: v.get("inc")?.as_u64()?,
                grad: None,
                ts: v.get("ts")?.as_u64()?,
                seq: seq_of(v, "seq")?,
            },
            "relay" => {
                let flat = v.get("batch")?.as_u64_vec()?;
                anyhow::ensure!(
                    flat.len() % 3 == 0,
                    "relay batch length {} not a multiple of 3",
                    flat.len()
                );
                Ev::RelayAtRoot {
                    leaf: v.get("leaf")?.as_usize()?,
                    batch: flat
                        .chunks_exact(3)
                        .map(|c| (c[0] as usize, c[1], None, c[2]))
                        .collect(),
                    rseq: seq_of(v, "rseq")?,
                }
            }
            "pull" => Ev::PullDone {
                learner: v.get("l")?.as_usize()?,
                inc: v.get("inc")?.as_u64()?,
                snapshot: None,
                ts: v.get("ts")?.as_u64()?,
                seq: seq_of(v, "seq")?,
            },
            "bcast" => Ev::Broadcast {
                learner: v.get("l")?.as_usize()?,
                inc: v.get("inc")?.as_u64()?,
                snapshot: None,
                ts: v.get("ts")?.as_u64()?,
                seq: seq_of(v, "seq")?,
            },
            "churn" => Ev::Churn {
                event: ChurnEvent {
                    at: v.get("at")?.as_f64()?,
                    learner: v.get("l")?.as_usize()?,
                    action: match v.get("action")?.as_str()? {
                        "kill" => ChurnAction::Kill,
                        "rejoin" => ChurnAction::Rejoin,
                        "join" => ChurnAction::Join,
                        other => anyhow::bail!("unknown churn action {other:?}"),
                    },
                },
            },
            "random_kill" => Ev::RandomKill,
            "fault_dead" => Ev::FaultDead {
                learner: v.get("l")?.as_usize()?,
                inc: v.get("inc")?.as_u64()?,
                by_partition: v.get("bp")?.as_bool()?,
            },
            "heal" => Ev::PartitionHeal,
            other => anyhow::bail!("unknown event kind {other:?}"),
        })
    }
}

struct Slot {
    state: LearnerState,
    /// Adv* staging buffer: the gradient (and its timestamp) waiting for
    /// the push pipeline to free. The learner stalls once this is
    /// occupied, so it holds at most one gradient; Base/Adv pushes carry
    /// their payload in the push event instead.
    pending_grad: GradInFlight,
    pending_ts: Timestamp,
    compute_cost: f64,
    blocked_since: f64,
    pipe_busy: bool,
    /// Adv*: a finished gradient is waiting for the push pipeline.
    pipe_waiting: bool,
    /// Bumped on every death; stale-incarnation events are dropped.
    inc: u64,
    overlap: OverlapTracker,
}

struct LeafSim {
    queue: RelayBatch,
    relay_busy: bool,
    /// Pull cache: last fetched weights (ts, ready time, payload).
    cache_ts: Timestamp,
    cache_ready: f64,
    cache_snap: Option<Arc<FlatVec>>,
}

/// A routed-message verdict with the byte overhead already booked into
/// the plane's ledger; the caller adds `extra_bytes` to the direction's
/// root-byte counter (retransmissions and injected duplicates re-cross
/// the same link as the original).
enum Routed {
    Deliver { at: f64, dup_at: Option<f64>, retries: u32, extra_bytes: f64 },
    Lost { give_up_at: f64, by_partition: bool, extra_bytes: f64 },
}

/// Everything the engine tracks only when the fault plane is armed: the
/// plane itself, per-link sequence counters (stamped at send time), the
/// receiver-side dedup windows, and which learners fault-eviction took
/// down (partition victims revive on heal; loss victims stay dead).
struct FaultRuntime {
    plane: FaultPlane,
    /// Next upstream (gradient push) sequence per learner.
    up_next: Vec<u64>,
    /// Next downstream sequence per learner (pulls and broadcasts share
    /// one stream — a learner waits on at most one of them at a time).
    down_next: Vec<u64>,
    /// Next relay sequence per aggregation leaf.
    rseq_next: Vec<u64>,
    /// Dedup windows: root/leaf gradient ingress per learner.
    up_win: Vec<DedupWindow>,
    /// Dedup windows: weight deliveries per learner.
    down_win: Vec<DedupWindow>,
    /// Dedup windows: relayed leaf batches at the root.
    relay_win: Vec<DedupWindow>,
    /// Learner evicted by retry exhaustion (still down).
    evicted: Vec<bool>,
    /// The eviction was partition-blocked, so the next heal revives it.
    evicted_by_partition: Vec<bool>,
}

impl FaultRuntime {
    fn new(spec: FaultSpec, seed: u64, lambda: usize, n_leaves: usize) -> FaultRuntime {
        FaultRuntime {
            plane: FaultPlane::new(spec, seed, lambda),
            up_next: vec![0; lambda],
            down_next: vec![0; lambda],
            rseq_next: vec![0; n_leaves],
            up_win: vec![DedupWindow::new(); lambda],
            down_win: vec![DedupWindow::new(); lambda],
            relay_win: vec![DedupWindow::new(); n_leaves],
            evicted: vec![false; lambda],
            evicted_by_partition: vec![false; lambda],
        }
    }

    /// Route a learner↔infra message (capped retries; partitions apply).
    fn route(
        &mut self,
        now: f64,
        l: usize,
        bytes: f64,
        price: impl FnMut(f64) -> f64,
    ) -> Routed {
        match self.plane.route(now, l, price) {
            RouteOutcome::Deliver { at, dup_at, retries } => {
                let extra = (f64::from(retries) + f64::from(dup_at.is_some() as u8)) * bytes;
                self.plane.stats.retry_bytes += extra;
                Routed::Deliver { at, dup_at, retries, extra_bytes: extra }
            }
            RouteOutcome::Lost { give_up_at, retries, by_partition } => {
                let extra = f64::from(retries) * bytes;
                self.plane.stats.retry_bytes += extra;
                Routed::Lost { give_up_at, by_partition, extra_bytes: extra }
            }
        }
    }

    /// Route an infra↔infra relay (delivery guaranteed at the safety cap).
    fn route_reliable(
        &mut self,
        now: f64,
        bytes: f64,
        price: impl FnMut(f64) -> f64,
    ) -> (f64, Option<f64>, f64) {
        match self.plane.route_reliable(now, price) {
            RouteOutcome::Deliver { at, dup_at, retries } => {
                let extra = (f64::from(retries) + f64::from(dup_at.is_some() as u8)) * bytes;
                self.plane.stats.retry_bytes += extra;
                (at, dup_at, extra)
            }
            RouteOutcome::Lost { .. } => unreachable!("reliable routing never loses"),
        }
    }
}

pub struct SimEngine<'a> {
    cfg: &'a SimConfig,
    server: ShardedServer,
    fabric: Fabric,
    q: EventQueue<Ev>,
    slots: Vec<Slot>,
    leaves: Vec<LeafSim>,
    tree: PsTree,
    rng: Rng,
    barrier: Vec<usize>,
    /// `in_barrier[l]` mirrors membership of `barrier` (the Vec keeps the
    /// broadcast *order*, which fabric endpoint sequencing depends on; the
    /// mask makes kill-time removal and backup-sync waiting checks O(1)
    /// instead of O(λ) scans at datacenter scale).
    in_barrier: Vec<bool>,
    /// Reusable drain buffer for `maybe_broadcast` (swapped with
    /// `barrier` so neither Vec surrenders its capacity per round).
    waiting_scratch: Vec<usize>,
    /// Scratch mask: backup-sync "is this learner in the waiting set".
    waiting_mask: Vec<bool>,
    /// Reusable live-learner list for the random failure process.
    live_scratch: Vec<usize>,
    /// Leaf → member learner ids, precomputed once ([`PsTree::members`]
    /// is an O(λ) scan per call — ruinous per broadcast at λ ≈ 4096).
    leaf_members: Vec<Vec<usize>>,
    /// Timestamp as of the last hardsync broadcast (guards against
    /// broadcasting before the root has folded every relayed gradient).
    last_bcast_ts: Timestamp,
    /// Recent update history (time, ts, snapshot) for the adv* broadcast
    /// model; pruned to the broadcast window.
    recent: VecDeque<(f64, Timestamp, Option<Arc<FlatVec>>)>,
    /// Weight-snapshot cache keyed by timestamp: many pulls land between
    /// two updates, and cloning the full parameter vector per pull was
    /// the engine's top allocation cost (see EXPERIMENTS.md §Perf-L3).
    snap_cache: Option<(Timestamp, Arc<FlatVec>)>,
    /// Retired snapshot buffers awaiting reuse: when a cache entry (or a
    /// pruned adv* history entry) is the last reference to its `Arc`, the
    /// buffer returns here and the next clock tick assembles into it
    /// instead of allocating a fresh model-sized vector.
    snap_pool: Vec<FlatVec>,
    provider: Option<&'a mut dyn GradProvider>,
    evaluator: Option<&'a mut dyn Evaluator>,
    numeric: bool,
    /// Compressed-payload sizes for every transfer (push/relay/pull);
    /// with `compress none` each equals `cfg.model.bytes` exactly.
    wire: WireModel,
    /// Per-learner codecs (numeric runs with a codec on; `None` keeps
    /// the baseline value path untouched).
    comm: Option<CommState>,
    /// Cumulative bytes into / out of the root tier (the §3.3 quantity
    /// `benches/perf_comm.rs` sweeps).
    root_bytes_in: f64,
    root_bytes_out: f64,
    /// Per-learner bytes pushed onto the wire.
    comm_bytes_by_learner: Vec<f64>,
    base_compute: f64,
    /// Fabric endpoints of the root shards (one per shard; the flat
    /// server of the paper is the single-endpoint case).
    ps_eps: Vec<usize>,
    bcast_period: f64,
    epoch_losses: Vec<f64>,
    epoch_stats: Vec<EpochStat>,
    last_epoch_loss: f64,
    /// Elastic membership ledger (all-Active for churn-free runs).
    membership: Membership,
    /// Random-failure process (inert unless the schedule sets a rate).
    injector: FailureInjector,
    /// μ·λ = const rescaling (inert under `RescalePolicy::None`).
    rescaler: Rescaler,
    /// Per-learner μ currently in force (rescaled on churn).
    cur_mu: usize,
    /// Copy of the LR policy for rescale-factor reporting (the server
    /// owns the live one).
    lr: LrPolicy,
    rescale_log: Vec<RescaleRecord>,
    checkpoints_taken: u64,
    last_checkpoint: Option<Checkpoint>,
    /// Per-learner speed heterogeneity (inert when the spec is quiet;
    /// draws from its own RNG stream, never the engine's).
    hetero: HeteroModel,
    /// Adaptive-n staleness controller (None when the knob is off).
    adaptive: Option<AdaptiveController>,
    /// Whether a RandomKill event is currently scheduled. The process
    /// disarms instead of re-arming when no learner is live (otherwise an
    /// all-dead run would spin on self-scheduled kills forever) and is
    /// re-armed by the next revive.
    random_armed: bool,
    /// Set by [`SimEngine::install_sim_checkpoint`]: `run` then skips its
    /// cold-start prologue (churn scheduling, injector arm, initial
    /// compute kicks) — the restored event queue already holds the
    /// mid-flight continuation.
    resumed: bool,
    /// Observability (trace recorder + metrics registry;
    /// [`crate::obs::Obs::off`] — one branch per site — when both knobs
    /// are quiet). Strictly observational: it never draws from an engine
    /// RNG or perturbs event order, so trajectories are bit-identical
    /// either way.
    obs: crate::obs::Obs,
    /// Fault plane + reliability state, armed only when
    /// [`SimConfig::faults`] is non-quiet — `None` keeps every send site
    /// on the exact pre-chaos path.
    faults: Option<FaultRuntime>,
}

impl<'a> SimEngine<'a> {
    pub fn new(
        cfg: &'a SimConfig,
        theta0: FlatVec,
        optimizer: Optimizer,
        lr: LrPolicy,
        provider: Option<&'a mut dyn GradProvider>,
        evaluator: Option<&'a mut dyn Evaluator>,
    ) -> SimEngine<'a> {
        let numeric = provider.is_some();
        let lambda = cfg.lambda;
        // Learners whose first scheduled churn action is Join start in the
        // Joining phase (deferred spot instances); ids are validated
        // against λ at the top of `run`, so filtering here cannot hide a
        // bad schedule.
        let joining: Vec<usize> =
            cfg.churn.joining_ids().into_iter().filter(|&l| l < lambda).collect();
        let membership = Membership::with_joining(lambda, &joining)
            .expect("joining ids pre-filtered to < λ");
        let lpn = cfg.cluster.learners_per_node.max(1);
        let n_nodes = lambda.div_ceil(lpn);
        let tree = PsTree::with_shards(lambda, lpn, cfg.shards);
        let slots = (0..lambda)
            .map(|id| Slot {
                state: LearnerState::new(id, &theta0),
                pending_grad: None,
                pending_ts: 0,
                compute_cost: 0.0,
                blocked_since: 0.0,
                pipe_busy: false,
                pipe_waiting: false,
                inc: 0,
                overlap: OverlapTracker::default(),
            })
            .collect();
        let leaves = (0..tree.n_leaves)
            .map(|_| LeafSim {
                queue: Vec::new(),
                relay_busy: false,
                cache_ts: 0,
                cache_ready: 0.0,
                cache_snap: None,
            })
            .collect();
        // Adv* weight propagation: one broadcast subtree per root shard,
        // each carrying its θ slice ([`crate::comm::stripe`]). S = 1
        // reproduces the classic single-tree period bit for bit.
        let bcast_period = tree.broadcast_plan().period(&cfg.cluster, cfg.model.bytes);
        // Leaf membership is static for the life of the run: precompute it
        // so broadcasts stop paying `tree.members`' O(λ) scan per leaf.
        let leaf_members: Vec<Vec<usize>> =
            (0..tree.n_leaves).map(|leaf| tree.members(leaf).collect()).collect();
        let n_leaves = tree.n_leaves;
        let n_params = theta0.len();
        let lr_copy = lr.clone();
        let server = ShardedServer::new(
            cfg.server_config(),
            if numeric { theta0 } else { FlatVec::zeros(0) },
            optimizer,
            lr,
        );
        // Each PS shard process handles its incoming messages one by one
        // (§3.2): a shard's sends and receives share a single service
        // queue, but the S shards serve independently — the §3.3 fix.
        let ps_eps = tree.shard_endpoints(n_nodes);
        let mut fabric = Fabric::new(cfg.cluster.clone(), n_nodes + ps_eps.len());
        for &e in &ps_eps {
            fabric.set_single_duplex(e);
        }
        SimEngine {
            cfg,
            server,
            fabric,
            // Steady state holds a few events per live learner (compute,
            // push, pull/broadcast, relays) plus the scheduled churn —
            // pre-reserving spares the heap its doubling migrations.
            q: EventQueue::with_capacity(4 * lambda + cfg.churn.events.len() + 8),
            slots,
            leaves,
            tree,
            rng: Rng::new(cfg.seed),
            barrier: Vec::with_capacity(lambda),
            in_barrier: vec![false; lambda],
            waiting_scratch: Vec::with_capacity(lambda),
            waiting_mask: vec![false; lambda],
            live_scratch: Vec::with_capacity(lambda),
            leaf_members,
            last_bcast_ts: 0,
            snap_cache: None,
            snap_pool: Vec::new(),
            recent: VecDeque::new(),
            provider,
            evaluator,
            numeric,
            wire: WireModel::new(cfg.compress, cfg.model.bytes),
            comm: if numeric {
                CommState::build(cfg.compress, lambda, n_params, cfg.seed)
            } else {
                None
            },
            root_bytes_in: 0.0,
            root_bytes_out: 0.0,
            comm_bytes_by_learner: vec![0.0; lambda],
            base_compute: cfg.compute.minibatch_secs(&cfg.model, cfg.mu),
            ps_eps,
            bcast_period,
            epoch_losses: Vec::new(),
            epoch_stats: Vec::new(),
            last_epoch_loss: f64::NAN,
            membership,
            injector: FailureInjector::new(
                cfg.churn.kill_rate_per_ksec,
                cfg.churn.mean_downtime_secs,
                cfg.seed,
            ),
            rescaler: Rescaler::new(cfg.rescale, cfg.mu, cfg.lambda),
            cur_mu: cfg.mu,
            lr: lr_copy,
            rescale_log: Vec::new(),
            checkpoints_taken: 0,
            last_checkpoint: None,
            hetero: HeteroModel::build(&cfg.hetero, lambda, cfg.seed),
            adaptive: AdaptiveController::new(
                &cfg.adaptive,
                cfg.protocol.effective_n(lambda).max(1),
            ),
            random_armed: false,
            resumed: false,
            obs: crate::obs::Obs::new(
                cfg.trace,
                cfg.collect_metrics,
                cfg.metrics_every,
                cfg.profile,
                lambda,
            ),
            faults: if cfg.faults.is_quiet() {
                None
            } else {
                Some(FaultRuntime::new(cfg.faults.clone(), cfg.seed, lambda, n_leaves))
            },
        }
    }

    /// Whether this run exercises the elastic machinery at all. Quiet
    /// runs skip the initial membership normalization so churn-free
    /// trajectories stay bit-identical with pre-elastic builds. A faulted
    /// network counts: retry exhaustion evicts through the same
    /// membership path a churn kill takes.
    fn elastic_enabled(&self) -> bool {
        !self.cfg.churn.is_quiet()
            || self.cfg.rescale != RescalePolicy::None
            || self.faults.is_some()
    }

    fn node_of(&self, l: usize) -> usize {
        l / self.cfg.cluster.learners_per_node.max(1)
    }

    fn leaf_node(&self, leaf: usize) -> usize {
        self.node_of(leaf * self.tree.fanout)
    }

    /// Snapshot of the server weights at its current timestamp, cached so
    /// repeated pulls between two updates share one allocation (the
    /// assembly from shards copies at the same rate the flat server
    /// cloned θ), and *pooled* so successive clock ticks recycle the same
    /// buffer: a stale cache entry this engine holds the last reference
    /// to is reclaimed instead of dropped, and the new snapshot assembles
    /// into it ([`ShardedServer::assemble_weights_into`] overwrites every
    /// element, so reuse is bit-identical to a fresh allocation).
    fn server_snapshot(&mut self) -> Option<Arc<FlatVec>> {
        if !self.numeric {
            return None;
        }
        let ts = self.server.timestamp();
        if let Some((cached_ts, snap)) = &self.snap_cache {
            if *cached_ts == ts {
                return Some(snap.clone());
            }
        }
        if let Some((_, stale)) = self.snap_cache.take() {
            self.reclaim_snapshot(stale);
        }
        let mut buf = self.snap_pool.pop().unwrap_or_else(|| FlatVec::zeros(0));
        self.server.assemble_weights_into(&mut buf);
        let snap = Arc::new(buf);
        self.snap_cache = Some((ts, snap.clone()));
        Some(snap)
    }

    /// Recycle a retired snapshot's buffer if nobody else (an in-flight
    /// pull event, the adv* history, a leaf cache) still shares it. The
    /// pool is bounded: one spare covers the steady per-tick cadence, a
    /// second absorbs the cache/history handoff racing a tick.
    fn reclaim_snapshot(&mut self, snap: Arc<FlatVec>) {
        if self.snap_pool.len() < 2 {
            if let Some(buf) = Arc::into_inner(snap) {
                self.snap_pool.push(buf);
            }
        }
    }

    /// Gather the time-series gauges from state the engine already
    /// tracks ([`crate::obs::series::SeriesInputs`]); pure reads, so the
    /// sampler cannot perturb the trajectory.
    fn series_inputs(&self) -> crate::obs::series::SeriesInputs {
        let (stale_count, stale_sum) = self.server.staleness.totals();
        crate::obs::series::SeriesInputs {
            queue_depth: self.q.len() as u64,
            active_lambda: self.membership.active_count() as u64,
            stale_count,
            stale_sum,
            stale_max: self.server.staleness.max,
            bytes_in: self.root_bytes_in,
        }
    }

    fn series_tick(&mut self, now: f64) {
        let inputs = self.series_inputs();
        self.obs.series_tick(now, &inputs);
    }

    /// Run the simulation to completion.
    pub fn run(mut self) -> Result<SimResult> {
        self.cfg.cluster.validate()?;
        anyhow::ensure!(
            !(self.cfg.protocol.is_barrier() && self.cfg.arch == Arch::AdvStar),
            "a barrier protocol (hardsync/backup-sync) + Rudra-adv* is \
             contradictory: adv* decouples the push/pull the barrier \
             requires (the paper pairs adv* with softsync only — Table 4)"
        );
        if let Some(max_id) = self.cfg.churn.max_learner_id() {
            anyhow::ensure!(
                max_id < self.cfg.lambda,
                "churn schedule references learner {max_id}, but λ = {}",
                self.cfg.lambda
            );
        }
        if let Some(max_id) = self.cfg.hetero.max_learner_id() {
            anyhow::ensure!(
                max_id < self.cfg.lambda,
                "hetero spec references learner {max_id}, but λ = {}",
                self.cfg.lambda
            );
        }
        anyhow::ensure!(
            self.adaptive.is_none()
                || matches!(self.cfg.protocol, Protocol::NSoftsync { .. }),
            "the adaptive-n controller retunes the n-softsync splitting \
             parameter; protocol {} has none",
            self.cfg.protocol.label()
        );
        if let Protocol::BackupSync { .. } = self.cfg.protocol {
            // the checked quota is the single source of the b < λ rule
            self.cfg.protocol.try_gradients_per_update(self.cfg.lambda)?;
        }
        if !self.cfg.faults.partitions.is_empty() {
            anyhow::ensure!(
                self.cfg.faults.racks() <= self.cfg.lambda,
                "fault spec names {} racks, but λ = {} learners cannot \
                 populate them",
                self.cfg.faults.racks(),
                self.cfg.lambda
            );
        }
        // A resumed engine skips the cold-start prologue entirely: the
        // restored event queue already carries the scheduled churn, the
        // armed failure process, and every in-flight compute/push/pull.
        // (The active-count check belongs to the prologue too — a resume
        // may legitimately land mid-outage, with a rejoin still queued.)
        if !self.resumed {
            anyhow::ensure!(
                self.membership.active_count() > 0,
                "churn schedule defers every learner's join: nothing can start"
            );
            // Elastic runs normalize the server's quota/μ to the *initial*
            // active set (deferred joins may make it smaller than λ).
            if self.elastic_enabled() {
                self.on_membership_change(0.0, None)?;
            }
            // `ChurnEvent` is `Copy` and `self.cfg` is a shared `'a`
            // borrow: schedule straight off the config instead of cloning
            // the whole event vector per run (it used to be re-cloned by
            // every grid point and warm-start prologue).
            let cfg = self.cfg;
            for &ev in &cfg.churn.events {
                self.q.schedule_at(ev.at, Ev::Churn { event: ev });
            }
            if self.injector.enabled() {
                let dt = self.injector.next_kill_delay();
                self.q.schedule_in(dt, Ev::RandomKill);
                self.random_armed = true;
            }
            // Every partition window gets a heal event at its close, so
            // partition-evicted learners come back deterministically.
            let heals: Vec<f64> = self
                .faults
                .as_ref()
                .map(|rt| rt.plane.spec().partitions.iter().map(|w| w.end()).collect())
                .unwrap_or_default();
            for at in heals {
                self.q.schedule_at(at, Ev::PartitionHeal);
            }
            for l in 0..self.cfg.lambda {
                if self.membership.is_live(l) {
                    self.start_compute(0.0, l);
                }
            }
        }
        let max_updates = self.cfg.max_updates.unwrap_or(u64::MAX);
        let stop_after = self.cfg.stop_after_events.unwrap_or(u64::MAX);
        let mut stopped_early = false;
        loop {
            // Checked *before* the pop: event k+1 must still be pending
            // when the checkpoint is cut, so the resumed run replays it.
            if self.q.processed() >= stop_after {
                stopped_early = !self.q.is_empty();
                break;
            }
            let Some((now, ev)) = self.q.pop() else { break };
            if self.server.done() || self.server.updates >= max_updates {
                break;
            }
            self.obs.queue_depth(self.q.len());
            if self.obs.series_enabled() {
                self.series_tick(now);
            }
            match ev {
                Ev::ComputeDone { learner, inc } => self.on_compute_done(now, learner, inc)?,
                Ev::PushAtRoot { learner, inc, grad, ts, seq } => {
                    self.on_push_at_root(now, learner, inc, grad, ts, seq)?
                }
                Ev::PushAtLeaf { learner, inc, grad, ts, seq } => {
                    self.on_push_at_leaf(now, learner, inc, grad, ts, seq)?
                }
                Ev::RelayAtRoot { leaf, batch, rseq } => {
                    self.on_relay_at_root(now, leaf, batch, rseq)?
                }
                Ev::PullDone { learner, inc, snapshot, ts, seq } => {
                    self.on_pull_done(now, learner, inc, snapshot, ts, seq)
                }
                Ev::Broadcast { learner, inc, snapshot, ts, seq } => {
                    self.on_broadcast(now, learner, inc, snapshot, ts, seq)
                }
                Ev::Churn { event } => self.on_churn(now, event)?,
                Ev::RandomKill => self.on_random_kill(now)?,
                Ev::FaultDead { learner, inc, by_partition } => {
                    self.on_fault_dead(now, learner, inc, by_partition)?
                }
                Ev::PartitionHeal => self.on_partition_heal(now)?,
            }
        }

        let sim_checkpoint = if stopped_early {
            let ckpt = self.capture_sim_checkpoint()?;
            if let Some(path) = &self.cfg.sim_checkpoint_path {
                ckpt.save(path)?;
            }
            Some(ckpt)
        } else {
            None
        };
        let final_eval = if self.numeric {
            let theta = self.server.assemble_weights();
            match &mut self.evaluator {
                Some(e) => Some(e.eval(&theta)?),
                None => None,
            }
        } else {
            None
        };
        let mut overlap = OverlapTracker::default();
        for s in &self.slots {
            overlap.merge(&s.overlap);
        }
        let horizon = self.q.now();
        let learner_utilization: Vec<f64> = self
            .slots
            .iter()
            .map(|s| if horizon > 0.0 { s.overlap.compute / horizon } else { 0.0 })
            .collect();
        let final_train_loss = if self.epoch_losses.is_empty() {
            self.last_epoch_loss
        } else {
            crate::util::mean(&self.epoch_losses)
        };
        // The queue tracks its own schedule-time peak; fold it in so the
        // gauge reflects the true high water, not just post-pop depths.
        self.obs.queue_depth(self.q.high_water());
        if self.obs.series_enabled() {
            let now = self.q.now();
            let inputs = self.series_inputs();
            self.obs.series_finish(now, &inputs);
        }
        if self.obs.profile_enabled() {
            // Per-shard ingress busy seconds (a pure read off the wire
            // model) ride the profile as per-shard blame.
            let shard_busy: Vec<f64> = self
                .ps_eps
                .iter()
                .map(|&e| self.fabric.ingress_utilization(e, horizon) * horizon)
                .collect();
            self.obs.profile_finish(horizon, shard_busy);
        }
        let mut metrics = self.obs.metrics_snapshot(
            &self.server.staleness,
            &self.server.shard_updates(),
            self.server.pushes_by(),
            self.root_bytes_in,
            self.root_bytes_out,
        );
        if let (Some(m), Some(rt)) = (&mut metrics, &self.faults) {
            crate::obs::metrics::attach_faults(m, rt.plane.stats.to_json());
        }
        let trace = self.obs.take_trace();
        if let (Some(events), Some(path)) = (&trace, &self.cfg.trace_path) {
            crate::obs::trace::write(path, events)?;
        }
        Ok(SimResult {
            sim_seconds: self.q.now(),
            updates: self.server.updates,
            staleness: self.server.staleness.clone(),
            overlap,
            epochs: self.epoch_stats,
            final_eval,
            theta: if self.numeric { Some(self.server.assemble_weights()) } else { None },
            final_train_loss,
            events_processed: self.q.processed(),
            shard_updates: self.server.shard_updates(),
            churn: self.membership.log,
            recovery_secs: self.membership.recovery_secs,
            rescales: self.rescale_log,
            final_active_lambda: self.server.active_lambda(),
            checkpoints_taken: self.checkpoints_taken,
            last_checkpoint: self.last_checkpoint,
            dropped_gradients: self.server.dropped,
            dropped_by_learner: self.server.dropped_by().to_vec(),
            learner_utilization,
            hetero_factors: self.hetero.persistent().to_vec(),
            adaptive: self.adaptive.map(|c| c.log).unwrap_or_default(),
            root_bytes_in: self.root_bytes_in,
            root_bytes_out: self.root_bytes_out,
            comm_bytes_by_learner: self.comm_bytes_by_learner,
            residual_norms: self.comm.map(|c| c.residual_norms()).unwrap_or_default(),
            sim_checkpoint,
            trace,
            metrics,
            faults: self.faults.map(|rt| rt.plane.stats),
        })
    }

    /// Canonical label of the run configuration, recorded in mid-flight
    /// sim checkpoints and the persistent run index
    /// ([`crate::obs::runindex`]). Everything that shapes the trajectory
    /// participates; `stop_after_events`, `sim_checkpoint_path`,
    /// `max_updates`, and the obs knobs
    /// (`trace`/`collect_metrics`/`metrics_every`/`profile`) deliberately
    /// do not
    /// (a resume legitimately changes them — a traced resume of an
    /// untraced checkpoint is valid).
    pub fn config_fingerprint(cfg: &SimConfig) -> String {
        let mut fp = format!(
            "timing|{}|{:?}|mu{}|lambda{}|epochs{}|seed{}|shards{}|{:?}|{:?}|{:?}|{:?}|{:?}|ckpt{}|{:?}|{:?}|{:?}",
            cfg.protocol.label(),
            cfg.arch,
            cfg.mu,
            cfg.lambda,
            cfg.epochs,
            cfg.seed,
            cfg.shards,
            cfg.cluster,
            cfg.compute,
            cfg.model,
            cfg.churn,
            cfg.rescale,
            cfg.checkpoint_every_updates,
            cfg.hetero,
            cfg.adaptive,
            cfg.compress,
        );
        // Appended only when armed, so pre-chaos checkpoints of quiet
        // configs keep their exact historical fingerprint.
        if !cfg.faults.is_quiet() {
            fp.push_str("|faults[");
            fp.push_str(&cfg.faults.label());
            fp.push(']');
        }
        fp
    }

    /// Capture the full mid-flight simulation state: the pending event
    /// queue, per-learner slots, leaf relay queues and caches, the adv*
    /// broadcast history, fabric contention horizons, membership ledger,
    /// and a nested server checkpoint with every RNG stream. Timing-only
    /// — numeric runs carry model-sized payloads in flight and checkpoint
    /// at update boundaries instead (`checkpoint_every_updates`).
    fn capture_sim_checkpoint(&self) -> Result<SimCheckpoint> {
        anyhow::ensure!(
            !self.numeric,
            "mid-flight sim checkpoints cover timing-only runs; numeric runs \
             checkpoint at update boundaries (checkpoint_every_updates)"
        );
        let mut streams: Vec<(&str, &Rng)> = vec![("engine", &self.rng)];
        if self.hetero.enabled() {
            streams.push(("hetero", self.hetero.rng()));
        }
        let server = Checkpoint::capture_full(
            "sim-resume",
            &self.server,
            &streams,
            self.comm.as_ref(),
            self.adaptive.as_ref(),
        );

        let mut q_rows = Vec::new();
        for (at, seq, ev) in self.q.entries() {
            q_rows.push(Json::obj(vec![
                ("at", Json::num(at)),
                ("seq", Json::num(seq as f64)),
                ("ev", ev.to_json()?),
            ]));
        }

        let lambda = self.cfg.lambda;
        let mut compute_cost = Vec::with_capacity(lambda);
        let mut blocked_since = Vec::with_capacity(lambda);
        let mut pipe_busy = Vec::with_capacity(lambda);
        let mut pipe_waiting = Vec::with_capacity(lambda);
        let mut inc = Vec::with_capacity(lambda);
        let mut pending_ts = Vec::with_capacity(lambda);
        let mut state_ts = Vec::with_capacity(lambda);
        let mut state_steps = Vec::with_capacity(lambda);
        let mut ov_compute = Vec::with_capacity(lambda);
        let mut ov_exposed = Vec::with_capacity(lambda);
        let mut ov_hidden = Vec::with_capacity(lambda);
        for s in &self.slots {
            anyhow::ensure!(
                s.pending_grad.is_none(),
                "numeric gradient staged in a timing-only checkpoint"
            );
            compute_cost.push(s.compute_cost);
            blocked_since.push(s.blocked_since);
            pipe_busy.push(s.pipe_busy as u64);
            pipe_waiting.push(s.pipe_waiting as u64);
            inc.push(s.inc);
            pending_ts.push(s.pending_ts);
            state_ts.push(s.state.ts);
            state_steps.push(s.state.steps);
            ov_compute.push(s.overlap.compute);
            ov_exposed.push(s.overlap.comm_exposed);
            ov_hidden.push(s.overlap.comm_hidden);
        }
        let slots = Json::obj(vec![
            ("compute_cost", Json::arr_f64(&compute_cost)),
            ("blocked_since", Json::arr_f64(&blocked_since)),
            ("pipe_busy", Json::arr_u64(&pipe_busy)),
            ("pipe_waiting", Json::arr_u64(&pipe_waiting)),
            ("inc", Json::arr_u64(&inc)),
            ("pending_ts", Json::arr_u64(&pending_ts)),
            ("state_ts", Json::arr_u64(&state_ts)),
            ("state_steps", Json::arr_u64(&state_steps)),
            ("overlap_compute", Json::arr_f64(&ov_compute)),
            ("overlap_exposed", Json::arr_f64(&ov_exposed)),
            ("overlap_hidden", Json::arr_f64(&ov_hidden)),
        ]);

        let mut leaf_rows = Vec::with_capacity(self.leaves.len());
        for leaf in &self.leaves {
            anyhow::ensure!(
                leaf.cache_snap.is_none(),
                "weight snapshot cached in a timing-only checkpoint"
            );
            let mut flat = Vec::with_capacity(leaf.queue.len() * 3);
            for (l, linc, grad, ts) in &leaf.queue {
                anyhow::ensure!(
                    grad.is_none(),
                    "numeric gradient queued in a timing-only checkpoint"
                );
                flat.extend([*l as u64, *linc, *ts]);
            }
            leaf_rows.push(Json::obj(vec![
                ("queue", Json::arr_u64(&flat)),
                ("relay_busy", Json::Bool(leaf.relay_busy)),
                ("cache_ts", Json::num(leaf.cache_ts as f64)),
                ("cache_ready", Json::num(leaf.cache_ready)),
            ]));
        }

        let mut recent_t = Vec::with_capacity(self.recent.len());
        let mut recent_ts = Vec::with_capacity(self.recent.len());
        for (t, ts, snap) in &self.recent {
            anyhow::ensure!(
                snap.is_none(),
                "weight snapshot in the adv* history of a timing-only checkpoint"
            );
            recent_t.push(*t);
            recent_ts.push(*ts);
        }

        let epoch_rows: Vec<Json> = self
            .epoch_stats
            .iter()
            .map(|e| {
                // train_loss is NaN in timing mode (no losses to average),
                // which JSON cannot carry as a number — store the bits.
                Json::obj(vec![
                    ("epoch", Json::num(e.epoch as f64)),
                    ("sim_time", Json::num(e.sim_time)),
                    ("train_loss_bits", Json::str(format!("{:016x}", e.train_loss.to_bits()))),
                    ("active_lambda", Json::num(e.active_lambda as f64)),
                ])
            })
            .collect();

        let rescale_rows: Vec<Json> = self
            .rescale_log
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("at", Json::num(r.at)),
                    ("active_lambda", Json::num(r.active_lambda as f64)),
                    ("mu", Json::num(r.mu as f64)),
                    ("quota", Json::num(r.quota as f64)),
                    ("lr_factor", Json::num(r.lr_factor)),
                ])
            })
            .collect();

        let mut fab = Vec::new();
        for (a, b, c, d) in self.fabric.endpoint_state() {
            fab.extend([a, b, c, d]);
        }

        let barrier: Vec<u64> = self.barrier.iter().map(|&l| l as u64).collect();
        let mut engine = vec![
            ("events_processed", Json::num(self.q.processed() as f64)),
            (
                "queue",
                Json::obj(vec![
                    ("now", Json::num(self.q.now())),
                    ("seq", Json::num(self.q.seq() as f64)),
                    ("processed", Json::num(self.q.processed() as f64)),
                    ("entries", Json::Arr(q_rows)),
                ]),
            ),
            ("slots", slots),
            ("leaves", Json::Arr(leaf_rows)),
            ("barrier", Json::arr_u64(&barrier)),
            ("last_bcast_ts", Json::num(self.last_bcast_ts as f64)),
            ("recent_t", Json::arr_f64(&recent_t)),
            ("recent_ts", Json::arr_u64(&recent_ts)),
            ("membership", self.membership.to_json()),
            ("injector_rng", Json::str(format!("{:016x}", self.injector.rng_state()))),
            ("cur_mu", Json::num(self.cur_mu as f64)),
            ("rescales", Json::Arr(rescale_rows)),
            ("checkpoints_taken", Json::num(self.checkpoints_taken as f64)),
            ("root_bytes_in", Json::num(self.root_bytes_in)),
            ("root_bytes_out", Json::num(self.root_bytes_out)),
            ("comm_bytes", Json::arr_f64(&self.comm_bytes_by_learner)),
            ("epochs", Json::Arr(epoch_rows)),
            (
                "last_epoch_loss_bits",
                Json::str(format!("{:016x}", self.last_epoch_loss.to_bits())),
            ),
            ("random_armed", Json::Bool(self.random_armed)),
            ("fabric", Json::arr_f64(&fab)),
        ];
        if self.hetero.enabled() {
            let degraded: Vec<u64> =
                self.hetero.degraded_state().iter().map(|&d| d as u64).collect();
            engine.push(("hetero_degraded", Json::arr_u64(&degraded)));
        }
        if let Some(rt) = &self.faults {
            // In-flight retry chains need no extra state: retries are
            // priced at send time, so their deliveries/give-ups already
            // sit in the event queue and the RNG has advanced past them.
            engine.push(("fault_rng", Json::str(format!("{:016x}", rt.plane.rng_state()))));
            engine.push(("fault_stats", rt.plane.stats.to_json()));
            engine.push(("fault_up_next", Json::arr_u64(&rt.up_next)));
            engine.push(("fault_down_next", Json::arr_u64(&rt.down_next)));
            engine.push(("fault_rseq_next", Json::arr_u64(&rt.rseq_next)));
            engine.push(("fault_up_win", windows_to_json(&rt.up_win)));
            engine.push(("fault_down_win", windows_to_json(&rt.down_win)));
            engine.push(("fault_relay_win", windows_to_json(&rt.relay_win)));
            let ev: Vec<u64> = rt.evicted.iter().map(|&b| b as u64).collect();
            let evp: Vec<u64> = rt.evicted_by_partition.iter().map(|&b| b as u64).collect();
            engine.push(("fault_evicted", Json::arr_u64(&ev)));
            engine.push(("fault_evicted_bp", Json::arr_u64(&evp)));
        }
        if let Some(c) = &self.last_checkpoint {
            engine.push(("last_checkpoint", Json::str(c.to_json_string())));
        }
        Ok(SimCheckpoint::new(
            &Self::config_fingerprint(self.cfg),
            server,
            Json::obj(engine),
        ))
    }

    /// Install a mid-flight checkpoint into a freshly constructed engine
    /// (same config, timing-only). The subsequent [`SimEngine::run`]
    /// skips the cold-start prologue and continues the event stream
    /// bit-identically to an uninterrupted run.
    pub fn install_sim_checkpoint(&mut self, ckpt: &SimCheckpoint) -> Result<()> {
        use anyhow::Context;
        anyhow::ensure!(
            !self.numeric,
            "sim-checkpoint resume is timing-only (numeric runs restore \
             server checkpoints at update boundaries)"
        );
        ckpt.ensure_fingerprint(&Self::config_fingerprint(self.cfg))?;
        let restored = ckpt.server_checkpoint()?.restore()?;
        self.server = restored.server;
        self.rng = restored
            .rngs
            .get("engine")
            .cloned()
            .context("sim checkpoint missing the engine RNG stream")?;
        if restored.adaptive.is_some() {
            self.adaptive = restored.adaptive;
        }
        let e = ckpt.engine_state()?;

        let qj = e.get("queue")?;
        let mut entries = Vec::new();
        for row in qj.get("entries")?.as_arr()? {
            entries.push((
                row.get("at")?.as_f64()?,
                row.get("seq")?.as_u64()?,
                Ev::from_json(row.get("ev")?)?,
            ));
        }
        self.q = EventQueue::restore(
            qj.get("now")?.as_f64()?,
            qj.get("seq")?.as_u64()?,
            qj.get("processed")?.as_u64()?,
            entries,
        );

        let lambda = self.cfg.lambda;
        let s = e.get("slots")?;
        let compute_cost = s.get("compute_cost")?.as_f64_vec()?;
        let blocked_since = s.get("blocked_since")?.as_f64_vec()?;
        let pipe_busy = s.get("pipe_busy")?.as_u64_vec()?;
        let pipe_waiting = s.get("pipe_waiting")?.as_u64_vec()?;
        let inc = s.get("inc")?.as_u64_vec()?;
        let pending_ts = s.get("pending_ts")?.as_u64_vec()?;
        let state_ts = s.get("state_ts")?.as_u64_vec()?;
        let state_steps = s.get("state_steps")?.as_u64_vec()?;
        let ov_compute = s.get("overlap_compute")?.as_f64_vec()?;
        let ov_exposed = s.get("overlap_exposed")?.as_f64_vec()?;
        let ov_hidden = s.get("overlap_hidden")?.as_f64_vec()?;
        anyhow::ensure!(
            compute_cost.len() == lambda && inc.len() == lambda && state_ts.len() == lambda,
            "sim checkpoint has {} learner slots, config has {lambda}",
            compute_cost.len()
        );
        for (l, slot) in self.slots.iter_mut().enumerate() {
            slot.compute_cost = compute_cost[l];
            slot.blocked_since = blocked_since[l];
            slot.pipe_busy = pipe_busy[l] != 0;
            slot.pipe_waiting = pipe_waiting[l] != 0;
            slot.inc = inc[l];
            slot.pending_ts = pending_ts[l];
            slot.state.ts = state_ts[l];
            slot.state.steps = state_steps[l];
            slot.overlap.compute = ov_compute[l];
            slot.overlap.comm_exposed = ov_exposed[l];
            slot.overlap.comm_hidden = ov_hidden[l];
        }

        let leaf_rows = e.get("leaves")?.as_arr()?;
        anyhow::ensure!(
            leaf_rows.len() == self.leaves.len(),
            "sim checkpoint has {} leaves, tree has {}",
            leaf_rows.len(),
            self.leaves.len()
        );
        for (leaf, row) in self.leaves.iter_mut().zip(leaf_rows) {
            let flat = row.get("queue")?.as_u64_vec()?;
            anyhow::ensure!(flat.len() % 3 == 0, "leaf queue length not a multiple of 3");
            leaf.queue =
                flat.chunks_exact(3).map(|c| (c[0] as usize, c[1], None, c[2])).collect();
            leaf.relay_busy = row.get("relay_busy")?.as_bool()?;
            leaf.cache_ts = row.get("cache_ts")?.as_u64()?;
            leaf.cache_ready = row.get("cache_ready")?.as_f64()?;
            leaf.cache_snap = None;
        }

        self.barrier.clear();
        self.in_barrier.iter_mut().for_each(|b| *b = false);
        for x in e.get("barrier")?.as_u64_vec()? {
            let l = x as usize;
            anyhow::ensure!(l < lambda, "barrier learner {l} out of range (λ = {lambda})");
            self.barrier.push(l);
            self.in_barrier[l] = true;
        }
        self.last_bcast_ts = e.get("last_bcast_ts")?.as_u64()?;

        self.recent.clear();
        let recent_t = e.get("recent_t")?.as_f64_vec()?;
        let recent_ts = e.get("recent_ts")?.as_u64_vec()?;
        anyhow::ensure!(
            recent_t.len() == recent_ts.len(),
            "adv* history time/ts length mismatch"
        );
        for (t, ts) in recent_t.into_iter().zip(recent_ts) {
            self.recent.push_back((t, ts, None));
        }

        let membership = Membership::from_json(e.get("membership")?)?;
        anyhow::ensure!(
            membership.total() == lambda,
            "sim checkpoint membership covers {} learners, config has {lambda}",
            membership.total()
        );
        self.membership = membership;
        self.injector.restore_rng_state(
            u64::from_str_radix(e.get("injector_rng")?.as_str()?, 16)
                .context("bad injector RNG state")?,
        );
        if self.hetero.enabled() {
            let h = restored
                .rngs
                .get("hetero")
                .context("sim checkpoint missing the hetero RNG stream")?;
            let degraded: Vec<bool> =
                e.get("hetero_degraded")?.as_u64_vec()?.iter().map(|&x| x != 0).collect();
            self.hetero.restore_state(h.state(), &degraded)?;
        }
        // Armed-ness matches by construction: the faults label is part of
        // the fingerprint checked above.
        if let Some(rt) = self.faults.as_mut() {
            rt.plane.restore_rng_state(
                u64::from_str_radix(e.get("fault_rng")?.as_str()?, 16)
                    .context("bad fault RNG state")?,
            );
            rt.plane.stats = FaultStats::from_json(e.get("fault_stats")?)?;
            anyhow::ensure!(
                rt.plane.stats.retransmits_by.len() == lambda,
                "fault stats cover {} learners, config has {lambda}",
                rt.plane.stats.retransmits_by.len()
            );
            let n_leaves = rt.relay_win.len();
            rt.up_next = e.get("fault_up_next")?.as_u64_vec()?;
            rt.down_next = e.get("fault_down_next")?.as_u64_vec()?;
            rt.rseq_next = e.get("fault_rseq_next")?.as_u64_vec()?;
            anyhow::ensure!(
                rt.up_next.len() == lambda
                    && rt.down_next.len() == lambda
                    && rt.rseq_next.len() == n_leaves,
                "fault sequence-counter length mismatch"
            );
            rt.up_win = windows_from_json(e.get("fault_up_win")?, lambda)?;
            rt.down_win = windows_from_json(e.get("fault_down_win")?, lambda)?;
            rt.relay_win = windows_from_json(e.get("fault_relay_win")?, n_leaves)?;
            rt.evicted =
                e.get("fault_evicted")?.as_u64_vec()?.iter().map(|&x| x != 0).collect();
            rt.evicted_by_partition =
                e.get("fault_evicted_bp")?.as_u64_vec()?.iter().map(|&x| x != 0).collect();
            anyhow::ensure!(
                rt.evicted.len() == lambda && rt.evicted_by_partition.len() == lambda,
                "fault eviction-flag length mismatch"
            );
        }
        self.cur_mu = e.get("cur_mu")?.as_usize()?;
        self.base_compute = self.cfg.compute.minibatch_secs(&self.cfg.model, self.cur_mu);
        self.rescale_log = e
            .get("rescales")?
            .as_arr()?
            .iter()
            .map(|r| {
                Ok(RescaleRecord {
                    at: r.get("at")?.as_f64()?,
                    active_lambda: r.get("active_lambda")?.as_usize()?,
                    mu: r.get("mu")?.as_usize()?,
                    quota: r.get("quota")?.as_usize()?,
                    lr_factor: r.get("lr_factor")?.as_f64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        self.checkpoints_taken = e.get("checkpoints_taken")?.as_u64()?;
        self.last_checkpoint = match e.opt("last_checkpoint") {
            Some(c) => Some(Checkpoint::from_json_str(c.as_str()?)?),
            None => None,
        };
        self.root_bytes_in = e.get("root_bytes_in")?.as_f64()?;
        self.root_bytes_out = e.get("root_bytes_out")?.as_f64()?;
        self.comm_bytes_by_learner = e.get("comm_bytes")?.as_f64_vec()?;
        anyhow::ensure!(
            self.comm_bytes_by_learner.len() == lambda,
            "comm-bytes vector length mismatch"
        );
        self.epoch_stats = e
            .get("epochs")?
            .as_arr()?
            .iter()
            .map(|r| {
                Ok(EpochStat {
                    epoch: r.get("epoch")?.as_usize()?,
                    sim_time: r.get("sim_time")?.as_f64()?,
                    train_loss: f64::from_bits(
                        u64::from_str_radix(r.get("train_loss_bits")?.as_str()?, 16)
                            .context("bad train-loss bits")?,
                    ),
                    test_loss: None,
                    test_error_pct: None,
                    active_lambda: r.get("active_lambda")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        self.last_epoch_loss = f64::from_bits(
            u64::from_str_radix(e.get("last_epoch_loss_bits")?.as_str()?, 16)
                .context("bad last-epoch-loss bits")?,
        );
        self.random_armed = e.get("random_armed")?.as_bool()?;

        let fab = e.get("fabric")?.as_f64_vec()?;
        anyhow::ensure!(fab.len() % 4 == 0, "fabric state length not a multiple of 4");
        let rows: Vec<(f64, f64, f64, f64)> =
            fab.chunks_exact(4).map(|c| (c[0], c[1], c[2], c[3])).collect();
        self.fabric.restore_endpoint_state(&rows)?;

        self.epoch_losses.clear();
        self.snap_cache = None;
        self.resumed = true;
        Ok(())
    }

    /// Begin a new mini-batch: adv* learners first swap in the weights a
    /// continuous broadcast would have delivered by now.
    fn start_compute(&mut self, now: f64, l: usize) {
        if self.cfg.arch == Arch::AdvStar {
            let horizon = now - self.bcast_period;
            let mut best: Option<(Timestamp, Option<Arc<FlatVec>>)> = None;
            for (t, ts, snap) in self.recent.iter() {
                if *t <= horizon && *ts > self.slots[l].state.ts {
                    best = Some((*ts, snap.clone()));
                }
            }
            if let Some((ts, snap)) = best {
                if let Some(s) = snap {
                    self.slots[l].state.install(&s, ts);
                } else {
                    self.slots[l].state.ts = ts;
                }
            }
        }
        // Heterogeneous clusters scale the learner's cached base compute
        // time by its current slowdown factor (persistent × Markov
        // transient) before the jitter draw; a quiet hetero model takes
        // the exact pre-straggler path, bit for bit.
        let base = if self.hetero.enabled() {
            self.base_compute * self.hetero.draw(l)
        } else {
            self.base_compute
        };
        let dt = jittered(base, &self.cfg.cluster, &mut self.rng);
        self.slots[l].compute_cost = dt;
        let inc = self.slots[l].inc;
        self.q.schedule_in(dt, Ev::ComputeDone { learner: l, inc });
    }

    fn on_compute_done(&mut self, now: f64, l: usize, inc: u64) -> Result<()> {
        if inc != self.slots[l].inc || !self.membership.is_live(l) {
            return Ok(()); // the learner died mid-compute; work is lost
        }
        let cost = self.slots[l].compute_cost;
        self.slots[l].overlap.add_compute(cost);
        // the engine caches the jittered cost, so the span start is exact
        self.obs.compute(l, now - cost, now);
        self.slots[l].state.steps += 1;
        let grad_ts = self.slots[l].state.ts;
        let enc: GradInFlight = if self.provider.is_some() {
            let (g, loss) = {
                let theta = &self.slots[l].state.theta;
                self.provider.as_deref_mut().unwrap().compute(l, theta)?
            };
            self.epoch_losses.push(loss as f64);
            self.obs.series_loss(loss as f64);
            // Encode at the push boundary: the learner's error-feedback
            // residual updates here; the root decodes at fold time.
            Some(Box::new(match self.comm.as_mut() {
                Some(c) => c.encode(l, &g),
                None => EncodedGrad::Dense(g),
            }))
        } else {
            None
        };
        self.slots[l].blocked_since = now;

        match self.cfg.arch {
            Arch::Base => {
                let bytes = self.wire.push_bytes();
                self.comm_bytes_by_learner[l] += bytes;
                self.root_bytes_in += bytes;
                if self.faults.is_some() {
                    let src = self.node_of(l);
                    let fabric = &mut self.fabric;
                    let ps_eps = &self.ps_eps;
                    let rt = self.faults.as_mut().expect("checked above");
                    let seq = rt.up_next[l];
                    rt.up_next[l] += 1;
                    let routed =
                        rt.route(now, l, bytes, |t| fabric.send_to_shards(t, src, ps_eps, bytes));
                    let (times, extra) = self.note_routed(now, l, inc, routed);
                    self.comm_bytes_by_learner[l] += extra;
                    self.root_bytes_in += extra;
                    if let Some((at, dup_at)) = times {
                        self.obs.push(l, now, at);
                        self.q.schedule_at(
                            at,
                            Ev::PushAtRoot { learner: l, inc, grad: enc, ts: grad_ts, seq },
                        );
                        if let Some(d) = dup_at {
                            // The duplicate trails the original (and ties
                            // break by insertion order), so the dedup window
                            // always rejects it — it never needs the payload.
                            self.q.schedule_at(
                                d,
                                Ev::PushAtRoot { learner: l, inc, grad: None, ts: grad_ts, seq },
                            );
                        }
                    }
                } else {
                    let t = self.fabric.send_to_shards(now, self.node_of(l), &self.ps_eps, bytes);
                    self.obs.push(l, now, t);
                    self.q.schedule_at(
                        t,
                        Ev::PushAtRoot { learner: l, inc, grad: enc, ts: grad_ts, seq: 0 },
                    );
                }
            }
            Arch::Adv => {
                let leaf = self.tree.leaf_of[l];
                let bytes = self.wire.push_bytes();
                self.comm_bytes_by_learner[l] += bytes;
                if self.faults.is_some() {
                    let src = self.node_of(l);
                    let dst = self.leaf_node(leaf);
                    let fabric = &mut self.fabric;
                    let rt = self.faults.as_mut().expect("checked above");
                    let seq = rt.up_next[l];
                    rt.up_next[l] += 1;
                    let routed = rt.route(now, l, bytes, |t| fabric.send(t, src, dst, bytes));
                    let (times, extra) = self.note_routed(now, l, inc, routed);
                    self.comm_bytes_by_learner[l] += extra;
                    if let Some((at, dup_at)) = times {
                        self.obs.push(l, now, at);
                        self.q.schedule_at(
                            at,
                            Ev::PushAtLeaf { learner: l, inc, grad: enc, ts: grad_ts, seq },
                        );
                        if let Some(d) = dup_at {
                            self.q.schedule_at(
                                d,
                                Ev::PushAtLeaf { learner: l, inc, grad: None, ts: grad_ts, seq },
                            );
                        }
                    }
                } else {
                    let t = self.fabric.send(now, self.node_of(l), self.leaf_node(leaf), bytes);
                    self.obs.push(l, now, t);
                    self.q.schedule_at(
                        t,
                        Ev::PushAtLeaf { learner: l, inc, grad: enc, ts: grad_ts, seq: 0 },
                    );
                }
            }
            Arch::AdvStar => {
                if self.slots[l].pipe_busy {
                    // The §3.3 constraint: the pushGradient thread may not
                    // start the current gradient before the previous one is
                    // delivered — the gradient parks in the staging buffer
                    // and the learner stalls here, so the buffer can never
                    // be overwritten before its send.
                    self.slots[l].pending_grad = enc;
                    self.slots[l].pending_ts = grad_ts;
                    self.slots[l].pipe_waiting = true;
                } else {
                    self.start_advstar_push(now, l, enc, grad_ts);
                    self.start_compute(now, l);
                }
            }
        }
        Ok(())
    }

    fn start_advstar_push(&mut self, now: f64, l: usize, grad: GradInFlight, ts: Timestamp) {
        self.slots[l].pipe_busy = true;
        let leaf = self.tree.leaf_of[l];
        let inc = self.slots[l].inc;
        let bytes = self.wire.push_bytes();
        self.comm_bytes_by_learner[l] += bytes;
        if self.faults.is_some() {
            let src = self.node_of(l);
            let dst = self.leaf_node(leaf);
            let fabric = &mut self.fabric;
            let rt = self.faults.as_mut().expect("checked above");
            let seq = rt.up_next[l];
            rt.up_next[l] += 1;
            let routed = rt.route(now, l, bytes, |t| fabric.send(t, src, dst, bytes));
            let (times, extra) = self.note_routed(now, l, inc, routed);
            self.comm_bytes_by_learner[l] += extra;
            if let Some((at, dup_at)) = times {
                self.obs.push(l, now, at);
                self.q.schedule_at(at, Ev::PushAtLeaf { learner: l, inc, grad, ts, seq });
                if let Some(d) = dup_at {
                    self.q.schedule_at(
                        d,
                        Ev::PushAtLeaf { learner: l, inc, grad: None, ts, seq },
                    );
                }
            }
            // on Lost the pipeline slot stays busy until the FaultDead
            // eviction resets it in apply_kill — the learner is gone anyway
        } else {
            let t = self.fabric.send(now, self.node_of(l), self.leaf_node(leaf), bytes);
            self.obs.push(l, now, t);
            self.q.schedule_at(t, Ev::PushAtLeaf { learner: l, inc, grad, ts, seq: 0 });
        }
    }

    /// Book one fault-plane routing outcome: the retransmit/drop trace
    /// instants plus, on retry exhaustion, the deferred [`Ev::FaultDead`]
    /// eviction. Returns the delivery times `(at, dup_at)` — `None` when
    /// the message was lost — and the retry/dup byte overhead, which the
    /// caller adds to exactly the counters the original message was
    /// booked into.
    fn note_routed(
        &mut self,
        now: f64,
        l: usize,
        inc: u64,
        routed: Routed,
    ) -> (Option<(f64, Option<f64>)>, f64) {
        match routed {
            Routed::Deliver { at, dup_at, retries, extra_bytes } => {
                self.obs.fault_retransmit(l, now, u64::from(retries));
                (Some((at, dup_at)), extra_bytes)
            }
            Routed::Lost { give_up_at, by_partition, extra_bytes } => {
                self.obs.fault_drop(l, give_up_at);
                self.q.schedule_at(give_up_at, Ev::FaultDead { learner: l, inc, by_partition });
                (None, extra_bytes)
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn on_push_at_root(
        &mut self,
        now: f64,
        l: usize,
        inc: u64,
        grad: GradInFlight,
        ts: Timestamp,
        seq: u64,
    ) -> Result<()> {
        if inc != self.slots[l].inc || !self.membership.is_live(l) {
            return Ok(()); // gradient of a dead incarnation is discarded
        }
        if let Some(rt) = self.faults.as_mut() {
            if !rt.up_win[l].accept(seq) {
                rt.plane.stats.dedup_dropped += 1;
                self.obs.fault_dedup(l, now);
                return Ok(()); // duplicate/replayed gradient: never folded twice
            }
        }
        let out = self.fold(now, l, inc, grad, ts)?;
        if self.cfg.protocol.is_barrier() {
            if out.dropped {
                // backup-sync: one of the b slowest — its work is lost;
                // refresh it with the current weights instead of parking
                // it at a barrier its round already left behind.
                self.start_pull_base(now, l);
            } else {
                self.barrier.push(l);
                self.in_barrier[l] = true;
                self.obs.barrier_enter(l, now);
                self.maybe_broadcast(now);
            }
        } else {
            self.start_pull_base(now, l);
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn on_push_at_leaf(
        &mut self,
        now: f64,
        l: usize,
        inc: u64,
        grad: GradInFlight,
        ts: Timestamp,
        seq: u64,
    ) -> Result<()> {
        if inc != self.slots[l].inc || !self.membership.is_live(l) {
            return Ok(());
        }
        if let Some(rt) = self.faults.as_mut() {
            if !rt.up_win[l].accept(seq) {
                rt.plane.stats.dedup_dropped += 1;
                self.obs.fault_dedup(l, now);
                // rejected before the barrier/pipeline bookkeeping below:
                // the original delivery already did all of it
                return Ok(());
            }
        }
        let leaf = self.tree.leaf_of[l];
        self.leaves[leaf].queue.push((l, inc, grad, ts));
        self.try_relay(now, leaf);

        match self.cfg.arch {
            Arch::Adv => {
                if self.cfg.protocol.is_barrier() {
                    self.barrier.push(l);
                    self.in_barrier[l] = true;
                    self.obs.barrier_enter(l, now);
                    // broadcast fires from on_relay_at_root once the root
                    // has folded all λ gradients
                } else {
                    self.start_pull_adv(now, l);
                }
            }
            Arch::AdvStar => {
                // pipeline slot freed (delivery to the PS parent complete)
                if self.slots[l].pipe_waiting {
                    self.slots[l].pipe_waiting = false;
                    let stall = now - self.slots[l].blocked_since;
                    self.slots[l].overlap.add_exposed_comm(stall);
                    let staged = self.slots[l].pending_grad.take();
                    let staged_ts = self.slots[l].pending_ts;
                    self.start_advstar_push(now, l, staged, staged_ts);
                    self.start_compute(now, l);
                } else {
                    self.slots[l].pipe_busy = false;
                }
            }
            Arch::Base => unreachable!("PushAtLeaf in Base"),
        }
        Ok(())
    }

    fn try_relay(&mut self, now: f64, leaf: usize) {
        if self.leaves[leaf].relay_busy || self.leaves[leaf].queue.is_empty() {
            return;
        }
        let take = self.tree.fanout.min(self.leaves[leaf].queue.len());
        let batch: RelayBatch = self.leaves[leaf].queue.drain(..take).collect();
        self.leaves[leaf].relay_busy = true;
        // Uncompressed, the relay is the leaf's dense partial sum (one
        // model-sized message); compressed, the leaf forwards the batch's
        // encodings, capped at the dense size (see WireModel::relay_bytes).
        let bytes = self.wire.relay_bytes(batch.len());
        self.root_bytes_in += bytes;
        if self.faults.is_some() {
            // The leaf→root trunk uses the *reliable* routing path: it
            // retries past the learner budget and is never lost (a lost
            // relay would wedge `relay_busy` forever — the trunk link is
            // infra-to-infra, not a learner that membership can evict).
            let src = self.leaf_node(leaf);
            let fabric = &mut self.fabric;
            let ps_eps = &self.ps_eps;
            let rt = self.faults.as_mut().expect("checked above");
            let rseq = rt.rseq_next[leaf];
            rt.rseq_next[leaf] += 1;
            let (t, dup_at, extra) =
                rt.route_reliable(now, bytes, |at| fabric.send_to_shards(at, src, ps_eps, bytes));
            self.root_bytes_in += extra;
            self.obs.relay(leaf, now, t);
            if self.obs.profile_enabled() {
                for (l, _, _, _) in &batch {
                    self.obs.profile_relay(*l, now, t);
                }
            }
            self.q.schedule_at(t, Ev::RelayAtRoot { leaf, batch, rseq });
            if let Some(d) = dup_at {
                // payload-free duplicate: it trails the original, so the
                // rseq window rejects it before the batch would be needed
                self.q.schedule_at(d, Ev::RelayAtRoot { leaf, batch: Vec::new(), rseq });
            }
        } else {
            let t = self.fabric.send_to_shards(now, self.leaf_node(leaf), &self.ps_eps, bytes);
            self.obs.relay(leaf, now, t);
            if self.obs.profile_enabled() {
                // The relay span is keyed by leaf; the profiler needs it per
                // carried gradient to walk the commit chain back through it.
                for (l, _, _, _) in &batch {
                    self.obs.profile_relay(*l, now, t);
                }
            }
            self.q.schedule_at(t, Ev::RelayAtRoot { leaf, batch, rseq: 0 });
        }
    }

    fn on_relay_at_root(
        &mut self,
        now: f64,
        leaf: usize,
        batch: RelayBatch,
        rseq: u64,
    ) -> Result<()> {
        if let Some(rt) = self.faults.as_mut() {
            if !rt.relay_win[leaf].accept(rseq) {
                rt.plane.stats.dedup_dropped += 1;
                self.obs.fault_dedup(leaf, now);
                // rejected before relay_busy is cleared: the original
                // delivery already released the trunk
                return Ok(());
            }
        }
        for (l, inc, grad, ts) in batch {
            // A backup-sync drop needs no action here: the learner either
            // already took the round's broadcast (its stale gradient was
            // still in the relay pipeline) and is computing fresh, or it
            // is parked in the barrier and the next broadcast releases it.
            // Refreshing it directly instead would risk starting a second
            // compute loop for the same slot.
            self.fold(now, l, inc, grad, ts)?;
        }
        self.leaves[leaf].relay_busy = false;
        self.try_relay(now, leaf);
        if self.cfg.protocol.is_barrier() {
            self.maybe_broadcast(now);
        }
        Ok(())
    }

    /// Fold one gradient into the server; handle update/epoch outcomes.
    /// Gradients from dead incarnations are dropped here (crashed
    /// learners' messages are lost, not replayed); the returned outcome's
    /// `dropped` flag reports a backup-sync too-slow drop so the caller
    /// can refresh the learner.
    fn fold(
        &mut self,
        now: f64,
        l: usize,
        inc: u64,
        grad: GradInFlight,
        ts: Timestamp,
    ) -> Result<PushOutcome> {
        if inc != self.slots[l].inc || !self.membership.is_live(l) {
            return Ok(PushOutcome::default());
        }
        let outcome: PushOutcome = match grad {
            // decode-then-accumulate at the root tier; `Dense` (the
            // `none` codec) decodes without a copy
            Some(enc) => self.server.push_encoded(l, *enc, ts)?,
            None => self.server.push_gradient_timing_only(l, ts),
        };
        self.after_update(now, Some(l), outcome.clone())?;
        Ok(outcome)
    }

    /// Post-applyUpdate bookkeeping shared by the push path and the
    /// membership-change quota flush: adv* broadcast history, periodic
    /// checkpoints, and epoch-boundary stats/eval. `by` names the learner
    /// whose gradient triggered the outcome (None for quota flushes —
    /// those commits have no causal chain to profile).
    fn after_update(&mut self, now: f64, by: Option<usize>, outcome: PushOutcome) -> Result<()> {
        if outcome.updated {
            self.obs.apply_update(self.cfg.shards, now);
            self.obs.profile_commit(by, now);
            if self.cfg.arch == Arch::AdvStar {
                // Each update initiates a striped broadcast: the S root
                // shards emit their θ slices (M bytes total) into their
                // subtrees ([`crate::comm::stripe`]).
                self.obs.advstar_broadcast(now);
                self.root_bytes_out += self.wire.pull_bytes();
                let snap = self.server_snapshot();
                self.recent.push_back((now, self.server.timestamp(), snap));
                // prune entries older than the broadcast window (keep one
                // older entry as the query floor), recycling buffers the
                // history held the last reference to
                while self.recent.len() > 1
                    && self.recent[1].0 <= now - self.bcast_period - 1e-9
                {
                    if let Some((_, _, Some(snap))) = self.recent.pop_front() {
                        self.reclaim_snapshot(snap);
                    }
                }
            }
            let every = self.cfg.checkpoint_every_updates;
            if every > 0 && self.server.updates % every == 0 {
                // A heterogeneous run has a second named RNG stream to
                // resume; quiet runs keep the exact pre-straggler payload.
                let mut streams: Vec<(&str, &Rng)> = vec![("engine", &self.rng)];
                if self.hetero.enabled() {
                    streams.push(("hetero", self.hetero.rng()));
                }
                self.last_checkpoint = Some(Checkpoint::capture_full(
                    &format!("update-{}", self.server.updates),
                    &self.server,
                    &streams,
                    self.comm.as_ref(),
                    self.adaptive.as_ref(),
                ));
                self.checkpoints_taken += 1;
                self.obs.checkpoint(now);
            }
        }
        if let Some(epoch) = outcome.epoch_completed {
            let train_loss = crate::util::mean(&self.epoch_losses);
            self.last_epoch_loss = train_loss;
            self.epoch_losses.clear();
            let (test_loss, test_err) = if self.cfg.eval_each_epoch && self.numeric {
                let theta = self.server.assemble_weights();
                match &mut self.evaluator {
                    Some(e) => {
                        let (tl, te) = e.eval(&theta)?;
                        (Some(tl), Some(te))
                    }
                    None => (None, None),
                }
            } else {
                (None, None)
            };
            self.epoch_stats.push(EpochStat {
                epoch,
                sim_time: now,
                train_loss,
                test_loss,
                test_error_pct: test_err,
                active_lambda: self.membership.active_count(),
            });
            self.obs.series_epoch(
                now,
                epoch as u64,
                train_loss,
                test_err.unwrap_or(f64::NAN),
            );
            // After the commit accounting above, so the epoch delta tiles
            // the commit windows exactly.
            self.obs.profile_epoch(epoch as u64);
            // Adaptive-n control: close the loop at the epoch boundary —
            // measure the epoch's ⟨σ⟩ window and retune the softsync
            // splitting parameter on the server (between updates; the
            // next push closes any already-satisfied round).
            if self.adaptive.is_some() {
                let (count, sum) = self.server.staleness.totals();
                let active = self.membership.active_count();
                let ctl = self.adaptive.as_mut().expect("checked above");
                if let Some(new_n) = ctl.epoch_tick(epoch, now, count, sum, active) {
                    self.server.set_softsync_n(new_n)?;
                    self.obs.series_adaptive(now, new_n as u64);
                }
            }
        }
        Ok(())
    }

    /// Barrier protocols: once the round's update has fired (server ts
    /// advanced past the last broadcast), broadcast new weights.
    fn maybe_broadcast(&mut self, now: f64) {
        // Hardsync waits for BOTH: every *live* learner at the barrier AND
        // the root having folded every gradient (its timestamp advanced
        // past the last broadcast) — with tree aggregation the barrier
        // fills before the final relay lands at the root. The quorum is
        // membership-aware: dead learners are removed from the barrier at
        // kill time, so a crash mid-round cannot deadlock the protocol.
        // Backup-sync rounds close on the first λ_active − b folds, so
        // there the ts advance alone is the signal: everyone waiting at
        // that moment is released, and the b stragglers are refreshed
        // individually when their late pushes land.
        let backup = matches!(self.cfg.protocol, Protocol::BackupSync { .. });
        let quorum = if backup { 1 } else { self.membership.active_count() };
        if self.barrier.len() < quorum || self.server.timestamp() <= self.last_bcast_ts {
            return;
        }
        let ts = self.server.timestamp();
        self.last_bcast_ts = ts;
        let snap = self.server_snapshot();
        // Drain the barrier into a reusable scratch buffer, preserving
        // arrival order — fabric endpoint sequencing depends on it. The
        // swap (instead of `mem::take`) keeps both Vecs' capacity, so the
        // hot path stops reallocating a λ-sized buffer every round.
        std::mem::swap(&mut self.barrier, &mut self.waiting_scratch);
        for &l in &self.waiting_scratch {
            self.in_barrier[l] = false;
            // the wait ends when the round closes; the delivery itself is
            // the broadcast span below
            self.obs.barrier_release(l, now);
        }
        self.obs.barrier_round_end();
        match self.cfg.arch {
            Arch::Base => {
                if self.faults.is_some() {
                    // index loop: `note_routed` needs `&mut self`, which an
                    // iterator borrow of `waiting_scratch` would forbid
                    for i in 0..self.waiting_scratch.len() {
                        let l = self.waiting_scratch[i];
                        let inc = self.slots[l].inc;
                        let bytes = self.wire.pull_bytes();
                        self.root_bytes_out += bytes;
                        let dst = self.node_of(l);
                        let fabric = &mut self.fabric;
                        let ps_eps = &self.ps_eps;
                        let rt = self.faults.as_mut().expect("checked above");
                        let seq = rt.down_next[l];
                        rt.down_next[l] += 1;
                        let routed = rt
                            .route(now, l, bytes, |t| fabric.send_from_shards(t, ps_eps, dst, bytes));
                        let (times, extra) = self.note_routed(now, l, inc, routed);
                        self.root_bytes_out += extra;
                        if let Some((t, dup_at)) = times {
                            self.obs.broadcast(l, now, t);
                            self.q.schedule_at(
                                t,
                                Ev::Broadcast { learner: l, inc, snapshot: snap.clone(), ts, seq },
                            );
                            if let Some(d) = dup_at {
                                self.q.schedule_at(
                                    d,
                                    Ev::Broadcast { learner: l, inc, snapshot: None, ts, seq },
                                );
                            }
                        }
                    }
                } else {
                    for &l in &self.waiting_scratch {
                        let inc = self.slots[l].inc;
                        let bytes = self.wire.pull_bytes();
                        self.root_bytes_out += bytes;
                        let t = self
                            .fabric
                            .send_from_shards(now, &self.ps_eps, self.node_of(l), bytes);
                        self.obs.broadcast(l, now, t);
                        self.q.schedule_at(
                            t,
                            Ev::Broadcast { learner: l, inc, snapshot: snap.clone(), ts, seq: 0 },
                        );
                    }
                }
            }
            Arch::Adv | Arch::AdvStar => {
                // root shards → leaf once, then leaf → co-located learners
                // (live ones only — dead and not-yet-joined slots get no
                // weights and, crucially, no compute restart). Under
                // hardsync every live learner is waiting by construction;
                // under backup-sync only the *waiting* set may be served —
                // a learner still computing (one of the b stragglers)
                // must not have a second compute loop started for it.
                if backup {
                    for &l in &self.waiting_scratch {
                        self.waiting_mask[l] = true;
                    }
                }
                // Index loops (not iterator borrows): the fault path calls
                // `note_routed(&mut self)` per member. Iteration order is
                // identical to the old iterator form, so quiet runs are
                // unchanged bit for bit.
                for leaf in 0..self.leaf_members.len() {
                    // The shards→leaf hop fires lazily on the first
                    // eligible member, so skipped leaves cost nothing and
                    // the fabric call order matches the old collect-first
                    // code exactly (one send_from_shards, then the member
                    // sends in member order).
                    let mut t1: Option<f64> = None;
                    for mi in 0..self.leaf_members[leaf].len() {
                        let l = self.leaf_members[leaf][mi];
                        if !self.membership.is_live(l) || (backup && !self.waiting_mask[l]) {
                            continue;
                        }
                        let bytes = self.wire.pull_bytes();
                        let start = match t1 {
                            Some(t) => t,
                            None => {
                                self.root_bytes_out += bytes;
                                let t = self.fabric.send_from_shards(
                                    now,
                                    &self.ps_eps,
                                    self.leaf_node(leaf),
                                    bytes,
                                );
                                t1 = Some(t);
                                t
                            }
                        };
                        let inc = self.slots[l].inc;
                        if self.faults.is_some() {
                            let src = self.leaf_node(leaf);
                            let dst = self.node_of(l);
                            let fabric = &mut self.fabric;
                            let rt = self.faults.as_mut().expect("checked above");
                            let seq = rt.down_next[l];
                            rt.down_next[l] += 1;
                            let routed =
                                rt.route(start, l, bytes, |t| fabric.send(t, src, dst, bytes));
                            let (times, _extra) = self.note_routed(start, l, inc, routed);
                            // the member hop books no root bytes (only the
                            // shared shards→leaf hop does), so neither does
                            // its retry overhead
                            if let Some((t, dup_at)) = times {
                                self.obs.broadcast(l, now, t);
                                self.q.schedule_at(
                                    t,
                                    Ev::Broadcast {
                                        learner: l,
                                        inc,
                                        snapshot: snap.clone(),
                                        ts,
                                        seq,
                                    },
                                );
                                if let Some(d) = dup_at {
                                    self.q.schedule_at(
                                        d,
                                        Ev::Broadcast { learner: l, inc, snapshot: None, ts, seq },
                                    );
                                }
                            }
                        } else {
                            let t = self.fabric.send(
                                start,
                                self.leaf_node(leaf),
                                self.node_of(l),
                                bytes,
                            );
                            // span covers both hops: round close → delivery
                            self.obs.broadcast(l, now, t);
                            self.q.schedule_at(
                                t,
                                Ev::Broadcast {
                                    learner: l,
                                    inc,
                                    snapshot: snap.clone(),
                                    ts,
                                    seq: 0,
                                },
                            );
                        }
                    }
                }
                if backup {
                    for &l in &self.waiting_scratch {
                        self.waiting_mask[l] = false;
                    }
                }
            }
        }
        self.waiting_scratch.clear();
    }

    fn start_pull_base(&mut self, now: f64, l: usize) {
        let inc = self.slots[l].inc;
        if self.slots[l].state.needs_pull(self.server.timestamp()) {
            let ts = self.server.timestamp();
            let snap = self.server_snapshot();
            let bytes = self.wire.pull_bytes();
            self.root_bytes_out += bytes;
            if self.faults.is_some() {
                let dst = self.node_of(l);
                let fabric = &mut self.fabric;
                let ps_eps = &self.ps_eps;
                let rt = self.faults.as_mut().expect("checked above");
                let seq = rt.down_next[l];
                rt.down_next[l] += 1;
                let routed =
                    rt.route(now, l, bytes, |t| fabric.send_from_shards(t, ps_eps, dst, bytes));
                let (times, extra) = self.note_routed(now, l, inc, routed);
                self.root_bytes_out += extra;
                if let Some((t, dup_at)) = times {
                    self.obs.pull(l, now, t);
                    self.q.schedule_at(
                        t,
                        Ev::PullDone { learner: l, inc, snapshot: snap, ts, seq },
                    );
                    if let Some(d) = dup_at {
                        self.q.schedule_at(
                            d,
                            Ev::PullDone { learner: l, inc, snapshot: None, ts, seq },
                        );
                    }
                }
            } else {
                let t = self.fabric.send_from_shards(now, &self.ps_eps, self.node_of(l), bytes);
                self.obs.pull(l, now, t);
                self.q.schedule_at(t, Ev::PullDone { learner: l, inc, snapshot: snap, ts, seq: 0 });
            }
        } else {
            // timestamp inquiry only (§3.2's pull-skip)
            let ts = self.slots[l].state.ts;
            if self.faults.is_some() {
                // latency-only pricing, zero bytes: the inquiry is still a
                // message — it can be lost, retried, and duplicated
                let lat = self.cfg.cluster.latency;
                let rt = self.faults.as_mut().expect("checked above");
                let seq = rt.down_next[l];
                rt.down_next[l] += 1;
                let routed = rt.route(now, l, 0.0, |t| t + lat);
                let (times, _extra) = self.note_routed(now, l, inc, routed);
                if let Some((t, dup_at)) = times {
                    self.obs.pull(l, now, t);
                    self.q.schedule_at(
                        t,
                        Ev::PullDone { learner: l, inc, snapshot: None, ts, seq },
                    );
                    if let Some(d) = dup_at {
                        self.q.schedule_at(
                            d,
                            Ev::PullDone { learner: l, inc, snapshot: None, ts, seq },
                        );
                    }
                }
            } else {
                self.obs.pull(l, now, now + self.cfg.cluster.latency);
                self.q.schedule_at(
                    now + self.cfg.cluster.latency,
                    Ev::PullDone { learner: l, inc, snapshot: None, ts, seq: 0 },
                );
            }
        }
    }

    fn start_pull_adv(&mut self, now: f64, l: usize) {
        let inc = self.slots[l].inc;
        let leaf = self.tree.leaf_of[l];
        let server_ts = self.server.timestamp();
        if !self.slots[l].state.needs_pull(server_ts) {
            let ts = self.slots[l].state.ts;
            if self.faults.is_some() {
                let lat = self.cfg.cluster.latency;
                let rt = self.faults.as_mut().expect("checked above");
                let seq = rt.down_next[l];
                rt.down_next[l] += 1;
                let routed = rt.route(now, l, 0.0, |t| t + lat);
                let (times, _extra) = self.note_routed(now, l, inc, routed);
                if let Some((t, dup_at)) = times {
                    self.obs.pull(l, now, t);
                    self.q.schedule_at(
                        t,
                        Ev::PullDone { learner: l, inc, snapshot: None, ts, seq },
                    );
                    if let Some(d) = dup_at {
                        self.q.schedule_at(
                            d,
                            Ev::PullDone { learner: l, inc, snapshot: None, ts, seq },
                        );
                    }
                }
            } else {
                self.obs.pull(l, now, now + self.cfg.cluster.latency);
                self.q.schedule_at(
                    now + self.cfg.cluster.latency,
                    Ev::PullDone { learner: l, inc, snapshot: None, ts, seq: 0 },
                );
            }
            return;
        }
        // Refresh the leaf cache from the root if it is stale and no fetch
        // is already in flight (one root egress serves all members).
        if self.leaves[leaf].cache_ts < server_ts && self.leaves[leaf].cache_ready <= now {
            let snap = self.server_snapshot();
            let bytes = self.wire.pull_bytes();
            self.root_bytes_out += bytes;
            let ready = self
                .fabric
                .send_from_shards(now, &self.ps_eps, self.leaf_node(leaf), bytes);
            self.leaves[leaf].cache_ts = server_ts;
            self.leaves[leaf].cache_ready = ready;
            self.leaves[leaf].cache_snap = snap;
        }
        // Join the cached/in-flight copy; final hop is node-local.
        let ready = self.leaves[leaf].cache_ready.max(now);
        if self.faults.is_some() {
            let src = self.leaf_node(leaf);
            let dst = self.node_of(l);
            let bytes = self.wire.pull_bytes();
            let fabric = &mut self.fabric;
            let rt = self.faults.as_mut().expect("checked above");
            let seq = rt.down_next[l];
            rt.down_next[l] += 1;
            let routed = rt.route(ready, l, bytes, |t| fabric.send(t, src, dst, bytes));
            let (times, _extra) = self.note_routed(ready, l, inc, routed);
            // the leaf→learner hop books no root bytes, so neither does
            // its retry overhead
            if let Some((t, dup_at)) = times {
                self.obs.pull(l, now, t);
                let snap = self.leaves[leaf].cache_snap.clone();
                let ts = self.leaves[leaf].cache_ts;
                self.q.schedule_at(t, Ev::PullDone { learner: l, inc, snapshot: snap, ts, seq });
                if let Some(d) = dup_at {
                    self.q.schedule_at(
                        d,
                        Ev::PullDone { learner: l, inc, snapshot: None, ts, seq },
                    );
                }
            }
        } else {
            let t = self.fabric.send(
                ready,
                self.leaf_node(leaf),
                self.node_of(l),
                self.wire.pull_bytes(),
            );
            self.obs.pull(l, now, t);
            self.q.schedule_at(
                t,
                Ev::PullDone {
                    learner: l,
                    inc,
                    snapshot: self.leaves[leaf].cache_snap.clone(),
                    ts: self.leaves[leaf].cache_ts,
                    seq: 0,
                },
            );
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn on_pull_done(
        &mut self,
        now: f64,
        l: usize,
        inc: u64,
        snapshot: Option<Arc<FlatVec>>,
        ts: Timestamp,
        seq: u64,
    ) {
        if inc != self.slots[l].inc || !self.membership.is_live(l) {
            return; // pulled weights for a dead incarnation: dropped
        }
        if let Some(rt) = self.faults.as_mut() {
            if !rt.down_win[l].accept(seq) {
                rt.plane.stats.dedup_dropped += 1;
                self.obs.fault_dedup(l, now);
                return; // a duplicated pull must not restart the compute loop
            }
        }
        if let Some(s) = snapshot {
            self.slots[l].state.install(&s, ts);
        } else {
            self.slots[l].state.ts = self.slots[l].state.ts.max(ts);
        }
        let stall = now - self.slots[l].blocked_since;
        self.slots[l].overlap.add_exposed_comm(stall);
        self.start_compute(now, l);
    }

    #[allow(clippy::too_many_arguments)]
    fn on_broadcast(
        &mut self,
        now: f64,
        l: usize,
        inc: u64,
        snapshot: Option<Arc<FlatVec>>,
        ts: Timestamp,
        seq: u64,
    ) {
        if inc != self.slots[l].inc || !self.membership.is_live(l) {
            return;
        }
        if let Some(rt) = self.faults.as_mut() {
            if !rt.down_win[l].accept(seq) {
                rt.plane.stats.dedup_dropped += 1;
                self.obs.fault_dedup(l, now);
                return; // a duplicated broadcast must not start a second loop
            }
        }
        if let Some(s) = snapshot {
            self.slots[l].state.install(&s, ts);
        } else {
            self.slots[l].state.ts = ts;
        }
        let stall = now - self.slots[l].blocked_since;
        self.slots[l].overlap.add_exposed_comm(stall);
        self.start_compute(now, l);
    }

    // ---- elastic membership ------------------------------------------------

    fn on_churn(&mut self, now: f64, event: ChurnEvent) -> Result<()> {
        match event.action {
            ChurnAction::Kill => self.apply_kill(now, event.learner),
            ChurnAction::Rejoin => self.apply_revive(now, event.learner, true),
            ChurnAction::Join => self.apply_revive(now, event.learner, false),
        }
    }

    /// The random failure process: kill a victim (never the last live
    /// learner — a cluster with zero learners is an outage, not a churn
    /// scenario), schedule its rejoin if the schedule allows downtime,
    /// and re-arm. With *no* live learner (scheduled kills took the rest)
    /// the process disarms, so the event loop can drain instead of
    /// spinning on self-scheduled kills forever; a later revive re-arms.
    fn on_random_kill(&mut self, now: f64) -> Result<()> {
        self.random_armed = false;
        // fill the reusable scratch list instead of allocating a fresh
        // live_ids() Vec per kill event
        self.live_scratch.clear();
        for l in 0..self.cfg.lambda {
            if self.membership.is_live(l) {
                self.live_scratch.push(l);
            }
        }
        if self.live_scratch.len() > 1 {
            if let Some(victim) = self.injector.pick(&self.live_scratch) {
                self.apply_kill(now, victim)?;
                if let Some(downtime) = self.injector.downtime() {
                    self.q.schedule_in(
                        downtime,
                        Ev::Churn {
                            event: ChurnEvent {
                                at: now + downtime,
                                learner: victim,
                                action: ChurnAction::Rejoin,
                            },
                        },
                    );
                }
            }
        }
        if !self.live_scratch.is_empty() {
            let dt = self.injector.next_kill_delay();
            self.q.schedule_in(dt, Ev::RandomKill);
            self.random_armed = true;
        }
        Ok(())
    }

    /// Kill learner `l`: bump its incarnation (in-flight events die with
    /// it), drop it from the hardsync barrier, and rescale the survivors.
    fn apply_kill(&mut self, now: f64, l: usize) -> Result<()> {
        if !self.membership.is_live(l) && self.membership.phase(l)
            != crate::elastic::membership::Phase::Joining
        {
            return Ok(()); // already dead: scheduled and random kills can race
        }
        self.membership.kill(l, now)?;
        self.slots[l].inc += 1;
        self.slots[l].pending_grad = None;
        self.slots[l].pipe_busy = false;
        self.slots[l].pipe_waiting = false;
        // untransmitted error feedback dies with the learner process; the
        // rejoined incarnation starts with a clean residual
        if let Some(c) = self.comm.as_mut() {
            c.reset_residual(l);
        }
        // O(λ) removal scan only when the victim is actually parked there
        // (the common kill races a learner that is mid-compute or
        // mid-push, where the old unconditional retain walked the whole
        // barrier for nothing)
        if self.in_barrier[l] {
            self.in_barrier[l] = false;
            self.barrier.retain(|&x| x != l);
            // the profiler's occupancy tracking must see the abandonment,
            // or the dead learner would count as parked forever
            self.obs.barrier_abandon(l, now);
        }
        self.on_membership_change(now, Some(l))?;
        Ok(())
    }

    /// Bring learner `l` up: `rejoin` = warm restart after a death,
    /// otherwise a first-time (deferred) join. The learner pulls the
    /// current weights from the root shards — paying the full striped
    /// transfer — and resumes its compute loop when they land.
    fn apply_revive(&mut self, now: f64, l: usize, rejoin: bool) -> Result<()> {
        use crate::elastic::membership::Phase;
        // Lenient on races: a deterministic rejoin may target a learner
        // the random process never killed, or that is already back.
        if rejoin {
            if self.membership.phase(l) != Phase::Dead {
                return Ok(());
            }
            self.membership.rejoin(l, now)?;
        } else {
            match self.membership.phase(l) {
                Phase::Joining => self.membership.activate(l, now)?,
                // a learner killed before its scheduled join (or a `join:`
                // written where `rejoin:` was meant) comes back warm
                Phase::Dead => {
                    self.membership.rejoin(l, now)?;
                }
                _ => return Ok(()),
            }
        }
        self.on_membership_change(now, None)?;
        // a revive brings the random failure process back if it disarmed
        // during a full outage
        if self.injector.enabled() && !self.random_armed {
            let dt = self.injector.next_kill_delay();
            self.q.schedule_in(dt, Ev::RandomKill);
            self.random_armed = true;
        }
        let inc = self.slots[l].inc;
        self.slots[l].blocked_since = now;
        let ts = self.server.timestamp();
        let snap = self.server_snapshot();
        let bytes = self.wire.pull_bytes();
        self.root_bytes_out += bytes;
        if self.faults.is_some() {
            let dst = self.node_of(l);
            let fabric = &mut self.fabric;
            let ps_eps = &self.ps_eps;
            let rt = self.faults.as_mut().expect("checked above");
            let seq = rt.down_next[l];
            rt.down_next[l] += 1;
            let routed =
                rt.route(now, l, bytes, |t| fabric.send_from_shards(t, ps_eps, dst, bytes));
            let (times, extra) = self.note_routed(now, l, inc, routed);
            self.root_bytes_out += extra;
            if let Some((t, dup_at)) = times {
                self.q.schedule_at(t, Ev::PullDone { learner: l, inc, snapshot: snap, ts, seq });
                if let Some(d) = dup_at {
                    self.q
                        .schedule_at(d, Ev::PullDone { learner: l, inc, snapshot: None, ts, seq });
                }
            }
        } else {
            let t = self.fabric.send_from_shards(now, &self.ps_eps, self.node_of(l), bytes);
            self.q.schedule_at(t, Ev::PullDone { learner: l, inc, snapshot: snap, ts, seq: 0 });
        }
        Ok(())
    }

    // ---- network chaos -----------------------------------------------------

    /// Retry exhaustion: the fault plane has given learner `l` up for
    /// unreachable. Route it through the same Suspect → Dead membership
    /// path a churn death takes (barrier removal, μ rescale, quota
    /// flush), so barrier protocols shed the learner instead of
    /// deadlocking on it. Partition victims are remembered for revival
    /// when their window heals; loss-exhausted learners stay down.
    fn on_fault_dead(&mut self, now: f64, l: usize, inc: u64, by_partition: bool) -> Result<()> {
        if inc != self.slots[l].inc || !self.membership.is_live(l) {
            return Ok(()); // a churn event got there first, or a stale chain
        }
        use crate::elastic::membership::Phase;
        if matches!(self.membership.phase(l), Phase::Active | Phase::Rejoined) {
            self.membership.suspect(l, now)?;
        }
        if let Some(rt) = self.faults.as_mut() {
            rt.evicted[l] = true;
            rt.evicted_by_partition[l] = by_partition;
        }
        self.obs.fault_evict(l, now);
        self.apply_kill(now, l)
    }

    /// A partition window closed: revive every learner that partition
    /// blocking evicted, provided no other window still cuts it off.
    fn on_partition_heal(&mut self, now: f64) -> Result<()> {
        self.obs.fault_heal(now);
        let mut healed = Vec::new();
        if let Some(rt) = self.faults.as_mut() {
            for l in 0..rt.evicted.len() {
                if rt.evicted[l] && rt.evicted_by_partition[l] && !rt.plane.partitioned(l, now) {
                    rt.evicted[l] = false;
                    rt.evicted_by_partition[l] = false;
                    healed.push(l);
                }
            }
        }
        for l in healed {
            // apply_revive is lenient about races with churn rejoins
            self.apply_revive(now, l, true)?;
        }
        Ok(())
    }

    /// Re-point the server at the new active set: rescale μ (μ·λ = const),
    /// recompute the collection quota c — flushing a round the shrink
    /// just satisfied (via the membership-aware [`ShardedServer::remove_learner`]
    /// when a death triggered the change) — and log the rescale decision.
    /// With every learner down (a full outage between kill and rejoin
    /// events) the server is left as-is; the next revive re-normalizes.
    fn on_membership_change(&mut self, now: f64, removed: Option<usize>) -> Result<()> {
        let active = self.membership.active_count();
        if active == 0 {
            return Ok(());
        }
        // Adaptive-n follows the quorum down: the controller may have
        // steered n to the λ_active ceiling, and re-deriving the quota
        // below n is a hard error for a *static* n-softsync run — but a
        // feedback-controlled one retunes instead of aborting. Must
        // happen before the quota recomputation and the rescale record.
        if let Some(ctl) = self.adaptive.as_mut() {
            if let Some(new_n) = ctl.clamp_to_lambda(active) {
                self.server.set_softsync_n(new_n)?;
            }
        }
        let mu = self.rescaler.mu_for(active);
        if mu != self.cur_mu {
            self.cur_mu = mu;
            self.server.set_mu(mu);
            self.base_compute = self.cfg.compute.minibatch_secs(&self.cfg.model, mu);
            // dynamic-μ control channel: providers that can resample at
            // the rescaled μ do so from the next mini-batch on
            if let Some(p) = self.provider.as_deref_mut() {
                p.set_mu(mu);
            }
        }
        let flush = match removed {
            Some(dead) => self.server.remove_learner(dead, active)?,
            None => self.server.set_active_lambda(active)?,
        };
        // The server's protocol is the live one (adaptive-n may have
        // retuned the splitting parameter since the run started).
        let record = self.rescaler.record(now, &self.lr, self.server.protocol(), active)?;
        self.rescale_log.push(record);
        if let Some(outcome) = flush {
            self.after_update(now, None, outcome)?;
        }
        if self.cfg.protocol.is_barrier() {
            self.maybe_broadcast(now);
        }
        Ok(())
    }
}

/// Convenience wrapper: build and run in one call.
pub fn run_sim<'a>(
    cfg: &'a SimConfig,
    theta0: FlatVec,
    optimizer: Optimizer,
    lr: LrPolicy,
    provider: Option<&'a mut dyn GradProvider>,
    evaluator: Option<&'a mut dyn Evaluator>,
) -> Result<SimResult> {
    SimEngine::new(cfg, theta0, optimizer, lr, provider, evaluator).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::learner::MockProvider;
    use crate::params::lr::{LrPolicy, Modulation, Schedule};
    use crate::params::optimizer::{Optimizer, OptimizerKind};

    fn tiny_model() -> ModelCost {
        ModelCost {
            name: "tiny",
            flops_per_sample: 1.0e6,
            bytes: 1.0e3,
            samples_per_epoch: 64,
        }
    }

    fn run(
        protocol: Protocol,
        arch: Arch,
        mu: usize,
        lambda: usize,
        epochs: usize,
        numeric: bool,
        modulation: Modulation,
    ) -> SimResult {
        let mut cfg = SimConfig::paper(protocol, arch, mu, lambda, epochs, tiny_model());
        cfg.seed = 7;
        let n = 4;
        let theta0 = FlatVec::from_vec(vec![1.0, -2.0, 0.5, 3.0]);
        let opt = Optimizer::new(OptimizerKind::Sgd, 0.0, n);
        let lr = LrPolicy::new(Schedule::constant(0.05), modulation, 128);
        let mut provider = MockProvider::new(vec![0.0; n]);
        run_sim(
            &cfg,
            theta0,
            opt,
            lr,
            if numeric { Some(&mut provider) } else { None },
            None,
        )
        .unwrap()
    }

    #[test]
    fn hardsync_zero_staleness_and_convergence() {
        let r = run(Protocol::Hardsync, Arch::Base, 4, 4, 3, true, Modulation::None);
        assert_eq!(r.staleness.max, 0);
        assert!(r.updates > 0);
        // 12 updates at α=0.05 on the quadratic bowl contract the norm by
        // 0.95^12 ≈ 0.54 of the initial 3.84.
        let theta = r.theta.unwrap();
        let init_norm = FlatVec::from_vec(vec![1.0, -2.0, 0.5, 3.0]).norm();
        assert!(
            theta.norm() < 0.7 * init_norm,
            "should contract toward 0: {} vs {}",
            theta.norm(),
            init_norm
        );
        assert!(r.sim_seconds > 0.0);
    }

    #[test]
    fn one_softsync_avg_staleness_near_one() {
        let r = run(
            Protocol::NSoftsync { n: 1 },
            Arch::Base,
            4,
            8,
            4,
            true,
            Modulation::StalenessReciprocal,
        );
        let avg = r.staleness.overall_avg();
        assert!(
            (0.3..=2.0).contains(&avg),
            "1-softsync ⟨σ⟩ should be ≈1, got {avg}"
        );
        assert!(r.staleness.max <= 4, "σ ≤ 2n bound grossly violated: {}", r.staleness.max);
    }

    #[test]
    fn lambda_softsync_avg_staleness_near_lambda() {
        let lambda = 8;
        let r = run(
            Protocol::NSoftsync { n: lambda },
            Arch::Base,
            4,
            lambda,
            4,
            true,
            Modulation::StalenessReciprocal,
        );
        let avg = r.staleness.overall_avg();
        assert!(
            (lambda as f64 * 0.4..=lambda as f64 * 1.8).contains(&avg),
            "λ-softsync ⟨σ⟩ should be ≈λ={lambda}, got {avg}"
        );
    }

    #[test]
    fn timing_only_runs_all_archs() {
        for arch in [Arch::Base, Arch::Adv, Arch::AdvStar] {
            let r = run(Protocol::NSoftsync { n: 1 }, arch, 4, 8, 2, false, Modulation::None);
            assert!(r.sim_seconds > 0.0, "{arch:?}");
            assert!(r.updates > 0, "{arch:?}");
            assert!(r.theta.is_none());
        }
    }

    #[test]
    fn hardsync_adv_completes_stale_free() {
        let r = run(Protocol::Hardsync, Arch::Adv, 4, 8, 2, true, Modulation::None);
        assert!(r.updates > 0);
        assert_eq!(r.staleness.max, 0, "hardsync over the PS tree must be stale-free");
    }

    #[test]
    fn hardsync_advstar_rejected() {
        let cfg = SimConfig::paper(Protocol::Hardsync, Arch::AdvStar, 4, 4, 1, tiny_model());
        let mut p = MockProvider::new(vec![0.0; 2]);
        let err = run_sim(
            &cfg,
            FlatVec::zeros(2),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 2),
            LrPolicy::new(Schedule::constant(0.1), Modulation::None, 128),
            Some(&mut p),
            None,
        )
        .unwrap_err();
        assert!(err.to_string().contains("contradictory"), "{err}");
    }

    #[test]
    fn more_learners_train_faster_in_sim_time() {
        let slow = run(Protocol::NSoftsync { n: 1 }, Arch::Base, 8, 1, 2, false, Modulation::None);
        let fast = run(Protocol::NSoftsync { n: 1 }, Arch::Base, 8, 8, 2, false, Modulation::None);
        assert!(
            fast.sim_seconds < slow.sim_seconds,
            "scale-out should reduce simulated time: {} vs {}",
            fast.sim_seconds,
            slow.sim_seconds
        );
    }

    #[test]
    fn sharded_root_preserves_semantics() {
        let base_cfg =
            SimConfig::paper(Protocol::NSoftsync { n: 1 }, Arch::Base, 4, 8, 2, tiny_model());
        let run_s = |shards: usize| {
            let mut cfg = base_cfg.clone();
            cfg.seed = 7;
            cfg.shards = shards;
            let mut provider = MockProvider::new(vec![0.0; 4]);
            run_sim(
                &cfg,
                FlatVec::from_vec(vec![1.0, -2.0, 0.5, 3.0]),
                Optimizer::new(OptimizerKind::Sgd, 0.0, 4),
                LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
                Some(&mut provider),
                None,
            )
            .unwrap()
        };
        let flat = run_s(1);
        let sharded = run_s(4);
        // epoch accounting is sample-driven, so the update budget is
        // shard-invariant; per-shard counters stay in lockstep.
        assert_eq!(flat.updates, sharded.updates);
        assert_eq!(flat.shard_updates, vec![flat.updates]);
        assert_eq!(sharded.shard_updates, vec![sharded.updates; 4]);
        assert!(sharded.theta.unwrap().is_finite());
    }

    #[test]
    fn deterministic_replay() {
        let a = run(Protocol::NSoftsync { n: 2 }, Arch::Base, 4, 4, 2, true, Modulation::Auto);
        let b = run(Protocol::NSoftsync { n: 2 }, Arch::Base, 4, 4, 2, true, Modulation::Auto);
        assert_eq!(a.sim_seconds, b.sim_seconds);
        assert_eq!(a.theta.unwrap().data, b.theta.unwrap().data);
        assert_eq!(a.events_processed, b.events_processed);
    }

    #[test]
    fn epoch_stats_emitted() {
        let r = run(Protocol::Hardsync, Arch::Base, 4, 4, 3, true, Modulation::None);
        assert_eq!(r.epochs.len(), 3);
        assert!(r.epochs[0].epoch == 1);
        assert!(r.epochs.windows(2).all(|w| w[0].sim_time <= w[1].sim_time));
    }

    #[test]
    fn backup_zero_is_bitwise_hardsync() {
        let a = run(Protocol::Hardsync, Arch::Base, 4, 4, 3, true, Modulation::None);
        let b = run(Protocol::BackupSync { b: 0 }, Arch::Base, 4, 4, 3, true, Modulation::None);
        assert_eq!(a.sim_seconds, b.sim_seconds);
        assert_eq!(a.updates, b.updates);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.theta.unwrap().data, b.theta.unwrap().data);
        assert_eq!(b.dropped_gradients, 0, "b = 0 can never drop");
    }

    #[test]
    fn backup_sync_completes_stale_free_and_books_drops() {
        for arch in [Arch::Base, Arch::Adv] {
            let r = run(Protocol::BackupSync { b: 2 }, arch, 4, 8, 3, true, Modulation::None);
            assert_eq!(r.epochs.len(), 3, "{arch:?}: completed");
            assert_eq!(r.staleness.max, 0, "{arch:?}: backup-sync folds only fresh gradients");
            assert!(r.updates > 0, "{arch:?}");
            assert_eq!(
                r.dropped_by_learner.iter().sum::<u64>(),
                r.dropped_gradients,
                "{arch:?}: per-learner attribution must add up"
            );
            assert!(r.theta.unwrap().is_finite(), "{arch:?}");
        }
    }

    #[test]
    fn backup_sync_advstar_rejected_like_hardsync() {
        let cfg =
            SimConfig::paper(Protocol::BackupSync { b: 1 }, Arch::AdvStar, 4, 4, 1, tiny_model());
        let mut p = MockProvider::new(vec![0.0; 2]);
        let err = run_sim(
            &cfg,
            FlatVec::zeros(2),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 2),
            LrPolicy::new(Schedule::constant(0.1), Modulation::None, 128),
            Some(&mut p),
            None,
        )
        .unwrap_err();
        assert!(err.to_string().contains("contradictory"), "{err}");
    }

    #[test]
    fn hetero_slowdown_extends_sim_time_deterministically() {
        let mut cfg =
            SimConfig::paper(Protocol::NSoftsync { n: 1 }, Arch::Base, 4, 4, 2, tiny_model());
        cfg.seed = 7;
        let time = |hetero: &str| {
            let mut c = cfg.clone();
            c.hetero = crate::straggler::hetero::HeteroSpec::parse(hetero).unwrap();
            run_sim(
                &c,
                FlatVec::zeros(0),
                Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
                LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
                None,
                None,
            )
            .unwrap()
        };
        let flat = time("none");
        let slow = time("slow:0x4");
        assert!(
            slow.sim_seconds > flat.sim_seconds,
            "a 4× straggler must stretch the run: {} vs {}",
            slow.sim_seconds,
            flat.sim_seconds
        );
        assert_eq!(slow.hetero_factors, vec![4.0, 1.0, 1.0, 1.0]);
        let replay = time("slow:0x4");
        assert_eq!(slow.sim_seconds, replay.sim_seconds, "hetero runs replay exactly");
        assert_eq!(slow.events_processed, replay.events_processed);
    }

    #[test]
    fn hetero_out_of_range_and_bad_jitter_rejected() {
        let mut cfg =
            SimConfig::paper(Protocol::NSoftsync { n: 1 }, Arch::Base, 4, 2, 1, tiny_model());
        cfg.hetero = crate::straggler::hetero::HeteroSpec::parse("slow:5x2").unwrap();
        let run_cfg = |c: &SimConfig| {
            run_sim(
                c,
                FlatVec::zeros(0),
                Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
                LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
                None,
                None,
            )
        };
        let err = run_cfg(&cfg).unwrap_err();
        assert!(err.to_string().contains("hetero"), "{err}");
        // Regression: compute_jitter outside [0, 1) used to be silently
        // accepted and mean-shifted every duration via the clamp.
        let mut cfg =
            SimConfig::paper(Protocol::NSoftsync { n: 1 }, Arch::Base, 4, 2, 1, tiny_model());
        cfg.cluster.compute_jitter = 1.5;
        let err = run_cfg(&cfg).unwrap_err();
        assert!(err.to_string().contains("compute_jitter"), "{err}");
        cfg.cluster.compute_jitter = -0.2;
        assert!(run_cfg(&cfg).is_err());
    }

    #[test]
    fn compression_shrinks_sim_time_and_root_bytes() {
        // Timing-only on the Table 1 adversarial model: wire time
        // dominates, so a 50× push codec must shorten the run and cut
        // the root's ingress bytes accordingly.
        let mut cfg = SimConfig::paper(
            Protocol::NSoftsync { n: 1 },
            Arch::Base,
            4,
            8,
            1,
            ModelCost::adversarial_300mb(),
        );
        cfg.seed = 7;
        cfg.max_updates = Some(20);
        let run_c = |compress: &str| {
            let mut c = cfg.clone();
            c.compress = CodecSpec::parse(compress).unwrap();
            run_sim(
                &c,
                FlatVec::zeros(0),
                Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
                LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
                None,
                None,
            )
            .unwrap()
        };
        let dense = run_c("none");
        let topk = run_c("topk:0.01");
        assert!(dense.root_bytes_in > 0.0 && dense.root_bytes_out > 0.0);
        assert!(
            topk.sim_seconds < dense.sim_seconds,
            "compressed pushes must finish sooner: {} vs {}",
            topk.sim_seconds,
            dense.sim_seconds
        );
        let per_update = |r: &SimResult| r.root_bytes_in / r.updates.max(1) as f64;
        assert!(
            per_update(&topk) < 0.05 * per_update(&dense),
            "topk:0.01 ingress should be ~2% of dense: {} vs {}",
            per_update(&topk),
            per_update(&dense)
        );
        // pulls stay dense: out-bytes per update are the same order
        assert!(topk.root_bytes_out > 0.0);
        // timing-only runs have no codecs, so no residual column
        assert!(topk.residual_norms.is_empty());
        // per-learner accounting adds up to the ingress of the Base arch
        let pushed: f64 = topk.comm_bytes_by_learner.iter().sum();
        assert!((pushed - topk.root_bytes_in).abs() < 1e-6 * pushed.max(1.0));
    }

    #[test]
    fn advstar_striped_broadcast_shortens_the_period_at_s4() {
        // The ROADMAP stripe item, observable end to end: a comm-bound
        // adv* run (fat model, negligible compute, zero jitter so the
        // comparison is structural, not a different random sequence) must
        // get faster when the root tier stripes — relays carry 1/S slices
        // into S endpoints and the broadcast period scales with bytes/S.
        let fat_model = ModelCost {
            name: "fat-tiny-flops",
            flops_per_sample: 1.0e6,
            bytes: 300.0e6,
            samples_per_epoch: 1_000_000,
        };
        let mut cfg =
            SimConfig::paper(Protocol::NSoftsync { n: 1 }, Arch::AdvStar, 4, 16, 1, fat_model);
        cfg.seed = 9;
        cfg.max_updates = Some(30);
        cfg.cluster.compute_jitter = 0.0;
        let run_s = |shards: usize| {
            let mut c = cfg.clone();
            c.shards = shards;
            run_sim(
                &c,
                FlatVec::zeros(0),
                Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
                LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
                None,
                None,
            )
            .unwrap()
        };
        let flat = run_s(1);
        let striped = run_s(4);
        assert_eq!(flat.updates, striped.updates, "same update budget either way");
        assert!(
            striped.sim_seconds < flat.sim_seconds,
            "striping must speed a comm-bound adv* run: {} vs {}",
            striped.sim_seconds,
            flat.sim_seconds
        );
        assert!(striped.root_bytes_in > 0.0 && striped.root_bytes_out > 0.0);
    }

    #[test]
    fn adaptive_requires_softsync() {
        let mut cfg = SimConfig::paper(Protocol::Async, Arch::Base, 4, 4, 1, tiny_model());
        cfg.adaptive = crate::straggler::adaptive::AdaptiveSpec::parse("sigma:2").unwrap();
        let err = run_sim(
            &cfg,
            FlatVec::zeros(0),
            Optimizer::new(OptimizerKind::Sgd, 0.0, 0),
            LrPolicy::new(Schedule::constant(0.05), Modulation::None, 128),
            None,
            None,
        )
        .unwrap_err();
        assert!(err.to_string().contains("adaptive"), "{err}");
    }
}
