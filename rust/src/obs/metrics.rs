//! Metrics registry: counters/gauges/histograms the engines accumulate
//! while running and snapshot into their results.
//!
//! The paper's tradeoff study needs *distributions*, not just means —
//! Zhang et al.'s staleness-aware tuning works off the staleness
//! histogram, and the §3.3 bottleneck analysis needs root byte flows and
//! barrier wait time, none of which the per-epoch CSV rows carry. The
//! registry is purely observational: it reads engine state and never
//! draws from an engine RNG or touches event order, so metrics-on runs
//! stay bit-identical to metrics-off ones (property-tested).

use std::collections::BTreeMap;

use crate::coordinator::clock::StalenessStats;
use crate::util::json::Json;

/// Counter/gauge store. Counter names are `&'static str`: the vocabulary
/// is the engines' closed set of event kinds, and incrementing must not
/// allocate on the event hot path.
#[derive(Debug, Default, Clone)]
pub struct MetricsRegistry {
    counters: BTreeMap<&'static str, u64>,
    /// Event-queue depth high-water mark (gauge).
    queue_depth_high_water: u64,
    /// Barrier rounds closed (hardsync/backup-sync broadcasts).
    barrier_rounds: u64,
    /// Individual learner barrier waits observed.
    barrier_waits: u64,
    barrier_wait_sum: f64,
    barrier_wait_max: f64,
    /// Mean barrier wait per round, in virtual seconds (one entry per
    /// round — the same unbounded-series precedent as
    /// [`StalenessStats::per_update_avg`]).
    barrier_round_mean_wait: Vec<f64>,
}

impl MetricsRegistry {
    #[inline]
    pub fn count(&mut self, name: &'static str) {
        *self.counters.entry(name).or_insert(0) += 1;
    }

    /// Bulk counter increment (e.g. a delivery preceded by `n`
    /// retransmission attempts books them all at once).
    #[inline]
    pub fn count_n(&mut self, name: &'static str, n: u64) {
        *self.counters.entry(name).or_insert(0) += n;
    }

    #[inline]
    pub fn gauge_queue_depth(&mut self, depth: u64) {
        if depth > self.queue_depth_high_water {
            self.queue_depth_high_water = depth;
        }
    }

    /// Close one barrier round with the per-learner waits it released.
    pub fn barrier_round(&mut self, waits: &[f64]) {
        if waits.is_empty() {
            return;
        }
        self.barrier_rounds += 1;
        let mut sum = 0.0;
        for &w in waits {
            self.barrier_waits += 1;
            self.barrier_wait_sum += w;
            if w > self.barrier_wait_max {
                self.barrier_wait_max = w;
            }
            sum += w;
        }
        self.barrier_round_mean_wait.push(sum / waits.len() as f64);
    }

    pub fn queue_depth_high_water(&self) -> u64 {
        self.queue_depth_high_water
    }

    /// Snapshot everything into one JSON object, folding in the
    /// server-side distributions (staleness histogram, per-shard update
    /// counts, per-learner push contributions, root byte flows) that live
    /// outside the registry.
    pub fn snapshot(
        &self,
        staleness: &StalenessStats,
        shard_updates: &[u64],
        pushes_by_learner: &[u64],
        root_bytes_in: f64,
        root_bytes_out: f64,
    ) -> Json {
        let counters = Json::Obj(
            self.counters.iter().map(|(k, v)| (k.to_string(), Json::num(*v as f64))).collect(),
        );
        Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("counters", counters),
            ("queue_depth_high_water", Json::num(self.queue_depth_high_water as f64)),
            (
                "barrier",
                Json::obj(vec![
                    ("rounds", Json::num(self.barrier_rounds as f64)),
                    ("waits", Json::num(self.barrier_waits as f64)),
                    ("wait_secs_sum", Json::num(self.barrier_wait_sum)),
                    ("wait_secs_max", Json::num(self.barrier_wait_max)),
                    (
                        "wait_secs_mean",
                        Json::num(if self.barrier_waits == 0 {
                            0.0
                        } else {
                            self.barrier_wait_sum / self.barrier_waits as f64
                        }),
                    ),
                    ("round_mean_wait_secs", Json::arr_f64(&self.barrier_round_mean_wait)),
                ]),
            ),
            (
                "staleness",
                Json::obj(vec![
                    ("avg", Json::num(staleness.overall_avg())),
                    ("max", Json::num(staleness.max as f64)),
                    ("count", Json::num(staleness.count as f64)),
                    ("histogram", Json::arr_u64(&staleness.histogram)),
                ]),
            ),
            ("shard_updates", Json::arr_u64(shard_updates)),
            ("pushes_by_learner", Json::arr_u64(pushes_by_learner)),
            ("root_bytes_in", Json::num(root_bytes_in)),
            ("root_bytes_out", Json::num(root_bytes_out)),
        ])
    }
}

/// Attach a recorded time series ([`super::series`]) to a snapshot under
/// the `"series"` key. Both engines call this so the key name and
/// placement stay consistent across sim/timing/live outputs.
pub fn attach_series(snapshot: &mut Json, series: Json) {
    if let Json::Obj(m) = snapshot {
        m.insert("series".to_string(), series);
    }
}

/// Attach a critical-path profile ([`super::profile`]) to a snapshot
/// under the `"profile"` key — the same placement contract as
/// [`attach_series`], shared by both engines and `rudra analyze`.
pub fn attach_profile(snapshot: &mut Json, profile: Json) {
    if let Json::Obj(m) = snapshot {
        m.insert("profile".to_string(), profile);
    }
}

/// Attach the fault-plane accounting
/// ([`crate::netsim::reliable::FaultStats`]) to a snapshot under the
/// `"faults"` key — same placement contract as [`attach_series`], shared
/// by both engines and the CI chaos smoke's validator.
pub fn attach_faults(snapshot: &mut Json, faults: Json) {
    if let Json::Obj(m) = snapshot {
        m.insert("faults".to_string(), faults);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_accumulate() {
        let mut m = MetricsRegistry::default();
        m.count("compute_done");
        m.count("compute_done");
        m.count("push");
        m.gauge_queue_depth(3);
        m.gauge_queue_depth(9);
        m.gauge_queue_depth(5);
        assert_eq!(m.counters["compute_done"], 2);
        assert_eq!(m.counters["push"], 1);
        assert_eq!(m.queue_depth_high_water(), 9);
    }

    #[test]
    fn barrier_rounds_track_wait_distribution() {
        let mut m = MetricsRegistry::default();
        m.barrier_round(&[1.0, 3.0]);
        m.barrier_round(&[0.0, 2.0]);
        m.barrier_round(&[]); // released nobody: not a round
        assert_eq!(m.barrier_rounds, 2);
        assert_eq!(m.barrier_waits, 4);
        assert_eq!(m.barrier_wait_sum, 6.0);
        assert_eq!(m.barrier_wait_max, 3.0);
        assert_eq!(m.barrier_round_mean_wait, vec![2.0, 1.0]);
    }

    #[test]
    fn snapshot_round_trips_through_json_text() {
        let mut m = MetricsRegistry::default();
        m.count("apply_update");
        m.gauge_queue_depth(17);
        m.barrier_round(&[0.5]);
        let mut staleness = StalenessStats::default();
        staleness.record(2, &[1, 0]);
        let snap = m.snapshot(&staleness, &[4, 4], &[3, 5], 100.0, 200.0);
        let parsed = Json::parse(&snap.to_string()).unwrap();
        assert_eq!(parsed.get("queue_depth_high_water").unwrap().as_u64().unwrap(), 17);
        assert_eq!(parsed.get("barrier").unwrap().get("rounds").unwrap().as_u64().unwrap(), 1);
        assert_eq!(
            parsed.get("staleness").unwrap().get("histogram").unwrap().as_u64_vec().unwrap(),
            vec![1, 1]
        );
        assert_eq!(parsed.get("pushes_by_learner").unwrap().as_u64_vec().unwrap(), vec![3, 5]);
        assert_eq!(parsed.get("root_bytes_in").unwrap().as_f64().unwrap(), 100.0);
    }

    #[test]
    fn attach_series_inserts_under_the_series_key() {
        let m = MetricsRegistry::default();
        let mut snap = m.snapshot(&StalenessStats::default(), &[], &[], 0.0, 0.0);
        attach_series(&mut snap, Json::obj(vec![("schema", Json::num(1.0))]));
        assert_eq!(snap.get("series").unwrap().get("schema").unwrap().as_u64().unwrap(), 1);
    }

    #[test]
    fn attach_profile_inserts_under_the_profile_key() {
        let m = MetricsRegistry::default();
        let mut snap = m.snapshot(&StalenessStats::default(), &[], &[], 0.0, 0.0);
        attach_profile(&mut snap, Json::obj(vec![("schema", Json::num(1.0))]));
        assert_eq!(snap.get("profile").unwrap().get("schema").unwrap().as_u64().unwrap(), 1);
    }
}
