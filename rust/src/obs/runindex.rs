//! Persistent run index: one appended JSONL record per sim/sweep/timing
//! point, so results outlive the process that produced them.
//!
//! Every record carries enough to reconstruct the paper's tradeoff
//! frontier later — config fingerprint + label + seed (identity),
//! accuracy (test error / train loss when numeric), virtual and wall
//! time, root byte flows, staleness stats, and the full metrics snapshot
//! when one was collected. `rudra runs list` / `rudra runs diff` read
//! the index back; the file is append-only (concatenating indexes from
//! two machines is a valid merge).

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::stats::table::Table;
use crate::util::json::Json;

/// Default index path (workspace-relative, like `BENCH_hotpath.json`).
pub const DEFAULT_INDEX: &str = "runs.jsonl";

/// One indexed run (or sweep point).
#[derive(Debug, Clone)]
pub struct RunRecord {
    /// Which command produced it: "sim", "sweep", or "timing".
    pub kind: String,
    /// Human-readable run label ([`crate::config::RunConfig::label`]).
    pub label: String,
    /// Trajectory-shaping config fingerprint
    /// ([`crate::coordinator::engine_sim::SimEngine::config_fingerprint`]).
    pub fingerprint: String,
    pub seed: u64,
    pub mu: usize,
    pub lambda: usize,
    pub shards: usize,
    pub epochs: usize,
    /// Final held-out error % (numeric runs only).
    pub test_error_pct: Option<f64>,
    /// Final training loss (numeric runs only).
    pub train_loss: Option<f64>,
    /// Virtual (simulated) seconds.
    pub sim_seconds: f64,
    /// Host wall-clock seconds the point took to run.
    pub wall_seconds: f64,
    pub updates: u64,
    pub events: u64,
    pub avg_staleness: f64,
    pub max_staleness: u64,
    pub root_bytes_in: f64,
    pub root_bytes_out: f64,
    /// Metrics snapshot ([`crate::obs::metrics::MetricsRegistry`]), when
    /// the run collected one.
    pub metrics: Option<Json>,
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("kind", Json::str(&self.kind)),
            ("label", Json::str(&self.label)),
            ("fingerprint", Json::str(&self.fingerprint)),
            ("seed", Json::num(self.seed as f64)),
            ("mu", Json::num(self.mu as f64)),
            ("lambda", Json::num(self.lambda as f64)),
            ("shards", Json::num(self.shards as f64)),
            ("epochs", Json::num(self.epochs as f64)),
            ("sim_seconds", Json::num(self.sim_seconds)),
            ("wall_seconds", Json::num(self.wall_seconds)),
            ("updates", Json::num(self.updates as f64)),
            ("events", Json::num(self.events as f64)),
            ("avg_staleness", Json::num(self.avg_staleness)),
            ("max_staleness", Json::num(self.max_staleness as f64)),
            ("root_bytes_in", Json::num(self.root_bytes_in)),
            ("root_bytes_out", Json::num(self.root_bytes_out)),
        ];
        // Optional accuracy fields are *omitted* when absent or non-finite
        // (timing-only runs report NaN train loss; NaN has no JSON form).
        if let Some(e) = self.test_error_pct.filter(|e| e.is_finite()) {
            pairs.push(("test_error_pct", Json::num(e)));
        }
        if let Some(l) = self.train_loss.filter(|l| l.is_finite()) {
            pairs.push(("train_loss", Json::num(l)));
        }
        if let Some(m) = &self.metrics {
            pairs.push(("metrics", m.clone()));
        }
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<RunRecord> {
        Ok(RunRecord {
            kind: v.get("kind")?.as_str()?.to_string(),
            label: v.get("label")?.as_str()?.to_string(),
            fingerprint: v.get("fingerprint")?.as_str()?.to_string(),
            seed: v.get("seed")?.as_u64()?,
            mu: v.get("mu")?.as_usize()?,
            lambda: v.get("lambda")?.as_usize()?,
            shards: v.get("shards")?.as_usize()?,
            epochs: v.get("epochs")?.as_usize()?,
            test_error_pct: match v.opt("test_error_pct") {
                Some(e) => Some(e.as_f64()?),
                None => None,
            },
            train_loss: match v.opt("train_loss") {
                Some(l) => Some(l.as_f64()?),
                None => None,
            },
            sim_seconds: v.get("sim_seconds")?.as_f64()?,
            wall_seconds: v.get("wall_seconds")?.as_f64()?,
            updates: v.get("updates")?.as_u64()?,
            events: v.get("events")?.as_u64()?,
            avg_staleness: v.get("avg_staleness")?.as_f64()?,
            max_staleness: v.get("max_staleness")?.as_u64()?,
            root_bytes_in: v.get("root_bytes_in")?.as_f64()?,
            root_bytes_out: v.get("root_bytes_out")?.as_f64()?,
            metrics: v.opt("metrics").cloned(),
        })
    }
}

/// Serializes in-process appenders (parallel sweep jobs, concurrent
/// tests) so records never interleave mid-line. Cross-process appends are
/// already atomic because each record lands as one `write_all` of a full
/// line on an `O_APPEND` descriptor.
static APPEND_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Append one record. The file is opened in append mode (not the
/// truncating [`crate::stats::log::JsonlLog`] writer): the whole point is
/// that records from *successive processes* accumulate. The record is
/// pre-formatted (JSON + trailing newline) and written with a single
/// `write_all` under [`APPEND_LOCK`], so a reader never observes half a
/// line from a concurrent writer.
pub fn append(path: &Path, record: &RunRecord) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating run-index directory {}", parent.display()))?;
        }
    }
    let mut line = record.to_json().to_string();
    line.push('\n');
    let guard = APPEND_LOCK.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    let mut file = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("opening run index {}", path.display()))?;
    let result = file
        .write_all(line.as_bytes())
        .with_context(|| format!("appending to run index {}", path.display()));
    drop(guard);
    result
}

/// Load every record (empty if the index does not exist yet).
///
/// Streams line by line instead of slurping the whole file (indexes
/// accumulate across processes and machines). A malformed *final* line is
/// the signature of a crash-truncated append: it is skipped with a
/// warning so `rudra runs` keeps working over everything that did land.
/// A malformed line with more records after it is real corruption and
/// stays a hard error.
pub fn load(path: &Path) -> Result<Vec<RunRecord>> {
    use std::io::BufRead;
    let file = match std::fs::File::open(path) {
        Ok(f) => f,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => {
            return Err(e).with_context(|| format!("opening run index {}", path.display()))
        }
    };
    let mut reader = std::io::BufReader::new(file);
    let mut records = Vec::new();
    let mut pending_error: Option<(usize, anyhow::Error)> = None;
    let mut line = String::new();
    let mut lineno = 0usize;
    loop {
        line.clear();
        let n = reader
            .read_line(&mut line)
            .with_context(|| format!("reading run index {}", path.display()))?;
        if n == 0 {
            break;
        }
        lineno += 1;
        if line.trim().is_empty() {
            continue;
        }
        // A bad line earlier than the last non-blank one is corruption,
        // not truncation: surface the original error.
        if let Some((bad_line, err)) = pending_error.take() {
            return Err(err)
                .with_context(|| format!("{}:{}: bad JSONL line", path.display(), bad_line));
        }
        let parsed = Json::parse(line.trim_end())
            .and_then(|v| RunRecord::from_json(&v).context("bad run record"));
        match parsed {
            Ok(r) => records.push(r),
            Err(e) => pending_error = Some((lineno, e)),
        }
    }
    if let Some((bad_line, _)) = pending_error {
        eprintln!(
            "warning: {}:{}: skipping trailing partial record (crash-truncated append?)",
            path.display(),
            bad_line
        );
    }
    Ok(records)
}

fn fmt_opt_pct(v: Option<f64>) -> String {
    match v {
        Some(e) => format!("{e:.2}"),
        None => "-".to_string(),
    }
}

/// Render records as the `rudra runs list` table. Row numbers are the
/// record's position in the *full* index (stable diff handles even when
/// a filter hides rows).
pub fn render_list(records: &[(usize, &RunRecord)]) -> Table {
    let mut t = Table::new(&[
        "#",
        "kind",
        "label",
        "seed",
        "err%",
        "<sigma>",
        "sim s",
        "wall s",
        "updates",
        "events",
    ]);
    for (i, r) in records {
        t.row(vec![
            i.to_string(),
            r.kind.clone(),
            r.label.clone(),
            r.seed.to_string(),
            fmt_opt_pct(r.test_error_pct),
            format!("{:.3}", r.avg_staleness),
            format!("{:.1}", r.sim_seconds),
            format!("{:.2}", r.wall_seconds),
            r.updates.to_string(),
            r.events.to_string(),
        ]);
    }
    t
}

fn diff_num(lines: &mut Vec<String>, name: &str, a: f64, b: f64) {
    // Both-NaN means "absent on both sides" (timing records carry no
    // accuracy) — not a difference.
    if a == b || (a.is_nan() && b.is_nan()) {
        return;
    }
    let rel = if a != 0.0 {
        format!(" ({:+.1}%)", (b - a) / a * 100.0)
    } else {
        String::new()
    };
    lines.push(format!("  {name}: {a} -> {b}{rel}"));
}

/// Field-by-field diff of two records (the `rudra runs diff I J` body).
pub fn render_diff(a: &RunRecord, b: &RunRecord) -> Vec<String> {
    let mut lines = Vec::new();
    if a.label != b.label {
        lines.push(format!("  label: {} -> {}", a.label, b.label));
    }
    if a.fingerprint != b.fingerprint {
        lines.push("  fingerprint: DIFFERENT (configs are not comparable point-for-point)".into());
    }
    diff_num(&mut lines, "seed", a.seed as f64, b.seed as f64);
    diff_num(
        &mut lines,
        "test_error_pct",
        a.test_error_pct.unwrap_or(f64::NAN),
        b.test_error_pct.unwrap_or(f64::NAN),
    );
    diff_num(&mut lines, "sim_seconds", a.sim_seconds, b.sim_seconds);
    diff_num(&mut lines, "wall_seconds", a.wall_seconds, b.wall_seconds);
    diff_num(&mut lines, "updates", a.updates as f64, b.updates as f64);
    diff_num(&mut lines, "events", a.events as f64, b.events as f64);
    diff_num(&mut lines, "avg_staleness", a.avg_staleness, b.avg_staleness);
    diff_num(&mut lines, "max_staleness", a.max_staleness as f64, b.max_staleness as f64);
    diff_num(&mut lines, "root_bytes_in", a.root_bytes_in, b.root_bytes_in);
    diff_num(&mut lines, "root_bytes_out", a.root_bytes_out, b.root_bytes_out);
    if lines.is_empty() {
        lines.push("  (identical)".into());
    }
    lines
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(kind: &str, seed: u64) -> RunRecord {
        RunRecord {
            kind: kind.to_string(),
            label: format!("sim-1-softsync-mu4-lambda8-seed{seed}"),
            fingerprint: "timing|1-softsync|Base|...".to_string(),
            seed,
            mu: 4,
            lambda: 8,
            shards: 1,
            epochs: 2,
            test_error_pct: Some(12.5),
            train_loss: Some(0.42),
            sim_seconds: 100.0,
            wall_seconds: 1.5,
            updates: 2000,
            events: 60_000,
            avg_staleness: 3.25,
            max_staleness: 9,
            root_bytes_in: 1e9,
            root_bytes_out: 2e9,
            metrics: Some(Json::obj(vec![("queue_depth_high_water", Json::num(33.0))])),
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("rudra_runindex_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn append_is_cumulative_and_loads_back() {
        let path = tmp("append.jsonl");
        std::fs::remove_file(&path).ok();
        append(&path, &sample("sim", 1)).unwrap();
        append(&path, &sample("sweep", 2)).unwrap();
        let records = load(&path).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(records[0].kind, "sim");
        assert_eq!(records[1].seed, 2);
        assert!(records[1].metrics.is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn timing_records_omit_nan_accuracy() {
        let mut r = sample("timing", 3);
        r.test_error_pct = Some(f64::NAN);
        r.train_loss = None;
        let text = r.to_json().to_string();
        assert!(!text.contains("test_error_pct"), "NaN must be omitted: {text}");
        let back = RunRecord::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(back.test_error_pct.is_none());
        assert!(back.train_loss.is_none());
    }

    /// jobs ∈ {1, 4}: concurrent appenders must never tear a line — every
    /// line in the index parses and every record lands exactly once.
    #[test]
    fn concurrent_appends_never_tear_lines() {
        for jobs in [1usize, 4] {
            let path = tmp(&format!("concurrent_{jobs}.jsonl"));
            std::fs::remove_file(&path).ok();
            let per_job = 25u64;
            std::thread::scope(|scope| {
                for job in 0..jobs {
                    let path = path.clone();
                    scope.spawn(move || {
                        for i in 0..per_job {
                            let seed = job as u64 * 1000 + i;
                            append(&path, &sample("sweep", seed)).unwrap();
                        }
                    });
                }
            });
            // Raw-text check first: every line must parse on its own (the
            // failure mode of interleaved writes is a torn/merged line).
            let text = std::fs::read_to_string(&path).unwrap();
            for (i, line) in text.lines().enumerate() {
                Json::parse(line).unwrap_or_else(|e| {
                    panic!("jobs={jobs}: line {} is not valid JSON ({e}): {line}", i + 1)
                });
            }
            let records = load(&path).unwrap();
            assert_eq!(records.len(), jobs * per_job as usize, "jobs={jobs}");
            let mut seeds: Vec<u64> = records.iter().map(|r| r.seed).collect();
            seeds.sort_unstable();
            seeds.dedup();
            assert_eq!(
                seeds.len(),
                jobs * per_job as usize,
                "jobs={jobs}: duplicate or lost record"
            );
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn missing_index_loads_empty() {
        assert!(load(Path::new("/nonexistent/runs.jsonl")).unwrap().is_empty());
    }

    #[test]
    fn truncated_last_line_is_tolerated_with_the_rest_intact() {
        let path = tmp("truncated.jsonl");
        std::fs::remove_file(&path).ok();
        append(&path, &sample("sim", 1)).unwrap();
        append(&path, &sample("timing", 2)).unwrap();
        // Simulate a crash mid-append: half a record, no newline.
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"kind\": \"sim\", \"label\": \"cut-off-mid");
        std::fs::write(&path, &text).unwrap();
        let records = load(&path).unwrap();
        assert_eq!(records.len(), 2, "intact records must survive the torn tail");
        assert_eq!(records[1].seed, 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn garbage_before_the_end_is_still_a_hard_error() {
        let path = tmp("corrupt.jsonl");
        std::fs::remove_file(&path).ok();
        append(&path, &sample("sim", 1)).unwrap();
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("not json at all\n");
        std::fs::write(&path, &text).unwrap();
        append(&path, &sample("sim", 2)).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("bad JSONL line"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn diff_reports_changed_fields_only() {
        let a = sample("sim", 1);
        let mut b = sample("sim", 1);
        b.sim_seconds = 110.0;
        let lines = render_diff(&a, &b);
        assert_eq!(lines.len(), 1);
        assert!(lines[0].contains("sim_seconds"), "{lines:?}");
        assert!(lines[0].contains("+10.0%"), "{lines:?}");
        assert_eq!(render_diff(&a, &a.clone()), vec!["  (identical)".to_string()]);
    }
}
