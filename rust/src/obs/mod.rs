//! Observability: run tracing, metrics, and the persistent run index.
//!
//! Three layers, all *purely observational* — nothing here draws from an
//! engine RNG, touches event order, or mutates server state, so enabling
//! any of it leaves trajectories bit-identical (pinned by
//! `tests/integration_obs.rs`):
//!
//! * [`trace`] — Chrome trace-event recording over virtual sim time
//!   (`--trace FILE`, loadable in Perfetto / `chrome://tracing`).
//! * [`metrics`] — counters/gauges/histograms snapshotted into run
//!   results (`--metrics-json FILE`).
//! * [`runindex`] — append-only `runs.jsonl` of every sim/sweep/timing
//!   point (`--run-index FILE`, `rudra runs`), plus [`benchdiff`], the
//!   `rudra bench-diff` perf-trajectory gate over `BENCH_hotpath.json`.
//!
//! [`Obs`] is the engines' single integration point: one call per event
//! site feeds both the trace and the metrics, and the quiet default
//! costs one branch per site.

pub mod benchdiff;
pub mod metrics;
pub mod runindex;
pub mod trace;

use metrics::MetricsRegistry;
use trace::{TraceEvent, TraceRecorder};

/// Per-engine observability state. `Obs::off()` (the default) makes every
/// method an early-return branch.
#[derive(Debug, Default)]
pub struct Obs {
    trace: TraceRecorder,
    metrics: Option<MetricsRegistry>,
    /// Observer-side barrier bookkeeping: when each learner's gradient
    /// entered the barrier (engine state is not consulted at release
    /// time, so recording cannot perturb it).
    barrier_entered: Vec<f64>,
    /// Scratch: waits released by the round being closed.
    round_waits: Vec<f64>,
    active: bool,
}

impl Obs {
    /// The quiet default: records nothing, collects nothing.
    pub fn off() -> Obs {
        Obs::default()
    }

    pub fn new(trace_on: bool, metrics_on: bool, lambda: usize) -> Obs {
        if !trace_on && !metrics_on {
            return Obs::off();
        }
        Obs {
            trace: if trace_on { TraceRecorder::on() } else { TraceRecorder::off() },
            metrics: if metrics_on { Some(MetricsRegistry::default()) } else { None },
            barrier_entered: vec![0.0; lambda],
            round_waits: Vec::new(),
            active: true,
        }
    }

    #[inline]
    pub fn active(&self) -> bool {
        self.active
    }

    pub fn metrics(&self) -> Option<&MetricsRegistry> {
        self.metrics.as_ref()
    }

    /// Mini-batch compute span (reconstructed at completion: the engine
    /// caches the jittered cost, so the start is `end - cost`).
    #[inline]
    pub fn compute(&mut self, l: usize, start: f64, end: f64) {
        if !self.active {
            return;
        }
        self.trace.span("compute", trace::PID_LEARNERS, l as u64, start, end);
        if let Some(m) = &mut self.metrics {
            m.count("compute_done");
        }
    }

    /// Gradient push wire transit (learner → root or learner → leaf).
    #[inline]
    pub fn push(&mut self, l: usize, start: f64, end: f64) {
        if !self.active {
            return;
        }
        self.trace.span("push", trace::PID_LEARNERS, l as u64, start, end);
        if let Some(m) = &mut self.metrics {
            m.count("push_wire");
        }
    }

    /// Leaf aggregator relay hop (leaf → root).
    #[inline]
    pub fn relay(&mut self, leaf: usize, start: f64, end: f64) {
        if !self.active {
            return;
        }
        self.trace.span("relay", trace::PID_LEAVES, leaf as u64, start, end);
        if let Some(m) = &mut self.metrics {
            m.count("relay");
        }
    }

    /// Weight pull (request → delivery at the learner).
    #[inline]
    pub fn pull(&mut self, l: usize, start: f64, end: f64) {
        if !self.active {
            return;
        }
        self.trace.span("pull", trace::PID_LEARNERS, l as u64, start, end);
        if let Some(m) = &mut self.metrics {
            m.count("pull");
        }
    }

    /// Broadcast delivery span (root/leaf egress → learner).
    #[inline]
    pub fn broadcast(&mut self, l: usize, start: f64, end: f64) {
        if !self.active {
            return;
        }
        self.trace.span("broadcast", trace::PID_LEARNERS, l as u64, start, end);
        if let Some(m) = &mut self.metrics {
            m.count("broadcast");
        }
    }

    /// Adv* striped per-update broadcast initiation (modeled, not an
    /// event — recorded as an instant at the root tier).
    #[inline]
    pub fn advstar_broadcast(&mut self, now: f64) {
        if !self.active {
            return;
        }
        self.trace.instant("broadcast", trace::PID_SHARDS, 0, now);
        if let Some(m) = &mut self.metrics {
            m.count("broadcast");
        }
    }

    /// applyUpdate fired on every root shard (lockstep).
    #[inline]
    pub fn apply_update(&mut self, shards: usize, now: f64) {
        if !self.active {
            return;
        }
        for s in 0..shards {
            self.trace.instant("apply_update", trace::PID_SHARDS, s as u64, now);
        }
        if let Some(m) = &mut self.metrics {
            m.count("apply_update");
        }
    }

    /// Periodic checkpoint capture.
    #[inline]
    pub fn checkpoint(&mut self, now: f64) {
        if !self.active {
            return;
        }
        self.trace.instant("checkpoint", trace::PID_SHARDS, 0, now);
        if let Some(m) = &mut self.metrics {
            m.count("checkpoint");
        }
    }

    /// A learner's gradient reached the barrier (starts its wait).
    #[inline]
    pub fn barrier_enter(&mut self, l: usize, now: f64) {
        if !self.active {
            return;
        }
        if let Some(e) = self.barrier_entered.get_mut(l) {
            *e = now;
        }
    }

    /// The closing broadcast released learner `l` from the barrier.
    #[inline]
    pub fn barrier_release(&mut self, l: usize, now: f64) {
        if !self.active {
            return;
        }
        let entered = self.barrier_entered.get(l).copied().unwrap_or(now);
        self.trace.span("barrier_wait", trace::PID_LEARNERS, l as u64, entered, now);
        if self.metrics.is_some() {
            self.round_waits.push((now - entered).max(0.0));
        }
    }

    /// All releases for the current round are in; fold them into the
    /// per-round barrier histogram.
    #[inline]
    pub fn barrier_round_end(&mut self) {
        if !self.active {
            return;
        }
        if let Some(m) = &mut self.metrics {
            m.barrier_round(&self.round_waits);
        }
        self.round_waits.clear();
    }

    /// Event-queue depth gauge (called per loop iteration; a no-op
    /// unless metrics are on).
    #[inline]
    pub fn queue_depth(&mut self, depth: usize) {
        if let Some(m) = &mut self.metrics {
            m.gauge_queue_depth(depth as u64);
        }
    }

    /// Snapshot the metrics (if collecting) with the server-side
    /// distributions folded in.
    pub fn metrics_snapshot(
        &self,
        staleness: &crate::coordinator::clock::StalenessStats,
        shard_updates: &[u64],
        pushes_by_learner: &[u64],
        root_bytes_in: f64,
        root_bytes_out: f64,
    ) -> Option<crate::util::json::Json> {
        self.metrics.as_ref().map(|m| {
            m.snapshot(staleness, shard_updates, pushes_by_learner, root_bytes_in, root_bytes_out)
        })
    }

    /// Take the recorded trace (None when tracing was off).
    pub fn take_trace(&mut self) -> Option<Vec<TraceEvent>> {
        self.trace.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_is_inert() {
        let mut obs = Obs::off();
        obs.compute(0, 0.0, 1.0);
        obs.barrier_enter(0, 1.0);
        obs.barrier_release(0, 2.0);
        obs.barrier_round_end();
        obs.queue_depth(100);
        assert!(!obs.active());
        assert!(obs.take_trace().is_none());
        assert!(obs.metrics().is_none());
    }

    #[test]
    fn barrier_waits_span_entry_to_release() {
        let mut obs = Obs::new(true, true, 2);
        obs.barrier_enter(0, 1.0);
        obs.barrier_enter(1, 3.0);
        obs.barrier_release(0, 4.0);
        obs.barrier_release(1, 4.0);
        obs.barrier_round_end();
        let trace = obs.take_trace().unwrap();
        assert_eq!(trace.len(), 2);
        assert_eq!(trace[0].name, "barrier_wait");
        assert_eq!(trace[0].dur_us, 3.0e6);
        assert_eq!(trace[1].dur_us, 1.0e6);
        let snap = obs
            .metrics_snapshot(&Default::default(), &[], &[], 0.0, 0.0)
            .expect("metrics were on");
        let barrier = snap.get("barrier").unwrap();
        assert_eq!(barrier.get("rounds").unwrap().as_u64().unwrap(), 1);
        assert_eq!(barrier.get("wait_secs_max").unwrap().as_f64().unwrap(), 3.0);
    }

    #[test]
    fn trace_only_still_skips_metrics() {
        let mut obs = Obs::new(true, false, 1);
        obs.compute(0, 0.0, 0.5);
        assert!(obs.metrics_snapshot(&Default::default(), &[], &[], 0.0, 0.0).is_none());
        assert_eq!(obs.take_trace().unwrap().len(), 1);
    }
}
