//! Observability: run tracing, metrics, time series, and the persistent
//! run index with its report renderer.
//!
//! All layers are *purely observational* — nothing here draws from an
//! engine RNG, touches event order, or mutates server state, so enabling
//! any of it leaves trajectories bit-identical (pinned by
//! `tests/integration_obs.rs`):
//!
//! * [`trace`] — Chrome trace-event recording (`--trace FILE`, loadable
//!   in Perfetto / `chrome://tracing`) over virtual sim time, or wall
//!   time in the live engine via [`trace::TimeBase::Wall`].
//! * [`metrics`] — counters/gauges/histograms snapshotted into run
//!   results (`--metrics-json FILE`).
//! * [`series`] — windowed time series (`--metrics-every SECS`) sampled
//!   over the run and attached to the metrics snapshot.
//! * [`runindex`] — append-only `runs.jsonl` of every sim/sweep/timing
//!   point (`--run-index FILE`, `rudra runs`), plus [`benchdiff`], the
//!   `rudra bench-diff` perf-trajectory gate over `BENCH_hotpath.json`.
//! * [`report`] — `rudra report`: the index (+ per-run series) rendered
//!   into one self-contained HTML dashboard.
//!
//! [`Obs`] is the sim engines' single integration point: one call per
//! event site feeds the trace, the metrics, and the series, and the
//! quiet default costs one branch per site. The live engine drives
//! [`trace::TraceRecorder`] / [`series::SeriesRecorder`] directly — its
//! spans come from OS threads, not an event loop.

pub mod benchdiff;
pub mod metrics;
pub mod profile;
pub mod report;
pub mod runindex;
pub mod series;
pub mod trace;

use metrics::MetricsRegistry;
use series::{SeriesInputs, SeriesRecorder};
use trace::{TraceEvent, TraceRecorder};

/// Per-engine observability state. `Obs::off()` (the default) makes every
/// method an early-return branch.
#[derive(Debug, Default)]
pub struct Obs {
    trace: TraceRecorder,
    metrics: Option<MetricsRegistry>,
    /// Windowed time series (`metrics_every`), attached to the metrics
    /// snapshot when on.
    series: Option<SeriesRecorder>,
    /// Critical-path profiler (`profile`), attached to the metrics
    /// snapshot when on.
    profile: Option<profile::Profiler>,
    /// Observer-side barrier bookkeeping: when each learner's gradient
    /// entered the barrier (engine state is not consulted at release
    /// time, so recording cannot perturb it).
    barrier_entered: Vec<f64>,
    /// Scratch: waits released by the round being closed.
    round_waits: Vec<f64>,
    active: bool,
}

impl Obs {
    /// The quiet default: records nothing, collects nothing.
    pub fn off() -> Obs {
        Obs::default()
    }

    /// `metrics_every` (seconds of engine time between series samples)
    /// arms the metrics registry too: a series without its enclosing
    /// snapshot has nowhere to be serialized. `profile` arms it for the
    /// same reason — the attribution rides inside the snapshot.
    pub fn new(
        trace_on: bool,
        metrics_on: bool,
        metrics_every: Option<f64>,
        profile_on: bool,
        lambda: usize,
    ) -> Obs {
        if !trace_on && !metrics_on && metrics_every.is_none() && !profile_on {
            return Obs::off();
        }
        Obs {
            trace: if trace_on { TraceRecorder::on() } else { TraceRecorder::off() },
            metrics: if metrics_on || metrics_every.is_some() || profile_on {
                Some(MetricsRegistry::default())
            } else {
                None
            },
            series: metrics_every.map(SeriesRecorder::new),
            profile: profile_on.then(|| profile::Profiler::new(lambda)),
            barrier_entered: vec![0.0; lambda],
            round_waits: Vec::new(),
            active: true,
        }
    }

    #[inline]
    pub fn active(&self) -> bool {
        self.active
    }

    pub fn metrics(&self) -> Option<&MetricsRegistry> {
        self.metrics.as_ref()
    }

    /// Mini-batch compute span (reconstructed at completion: the engine
    /// caches the jittered cost, so the start is `end - cost`).
    #[inline]
    pub fn compute(&mut self, l: usize, start: f64, end: f64) {
        if !self.active {
            return;
        }
        self.trace.span("compute", trace::PID_LEARNERS, l as u64, start, end);
        if let Some(m) = &mut self.metrics {
            m.count("compute_done");
        }
        if let Some(p) = &mut self.profile {
            p.note_compute(l, start, end);
        }
    }

    /// Gradient push wire transit (learner → root or learner → leaf).
    #[inline]
    pub fn push(&mut self, l: usize, start: f64, end: f64) {
        if !self.active {
            return;
        }
        self.trace.span("push", trace::PID_LEARNERS, l as u64, start, end);
        if let Some(m) = &mut self.metrics {
            m.count("push_wire");
        }
        if let Some(p) = &mut self.profile {
            p.note_push(l, start, end);
        }
    }

    /// Leaf aggregator relay hop (leaf → root).
    #[inline]
    pub fn relay(&mut self, leaf: usize, start: f64, end: f64) {
        if !self.active {
            return;
        }
        self.trace.span("relay", trace::PID_LEAVES, leaf as u64, start, end);
        if let Some(m) = &mut self.metrics {
            m.count("relay");
        }
    }

    /// Weight pull (request → delivery at the learner).
    #[inline]
    pub fn pull(&mut self, l: usize, start: f64, end: f64) {
        if !self.active {
            return;
        }
        self.trace.span("pull", trace::PID_LEARNERS, l as u64, start, end);
        if let Some(m) = &mut self.metrics {
            m.count("pull");
        }
        if let Some(p) = &mut self.profile {
            p.note_deliver(l, start, end);
        }
    }

    /// Broadcast delivery span (root/leaf egress → learner).
    #[inline]
    pub fn broadcast(&mut self, l: usize, start: f64, end: f64) {
        if !self.active {
            return;
        }
        self.trace.span("broadcast", trace::PID_LEARNERS, l as u64, start, end);
        if let Some(m) = &mut self.metrics {
            m.count("broadcast");
        }
        if let Some(p) = &mut self.profile {
            p.note_deliver(l, start, end);
        }
    }

    /// Adv* striped per-update broadcast initiation (modeled, not an
    /// event — recorded as an instant at the root tier).
    #[inline]
    pub fn advstar_broadcast(&mut self, now: f64) {
        if !self.active {
            return;
        }
        self.trace.instant("broadcast", trace::PID_SHARDS, 0, now);
        if let Some(m) = &mut self.metrics {
            m.count("broadcast");
        }
    }

    /// applyUpdate fired on every root shard (lockstep).
    #[inline]
    pub fn apply_update(&mut self, shards: usize, now: f64) {
        if !self.active {
            return;
        }
        for s in 0..shards {
            self.trace.instant("apply_update", trace::PID_SHARDS, s as u64, now);
        }
        if let Some(m) = &mut self.metrics {
            m.count("apply_update");
        }
    }

    /// Periodic checkpoint capture.
    #[inline]
    pub fn checkpoint(&mut self, now: f64) {
        if !self.active {
            return;
        }
        self.trace.instant("checkpoint", trace::PID_SHARDS, 0, now);
        if let Some(m) = &mut self.metrics {
            m.count("checkpoint");
        }
    }

    /// A learner's gradient reached the barrier (starts its wait).
    #[inline]
    pub fn barrier_enter(&mut self, l: usize, now: f64) {
        if !self.active {
            return;
        }
        if let Some(e) = self.barrier_entered.get_mut(l) {
            *e = now;
        }
        if let Some(p) = &mut self.profile {
            p.barrier_enter(l, now);
        }
    }

    /// The closing broadcast released learner `l` from the barrier.
    #[inline]
    pub fn barrier_release(&mut self, l: usize, now: f64) {
        if !self.active {
            return;
        }
        let entered = self.barrier_entered.get(l).copied().unwrap_or(now);
        self.trace.span("barrier_wait", trace::PID_LEARNERS, l as u64, entered, now);
        if self.metrics.is_some() {
            self.round_waits.push((now - entered).max(0.0));
        }
        if let Some(s) = &mut self.series {
            s.note_barrier_wait(now - entered);
        }
        if let Some(p) = &mut self.profile {
            p.barrier_leave(l, now);
        }
    }

    /// All releases for the current round are in; fold them into the
    /// per-round barrier histogram.
    #[inline]
    pub fn barrier_round_end(&mut self) {
        if !self.active {
            return;
        }
        if let Some(m) = &mut self.metrics {
            m.barrier_round(&self.round_waits);
        }
        self.round_waits.clear();
    }

    /// A delivery was preceded by `n` retransmission attempts (fault
    /// plane; recorded at the moment the send chain was planned).
    #[inline]
    pub fn fault_retransmit(&mut self, l: usize, now: f64, n: u64) {
        if !self.active || n == 0 {
            return;
        }
        self.trace.instant("retransmit", trace::PID_LEARNERS, l as u64, now);
        if let Some(m) = &mut self.metrics {
            m.count_n("fault_retransmit", n);
        }
    }

    /// A message (and its whole retry chain) was lost: the sender gave
    /// the peer up at `now`.
    #[inline]
    pub fn fault_drop(&mut self, l: usize, now: f64) {
        if !self.active {
            return;
        }
        self.trace.instant("fault_drop", trace::PID_LEARNERS, l as u64, now);
        if let Some(m) = &mut self.metrics {
            m.count("fault_drop");
        }
    }

    /// A receiver dedup window rejected a duplicated/retried delivery.
    #[inline]
    pub fn fault_dedup(&mut self, l: usize, now: f64) {
        if !self.active {
            return;
        }
        self.trace.instant("dedup_drop", trace::PID_LEARNERS, l as u64, now);
        if let Some(m) = &mut self.metrics {
            m.count("fault_dedup_drop");
        }
    }

    /// Retry exhaustion handed learner `l` to the membership eviction
    /// path.
    #[inline]
    pub fn fault_evict(&mut self, l: usize, now: f64) {
        if !self.active {
            return;
        }
        self.trace.instant("fault_evict", trace::PID_LEARNERS, l as u64, now);
        if let Some(m) = &mut self.metrics {
            m.count("fault_evict");
        }
    }

    /// A partition window closed (heal event processed).
    #[inline]
    pub fn fault_heal(&mut self, now: f64) {
        if !self.active {
            return;
        }
        self.trace.instant("partition_heal", trace::PID_SHARDS, 0, now);
        if let Some(m) = &mut self.metrics {
            m.count("partition_heal");
        }
    }

    /// Whether the critical-path profiler is armed (gates the engine
    /// sites that exist only for profiling, like the per-gradient relay
    /// association loop).
    #[inline]
    pub fn profile_enabled(&self) -> bool {
        self.profile.is_some()
    }

    /// Associate a relay hop with the learner whose gradient it carries
    /// (the [`Obs::relay`] span is keyed by leaf, not learner).
    #[inline]
    pub fn profile_relay(&mut self, l: usize, start: f64, end: f64) {
        if let Some(p) = &mut self.profile {
            p.note_relay(l, start, end);
        }
    }

    /// A weight update committed at `now`, triggered by learner `by`
    /// (None for membership-change flushes).
    #[inline]
    pub fn profile_commit(&mut self, by: Option<usize>, now: f64) {
        if let Some(p) = &mut self.profile {
            p.commit(by, now);
        }
    }

    /// Epoch boundary crossed (records the per-epoch category delta).
    #[inline]
    pub fn profile_epoch(&mut self, epoch: u64) {
        if let Some(p) = &mut self.profile {
            p.epoch(epoch);
        }
    }

    /// A parked learner was killed: its barrier occupancy ends without a
    /// release, so the profiler must not count it parked forever.
    #[inline]
    pub fn barrier_abandon(&mut self, l: usize, now: f64) {
        if let Some(p) = &mut self.profile {
            p.barrier_leave(l, now);
        }
    }

    /// End of run: attribute the tail past the last commit and record
    /// per-shard ingress busy seconds. Call before
    /// [`Obs::metrics_snapshot`].
    pub fn profile_finish(&mut self, now: f64, shard_busy: Vec<f64>) {
        if let Some(p) = &mut self.profile {
            p.finish(now, shard_busy);
        }
    }

    /// Event-queue depth gauge (called per loop iteration; a no-op
    /// unless metrics are on).
    #[inline]
    pub fn queue_depth(&mut self, depth: usize) {
        if let Some(m) = &mut self.metrics {
            m.gauge_queue_depth(depth as u64);
        }
    }

    /// Whether the time-series recorder is armed (gates the per-event
    /// sampling site: assembling [`SeriesInputs`] costs a few reads, so
    /// quiet runs skip even that).
    #[inline]
    pub fn series_enabled(&self) -> bool {
        self.series.is_some()
    }

    /// Per-event series sampling site (no-op between window boundaries).
    #[inline]
    pub fn series_tick(&mut self, now: f64, inputs: &SeriesInputs) {
        if let Some(s) = &mut self.series {
            s.maybe_sample(now, inputs);
        }
    }

    /// A minibatch training-loss observation for the open window.
    #[inline]
    pub fn series_loss(&mut self, loss: f64) {
        if let Some(s) = &mut self.series {
            s.note_loss(loss);
        }
    }

    /// Epoch boundary crossed (event-aligned sub-series).
    #[inline]
    pub fn series_epoch(&mut self, now: f64, epoch: u64, train_loss: f64, test_error_pct: f64) {
        if let Some(s) = &mut self.series {
            s.note_epoch(now, epoch, train_loss, test_error_pct);
        }
    }

    /// Adaptive-n retune decision (event-aligned sub-series).
    #[inline]
    pub fn series_adaptive(&mut self, now: f64, n: u64) {
        if let Some(s) = &mut self.series {
            s.note_adaptive(now, n);
        }
    }

    /// Final sample at end of run, so runs shorter than one window still
    /// register a point. Call before [`Obs::metrics_snapshot`].
    pub fn series_finish(&mut self, now: f64, inputs: &SeriesInputs) {
        if let Some(s) = &mut self.series {
            s.final_flush(now, inputs);
        }
    }

    /// Snapshot the metrics (if collecting) with the server-side
    /// distributions folded in and the recorded series (if any) attached
    /// under `"series"`.
    pub fn metrics_snapshot(
        &self,
        staleness: &crate::coordinator::clock::StalenessStats,
        shard_updates: &[u64],
        pushes_by_learner: &[u64],
        root_bytes_in: f64,
        root_bytes_out: f64,
    ) -> Option<crate::util::json::Json> {
        self.metrics.as_ref().map(|m| {
            let mut snap = m.snapshot(
                staleness,
                shard_updates,
                pushes_by_learner,
                root_bytes_in,
                root_bytes_out,
            );
            if let Some(s) = &self.series {
                metrics::attach_series(&mut snap, s.to_json());
            }
            if let Some(p) = &self.profile {
                metrics::attach_profile(&mut snap, p.to_json());
            }
            snap
        })
    }

    /// Take the recorded trace (None when tracing was off).
    pub fn take_trace(&mut self) -> Option<Vec<TraceEvent>> {
        self.trace.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_is_inert() {
        let mut obs = Obs::off();
        obs.compute(0, 0.0, 1.0);
        obs.barrier_enter(0, 1.0);
        obs.barrier_release(0, 2.0);
        obs.barrier_round_end();
        obs.queue_depth(100);
        assert!(!obs.active());
        assert!(obs.take_trace().is_none());
        assert!(obs.metrics().is_none());
    }

    #[test]
    fn barrier_waits_span_entry_to_release() {
        let mut obs = Obs::new(true, true, None, false, 2);
        obs.barrier_enter(0, 1.0);
        obs.barrier_enter(1, 3.0);
        obs.barrier_release(0, 4.0);
        obs.barrier_release(1, 4.0);
        obs.barrier_round_end();
        let trace = obs.take_trace().unwrap();
        assert_eq!(trace.len(), 2);
        assert_eq!(trace[0].name, "barrier_wait");
        assert_eq!(trace[0].dur_us, 3.0e6);
        assert_eq!(trace[1].dur_us, 1.0e6);
        let snap = obs
            .metrics_snapshot(&Default::default(), &[], &[], 0.0, 0.0)
            .expect("metrics were on");
        let barrier = snap.get("barrier").unwrap();
        assert_eq!(barrier.get("rounds").unwrap().as_u64().unwrap(), 1);
        assert_eq!(barrier.get("wait_secs_max").unwrap().as_f64().unwrap(), 3.0);
    }

    #[test]
    fn trace_only_still_skips_metrics() {
        let mut obs = Obs::new(true, false, None, false, 1);
        obs.compute(0, 0.0, 0.5);
        assert!(obs.metrics_snapshot(&Default::default(), &[], &[], 0.0, 0.0).is_none());
        assert_eq!(obs.take_trace().unwrap().len(), 1);
    }

    #[test]
    fn metrics_every_arms_the_registry_and_attaches_series() {
        let mut obs = Obs::new(false, false, Some(1.0), false, 2);
        assert!(obs.active() && obs.series_enabled());
        let inputs = SeriesInputs {
            queue_depth: 5,
            active_lambda: 2,
            stale_count: 3,
            stale_sum: 6.0,
            stale_max: 4,
            bytes_in: 50.0,
        };
        obs.series_tick(0.5, &inputs); // below the first boundary
        obs.series_tick(1.5, &inputs);
        obs.series_epoch(1.5, 1, 0.8, f64::NAN);
        obs.series_finish(2.0, &inputs);
        let snap = obs
            .metrics_snapshot(&Default::default(), &[], &[], 50.0, 0.0)
            .expect("metrics_every alone must arm the registry");
        let series = snap.get("series").unwrap();
        assert_eq!(series.get("t").unwrap().as_f64_vec().unwrap(), vec![1.5, 2.0]);
        assert_eq!(
            series.get("epoch").unwrap().get("epoch").unwrap().as_u64_vec().unwrap(),
            vec![1]
        );
        assert_eq!(series.get("mean_staleness").unwrap().as_f64_vec().unwrap()[0], 2.0);
    }

    #[test]
    fn profile_alone_arms_the_registry_and_attaches_the_profile() {
        let mut obs = Obs::new(false, false, None, true, 2);
        assert!(obs.active() && obs.profile_enabled());
        obs.compute(0, 0.0, 2.0);
        obs.push(0, 2.0, 3.0);
        obs.profile_commit(Some(0), 3.0);
        obs.profile_epoch(1);
        obs.profile_finish(3.5, vec![0.25]);
        let snap = obs
            .metrics_snapshot(&Default::default(), &[], &[], 0.0, 0.0)
            .expect("profile alone must arm the registry");
        let p = snap.get("profile").unwrap();
        assert_eq!(p.get("mode").unwrap().as_str().unwrap(), "critical_path");
        let total = p.get("total_secs").unwrap().as_f64().unwrap();
        let cats = p.get("categories").unwrap();
        let sum: f64 = profile::CATEGORY_NAMES
            .iter()
            .map(|&n| cats.get(n).unwrap().as_f64().unwrap())
            .sum();
        assert!((sum - total).abs() < 1e-9, "partition must be exact: {sum} vs {total}");
        assert!(obs.take_trace().is_none(), "profile must not arm tracing");
    }
}
