//! Windowed time-series telemetry (`--metrics-every SECS`).
//!
//! The end-of-run metrics snapshot ([`super::metrics`]) answers "what did
//! the run total up to"; the paper's argument is about *trajectories* —
//! the staleness distribution drifting over epochs, the μ·λ rescaler
//! reacting to churn, the queue filling behind a straggler. The
//! [`SeriesRecorder`] samples those quantities every `every` seconds of
//! engine time (virtual seconds in the sim engines, wall seconds in the
//! live engine) into parallel arrays serialized under the `"series"` key
//! of the metrics snapshot.
//!
//! Purely observational, like every other obs layer: sampling reads
//! engine state the engine computed anyway, draws from no RNG, and the
//! off default ([`None`] recorder) costs one branch per event.

use crate::util::json::Json;

/// The gauges sampled at each window boundary. The engine fills this from
/// state it already tracks; the recorder differentiates the monotone
/// totals (`stale_count`/`stale_sum`/`bytes_in`) into per-window rates.
#[derive(Debug, Clone, Copy, Default)]
pub struct SeriesInputs {
    /// Pending event-queue depth (0 in the live engine — an OS channel
    /// has no observable depth).
    pub queue_depth: u64,
    /// Live learner count (λ_active).
    pub active_lambda: u64,
    /// Cumulative staleness observation count.
    pub stale_count: u64,
    /// Cumulative staleness sum.
    pub stale_sum: f64,
    /// Running maximum staleness.
    pub stale_max: u64,
    /// Cumulative bytes delivered into the root tier.
    pub bytes_in: f64,
}

/// Accumulates windowed samples over engine time. Create with
/// [`SeriesRecorder::new`], feed [`SeriesRecorder::maybe_sample`] from a
/// per-event (or per-loop) site, and call [`SeriesRecorder::final_flush`]
/// before snapshotting so even a run shorter than one window gets a
/// sample.
#[derive(Debug, Clone)]
pub struct SeriesRecorder {
    every: f64,
    next_at: f64,
    // Window-boundary state for differencing the monotone inputs.
    last_count: u64,
    last_sum: f64,
    last_bytes: f64,
    last_t: f64,
    // In-window accumulators fed by dedicated note_* hooks.
    win_barrier_sum: f64,
    win_barrier_n: u64,
    win_loss_sum: f64,
    win_loss_n: u64,
    // The series proper.
    t: Vec<f64>,
    mean_staleness: Vec<f64>,
    max_staleness: Vec<u64>,
    queue_depth: Vec<u64>,
    active_lambda: Vec<u64>,
    bytes_per_sec: Vec<f64>,
    barrier_wait_mean: Vec<f64>,
    loss_mean: Vec<f64>,
    // Event-aligned sub-series (epoch boundaries, adaptive-n decisions).
    epoch_t: Vec<f64>,
    epoch_no: Vec<u64>,
    epoch_train_loss: Vec<f64>,
    epoch_test_error: Vec<f64>,
    adaptive_t: Vec<f64>,
    adaptive_n: Vec<u64>,
}

impl SeriesRecorder {
    /// `every` must be finite and positive (config validation enforces
    /// this before an engine is built).
    pub fn new(every: f64) -> SeriesRecorder {
        SeriesRecorder {
            every,
            next_at: every,
            last_count: 0,
            last_sum: 0.0,
            last_bytes: 0.0,
            last_t: 0.0,
            win_barrier_sum: 0.0,
            win_barrier_n: 0,
            win_loss_sum: 0.0,
            win_loss_n: 0,
            t: Vec::new(),
            mean_staleness: Vec::new(),
            max_staleness: Vec::new(),
            queue_depth: Vec::new(),
            active_lambda: Vec::new(),
            bytes_per_sec: Vec::new(),
            barrier_wait_mean: Vec::new(),
            loss_mean: Vec::new(),
            epoch_t: Vec::new(),
            epoch_no: Vec::new(),
            epoch_train_loss: Vec::new(),
            epoch_test_error: Vec::new(),
            adaptive_t: Vec::new(),
            adaptive_n: Vec::new(),
        }
    }

    /// Sample if `now` crossed the current window boundary. Samples land
    /// at the *actual* event times that crossed the boundary (event time
    /// is discrete; the next window opens relative to `now`, so an idle
    /// stretch yields no empty samples).
    #[inline]
    pub fn maybe_sample(&mut self, now: f64, inputs: &SeriesInputs) {
        if now < self.next_at {
            return;
        }
        self.sample(now, inputs);
        self.next_at = now + self.every;
    }

    /// One last sample at end of run, so short runs (or the tail window)
    /// still register. Skipped if nothing advanced since the last sample.
    pub fn final_flush(&mut self, now: f64, inputs: &SeriesInputs) {
        if now > self.last_t || self.t.is_empty() {
            self.sample(now, inputs);
        }
    }

    fn sample(&mut self, now: f64, inputs: &SeriesInputs) {
        let d_count = inputs.stale_count.saturating_sub(self.last_count);
        let d_sum = inputs.stale_sum - self.last_sum;
        let d_bytes = inputs.bytes_in - self.last_bytes;
        let d_t = now - self.last_t;
        self.t.push(now);
        // Windowed mean staleness: NaN when no updates landed in the
        // window (serialized as null — see to_json).
        self.mean_staleness
            .push(if d_count > 0 { d_sum / d_count as f64 } else { f64::NAN });
        self.max_staleness.push(inputs.stale_max);
        self.queue_depth.push(inputs.queue_depth);
        self.active_lambda.push(inputs.active_lambda);
        self.bytes_per_sec.push(if d_t > 0.0 { d_bytes / d_t } else { f64::NAN });
        self.barrier_wait_mean.push(if self.win_barrier_n > 0 {
            self.win_barrier_sum / self.win_barrier_n as f64
        } else {
            f64::NAN
        });
        self.loss_mean.push(if self.win_loss_n > 0 {
            self.win_loss_sum / self.win_loss_n as f64
        } else {
            f64::NAN
        });
        self.last_count = inputs.stale_count;
        self.last_sum = inputs.stale_sum;
        self.last_bytes = inputs.bytes_in;
        self.last_t = now;
        self.win_barrier_sum = 0.0;
        self.win_barrier_n = 0;
        self.win_loss_sum = 0.0;
        self.win_loss_n = 0;
    }

    /// A barrier release happened; fold the wait into the open window.
    #[inline]
    pub fn note_barrier_wait(&mut self, wait: f64) {
        self.win_barrier_sum += wait.max(0.0);
        self.win_barrier_n += 1;
    }

    /// A training loss observation (per minibatch) for the open window.
    #[inline]
    pub fn note_loss(&mut self, loss: f64) {
        if loss.is_finite() {
            self.win_loss_sum += loss;
            self.win_loss_n += 1;
        }
    }

    /// Epoch boundary crossed (event-aligned sub-series).
    #[inline]
    pub fn note_epoch(&mut self, now: f64, epoch: u64, train_loss: f64, test_error_pct: f64) {
        self.epoch_t.push(now);
        self.epoch_no.push(epoch);
        self.epoch_train_loss.push(train_loss);
        self.epoch_test_error.push(test_error_pct);
    }

    /// The adaptive-n controller retuned the splitting parameter.
    #[inline]
    pub fn note_adaptive(&mut self, now: f64, n: u64) {
        self.adaptive_t.push(now);
        self.adaptive_n.push(n);
    }

    /// Number of window samples taken so far.
    pub fn len(&self) -> usize {
        self.t.len()
    }

    pub fn is_empty(&self) -> bool {
        self.t.is_empty()
    }

    /// Serialize. Non-finite values (empty-window means) become `null`:
    /// the hand-rolled writer would print `NaN` bare, which is not JSON.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("every_secs", Json::num(self.every)),
            ("t", Json::arr_f64(&self.t)),
            ("mean_staleness", arr_or_null(&self.mean_staleness)),
            ("max_staleness", Json::arr_u64(&self.max_staleness)),
            ("queue_depth", Json::arr_u64(&self.queue_depth)),
            ("active_lambda", Json::arr_u64(&self.active_lambda)),
            ("bytes_per_sec", arr_or_null(&self.bytes_per_sec)),
            ("barrier_wait_mean", arr_or_null(&self.barrier_wait_mean)),
            ("loss_mean", arr_or_null(&self.loss_mean)),
            (
                "epoch",
                Json::obj(vec![
                    ("t", Json::arr_f64(&self.epoch_t)),
                    ("epoch", Json::arr_u64(&self.epoch_no)),
                    ("train_loss", arr_or_null(&self.epoch_train_loss)),
                    ("test_error_pct", arr_or_null(&self.epoch_test_error)),
                ]),
            ),
            (
                "adaptive_n",
                Json::obj(vec![
                    ("t", Json::arr_f64(&self.adaptive_t)),
                    ("n", Json::arr_u64(&self.adaptive_n)),
                ]),
            ),
        ])
    }
}

/// f64 array with non-finite entries mapped to `null`.
fn arr_or_null(xs: &[f64]) -> Json {
    Json::Arr(
        xs.iter()
            .map(|&x| if x.is_finite() { Json::Num(x) } else { Json::Null })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs(count: u64, sum: f64, bytes: f64) -> SeriesInputs {
        SeriesInputs {
            queue_depth: 3,
            active_lambda: 4,
            stale_count: count,
            stale_sum: sum,
            stale_max: 7,
            bytes_in: bytes,
        }
    }

    #[test]
    fn windows_difference_the_monotone_totals() {
        let mut s = SeriesRecorder::new(1.0);
        s.maybe_sample(0.5, &inputs(1, 2.0, 10.0)); // below boundary: no sample
        assert_eq!(s.len(), 0);
        s.maybe_sample(1.25, &inputs(4, 10.0, 100.0));
        s.maybe_sample(2.5, &inputs(10, 40.0, 300.0));
        assert_eq!(s.len(), 2);
        let j = s.to_json();
        let means = j.get("mean_staleness").unwrap().as_f64_vec().unwrap();
        // Window 1: 10/4 = 2.5; window 2: (40-10)/(10-4) = 5.
        assert!((means[0] - 2.5).abs() < 1e-12);
        assert!((means[1] - 5.0).abs() < 1e-12);
        let bps = j.get("bytes_per_sec").unwrap().as_f64_vec().unwrap();
        assert!((bps[0] - 100.0 / 1.25).abs() < 1e-9);
        assert!((bps[1] - 200.0 / 1.25).abs() < 1e-9);
    }

    #[test]
    fn empty_windows_serialize_null_not_nan() {
        let mut s = SeriesRecorder::new(1.0);
        s.maybe_sample(1.5, &inputs(0, 0.0, 0.0));
        let text = s.to_json().to_string();
        assert!(!text.contains("NaN"), "bare NaN is not JSON: {text}");
        assert!(text.contains("null"));
        // And it must re-parse.
        Json::parse(&text).unwrap();
    }

    #[test]
    fn final_flush_gives_short_runs_a_sample() {
        let mut s = SeriesRecorder::new(1e9);
        s.note_loss(2.0);
        s.note_loss(4.0);
        s.note_barrier_wait(0.5);
        s.final_flush(0.01, &inputs(2, 3.0, 8.0));
        assert_eq!(s.len(), 1);
        let j = s.to_json();
        assert_eq!(j.get("loss_mean").unwrap().as_f64_vec().unwrap()[0], 3.0);
        assert_eq!(j.get("barrier_wait_mean").unwrap().as_f64_vec().unwrap()[0], 0.5);
        // Flushing again without progress adds nothing.
        s.final_flush(0.01, &inputs(2, 3.0, 8.0));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn event_subseries_record_epochs_and_adaptive_n() {
        let mut s = SeriesRecorder::new(10.0);
        s.note_epoch(3.0, 1, 0.9, f64::NAN);
        s.note_epoch(6.0, 2, 0.7, 12.5);
        s.note_adaptive(6.0, 4);
        let j = s.to_json();
        let ep = j.get("epoch").unwrap();
        assert_eq!(ep.get("epoch").unwrap().as_u64_vec().unwrap(), vec![1, 2]);
        assert_eq!(ep.get("test_error_pct").unwrap().as_arr().unwrap()[0], Json::Null);
        let ad = j.get("adaptive_n").unwrap();
        assert_eq!(ad.get("n").unwrap().as_u64_vec().unwrap(), vec![4]);
        Json::parse(&j.to_string()).unwrap();
    }
}
