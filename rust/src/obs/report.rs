//! `rudra report`: render the run index into one self-contained HTML
//! dashboard.
//!
//! Dependency-free on both ends — the input is `runs.jsonl` (+ optional
//! `BENCH_hotpath.json` baselines) parsed with the in-tree JSON reader,
//! and the output is a single HTML file with inline CSS and inline-SVG
//! plots, so it opens anywhere a browser exists (CI artifact viewers
//! included) with no JS, no CDN, no image files.
//!
//! Panels: the runs table, the paper's μ·λ-vs-error scatter (the
//! tradeoff frontier at a glance), per-run staleness histograms, per-run
//! time-series sparklines when `--metrics-every` was on, per-run stacked
//! attribution bars + what-if projections when `--profile` was on, and
//! the `bench-diff` events/sec ladder when baselines are supplied.

use crate::stats::finite_min_max;
use crate::util::json::Json;

use super::runindex::RunRecord;

/// Escape text destined for an HTML context.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '&' => out.push_str("&amp;"),
            '<' => out.push_str("&lt;"),
            '>' => out.push_str("&gt;"),
            '"' => out.push_str("&quot;"),
            '\'' => out.push_str("&#39;"),
            c => out.push(c),
        }
    }
    out
}

fn fmt_opt(v: Option<f64>) -> String {
    match v {
        Some(x) if x.is_finite() => format!("{x:.2}"),
        _ => "–".to_string(),
    }
}

/// Map data coordinates into an SVG viewport with padding.
struct Scale {
    x0: f64,
    x1: f64,
    y0: f64,
    y1: f64,
    w: f64,
    h: f64,
    pad: f64,
}

impl Scale {
    fn new(xr: (f64, f64), yr: (f64, f64), w: f64, h: f64) -> Scale {
        // Degenerate ranges (single point) get a unit span so division
        // stays finite and the point lands mid-axis.
        let widen = |(lo, hi): (f64, f64)| if hi > lo { (lo, hi) } else { (lo - 0.5, lo + 0.5) };
        let (x0, x1) = widen(xr);
        let (y0, y1) = widen(yr);
        Scale { x0, x1, y0, y1, w, h, pad: 34.0 }
    }

    fn x(&self, v: f64) -> f64 {
        self.pad + (v - self.x0) / (self.x1 - self.x0) * (self.w - 2.0 * self.pad)
    }

    /// SVG y grows downward; data y grows upward.
    fn y(&self, v: f64) -> f64 {
        self.h - self.pad - (v - self.y0) / (self.y1 - self.y0) * (self.h - 2.0 * self.pad)
    }
}

/// The μ·λ-vs-test-error scatter (numeric runs only).
fn scatter_mu_lambda(records: &[RunRecord]) -> String {
    let pts: Vec<(f64, f64, &RunRecord)> = records
        .iter()
        .filter_map(|r| {
            let err = r.test_error_pct.filter(|e| e.is_finite())?;
            Some(((r.mu * r.lambda) as f64, err, r))
        })
        .collect();
    if pts.is_empty() {
        return "<p class=\"note\">No numeric runs with a final test error — \
                run <code>rudra sim</code> points with <code>--run-index</code> \
                to populate this panel.</p>"
            .to_string();
    }
    let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
    let (w, h) = (560.0, 300.0);
    let sc = Scale::new(
        finite_min_max(&xs).unwrap_or((0.0, 1.0)),
        finite_min_max(&ys).unwrap_or((0.0, 1.0)),
        w,
        h,
    );
    let mut svg = svg_open(w, h);
    svg.push_str(&axes(&sc, "μ·λ", "test error %"));
    for (x, y, r) in &pts {
        svg.push_str(&format!(
            "<circle cx=\"{:.1}\" cy=\"{:.1}\" r=\"4\" class=\"pt\">\
             <title>{} (seed {}): μ·λ={} err={:.2}%</title></circle>",
            sc.x(*x),
            sc.y(*y),
            esc(&r.label),
            r.seed,
            *x as u64,
            y
        ));
    }
    svg.push_str("</svg>");
    svg
}

fn svg_open(w: f64, h: f64) -> String {
    format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{w}\" height=\"{h}\" \
         viewBox=\"0 0 {w} {h}\">"
    )
}

fn axes(sc: &Scale, xlabel: &str, ylabel: &str) -> String {
    format!(
        "<line x1=\"{p}\" y1=\"{yb}\" x2=\"{xe}\" y2=\"{yb}\" class=\"axis\"/>\
         <line x1=\"{p}\" y1=\"{p}\" x2=\"{p}\" y2=\"{yb}\" class=\"axis\"/>\
         <text x=\"{xm}\" y=\"{ybl}\" class=\"lbl\">{xl}</text>\
         <text x=\"10\" y=\"{ym}\" class=\"lbl\" transform=\"rotate(-90 10 {ym})\">{yl}</text>\
         <text x=\"{p}\" y=\"{ybl}\" class=\"tick\">{x0}</text>\
         <text x=\"{xe}\" y=\"{ybl}\" class=\"tick\" text-anchor=\"end\">{x1}</text>\
         <text x=\"{pl}\" y=\"{yb}\" class=\"tick\" text-anchor=\"end\">{y0}</text>\
         <text x=\"{pl}\" y=\"{pt}\" class=\"tick\" text-anchor=\"end\">{y1}</text>",
        p = sc.pad,
        pl = sc.pad - 4.0,
        pt = sc.pad + 4.0,
        yb = sc.h - sc.pad,
        ybl = sc.h - sc.pad + 16.0,
        xe = sc.w - sc.pad,
        xm = sc.w / 2.0,
        ym = sc.h / 2.0,
        xl = esc(xlabel),
        yl = esc(ylabel),
        x0 = trim_num(sc.x0),
        x1 = trim_num(sc.x1),
        y0 = trim_num(sc.y0),
        y1 = trim_num(sc.y1),
    )
}

fn trim_num(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.3}")
    }
}

/// Pull an f64 series out of a JSON array that may contain nulls (empty
/// sample windows serialize as `null`); nulls become NaN and are skipped
/// at plot time.
fn f64_series(v: &Json) -> Vec<f64> {
    match v {
        Json::Arr(xs) => xs.iter().map(|x| x.as_f64().unwrap_or(f64::NAN)).collect(),
        _ => Vec::new(),
    }
}

/// A small inline sparkline of `ys` over `t` (NaN gaps break the line).
fn sparkline(t: &[f64], ys: &[f64], label: &str) -> String {
    let finite: Vec<f64> = ys.iter().copied().filter(|y| y.is_finite()).collect();
    let (Some(xr), Some(yr)) = (finite_min_max(t), finite_min_max(&finite)) else {
        return String::new();
    };
    let (w, h) = (180.0, 44.0);
    let sc = Scale { x0: xr.0, x1: xr.1.max(xr.0 + 1e-12), y0: yr.0, y1: yr.1, w, h, pad: 3.0 };
    // Degenerate y-range: flat line mid-panel.
    let ymid = h / 2.0;
    let flat = yr.0 == yr.1;
    let mut segs: Vec<Vec<(f64, f64)>> = vec![Vec::new()];
    for (x, y) in t.iter().zip(ys.iter()) {
        if y.is_finite() {
            let py = if flat { ymid } else { sc.y(*y) };
            segs.last_mut().unwrap().push((sc.x(*x), py));
        } else if !segs.last().unwrap().is_empty() {
            segs.push(Vec::new());
        }
    }
    let mut svg = svg_open(w, h);
    for seg in segs.iter().filter(|s| !s.is_empty()) {
        let pts: Vec<String> = seg.iter().map(|(x, y)| format!("{x:.1},{y:.1}")).collect();
        if seg.len() == 1 {
            svg.push_str(&format!(
                "<circle cx=\"{:.1}\" cy=\"{:.1}\" r=\"2\" class=\"pt\"/>",
                seg[0].0, seg[0].1
            ));
        } else {
            svg.push_str(&format!("<polyline points=\"{}\" class=\"spark\"/>", pts.join(" ")));
        }
    }
    svg.push_str("</svg>");
    format!(
        "<div class=\"spark-cell\"><div class=\"spark-label\">{} \
         <span class=\"tick\">[{} … {}]</span></div>{}</div>",
        esc(label),
        trim_num(yr.0),
        trim_num(yr.1),
        svg
    )
}

/// Per-run series panel (only for records whose metrics carry `series`).
fn series_panel(r: &RunRecord, idx: usize) -> Option<String> {
    let series = r.metrics.as_ref()?.opt("series")?;
    let t = f64_series(series.opt("t")?);
    if t.is_empty() {
        return None;
    }
    let mut cells = String::new();
    for (key, label) in [
        ("mean_staleness", "mean staleness"),
        ("max_staleness", "max staleness"),
        ("queue_depth", "queue depth"),
        ("active_lambda", "active λ"),
        ("bytes_per_sec", "root bytes/s"),
        ("barrier_wait_mean", "barrier wait (s)"),
        ("loss_mean", "train loss"),
    ] {
        if let Some(v) = series.opt(key) {
            cells.push_str(&sparkline(&t, &f64_series(v), label));
        }
    }
    if let Some(ep) = series.opt("epoch") {
        let et = f64_series(ep.opt("t")?);
        if !et.is_empty() {
            if let Some(v) = ep.opt("train_loss") {
                cells.push_str(&sparkline(&et, &f64_series(v), "epoch train loss"));
            }
            if let Some(v) = ep.opt("test_error_pct") {
                cells.push_str(&sparkline(&et, &f64_series(v), "epoch test error %"));
            }
        }
    }
    if let Some(ad) = series.opt("adaptive_n") {
        if let (Some(at), Some(an)) = (ad.opt("t"), ad.opt("n")) {
            let at = f64_series(at);
            if !at.is_empty() {
                cells.push_str(&sparkline(&at, &f64_series(an), "adaptive n"));
            }
        }
    }
    if cells.is_empty() {
        return None;
    }
    Some(format!(
        "<div class=\"run-series\"><h3>#{idx} {} <span class=\"tick\">seed {}</span></h3>\
         <div class=\"spark-row\">{cells}</div></div>",
        esc(&r.label),
        r.seed
    ))
}

/// Per-run stacked attribution bar + what-ifs (only for records whose
/// metrics carry `profile`, i.e. runs made with `--profile`).
fn profile_panel(r: &RunRecord, idx: usize) -> Option<String> {
    let profile = r.metrics.as_ref()?.opt("profile")?;
    let total = profile.opt("total_secs").and_then(|v| v.as_f64().ok())?;
    if !(total > 0.0) {
        return None;
    }
    let rows = super::profile::category_rows(profile);
    let mode = profile.opt("mode").and_then(|v| v.as_str().ok()).unwrap_or("critical_path");
    let timebase = profile.opt("timebase").and_then(|v| v.as_str().ok()).unwrap_or("sim");
    let (w, h) = (560.0, 22.0);
    let mut svg = svg_open(w, h);
    let mut x = 0.0;
    let mut legend = String::new();
    for (i, (name, secs)) in rows.iter().enumerate() {
        let frac = (secs / total).clamp(0.0, 1.0);
        let bw = frac * w;
        if bw > 0.05 {
            svg.push_str(&format!(
                "<rect x=\"{x:.1}\" y=\"0\" width=\"{bw:.1}\" height=\"{h}\" class=\"cat{i}\">\
                 <title>{}: {secs:.4}s ({:.1}%)</title></rect>",
                esc(name),
                frac * 100.0
            ));
            x += bw;
        }
        if *secs > 0.0 {
            legend.push_str(&format!(
                "<span class=\"chip\"><span class=\"swatch cat{i}\"></span>{} {:.1}%</span>",
                esc(name),
                frac * 100.0
            ));
        }
    }
    svg.push_str("</svg>");
    let mut whatifs = String::new();
    if let Some(w) = profile.opt("whatif") {
        for (key, label) in [
            ("zero_wire_secs", "zero wire cost"),
            ("zero_barrier_secs", "zero barrier wait"),
            ("balanced_learners_secs", "perfectly balanced learners"),
            ("fast_root_secs", "infinitely fast root"),
        ] {
            if let Some(secs) = w.opt(key).and_then(|v| v.as_f64().ok()) {
                let speedup = if secs > 0.0 { total / secs } else { f64::INFINITY };
                whatifs.push_str(&format!(
                    "<tr><td>{label}</td><td>{secs:.4}</td><td>{speedup:.2}×</td></tr>"
                ));
            }
        }
    }
    let whatif_table = if whatifs.is_empty() {
        String::new()
    } else {
        format!(
            "<table class=\"whatif\"><thead><tr><th>what-if</th><th>projected s</th>\
             <th>speedup</th></tr></thead><tbody>{whatifs}</tbody></table>"
        )
    };
    Some(format!(
        "<div class=\"run-profile\"><h3>#{idx} {} \
         <span class=\"tick\">{total:.4}s total · {} over {} time</span></h3>\
         {svg}<div class=\"chips\">{legend}</div>{whatif_table}</div>",
        esc(&r.label),
        esc(mode),
        esc(timebase),
    ))
}

/// Staleness histogram bars from a record's metrics snapshot.
fn staleness_panel(r: &RunRecord, idx: usize) -> Option<String> {
    let hist = r.metrics.as_ref()?.opt("staleness")?.opt("histogram")?;
    let counts: Vec<f64> = f64_series(hist);
    let total: f64 = counts.iter().sum();
    if total <= 0.0 {
        return None;
    }
    let (w, h) = (280.0, 90.0);
    let peak = counts.iter().cloned().fold(0.0_f64, f64::max);
    let bw = (w - 20.0) / counts.len() as f64;
    let mut svg = svg_open(w, h);
    for (i, &c) in counts.iter().enumerate() {
        let bh = if peak > 0.0 { c / peak * (h - 24.0) } else { 0.0 };
        svg.push_str(&format!(
            "<rect x=\"{:.1}\" y=\"{:.1}\" width=\"{:.1}\" height=\"{:.1}\" class=\"bar\">\
             <title>σ={i}: {c:.0}</title></rect>",
            10.0 + i as f64 * bw,
            h - 14.0 - bh,
            (bw - 1.0).max(0.5),
            bh
        ));
    }
    svg.push_str(&format!(
        "<text x=\"10\" y=\"{:.1}\" class=\"tick\">σ 0…{}</text></svg>",
        h - 2.0,
        counts.len() - 1
    ));
    Some(format!(
        "<div class=\"hist-cell\"><div class=\"spark-label\">#{idx} {} \
         <span class=\"tick\">⟨σ⟩={:.3}</span></div>{svg}</div>",
        esc(&r.label),
        r.avg_staleness
    ))
}

/// Bench events/sec ladder from `BENCH_hotpath.json` baselines.
fn bench_panel(benches: &[(String, Json)]) -> String {
    let mut rows = String::new();
    for (name, bench) in benches {
        let Some(Json::Arr(ladder)) = bench.opt("sim_engine") else { continue };
        for row in ladder {
            let (Ok(lambda), Ok(eps)) = (
                row.get("lambda").and_then(|v| v.as_u64()),
                row.get("events_per_sec").and_then(|v| v.as_f64()),
            ) else {
                continue;
            };
            rows.push_str(&format!(
                "<tr><td>{}</td><td>{lambda}</td><td>{eps:.3e}</td></tr>",
                esc(name)
            ));
        }
    }
    if rows.is_empty() {
        return String::new();
    }
    format!(
        "<h2>Sim-engine throughput (bench baselines)</h2>\
         <table><thead><tr><th>baseline</th><th>λ</th><th>events/s</th></tr></thead>\
         <tbody>{rows}</tbody></table>"
    )
}

const STYLE: &str = "\
 body{font:14px/1.5 system-ui,sans-serif;margin:24px auto;max-width:1080px;\
      color:#1a1a2e;background:#fafafa}\
 h1{font-size:20px} h2{font-size:16px;margin-top:28px} h3{font-size:14px;margin:10px 0 4px}\
 table{border-collapse:collapse;width:100%;font-size:12px;background:#fff}\
 th,td{border:1px solid #ddd;padding:3px 7px;text-align:right}\
 th:first-child,td:first-child,th:nth-child(2),td:nth-child(2),\
 th:nth-child(3),td:nth-child(3){text-align:left}\
 thead{background:#eef} .note{color:#666;font-style:italic}\
 .axis{stroke:#888;stroke-width:1} .lbl{font-size:11px;fill:#444;text-anchor:middle}\
 .tick{font-size:10px;fill:#888;font-style:normal}\
 .pt{fill:#3b6fd4;opacity:.75} .bar{fill:#3b6fd4;opacity:.75}\
 .spark{fill:none;stroke:#3b6fd4;stroke-width:1.4}\
 .spark-row{display:flex;flex-wrap:wrap;gap:10px}\
 .spark-cell,.hist-cell{background:#fff;border:1px solid #ddd;padding:6px}\
 .spark-label{font-size:11px;color:#444;margin-bottom:2px}\
 .run-profile{background:#fff;border:1px solid #ddd;padding:6px;margin:8px 0}\
 .chips{font-size:11px;color:#444;margin-top:4px}\
 .chip{margin-right:10px;white-space:nowrap}\
 .swatch{display:inline-block;width:9px;height:9px;margin-right:3px}\
 .whatif{width:auto;margin-top:6px}\
 .cat0{fill:#3b6fd4;background:#3b6fd4} .cat1{fill:#d47a3b;background:#d47a3b}\
 .cat2{fill:#d4b13b;background:#d4b13b} .cat3{fill:#c23b3b;background:#c23b3b}\
 .cat4{fill:#3bae8a;background:#3bae8a} .cat5{fill:#8a5fd4;background:#8a5fd4}\
 .cat6{fill:#999;background:#999}\
 svg{display:block}";

/// Render the full report. `source` names the index the records came
/// from (shown in the header); `benches` are (name, parsed JSON) pairs.
pub fn render(records: &[RunRecord], benches: &[(String, Json)], source: &str) -> String {
    let mut table_rows = String::new();
    for (i, r) in records.iter().enumerate() {
        let has_series =
            r.metrics.as_ref().and_then(|m| m.opt("series")).is_some();
        table_rows.push_str(&format!(
            "<tr><td>{i}</td><td>{}</td><td>{}</td><td>{}</td><td>{}</td><td>{}</td>\
             <td>{}</td><td>{}</td><td>{}</td><td>{:.3}</td><td>{}</td><td>{:.1}</td>\
             <td>{:.2}</td><td>{}</td><td>{}</td><td>{}</td></tr>",
            esc(&r.kind),
            esc(&r.label),
            r.seed,
            r.mu,
            r.lambda,
            r.shards,
            r.epochs,
            fmt_opt(r.test_error_pct),
            r.avg_staleness,
            r.max_staleness,
            r.sim_seconds,
            r.wall_seconds,
            r.updates,
            r.events,
            if has_series { "✓" } else { "" },
        ));
    }
    let series_panels: String =
        records.iter().enumerate().filter_map(|(i, r)| series_panel(r, i)).collect();
    let hist_panels: String =
        records.iter().enumerate().filter_map(|(i, r)| staleness_panel(r, i)).collect();
    let profile_panels: String =
        records.iter().enumerate().filter_map(|(i, r)| profile_panel(r, i)).collect();
    format!(
        "<!DOCTYPE html><html><head><meta charset=\"utf-8\">\
         <title>rudra report</title><style>{STYLE}</style></head><body>\
         <h1>rudra report</h1>\
         <p class=\"note\">{} record{} from <code>{}</code></p>\
         <h2>Runs</h2>\
         <table><thead><tr><th>#</th><th>kind</th><th>label</th><th>seed</th>\
         <th>μ</th><th>λ</th><th>S</th><th>epochs</th><th>err%</th><th>⟨σ⟩</th>\
         <th>σ max</th><th>sim s</th><th>wall s</th><th>updates</th><th>events</th>\
         <th>series</th></tr></thead><tbody>{table_rows}</tbody></table>\
         <h2>μ·λ vs test error</h2>{}\
         {}{}{}{}\
         </body></html>",
        records.len(),
        if records.len() == 1 { "" } else { "s" },
        esc(source),
        scatter_mu_lambda(records),
        if hist_panels.is_empty() {
            String::new()
        } else {
            format!("<h2>Staleness histograms</h2><div class=\"spark-row\">{hist_panels}</div>")
        },
        if series_panels.is_empty() {
            String::new()
        } else {
            format!("<h2>Time series (--metrics-every)</h2>{series_panels}")
        },
        if profile_panels.is_empty() {
            String::new()
        } else {
            format!("<h2>Bottleneck attribution (--profile)</h2>{profile_panels}")
        },
        bench_panel(benches),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(seed: u64, err: Option<f64>, metrics: Option<Json>) -> RunRecord {
        RunRecord {
            kind: "sim".to_string(),
            label: format!("sim-1-softsync-mu4-lambda8-seed{seed} <unsafe>"),
            fingerprint: "fp".to_string(),
            seed,
            mu: 4,
            lambda: 8,
            shards: 1,
            epochs: 2,
            test_error_pct: err,
            train_loss: Some(0.4),
            sim_seconds: 100.0,
            wall_seconds: 1.0,
            updates: 500,
            events: 9000,
            avg_staleness: 2.5,
            max_staleness: 6,
            root_bytes_in: 1e8,
            root_bytes_out: 2e8,
            metrics,
        }
    }

    fn metrics_with_series() -> Json {
        Json::parse(
            r#"{"staleness": {"histogram": [5, 3, 1]},
                "series": {"schema": 1, "every_secs": 1,
                           "t": [1.0, 2.0, 3.0],
                           "mean_staleness": [2.0, null, 3.0],
                           "queue_depth": [4, 5, 6],
                           "active_lambda": [8, 8, 8],
                           "bytes_per_sec": [10.0, 20.0, 30.0],
                           "epoch": {"t": [2.5], "epoch": [1],
                                     "train_loss": [0.5], "test_error_pct": [null]},
                           "adaptive_n": {"t": [], "n": []}}}"#,
        )
        .unwrap()
    }

    #[test]
    fn report_is_self_contained_html_with_escaped_labels() {
        let records =
            vec![record(1, Some(12.5), Some(metrics_with_series())), record(2, None, None)];
        let html = render(&records, &[], "out/runs.jsonl");
        assert!(html.starts_with("<!DOCTYPE html>"));
        assert!(html.ends_with("</body></html>"));
        assert!(html.contains("&lt;unsafe&gt;"), "labels must be escaped");
        assert!(!html.contains("<unsafe>"), "raw label text must not leak into markup");
        // Self-contained: no external references of any kind.
        assert!(!html.contains("http-equiv"));
        assert!(!html.contains("src="));
        assert!(!html.contains("href="));
        // Panels present: scatter point, histogram bars, sparklines.
        assert!(html.contains("<circle"), "scatter needs at least one point");
        assert!(html.contains("class=\"bar\""), "histogram bars expected");
        assert!(html.contains("class=\"spark\""), "series sparklines expected");
    }

    fn metrics_with_profile() -> Json {
        Json::parse(
            r#"{"profile": {"schema": 1, "timebase": "sim", "mode": "critical_path",
                "total_secs": 100.0, "updates": 500,
                "categories": {"compute": 60.0, "push_wire": 10.0, "relay_wire": 5.0,
                               "barrier_wait": 15.0, "weight_delivery": 5.0,
                               "pipeline_wait": 3.0, "other": 2.0},
                "epochs": [], "blame": {"learner_secs": [], "learner_commits": [],
                                        "shard_busy_secs": []},
                "whatif": {"zero_wire_secs": 80.0, "zero_barrier_secs": 85.0,
                           "balanced_learners_secs": 90.0, "fast_root_secs": 90.0}}}"#,
        )
        .unwrap()
    }

    #[test]
    fn profile_panel_renders_attribution_and_whatifs() {
        let records = vec![record(1, Some(12.5), Some(metrics_with_profile()))];
        let html = render(&records, &[], "runs.jsonl");
        assert!(html.contains("Bottleneck attribution (--profile)"));
        assert!(html.contains("class=\"cat0\""), "stacked bar segments expected");
        assert!(html.contains("barrier_wait"), "legend names the busy categories");
        assert!(html.contains("zero barrier wait"), "what-if rows expected");
        assert!(html.contains("1.18×"), "100/85 speedup for zero barrier");
        assert!(html.starts_with("<!DOCTYPE html>") && html.ends_with("</body></html>"));
        assert!(!html.contains("src=") && !html.contains("href="));
        // A record without a profile stays out of the section.
        let html = render(&[record(1, None, None)], &[], "runs.jsonl");
        assert!(!html.contains("Bottleneck attribution"));
    }

    #[test]
    fn empty_index_still_renders_a_document() {
        let html = render(&[], &[], "runs.jsonl");
        assert!(html.contains("0 records"));
        assert!(html.contains("No numeric runs"));
    }

    #[test]
    fn nan_series_windows_break_the_line_not_the_report() {
        let records = vec![record(1, Some(10.0), Some(metrics_with_series()))];
        let html = render(&records, &[], "runs.jsonl");
        assert!(!html.contains("NaN"), "NaN must never reach the markup");
    }

    #[test]
    fn bench_ladder_renders_when_given_baselines() {
        let bench = Json::parse(
            r#"{"schema": 2, "quick": true,
                "sim_engine": [{"lambda": 512, "events": 2000,
                                "wall_secs": 0.002, "events_per_sec": 1.0e6}]}"#,
        )
        .unwrap();
        let html = render(&[], &[("old.json".to_string(), bench)], "runs.jsonl");
        assert!(html.contains("Sim-engine throughput"));
        assert!(html.contains("512"));
        assert!(html.contains("1.000e6"));
    }
}
