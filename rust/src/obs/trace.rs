//! Chrome trace-event recorder with a pluggable time base.
//!
//! Emits the trace-event JSON format (`{"traceEvents": [...]}`) that
//! Perfetto and `chrome://tracing` load directly: complete spans
//! (`ph: "X"`) for mini-batch compute, gradient push wire transit,
//! barrier waits, leaf relay hops, pulls, and broadcasts, plus instant
//! events (`ph: "i"`) for per-shard applyUpdate and checkpoint capture.
//! Timestamps are seconds converted to microseconds (the format's unit)
//! over the recorder's [`TimeBase`]: the sim engines record *virtual*
//! sim seconds (the timeline a viewer shows is the simulated schedule),
//! while the live engine ([`crate::coordinator::engine_live`]) records
//! wall seconds since its run epoch ([`TraceRecorder::on_wall`]).
//!
//! The recorder is off by default and costs one branch per call site
//! when off — `trace none` runs take the exact pre-obs path, which the
//! bit-identity property tests in `tests/integration_obs.rs` pin down.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::util::json::Json;

/// Trace process ids group the timeline rows: one lane per learner under
/// the "learners" process, per root shard under "root shards", per leaf
/// aggregator under "leaf aggregators".
pub const PID_LEARNERS: u64 = 1;
pub const PID_SHARDS: u64 = 2;
pub const PID_LEAVES: u64 = 3;

/// One recorded event. `name` is a `&'static str` on purpose: span names
/// form a small closed vocabulary and recording must not allocate per
/// event on the sim hot path.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub name: &'static str,
    /// Trace-event phase: `'X'` complete span, `'i'` instant.
    pub ph: char,
    /// Start, in virtual microseconds.
    pub ts_us: f64,
    /// Duration in microseconds (0 for instants).
    pub dur_us: f64,
    pub pid: u64,
    pub tid: u64,
}

/// What a recorded timestamp *means*. The sim engines pass virtual
/// seconds straight from the event queue; the live engine measures wall
/// offsets from a run epoch.
#[derive(Debug, Clone, Copy, Default)]
pub enum TimeBase {
    /// Timestamps are virtual sim seconds supplied by the caller.
    #[default]
    Virtual,
    /// Timestamps are wall seconds since this epoch ([`TraceRecorder::now_s`]).
    Wall(Instant),
}

/// Span recorder: `None` events = disabled (the no-op recorder). Every
/// record method is an early-return branch when off, so quiet runs pay
/// nothing but the check.
#[derive(Debug, Default)]
pub struct TraceRecorder {
    events: Option<Vec<TraceEvent>>,
    time_base: TimeBase,
}

impl TraceRecorder {
    /// The no-op recorder (default): records nothing.
    pub fn off() -> TraceRecorder {
        TraceRecorder::default()
    }

    pub fn on() -> TraceRecorder {
        TraceRecorder { events: Some(Vec::new()), time_base: TimeBase::Virtual }
    }

    /// A recorder over wall time: timestamps are seconds since `epoch`.
    /// Callers either pass offsets they measured themselves (threads
    /// sharing the epoch) or read [`TraceRecorder::now_s`].
    pub fn on_wall(epoch: Instant) -> TraceRecorder {
        TraceRecorder { events: Some(Vec::new()), time_base: TimeBase::Wall(epoch) }
    }

    /// Current time on the recorder's base: wall seconds since the epoch
    /// for [`TimeBase::Wall`]; 0.0 for [`TimeBase::Virtual`] (virtual
    /// time lives in the engine's event queue, not here).
    pub fn now_s(&self) -> f64 {
        match self.time_base {
            TimeBase::Virtual => 0.0,
            TimeBase::Wall(epoch) => epoch.elapsed().as_secs_f64(),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.events.is_some()
    }

    /// Record a complete span over `[start_s, end_s]` virtual seconds.
    #[inline]
    pub fn span(&mut self, name: &'static str, pid: u64, tid: u64, start_s: f64, end_s: f64) {
        if let Some(events) = &mut self.events {
            events.push(TraceEvent {
                name,
                ph: 'X',
                ts_us: start_s * 1e6,
                dur_us: (end_s - start_s).max(0.0) * 1e6,
                pid,
                tid,
            });
        }
    }

    /// Record an instant event at `at_s` virtual seconds.
    #[inline]
    pub fn instant(&mut self, name: &'static str, pid: u64, tid: u64, at_s: f64) {
        if let Some(events) = &mut self.events {
            events.push(TraceEvent { name, ph: 'i', ts_us: at_s * 1e6, dur_us: 0.0, pid, tid });
        }
    }

    /// Take the recorded events, leaving the recorder disabled.
    pub fn take(&mut self) -> Option<Vec<TraceEvent>> {
        self.events.take()
    }
}

fn metadata_event(pid: u64, name: &str) -> Json {
    Json::obj(vec![
        ("name", Json::str("process_name")),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

/// Render events as the Chrome trace-event JSON object.
pub fn to_json(events: &[TraceEvent]) -> Json {
    let mut rows = vec![
        metadata_event(PID_LEARNERS, "learners"),
        metadata_event(PID_SHARDS, "root shards"),
        metadata_event(PID_LEAVES, "leaf aggregators"),
    ];
    for e in events {
        let mut pairs = vec![
            ("name", Json::str(e.name)),
            ("ph", Json::str(e.ph.to_string())),
            ("ts", Json::num(e.ts_us)),
            ("pid", Json::num(e.pid as f64)),
            ("tid", Json::num(e.tid as f64)),
        ];
        if e.ph == 'X' {
            pairs.push(("dur", Json::num(e.dur_us)));
        } else {
            // instant scope: thread-local marker
            pairs.push(("s", Json::str("t")));
        }
        rows.push(Json::obj(pairs));
    }
    Json::obj(vec![("traceEvents", Json::Arr(rows))])
}

/// Write the trace file atomically (tmp + rename, creating parent
/// directories) — a crash mid-flush cannot leave a truncated trace.
pub fn write(path: &Path, events: &[TraceEvent]) -> Result<()> {
    crate::util::write_atomic(path, &to_json(events).to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_recorder_records_nothing() {
        let mut r = TraceRecorder::off();
        r.span("compute", PID_LEARNERS, 0, 0.0, 1.0);
        r.instant("checkpoint", PID_SHARDS, 0, 2.0);
        assert!(!r.enabled());
        assert!(r.take().is_none());
    }

    #[test]
    fn spans_convert_seconds_to_microseconds() {
        let mut r = TraceRecorder::on();
        r.span("compute", PID_LEARNERS, 3, 0.5, 0.75);
        let events = r.take().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].ts_us, 0.5e6);
        assert_eq!(events[0].dur_us, 0.25e6);
        assert_eq!(events[0].tid, 3);
    }

    #[test]
    fn json_has_trace_events_array_with_metadata() {
        let mut r = TraceRecorder::on();
        r.span("push", PID_LEARNERS, 1, 0.0, 0.1);
        r.instant("apply_update", PID_SHARDS, 0, 0.1);
        let json = to_json(&r.take().unwrap());
        let text = json.to_string();
        let parsed = Json::parse(&text).expect("trace JSON must re-parse");
        let rows = match parsed.get("traceEvents").unwrap() {
            Json::Arr(rows) => rows.clone(),
            other => panic!("traceEvents must be an array, got {other:?}"),
        };
        // 3 process_name metadata rows + 2 recorded events
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[0].get("ph").unwrap().as_str().unwrap(), "M");
        assert_eq!(rows[3].get("name").unwrap().as_str().unwrap(), "push");
        assert_eq!(rows[4].get("ph").unwrap().as_str().unwrap(), "i");
    }

    #[test]
    fn wall_base_reports_monotone_now() {
        let r = TraceRecorder::on_wall(Instant::now());
        assert!(r.enabled());
        let a = r.now_s();
        let b = r.now_s();
        assert!(a >= 0.0 && b >= a);
        // A virtual-base recorder has no wall clock to consult.
        assert_eq!(TraceRecorder::on().now_s(), 0.0);
    }

    #[test]
    fn negative_durations_clamp_to_zero() {
        // Defensive: a span whose end precedes its start (should not
        // happen, but a viewer would reject a negative dur) clamps.
        let mut r = TraceRecorder::on();
        r.span("push", PID_LEARNERS, 0, 1.0, 0.5);
        assert_eq!(r.take().unwrap()[0].dur_us, 0.0);
    }
}
