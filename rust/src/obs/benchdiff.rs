//! `BENCH_hotpath.json` comparator: the perf-trajectory gate.
//!
//! `rudra bench-diff OLD.json NEW.json` diffs two machine-readable bench
//! baselines (schema 2, written by `benches/perf_hotpath.rs`) and exits
//! non-zero when a kernel slows past its noise threshold or the sim
//! engine's event throughput collapses — CI wires it against the
//! previous run's uploaded artifact, so perf regressions go red instead
//! of requiring manual artifact archaeology.
//!
//! Thresholds are deliberately loose (shared CI runners jitter hard):
//! the default flags ≥ 1.75× kernel slowdowns, and sub-microsecond
//! kernels — where a single cache miss moves the number — get a 3×
//! floor. An injected 2× regression on a normal kernel must fail; a
//! self-diff must pass.
//!
//! Coverage changes are first-class: kernels or sim-engine ladder rows
//! present in only one baseline are always reported (a silently dropped
//! benchmark looks exactly like a fixed one), and `--strict` turns
//! removals into gate failures.

use anyhow::Result;

use crate::util::json::Json;

/// Default slowdown ratio that counts as a regression.
pub const DEFAULT_THRESHOLD: f64 = 1.75;
/// Noise floor for kernels faster than [`FAST_KERNEL_SECS`] per iter.
pub const FAST_KERNEL_THRESHOLD: f64 = 3.0;
/// "Too fast to trust a tight threshold" cutoff (1 µs/iter).
pub const FAST_KERNEL_SECS: f64 = 1e-6;

/// Outcome of one comparison: human-readable lines plus the regressions
/// that should fail the gate.
#[derive(Debug, Default)]
pub struct DiffReport {
    pub lines: Vec<String>,
    pub regressions: Vec<String>,
}

impl DiffReport {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }
}

fn kernel_threshold(old_secs: f64, base: f64) -> f64 {
    if old_secs < FAST_KERNEL_SECS {
        base.max(FAST_KERNEL_THRESHOLD)
    } else {
        base
    }
}

/// Compare two bench baselines. `threshold` is the base slowdown ratio
/// (see [`DEFAULT_THRESHOLD`]); `strict` additionally fails the gate
/// when a kernel or sim-engine ladder row vanishes from the new
/// baseline (lost coverage instead of lost performance).
pub fn compare(old: &Json, new: &Json, threshold: f64, strict: bool) -> Result<DiffReport> {
    anyhow::ensure!(threshold > 1.0, "threshold must be > 1.0, got {threshold}");
    let old_schema = old.get("schema")?.as_u64()?;
    let new_schema = new.get("schema")?.as_u64()?;
    let mut report = DiffReport::default();
    if old_schema != new_schema {
        report
            .lines
            .push(format!("schema changed {old_schema} -> {new_schema}; comparing shared keys"));
    }
    // Quick-mode runs use reduced iteration counts: numbers from the two
    // modes measure different things and must never gate each other.
    let old_quick = old.get("quick")?.as_bool()?;
    let new_quick = new.get("quick")?.as_bool()?;
    anyhow::ensure!(
        old_quick == new_quick,
        "refusing to diff a quick-mode baseline against a full one \
         (old quick={old_quick}, new quick={new_quick})"
    );

    // Kernels: intersect by name (a renamed/added kernel is reported but
    // cannot regress).
    let (old_kernels, new_kernels) =
        (old.get("kernels_secs_per_iter")?, new.get("kernels_secs_per_iter")?);
    if let (Json::Obj(old_map), Json::Obj(new_map)) = (old_kernels, new_kernels) {
        for (name, old_v) in old_map {
            let old_secs = old_v.as_f64()?;
            let Some(new_v) = new_map.get(name) else {
                report.lines.push(format!("kernel {name}: removed"));
                if strict {
                    report
                        .regressions
                        .push(format!("kernel {name}: removed from the new baseline (--strict)"));
                }
                continue;
            };
            let new_secs = new_v.as_f64()?;
            if old_secs <= 0.0 {
                report.lines.push(format!("kernel {name}: old time {old_secs} s — skipped"));
                continue;
            }
            let ratio = new_secs / old_secs;
            let thr = kernel_threshold(old_secs, threshold);
            let verdict = if ratio > thr {
                report.regressions.push(format!(
                    "kernel {name}: {old_secs:.3e} s -> {new_secs:.3e} s \
                     ({ratio:.2}x > {thr:.2}x threshold)"
                ));
                "REGRESSED"
            } else if ratio < 1.0 / thr {
                "improved"
            } else {
                "ok"
            };
            report.lines.push(format!(
                "kernel {name}: {old_secs:.3e} -> {new_secs:.3e} s/iter ({ratio:.2}x) {verdict}"
            ));
        }
        for name in new_map.keys() {
            if !old_map.contains_key(name) {
                report.lines.push(format!("kernel {name}: new (no baseline)"));
            }
        }
    } else {
        anyhow::bail!("kernels_secs_per_iter must be an object in both files");
    }

    // Sim-engine ladder: events/s per λ; a throughput *drop* past the
    // threshold regresses (ratios invert vs kernel times).
    let ladder = |v: &Json| -> Result<Vec<(u64, f64)>> {
        match v.get("sim_engine")? {
            Json::Arr(rows) => rows
                .iter()
                .map(|r| Ok((r.get("lambda")?.as_u64()?, r.get("events_per_sec")?.as_f64()?)))
                .collect(),
            _ => anyhow::bail!("sim_engine must be an array"),
        }
    };
    let old_ladder = ladder(old)?;
    let new_ladder = ladder(new)?;
    // A ladder row that exists only in the old baseline is lost coverage
    // at that λ — say so instead of silently shrinking the gate.
    for &(lambda, _) in &old_ladder {
        if !new_ladder.iter().any(|&(l, _)| l == lambda) {
            report.lines.push(format!("sim engine lambda={lambda}: removed"));
            if strict {
                report.regressions.push(format!(
                    "sim engine lambda={lambda}: removed from the new baseline (--strict)"
                ));
            }
        }
    }
    for (lambda, new_eps) in new_ladder {
        let Some(&(_, old_eps)) = old_ladder.iter().find(|(l, _)| *l == lambda) else {
            report.lines.push(format!("sim engine lambda={lambda}: new (no baseline)"));
            continue;
        };
        if old_eps <= 0.0 {
            continue;
        }
        let ratio = old_eps / new_eps.max(1e-12);
        let verdict = if ratio > threshold {
            report.regressions.push(format!(
                "sim engine lambda={lambda}: {old_eps:.3e} -> {new_eps:.3e} events/s \
                 ({ratio:.2}x slower > {threshold:.2}x threshold)"
            ));
            "REGRESSED"
        } else {
            "ok"
        };
        report.lines.push(format!(
            "sim engine lambda={lambda}: {old_eps:.3e} -> {new_eps:.3e} events/s {verdict}"
        ));
    }

    // Grid speedup is informational only: it measures runner core count
    // as much as our executor.
    if let (Ok(old_g), Ok(new_g)) = (old.get("grid"), new.get("grid")) {
        if let (Ok(a), Ok(b)) = (old_g.get("speedup"), new_g.get("speedup")) {
            report.lines.push(format!(
                "grid speedup: {:.2}x -> {:.2}x (informational)",
                a.as_f64()?,
                b.as_f64()?
            ));
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn baseline() -> Json {
        Json::parse(
            r#"{
              "schema": 2, "quick": true, "cores": 4,
              "kernels_secs_per_iter": {
                "axpy 24k (CNN)": 2.0e-5,
                "event queue push+pop x1000": 5.0e-7
              },
              "sim_engine": [
                {"lambda": 30, "events": 1000, "wall_secs": 0.001, "events_per_sec": 1.0e6},
                {"lambda": 512, "events": 2000, "wall_secs": 0.002, "events_per_sec": 1.0e6}
              ],
              "grid": {"points": 4, "jobs": 4, "serial_secs": 4.0, "parallel_secs": 1.5,
                       "speedup": 2.67}
            }"#,
        )
        .unwrap()
    }

    fn with_kernel(base: &Json, name: &str, secs: f64) -> Json {
        let mut v = base.clone();
        if let Json::Obj(top) = &mut v {
            if let Some(Json::Obj(kernels)) = top.get_mut("kernels_secs_per_iter") {
                kernels.insert(name.to_string(), Json::num(secs));
            }
        }
        v
    }

    #[test]
    fn self_diff_passes() {
        let b = baseline();
        let report = compare(&b, &b, DEFAULT_THRESHOLD, false).unwrap();
        assert!(report.passed(), "self-diff must pass: {:?}", report.regressions);
    }

    #[test]
    fn injected_2x_kernel_regression_fails() {
        let b = baseline();
        let worse = with_kernel(&b, "axpy 24k (CNN)", 4.0e-5);
        let report = compare(&b, &worse, DEFAULT_THRESHOLD, false).unwrap();
        assert!(!report.passed());
        assert!(report.regressions[0].contains("axpy"), "{:?}", report.regressions);
    }

    #[test]
    fn sub_microsecond_kernels_get_a_wider_noise_floor() {
        // 2x on a 0.5 µs kernel is cache-miss noise, not a regression...
        let b = baseline();
        let jittery = with_kernel(&b, "event queue push+pop x1000", 1.0e-6);
        assert!(compare(&b, &jittery, DEFAULT_THRESHOLD, false).unwrap().passed());
        // ...but 4x still fails even there.
        let bad = with_kernel(&b, "event queue push+pop x1000", 2.0e-6);
        assert!(!compare(&b, &bad, DEFAULT_THRESHOLD, false).unwrap().passed());
    }

    #[test]
    fn sim_engine_throughput_collapse_fails() {
        let b = baseline();
        let mut worse = b.clone();
        if let Json::Obj(top) = &mut worse {
            if let Some(Json::Arr(rows)) = top.get_mut("sim_engine") {
                if let Json::Obj(row) = &mut rows[1] {
                    row.insert("events_per_sec".to_string(), Json::num(4.0e5));
                }
            }
        }
        let report = compare(&b, &worse, DEFAULT_THRESHOLD, false).unwrap();
        assert!(!report.passed());
        assert!(report.regressions[0].contains("lambda=512"), "{:?}", report.regressions);
    }

    #[test]
    fn quick_vs_full_refuses_to_compare() {
        let b = baseline();
        let mut full = b.clone();
        if let Json::Obj(top) = &mut full {
            top.insert("quick".to_string(), Json::Bool(false));
        }
        assert!(compare(&b, &full, DEFAULT_THRESHOLD, false).is_err());
    }

    /// A baseline missing a kernel and a λ rung from the other one: both
    /// directions are reported as coverage changes, and only `strict`
    /// turns the *removals* into gate failures.
    #[test]
    fn removed_rows_are_reported_and_fail_only_under_strict() {
        let b = baseline();
        let mut shrunk = b.clone();
        if let Json::Obj(top) = &mut shrunk {
            if let Some(Json::Obj(kernels)) = top.get_mut("kernels_secs_per_iter") {
                kernels.remove("axpy 24k (CNN)");
            }
            if let Some(Json::Arr(rows)) = top.get_mut("sim_engine") {
                rows.retain(|r| {
                    r.get("lambda").and_then(|l| l.as_u64()).map(|l| l != 512).unwrap_or(true)
                });
            }
        }
        let report = compare(&b, &shrunk, DEFAULT_THRESHOLD, false).unwrap();
        assert!(report.passed(), "loose mode only reports: {:?}", report.regressions);
        assert!(
            report.lines.iter().any(|l| l.contains("axpy") && l.contains("removed")),
            "{:?}",
            report.lines
        );
        assert!(
            report.lines.iter().any(|l| l.contains("lambda=512") && l.contains("removed")),
            "{:?}",
            report.lines
        );
        let strict = compare(&b, &shrunk, DEFAULT_THRESHOLD, true).unwrap();
        assert!(!strict.passed(), "strict mode fails on removals");
        assert_eq!(strict.regressions.len(), 2, "{:?}", strict.regressions);
        // additions are coverage *gains*: reported, never failed, even strict
        let grown = compare(&shrunk, &b, DEFAULT_THRESHOLD, true).unwrap();
        assert!(grown.passed(), "{:?}", grown.regressions);
        assert!(
            grown.lines.iter().any(|l| l.contains("new (no baseline)")),
            "{:?}",
            grown.lines
        );
    }
}
