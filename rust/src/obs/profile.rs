//! Critical-path profiler: exact per-category attribution of sim
//! runtime, per-learner blame, and Amdahl-style what-if projections.
//!
//! The discrete-event engine already knows the causal chain behind every
//! weight update — broadcast receipt → compute → push wire → barrier or
//! quota wait → shard apply, plus relay hops for the Adv\* trees. The
//! profiler replays that chain *backwards* from each commit: the window
//! between consecutive commits is the critical path of that update, and
//! walking the triggering learner's most recent spans downstream →
//! upstream partitions the window into categories **exactly** (the slices
//! are constructed to tile `[last_commit, now]` with no gaps and no
//! overlap, so the category totals sum to the final virtual time to the
//! last bit — an invariant the integration tests pin at 1e-9).
//!
//! Categories:
//!
//! * `compute` — mini-batch gradient computation on the critical chain.
//! * `push_wire` — gradient transit, learner → root (or learner → leaf).
//! * `relay_wire` — leaf-aggregator relay hop (Adv\* only).
//! * `barrier_wait` — any non-wire critical time during which at least
//!   one *other* learner sat parked in a sync barrier. Hardsync rounds
//!   convert straggler compute into this category; 1-softsync never
//!   parks anyone, so its share is exactly zero.
//! * `weight_delivery` — broadcast/pull transit of fresh weights back to
//!   the critical learner.
//! * `pipeline_wait` — gaps inside the chain (gradient queued at a leaf
//!   before its relay departed, compute finished before its push left).
//! * `other` — critical time not covered by the chain (quota waits
//!   between async commits, scheduling slack, the pre-chain remainder).
//!
//! Blame: the whole inter-commit window is charged to the learner whose
//! arrival closed it — stragglers accumulate wall share for free.
//! What-ifs are subset subtractions of the partition, so every
//! projection is guaranteed `0 ≤ whatif ≤ total`.
//!
//! Off by default (`profile` / `--profile`), one branch per site when
//! off, and purely observational: profile-on runs are bit-identical to
//! quiet runs (property-tested in `tests/integration_obs.rs`).

use crate::util::json::Json;

/// Attribution categories, in report order.
pub const CATEGORY_NAMES: [&str; 7] = [
    "compute",
    "push_wire",
    "relay_wire",
    "barrier_wait",
    "weight_delivery",
    "pipeline_wait",
    "other",
];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Cat {
    Compute,
    PushWire,
    RelayWire,
    BarrierWait,
    WeightDelivery,
    PipelineWait,
    Other,
}

/// Per-category accumulated seconds.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct Categories {
    pub compute: f64,
    pub push_wire: f64,
    pub relay_wire: f64,
    pub barrier_wait: f64,
    pub weight_delivery: f64,
    pub pipeline_wait: f64,
    pub other: f64,
}

impl Categories {
    fn add(&mut self, cat: Cat, secs: f64) {
        match cat {
            Cat::Compute => self.compute += secs,
            Cat::PushWire => self.push_wire += secs,
            Cat::RelayWire => self.relay_wire += secs,
            Cat::BarrierWait => self.barrier_wait += secs,
            Cat::WeightDelivery => self.weight_delivery += secs,
            Cat::PipelineWait => self.pipeline_wait += secs,
            Cat::Other => self.other += secs,
        }
    }

    pub fn total(&self) -> f64 {
        self.compute
            + self.push_wire
            + self.relay_wire
            + self.barrier_wait
            + self.weight_delivery
            + self.pipeline_wait
            + self.other
    }

    fn minus(&self, other: &Categories) -> Categories {
        Categories {
            compute: self.compute - other.compute,
            push_wire: self.push_wire - other.push_wire,
            relay_wire: self.relay_wire - other.relay_wire,
            barrier_wait: self.barrier_wait - other.barrier_wait,
            weight_delivery: self.weight_delivery - other.weight_delivery,
            pipeline_wait: self.pipeline_wait - other.pipeline_wait,
            other: self.other - other.other,
        }
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("compute", Json::num(self.compute)),
            ("push_wire", Json::num(self.push_wire)),
            ("relay_wire", Json::num(self.relay_wire)),
            ("barrier_wait", Json::num(self.barrier_wait)),
            ("weight_delivery", Json::num(self.weight_delivery)),
            ("pipeline_wait", Json::num(self.pipeline_wait)),
            ("other", Json::num(self.other)),
        ])
    }
}

/// Most recent chain spans per learner, recorded as the engine schedules
/// the corresponding events. `(start, end)` with `end <= start` meaning
/// "not seen yet".
#[derive(Debug, Default, Clone, Copy)]
struct Chain {
    deliver: (f64, f64),
    compute: (f64, f64),
    push: (f64, f64),
    relay: (f64, f64),
}

/// The profiler proper. All inputs are virtual-time stamps the engine
/// already computes; nothing here reads engine RNGs or reorders events.
#[derive(Debug)]
pub struct Profiler {
    chains: Vec<Chain>,
    last_commit: f64,
    cats: Categories,
    /// Cumulative categories at the last epoch boundary.
    epoch_mark: Categories,
    epochs: Vec<(u64, Categories)>,
    blame_secs: Vec<f64>,
    blame_commits: Vec<u64>,
    /// Barrier occupancy since the last commit: closed intervals plus the
    /// open one (while any learner is parked).
    parked: Vec<bool>,
    parked_count: usize,
    busy: Vec<(f64, f64)>,
    busy_open: Option<f64>,
    /// All-learner compute span statistics (for the balanced-learners
    /// projection) and the raw critical compute actually claimed.
    compute_span_sum: f64,
    compute_span_count: u64,
    crit_compute_raw: f64,
    crit_compute_commits: u64,
    updates: u64,
    total: f64,
    shard_busy: Vec<f64>,
}

impl Profiler {
    pub fn new(lambda: usize) -> Profiler {
        Profiler {
            chains: vec![Chain::default(); lambda],
            last_commit: 0.0,
            cats: Categories::default(),
            epoch_mark: Categories::default(),
            epochs: Vec::new(),
            blame_secs: vec![0.0; lambda],
            blame_commits: vec![0; lambda],
            parked: vec![false; lambda],
            parked_count: 0,
            busy: Vec::new(),
            busy_open: None,
            compute_span_sum: 0.0,
            compute_span_count: 0,
            crit_compute_raw: 0.0,
            crit_compute_commits: 0,
            updates: 0,
            total: 0.0,
            shard_busy: Vec::new(),
        }
    }

    fn chain_mut(&mut self, l: usize) -> Option<&mut Chain> {
        self.chains.get_mut(l)
    }

    pub fn note_deliver(&mut self, l: usize, start: f64, end: f64) {
        if let Some(c) = self.chain_mut(l) {
            c.deliver = (start, end);
        }
    }

    pub fn note_compute(&mut self, l: usize, start: f64, end: f64) {
        if end > start {
            self.compute_span_sum += end - start;
            self.compute_span_count += 1;
        }
        if let Some(c) = self.chain_mut(l) {
            c.compute = (start, end);
        }
    }

    pub fn note_push(&mut self, l: usize, start: f64, end: f64) {
        if let Some(c) = self.chain_mut(l) {
            c.push = (start, end);
        }
    }

    pub fn note_relay(&mut self, l: usize, start: f64, end: f64) {
        if let Some(c) = self.chain_mut(l) {
            c.relay = (start, end);
        }
    }

    pub fn barrier_enter(&mut self, l: usize, now: f64) {
        if let Some(p) = self.parked.get_mut(l) {
            if !*p {
                *p = true;
                self.parked_count += 1;
                if self.parked_count == 1 {
                    self.busy_open = Some(now);
                }
            }
        }
    }

    /// Release or abandonment (a parked learner was killed) — both end
    /// the learner's occupancy.
    pub fn barrier_leave(&mut self, l: usize, now: f64) {
        if let Some(p) = self.parked.get_mut(l) {
            if *p {
                *p = false;
                self.parked_count -= 1;
                if self.parked_count == 0 {
                    if let Some(s) = self.busy_open.take() {
                        if now > s {
                            self.busy.push((s, now));
                        }
                    }
                }
            }
        }
    }

    /// Busy intervals covering `[last_commit, now]`, including the
    /// still-open one.
    fn busy_upto(&self, now: f64) -> Vec<(f64, f64)> {
        let mut v = self.busy.clone();
        if let Some(s) = self.busy_open {
            if now > s {
                v.push((s, now));
            }
        }
        v
    }

    /// One weight update committed at `now`, triggered by learner `by`
    /// (None for membership-change flushes, which have no causal chain).
    pub fn commit(&mut self, by: Option<usize>, now: f64) {
        let c = self.last_commit;
        if now < c {
            return;
        }
        let slices = self.slice_window(by, c, now);
        self.accumulate(&slices, now);
        if let Some(l) = by {
            if let Some(b) = self.blame_secs.get_mut(l) {
                *b += now - c;
            }
            if let Some(b) = self.blame_commits.get_mut(l) {
                *b += 1;
            }
        }
        self.updates += 1;
        self.last_commit = now;
        self.busy.clear();
        if self.parked_count > 0 {
            self.busy_open = Some(now);
        }
    }

    /// Epoch boundary: snapshot the cumulative categories and record the
    /// delta since the previous boundary. Called right after the commit
    /// that completed the epoch, so the epoch rows tile the commit
    /// windows exactly.
    pub fn epoch(&mut self, epoch: u64) {
        let delta = self.cats.minus(&self.epoch_mark);
        self.epochs.push((epoch, delta));
        self.epoch_mark = self.cats;
    }

    /// End of run at virtual time `now`: attribute the tail past the
    /// last commit, store per-shard ingress busy seconds, and freeze the
    /// total.
    pub fn finish(&mut self, now: f64, shard_busy: Vec<f64>) {
        let c = self.last_commit;
        if now > c {
            let slices = self.slice_window(None, c, now);
            self.accumulate(&slices, now);
            self.last_commit = now;
        }
        self.busy.clear();
        self.busy_open = None;
        self.total = self.total.max(now);
        self.shard_busy = shard_busy;
    }

    /// Partition `[c, now]` into raw category slices by walking the
    /// triggering learner's chain downstream → upstream. The slices tile
    /// the window exactly: each claim advances a cursor monotonically
    /// from `now` toward `c`, and the remainder is `other`.
    fn slice_window(&self, by: Option<usize>, c: f64, now: f64) -> Vec<(Cat, f64, f64)> {
        let mut slices: Vec<(Cat, f64, f64)> = Vec::new();
        let mut cursor = now;
        {
            let mut claim = |cat: Cat, from: f64| {
                let s = from.max(c).min(cursor);
                if cursor > s {
                    slices.push((cat, s, cursor));
                    cursor = s;
                }
            };
            if let Some(ch) = by.and_then(|l| self.chains.get(l)) {
                // Terminal relay hop (Adv* commits land via RelayAtRoot);
                // a relay ending before the commit instant is a stale
                // stamp from an earlier round and is skipped.
                let (r0, r1) = ch.relay;
                if r1 > r0 && r1 >= now - 1e-12 {
                    claim(Cat::RelayWire, r0);
                }
                let (p0, p1) = ch.push;
                if p1 > p0 && p1 > c {
                    claim(Cat::PipelineWait, p1);
                    claim(Cat::PushWire, p0);
                }
                let (c0, c1) = ch.compute;
                if c1 > c0 && c1 > c {
                    claim(Cat::PipelineWait, c1);
                    claim(Cat::Compute, c0);
                }
                let (d0, d1) = ch.deliver;
                if d1 > d0 && d1 > c {
                    claim(Cat::Other, d1);
                    claim(Cat::WeightDelivery, d0);
                }
            }
        }
        if cursor > c {
            slices.push((Cat::Other, c, cursor));
        }
        slices
    }

    /// Fold raw slices into the category totals, reassigning the parts of
    /// non-wire slices that overlap barrier occupancy to `barrier_wait`
    /// (wire transit is wire transit whether or not someone is parked).
    fn accumulate(&mut self, slices: &[(Cat, f64, f64)], now: f64) {
        let busy = self.busy_upto(now);
        let mut saw_compute = false;
        for &(cat, s, e) in slices {
            let len = e - s;
            if cat == Cat::PushWire || cat == Cat::RelayWire {
                self.cats.add(cat, len);
                continue;
            }
            if cat == Cat::Compute {
                self.crit_compute_raw += len;
                saw_compute = true;
            }
            let mut overlapped = 0.0;
            for &(bs, be) in &busy {
                let ov = e.min(be) - s.max(bs);
                if ov > 0.0 {
                    overlapped += ov;
                }
            }
            let overlapped = overlapped.min(len);
            self.cats.add(Cat::BarrierWait, overlapped);
            self.cats.add(cat, len - overlapped);
        }
        if saw_compute {
            self.crit_compute_commits += 1;
        }
    }

    /// What-if projections: each subtracts a subset of the partition, so
    /// every value lies in `[0, total]`.
    fn whatif(&self) -> (f64, f64, f64, f64) {
        let t = self.total;
        let c = &self.cats;
        let zero_wire = t - c.push_wire - c.relay_wire - c.weight_delivery;
        let zero_barrier = t - c.barrier_wait;
        // Perfectly balanced learners: barrier waits vanish and critical
        // compute spans shrink to the all-learner mean; the excess is
        // capped at the compute seconds still attributed post-override.
        let mean_span = if self.compute_span_count > 0 {
            self.compute_span_sum / self.compute_span_count as f64
        } else {
            0.0
        };
        let expected = mean_span * self.crit_compute_commits as f64;
        let excess = (self.crit_compute_raw - expected).max(0.0).min(c.compute);
        let balanced = (t - c.barrier_wait - excess).max(0.0);
        let fast_root = t - c.relay_wire - c.weight_delivery;
        (zero_wire, zero_barrier, balanced, fast_root)
    }

    /// Serialize for the metrics snapshot (`"profile"` key). `timebase`
    /// is `"sim"` and `mode` `"critical_path"`: the live engine's
    /// aggregate wall-clock variant marks itself differently so readers
    /// never confuse exact partitions with overlapping thread sums.
    pub fn to_json(&self) -> Json {
        let (zero_wire, zero_barrier, balanced, fast_root) = self.whatif();
        let epochs: Vec<Json> = self
            .epochs
            .iter()
            .map(|(e, cats)| {
                Json::obj(vec![
                    ("epoch", Json::num(*e as f64)),
                    ("categories", cats.to_json()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("timebase", Json::str("sim")),
            ("mode", Json::str("critical_path")),
            ("total_secs", Json::num(self.total)),
            ("updates", Json::num(self.updates as f64)),
            ("categories", self.cats.to_json()),
            ("epochs", Json::Arr(epochs)),
            (
                "blame",
                Json::obj(vec![
                    ("learner_secs", Json::arr_f64(&self.blame_secs)),
                    (
                        "learner_commits",
                        Json::Arr(
                            self.blame_commits.iter().map(|&c| Json::num(c as f64)).collect(),
                        ),
                    ),
                    ("shard_busy_secs", Json::arr_f64(&self.shard_busy)),
                ]),
            ),
            (
                "whatif",
                Json::obj(vec![
                    ("zero_wire_secs", Json::num(zero_wire)),
                    ("zero_barrier_secs", Json::num(zero_barrier)),
                    ("balanced_learners_secs", Json::num(balanced)),
                    ("fast_root_secs", Json::num(fast_root)),
                ]),
            ),
        ])
    }
}

/// Aggregate wall-clock attribution for the live engine. OS threads
/// overlap, so there is no single critical path — sums here are
/// per-category wall seconds *summed across learners* and the JSON marks
/// itself `mode: "aggregate"` / `timebase: "wall"` to keep readers
/// honest.
#[derive(Debug)]
pub struct WallProfiler {
    compute: f64,
    push_wire: f64,
    barrier_wait: f64,
    blame_secs: Vec<f64>,
    blame_commits: Vec<u64>,
    updates: u64,
}

impl WallProfiler {
    pub fn new(lambda: usize) -> WallProfiler {
        WallProfiler {
            compute: 0.0,
            push_wire: 0.0,
            barrier_wait: 0.0,
            blame_secs: vec![0.0; lambda],
            blame_commits: vec![0; lambda],
            updates: 0,
        }
    }

    /// One gradient receipt: compute span + wire transit (send → server
    /// receipt), charged to the pushing learner.
    pub fn push(&mut self, l: usize, compute_secs: f64, wire_secs: f64) {
        let compute_secs = compute_secs.max(0.0);
        let wire_secs = wire_secs.max(0.0);
        self.compute += compute_secs;
        self.push_wire += wire_secs;
        if let Some(b) = self.blame_secs.get_mut(l) {
            *b += compute_secs + wire_secs;
        }
    }

    pub fn commit(&mut self, l: usize) {
        self.updates += 1;
        if let Some(b) = self.blame_commits.get_mut(l) {
            *b += 1;
        }
    }

    pub fn barrier_wait(&mut self, secs: f64) {
        self.barrier_wait += secs.max(0.0);
    }

    pub fn to_json(&self, wall_secs: f64) -> Json {
        let cats = Categories {
            compute: self.compute,
            push_wire: self.push_wire,
            barrier_wait: self.barrier_wait,
            ..Categories::default()
        };
        Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("timebase", Json::str("wall")),
            ("mode", Json::str("aggregate")),
            ("total_secs", Json::num(wall_secs)),
            ("updates", Json::num(self.updates as f64)),
            ("categories", cats.to_json()),
            ("epochs", Json::Arr(Vec::new())),
            (
                "blame",
                Json::obj(vec![
                    ("learner_secs", Json::arr_f64(&self.blame_secs)),
                    (
                        "learner_commits",
                        Json::Arr(
                            self.blame_commits.iter().map(|&c| Json::num(c as f64)).collect(),
                        ),
                    ),
                    ("shard_busy_secs", Json::arr_f64(&[])),
                ]),
            ),
        ])
    }
}

// ---------------------------------------------------------------------
// `rudra analyze` rendering: plain-text attribution tables over the
// profile JSON (from a metrics file or a run-index record).
// ---------------------------------------------------------------------

fn get_f64(profile: &Json, path: &[&str]) -> Option<f64> {
    let mut v = profile;
    for key in path {
        v = v.opt(key)?;
    }
    v.as_f64().ok()
}

/// Category (name, seconds) rows in report order.
pub fn category_rows(profile: &Json) -> Vec<(String, f64)> {
    CATEGORY_NAMES
        .iter()
        .map(|&name| {
            (name.to_string(), get_f64(profile, &["categories", name]).unwrap_or(0.0))
        })
        .collect()
}

fn fmt_secs(v: f64) -> String {
    if v.abs() >= 1000.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.3}")
    }
}

/// Render one profile as the `rudra analyze` text block.
pub fn render_analysis(profile: &Json, title: &str) -> Vec<String> {
    let mut out = Vec::new();
    let total = get_f64(profile, &["total_secs"]).unwrap_or(0.0);
    let updates = get_f64(profile, &["updates"]).unwrap_or(0.0);
    let timebase = profile.opt("timebase").and_then(|v| v.as_str().ok()).unwrap_or("sim");
    let mode = profile.opt("mode").and_then(|v| v.as_str().ok()).unwrap_or("critical_path");
    out.push(format!("analysis: {title}"));
    out.push(format!(
        "  {mode} attribution over {timebase} time: {} s across {} updates",
        fmt_secs(total),
        updates as u64
    ));
    out.push(format!("  {:<16} {:>12} {:>8}", "category", "seconds", "share"));
    for (name, secs) in category_rows(profile) {
        let share = if total > 0.0 { 100.0 * secs / total } else { 0.0 };
        out.push(format!("  {name:<16} {:>12} {share:>7.1}%", fmt_secs(secs)));
    }
    if mode == "critical_path" {
        let sum: f64 = category_rows(profile).iter().map(|(_, s)| s).sum();
        out.push(format!(
            "  {:<16} {:>12} {:>7.1}%  (exact partition of runtime)",
            "sum",
            fmt_secs(sum),
            if total > 0.0 { 100.0 * sum / total } else { 0.0 }
        ));
    }
    // Blame: top learners by critical-path seconds.
    if let Some(blame) = profile.opt("blame") {
        let secs = blame.opt("learner_secs").and_then(|v| v.as_f64_vec().ok()).unwrap_or_default();
        let commits: Vec<f64> = blame
            .opt("learner_commits")
            .and_then(|v| v.as_f64_vec().ok())
            .unwrap_or_default();
        let mut order: Vec<usize> = (0..secs.len()).collect();
        order.sort_by(|&a, &b| secs[b].partial_cmp(&secs[a]).unwrap_or(std::cmp::Ordering::Equal));
        if !order.is_empty() {
            out.push("  blame (top learners on the critical path):".to_string());
            for &l in order.iter().take(5) {
                let share = if total > 0.0 { 100.0 * secs[l] / total } else { 0.0 };
                let n = commits.get(l).copied().unwrap_or(0.0) as u64;
                out.push(format!(
                    "    learner {l:<4} {:>10} s {share:>6.1}%  ({n} commits closed)",
                    fmt_secs(secs[l])
                ));
            }
        }
        if let Some(shards) = blame.opt("shard_busy_secs").and_then(|v| v.as_f64_vec().ok()) {
            if !shards.is_empty() {
                let row = shards
                    .iter()
                    .enumerate()
                    .map(|(s, &b)| format!("S{s}={}", fmt_secs(b)))
                    .collect::<Vec<_>>()
                    .join(" ");
                out.push(format!("  shard ingress busy seconds: {row}"));
            }
        }
    }
    // What-ifs.
    if let Some(w) = profile.opt("whatif") {
        out.push("  what-if projections (optimistic lower bounds):".to_string());
        for (key, label) in [
            ("zero_wire_secs", "zero wire cost"),
            ("zero_barrier_secs", "zero barrier wait"),
            ("balanced_learners_secs", "perfectly balanced learners"),
            ("fast_root_secs", "infinitely fast root"),
        ] {
            if let Some(v) = w.opt(key).and_then(|v| v.as_f64().ok()) {
                let speedup = if v > 0.0 { total / v } else { f64::INFINITY };
                out.push(format!(
                    "    {label:<28} {:>10} s  ({speedup:.2}x)",
                    fmt_secs(v)
                ));
            }
        }
    }
    out
}

/// Side-by-side attribution diff of two profiles (`rudra analyze --index
/// runs.jsonl I J`).
pub fn render_diff(a: &Json, a_title: &str, b: &Json, b_title: &str) -> Vec<String> {
    let mut out = Vec::new();
    let ta = get_f64(a, &["total_secs"]).unwrap_or(0.0);
    let tb = get_f64(b, &["total_secs"]).unwrap_or(0.0);
    out.push(format!("attribution diff: [A] {a_title}  vs  [B] {b_title}"));
    out.push(format!(
        "  total: A {} s, B {} s ({})",
        fmt_secs(ta),
        fmt_secs(tb),
        if ta > 0.0 { format!("B/A = {:.2}x", tb / ta) } else { "–".to_string() }
    ));
    out.push(format!(
        "  {:<16} {:>12} {:>7} {:>12} {:>7} {:>9}",
        "category", "A secs", "A %", "B secs", "B %", "Δ share"
    ));
    let rows_a = category_rows(a);
    let rows_b = category_rows(b);
    for ((name, sa), (_, sb)) in rows_a.iter().zip(rows_b.iter()) {
        let pa = if ta > 0.0 { 100.0 * sa / ta } else { 0.0 };
        let pb = if tb > 0.0 { 100.0 * sb / tb } else { 0.0 };
        out.push(format!(
            "  {name:<16} {:>12} {pa:>6.1}% {:>12} {pb:>6.1}% {:>+8.1}pp",
            fmt_secs(*sa),
            fmt_secs(*sb),
            pb - pa
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const EPS: f64 = 1e-9;

    fn total_of(p: &Profiler) -> f64 {
        p.cats.total()
    }

    /// A base-architecture round: deliver → compute → push, commit at the
    /// push arrival. The window partitions into delivery, compute, wire,
    /// and the pre-chain remainder.
    #[test]
    fn walkback_partitions_a_base_round_exactly() {
        let mut p = Profiler::new(2);
        p.note_deliver(0, 1.0, 2.0);
        p.note_compute(0, 2.0, 5.0);
        p.note_push(0, 5.0, 6.0);
        p.commit(Some(0), 6.0);
        assert!((p.cats.weight_delivery - 1.0).abs() < EPS);
        assert!((p.cats.compute - 3.0).abs() < EPS);
        assert!((p.cats.push_wire - 1.0).abs() < EPS);
        assert!((p.cats.other - 1.0).abs() < EPS, "pre-chain [0,1] is other");
        assert!((total_of(&p) - 6.0).abs() < EPS);
    }

    /// Adv* commit via a relay hop, with the gradient queued at the leaf
    /// between push arrival and relay departure.
    #[test]
    fn relay_and_leaf_queue_gap_are_attributed() {
        let mut p = Profiler::new(1);
        p.note_compute(0, 0.0, 4.0);
        p.note_push(0, 4.0, 5.0);
        p.note_relay(0, 7.0, 9.0);
        p.commit(Some(0), 9.0);
        assert!((p.cats.relay_wire - 2.0).abs() < EPS);
        assert!((p.cats.pipeline_wait - 2.0).abs() < EPS, "[5,7] queued at leaf");
        assert!((p.cats.push_wire - 1.0).abs() < EPS);
        assert!((p.cats.compute - 4.0).abs() < EPS);
        assert!((total_of(&p) - 9.0).abs() < EPS);
    }

    /// A stale relay stamp from an earlier round must not be claimed by a
    /// direct-push commit.
    #[test]
    fn stale_relay_is_skipped() {
        let mut p = Profiler::new(1);
        p.note_relay(0, 0.5, 1.0);
        p.commit(Some(0), 2.0); // earlier round commits via relay
        p.note_compute(0, 2.0, 3.0);
        p.note_push(0, 3.0, 4.0);
        p.commit(Some(0), 4.0);
        assert!((p.cats.compute - 1.0).abs() < EPS);
        assert!((p.cats.push_wire - 1.0).abs() < EPS);
        assert!((total_of(&p) - 4.0).abs() < EPS);
    }

    /// Non-wire critical time while another learner sits parked in the
    /// barrier is reassigned to barrier_wait; wire slices keep their
    /// category.
    #[test]
    fn barrier_occupancy_overrides_non_wire_slices() {
        let mut p = Profiler::new(2);
        p.note_deliver(0, 0.0, 1.0);
        p.note_compute(0, 1.0, 8.0);
        p.note_push(0, 8.0, 10.0);
        // learner 1 parks from t=4 to the commit
        p.barrier_enter(1, 4.0);
        p.commit(Some(0), 10.0);
        // compute [1,8] overlaps busy [4,10] on [4,8] → 4s barrier; wire
        // [8,10] overlaps too but stays wire.
        assert!((p.cats.compute - 3.0).abs() < EPS);
        assert!((p.cats.barrier_wait - 4.0).abs() < EPS);
        assert!((p.cats.push_wire - 2.0).abs() < EPS);
        assert!((p.cats.weight_delivery - 1.0).abs() < EPS);
        assert!((total_of(&p) - 10.0).abs() < EPS);
    }

    /// No barrier entries (1-softsync) means the barrier share is exactly
    /// zero, however long the run.
    #[test]
    fn no_barrier_entries_means_zero_barrier_share() {
        let mut p = Profiler::new(4);
        for round in 0..10 {
            let t0 = round as f64 * 5.0;
            p.note_compute(0, t0, t0 + 4.0);
            p.note_push(0, t0 + 4.0, t0 + 5.0);
            p.commit(Some(0), t0 + 5.0);
        }
        p.finish(50.0, vec![]);
        assert_eq!(p.cats.barrier_wait, 0.0);
        assert!((p.cats.total() - 50.0).abs() < EPS);
    }

    /// A killed learner abandons the barrier: occupancy closes and later
    /// windows are not poisoned.
    #[test]
    fn barrier_abandon_closes_occupancy() {
        let mut p = Profiler::new(2);
        p.barrier_enter(1, 1.0);
        p.barrier_leave(1, 2.0); // killed
        p.note_compute(0, 3.0, 5.0);
        p.note_push(0, 5.0, 6.0);
        p.commit(Some(0), 6.0);
        // only [1,2] was occupied; compute [3,5] stays compute
        assert!((p.cats.compute - 2.0).abs() < EPS);
        assert!((p.cats.barrier_wait - 0.0).abs() < EPS);
        assert!((total_of(&p) - 6.0).abs() < EPS);
    }

    /// The chainless flush (membership change) and the run tail both land
    /// in `other`, preserving the partition.
    #[test]
    fn chainless_commit_and_tail_partition_exactly() {
        let mut p = Profiler::new(2);
        p.commit(None, 3.0);
        p.finish(7.5, vec![1.0, 2.0]);
        assert!((p.cats.other - 7.5).abs() < EPS);
        assert!((p.cats.total() - p.total).abs() < EPS);
        assert_eq!(p.shard_busy, vec![1.0, 2.0]);
    }

    /// Epoch deltas tile the cumulative totals.
    #[test]
    fn epoch_deltas_sum_to_cumulative() {
        let mut p = Profiler::new(1);
        p.note_compute(0, 0.0, 2.0);
        p.note_push(0, 2.0, 3.0);
        p.commit(Some(0), 3.0);
        p.epoch(1);
        p.note_compute(0, 3.0, 6.0);
        p.note_push(0, 6.0, 7.0);
        p.commit(Some(0), 7.0);
        p.epoch(2);
        assert_eq!(p.epochs.len(), 2);
        let sum: f64 = p.epochs.iter().map(|(_, c)| c.total()).sum();
        assert!((sum - p.cats.total()).abs() < EPS);
        assert!((p.epochs[1].1.compute - 3.0).abs() < EPS);
    }

    /// Every what-if is a subset subtraction: 0 ≤ projection ≤ total.
    #[test]
    fn whatifs_are_bounded_by_baseline() {
        let mut p = Profiler::new(3);
        p.note_deliver(0, 0.0, 1.0);
        p.note_compute(0, 1.0, 9.0);
        p.note_push(0, 9.0, 10.0);
        p.barrier_enter(1, 2.0);
        p.barrier_enter(2, 3.0);
        p.commit(Some(0), 10.0);
        p.finish(11.0, vec![]);
        let (zw, zb, bal, fr) = p.whatif();
        for v in [zw, zb, bal, fr] {
            assert!(v >= -EPS && v <= p.total + EPS, "projection {v} outside [0, {}]", p.total);
        }
        // barrier occupancy was present, so zero-barrier must project a
        // real saving
        assert!(zb < p.total);
    }

    /// Blame charges the whole window to the closing learner.
    #[test]
    fn blame_charges_the_closing_learner() {
        let mut p = Profiler::new(2);
        p.note_compute(1, 0.0, 2.0);
        p.note_push(1, 2.0, 3.0);
        p.commit(Some(1), 3.0);
        assert_eq!(p.blame_commits, vec![0, 1]);
        assert!((p.blame_secs[1] - 3.0).abs() < EPS);
        assert_eq!(p.blame_secs[0], 0.0);
    }

    /// JSON round-trips with the schema the analyzer expects.
    #[test]
    fn profile_json_round_trips() {
        let mut p = Profiler::new(2);
        p.note_compute(0, 0.0, 1.0);
        p.note_push(0, 1.0, 2.0);
        p.commit(Some(0), 2.0);
        p.epoch(1);
        p.finish(2.5, vec![0.5]);
        let j = Json::parse(&p.to_json().to_string()).unwrap();
        assert_eq!(j.get("timebase").unwrap().as_str().unwrap(), "sim");
        assert_eq!(j.get("mode").unwrap().as_str().unwrap(), "critical_path");
        assert_eq!(j.get("updates").unwrap().as_u64().unwrap(), 1);
        let cats = category_rows(&j);
        let sum: f64 = cats.iter().map(|(_, s)| s).sum();
        let total = j.get("total_secs").unwrap().as_f64().unwrap();
        assert!((sum - total).abs() < EPS);
        assert_eq!(
            j.get("blame").unwrap().get("shard_busy_secs").unwrap().as_f64_vec().unwrap(),
            vec![0.5]
        );
        for key in
            ["zero_wire_secs", "zero_barrier_secs", "balanced_learners_secs", "fast_root_secs"]
        {
            let v = j.get("whatif").unwrap().get(key).unwrap().as_f64().unwrap();
            assert!(v >= 0.0 && v <= total + EPS, "{key} = {v}");
        }
    }

    /// The wall-clock aggregate variant marks itself and never claims an
    /// exact partition.
    #[test]
    fn wall_profiler_marks_aggregate_mode() {
        let mut p = WallProfiler::new(2);
        p.push(0, 0.5, 0.1);
        p.push(1, 0.7, 0.2);
        p.commit(1);
        p.barrier_wait(0.3);
        let j = Json::parse(&p.to_json(1.5).to_string()).unwrap();
        assert_eq!(j.get("mode").unwrap().as_str().unwrap(), "aggregate");
        assert_eq!(j.get("timebase").unwrap().as_str().unwrap(), "wall");
        assert!((j.get("categories").unwrap().get("compute").unwrap().as_f64().unwrap() - 1.2).abs() < EPS);
        assert_eq!(j.get("updates").unwrap().as_u64().unwrap(), 1);
    }

    /// The analyzer renders both single and diff views without panicking
    /// and carries the category vocabulary.
    #[test]
    fn analyzer_renders_tables() {
        let mut p = Profiler::new(2);
        p.note_compute(0, 0.0, 3.0);
        p.note_push(0, 3.0, 4.0);
        p.commit(Some(0), 4.0);
        p.finish(4.0, vec![1.0]);
        let j = p.to_json();
        let lines = render_analysis(&j, "hardsync λ=2");
        let text = lines.join("\n");
        for name in CATEGORY_NAMES {
            assert!(text.contains(name), "missing {name} in analysis:\n{text}");
        }
        assert!(text.contains("what-if"));
        let diff = render_diff(&j, "A", &j, "B").join("\n");
        assert!(diff.contains("Δ share") && diff.contains("compute"));
    }
}
