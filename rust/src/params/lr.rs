//! Learning-rate policies under study in the paper.
//!
//! * Baseline step schedule: α₀ dropped by 10× at fixed epochs ("reduced
//!   by a factor of 10 after the 120th and 130th epoch", §4.2).
//! * Hardsync scale-out rule: α = α₀·√(λμ/B) where B is the reference
//!   batch size (§3.2).
//! * Staleness modulation (Eq. 6): α = α₀/⟨σ⟩ = α₀/n for n-softsync —
//!   the paper's contribution #3; Figure 5 shows it rescues convergence.
//! * AdaGrad (per-coordinate, §5.5) lives in [`crate::params::optimizer`];
//!   here we only decide the scalar α fed to it.

use crate::coordinator::protocol::Protocol;

/// How the scalar learning rate is derived from (protocol, μ, λ).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Modulation {
    /// Use α₀ unmodified (the "no modulation" arm of Figure 5).
    None,
    /// Hardsync rule: α₀·√(λμ/B).
    HardsyncSqrt,
    /// Softsync rule (Eq. 6): α₀ / ⟨σ⟩ with ⟨σ⟩ = n.
    StalenessReciprocal,
    /// The paper's footnote-3 extension: "a finer-grained learning rate
    /// modulation strategy that depends on the staleness of each of [the]
    /// gradients … instead of the average staleness. Such a strategy
    /// should apply smaller learning rates to staler gradients." Each
    /// gradient is scaled by 1/(σᵢ + 1) *at fold time* (σᵢ measured
    /// against the server clock); the scalar α stays α₀.
    PerGradient,
    /// Pick the paper's default for the protocol (√-rule for hardsync,
    /// 1/⟨σ⟩ for n-softsync).
    Auto,
}

impl Modulation {
    /// Parse a config/CLI label (`none | sqrt | staleness | per-gradient |
    /// auto`, plus the aliases the config file historically accepted).
    pub fn parse(s: &str) -> anyhow::Result<Modulation> {
        match s.trim().to_ascii_lowercase().as_str() {
            "none" => Ok(Modulation::None),
            "sqrt" | "hardsync-sqrt" => Ok(Modulation::HardsyncSqrt),
            "staleness" | "reciprocal" | "1/n" => Ok(Modulation::StalenessReciprocal),
            "per-gradient" | "pergrad" => Ok(Modulation::PerGradient),
            "auto" => Ok(Modulation::Auto),
            other => anyhow::bail!(
                "unknown modulation {other:?} (none|sqrt|staleness|per-gradient|auto)"
            ),
        }
    }

    /// Canonical label; `Modulation::parse(m.label())` round-trips.
    pub fn label(&self) -> &'static str {
        match self {
            Modulation::None => "none",
            Modulation::HardsyncSqrt => "sqrt",
            Modulation::StalenessReciprocal => "staleness",
            Modulation::PerGradient => "per-gradient",
            Modulation::Auto => "auto",
        }
    }
}

/// Step-drop schedule: α is multiplied by `factor` at each epoch in
/// `drops` (paper: factor 0.1 at epochs 120 and 130 of 140).
#[derive(Debug, Clone)]
pub struct Schedule {
    pub base: f64,
    pub drops: Vec<usize>,
    pub factor: f64,
}

impl Schedule {
    pub fn constant(base: f64) -> Schedule {
        Schedule { base, drops: vec![], factor: 1.0 }
    }

    /// The paper's CIFAR10 schedule shape scaled to `epochs` total epochs:
    /// drops at ~85% and ~93% of training.
    pub fn paper_shape(base: f64, epochs: usize) -> Schedule {
        let d1 = (epochs as f64 * 120.0 / 140.0).round() as usize;
        let d2 = (epochs as f64 * 130.0 / 140.0).round() as usize;
        Schedule { base, drops: vec![d1.max(1), d2.max(2)], factor: 0.1 }
    }

    pub fn at_epoch(&self, epoch: usize) -> f64 {
        let mut a = self.base;
        for &d in &self.drops {
            if epoch >= d {
                a *= self.factor;
            }
        }
        a
    }
}

/// Full LR policy: schedule × scale-out modulation.
#[derive(Debug, Clone)]
pub struct LrPolicy {
    pub schedule: Schedule,
    pub modulation: Modulation,
    /// Reference (baseline) batch size B for the hardsync √-rule.
    pub reference_batch: usize,
}

impl LrPolicy {
    pub fn new(schedule: Schedule, modulation: Modulation, reference_batch: usize) -> Self {
        LrPolicy { schedule, modulation, reference_batch }
    }

    /// The modulation factor applied on top of the schedule.
    pub fn factor(&self, protocol: Protocol, mu: usize, lambda: usize) -> f64 {
        let eff = match self.modulation {
            Modulation::Auto => match protocol {
                // backup-sync is stale-free like hardsync; its aggregate
                // batch is the √-rule's input (with the dropped b removed)
                Protocol::Hardsync | Protocol::BackupSync { .. } => Modulation::HardsyncSqrt,
                Protocol::NSoftsync { .. } | Protocol::Async => {
                    Modulation::StalenessReciprocal
                }
            },
            m => m,
        };
        match eff {
            Modulation::None => 1.0,
            // Per-gradient scaling happens at fold time in the server
            // (see ParameterServer::push_gradient); the scalar α is α₀.
            Modulation::PerGradient => 1.0,
            Modulation::HardsyncSqrt => {
                // aggregate samples per update: λμ, minus the b dropped
                // gradients under backup-sync
                let agg = match protocol {
                    Protocol::BackupSync { b } => lambda.saturating_sub(b).max(1) * mu,
                    _ => lambda * mu,
                };
                (agg as f64 / self.reference_batch as f64).sqrt()
            }
            Modulation::StalenessReciprocal => {
                // ⟨σ⟩ = n for n-softsync (measured in §5.1); the barrier
                // protocols have σ = 0, where the rule degenerates to no
                // modulation.
                let n = match protocol {
                    Protocol::Hardsync | Protocol::BackupSync { .. } => 1,
                    Protocol::NSoftsync { n } => n.max(1),
                    Protocol::Async => lambda.max(1),
                };
                1.0 / n as f64
            }
            Modulation::Auto => unreachable!(),
        }
    }

    /// Scalar α for a weight update at `epoch` under `(protocol, μ, λ)`.
    pub fn alpha(&self, epoch: usize, protocol: Protocol, mu: usize, lambda: usize) -> f64 {
        self.schedule.at_epoch(epoch) * self.factor(protocol, mu, lambda)
    }

    /// Whether gradients are individually rescaled by staleness at fold
    /// time (the footnote-3 strategy).
    pub fn is_per_gradient(&self) -> bool {
        self.modulation == Modulation::PerGradient
    }

    /// Serialize for checkpointing: a restored server must reproduce the
    /// exact α sequence of the original run.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("base", Json::num(self.schedule.base)),
            (
                "drops",
                Json::Arr(self.schedule.drops.iter().map(|&d| Json::num(d as f64)).collect()),
            ),
            ("factor", Json::num(self.schedule.factor)),
            ("modulation", Json::str(self.modulation.label())),
            ("reference_batch", Json::num(self.reference_batch as f64)),
        ])
    }

    /// Restore from [`LrPolicy::to_json`] output.
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<LrPolicy> {
        let drops = j
            .get("drops")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<anyhow::Result<Vec<usize>>>()?;
        Ok(LrPolicy {
            schedule: Schedule {
                base: j.get("base")?.as_f64()?,
                drops,
                factor: j.get("factor")?.as_f64()?,
            },
            modulation: Modulation::parse(j.get("modulation")?.as_str()?)?,
            reference_batch: j.get("reference_batch")?.as_usize()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_drops() {
        let s = Schedule { base: 0.1, drops: vec![10, 20], factor: 0.1 };
        assert!((s.at_epoch(0) - 0.1).abs() < 1e-12);
        assert!((s.at_epoch(10) - 0.01).abs() < 1e-12);
        assert!((s.at_epoch(25) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn paper_shape_scales() {
        let s = Schedule::paper_shape(0.001, 140);
        assert_eq!(s.drops, vec![120, 130]);
        let s30 = Schedule::paper_shape(0.001, 30);
        assert_eq!(s30.drops, vec![26, 28]);
    }

    #[test]
    fn hardsync_sqrt_rule() {
        let p = LrPolicy::new(Schedule::constant(0.001), Modulation::Auto, 128);
        // λμ = B ⇒ factor 1
        assert!((p.factor(Protocol::Hardsync, 128, 1) - 1.0).abs() < 1e-12);
        // λμ = 4·128 ⇒ factor 2
        assert!((p.factor(Protocol::Hardsync, 128, 4) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn backup_sync_uses_sqrt_rule_on_the_surviving_aggregate() {
        let p = LrPolicy::new(Schedule::constant(0.001), Modulation::Auto, 128);
        // (λ − b)μ = 1·128 ⇒ factor 1 (b = 3 of λ = 4 dropped)
        let f = p.factor(Protocol::BackupSync { b: 3 }, 128, 4);
        assert!((f - 1.0).abs() < 1e-12, "{f}");
        // b = 0 matches hardsync exactly
        assert_eq!(
            p.factor(Protocol::BackupSync { b: 0 }, 128, 4),
            p.factor(Protocol::Hardsync, 128, 4)
        );
        // under the reciprocal rule, backup-sync is stale-free (n = 1)
        let p = LrPolicy::new(Schedule::constant(0.001), Modulation::StalenessReciprocal, 128);
        assert!((p.factor(Protocol::BackupSync { b: 2 }, 4, 8) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn staleness_reciprocal_rule() {
        let p = LrPolicy::new(Schedule::constant(0.001), Modulation::Auto, 128);
        let f30 = p.factor(Protocol::NSoftsync { n: 30 }, 128, 30);
        assert!((f30 - 1.0 / 30.0).abs() < 1e-12);
        let f1 = p.factor(Protocol::NSoftsync { n: 1 }, 4, 30);
        assert!((f1 - 1.0).abs() < 1e-12);
        // async degenerates to n = λ
        let fa = p.factor(Protocol::Async, 4, 30);
        assert!((fa - 1.0 / 30.0).abs() < 1e-12);
    }

    #[test]
    fn none_modulation_is_identity() {
        let p = LrPolicy::new(Schedule::constant(0.01), Modulation::None, 128);
        assert!((p.alpha(0, Protocol::NSoftsync { n: 30 }, 128, 30) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn modulation_labels_roundtrip() {
        for m in [
            Modulation::None,
            Modulation::HardsyncSqrt,
            Modulation::StalenessReciprocal,
            Modulation::PerGradient,
            Modulation::Auto,
        ] {
            assert_eq!(Modulation::parse(m.label()).unwrap(), m);
        }
        assert!(Modulation::parse("wat").is_err());
    }

    #[test]
    fn policy_json_roundtrip_reproduces_alpha() {
        let p = LrPolicy::new(
            Schedule { base: 0.02, drops: vec![8, 12], factor: 0.1 },
            Modulation::StalenessReciprocal,
            256,
        );
        let text = p.to_json().to_string();
        let back = LrPolicy::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        for epoch in [0usize, 8, 12, 20] {
            let proto = Protocol::NSoftsync { n: 4 };
            assert_eq!(p.alpha(epoch, proto, 16, 8), back.alpha(epoch, proto, 16, 8));
        }
    }
}
