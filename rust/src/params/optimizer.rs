//! Optimizers applied at the parameter server (applyUpdate, §2).
//!
//! The paper trains with momentum-accelerated mini-batch SGD (momentum
//! 0.9) and switches to AdaGrad for the ImageNet 1-softsync runs (§5.5).
//! Weight decay (0.0005 on the big model) is applied as an L2 term folded
//! into the aggregated gradient at the server.

use crate::params::FlatVec;

/// Optimizer selection + hyperparameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptimizerKind {
    /// Plain SGD: θ ← θ − α·Δ.
    Sgd,
    /// Momentum SGD: v ← m·v − α·Δ; θ ← θ + v.
    Momentum { momentum: f32 },
    /// AdaGrad: G += Δ²; θ ← θ − α·Δ/√(G + ε).
    Adagrad { eps: f32 },
}

/// Server-side optimizer state over flat vectors.
#[derive(Debug, Clone)]
pub struct Optimizer {
    pub kind: OptimizerKind,
    pub weight_decay: f32,
    /// Momentum velocity or AdaGrad accumulator, depending on `kind`.
    state: Option<FlatVec>,
}

impl Optimizer {
    pub fn new(kind: OptimizerKind, weight_decay: f32, n_params: usize) -> Optimizer {
        let state = match kind {
            OptimizerKind::Sgd => None,
            OptimizerKind::Momentum { .. } | OptimizerKind::Adagrad { .. } => {
                Some(FlatVec::zeros(n_params))
            }
        };
        Optimizer { kind, weight_decay, state }
    }

    /// The paper's CIFAR10 setup: momentum 0.9, no weight decay.
    pub fn paper_momentum(n_params: usize) -> Optimizer {
        Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, n_params)
    }

    /// The paper's ImageNet softsync setup: AdaGrad + weight decay 5e-4.
    pub fn paper_adagrad(n_params: usize) -> Optimizer {
        Optimizer::new(OptimizerKind::Adagrad { eps: 1e-8 }, 5e-4, n_params)
    }

    /// Apply one update with aggregated gradient `grad` and step size
    /// `alpha` to `theta` in place. `grad` is the protocol-averaged
    /// gradient Δθ of Eq. (3)/(5).
    pub fn apply(&mut self, theta: &mut FlatVec, grad: &FlatVec, alpha: f32) {
        debug_assert_eq!(theta.len(), grad.len());
        let wd = self.weight_decay;
        match self.kind {
            OptimizerKind::Sgd => {
                if wd == 0.0 {
                    theta.axpy(-alpha, grad);
                } else {
                    for (t, g) in theta.data.iter_mut().zip(grad.data.iter()) {
                        *t -= alpha * (g + wd * *t);
                    }
                }
            }
            OptimizerKind::Momentum { momentum } => {
                let v = self.state.as_mut().expect("momentum state");
                for ((vi, g), t) in
                    v.data.iter_mut().zip(grad.data.iter()).zip(theta.data.iter_mut())
                {
                    let g = g + wd * *t;
                    *vi = momentum * *vi - alpha * g;
                    *t += *vi;
                }
            }
            OptimizerKind::Adagrad { eps } => {
                let acc = self.state.as_mut().expect("adagrad state");
                for ((a, g), t) in
                    acc.data.iter_mut().zip(grad.data.iter()).zip(theta.data.iter_mut())
                {
                    let g = g + wd * *t;
                    *a += g * g;
                    *t -= alpha * g / (a.sqrt() + eps);
                }
            }
        }
    }

    /// Reset optimizer state (used when warm-starting switches protocol,
    /// §5.5: softsync runs warm-start from a 1-epoch hardsync model).
    pub fn reset(&mut self) {
        if let Some(s) = self.state.as_mut() {
            s.fill(0.0);
        }
    }

    /// Serialize kind, hyperparameters, and accumulated state (momentum
    /// velocity / AdaGrad accumulator) for checkpointing.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let (kind, param) = match self.kind {
            OptimizerKind::Sgd => ("sgd", 0.0f32),
            OptimizerKind::Momentum { momentum } => ("momentum", momentum),
            OptimizerKind::Adagrad { eps } => ("adagrad", eps),
        };
        Json::obj(vec![
            ("kind", Json::str(kind)),
            ("param", Json::num(param as f64)),
            ("weight_decay", Json::num(self.weight_decay as f64)),
            (
                "state",
                match &self.state {
                    Some(s) => Json::arr_f32(&s.data),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Restore from [`Optimizer::to_json`] output; the restored optimizer
    /// continues the exact update sequence.
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Optimizer> {
        let param = j.get("param")?.as_f64()? as f32;
        let kind = match j.get("kind")?.as_str()? {
            "sgd" => OptimizerKind::Sgd,
            "momentum" => OptimizerKind::Momentum { momentum: param },
            "adagrad" => OptimizerKind::Adagrad { eps: param },
            other => anyhow::bail!("unknown optimizer kind {other:?} in checkpoint"),
        };
        let state = match j.get("state")? {
            crate::util::json::Json::Null => None,
            arr => Some(FlatVec::from_vec(arr.as_f32_vec()?)),
        };
        anyhow::ensure!(
            state.is_some() == !matches!(kind, OptimizerKind::Sgd),
            "optimizer checkpoint: state presence does not match kind"
        );
        Ok(Optimizer { kind, weight_decay: j.get("weight_decay")?.as_f64()? as f32, state })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn theta3() -> FlatVec {
        FlatVec::from_vec(vec![1.0, -2.0, 0.5])
    }

    #[test]
    fn sgd_step() {
        let mut opt = Optimizer::new(OptimizerKind::Sgd, 0.0, 3);
        let mut t = theta3();
        let g = FlatVec::from_vec(vec![1.0, 1.0, 1.0]);
        opt.apply(&mut t, &g, 0.1);
        assert_eq!(t.data, vec![0.9, -2.1, 0.4]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = Optimizer::new(OptimizerKind::Momentum { momentum: 0.9 }, 0.0, 1);
        let mut t = FlatVec::from_vec(vec![0.0]);
        let g = FlatVec::from_vec(vec![1.0]);
        opt.apply(&mut t, &g, 0.1); // v = -0.1, θ = -0.1
        assert!((t.data[0] + 0.1).abs() < 1e-6);
        opt.apply(&mut t, &g, 0.1); // v = -0.19, θ = -0.29
        assert!((t.data[0] + 0.29).abs() < 1e-6);
    }

    #[test]
    fn adagrad_shrinks_steps() {
        let mut opt = Optimizer::new(OptimizerKind::Adagrad { eps: 1e-8 }, 0.0, 1);
        let mut t = FlatVec::from_vec(vec![0.0]);
        let g = FlatVec::from_vec(vec![1.0]);
        opt.apply(&mut t, &g, 0.1);
        let step1 = -t.data[0];
        let before = t.data[0];
        opt.apply(&mut t, &g, 0.1);
        let step2 = before - t.data[0];
        assert!(step2 < step1, "adagrad step should shrink: {step1} vs {step2}");
    }

    #[test]
    fn weight_decay_pulls_toward_zero() {
        let mut opt = Optimizer::new(OptimizerKind::Sgd, 0.1, 1);
        let mut t = FlatVec::from_vec(vec![1.0]);
        let g = FlatVec::zeros(1);
        opt.apply(&mut t, &g, 0.5);
        assert!(t.data[0] < 1.0 && t.data[0] > 0.0);
    }

    #[test]
    fn json_roundtrip_continues_identical_updates() {
        for kind in [
            OptimizerKind::Sgd,
            OptimizerKind::Momentum { momentum: 0.9 },
            OptimizerKind::Adagrad { eps: 1e-8 },
        ] {
            let mut a = Optimizer::new(kind, 1e-4, 3);
            let mut ta = FlatVec::from_vec(vec![1.0, -0.5, 0.25]);
            let g = FlatVec::from_vec(vec![0.3, 0.7, -0.2]);
            a.apply(&mut ta, &g, 0.1);
            let text = a.to_json().to_string();
            let mut b =
                Optimizer::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
            assert_eq!(b.kind, a.kind, "{kind:?}");
            let mut tb = ta.clone();
            a.apply(&mut ta, &g, 0.1);
            b.apply(&mut tb, &g, 0.1);
            assert_eq!(ta.data, tb.data, "{kind:?} must resume bit-identically");
        }
    }

    #[test]
    fn reset_clears_state() {
        let mut opt = Optimizer::paper_momentum(2);
        let mut t = FlatVec::zeros(2);
        opt.apply(&mut t, &FlatVec::from_vec(vec![1.0, 1.0]), 0.1);
        opt.reset();
        let mut t2 = FlatVec::zeros(2);
        opt.apply(&mut t2, &FlatVec::from_vec(vec![1.0, 1.0]), 0.1);
        assert_eq!(t.data, t2.data);
    }
}
