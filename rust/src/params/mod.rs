//! Flat parameter-vector layer.
//!
//! The parameter server treats the model exactly as the paper describes:
//! an opaque dense vector of f32 weights ("the size of pull and push
//! messages is the same as the model size plus the size of scalar
//! timestamp", §3.2). This module provides the vector type, the
//! optimizers applied at the server ([`optimizer`]), and the learning-rate
//! policies under study ([`lr`]).

pub mod lr;
pub mod optimizer;

use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

/// Magic header for weight files written by `python/compile/datagen.py`.
const WTS_MAGIC: &[u8; 8] = b"RUDRAWTS";

/// A flat f32 parameter (or gradient) vector.
#[derive(Debug, Clone, PartialEq)]
pub struct FlatVec {
    pub data: Vec<f32>,
}

impl FlatVec {
    pub fn zeros(n: usize) -> FlatVec {
        FlatVec { data: vec![0.0; n] }
    }

    pub fn from_vec(data: Vec<f32>) -> FlatVec {
        FlatVec { data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Load a `RUDRAWTS` binary (little-endian) written by the AOT step.
    pub fn load(path: &Path) -> Result<FlatVec> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening weights {}", path.display()))?;
        let mut header = [0u8; 8 + 4 + 8];
        f.read_exact(&mut header)?;
        if &header[..8] != WTS_MAGIC {
            bail!("{}: bad magic {:?}", path.display(), &header[..8]);
        }
        let ver = u32::from_le_bytes(header[8..12].try_into().unwrap());
        if ver != 1 {
            bail!("{}: unsupported version {ver}", path.display());
        }
        let n = u64::from_le_bytes(header[12..20].try_into().unwrap()) as usize;
        let mut raw = vec![0u8; n * 4];
        f.read_exact(&mut raw)
            .with_context(|| format!("{}: truncated payload", path.display()))?;
        let data = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(FlatVec { data })
    }

    /// `self += alpha * other` (the PS applyUpdate hot loop).
    pub fn axpy(&mut self, alpha: f32, other: &FlatVec) {
        self.axpy_slice(alpha, &other.data);
    }

    /// `self += alpha * other` over a raw slice — the sharded server folds
    /// each shard's contiguous gradient range without materializing a
    /// per-shard `FlatVec`.
    pub fn axpy_slice(&mut self, alpha: f32, other: &[f32]) {
        debug_assert_eq!(self.len(), other.len());
        for (a, b) in self.data.iter_mut().zip(other.iter()) {
            *a += alpha * b;
        }
    }

    /// `self *= s`.
    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Element-wise accumulate (gradient summing at the PS).
    pub fn add_assign(&mut self, other: &FlatVec) {
        self.axpy(1.0, other);
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// L2 norm (diagnostics; gradient-explosion detection).
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn axpy_and_norm() {
        let mut a = FlatVec::from_vec(vec![1.0, 2.0, 3.0]);
        let b = FlatVec::from_vec(vec![1.0, 1.0, 1.0]);
        a.axpy(-0.5, &b);
        assert_eq!(a.data, vec![0.5, 1.5, 2.5]);
        assert!((FlatVec::from_vec(vec![3.0, 4.0]).norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join("rudra_test_wts");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let vals: Vec<f32> = vec![0.5, -1.25, 3.0];
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(WTS_MAGIC).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&(vals.len() as u64).to_le_bytes()).unwrap();
        for v in &vals {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        drop(f);
        let loaded = FlatVec::load(&path).unwrap();
        assert_eq!(loaded.data, vals);
    }

    #[test]
    fn load_rejects_bad_magic() {
        let dir = std::env::temp_dir().join("rudra_test_wts");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTMAGIC\x01\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00").unwrap();
        assert!(FlatVec::load(&path).is_err());
    }

    #[test]
    fn finite_detection() {
        let mut v = FlatVec::from_vec(vec![1.0, 2.0]);
        assert!(v.is_finite());
        v.data[1] = f32::NAN;
        assert!(!v.is_finite());
    }
}
