//! Offline stub of the `xla` PJRT bindings.
//!
//! The offline build environment has no PJRT shared library, so this crate
//! mirrors the API surface `rudra::runtime` compiles against and returns a
//! descriptive error from every entry point that would touch PJRT.
//! [`PjRtClient::cpu`] failing is the load-bearing behavior: `Runtime::cpu()`
//! propagates it, `Workspace::open*` fails, and every artifact-dependent
//! test and bench takes its documented "skipping (no artifacts)" path.
//! Vendor the real bindings at this path to enable gradient execution.

use std::path::Path;

/// Debug-printable error, matching how call sites format the real crate's
/// errors (`map_err(|e| anyhow!("...: {e:?}"))`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT unavailable (offline `xla` stub; vendor the real bindings to execute graphs)"
    )))
}

/// PJRT client handle. Construction always fails in the stub.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A host literal (dense tensor value).
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        unavailable("Literal::to_tuple2")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// A device buffer produced by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_with_clear_message() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("offline `xla` stub"), "{e}");
    }

    #[test]
    fn literal_surface_compiles_for_both_dtypes() {
        let f = Literal::vec1(&[1.0f32]);
        assert!(f.reshape(&[1, 1]).is_err());
        let i = Literal::vec1(&[1i32]);
        assert!(i.to_vec::<i32>().is_err());
        assert!(Literal.to_tuple2().is_err());
    }
}
