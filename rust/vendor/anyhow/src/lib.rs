//! Offline stand-in for the `anyhow` crate, covering the API surface the
//! workspace uses: [`Error`], [`Result`], the [`Context`] extension trait
//! (on both `Result` and `Option`), and the `anyhow!` / `bail!` / `ensure!`
//! macros. Errors are stored as a flattened chain of human-readable
//! messages; `Display` renders the chain outermost-first joined by `": "`,
//! matching the `{:#}` rendering of the real crate closely enough for
//! logging and for tests that assert on message substrings.

use std::fmt;

/// A chain of error messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// Like the real crate, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes this blanket conversion (and
// therefore `?` on any std error) coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_two(s: &str) -> Result<u32> {
        let v: u32 = s.parse()?; // std error converts via `?`
        ensure!(v == 2, "expected 2, got {v}");
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_two("2").unwrap(), 2);
        let e = parse_two("x").unwrap_err();
        assert!(e.to_string().contains("invalid digit"), "{e}");
    }

    #[test]
    fn ensure_formats_message() {
        let e = parse_two("3").unwrap_err();
        assert!(e.to_string().contains("expected 2, got 3"), "{e}");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");

        let ok: Result<u32> = Ok(7);
        assert_eq!(ok.context("ignored").unwrap(), 7);
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("flagged {}", 42);
            }
            Err(anyhow!("plain"))
        }
        assert_eq!(f(true).unwrap_err().to_string(), "flagged 42");
        assert_eq!(f(false).unwrap_err().to_string(), "plain");
    }

    #[test]
    fn chain_iterates_outermost_first() {
        let e = Error::msg("root").context("mid").context("top");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["top", "mid", "root"]);
        assert_eq!(format!("{e:#}"), "top: mid: root");
    }
}
